//! Method comparison on perplexity (the Table-2 experience, sized to run
//! in about a minute): Full vs Exact-TopK vs H2O vs Loki at k_f = 0.25,
//! d_f = 0.25 on the wiki eval split.
//!
//!     cargo run --release --example compare_methods [-- --docs 8 --tokens 160]

use loki::data::EvalDocs;
use loki::eval::{perplexity, VariantSpec};
use loki::runtime::RuntimeStack;
use loki::util::args::Args;
use loki::util::artifacts_dir;
use loki::util::table::{fnum, Table};

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let n_docs = args.usize_or("docs", 8);
    let max_tokens = args.usize_or("tokens", 160);
    let stack = RuntimeStack::load(&artifacts_dir())?;
    let man = stack.manifest.clone();
    let docs = EvalDocs::load(&artifacts_dir(), "wiki")?;
    let docs: Vec<Vec<i32>> = docs.docs.into_iter().take(n_docs).collect();

    let variants = vec![
        ("Full Attention", VariantSpec::Full),
        ("Exact-TopK k=0.25", VariantSpec::TopK { k_f: 0.25 }),
        ("H2O k=0.25", VariantSpec::H2o { k_f: 0.25 }),
        ("Loki k=0.25 d=0.25", VariantSpec::Loki { k_f: 0.25, d_f: 0.25 }),
        ("PCAAttn d=0.25", VariantSpec::PcaAttn { d_f: 0.25 }),
    ];
    let mut table = Table::new(
        "Perplexity comparison (wiki eval split; lower is better)",
        &["method", "ppl", "Δ vs full", "eval s"],
    );
    let mut full_ppl = f64::NAN;
    for (label, variant) in variants {
        let rep = perplexity(&stack, &man.default_pca, &variant, &docs, 16, max_tokens)?;
        let ppl = rep.perplexity();
        if label == "Full Attention" {
            full_ppl = ppl;
        }
        table.row(vec![
            label.to_string(),
            fnum(ppl, 4),
            fnum(ppl - full_ppl, 4),
            fnum(rep.wall_s, 1),
        ]);
        println!("  {label}: ppl {ppl:.4} ({} tokens)", rep.n_tokens);
    }
    table.emit("compare_methods_example");
    Ok(())
}
