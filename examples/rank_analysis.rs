//! Dimensionality analysis walkthrough (paper §3): recompute PCA on the
//! exported key dumps with the Rust eigensolver and print the layer-wise
//! Rank@90 table for pre- vs post-rotary keys across calibration corpora.
//!
//!     cargo run --release --example rank_analysis [-- --v 90]

use loki::analysis::rank::rank_table;
use loki::analysis::KeyDump;
use loki::util::args::Args;
use loki::util::artifacts_dir;
use loki::util::table::{fnum, Table};

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let v = args.f64_or("v", 90.0);
    let dir = artifacts_dir();
    let profiles = ["wiki", "web", "book"];

    let mut table = Table::new(
        &format!("Rank@{v:.0} of attention keys per layer (head-mean)"),
        &["layer", "wiki pre", "wiki post", "web pre", "web post", "book pre", "book post"],
    );
    let mut per_profile = Vec::new();
    for prof in profiles {
        let path = dir.join(format!("keys_{prof}.npz"));
        let pre = KeyDump::load(&path, "k_pre")?;
        let post = KeyDump::load(&path, "k_post")?;
        let rp = rank_table(&pre.pca_all(), v);
        let ro = rank_table(&post.pca_all(), v);
        per_profile.push((rp, ro));
    }
    let layers = per_profile[0].0.per_layer.len();
    let dim = per_profile[0].0.dim;
    for l in 0..layers {
        let mut row = vec![format!("{l}")];
        for (rp, ro) in &per_profile {
            row.push(fnum(rp.per_layer[l], 1));
            row.push(fnum(ro.per_layer[l], 1));
        }
        table.row(row);
    }
    let mut mean_row = vec!["mean".to_string()];
    for (rp, ro) in &per_profile {
        mean_row.push(fnum(rp.model_mean(), 1));
        mean_row.push(fnum(ro.model_mean(), 1));
    }
    table.row(mean_row);
    table.emit("rank_analysis_example");
    println!("full head dimension D = {dim} — keys sit well below it, and");
    println!("the per-layer profile is consistent across calibration corpora");
    println!("(the paper's §3 findings, reproduced on our trained model).");
    Ok(())
}
