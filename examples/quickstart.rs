//! Quickstart: load the AOT artifacts, start the serving engine, and
//! generate text with Loki sparse attention.
//!
//!     make artifacts && cargo run --release --example quickstart
//!
//! This is the 60-second tour: one request through the full stack —
//! coordinator → runtime thread → compiled HLO (JAX model + Pallas
//! decode-attention kernels) → logits → sampler.

use std::sync::mpsc::channel;

use loki::coordinator::request::{GenRequest, Priority};
use loki::coordinator::sampler::SampleCfg;
use loki::coordinator::{Engine, EngineConfig};
use loki::model::ByteTokenizer;
use loki::runtime::{DecodeVariant, RuntimeService};
use loki::util::artifacts_dir;

fn main() -> anyhow::Result<()> {
    // 1. Start the runtime thread (owns the PJRT client + weights).
    let service = RuntimeService::start(artifacts_dir())?;
    println!(
        "loaded {} ({} layers, head_dim {}, max_len {})",
        service.manifest.model.name,
        service.manifest.model.n_layers,
        service.manifest.model.head_dim,
        service.manifest.model.max_len
    );

    // 2. Configure the engine: Loki attention at the paper's headline
    //    setting (k_f = 0.25 of the cache, d_f = 0.25 of head_dim —
    //    theoretical speedup 1/(d_f/2 + k_f) ≈ 2.67x).
    let cfg = EngineConfig {
        variant: DecodeVariant::loki_fractions(&service.manifest, 0.25, 0.25),
        ..Default::default()
    };
    let engine = Engine::new(&service, cfg.clone());

    // 3. Submit a prompt and run the engine until it drains.
    let tok = ByteTokenizer;
    let (tx, rx) = Engine::channel(&cfg);
    let (reply, results) = channel();
    tx.send(GenRequest {
        id: 1,
        prompt: tok.encode("the code of "),
        max_new_tokens: 40,
        stop_token: Some(b'\n' as i32),
        sampling: SampleCfg::greedy(),
        priority: Priority::Interactive,
        slo_ms: None,
        reply,
    })?;
    drop(tx); // closing the queue lets engine.run() return when done

    let metrics = engine.run(rx)?;
    let result = results.recv()?;
    println!("\n--- generation -------------------------------------------");
    println!("prompt : \"the code of \"");
    println!("output : \"{}\"", result.text);
    println!("reason : {:?}", result.finished_reason);
    println!("\n--- engine metrics ---------------------------------------");
    println!("{}", metrics.report());
    Ok(())
}
