//! End-to-end serving driver (the repo's E2E validation workload):
//! load the compiled model, serve a batched trace of requests through the
//! continuous-batching engine under BOTH full attention and Loki, and
//! report latency/throughput side by side.
//!
//!     cargo run --release --example serve_batch -- [--requests 24] [--rate 4]
//!
//! The run is recorded in EXPERIMENTS.md §E2E.

use std::sync::mpsc::channel;

use loki::coordinator::request::{GenRequest, Priority};
use loki::coordinator::sampler::SampleCfg;
use loki::coordinator::{Engine, EngineConfig, SchedulerPolicy};
use loki::data::workload::{Workload, WorkloadCfg};
use loki::data::TaskSuite;
use loki::model::ByteTokenizer;
use loki::runtime::{DecodeVariant, RuntimeService};
use loki::util::args::Args;
use loki::util::artifacts_dir;
use loki::util::json::{self, Json};

fn run_trace(
    service: &RuntimeService,
    label: &str,
    variant: DecodeVariant,
    wl: &Workload,
) -> anyhow::Result<Json> {
    let cfg = EngineConfig {
        variant,
        scheduler: SchedulerPolicy::PrefillFirst,
        ..Default::default()
    };
    let engine = Engine::new(service, cfg.clone());
    let (tx, rx) = Engine::channel(&cfg);
    let tok = ByteTokenizer;
    let items = wl.items.clone();
    let (reply, results) = channel();
    let submitter = std::thread::spawn(move || {
        let start = std::time::Instant::now();
        for (i, item) in items.iter().enumerate() {
            let wait = item.arrival_s - start.elapsed().as_secs_f64();
            if wait > 0.0 {
                std::thread::sleep(std::time::Duration::from_secs_f64(wait));
            }
            tx.send(GenRequest {
                id: i as u64,
                prompt: tok.encode(&item.prompt),
                max_new_tokens: item.max_new_tokens,
                stop_token: None,
                sampling: SampleCfg::greedy(),
                priority: Priority::Interactive,
                slo_ms: None,
                reply: reply.clone(),
            })
            .expect("engine queue");
        }
    });
    let metrics = engine.run(rx)?;
    submitter.join().unwrap();
    let n_results = results.try_iter().count();

    println!("\n=== {label} ===============================================");
    println!("{}", metrics.report());
    assert_eq!(n_results as u64, metrics.requests_done);
    Ok(json::obj(vec![
        ("label", json::s(label)),
        ("requests", json::num(metrics.requests_done as f64)),
        ("tokens", json::num(metrics.tokens_generated as f64)),
        ("throughput_tok_s", json::num(metrics.throughput_tok_s())),
        ("ttft_p50_s", json::num(metrics.ttft.percentile(50.0))),
        ("ttft_p95_s", json::num(metrics.ttft.percentile(95.0))),
        ("e2e_p50_s", json::num(metrics.e2e_latency.percentile(50.0))),
        ("e2e_p95_s", json::num(metrics.e2e_latency.percentile(95.0))),
        ("step_p50_s", json::num(metrics.decode_step_time.percentile(50.0))),
        ("injections", json::num(metrics.injections as f64)),
    ]))
}

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let service = RuntimeService::start(artifacts_dir())?;
    let suite = TaskSuite::load(&artifacts_dir())?;
    let wl = Workload::generate(
        &WorkloadCfg {
            n_requests: args.usize_or("requests", 24),
            rate: args.f64_or("rate", 0.0),
            burst_p: args.f64_or("burst", 0.0),
            prompt_len: (48, 220),
            gen_len: (12, 48),
            gen_len_dist: loki::data::workload::GenLenDist::Uniform,
            shared_prefix_len: args.usize_or("shared-prefix", 0),
            batch_frac: 0.0,
            slo_ms_interactive: None,
            slo_ms_batch: None,
            slo_jitter_frac: 0.0,
            seed: 7,
        },
        &suite.fillers,
    );
    println!(
        "trace: {} requests over {:.1}s (rate {})",
        wl.items.len(),
        wl.duration_s(),
        args.f64_or("rate", 0.0)
    );

    let man = &service.manifest;
    let runs = vec![
        ("full", DecodeVariant::Full),
        ("loki k=0.25 d=0.25", DecodeVariant::loki_fractions(man, 0.25, 0.25)),
        ("loki k=0.125 d=0.5", DecodeVariant::loki_fractions(man, 0.125, 0.5)),
    ];
    let mut reports = Vec::new();
    for (label, variant) in runs {
        reports.push(run_trace(&service, label, variant, &wl)?);
    }
    let out = json::arr(reports);
    let path = loki::util::results_dir().join("e2e_serving.json");
    std::fs::write(&path, out.to_string())?;
    println!("\nwrote {}", path.display());
    Ok(())
}
