//! Stub of the `xla` PJRT bindings (see README.md).
//!
//! Type-level drop-in for the surface `crate::runtime`, `analysis` and
//! `data` code against: construction/execution entry points return
//! [`Error`] describing the missing native backend instead of linking the
//! PJRT C++ client. Callers already treat every one of these operations
//! as fallible, so the degradation is clean: `RuntimeStack::load` fails
//! with a clear message, and artifact-gated tests skip long before
//! reaching it.

use std::fmt;
use std::path::Path;

const STUB_MSG: &str =
    "xla stub: native PJRT backend not vendored in this checkout (artifact-gated paths only)";

#[derive(Clone, Debug)]
pub struct Error(String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for Error {}

fn stub_err<T>(what: &str) -> Result<T> {
    Err(Error(format!("{STUB_MSG}: {what}")))
}

pub type Result<T> = std::result::Result<T, Error>;

/// Element types a [`Literal`] / host buffer can hold.
pub trait ArrayElement: Copy {}

impl ArrayElement for f32 {}
impl ArrayElement for f64 {}
impl ArrayElement for i32 {}
impl ArrayElement for i64 {}
impl ArrayElement for u8 {}

/// Shape of a dense array: dimension sizes in row-major order.
#[derive(Clone, Debug)]
pub struct ArrayShape {
    dims: Vec<i64>,
}

impl ArrayShape {
    pub fn dims(&self) -> &[i64] {
        &self.dims
    }
}

/// Host-side tensor value.
pub struct Literal(());

impl Literal {
    pub fn array_shape(&self) -> Result<ArrayShape> {
        stub_err("Literal::array_shape")
    }

    pub fn to_vec<T: ArrayElement>(&self) -> Result<Vec<T>> {
        stub_err("Literal::to_vec")
    }
}

/// Loading tensors out of `.npz` archives, generic over the destination
/// (host [`Literal`] with `()` context, device [`PjRtBuffer`] with a
/// [`PjRtClient`] context).
pub trait FromRawBytes: Sized {
    type Context;

    fn read_npz_by_name<P: AsRef<Path>>(
        path: P,
        ctx: &Self::Context,
        names: &[&str],
    ) -> Result<Vec<Self>>;
}

impl FromRawBytes for Literal {
    type Context = ();

    fn read_npz_by_name<P: AsRef<Path>>(
        path: P,
        _ctx: &Self::Context,
        _names: &[&str],
    ) -> Result<Vec<Self>> {
        stub_err(&format!("Literal::read_npz_by_name({})", path.as_ref().display()))
    }
}

impl FromRawBytes for PjRtBuffer {
    type Context = PjRtClient;

    fn read_npz_by_name<P: AsRef<Path>>(
        path: P,
        _ctx: &Self::Context,
        _names: &[&str],
    ) -> Result<Vec<Self>> {
        stub_err(&format!("PjRtBuffer::read_npz_by_name({})", path.as_ref().display()))
    }
}

/// Parsed HLO module text.
pub struct HloModuleProto(());

impl HloModuleProto {
    pub fn from_text_file<P: AsRef<Path>>(path: P) -> Result<Self> {
        stub_err(&format!("HloModuleProto::from_text_file({})", path.as_ref().display()))
    }
}

/// A computation ready for compilation.
pub struct XlaComputation(());

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> Self {
        // Unreachable in practice: an `HloModuleProto` can only come from
        // `from_text_file`, which always errors in the stub.
        XlaComputation(())
    }
}

/// Device-resident buffer handle.
pub struct PjRtBuffer(());

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        stub_err("PjRtBuffer::to_literal_sync")
    }
}

/// Compiled executable handle.
pub struct PjRtLoadedExecutable(());

impl PjRtLoadedExecutable {
    pub fn execute_b(&self, _args: &[&PjRtBuffer]) -> Result<Vec<Vec<PjRtBuffer>>> {
        stub_err("PjRtLoadedExecutable::execute_b")
    }
}

/// PJRT client handle (thread-confined in the real bindings).
pub struct PjRtClient(());

impl PjRtClient {
    pub fn cpu() -> Result<Self> {
        stub_err("PjRtClient::cpu")
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        stub_err("PjRtClient::compile")
    }

    pub fn buffer_from_host_buffer<T: ArrayElement>(
        &self,
        _data: &[T],
        _dims: &[usize],
        _device: Option<usize>,
    ) -> Result<PjRtBuffer> {
        stub_err("PjRtClient::buffer_from_host_buffer")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn entry_points_error_with_context() {
        let e = PjRtClient::cpu().unwrap_err();
        assert!(e.to_string().contains("PjRtClient::cpu"));
        let e = Literal::read_npz_by_name("a/b.npz", &(), &["x"]).unwrap_err();
        assert!(e.to_string().contains("a/b.npz"));
    }
}
