//! Vendored, dependency-free subset of the `anyhow` crate.
//!
//! The checkout must build with no network and no registry, so instead of
//! the crates.io `anyhow` this crate provides the (small) API surface the
//! workspace actually uses: [`Error`], [`Result`], the [`Context`] trait,
//! and the `anyhow!` / `bail!` / `ensure!` macros. Semantics match the
//! real crate for that surface: errors carry their rendered message and
//! context chain; `?` converts any `std::error::Error` automatically.

use std::fmt;

/// A flattened error: the rendered message of whatever it was built from,
/// with `context(..)` layers prepended `outer: inner` like anyhow's `{:#}`.
pub struct Error {
    msg: String,
}

impl Error {
    pub fn msg<M: fmt::Display>(m: M) -> Self {
        Self { msg: m.to_string() }
    }

    /// Prepend a context layer (what `Context::context` does).
    fn wrap<C: fmt::Display>(self, ctx: C) -> Self {
        Self { msg: format!("{ctx}: {}", self.msg) }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

// NOTE: like the real anyhow, `Error` deliberately does NOT implement
// `std::error::Error` — that is what makes the blanket `From` below
// coherent with core's reflexive `impl From<T> for T`.
impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Self {
        Error::msg(e)
    }
}

pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Attach context to failures of a `Result` or emptiness of an `Option`.
pub trait Context<T>: Sized {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T, Error>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error>;
}

impl<T, E: fmt::Display> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T, Error> {
        self.map_err(|e| Error::msg(e).wrap(ctx))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.map_err(|e| Error::msg(e).wrap(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(ctx))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(::std::format!($($arg)*))
    };
}

/// Return early with a formatted [`Error`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return ::std::result::Result::Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with a formatted [`Error`] unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            $crate::bail!($($arg)*);
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_fail() -> Result<()> {
        Err(std::io::Error::other("boom"))?;
        Ok(())
    }

    #[test]
    fn question_mark_converts_std_errors() {
        let e = io_fail().unwrap_err();
        assert_eq!(e.to_string(), "boom");
    }

    #[test]
    fn context_layers_prepend() {
        let r: Result<(), std::fmt::Error> = Err(std::fmt::Error);
        let e = r.context("outer").unwrap_err();
        assert!(e.to_string().starts_with("outer: "));
        let o: Option<u32> = None;
        let e = o.with_context(|| format!("missing {}", 7)).unwrap_err();
        assert_eq!(e.to_string(), "missing 7");
    }

    #[test]
    fn macros_format() {
        let e = anyhow!("x = {}", 3);
        assert_eq!(e.to_string(), "x = 3");
        fn f(flag: bool) -> Result<u32> {
            ensure!(flag, "flag was {flag}");
            if !flag {
                bail!("unreachable");
            }
            Ok(1)
        }
        assert_eq!(f(true).unwrap(), 1);
        assert_eq!(f(false).unwrap_err().to_string(), "flag was false");
    }
}
