//! `cargo bench --bench topk_bench` — top-k selection-algorithm ablation.
//!
//! §6.4 of the paper observes PyTorch's top-k costs as much as the sparse
//! matmuls and leaves a custom kernel as future work; this bench is that
//! investigation: full sort vs bounded heap vs quickselect across cache
//! sizes and k fractions (the decode-time selection shapes).

use loki::linalg::topk::{top_k_indices, TopKAlgo};
use loki::util::bench::{bench, BenchConfig};
use loki::util::rng::Xoshiro256;
use loki::util::table::{fnum, Table};

fn main() {
    let quick = std::env::args().any(|a| a == "--quick") || std::env::var("LOKI_QUICK").is_ok();
    let seqs: &[usize] = if quick { &[1024, 4096] } else { &[512, 1024, 2048, 4096, 8192] };
    let kfs = [0.125, 0.25, 0.5];
    let cfg = if quick { BenchConfig::quick() } else { BenchConfig::default() };

    let mut table = Table::new(
        "Top-k selection algorithms over decode score vectors (µs per lane)",
        &["S", "k_f", "sort µs", "heap µs", "quickselect µs", "best"],
    );
    let mut rng = Xoshiro256::new(1);
    for &s in seqs {
        let scores = rng.normal_vec(s);
        for &kf in &kfs {
            let k = ((s as f64 * kf) as usize).max(1);
            let t_sort = bench("sort", &cfg, || {
                std::hint::black_box(top_k_indices(TopKAlgo::Sort, &scores, k));
            })
            .median_secs();
            let t_heap = bench("heap", &cfg, || {
                std::hint::black_box(top_k_indices(TopKAlgo::Heap, &scores, k));
            })
            .median_secs();
            let t_qs = bench("quickselect", &cfg, || {
                std::hint::black_box(top_k_indices(TopKAlgo::QuickSelect, &scores, k));
            })
            .median_secs();
            let best = [("sort", t_sort), ("heap", t_heap), ("quickselect", t_qs)]
                .iter()
                .min_by(|a, b| a.1.total_cmp(&b.1))
                .unwrap()
                .0
                .to_string();
            table.row(vec![
                format!("{s}"),
                format!("{kf}"),
                fnum(t_sort * 1e6, 1),
                fnum(t_heap * 1e6, 1),
                fnum(t_qs * 1e6, 1),
                best,
            ]);
        }
    }
    table.emit("topk_bench");
}
