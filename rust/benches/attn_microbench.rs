//! `cargo bench --bench attn_microbench` — regenerates Figure 7's
//! attention-time microbenchmark (vanilla vs Loki configurations at
//! Llama2-13B shape) plus the (k_f, d_f) time sweep of Fig 7 (right).
//!
//! Equivalent to `repro-experiments fig7 fig7-tradeoff`; kept as a bench
//! target so `make bench` covers every timing figure.

fn main() -> anyhow::Result<()> {
    let quick = std::env::args().any(|a| a == "--quick") || std::env::var("LOKI_QUICK").is_ok();
    println!("# Fig 7 attention microbenchmark (quick={quick})");
    loki::experiments::fig7_attn_time::run(quick)?;
    loki::experiments::fig7_attn_time::run_tradeoff(quick)?;
    Ok(())
}
