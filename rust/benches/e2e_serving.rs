//! `cargo bench --bench e2e_serving` — end-to-end serving throughput and
//! latency through the full stack (coordinator → runtime thread → compiled
//! HLO), full attention vs Loki. Numbers feed Figure 6 (right)'s
//! serving-stack contrast and EXPERIMENTS.md §E2E.

use std::sync::mpsc::channel;

use loki::coordinator::request::GenRequest;
use loki::coordinator::sampler::SampleCfg;
use loki::coordinator::{Engine, EngineConfig};
use loki::data::workload::{Workload, WorkloadCfg};
use loki::data::TaskSuite;
use loki::model::ByteTokenizer;
use loki::runtime::{DecodeVariant, RuntimeService};
use loki::util::artifacts_dir;
use loki::util::table::{fnum, Table};

fn main() -> anyhow::Result<()> {
    let quick = std::env::args().any(|a| a == "--quick") || std::env::var("LOKI_QUICK").is_ok();
    if !artifacts_dir().join("manifest.json").exists() {
        eprintln!("skipping e2e_serving: run `make artifacts` first");
        return Ok(());
    }
    let service = RuntimeService::start(artifacts_dir())?;
    let suite = TaskSuite::load(&artifacts_dir())?;
    let n = if quick { 8 } else { 24 };
    let wl = Workload::generate(
        &WorkloadCfg {
            n_requests: n,
            rate: 0.0,
            burst_p: 0.0,
            prompt_len: (48, 200),
            gen_len: (12, 40),
            seed: 3,
        },
        &suite.fillers,
    );

    let man = service.manifest.clone();
    let mut table = Table::new(
        "E2E serving: full vs Loki through the coordinator",
        &["variant", "tok/s", "ttft p50 s", "e2e p95 s", "step p50 ms", "injections"],
    );
    for (label, variant) in [
        ("full", DecodeVariant::Full),
        ("loki .25/.25", DecodeVariant::loki_fractions(&man, 0.25, 0.25)),
    ] {
        let cfg = EngineConfig { variant, ..Default::default() };
        let engine = Engine::new(&service, cfg.clone());
        let (tx, rx) = Engine::channel(&cfg);
        let tok = ByteTokenizer;
        let (reply, _results) = channel();
        for (i, item) in wl.items.iter().enumerate() {
            tx.send(GenRequest {
                id: i as u64,
                prompt: tok.encode(&item.prompt),
                max_new_tokens: item.max_new_tokens,
                stop_token: None,
                sampling: SampleCfg::greedy(),
                reply: reply.clone(),
            })?;
        }
        drop(tx);
        let m = engine.run(rx)?;
        table.row(vec![
            label.to_string(),
            fnum(m.throughput_tok_s(), 1),
            fnum(m.ttft.percentile(50.0), 3),
            fnum(m.e2e_latency.percentile(95.0), 3),
            fnum(m.decode_step_time.percentile(50.0) * 1e3, 1),
            format!("{}", m.injections),
        ]);
    }
    table.emit("e2e_serving_bench");
    Ok(())
}
