//! `cargo bench --bench e2e_serving` — end-to-end serving throughput and
//! latency through the full stack (coordinator → runtime thread → compiled
//! HLO), full attention vs Loki. Numbers feed Figure 6 (right)'s
//! serving-stack contrast and EXPERIMENTS.md §E2E.
//!
//! Scenario 2 drives a multi-tenant shared-system-prompt trace through
//! the engine's KV-pool admission layer (prefix sharing on vs off) and
//! reports peak resident pool bytes against the flat per-lane cache the
//! pool replaced — the serving-level counterpart of
//! `kvpool_bench::shared_prefix_residency`.

use std::sync::mpsc::channel;

use loki::coordinator::request::{GenRequest, Priority};
use loki::coordinator::sampler::SampleCfg;
use loki::coordinator::{
    AdmissionPolicy, Engine, EngineConfig, EngineMetrics, PoolConfig, PreemptMode, VictimPolicy,
};
use loki::data::workload::{GenLenDist, Workload, WorkloadCfg};
use loki::data::TaskSuite;
use loki::model::ByteTokenizer;
use loki::runtime::{DecodeVariant, RuntimeService};
use loki::util::artifacts_dir;
use loki::util::table::{fnum, Table};

fn run_trace(
    service: &RuntimeService,
    cfg: EngineConfig,
    wl: &Workload,
) -> anyhow::Result<EngineMetrics> {
    let engine = Engine::new(service, cfg.clone());
    let (tx, rx) = Engine::channel(&cfg);
    let tok = ByteTokenizer;
    let (reply, _results) = channel();
    for (i, item) in wl.items.iter().enumerate() {
        tx.send(GenRequest {
            id: i as u64,
            prompt: tok.encode(&item.prompt),
            max_new_tokens: item.max_new_tokens,
            stop_token: None,
            sampling: SampleCfg::greedy(),
            priority: item.priority,
            reply: reply.clone(),
        })?;
    }
    drop(tx);
    engine.run(rx)
}

fn main() -> anyhow::Result<()> {
    let quick = std::env::args().any(|a| a == "--quick") || std::env::var("LOKI_QUICK").is_ok();
    if !artifacts_dir().join("manifest.json").exists() {
        eprintln!("skipping e2e_serving: run `make artifacts` first");
        return Ok(());
    }
    let service = RuntimeService::start(artifacts_dir())?;
    let suite = TaskSuite::load(&artifacts_dir())?;
    let n = if quick { 8 } else { 24 };
    let wl = Workload::generate(
        &WorkloadCfg {
            n_requests: n,
            rate: 0.0,
            burst_p: 0.0,
            prompt_len: (48, 200),
            gen_len: (12, 40),
            gen_len_dist: GenLenDist::Uniform,
            shared_prefix_len: 0,
            batch_frac: 0.0,
            seed: 3,
        },
        &suite.fillers,
    );

    let man = service.manifest.clone();
    let mut table = Table::new(
        "E2E serving: full vs Loki through the coordinator",
        &["variant", "tok/s", "ttft p50 s", "e2e p95 s", "step p50 ms", "injections"],
    );
    for (label, variant) in [
        ("full", DecodeVariant::Full),
        ("loki .25/.25", DecodeVariant::loki_fractions(&man, 0.25, 0.25)),
    ] {
        let cfg = EngineConfig { variant, ..Default::default() };
        let m = run_trace(&service, cfg, &wl)?;
        table.row(vec![
            label.to_string(),
            fnum(m.throughput_tok_s(), 1),
            fnum(m.ttft.percentile(50.0), 3),
            fnum(m.e2e_latency.percentile(95.0), 3),
            fnum(m.decode_step_time.percentile(50.0) * 1e3, 1),
            format!("{}", m.injections),
        ]);
    }
    table.emit("e2e_serving_bench");

    // ---- Scenario 2: shared system prompt through pool admission ------
    let shared_wl = Workload::generate(
        &WorkloadCfg {
            n_requests: if quick { 8 } else { 32 },
            rate: 0.0,
            burst_p: 0.0,
            prompt_len: (16, 48),
            gen_len: (8, 24),
            gen_len_dist: GenLenDist::Uniform,
            shared_prefix_len: 96,
            batch_frac: 0.0,
            seed: 7,
        },
        &suite.fillers,
    );
    let mut table = Table::new(
        "E2E serving: shared 96-byte system prompt, KV-pool residency",
        &[
            "prefix sharing",
            "peak pool MB",
            "flat cache MB",
            "savings",
            "shared blocks",
            "blocked",
        ],
    );
    for (label, sharing) in [("on", true), ("off", false)] {
        let cfg = EngineConfig {
            variant: DecodeVariant::loki_fractions(&man, 0.25, 0.25),
            pool: PoolConfig { block_size: 16, num_blocks: 0, prefix_sharing: sharing },
            ..Default::default()
        };
        let m = run_trace(&service, cfg, &shared_wl)?;
        table.row(vec![
            label.to_string(),
            fnum(m.kv_resident_bytes_peak() as f64 / 1e6, 2),
            fnum(m.kv_flat_bytes as f64 / 1e6, 2),
            format!("{:.2}x", m.kv_savings_vs_flat()),
            format!("{}", m.prefix_shared_blocks),
            format!("{}", m.admission_blocked),
        ]);
    }
    table.emit("e2e_serving_sharing");
    println!(
        "(peak pool bytes mirror granted blocks × per-block KV bytes; the\n\
         flat baseline is the gang-wide [lanes, max_len, D] cache the\n\
         lane_reset_frac era preallocated)"
    );

    // ---- Scenario 3: long-tail decode budgets through a constrained ---
    // pool — ReserveFull prices every request at its worst case and
    // blocks the queue; Speculative admits on a partial reservation,
    // grows at decode time and preempts under pressure. Deterministic
    // twins of this comparison (byte-identical outputs, strictly higher
    // occupancy) run artifact-free in rust/tests/engine_admission.rs.
    let bs = 16usize;
    let gang = man.batch_buckets.iter().copied().max().unwrap_or(1);
    let worst_case_blocks = gang * man.model.max_len.div_ceil(bs);
    let constrained = (worst_case_blocks / 2).max(gang * 2);
    let tail_cap = (man.model.max_len / 2).max(8);
    let tail_wl = Workload::generate(
        &WorkloadCfg {
            n_requests: if quick { 8 } else { 32 },
            rate: 0.0,
            burst_p: 0.0,
            prompt_len: (24, 64),
            gen_len: (8, 8), // ignored under LongTail
            gen_len_dist: GenLenDist::LongTail { mean: 24.0, cap: tail_cap },
            shared_prefix_len: 0,
            batch_frac: 0.0,
            seed: 11,
        },
        &suite.fillers,
    );
    let mut table = Table::new(
        "E2E serving: long-tail max_new, ReserveFull vs Speculative admission",
        &["policy", "tok/s", "mean occ %", "peak blocks", "preempts", "resumes", "blocked"],
    );
    for (label, admission) in [
        ("reserve-full", AdmissionPolicy::ReserveFull),
        (
            "speculative .25",
            AdmissionPolicy::Speculative { reserve_frac: 0.25, headroom_blocks: 2 },
        ),
    ] {
        let cfg = EngineConfig {
            variant: DecodeVariant::loki_fractions(&man, 0.25, 0.25),
            pool: PoolConfig { block_size: bs, num_blocks: constrained, prefix_sharing: true },
            admission,
            ..Default::default()
        };
        let m = run_trace(&service, cfg, &tail_wl)?;
        table.row(vec![
            label.to_string(),
            fnum(m.throughput_tok_s(), 1),
            fnum(m.mean_pool_occupancy() * 100.0, 1),
            format!("{}/{}", m.pool_blocks_peak, m.pool_blocks_total),
            format!("{}", m.preemptions),
            format!("{}", m.resumes),
            format!("{}", m.admission_blocked),
        ]);
    }
    table.emit("e2e_serving_longtail");
    println!(
        "(mean occ counts only blocks holding real KV: reserved-but-\n\
         unwritten blocks are exactly the waste speculative admission\n\
         reclaims under long-tail decode budgets)"
    );

    // ---- Scenario 4: contended mixed-priority traffic — full vs -------
    // partial preemption under the priority-aware victim policy. The
    // interesting deltas: how much resume recompute partial preemption
    // avoids, and how far interactive TTFT sits below batch TTFT when
    // the scheduler is allowed to see classes. Deterministic twins of
    // the acceptance assertions live in rust/tests/engine_admission.rs.
    let mixed_wl = Workload::generate(
        &WorkloadCfg {
            n_requests: if quick { 8 } else { 32 },
            rate: 0.0,
            burst_p: 0.0,
            prompt_len: (24, 64),
            gen_len: (8, 8), // ignored under LongTail
            gen_len_dist: GenLenDist::LongTail { mean: 24.0, cap: tail_cap },
            shared_prefix_len: 0,
            batch_frac: 0.5,
            seed: 17,
        },
        &suite.fillers,
    );
    let mut table = Table::new(
        "E2E serving: mixed-priority contention, full vs partial preemption",
        &[
            "preempt",
            "tok/s",
            "preempts",
            "partial",
            "recomputed tok",
            "saved tok",
            "int ttft p50",
            "batch ttft p50",
        ],
    );
    for (label, preempt) in [("full", PreemptMode::Full), ("partial", PreemptMode::Partial)] {
        let cfg = EngineConfig {
            variant: DecodeVariant::loki_fractions(&man, 0.25, 0.25),
            pool: PoolConfig { block_size: bs, num_blocks: constrained, prefix_sharing: true },
            admission: AdmissionPolicy::Speculative { reserve_frac: 0.25, headroom_blocks: 2 },
            victim_policy: VictimPolicy::PriorityAware,
            preempt,
            ..Default::default()
        };
        let m = run_trace(&service, cfg, &mixed_wl)?;
        table.row(vec![
            label.to_string(),
            fnum(m.throughput_tok_s(), 1),
            format!("{}", m.preemptions),
            format!("{}", m.partial_preemptions),
            format!("{}", m.recomputed_tokens),
            format!("{}", m.recompute_saved_tokens),
            fnum(m.class(Priority::Interactive).ttft.percentile(50.0), 3),
            fnum(m.class(Priority::Batch).ttft.percentile(50.0), 3),
        ]);
    }
    table.emit("e2e_serving_priority");
    println!(
        "(partial preemption frees only the tail blocks a grower needs,\n\
         so resumes re-prefill just the truncated suffix; saved tok is\n\
         the recompute the kept prefixes avoided)"
    );
    Ok(())
}
