//! `cargo bench --bench e2e_serving` — end-to-end serving throughput and
//! latency through the full stack (coordinator → runtime thread → compiled
//! HLO), full attention vs Loki. Numbers feed Figure 6 (right)'s
//! serving-stack contrast and EXPERIMENTS.md §E2E.
//!
//! Scenario 2 drives a multi-tenant shared-system-prompt trace through
//! the engine's KV-pool admission layer (prefix sharing on vs off) and
//! reports peak resident pool bytes against the flat per-lane cache the
//! pool replaced — the serving-level counterpart of
//! `kvpool_bench::shared_prefix_residency`.
//!
//! Scenario 5 (first, artifact-free over [`SimRuntime`]) floods the
//! deadline-aware scheduler with interactive traffic over a parked batch
//! backlog, with and without cross-class aging. Scenario 6 (also
//! artifact-free, on the deterministic steps clock) floods an
//! undersized gang with more SLO'd traffic than it can serve in budget
//! and compares predictive shedding against queueing-to-die: goodput,
//! wasted work and replay-graded shed errors. Scenario 7 (artifact-free,
//! steps clock with a nonzero per-token prefill charge) runs a mixed
//! long-prompt + interactive trace with chunked prefill on vs off and
//! reports the interactive TTFT win, the bounded long-prompt penalty
//! and output equality. Scenario 8 (artifact-free, steps clock) routes
//! a bursty multi-tenant shared-prefix trace through the sharded
//! frontend's [`Router`] over two engine replicas — round-robin vs
//! prefix-affinity — and reports fleet prefix-hit rate, charged TTFT
//! and goodput; `--trace-out-router PATH` dumps the affinity run's
//! per-replica flight recorders for `repro trace-check`'s
//! cross-replica disjointness gate. Scenario 9 (artifact-free, steps
//! clock) drives a multi-turn conversational session tree — each turn's
//! prompt a strict extension of the last — through two prefix-affinity
//! replicas with chunked prefill and the idle-leaf victim policy,
//! prefix reuse on vs off, and reports the turn-≥1 radix hit rate and
//! the warm-turn charged-TTFT gap; `--trace-out-session PATH` dumps its
//! per-replica traces. `--smoke-json PATH` writes all five scenarios'
//! deterministic numbers as one JSON document and exits — the bounded
//! e2e smoke CI runs on every push.

use std::sync::mpsc::channel;

use loki::coordinator::request::{FinishReason, GenRequest, GenResult, Priority};
use loki::coordinator::sampler::SampleCfg;
use loki::coordinator::{
    AdmissionPolicy, Engine, EngineCaps, EngineClock, EngineConfig, EngineMetrics, PoolConfig,
    PreemptMode, RoutePolicy, Router, RouterCfg, ShedPolicy, VictimPolicy,
};
use loki::data::workload::{GenLenDist, Workload, WorkloadCfg};
use loki::data::TaskSuite;
use loki::model::ByteTokenizer;
use loki::runtime::{DecodeVariant, RuntimeService, SimCfg, SimRuntime};
use loki::util::args::Args;
use loki::util::artifacts_dir;
use loki::util::json;
use loki::util::table::{fnum, Table};

/// Distinct-per-request prompt material within the sim vocabulary —
/// the same formula the deterministic engine tests use, so traces stay
/// comparable across harnesses.
fn sim_prompt(id: u64, len: usize) -> Vec<i32> {
    (0..len).map(|i| ((id as usize * 31 + i * 7 + 3) % 96) as i32).collect()
}

fn run_trace(
    service: &RuntimeService,
    cfg: EngineConfig,
    wl: &Workload,
) -> anyhow::Result<EngineMetrics> {
    let engine = Engine::new(service, cfg.clone());
    let (tx, rx) = Engine::channel(&cfg);
    let tok = ByteTokenizer;
    let (reply, _results) = channel();
    for (i, item) in wl.items.iter().enumerate() {
        tx.send(GenRequest {
            id: i as u64,
            prompt: tok.encode(&item.prompt),
            max_new_tokens: item.max_new_tokens,
            stop_token: None,
            sampling: SampleCfg::greedy(),
            priority: item.priority,
            turn: item.turn,
            slo_ms: item.slo_ms,
            reply: reply.clone(),
        })?;
    }
    drop(tx);
    engine.run(rx)
}

/// Scenario 5: a sustained interactive flood arrives on top of a parked
/// batch backlog, under the deadline-aware scheduler with and without
/// cross-class aging. Runs over the deterministic [`SimRuntime`] — no
/// artifacts, wall-clock-free step accounting — so it doubles as the CI
/// e2e smoke (`--smoke-json PATH` writes the numbers as JSON). The
/// deterministic acceptance twin lives in
/// `rust/tests/engine_admission.rs`.
fn flood_over_backlog(quick: bool) -> anyhow::Result<Vec<(String, EngineMetrics)>> {
    const AGING_STEPS: u64 = 32;
    let caps = EngineCaps { max_len: 256, max_prompt: 256, gang_batch: 4, bytes_per_token: 8 };
    let (n_batch, n_flood) = if quick { (4usize, 24usize) } else { (6, 48) };
    let mut runs = Vec::new();
    for (label, aging) in [("off", None), ("on", Some(AGING_STEPS))] {
        let cfg = EngineConfig {
            gang_batch: caps.gang_batch,
            victim_policy: VictimPolicy::DeadlineAware,
            aging_steps: aging,
            ..Default::default()
        };
        let backend = Box::new(SimRuntime::new(SimCfg::default()));
        let engine = Engine::with_backend(backend, caps, cfg.clone());
        let (tx, rx) = Engine::channel(&cfg);
        let (reply, _results) = channel();
        // The backlog is queued first: plain FIFO would admit it ahead
        // of the flood; the deadline scheduler must not — and aging must
        // still bound how long it parks.
        let mut id = 0u64;
        for _ in 0..n_batch {
            tx.send(GenRequest {
                id,
                prompt: sim_prompt(id, 24),
                max_new_tokens: 48,
                stop_token: None,
                sampling: SampleCfg::greedy(),
                priority: Priority::Batch,
                turn: 0,
                slo_ms: None,
                reply: reply.clone(),
            })?;
            id += 1;
        }
        for _ in 0..n_flood {
            tx.send(GenRequest {
                id,
                prompt: sim_prompt(id, 12),
                max_new_tokens: 8,
                stop_token: None,
                sampling: SampleCfg::greedy(),
                priority: Priority::Interactive,
                turn: 0,
                slo_ms: Some(250.0),
                reply: reply.clone(),
            })?;
            id += 1;
        }
        drop(tx);
        drop(reply);
        runs.push((label.to_string(), engine.run(rx)?));
    }
    Ok(runs)
}

fn emit_flood_table(runs: &[(String, EngineMetrics)]) {
    let mut table = Table::new(
        "E2E serving: interactive flood over a batch backlog, deadline-aware ± aging",
        &[
            "aging",
            "tok/s",
            "batch max wait (steps)",
            "promotions",
            "int ttft steps",
            "batch ttft steps",
            "int deadline hit %",
        ],
    );
    for (label, m) in runs {
        let int = m.class(Priority::Interactive);
        let bat = m.class(Priority::Batch);
        table.row(vec![
            label.clone(),
            fnum(m.throughput_tok_s(), 1),
            format!("{}", bat.max_wait_steps),
            format!("{}", m.aging_promotions),
            fnum(int.ttft_steps.mean(), 1),
            fnum(bat.ttft_steps.mean(), 1),
            fnum(int.deadline_hit_rate() * 100.0, 1),
        ]);
    }
    table.emit("e2e_serving_deadline");
    println!(
        "(batch max wait is in deterministic decode steps; with aging on\n\
         it must stay within the aging bound plus one lane-drain, with\n\
         aging off the backlog parks until the flood drains)"
    );
}

/// Scenario 6: an overload flood — far more SLO'd interactive traffic
/// than the gang can serve in budget — under predictive admission, shed
/// vs no-shed. Runs on the deterministic steps clock
/// ([`EngineClock::Steps`]), so every reported number (sheds, goodput,
/// wasted work, deadline grades) is bit-reproducible; the acceptance
/// twin with the strict assertions lives in
/// `rust/tests/engine_admission.rs`. Shed *errors* are graded by
/// replay: a shed id whose `Off` twin hit its deadline was reachable —
/// the count every run here must keep at zero.
fn overload_shed(quick: bool) -> anyhow::Result<Vec<(String, EngineMetrics)>> {
    const GANG: usize = 4;
    const TOKENS: usize = 6;
    const SLO_MS: f64 = 25.0; // steps-domain ms: waves 0..4 are reachable
    let caps = EngineCaps { max_len: 256, max_prompt: 256, gang_batch: GANG, bytes_per_token: 8 };
    let n = if quick { 32 } else { 64 };
    let run = |shed: ShedPolicy| -> anyhow::Result<(Vec<GenResult>, EngineMetrics)> {
        let cfg = EngineConfig {
            gang_batch: GANG,
            victim_policy: VictimPolicy::DeadlineAware,
            shed,
            clock: EngineClock::Steps { step_ms: 1.0, prefill_ms_per_token: 0.0 },
            ..Default::default()
        };
        let backend = Box::new(SimRuntime::new(SimCfg::default()));
        let engine = Engine::with_backend(backend, caps, cfg.clone());
        let (tx, rx) = Engine::channel(&cfg);
        let (reply, results) = channel();
        for id in 0..n as u64 {
            tx.send(GenRequest {
                id,
                prompt: sim_prompt(id, 12),
                max_new_tokens: TOKENS,
                stop_token: None,
                sampling: SampleCfg::greedy(),
                priority: Priority::Interactive,
                turn: 0,
                slo_ms: Some(SLO_MS),
                reply: reply.clone(),
            })?;
        }
        drop(tx);
        drop(reply);
        let metrics = engine.run(rx)?;
        let mut got: Vec<GenResult> = results.try_iter().collect();
        got.sort_by_key(|r| r.id);
        Ok((got, metrics))
    };
    let (off_results, off_metrics) = run(ShedPolicy::Off)?;
    let mut runs = vec![("off".to_string(), off_results, off_metrics)];
    for (label, policy) in [
        ("strict", ShedPolicy::Strict),
        ("hedged .5", ShedPolicy::Hedged { margin_frac: 0.5 }),
    ] {
        let (results, metrics) = run(policy)?;
        runs.push((label.to_string(), results, metrics));
    }
    // Replay grading: a shed whose Off twin hit its deadline was a shed
    // error. (All scenario-6 traffic is interactive, so errors land in
    // that class's counter.)
    let off_hit: Vec<bool> = runs[0]
        .1
        .iter()
        .map(|r| r.timing.deadline_hit == Some(true))
        .collect();
    for (_, results, metrics) in runs.iter_mut().skip(1) {
        let errors = results
            .iter()
            .filter(|r| r.finished_reason == FinishReason::Shed)
            .filter(|r| off_hit.get(r.id as usize).copied().unwrap_or(false))
            .count() as u64;
        metrics.per_class[Priority::Interactive.index()].shed_errors = errors;
    }
    Ok(runs.into_iter().map(|(label, _, m)| (label, m)).collect())
}

fn emit_shed_table(runs: &[(String, EngineMetrics)]) {
    let mut table = Table::new(
        "E2E serving: overload flood, predictive admission (shed vs no-shed)",
        &[
            "shed policy",
            "done",
            "shed",
            "shed errors",
            "goodput tok/step",
            "wasted tok",
            "decode steps",
            "deadline hits",
        ],
    );
    for (label, m) in runs {
        let int = m.class(Priority::Interactive);
        table.row(vec![
            label.clone(),
            format!("{}", m.requests_done),
            format!("{}", m.requests_shed),
            format!("{}", m.shed_errors()),
            fnum(m.goodput(), 3),
            format!("{}", m.wasted_work_tokens()),
            format!("{}", m.decode_steps),
            format!("{}/{}", int.deadline_hits, int.deadline_hits + int.deadline_misses),
        ]);
    }
    table.emit("e2e_serving_shed");
    println!(
        "(steps-clock run: every column is deterministic. shedding drops\n\
         provably-doomed requests at admission, so goodput — deadline-hit\n\
         tokens per decode step — rises and wasted work falls; shed errors\n\
         are graded by replaying the trace under shed=off)"
    );
}

/// Scenario 7: chunked prefill vs monolithic under a mixed gang — two
/// long prompts sharing one bootstrap batch with six interactive
/// requests, on the deterministic steps clock with a nonzero per-token
/// prefill charge (`prefill_ms_per_token`), so TTFT-in-ms actually sees
/// prefill cost. The whole mix fits the gang, so monolithically the
/// long prompts' full prefill charge lands on the clock before *any*
/// first token (batched prefill is all-or-nothing); with
/// `prefill_chunk` set the long prefills advance one chunk per
/// scheduling round and every interactive first token lands after a
/// single short chunk. Interactive ttft_ms p99 must drop while
/// completed token streams stay byte-identical and the long-prompt
/// penalty stays bounded (one decode round per extra chunk). The strict
/// assertions live in rust/tests/engine_admission.rs; this scenario
/// reports the numbers and feeds the chunked trace to
/// `repro trace-check`.
fn chunked_prefill(quick: bool) -> anyhow::Result<Vec<(String, Vec<GenResult>, EngineMetrics)>> {
    const GANG: usize = 8;
    const CHUNK: usize = 32;
    let caps = EngineCaps { max_len: 256, max_prompt: 256, gang_batch: GANG, bytes_per_token: 8 };
    let (n_long, n_int) = (2usize, 6usize);
    let long_new = if quick { 12 } else { 24 };
    let mut runs: Vec<(String, Vec<GenResult>, EngineMetrics)> = Vec::new();
    for (label, chunk) in [("monolithic", None), ("chunked 32", Some(CHUNK))] {
        let cfg = EngineConfig {
            gang_batch: GANG,
            victim_policy: VictimPolicy::DeadlineAware,
            clock: EngineClock::Steps { step_ms: 1.0, prefill_ms_per_token: 0.5 },
            prefill_chunk: chunk,
            ..Default::default()
        };
        let backend = Box::new(SimRuntime::new(SimCfg::default()));
        let engine = Engine::with_backend(backend, caps, cfg.clone());
        let (tx, rx) = Engine::channel(&cfg);
        let (reply, results) = channel();
        let mut id = 0u64;
        // Everything below fits one bootstrap gang, so admission order
        // is immaterial: the monolithic run prefills longs and
        // interactives in a single batch whose combined charge precedes
        // every first token. The batch SLO is loose — both modes hit it;
        // the contrast this scenario measures is interactive TTFT.
        for _ in 0..n_long {
            tx.send(GenRequest {
                id,
                prompt: sim_prompt(id, 192),
                max_new_tokens: long_new,
                stop_token: None,
                sampling: SampleCfg::greedy(),
                priority: Priority::Batch,
                turn: 0,
                slo_ms: Some(1000.0),
                reply: reply.clone(),
            })?;
            id += 1;
        }
        for _ in 0..n_int {
            tx.send(GenRequest {
                id,
                prompt: sim_prompt(id, 8),
                max_new_tokens: 4,
                stop_token: None,
                sampling: SampleCfg::greedy(),
                priority: Priority::Interactive,
                turn: 0,
                slo_ms: Some(400.0),
                reply: reply.clone(),
            })?;
            id += 1;
        }
        drop(tx);
        drop(reply);
        let metrics = engine.run(rx)?;
        let mut got: Vec<GenResult> = results.try_iter().collect();
        got.sort_by_key(|r| r.id);
        runs.push((label.to_string(), got, metrics));
    }
    Ok(runs)
}

fn emit_chunked_table(runs: &[(String, Vec<GenResult>, EngineMetrics)]) {
    let mut table = Table::new(
        "E2E serving: chunked prefill vs monolithic, long prompts + interactive flood",
        &[
            "prefill",
            "done",
            "chunks",
            "chunk tok",
            "int ttft ms p99",
            "long ttft ms mean",
            "decode steps",
            "stall p95 (rounds)",
        ],
    );
    for (label, _, m) in runs {
        let int = m.class(Priority::Interactive);
        let long = m.class(Priority::Batch);
        table.row(vec![
            label.clone(),
            format!("{}", m.requests_done),
            format!("{}", m.prefill_chunks),
            format!("{}", m.chunked_prefill_tokens),
            fnum(int.ttft_ms.percentile(99.0), 1),
            fnum(long.ttft_ms.mean(), 1),
            format!("{}", m.decode_steps),
            fnum(m.prefill_stall.percentile(95.0), 1),
        ]);
    }
    table.emit("e2e_serving_chunked");
    println!(
        "(steps-clock run with prefill charged at 0.5 ms/token: chunking\n\
         lets interactive first tokens land between a long prompt's\n\
         chunks instead of behind its whole prefill charge; completed\n\
         token streams are byte-identical across the two runs)"
    );
}

/// Serialize the scenario-7 runs for the CI artifact. Everything here is
/// deterministic under the steps clock; `outputs_match_monolithic`
/// asserts stream equality against the monolithic run in-band so the
/// smoke diff catches a divergence without shipping token dumps.
fn chunked_json(runs: &[(String, Vec<GenResult>, EngineMetrics)]) -> json::Json {
    let mono = &runs[0].1;
    let mut items = Vec::new();
    for (label, results, m) in runs {
        let int = m.class(Priority::Interactive);
        let long = m.class(Priority::Batch);
        let outputs_match = results.len() == mono.len()
            && results
                .iter()
                .zip(mono.iter())
                .all(|(a, b)| a.id == b.id && a.tokens == b.tokens);
        items.push(json::obj(vec![
            ("prefill", json::s(label)),
            ("requests_done", json::num(m.requests_done as f64)),
            ("decode_steps", json::num(m.decode_steps as f64)),
            ("prefills", json::num(m.prefills as f64)),
            ("prefill_chunks", json::num(m.prefill_chunks as f64)),
            ("chunked_prefill_tokens", json::num(m.chunked_prefill_tokens as f64)),
            ("lane_reset_prefills", json::num(m.lane_reset_prefills as f64)),
            ("int_ttft_ms_p99", json::num(int.ttft_ms.percentile(99.0))),
            ("int_ttft_ms_mean", json::num(int.ttft_ms.mean())),
            ("long_ttft_ms_mean", json::num(long.ttft_ms.mean())),
            ("prefill_stall_p95_rounds", json::num(m.prefill_stall.percentile(95.0))),
            ("outputs_match_monolithic", json::Json::Bool(outputs_match)),
        ]));
    }
    json::obj(vec![
        ("scenario", json::s("chunked_prefill_mixed_trace")),
        ("runs", json::arr(items)),
    ])
}

/// One scenario-8 policy run: the routing split plus the fleet-level
/// numbers affinity routing is graded on.
struct RouterRun {
    label: String,
    /// Requests routed to each of the two replicas.
    routed: Vec<u64>,
    replicas: Vec<EngineMetrics>,
    /// Fleet prefix-hit rate: shared blocks over probed blocks, summed
    /// across replicas before dividing.
    prefix_hit_rate: f64,
    /// Fleet charged-domain TTFT mean (count-weighted across replicas).
    ttft_ms_mean: f64,
    /// Fleet goodput: deadline-hit tokens per decode step, summed
    /// across replicas before dividing.
    goodput: f64,
    deadline_hits: u64,
    /// Total prefix blocks the router already had mirrored on the
    /// chosen replica at decision time, across all decisions.
    matched_blocks: usize,
}

/// Scenario 8: sharded serving — the frontend's [`Router`] splits a
/// bursty multi-tenant shared-prefix trace across two engine replicas,
/// round-robin vs prefix-affinity. Each tenant's requests arrive as a
/// gang-sized burst whose prompts share an 8-block system prefix;
/// affinity routing lands the whole burst on the tenant's home replica,
/// so one burst-mate pays the cold prefill and the rest share its
/// blocks (3/4 warm per gang wave), while round-robin splits every
/// burst 2/2 and pays the cold miss on *both* replicas (2/4 warm).
/// With `prefix_prefill_discount` on and a nonzero per-token prefill
/// charge, the extra cold prefills show up in charged TTFT and in the
/// deadline grades (warm admissions hit the SLO, cold ones can't), so
/// affinity must strictly win on prefix-hit rate, mean TTFT and
/// goodput. Runs on [`SimRuntime`] + the steps clock: every number and
/// every per-replica flight-recorder trace is byte-reproducible. The
/// acceptance twin with the strict assertions lives in
/// `rust/tests/router_sharding.rs`.
fn router_sharding(quick: bool) -> anyhow::Result<Vec<RouterRun>> {
    const GANG: usize = 4;
    const BS: usize = 16;
    const TENANTS: usize = 8;
    const BURST: usize = GANG;
    const PREFIX_BLOCKS: usize = 8;
    const SUFFIX: usize = 16;
    // Charged-domain SLO: a warm first token costs its wave's decode
    // steps plus the 16 undiscounted suffix tokens (≤ ~61 ms at the
    // trace sizes below); a cold one is charged the full 144-token
    // prefill (≥ 145 ms) and can never land in budget.
    const SLO_MS: f64 = 80.0;
    let rounds = if quick { 2 } else { 3 };
    let caps = EngineCaps { max_len: 256, max_prompt: 256, gang_batch: GANG, bytes_per_token: 8 };
    // Bursty arrivals: each tenant fires BURST parallel calls per round
    // (prefix ++ unique per-request suffix), tenants round-robining the
    // submission stream.
    let mut prompts: Vec<Vec<i32>> = Vec::new();
    for round in 0..rounds {
        for tenant in 0..TENANTS {
            for slot in 0..BURST {
                let mut p = sim_prompt(10_000 + tenant as u64, PREFIX_BLOCKS * BS);
                let unique = (round * TENANTS * BURST + tenant * BURST + slot) as u64;
                p.extend(sim_prompt(20_000 + unique, SUFFIX));
                prompts.push(p);
            }
        }
    }
    let mut runs = Vec::new();
    for (label, policy) in
        [("round-robin", RoutePolicy::RoundRobin), ("prefix-affinity", RoutePolicy::PrefixAffinity)]
    {
        let mut router =
            Router::new(RouterCfg { replicas: 2, policy, block_size: BS, max_load_skew: 64 });
        // The whole trace is routed up front: each replica's input queue
        // is then a pure function of (trace, policy), so every engine
        // run — and its flight-recorder trace — is byte-reproducible.
        let assignment: Vec<usize> =
            prompts.iter().enumerate().map(|(i, p)| router.route(i as u64, p)).collect();
        let mut replicas = Vec::new();
        for r in 0..router.replicas() {
            let cfg = EngineConfig {
                gang_batch: GANG,
                victim_policy: VictimPolicy::DeadlineAware,
                clock: EngineClock::Steps { step_ms: 1.0, prefill_ms_per_token: 1.0 },
                pool: PoolConfig { block_size: BS, num_blocks: 0, prefix_sharing: true },
                prefix_prefill_discount: true,
                ..Default::default()
            };
            let backend = Box::new(SimRuntime::new(SimCfg::default()));
            let engine = Engine::with_backend(backend, caps, cfg.clone());
            let (tx, rx) = Engine::channel(&cfg);
            let (reply, _results) = channel();
            for (i, prompt) in prompts.iter().enumerate() {
                if assignment[i] != r {
                    continue;
                }
                tx.send(GenRequest {
                    id: i as u64,
                    prompt: prompt.clone(),
                    max_new_tokens: 4,
                    stop_token: None,
                    sampling: SampleCfg::greedy(),
                    priority: Priority::Interactive,
                    turn: 0,
                    slo_ms: Some(SLO_MS),
                    reply: reply.clone(),
                })?;
            }
            drop(tx);
            drop(reply);
            replicas.push(engine.run(rx)?);
        }
        let (mut shared, mut refb, mut steps, mut hits, mut hit_tokens) =
            (0u64, 0u64, 0u64, 0u64, 0u64);
        let (mut ttft_w, mut ttft_n) = (0.0f64, 0usize);
        for m in &replicas {
            shared += m.prefix_shared_blocks;
            refb += m.prefix_ref_blocks;
            steps += m.decode_steps;
            let int = m.class(Priority::Interactive);
            hits += int.deadline_hits;
            hit_tokens += int.deadline_hit_tokens;
            ttft_w += int.ttft_ms.mean() * int.ttft_ms.count() as f64;
            ttft_n += int.ttft_ms.count();
        }
        let matched: usize = router.decisions().iter().map(|d| d.matched_blocks).sum();
        runs.push(RouterRun {
            label: label.to_string(),
            routed: router.routed().to_vec(),
            prefix_hit_rate: if refb == 0 { 1.0 } else { shared as f64 / refb as f64 },
            ttft_ms_mean: if ttft_n == 0 { 0.0 } else { ttft_w / ttft_n as f64 },
            goodput: if steps == 0 { 0.0 } else { hit_tokens as f64 / steps as f64 },
            deadline_hits: hits,
            matched_blocks: matched,
            replicas,
        });
    }
    Ok(runs)
}

fn emit_router_table(runs: &[RouterRun]) {
    let mut table = Table::new(
        "E2E serving: sharded frontend over 2 replicas, round-robin vs prefix-affinity",
        &[
            "route policy",
            "routed r0/r1",
            "prefix hit %",
            "ttft ms mean",
            "goodput tok/step",
            "deadline hits",
            "matched blocks",
        ],
    );
    for run in runs {
        table.row(vec![
            run.label.clone(),
            format!("{}/{}", run.routed[0], run.routed[1]),
            fnum(run.prefix_hit_rate * 100.0, 1),
            fnum(run.ttft_ms_mean, 1),
            fnum(run.goodput, 3),
            format!("{}", run.deadline_hits),
            format!("{}", run.matched_blocks),
        ]);
    }
    table.emit("e2e_serving_router");
    println!(
        "(steps-clock run over SimRuntime replicas: every column is\n\
         deterministic. affinity lands each tenant burst on its home\n\
         replica, so burst-mates share the cold prefill's blocks;\n\
         round-robin pays the cold miss on both replicas, which the\n\
         prefix-prefill discount turns into charged-TTFT and goodput\n\
         losses)"
    );
}

/// Serialize the scenario-8 runs for the CI artifact: every field is
/// steps-clock deterministic, so CI can assert the affinity-beats-
/// round-robin ordering on exact numbers.
fn router_json(runs: &[RouterRun]) -> json::Json {
    let mut items = Vec::new();
    for run in runs {
        let per_replica: Vec<json::Json> = run
            .replicas
            .iter()
            .enumerate()
            .map(|(i, m)| {
                json::obj(vec![
                    ("replica", json::num(i as f64)),
                    ("routed", json::num(run.routed[i] as f64)),
                    ("requests_done", json::num(m.requests_done as f64)),
                    ("decode_steps", json::num(m.decode_steps as f64)),
                    ("prefix_shared_blocks", json::num(m.prefix_shared_blocks as f64)),
                    ("prefix_ref_blocks", json::num(m.prefix_ref_blocks as f64)),
                    ("prefix_hit_rate", json::num(m.prefix_hit_rate())),
                    (
                        "prefill_discounted_tokens",
                        json::num(m.prefill_discounted_tokens as f64),
                    ),
                ])
            })
            .collect();
        items.push(json::obj(vec![
            ("route_policy", json::s(&run.label)),
            ("prefix_hit_rate", json::num(run.prefix_hit_rate)),
            ("ttft_ms_mean", json::num(run.ttft_ms_mean)),
            ("goodput_tok_per_step", json::num(run.goodput)),
            ("deadline_hits", json::num(run.deadline_hits as f64)),
            ("matched_blocks", json::num(run.matched_blocks as f64)),
            ("replicas", json::arr(per_replica)),
        ]));
    }
    json::obj(vec![
        ("scenario", json::s("sharded_prefix_affinity_routing")),
        ("runs", json::arr(items)),
    ])
}

/// One scenario-9 run: fleet numbers for the multi-turn session tree.
struct SessionRun {
    label: String,
    /// Requests routed to each of the two replicas.
    routed: Vec<u64>,
    replicas: Vec<EngineMetrics>,
    /// Fleet turn-≥1 prefix-hit rate: shared blocks over probed blocks
    /// across follow-up turns, summed across replicas before dividing.
    turn_hit_rate: f64,
    /// Fleet charged-domain TTFT mean over follow-up turns
    /// (count-weighted across both replicas' per-turn histograms).
    warm_ttft_ms_mean: f64,
    /// Cumulative radix-tree block hits, summed across replicas.
    radix_hit_blocks: u64,
    /// Whether an immediate rerun reproduced every replica's
    /// flight-recorder trace byte-for-byte.
    rerun_identical: bool,
}

/// Scenario 9: multi-turn conversational sessions through the sharded
/// frontend — each session's turn-t prompt extends its turn-(t-1)
/// prompt by the assistant reply plus the next user message
/// (block-aligned, so the whole history is shareable), and the radix
/// tree is what makes the follow-up turns cheap. Prefix-affinity
/// routing lands a whole session on its home replica, chunked prefill
/// is on, and the idle-leaf victim policy is live. With prefix reuse
/// on, every turn-≥1 admission walks the tree and is charged only its
/// fresh suffix; the no-reuse baseline re-pays the whole growing
/// history each turn, which the prefix-prefill discount turns into a
/// charged-TTFT gap. Runs on [`SimRuntime`] + the steps clock, and
/// each config is run twice so byte-identical reruns are checked
/// in-band; the strict assertions live in
/// `rust/tests/multi_turn_radix.rs`.
fn session_tree() -> anyhow::Result<Vec<SessionRun>> {
    const GANG: usize = 8;
    const BS: usize = 16;
    const SESSIONS: usize = 4;
    const TURNS: usize = 3;
    const T0_BLOCKS: usize = 4;
    const GROW_BLOCKS: usize = 2;
    let caps = EngineCaps { max_len: 256, max_prompt: 256, gang_batch: GANG, bytes_per_token: 8 };
    // Token-level session histories in submission order (turn-major, so
    // every turn-(t-1) admission precedes its turn-t extension).
    let mut prompts: Vec<Vec<i32>> = Vec::new();
    let mut turns: Vec<u32> = Vec::new();
    let mut hists: Vec<Vec<i32>> =
        (0..SESSIONS).map(|s| sim_prompt(30_000 + s as u64, T0_BLOCKS * BS)).collect();
    for t in 0..TURNS {
        for (s, hist) in hists.iter_mut().enumerate() {
            if t > 0 {
                hist.extend(sim_prompt(40_000 + (s * 16 + t) as u64, GROW_BLOCKS * BS));
            }
            prompts.push(hist.clone());
            turns.push(t as u32);
        }
    }
    // Route once with prefix affinity; both configs replay the same
    // assignment, so the reuse contrast below is engine-side only.
    let mut router = Router::new(RouterCfg {
        replicas: 2,
        policy: RoutePolicy::PrefixAffinity,
        block_size: BS,
        max_load_skew: 64,
    });
    let assignment: Vec<usize> =
        prompts.iter().enumerate().map(|(i, p)| router.route(i as u64, p)).collect();
    let routed = router.routed().to_vec();
    let run_once = |sharing: bool| -> anyhow::Result<Vec<EngineMetrics>> {
        let mut replicas = Vec::new();
        for r in 0..2 {
            let cfg = EngineConfig {
                gang_batch: GANG,
                victim_policy: VictimPolicy::IdleLeaf,
                clock: EngineClock::Steps { step_ms: 1.0, prefill_ms_per_token: 1.0 },
                prefill_chunk: Some(2 * BS),
                pool: PoolConfig { block_size: BS, num_blocks: 0, prefix_sharing: sharing },
                prefix_prefill_discount: true,
                ..Default::default()
            };
            let backend = Box::new(SimRuntime::new(SimCfg::default()));
            let engine = Engine::with_backend(backend, caps, cfg.clone());
            let (tx, rx) = Engine::channel(&cfg);
            let (reply, _results) = channel();
            for (i, prompt) in prompts.iter().enumerate() {
                if assignment[i] != r {
                    continue;
                }
                tx.send(GenRequest {
                    id: i as u64,
                    prompt: prompt.clone(),
                    max_new_tokens: 24,
                    stop_token: None,
                    sampling: SampleCfg::greedy(),
                    priority: Priority::Interactive,
                    turn: turns[i],
                    slo_ms: None,
                    reply: reply.clone(),
                })?;
            }
            drop(tx);
            drop(reply);
            replicas.push(engine.run(rx)?);
        }
        Ok(replicas)
    };
    let mut runs = Vec::new();
    for (label, sharing) in [("prefix-reuse", true), ("no-reuse", false)] {
        let replicas = run_once(sharing)?;
        let again = run_once(sharing)?;
        let rerun_identical = replicas.iter().zip(&again).all(|(a, b)| {
            loki::obs::export::trace_jsonl(&a.trace) == loki::obs::export::trace_jsonl(&b.trace)
        });
        let (mut shared, mut refb, mut hitb) = (0u64, 0u64, 0u64);
        let (mut ttft_w, mut ttft_n) = (0.0f64, 0usize);
        for m in &replicas {
            shared += m.turn_shared_blocks;
            refb += m.turn_ref_blocks;
            hitb += m.radix_hit_blocks;
            for h in m.turn_ttft_ms.iter().skip(1) {
                ttft_w += h.mean() * h.count() as f64;
                ttft_n += h.count();
            }
        }
        runs.push(SessionRun {
            label: label.to_string(),
            routed: routed.clone(),
            turn_hit_rate: if refb == 0 { 1.0 } else { shared as f64 / refb as f64 },
            warm_ttft_ms_mean: if ttft_n == 0 { 0.0 } else { ttft_w / ttft_n as f64 },
            radix_hit_blocks: hitb,
            rerun_identical,
            replicas,
        });
    }
    Ok(runs)
}

fn emit_session_table(runs: &[SessionRun]) {
    let mut table = Table::new(
        "E2E serving: multi-turn session tree over 2 replicas, prefix reuse vs none",
        &[
            "prefix reuse",
            "routed r0/r1",
            "turn>=1 hit %",
            "warm ttft ms",
            "radix hits",
            "done",
            "rerun identical",
        ],
    );
    for run in runs {
        let done: u64 = run.replicas.iter().map(|m| m.requests_done).sum();
        table.row(vec![
            run.label.clone(),
            format!("{}/{}", run.routed[0], run.routed[1]),
            fnum(run.turn_hit_rate * 100.0, 1),
            fnum(run.warm_ttft_ms_mean, 1),
            format!("{}", run.radix_hit_blocks),
            format!("{done}"),
            format!("{}", run.rerun_identical),
        ]);
    }
    table.emit("e2e_serving_session");
    println!(
        "(steps-clock run over SimRuntime replicas: every column is\n\
         deterministic. each follow-up turn re-references the whole\n\
         conversation so far; with reuse on the radix tree charges only\n\
         the fresh suffix, the no-reuse baseline re-prefills the full\n\
         history every turn)"
    );
}

/// Serialize the scenario-9 runs for the CI artifact: every field is
/// steps-clock deterministic, so CI can assert the reuse-beats-no-reuse
/// ordering and the rerun byte-identity on exact numbers.
fn session_json(runs: &[SessionRun]) -> json::Json {
    let mut items = Vec::new();
    for run in runs {
        let per_replica: Vec<json::Json> = run
            .replicas
            .iter()
            .enumerate()
            .map(|(i, m)| {
                json::obj(vec![
                    ("replica", json::num(i as f64)),
                    ("routed", json::num(run.routed[i] as f64)),
                    ("requests_done", json::num(m.requests_done as f64)),
                    ("decode_steps", json::num(m.decode_steps as f64)),
                    ("turn_ref_blocks", json::num(m.turn_ref_blocks as f64)),
                    ("turn_shared_blocks", json::num(m.turn_shared_blocks as f64)),
                    ("radix_hit_blocks", json::num(m.radix_hit_blocks as f64)),
                    (
                        "prefill_discounted_tokens",
                        json::num(m.prefill_discounted_tokens as f64),
                    ),
                ])
            })
            .collect();
        items.push(json::obj(vec![
            ("prefix_reuse", json::s(&run.label)),
            ("turn_hit_rate", json::num(run.turn_hit_rate)),
            ("warm_ttft_ms_mean", json::num(run.warm_ttft_ms_mean)),
            ("radix_hit_blocks", json::num(run.radix_hit_blocks as f64)),
            ("rerun_identical", json::Json::Bool(run.rerun_identical)),
            ("replicas", json::arr(per_replica)),
        ]));
    }
    json::obj(vec![
        ("scenario", json::s("multi_turn_session_tree")),
        ("runs", json::arr(items)),
    ])
}

/// `foo.jsonl` → `foo-r0.jsonl`: one flight-recorder file per replica,
/// the same naming `repro bench-serve --replicas N --trace-out` uses.
fn replica_trace_path(raw: &str, replica: usize) -> std::path::PathBuf {
    let p = std::path::Path::new(raw);
    let stem = p.file_stem().and_then(|s| s.to_str()).unwrap_or("trace");
    let ext = p.extension().and_then(|s| s.to_str()).unwrap_or("jsonl");
    p.with_file_name(format!("{stem}-r{replica}.{ext}"))
}

/// Serialize the scenario-6 runs for the CI artifact: under the steps
/// clock every field here is deterministic across builds.
fn shed_json(runs: &[(String, EngineMetrics)]) -> json::Json {
    let mut items = Vec::new();
    for (label, m) in runs {
        let int = m.class(Priority::Interactive);
        items.push(json::obj(vec![
            ("shed_policy", json::s(label)),
            ("requests_done", json::num(m.requests_done as f64)),
            ("requests_shed", json::num(m.requests_shed as f64)),
            ("shed_errors", json::num(m.shed_errors() as f64)),
            ("goodput_tok_per_step", json::num(m.goodput())),
            ("wasted_work_tokens", json::num(m.wasted_work_tokens() as f64)),
            ("decode_steps", json::num(m.decode_steps as f64)),
            ("deadline_hits", json::num(int.deadline_hits as f64)),
            ("deadline_misses", json::num(int.deadline_misses as f64)),
        ]));
    }
    json::obj(vec![
        ("scenario", json::s("overload_flood_predictive_shedding")),
        ("runs", json::arr(items)),
    ])
}

/// Serialize the scenario-5 runs for the CI artifact: one object per
/// run. The step-based fields (`decode_steps`, `aging_promotions`,
/// `batch_max_wait_steps`, the ttft-step means, `requests_done`) are
/// deterministic across runs; `tok_s` and `int_deadline_hit_rate` are
/// wall-clock-derived and informational only — don't diff them across
/// builds.
fn flood_json(runs: &[(String, EngineMetrics)]) -> json::Json {
    let mut items = Vec::new();
    for (label, m) in runs {
        let int = m.class(Priority::Interactive);
        let bat = m.class(Priority::Batch);
        items.push(json::obj(vec![
            ("aging", json::s(label)),
            ("requests_done", json::num(m.requests_done as f64)),
            ("decode_steps", json::num(m.decode_steps as f64)),
            ("aging_promotions", json::num(m.aging_promotions as f64)),
            ("batch_max_wait_steps", json::num(bat.max_wait_steps as f64)),
            ("int_ttft_steps_mean", json::num(int.ttft_steps.mean())),
            ("batch_ttft_steps_mean", json::num(bat.ttft_steps.mean())),
            ("int_deadline_hit_rate", json::num(int.deadline_hit_rate())),
            ("tok_s", json::num(m.throughput_tok_s())),
        ]));
    }
    json::obj(vec![
        ("scenario", json::s("interactive_flood_over_batch_backlog")),
        ("runs", json::arr(items)),
    ])
}

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let quick = args.flag("quick") || std::env::var("LOKI_QUICK").is_ok();

    // ---- Scenarios 5 and 6 run first: artifact-free (SimRuntime), so
    // they also work in CI and as the `--smoke-json` e2e smoke gate.
    let flood_runs = flood_over_backlog(quick)?;
    emit_flood_table(&flood_runs);
    let shed_runs = overload_shed(quick)?;
    emit_shed_table(&shed_runs);
    let chunked_runs = chunked_prefill(quick)?;
    emit_chunked_table(&chunked_runs);
    let router_runs = router_sharding(quick)?;
    emit_router_table(&router_runs);
    let session_runs = session_tree()?;
    emit_session_table(&session_runs);
    // `--trace-out PATH`: dump the strict-shedding scenario-6 run's
    // flight recorder. That run is on the deterministic steps clock, so
    // the JSONL bytes are identical across builds and CI gates on its
    // conservation invariants via `repro trace-check`.
    if args.flag("trace-out") {
        anyhow::bail!("--trace-out needs a file path");
    }
    if let Some(raw) = args.get("trace-out") {
        let m = shed_runs
            .iter()
            .find(|(label, _)| label.as_str() == "strict")
            .map(|(_, m)| m)
            .expect("scenario 6 always includes a strict-shedding pass");
        let path = std::path::PathBuf::from(raw);
        loki::obs::export::write_jsonl(&m.trace, &path)?;
        let chrome = loki::obs::export::chrome_sibling(&path);
        loki::obs::export::write_chrome(&m.trace, &chrome)?;
        println!(
            "trace written to {} (+ {}): {} events, {} dropped",
            path.display(),
            chrome.display(),
            m.trace.len(),
            m.trace.dropped()
        );
    }
    // `--trace-out-chunked PATH`: dump the scenario-7 chunked run's
    // flight recorder — the trace that exercises the prefill_chunk
    // lifecycle (admitted → N chunks → first token) the checker learned,
    // so CI gates `repro trace-check` on it.
    if args.flag("trace-out-chunked") {
        anyhow::bail!("--trace-out-chunked needs a file path");
    }
    if let Some(raw) = args.get("trace-out-chunked") {
        let m = chunked_runs
            .iter()
            .find(|(label, _, _)| label.starts_with("chunked"))
            .map(|(_, _, m)| m)
            .expect("scenario 7 always includes a chunked pass");
        let path = std::path::PathBuf::from(raw);
        loki::obs::export::write_jsonl(&m.trace, &path)?;
        let chrome = loki::obs::export::chrome_sibling(&path);
        loki::obs::export::write_chrome(&m.trace, &chrome)?;
        println!(
            "chunked trace written to {} (+ {}): {} events, {} dropped",
            path.display(),
            chrome.display(),
            m.trace.len(),
            m.trace.dropped()
        );
    }
    // `--trace-out-router PATH`: dump the scenario-8 prefix-affinity
    // run's per-replica flight recorders (PATH-r0.jsonl, PATH-r1.jsonl
    // + chrome siblings). Each request's whole lifecycle runs on the
    // replica the router picked, so `repro trace-check` over both files
    // at once must find disjoint admitted-id sets — the cross-replica
    // conservation gate CI blocks on.
    if args.flag("trace-out-router") {
        anyhow::bail!("--trace-out-router needs a file path");
    }
    if let Some(raw) = args.get("trace-out-router") {
        let run = router_runs
            .iter()
            .find(|r| r.label == "prefix-affinity")
            .expect("scenario 8 always includes a prefix-affinity pass");
        for (i, m) in run.replicas.iter().enumerate() {
            let path = replica_trace_path(raw, i);
            loki::obs::export::write_jsonl(&m.trace, &path)?;
            let chrome = loki::obs::export::chrome_sibling(&path);
            loki::obs::export::write_chrome(&m.trace, &chrome)?;
            println!(
                "router replica {} trace written to {} (+ {}): {} events, {} dropped",
                i,
                path.display(),
                chrome.display(),
                m.trace.len(),
                m.trace.dropped()
            );
        }
    }
    // `--trace-out-session PATH`: dump the scenario-9 prefix-reuse
    // run's per-replica flight recorders (PATH-r0.jsonl, PATH-r1.jsonl
    // + chrome siblings). The traces exercise the radix-tree share →
    // release lifecycle across conversation turns, so CI gates
    // `repro trace-check` on them alongside the router traces.
    if args.flag("trace-out-session") {
        anyhow::bail!("--trace-out-session needs a file path");
    }
    if let Some(raw) = args.get("trace-out-session") {
        let run = session_runs
            .iter()
            .find(|r| r.label == "prefix-reuse")
            .expect("scenario 9 always includes a prefix-reuse pass");
        for (i, m) in run.replicas.iter().enumerate() {
            let path = replica_trace_path(raw, i);
            loki::obs::export::write_jsonl(&m.trace, &path)?;
            let chrome = loki::obs::export::chrome_sibling(&path);
            loki::obs::export::write_chrome(&m.trace, &chrome)?;
            println!(
                "session replica {} trace written to {} (+ {}): {} events, {} dropped",
                i,
                path.display(),
                chrome.display(),
                m.trace.len(),
                m.trace.dropped()
            );
        }
    }
    if let Some(path) = args.get("smoke-json") {
        let doc = json::obj(vec![(
            "scenarios",
            json::arr(vec![
                flood_json(&flood_runs),
                shed_json(&shed_runs),
                chunked_json(&chunked_runs),
                router_json(&router_runs),
                session_json(&session_runs),
            ]),
        )]);
        std::fs::write(path, doc.to_string() + "\n")?;
        println!("smoke metrics written to {path}");
        return Ok(());
    }

    if !artifacts_dir().join("manifest.json").exists() {
        eprintln!("skipping compiled-artifact scenarios: run `make artifacts` first");
        return Ok(());
    }
    let service = RuntimeService::start(artifacts_dir())?;
    let suite = TaskSuite::load(&artifacts_dir())?;
    let n = if quick { 8 } else { 24 };
    let wl = Workload::generate(
        &WorkloadCfg {
            n_requests: n,
            rate: 0.0,
            burst_p: 0.0,
            prompt_len: (48, 200),
            gen_len: (12, 40),
            gen_len_dist: GenLenDist::Uniform,
            shared_prefix_len: 0,
            prefix_group_count: 1,
            batch_frac: 0.0,
            slo_ms_interactive: None,
            slo_ms_batch: None,
            slo_jitter_frac: 0.0,
            seed: 3,
            ..Default::default()
        },
        &suite.fillers,
    );

    let man = service.manifest.clone();
    let mut table = Table::new(
        "E2E serving: full vs Loki through the coordinator",
        &["variant", "tok/s", "ttft p50 s", "e2e p95 s", "step p50 ms", "injections"],
    );
    for (label, variant) in [
        ("full", DecodeVariant::Full),
        ("loki .25/.25", DecodeVariant::loki_fractions(&man, 0.25, 0.25)),
    ] {
        let cfg = EngineConfig { variant, ..Default::default() };
        let m = run_trace(&service, cfg, &wl)?;
        table.row(vec![
            label.to_string(),
            fnum(m.throughput_tok_s(), 1),
            fnum(m.ttft.percentile(50.0), 3),
            fnum(m.e2e_latency.percentile(95.0), 3),
            fnum(m.decode_step_time.percentile(50.0) * 1e3, 1),
            format!("{}", m.injections),
        ]);
    }
    table.emit("e2e_serving_bench");

    // ---- Scenario 2: shared system prompt through pool admission ------
    let shared_wl = Workload::generate(
        &WorkloadCfg {
            n_requests: if quick { 8 } else { 32 },
            rate: 0.0,
            burst_p: 0.0,
            prompt_len: (16, 48),
            gen_len: (8, 24),
            gen_len_dist: GenLenDist::Uniform,
            shared_prefix_len: 96,
            prefix_group_count: 1,
            batch_frac: 0.0,
            slo_ms_interactive: None,
            slo_ms_batch: None,
            slo_jitter_frac: 0.0,
            seed: 7,
            ..Default::default()
        },
        &suite.fillers,
    );
    let mut table = Table::new(
        "E2E serving: shared 96-byte system prompt, KV-pool residency",
        &[
            "prefix sharing",
            "peak pool MB",
            "flat cache MB",
            "savings",
            "shared blocks",
            "blocked",
        ],
    );
    for (label, sharing) in [("on", true), ("off", false)] {
        let cfg = EngineConfig {
            variant: DecodeVariant::loki_fractions(&man, 0.25, 0.25),
            pool: PoolConfig { block_size: 16, num_blocks: 0, prefix_sharing: sharing },
            ..Default::default()
        };
        let m = run_trace(&service, cfg, &shared_wl)?;
        table.row(vec![
            label.to_string(),
            fnum(m.kv_resident_bytes_peak() as f64 / 1e6, 2),
            fnum(m.kv_flat_bytes as f64 / 1e6, 2),
            format!("{:.2}x", m.kv_savings_vs_flat()),
            format!("{}", m.prefix_shared_blocks),
            format!("{}", m.admission_blocked),
        ]);
    }
    table.emit("e2e_serving_sharing");
    println!(
        "(peak pool bytes mirror granted blocks × per-block KV bytes; the\n\
         flat baseline is the gang-wide [lanes, max_len, D] cache the\n\
         lane_reset_frac era preallocated)"
    );

    // ---- Scenario 3: long-tail decode budgets through a constrained ---
    // pool — ReserveFull prices every request at its worst case and
    // blocks the queue; Speculative admits on a partial reservation,
    // grows at decode time and preempts under pressure. Deterministic
    // twins of this comparison (byte-identical outputs, strictly higher
    // occupancy) run artifact-free in rust/tests/engine_admission.rs.
    let bs = 16usize;
    let gang = man.batch_buckets.iter().copied().max().unwrap_or(1);
    let worst_case_blocks = gang * man.model.max_len.div_ceil(bs);
    let constrained = (worst_case_blocks / 2).max(gang * 2);
    let tail_cap = (man.model.max_len / 2).max(8);
    let tail_wl = Workload::generate(
        &WorkloadCfg {
            n_requests: if quick { 8 } else { 32 },
            rate: 0.0,
            burst_p: 0.0,
            prompt_len: (24, 64),
            gen_len: (8, 8), // ignored under LongTail
            gen_len_dist: GenLenDist::LongTail { mean: 24.0, cap: tail_cap },
            shared_prefix_len: 0,
            prefix_group_count: 1,
            batch_frac: 0.0,
            slo_ms_interactive: None,
            slo_ms_batch: None,
            slo_jitter_frac: 0.0,
            seed: 11,
            ..Default::default()
        },
        &suite.fillers,
    );
    let mut table = Table::new(
        "E2E serving: long-tail max_new, ReserveFull vs Speculative admission",
        &["policy", "tok/s", "mean occ %", "peak blocks", "preempts", "resumes", "blocked"],
    );
    for (label, admission) in [
        ("reserve-full", AdmissionPolicy::ReserveFull),
        (
            "speculative .25",
            AdmissionPolicy::Speculative { reserve_frac: 0.25, headroom_blocks: 2 },
        ),
    ] {
        let cfg = EngineConfig {
            variant: DecodeVariant::loki_fractions(&man, 0.25, 0.25),
            pool: PoolConfig { block_size: bs, num_blocks: constrained, prefix_sharing: true },
            admission,
            ..Default::default()
        };
        let m = run_trace(&service, cfg, &tail_wl)?;
        table.row(vec![
            label.to_string(),
            fnum(m.throughput_tok_s(), 1),
            fnum(m.mean_pool_occupancy() * 100.0, 1),
            format!("{}/{}", m.pool_blocks_peak, m.pool_blocks_total),
            format!("{}", m.preemptions),
            format!("{}", m.resumes),
            format!("{}", m.admission_blocked),
        ]);
    }
    table.emit("e2e_serving_longtail");
    println!(
        "(mean occ counts only blocks holding real KV: reserved-but-\n\
         unwritten blocks are exactly the waste speculative admission\n\
         reclaims under long-tail decode budgets)"
    );

    // ---- Scenario 4: contended mixed-priority traffic — full vs -------
    // partial preemption under the priority-aware victim policy. The
    // interesting deltas: how much resume recompute partial preemption
    // avoids, and how far interactive TTFT sits below batch TTFT when
    // the scheduler is allowed to see classes. Deterministic twins of
    // the acceptance assertions live in rust/tests/engine_admission.rs.
    let mixed_wl = Workload::generate(
        &WorkloadCfg {
            n_requests: if quick { 8 } else { 32 },
            rate: 0.0,
            burst_p: 0.0,
            prompt_len: (24, 64),
            gen_len: (8, 8), // ignored under LongTail
            gen_len_dist: GenLenDist::LongTail { mean: 24.0, cap: tail_cap },
            shared_prefix_len: 0,
            prefix_group_count: 1,
            batch_frac: 0.5,
            slo_ms_interactive: None,
            slo_ms_batch: None,
            slo_jitter_frac: 0.0,
            seed: 17,
            ..Default::default()
        },
        &suite.fillers,
    );
    let mut table = Table::new(
        "E2E serving: mixed-priority contention, full vs partial preemption",
        &[
            "preempt",
            "tok/s",
            "preempts",
            "partial",
            "recomputed tok",
            "saved tok",
            "int ttft p50",
            "batch ttft p50",
        ],
    );
    for (label, preempt) in [("full", PreemptMode::Full), ("partial", PreemptMode::Partial)] {
        let cfg = EngineConfig {
            variant: DecodeVariant::loki_fractions(&man, 0.25, 0.25),
            pool: PoolConfig { block_size: bs, num_blocks: constrained, prefix_sharing: true },
            admission: AdmissionPolicy::Speculative { reserve_frac: 0.25, headroom_blocks: 2 },
            victim_policy: VictimPolicy::PriorityAware,
            preempt,
            ..Default::default()
        };
        let m = run_trace(&service, cfg, &mixed_wl)?;
        table.row(vec![
            label.to_string(),
            fnum(m.throughput_tok_s(), 1),
            format!("{}", m.preemptions),
            format!("{}", m.partial_preemptions),
            format!("{}", m.recomputed_tokens),
            format!("{}", m.recompute_saved_tokens),
            fnum(m.class(Priority::Interactive).ttft.percentile(50.0), 3),
            fnum(m.class(Priority::Batch).ttft.percentile(50.0), 3),
        ]);
    }
    table.emit("e2e_serving_priority");
    println!(
        "(partial preemption frees only the tail blocks a grower needs,\n\
         so resumes re-prefill just the truncated suffix; saved tok is\n\
         the recompute the kept prefixes avoided)"
    );
    Ok(())
}
