//! `cargo bench --bench kernel_1d_vs_2d` — regenerates Figure 16
//! (Appendix C): Loki's 2-D-parallel score kernel vs the SparQ-style
//! 1-D kernel and the dense-copy baseline, across batch and cache sizes.

fn main() -> anyhow::Result<()> {
    let quick = std::env::args().any(|a| a == "--quick") || std::env::var("LOKI_QUICK").is_ok();
    println!("# Fig 16 kernel comparison (quick={quick})");
    loki::experiments::fig16_kernels::run(quick)?;
    Ok(())
}
