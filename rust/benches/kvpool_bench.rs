//! `cargo bench --bench kvpool_bench` — the paged KV-pool microbench.
//!
//! Three questions, all on the pure-Rust substrate (no compiled
//! artifacts needed):
//!
//! 1. **Append cost** — paged append must price like `InPlace` (write one
//!    row), not like `Realloc` (copy history), while allocating resident
//!    bytes per *block* instead of per worst-case lane.
//! 2. **Decode overhead** — Loki decode through block-table indirection
//!    vs the flat cache at a serving shape (the indirection is pointer
//!    math; it must stay within noise).
//! 3. **Shared-prefix residency** — the acceptance scenario: a gang of
//!    sequences sharing a long system prompt. Reports resident KV bytes
//!    vs the flat `[lanes, max_len, D]` cache and asserts the ≥2×
//!    reduction at gang width ≥ 4.

use loki::attnsim::cache::{AppendPolicy, KvCache};
use loki::attnsim::variants::{decode_attend, decode_attend_paged, AttnVariant, VariantParams};
use loki::attnsim::AttnShape;
use loki::kvpool::{TieredKvPool, TieredPoolCfg};
use loki::util::bench::{bench, BenchConfig};
use loki::util::rng::Xoshiro256;
use loki::util::table::{fnum, Table};

fn main() {
    let quick = std::env::args().any(|a| a == "--quick") || std::env::var("LOKI_QUICK").is_ok();
    let cfg = if quick { BenchConfig::quick() } else { BenchConfig::default() };

    append_bench(&cfg, quick);
    decode_bench(&cfg, quick);
    shared_prefix_residency(quick);
}

/// Paged vs InPlace vs Realloc append: per-step wall time and resident
/// bytes after a partial fill (the regime serving actually runs in —
/// nobody decodes to max_len).
fn append_bench(cfg: &BenchConfig, quick: bool) {
    let lanes = if quick { 16 } else { 40 }; // heads of one 13B layer
    let d = 128;
    let max_len = 4096;
    let fill = 512; // live tokens when we measure
    let shape = AttnShape { lanes, head_dim: d, max_len };
    let mut rng = Xoshiro256::new(21);
    let rows = rng.normal_vec(lanes * d);

    let mut table = Table::new(
        "kvpool: append cost and residency at 512/4096 tokens",
        &["policy", "per-append", "resident MB", "vs in-place"],
    );
    let mut inplace_resident = 0u64;
    for (name, policy) in [
        ("in-place (flat prealloc)", AppendPolicy::InPlace),
        ("realloc (HF torch.cat)", AppendPolicy::Realloc),
        ("paged (kvpool, bs=16)", AppendPolicy::Paged { block_size: 16 }),
    ] {
        // Measure append at the fill point: refill a fresh cache per
        // batch outside the timed region is too slow for Realloc, so time
        // one append on a cache held at `fill` (append + truncate-by-
        // rebuild for flat would distort; instead time a fresh fill of
        // `step` appends and divide).
        let step = if quick { 64 } else { 128 };
        let r = bench(name, cfg, || {
            let mut c = KvCache::new(shape, policy);
            // Pre-fill without timing distortion is impossible inside the
            // closure cheaply for Realloc; include it and report per-step
            // time over the whole fill+steps run for an honest relative
            // comparison (every policy pays the same row traffic).
            for _ in 0..fill + step {
                c.append(std::hint::black_box(&rows));
            }
            std::hint::black_box(c.len());
        });
        let mut c = KvCache::new(shape, policy);
        for _ in 0..fill {
            c.append(&rows);
        }
        let resident = c.resident_bytes();
        if matches!(policy, AppendPolicy::InPlace) {
            inplace_resident = resident;
        }
        println!("{}", r.summary());
        table.row(vec![
            name.to_string(),
            format!("{:.2}µs", r.median_secs() * 1e6 / (fill + step) as f64),
            fnum(resident as f64 / 1e6, 1),
            if inplace_resident > 0 {
                format!("{:.2}x", resident as f64 / inplace_resident as f64)
            } else {
                "-".to_string()
            },
        ]);
    }
    table.emit("kvpool_append");
}

/// Loki decode step: flat cache vs paged pool at the same shape. Also
/// reports the tier traffic the pool modeled (hot passes, cold faults).
fn decode_bench(cfg: &BenchConfig, quick: bool) {
    let lanes = if quick { 4 } else { 8 };
    let d = 128;
    let live = if quick { 1024 } else { 2048 };
    let d_hot = 32;
    let shape = AttnShape { lanes, head_dim: d, max_len: live };
    let stride = live * d;
    let mut rng = Xoshiro256::new(22);
    let kc = rng.normal_vec(lanes * live * d);
    let vc = rng.normal_vec(lanes * live * d);
    let q = rng.normal_vec(lanes * d);
    let params = VariantParams { k_sel: live / 4, d_sub: d_hot, ..Default::default() };

    let mut pool = TieredKvPool::new(TieredPoolCfg {
        num_blocks: lanes * live.div_ceil(16) + 1,
        block_size: 16,
        head_dim: d,
        d_hot,
        cold_resident_blocks: 0,
    });
    let seqs: Vec<_> = (0..lanes)
        .map(|lane| {
            let s = pool.new_seq();
            pool.load_prefix(
                s,
                &kc[lane * stride..lane * stride + live * d],
                &vc[lane * stride..lane * stride + live * d],
                live,
            )
            .unwrap();
            s
        })
        .collect();

    let mut table = Table::new(
        "kvpool: Loki decode step, flat vs paged (same rows, same math)",
        &["path", "median", "ctx checksum"],
    );
    let flat = bench("loki decode, flat cache", cfg, || {
        let out = decode_attend(
            &AttnVariant::Loki,
            shape,
            std::hint::black_box(&q),
            &kc,
            &vc,
            stride,
            live,
            &params,
            None,
        );
        std::hint::black_box(out.context);
    });
    println!("{}", flat.summary());
    let paged = bench("loki decode, paged pool", cfg, || {
        let out = decode_attend_paged(
            &AttnVariant::Loki,
            &mut pool,
            &seqs,
            std::hint::black_box(&q),
            &params,
            None,
        );
        std::hint::black_box(out.context);
    });
    println!("{}", paged.summary());
    let a = decode_attend(&AttnVariant::Loki, shape, &q, &kc, &vc, stride, live, &params, None);
    let b = decode_attend_paged(&AttnVariant::Loki, &mut pool, &seqs, &q, &params, None);
    assert_eq!(a.context, b.context, "paged decode must stay bit-identical to flat");
    let sum: f32 = b.context.iter().sum();
    table.row(vec![
        "flat".to_string(),
        format!("{:.2}ms", flat.median_secs() * 1e3),
        fnum(a.context.iter().sum::<f32>() as f64, 4),
    ]);
    table.row(vec![
        "paged".to_string(),
        format!("{:.2}ms", paged.median_secs() * 1e3),
        fnum(sum as f64, 4),
    ]);
    table.emit("kvpool_decode");
    let ts = pool.tier_stats;
    println!(
        "tier traffic: {} hot passes, {} cold-page gathers ({} faults, {:.1} MB faulted)",
        ts.hot_hits,
        ts.gather_hits + ts.gather_faults,
        ts.gather_faults,
        ts.bytes_faulted as f64 / 1e6
    );
}

/// The acceptance scenario: gang of G sequences = shared 1024-token
/// system prompt + 128 private tokens each, against a flat per-lane
/// cache sized to max_len. Must show ≥2× resident-byte reduction at
/// gang width ≥ 4 (it shows far more).
fn shared_prefix_residency(quick: bool) {
    let d = 128;
    let d_hot = 32;
    let (prefix, tail, max_len) = (1024usize, 128usize, 2048usize);
    let mut rng = Xoshiro256::new(23);
    let kp: Vec<f32> = rng.normal_vec(prefix * d);
    let vp: Vec<f32> = rng.normal_vec(prefix * d);

    let mut table = Table::new(
        "kvpool: resident KV bytes, shared system prompt (1024 tok) + 128-tok tails",
        &["gang", "paged MB", "flat(live) MB", "flat(max_len) MB", "savings vs flat"],
    );
    let gangs: &[usize] = if quick { &[4, 8] } else { &[4, 8, 16, 32] };
    for &gang in gangs {
        let mut pool = TieredKvPool::new(TieredPoolCfg {
            num_blocks: (prefix + tail).div_ceil(16) * (gang + 1),
            block_size: 16,
            head_dim: d,
            d_hot,
            cold_resident_blocks: 0,
        });
        let base = pool.new_seq();
        pool.load_prefix(base, &kp, &vp, prefix).unwrap();
        for _ in 0..gang {
            let s = pool.fork(base);
            for _ in 0..tail {
                let k = rng.normal_vec(d);
                pool.append(s, &k, &k).unwrap();
            }
        }
        pool.free_seq(base);
        pool.check_invariants();

        let paged = pool.resident_kv_bytes();
        let live = prefix + tail;
        let flat_live = (gang * live * 2 * d * 4) as u64;
        let flat_max = pool.flat_equivalent_bytes(max_len);
        let savings = flat_max as f64 / paged as f64;
        if gang >= 4 {
            assert!(
                savings >= 2.0,
                "acceptance: expected ≥2x resident-byte reduction at gang {gang}, \
                 got {savings:.2}x"
            );
        }
        table.row(vec![
            gang.to_string(),
            fnum(paged as f64 / 1e6, 2),
            fnum(flat_live as f64 / 1e6, 2),
            fnum(flat_max as f64 / 1e6, 2),
            format!("{savings:.1}x"),
        ]);
    }
    table.emit("kvpool_sharing");
    println!(
        "(paged bytes = one copy of the shared prefix + per-seq tails + the\n\
         d_hot/2D hot tier; the flat baseline pays gang × max_len regardless)"
    );
}
