//! Deterministic acceptance tests for the radix-tree prefix refactor:
//! multi-turn conversational sessions through the sharded frontend,
//! idle-leaf victim selection, and the eviction-feedback loop that
//! keeps the router's affinity mirror honest.
//!
//! This is the acceptance twin of e2e_serving scenario 9: the bench
//! reports the numbers, this file pins the orderings (prefix reuse
//! strictly beats the no-reuse baseline on turn-≥1 hit rate and warm
//! charged TTFT), the byte-identity invariants (reruns reproduce every
//! replica trace exactly; sharing never changes token streams), and
//! the structural ancestor-protection guarantee of idle-leaf eviction.

use std::sync::mpsc::channel;

use loki::coordinator::request::{FinishReason, GenRequest, GenResult, Priority};
use loki::coordinator::sampler::SampleCfg;
use loki::coordinator::{
    AdmissionPolicy, Engine, EngineCaps, EngineClock, EngineConfig, EngineMetrics, PoolConfig,
    RoutePolicy, Router, RouterCfg, VictimPolicy,
};
use loki::kvpool::{prefix_block_hashes, BlockAllocator, TableSet};
use loki::obs::export::trace_jsonl;
use loki::obs::PoolEvent;
use loki::runtime::{SimCfg, SimRuntime};

const GANG: usize = 8;
const BS: usize = 16;
const SESSIONS: usize = 4;
const TURNS: usize = 3;
const T0_BLOCKS: usize = 4;
const GROW_BLOCKS: usize = 2;
const MAX_NEW: usize = 24;

/// Distinct-per-request prompt material within the sim vocabulary.
fn sim_prompt(id: u64, len: usize) -> Vec<i32> {
    (0..len).map(|i| ((id as usize * 31 + i * 7 + 3) % 96) as i32).collect()
}

/// The scenario-9 trace shape: per session, turn t's prompt is turn
/// t-1's prompt extended by the (block-aligned) assistant reply plus
/// the next user message. Submission order is turn-major, so every
/// turn-(t-1) admission precedes its turn-t extension. Returns
/// (prompts, turn indices) in submission order.
fn session_trace() -> (Vec<Vec<i32>>, Vec<u32>) {
    let mut prompts = Vec::new();
    let mut turns = Vec::new();
    let mut hists: Vec<Vec<i32>> =
        (0..SESSIONS).map(|s| sim_prompt(30_000 + s as u64, T0_BLOCKS * BS)).collect();
    for t in 0..TURNS {
        for (s, hist) in hists.iter_mut().enumerate() {
            if t > 0 {
                hist.extend(sim_prompt(40_000 + (s * 16 + t) as u64, GROW_BLOCKS * BS));
            }
            prompts.push(hist.clone());
            turns.push(t as u32);
        }
    }
    (prompts, turns)
}

struct FleetRun {
    replicas: Vec<(Vec<GenResult>, EngineMetrics)>,
    /// Per-replica flight-recorder JSONL bytes.
    traces: Vec<String>,
}

/// Route the session trace up front with prefix affinity, then run each
/// replica's share through its own sim-backed engine on the Steps clock
/// with chunked prefill and the idle-leaf victim policy — the same
/// construction as e2e_serving scenario 9.
fn run_fleet(sharing: bool) -> FleetRun {
    let (prompts, turns) = session_trace();
    let mut router = Router::new(RouterCfg {
        replicas: 2,
        policy: RoutePolicy::PrefixAffinity,
        block_size: BS,
        max_load_skew: 64,
    });
    let assignment: Vec<usize> =
        prompts.iter().enumerate().map(|(i, p)| router.route(i as u64, p)).collect();
    let caps = EngineCaps { max_len: 256, max_prompt: 256, gang_batch: GANG, bytes_per_token: 8 };
    let mut replicas = Vec::new();
    let mut traces = Vec::new();
    for r in 0..router.replicas() {
        let cfg = EngineConfig {
            gang_batch: GANG,
            victim_policy: VictimPolicy::IdleLeaf,
            clock: EngineClock::Steps { step_ms: 1.0, prefill_ms_per_token: 1.0 },
            prefill_chunk: Some(2 * BS),
            pool: PoolConfig { block_size: BS, num_blocks: 0, prefix_sharing: sharing },
            prefix_prefill_discount: true,
            ..Default::default()
        };
        let engine =
            Engine::with_backend(Box::new(SimRuntime::new(SimCfg::default())), caps, cfg.clone());
        let (tx, rx) = Engine::channel(&cfg);
        let (reply, results) = channel();
        for (i, prompt) in prompts.iter().enumerate() {
            if assignment[i] != r {
                continue;
            }
            tx.send(GenRequest {
                id: i as u64,
                prompt: prompt.clone(),
                max_new_tokens: MAX_NEW,
                stop_token: None,
                sampling: SampleCfg::greedy(),
                priority: Priority::Interactive,
                turn: turns[i],
                slo_ms: None,
                reply: reply.clone(),
            })
            .unwrap();
        }
        drop(tx);
        drop(reply);
        let metrics = engine.run(rx).unwrap();
        let mut got: Vec<GenResult> = results.try_iter().collect();
        got.sort_by_key(|x| x.id);
        traces.push(trace_jsonl(&metrics.trace));
        replicas.push((got, metrics));
    }
    FleetRun { replicas, traces }
}

/// Fleet turn-≥1 hit rate plus the count-weighted mean charged TTFT of
/// the follow-up-turn histograms.
fn fleet_warm_numbers(run: &FleetRun) -> (u64, u64, f64) {
    let (mut shared, mut refb) = (0u64, 0u64);
    let (mut w, mut n) = (0.0f64, 0usize);
    for (_, m) in &run.replicas {
        shared += m.turn_shared_blocks;
        refb += m.turn_ref_blocks;
        for h in m.turn_ttft_ms.iter().skip(1) {
            w += h.mean() * h.count() as f64;
            n += h.count();
        }
    }
    assert!(n > 0, "trace must produce follow-up-turn first tokens");
    (shared, refb, w / n as f64)
}

/// The scenario-9 pins: with prefix reuse on, every follow-up turn
/// resolves its history through the radix tree (high turn-≥1 hit rate,
/// nonzero tree hits) and its charged TTFT strictly beats the no-reuse
/// baseline, while sharing changes no token stream and reruns reproduce
/// every replica trace byte-for-byte.
#[test]
fn multi_turn_reuse_beats_no_reuse_and_reruns_are_byte_identical() {
    let reuse = run_fleet(true);
    let again = run_fleet(true);
    assert_eq!(reuse.traces, again.traces, "rerun must reproduce traces byte-for-byte");
    let none = run_fleet(false);

    let done: u64 = reuse.replicas.iter().map(|(_, m)| m.requests_done).sum();
    assert_eq!(done as usize, SESSIONS * TURNS, "every turn of every session must finish");
    for ((a, _), (b, _)) in reuse.replicas.iter().zip(&none.replicas) {
        assert_eq!(a.len(), b.len());
        for (x, y) in a.iter().zip(b) {
            assert_eq!(x.id, y.id);
            assert_eq!(x.tokens, y.tokens, "sharing changed request #{}'s tokens", x.id);
            assert_eq!(x.finished_reason, FinishReason::MaxTokens);
        }
    }

    let (shared_on, ref_on, warm_on) = fleet_warm_numbers(&reuse);
    let (shared_off, ref_off, warm_off) = fleet_warm_numbers(&none);
    assert_eq!(ref_on, ref_off, "probed follow-up blocks are a property of the trace");
    assert_eq!(shared_off, 0, "no-reuse baseline must share nothing");
    assert!(
        2 * shared_on > ref_on,
        "follow-up turns must resolve most of their history through the tree: {shared_on}/{ref_on}"
    );
    assert!(
        warm_on < warm_off,
        "reused history must strictly beat re-prefilling it: {warm_on} vs {warm_off}"
    );

    let tree_hits: u64 = reuse.replicas.iter().map(|(_, m)| m.radix_hit_blocks).sum();
    assert_eq!(tree_hits, shared_on, "every shared block is a radix-tree hit");
    assert_eq!(
        none.replicas.iter().map(|(_, m)| m.radix_hit_blocks).sum::<u64>(),
        0,
        "sharing off must never consult the tree"
    );
}

/// Satellite 1 end-to-end: the chain hashes the engine's pool announces
/// on physical prefix frees flow through the eviction-feedback channel,
/// and replaying them into [`Router::note_evicted`] drains the mirror
/// of exactly the replica whose engine freed them.
#[test]
fn engine_evictions_drain_the_router_mirror_end_to_end() {
    let (prompts, turns) = session_trace();
    let mut router = Router::new(RouterCfg {
        replicas: 2,
        policy: RoutePolicy::PrefixAffinity,
        block_size: BS,
        max_load_skew: 64,
    });
    let assignment: Vec<usize> =
        prompts.iter().enumerate().map(|(i, p)| router.route(i as u64, p)).collect();
    assert!(router.mirror_len(0) > 0 && router.mirror_len(1) > 0);
    let mirrored_r1 = router.mirror_len(1);

    // Run replica 0's share with eviction feedback wired up.
    let caps = EngineCaps { max_len: 256, max_prompt: 256, gang_batch: GANG, bytes_per_token: 8 };
    let cfg = EngineConfig {
        gang_batch: GANG,
        victim_policy: VictimPolicy::IdleLeaf,
        clock: EngineClock::Steps { step_ms: 1.0, prefill_ms_per_token: 1.0 },
        pool: PoolConfig { block_size: BS, num_blocks: 0, prefix_sharing: true },
        prefix_prefill_discount: true,
        ..Default::default()
    };
    let (etx, erx) = channel();
    let engine =
        Engine::with_backend(Box::new(SimRuntime::new(SimCfg::default())), caps, cfg.clone())
            .with_evict_feedback(etx);
    let (tx, rx) = Engine::channel(&cfg);
    let (reply, _results) = channel();
    for (i, prompt) in prompts.iter().enumerate() {
        if assignment[i] != 0 {
            continue;
        }
        tx.send(GenRequest {
            id: i as u64,
            prompt: prompt.clone(),
            max_new_tokens: MAX_NEW,
            stop_token: None,
            sampling: SampleCfg::greedy(),
            priority: Priority::Interactive,
            turn: turns[i],
            slo_ms: None,
            reply: reply.clone(),
        })
        .unwrap();
    }
    drop(tx);
    drop(reply);
    engine.run(rx).unwrap();

    // By the end of the run every sequence has completed, so every
    // prefix block replica 0 ever registered was physically freed and
    // its hash forwarded. Replaying the feed must empty replica 0's
    // mirror while leaving replica 1's untouched.
    let mut evicted = 0usize;
    for hash in erx.try_iter() {
        router.note_evicted(0, hash);
        evicted += 1;
    }
    assert!(evicted > 0, "completed run must announce prefix releases");
    assert_eq!(router.mirror_len(0), 0, "mirror kept entries its engine freed");
    assert_eq!(router.mirror_len(1), mirrored_r1, "other replica's mirror untouched");
}

/// Satellite 3 (structural half): evicting a leaf sequence returns
/// exactly its private blocks — the shared ancestor chain a sibling
/// still references survives with its radix nodes intact, and only the
/// leaf's own extension hashes are announced as released.
#[test]
fn leaf_eviction_returns_exactly_private_blocks_and_spares_ancestors() {
    let bs = 4;
    let mut alloc = BlockAllocator::new(32, bs);
    let mut ts = TableSet::new(bs, true);
    let ancestor_prompt: Vec<i32> = (0..12).collect(); // 3 full blocks
    let parent = ts.admit(&mut alloc, &ancestor_prompt, 12).unwrap();
    let mut leaf_prompt = ancestor_prompt.clone();
    leaf_prompt.extend(100..108); // +2 full blocks of divergent history
    let leaf = ts.admit(&mut alloc, &leaf_prompt, 24).unwrap(); // +1 reserved tail
    ts.events.drain().for_each(drop);

    let ancestor_hashes = prefix_block_hashes(&ancestor_prompt, bs);
    let leaf_hashes = prefix_block_hashes(&leaf_prompt, bs);
    assert_eq!(ts.radix_nodes(), 5, "3 shared ancestors + 2 leaf extensions");
    let private = ts.private_blocks(&alloc, leaf);
    assert_eq!(private, 3, "2 extension blocks + 1 reserved tail");
    let in_use = alloc.blocks_in_use();

    ts.preempt_free(&mut alloc, leaf);
    assert_eq!(
        alloc.blocks_in_use(),
        in_use - private,
        "eviction must return exactly the leaf's private blocks"
    );
    for h in &ancestor_hashes {
        assert!(ts.radix().contains(*h), "live-descendant ancestor evicted from the tree");
    }
    for h in &leaf_hashes[ancestor_hashes.len()..] {
        assert!(!ts.radix().contains(*h), "dead leaf extension must leave the tree");
    }
    // Exactly the extension hashes are announced — mirrors must not be
    // told to forget a prefix the survivor still serves.
    let released: Vec<u64> = ts
        .events
        .drain()
        .filter_map(|e| match e {
            PoolEvent::PrefixReleased { hash } => Some(hash),
            _ => None,
        })
        .collect();
    assert_eq!(released, leaf_hashes[ancestor_hashes.len()..].to_vec());

    // The survivor's chain is fully intact: a re-admission of the leaf
    // prompt re-shares the ancestors it kept alive.
    let back = ts.admit(&mut alloc, &leaf_prompt, 24).unwrap();
    assert_eq!(
        ts.table(back).unwrap().blocks[..3],
        ts.table(parent).unwrap().blocks[..3],
        "re-admission must land on the protected ancestor blocks"
    );
    ts.free(&mut alloc, parent);
    ts.free(&mut alloc, back);
    assert_eq!(alloc.blocks_in_use(), 0);
    alloc.check_invariants();
}

/// Satellite 3 (engine half): under a contended pool the idle-leaf
/// victim policy preempts and resumes without changing a single output
/// byte, and a rerun reproduces the whole flight-recorder trace — the
/// victim choice is deterministic.
#[test]
fn idle_leaf_victims_resume_byte_identically() {
    let pbs = 8; // pool block size for this scenario
    let caps = EngineCaps { max_len: 512, max_prompt: 512, gang_batch: 2, bytes_per_token: 8 };
    let specs: Vec<(Vec<i32>, usize)> = vec![
        (sim_prompt(0, 24), 40),
        (sim_prompt(1, 30), 48),
        (sim_prompt(2, 20), 32),
        (sim_prompt(3, 28), 36),
    ];
    let run = |num_blocks: usize| -> (Vec<GenResult>, EngineMetrics) {
        let cfg = EngineConfig {
            gang_batch: 2,
            victim_policy: VictimPolicy::IdleLeaf,
            pool: PoolConfig { block_size: pbs, num_blocks, prefix_sharing: true },
            admission: if num_blocks == 0 {
                AdmissionPolicy::ReserveFull
            } else {
                AdmissionPolicy::Speculative { reserve_frac: 0.2, headroom_blocks: 1 }
            },
            ..Default::default()
        };
        let engine =
            Engine::with_backend(Box::new(SimRuntime::new(SimCfg::default())), caps, cfg.clone());
        let (tx, rx) = Engine::channel(&cfg);
        let (reply, results) = channel();
        for (i, (prompt, max_new)) in specs.iter().enumerate() {
            tx.send(GenRequest {
                id: i as u64,
                prompt: prompt.clone(),
                max_new_tokens: *max_new,
                stop_token: None,
                sampling: SampleCfg::greedy(),
                priority: Priority::Interactive,
                turn: 0,
                slo_ms: None,
                reply: reply.clone(),
            })
            .unwrap();
        }
        drop(tx);
        drop(reply);
        let m = engine.run(rx).unwrap();
        let mut got: Vec<GenResult> = results.try_iter().collect();
        got.sort_by_key(|r| r.id);
        (got, m)
    };

    let (base, base_m) = run(0);
    assert_eq!(base_m.preemptions, 0, "unbounded pool must never preempt");
    // 16 blocks cannot hold the two longest footprints at once, so
    // decode-time growth must pick idle-leaf victims.
    let (got, m) = run(16);
    assert!(m.preemptions > 0, "scenario failed to force preemption: {}", m.report());
    assert!(m.resumes > 0, "preempted leaves must resume");
    assert_eq!(base.len(), got.len());
    for (x, y) in base.iter().zip(&got) {
        assert_eq!(x.id, y.id);
        assert_eq!(x.tokens, y.tokens, "request #{} tokens diverged under idle-leaf", x.id);
        assert_eq!(x.finished_reason, y.finished_reason);
    }
    let (got2, m2) = run(16);
    assert_eq!(m.preemptions, m2.preemptions, "victim choice must be deterministic");
    assert_eq!(trace_jsonl(&m.trace), trace_jsonl(&m2.trace), "rerun must reproduce the trace");
    for (x, y) in got.iter().zip(&got2) {
        assert_eq!(x.tokens, y.tokens);
    }
}
