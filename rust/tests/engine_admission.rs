//! Deterministic end-to-end tests of engine admission, speculative
//! growth, preemption and resumption — over the [`SimRuntime`] harness,
//! so they run hermetically (no compiled artifacts, no device).
//!
//! The sim's logits are a pure hash of each lane's token history, which
//! turns "scheduling must not change outputs" into an exact, bit-level
//! assertion: any divergence between an uncontended run and a
//! preempt-heavy run is an engine bug, not noise.

use std::sync::mpsc::channel;

use loki::coordinator::request::{FinishReason, GenRequest, GenResult, Priority};
use loki::coordinator::sampler::SampleCfg;
use loki::coordinator::{
    reserve_tokens, AdmissionPolicy, Engine, EngineCaps, EngineConfig, EngineMetrics,
    PoolConfig, PreemptMode, VictimPolicy, RESERVE_SLACK_TOKENS,
};
use loki::kvpool::BlockAllocator;
use loki::runtime::{SimCfg, SimRuntime};

const BS: usize = 8;

fn caps(max_len: usize, gang: usize) -> EngineCaps {
    EngineCaps { max_len, max_prompt: max_len, gang_batch: gang, bytes_per_token: 8 }
}

/// Distinct-per-request prompt material within the sim vocabulary.
fn prompt(id: u64, len: usize) -> Vec<i32> {
    (0..len).map(|i| ((id as usize * 31 + i * 7 + 3) % 96) as i32).collect()
}

struct Spec {
    prompt: Vec<i32>,
    max_new: usize,
    sampling: SampleCfg,
    priority: Priority,
    slo_ms: Option<f64>,
}

/// Run `specs` through a sim-backed engine; results come back sorted by
/// request id. Everything is submitted up front, so the scheduler's
/// behaviour is a pure function of (cfg, caps, specs).
fn run(cfg: &EngineConfig, caps: EngineCaps, specs: &[Spec]) -> (Vec<GenResult>, EngineMetrics) {
    let engine =
        Engine::with_backend(Box::new(SimRuntime::new(SimCfg::default())), caps, cfg.clone());
    let (tx, rx) = Engine::channel(cfg);
    let (reply, results) = channel();
    for (i, s) in specs.iter().enumerate() {
        tx.send(GenRequest {
            id: i as u64,
            prompt: s.prompt.clone(),
            max_new_tokens: s.max_new,
            stop_token: None,
            sampling: s.sampling,
            priority: s.priority,
            slo_ms: s.slo_ms,
            reply: reply.clone(),
        })
        .unwrap();
    }
    drop(tx);
    drop(reply);
    let metrics = engine.run(rx).unwrap();
    let mut got: Vec<GenResult> = results.try_iter().collect();
    got.sort_by_key(|r| r.id);
    (got, metrics)
}

fn mixed_specs() -> Vec<Spec> {
    vec![
        Spec {
            prompt: prompt(0, 24),
            max_new: 40,
            sampling: SampleCfg { temperature: 0.8, top_p: 0.9, seed: 100 },
            priority: Priority::Interactive,
            slo_ms: None,
        },
        Spec {
            prompt: prompt(1, 30),
            max_new: 48,
            sampling: SampleCfg { temperature: 0.7, top_p: 0.95, seed: 101 },
            priority: Priority::Interactive,
            slo_ms: None,
        },
        Spec {
            prompt: prompt(2, 20),
            max_new: 32,
            sampling: SampleCfg::greedy(),
            priority: Priority::Interactive,
            slo_ms: None,
        },
        Spec {
            prompt: prompt(3, 28),
            max_new: 36,
            sampling: SampleCfg { temperature: 1.0, top_p: 0.9, seed: 103 },
            priority: Priority::Interactive,
            slo_ms: None,
        },
    ]
}

fn assert_same_outputs(a: &[GenResult], b: &[GenResult]) {
    assert_eq!(a.len(), b.len());
    for (x, y) in a.iter().zip(b) {
        assert_eq!(x.id, y.id);
        assert_eq!(x.tokens, y.tokens, "request #{} tokens diverged", x.id);
        assert_eq!(x.text, y.text, "request #{} text diverged", x.id);
        assert_eq!(
            x.finished_reason, y.finished_reason,
            "request #{} finish reason diverged",
            x.id
        );
    }
}

/// Satellite (a): a preempted-then-resumed request produces exactly the
/// bytes it would have produced uncontended — through temperature
/// sampling, so the sampler-state save/restore is exercised too.
#[test]
fn preempted_then_resumed_output_is_byte_identical() {
    let specs = mixed_specs();
    let uncontended = EngineConfig {
        pool: PoolConfig { block_size: BS, num_blocks: 0, prefix_sharing: true },
        ..Default::default()
    };
    let (base, base_m) = run(&uncontended, caps(512, 2), &specs);
    assert_eq!(base_m.preemptions, 0, "worst-case pool must never preempt");
    assert_eq!(base.len(), 4);
    for r in &base {
        assert_eq!(r.finished_reason, FinishReason::MaxTokens);
    }

    // 16 blocks cannot hold the two longest requests' full footprints
    // (9 + 10 blocks) at once, so decode-time growth must preempt.
    let contended = EngineConfig {
        pool: PoolConfig { block_size: BS, num_blocks: 16, prefix_sharing: true },
        admission: AdmissionPolicy::Speculative { reserve_frac: 0.2, headroom_blocks: 1 },
        ..Default::default()
    };
    let (got, m) = run(&contended, caps(512, 2), &specs);
    assert!(m.preemptions > 0, "scenario failed to force preemption: {}", m.report());
    assert!(m.resumes > 0);
    assert!(m.recomputed_tokens > 0, "resumes must pay prefix recompute");
    assert_same_outputs(&base, &got);
    let per_request: usize = got.iter().map(|r| r.timing.preemptions).sum();
    assert_eq!(per_request as u64, m.preemptions, "per-request preemption tallies drift");
}

/// Satellite (b): pool sized so that admission fills it exactly and
/// *every* decode-time growth must preempt someone — the engine must
/// neither deadlock nor livelock, and still drain every request with
/// uncontended-identical output.
#[test]
fn saturated_pool_preempts_without_deadlock_and_stays_exact() {
    let specs: Vec<Spec> = (0..6)
        .map(|i| Spec {
            prompt: prompt(i, 8),
            max_new: 24,
            sampling: SampleCfg::greedy(),
            priority: Priority::Interactive,
            slo_ms: None,
        })
        .collect();
    let (base, _) = run(
        &EngineConfig {
            pool: PoolConfig { block_size: BS, num_blocks: 0, prefix_sharing: true },
            ..Default::default()
        },
        caps(128, 4),
        &specs,
    );

    // reserve_frac 0: each admission takes ceil((8+0+2)/8) = 2 blocks;
    // four lanes × 2 = 8 = the whole pool. Every subsequent grow finds
    // zero free blocks.
    let cfg = EngineConfig {
        pool: PoolConfig { block_size: BS, num_blocks: 8, prefix_sharing: true },
        admission: AdmissionPolicy::Speculative { reserve_frac: 0.0, headroom_blocks: 1 },
        ..Default::default()
    };
    let (got, m) = run(&cfg, caps(128, 4), &specs);
    assert_eq!(m.requests_done, 6, "drain stalled: {}", m.report());
    assert_eq!(m.requests_rejected, 0);
    assert!(m.preemptions > 0, "saturated pool must preempt: {}", m.report());
    assert!(m.grow_stalls > 0);
    for r in &got {
        assert_eq!(r.tokens.len(), 24);
        assert_eq!(r.finished_reason, FinishReason::MaxTokens);
    }
    assert_same_outputs(&base, &got);
}

/// Satellite (c): `ReserveFull` behaves exactly as PR 1's engine — no
/// preemptions, no growth, reproducible outputs, and impossible requests
/// rejected up front (by both policies, identically).
#[test]
fn reserve_full_results_are_unchanged_and_reproducible() {
    let specs = mixed_specs();
    let cfg = EngineConfig {
        pool: PoolConfig { block_size: BS, num_blocks: 0, prefix_sharing: true },
        admission: AdmissionPolicy::ReserveFull,
        ..Default::default()
    };
    let (a, ma) = run(&cfg, caps(512, 2), &specs);
    let (b, mb) = run(&cfg, caps(512, 2), &specs);
    assert_same_outputs(&a, &b);
    for m in [&ma, &mb] {
        assert_eq!(m.preemptions, 0);
        assert_eq!(m.resumes, 0);
        assert_eq!(m.grow_events, 0, "full reservation must never grow");
        assert_eq!(m.grow_stalls, 0);
        assert_eq!(m.requests_done, 4);
    }
}

#[test]
fn oversized_requests_are_rejected_by_both_policies() {
    // 4 blocks of 8 slots; a 600-token decode budget clamps to max_len
    // (256) and still needs 32 blocks — impossible, reject fast. A small
    // sibling request must be unaffected.
    for admission in [
        AdmissionPolicy::ReserveFull,
        AdmissionPolicy::Speculative { reserve_frac: 0.1, headroom_blocks: 2 },
    ] {
        let cfg = EngineConfig {
            pool: PoolConfig { block_size: BS, num_blocks: 4, prefix_sharing: true },
            admission,
            ..Default::default()
        };
        let specs = vec![
            Spec {
                prompt: prompt(0, 10),
                max_new: 600,
                sampling: SampleCfg::greedy(),
                priority: Priority::Interactive,
                slo_ms: None,
            },
            Spec {
                prompt: prompt(1, 10),
                max_new: 10,
                sampling: SampleCfg::greedy(),
                priority: Priority::Interactive,
                slo_ms: None,
            },
        ];
        let (got, m) = run(&cfg, caps(256, 2), &specs);
        assert_eq!(m.requests_rejected, 1, "{admission:?}");
        assert_eq!(got[0].finished_reason, FinishReason::CacheFull);
        assert!(got[0].tokens.is_empty(), "rejected request must not fabricate output");
        assert_eq!(got[1].tokens.len(), 10, "{admission:?}: small sibling must complete");
        assert_eq!(got[1].finished_reason, FinishReason::MaxTokens);
    }
}

/// The e2e acceptance criterion, deterministically: on a long-tail
/// workload through a constrained pool, `Speculative` sustains strictly
/// higher mean written-block occupancy and needs no more decode
/// iterations (≥ throughput at equal work), with zero output divergence
/// from `ReserveFull`.
#[test]
fn speculative_beats_reserve_full_on_long_tail_with_zero_divergence() {
    // Long-tail decode budgets: every 4th request runs 8× longer.
    let specs: Vec<Spec> = (0..12)
        .map(|i| Spec {
            prompt: prompt(i, 16),
            max_new: if i % 4 == 0 { 64 } else { 8 },
            sampling: if i % 2 == 0 {
                SampleCfg::greedy()
            } else {
                SampleCfg { temperature: 0.8, top_p: 0.9, seed: 200 + i }
            },
            priority: Priority::Interactive,
            slo_ms: None,
        })
        .collect();
    let pool = PoolConfig { block_size: BS, num_blocks: 24, prefix_sharing: true };
    let full_cfg = EngineConfig {
        pool,
        admission: AdmissionPolicy::ReserveFull,
        ..Default::default()
    };
    let spec_cfg = EngineConfig {
        pool,
        admission: AdmissionPolicy::Speculative { reserve_frac: 0.1, headroom_blocks: 1 },
        ..Default::default()
    };
    let (full, mf) = run(&full_cfg, caps(256, 4), &specs);
    let (spec, ms) = run(&spec_cfg, caps(256, 4), &specs);

    assert_same_outputs(&full, &spec);
    assert_eq!(mf.tokens_generated, ms.tokens_generated, "same work either way");
    assert_eq!(mf.requests_done, 12);
    assert_eq!(ms.requests_done, 12);
    assert!(ms.preemptions > 0, "constrained pool must exercise preemption");
    assert!(
        ms.mean_pool_occupancy() > mf.mean_pool_occupancy(),
        "speculative occupancy {:.4} must beat reserve-full {:.4}",
        ms.mean_pool_occupancy(),
        mf.mean_pool_occupancy()
    );
    assert!(
        ms.decode_steps <= mf.decode_steps,
        "speculative must not need more iterations ({} vs {})",
        ms.decode_steps,
        mf.decode_steps
    );
}

/// A contended mixed-priority long-tail workload: interactive requests
/// are short decodes with small distinct prompts; batch requests are
/// long decodes behind a *shared* 64-token system prompt (8 shared
/// blocks at `BS = 8`), submitted interleaved so plain FIFO would admit
/// batch work first. The shared prefix matters twice: it is what full
/// preemption re-prefills on every resume but partial preemption keeps
/// resident, and its blocks free nothing when released (refcounts), so
/// both modes pay eviction in comparable tail-block units.
fn mixed_priority_specs() -> Vec<Spec> {
    let shared: Vec<i32> = (0..64).map(|i| ((i * 5 + 1) % 96) as i32).collect();
    (0..12)
        .map(|i| {
            let batch = i % 2 == 0;
            let prompt = if batch {
                let mut p = shared.clone();
                p.extend(prompt(i, 8));
                p
            } else {
                prompt(i, 16)
            };
            Spec {
                prompt,
                max_new: if batch { 48 } else { 8 },
                sampling: if i % 3 == 0 {
                    SampleCfg { temperature: 0.8, top_p: 0.9, seed: 300 + i }
                } else {
                    SampleCfg::greedy()
                },
                priority: if batch { Priority::Batch } else { Priority::Interactive },
                slo_ms: None,
            }
        })
        .collect()
}

/// The PR 3 acceptance criterion, deterministically: under a contended
/// mixed-priority long-tail workload with the priority-aware victim
/// policy, (a) partial preemption recomputes strictly fewer tokens than
/// whole-sequence preemption, (b) `Interactive` gets strictly lower mean
/// TTFT than `Batch` (measured in decode steps — wall-clock-free), and
/// (c) every completed output is byte-identical to an uncontended run.
#[test]
fn priority_aware_partial_preemption_on_contended_mixed_long_tail() {
    let specs = mixed_priority_specs();

    // Uncontended baseline: worst-case pool, nothing can preempt.
    let base_cfg = EngineConfig {
        pool: PoolConfig { block_size: BS, num_blocks: 0, prefix_sharing: true },
        ..Default::default()
    };
    let (base, bm) = run(&base_cfg, caps(256, 4), &specs);
    assert_eq!(bm.preemptions, 0, "worst-case pool must never preempt");
    assert_eq!(bm.requests_done, 12);

    // Contended twins differing only in how much a preemption evicts.
    // A batch request's full footprint is 72 + 48 + 2 = 122 tokens → 16
    // blocks, of which 8 are the shared prompt: four batch lanes need
    // 8 + 4·8 = 40 blocks at peak, so a 32-block pool forces decode-time
    // growth to preempt (while any single request still fits: 16 ≤ 32).
    let contended = |preempt| EngineConfig {
        pool: PoolConfig { block_size: BS, num_blocks: 32, prefix_sharing: true },
        admission: AdmissionPolicy::Speculative { reserve_frac: 0.1, headroom_blocks: 1 },
        victim_policy: VictimPolicy::PriorityAware,
        preempt,
        ..Default::default()
    };
    let (full, mf) = run(&contended(PreemptMode::Full), caps(256, 4), &specs);
    let (part, mp) = run(&contended(PreemptMode::Partial), caps(256, 4), &specs);

    // (c) Scheduling must be invisible in outputs, under both modes.
    assert_same_outputs(&base, &full);
    assert_same_outputs(&base, &part);
    for (label, m) in [("full", &mf), ("partial", &mp)] {
        assert_eq!(m.requests_done, 12, "{label}: drain stalled: {}", m.report());
        assert_eq!(m.requests_rejected, 0, "{label}");
        assert!(m.preemptions > 0, "{label}: scenario failed to force preemption");
        assert!(m.resumes > 0, "{label}");
    }

    // (a) Partial preemption pays strictly less recompute.
    assert!(mp.partial_preemptions > 0, "no preemption kept a prefix: {}", mp.report());
    assert!(mp.recompute_saved_tokens > 0);
    assert!(
        mp.recomputed_tokens < mf.recomputed_tokens,
        "partial mode must recompute strictly fewer tokens ({} vs {})",
        mp.recomputed_tokens,
        mf.recomputed_tokens
    );

    // (b) The multi-class scheduler protects interactive latency. TTFT
    // is compared in decode steps, which the sim makes deterministic.
    for (label, m) in [("full", &mf), ("partial", &mp)] {
        let int = m.class(Priority::Interactive);
        let bat = m.class(Priority::Batch);
        assert_eq!((int.done, bat.done), (6, 6), "{label}");
        assert!(
            int.ttft_steps.mean() < bat.ttft_steps.mean(),
            "{label}: interactive mean TTFT {:.1} steps must beat batch {:.1}",
            int.ttft_steps.mean(),
            bat.ttft_steps.mean()
        );
        // Victim scoring points at batch lanes first.
        assert!(
            bat.preemptions >= int.preemptions,
            "{label}: batch must absorb at least as many preemptions"
        );
    }
}

/// `PreemptMode::Partial` is orthogonal to the victim policy: under the
/// default youngest-first scan it must still keep prefixes, still save
/// recompute, and still be invisible in outputs.
#[test]
fn partial_preemption_under_youngest_first_is_byte_identical() {
    let specs = mixed_specs();
    let uncontended = EngineConfig {
        pool: PoolConfig { block_size: BS, num_blocks: 0, prefix_sharing: true },
        ..Default::default()
    };
    let (base, _) = run(&uncontended, caps(512, 2), &specs);

    let contended = EngineConfig {
        pool: PoolConfig { block_size: BS, num_blocks: 16, prefix_sharing: true },
        admission: AdmissionPolicy::Speculative { reserve_frac: 0.2, headroom_blocks: 1 },
        preempt: PreemptMode::Partial,
        ..Default::default()
    };
    let (got, m) = run(&contended, caps(512, 2), &specs);
    assert!(m.preemptions > 0, "scenario failed to force preemption: {}", m.report());
    assert!(m.partial_preemptions > 0, "no preemption kept a prefix: {}", m.report());
    assert!(m.recompute_saved_tokens > 0, "kept prefixes must save recompute");
    assert_same_outputs(&base, &got);
}

/// The PR 4 acceptance criterion, deterministically: a sustained
/// interactive flood is queued on top of a parked batch backlog and
/// scheduled deadline-aware over 2 lanes with a worst-case pool (pure
/// queue scheduling — no preemption noise). The step accounting is
/// exact: interactive requests decode `INT_TOKENS` tokens each, so a
/// lane turns over every `INT_TOKENS` decode steps and the flood alone
/// drains in `N_FLOOD / 2 · INT_TOKENS` steps.
///
/// * With aging **off**, the backlog parks behind the whole flood (its
///   wait ≈ the flood drain time — unbounded in flood size).
/// * With aging **on** (bound `A`), each batch request is promoted at
///   wait `A` and takes the very next freed lane: max batch wait ≤
///   `A + 2·INT_TOKENS + 2` (promotion + both lanes turning over + the
///   first-token step).
/// * Interactive mean TTFT stays strictly below batch mean TTFT (the
///   flood is still served first; aging bounds starvation, it does not
///   invert the classes).
/// * Outputs are byte-identical to an uncontended default-policy run —
///   scheduling must never leak into content.
#[test]
fn aging_bounds_batch_starvation_under_interactive_flood() {
    const N_FLOOD: usize = 60;
    const INT_TOKENS: usize = 2;
    const BATCH_TOKENS: usize = 8;
    const AGING: u64 = 44;
    let specs: Vec<Spec> = (0..2)
        .map(|i| Spec {
            prompt: prompt(i, 16),
            max_new: BATCH_TOKENS,
            sampling: SampleCfg::greedy(),
            priority: Priority::Batch,
            slo_ms: None,
        })
        .chain((2..2 + N_FLOOD as u64).map(|i| Spec {
            prompt: prompt(i, 8),
            max_new: INT_TOKENS,
            sampling: SampleCfg::greedy(),
            priority: Priority::Interactive,
            // Generous wall-clock SLO: it exercises deadline stamping
            // and the hit metrics without making the *ordering* depend
            // on wall time (all flood deadlines are equal, so the
            // deterministic FIFO tiebreak decides within the band).
            slo_ms: Some(60_000.0),
        }))
        .collect();
    let pool = PoolConfig { block_size: BS, num_blocks: 0, prefix_sharing: true };

    // Uncontended baseline under the PR 2 default policy.
    let base_cfg = EngineConfig { pool, ..Default::default() };
    let (base, bm) = run(&base_cfg, caps(256, 2), &specs);
    assert_eq!(bm.requests_done, 2 + N_FLOOD as u64);
    assert_eq!(bm.aging_promotions, 0, "aging is deadline-policy-only");

    let deadline_cfg = |aging: Option<u64>| EngineConfig {
        pool,
        victim_policy: VictimPolicy::DeadlineAware,
        aging_steps: aging,
        ..Default::default()
    };
    let (starved, ms) = run(&deadline_cfg(None), caps(256, 2), &specs);
    let (aged, ma) = run(&deadline_cfg(Some(AGING)), caps(256, 2), &specs);

    // Scheduling is invisible in outputs, promoted or parked.
    assert_same_outputs(&base, &starved);
    assert_same_outputs(&base, &aged);

    // Aging promoted each batch request exactly once.
    assert_eq!(ms.aging_promotions, 0);
    assert_eq!(ma.aging_promotions, 2, "{}", ma.report());

    // The starvation bound: promotion + one turnover of both lanes +
    // the first-token step.
    let bound = AGING + 2 * INT_TOKENS as u64 + 2;
    let starved_wait = ms.class(Priority::Batch).max_wait_steps;
    let aged_wait = ma.class(Priority::Batch).max_wait_steps;
    assert!(
        aged_wait <= bound,
        "aged batch wait {aged_wait} exceeds the bound {bound}: {}",
        ma.report()
    );
    assert!(
        starved_wait > bound,
        "without aging the backlog must park past the bound \
         ({starved_wait} <= {bound}) or the scenario proves nothing"
    );
    assert!(
        aged_wait < starved_wait,
        "aging must strictly reduce the max batch wait ({aged_wait} vs {starved_wait})"
    );

    // Interactive latency stays protected, and every flood SLO is met.
    for m in [&ms, &ma] {
        let int = m.class(Priority::Interactive);
        let bat = m.class(Priority::Batch);
        assert_eq!((int.done, bat.done), (N_FLOOD as u64, 2));
        assert!(
            int.ttft_steps.mean() < bat.ttft_steps.mean(),
            "interactive mean TTFT {:.1} must stay below batch {:.1}: {}",
            int.ttft_steps.mean(),
            bat.ttft_steps.mean(),
            m.report()
        );
        assert_eq!(int.deadline_hits, N_FLOOD as u64);
        assert_eq!(int.deadline_misses, 0);
        assert_eq!(int.deadline_hit_rate(), 1.0);
        assert_eq!((bat.deadline_hits, bat.deadline_misses), (0, 0), "no SLO, no grade");
    }
}

/// Earliest-effective-deadline ordering within the interactive band: on
/// a single lane, requests are admitted tightest-deadline-first, and a
/// deadline-less request runs after every SLO'd one. The SLO spacing is
/// seconds-wide, so sub-millisecond submission jitter can never reorder
/// the keys.
#[test]
fn deadline_aware_admission_is_earliest_deadline_first() {
    let mk = |i: u64, slo_ms: Option<f64>| Spec {
        prompt: prompt(i, 8),
        max_new: 4,
        sampling: SampleCfg::greedy(),
        priority: Priority::Interactive,
        slo_ms,
    };
    // Submission order deliberately scrambled vs deadline order.
    let specs = vec![
        mk(0, Some(50_000.0)),
        mk(1, Some(500.0)),
        mk(2, None),
        mk(3, Some(5_000.0)),
    ];
    let cfg = EngineConfig {
        pool: PoolConfig { block_size: BS, num_blocks: 0, prefix_sharing: true },
        victim_policy: VictimPolicy::DeadlineAware,
        ..Default::default()
    };
    let (got, m) = run(&cfg, caps(256, 1), &specs);
    assert_eq!(m.requests_done, 4);
    let wait = |id: usize| got[id].timing.ttft_steps;
    assert!(
        wait(1) < wait(3) && wait(3) < wait(0) && wait(0) < wait(2),
        "admission order must be 500ms, 5s, 50s, no-SLO — got waits \
         [{} {} {} {}]",
        wait(0),
        wait(1),
        wait(2),
        wait(3)
    );
    // The FIFO twin: under the default policy the same submission order
    // is served in submission order.
    let fifo_cfg = EngineConfig {
        pool: PoolConfig { block_size: BS, num_blocks: 0, prefix_sharing: true },
        ..Default::default()
    };
    let (fifo, _) = run(&fifo_cfg, caps(256, 1), &specs);
    let fwait = |id: usize| fifo[id].timing.ttft_steps;
    assert!(fwait(0) < fwait(1) && fwait(1) < fwait(2) && fwait(2) < fwait(3));
    assert_same_outputs(&fifo, &got);
}

/// Satellite regression: the `PriorityAware` victim scorer prices
/// `Partial`-mode candidates by their **planned truncation depth**, not
/// their full history. Lane `Y`'s blocks are almost all shared (evicting
/// it degrades to a full release: planned cost = its whole 36-token
/// replay); lane `O` has twice the history but a cheap private tail
/// (planned cost ≈ 18 tokens). The full-history proxy would evict `Y`;
/// exact tail-cost scoring must evict `O` — and outputs stay
/// byte-identical either way.
#[test]
fn partial_victim_scoring_uses_planned_truncation_depth() {
    let shared: Vec<i32> = (0..32).map(|i| ((i * 5 + 1) % 96) as i32).collect();
    let with_shared = |suffix_seed: u64, suffix: usize| -> Vec<i32> {
        let mut p = shared.clone();
        p.extend(prompt(suffix_seed, suffix));
        p
    };
    let specs = vec![
        // Lane Z (interactive): co-holds the shared prefix so Y's shared
        // blocks stay refcount-2; never scored ahead of the batch lanes.
        Spec {
            prompt: with_shared(90, 8),
            max_new: 6,
            sampling: SampleCfg::greedy(),
            priority: Priority::Interactive,
            slo_ms: None,
        },
        // Lane Y (batch): 4 shared blocks + 1 private tail. Planned
        // truncation frees almost nothing → degrades to a full release →
        // planned cost = full 36-token replay.
        Spec {
            prompt: with_shared(91, 2),
            max_new: 4,
            sampling: SampleCfg::greedy(),
            priority: Priority::Batch,
            slo_ms: None,
        },
        // Lane O (batch): 48-token private prompt, 7 private blocks —
        // twice Y's history, but truncating 3 tail blocks keeps 32
        // tokens resident → planned cost ≈ 18 tokens.
        Spec {
            prompt: prompt(92, 48),
            max_new: 30,
            sampling: SampleCfg::greedy(),
            priority: Priority::Batch,
            slo_ms: None,
        },
        // Lane G (interactive): speculative grower that exhausts its
        // 1-block reservation and must preempt someone.
        Spec {
            prompt: prompt(93, 6),
            max_new: 20,
            sampling: SampleCfg::greedy(),
            priority: Priority::Interactive,
            slo_ms: None,
        },
    ];
    let (base, _) = run(
        &EngineConfig {
            pool: PoolConfig { block_size: BS, num_blocks: 0, prefix_sharing: true },
            ..Default::default()
        },
        caps(256, 4),
        &specs,
    );
    // 15 blocks = exactly the bootstrap footprint (6 + 1 + 7 + 1), so
    // G's first grow finds the pool dry and must preempt.
    let cfg = EngineConfig {
        pool: PoolConfig { block_size: BS, num_blocks: 15, prefix_sharing: true },
        admission: AdmissionPolicy::Speculative { reserve_frac: 0.0, headroom_blocks: 4 },
        victim_policy: VictimPolicy::PriorityAware,
        preempt: PreemptMode::Partial,
        ..Default::default()
    };
    let (got, m) = run(&cfg, caps(256, 4), &specs);
    assert_eq!(m.requests_done, 4, "drain stalled: {}", m.report());
    assert!(m.preemptions > 0, "scenario failed to force preemption: {}", m.report());
    assert!(m.partial_preemptions > 0, "no preemption kept a prefix: {}", m.report());
    assert!(m.recompute_saved_tokens > 0);
    assert!(
        got[2].timing.preemptions > 0,
        "O (cheap planned tail) must be the victim: {}",
        m.report()
    );
    assert_eq!(
        got[1].timing.preemptions, 0,
        "Y (shared-heavy, expensive planned cost) must be spared — the \
         full-history proxy would have evicted it"
    );
    assert_eq!(got[0].timing.preemptions, 0);
    assert_same_outputs(&base, &got);
}

/// Satellite: the reservation formula is pinned — the old magic `+ 2` is
/// now `RESERVE_SLACK_TOKENS` and the exact block count for a known
/// prompt/max_new/block_size triple must never drift silently.
#[test]
fn reservation_formula_is_pinned() {
    assert_eq!(RESERVE_SLACK_TOKENS, 2);
    // prompt 100, max_new 50, block_size 16: 100 + 50 + 2 = 152 tokens
    // → exactly 10 blocks.
    let r = reserve_tokens(AdmissionPolicy::ReserveFull, 100, 50, 1024);
    assert_eq!(r, 152);
    let alloc = BlockAllocator::new(64, 16);
    assert_eq!(alloc.blocks_for(r), 10);
    // Speculative at 0.25 reserves ceil(50·0.25) = 13 of the budget.
    let s = reserve_tokens(
        AdmissionPolicy::Speculative { reserve_frac: 0.25, headroom_blocks: 2 },
        100,
        50,
        1024,
    );
    assert_eq!(s, 100 + 13 + 2);
    assert_eq!(alloc.blocks_for(s), 8);
    // Both clamp at the physical cache bound.
    assert_eq!(reserve_tokens(AdmissionPolicy::ReserveFull, 100, 5000, 1024), 1024);
    assert_eq!(
        reserve_tokens(
            AdmissionPolicy::Speculative { reserve_frac: 1.0, headroom_blocks: 2 },
            100,
            5000,
            1024
        ),
        1024
    );
}
