//! Deterministic end-to-end tests of engine admission, speculative
//! growth, preemption and resumption — over the [`SimRuntime`] harness,
//! so they run hermetically (no compiled artifacts, no device).
//!
//! The sim's logits are a pure hash of each lane's token history, which
//! turns "scheduling must not change outputs" into an exact, bit-level
//! assertion: any divergence between an uncontended run and a
//! preempt-heavy run is an engine bug, not noise.

use std::sync::mpsc::channel;

use loki::coordinator::request::{FinishReason, GenRequest, GenResult, Priority};
use loki::coordinator::sampler::SampleCfg;
use loki::coordinator::{
    reserve_tokens, AdmissionPolicy, Engine, EngineCaps, EngineClock, EngineConfig,
    EngineMetrics, PoolConfig, PreemptMode, ShedPolicy, VictimPolicy, RESERVE_SLACK_TOKENS,
};
use loki::kvpool::BlockAllocator;
use loki::runtime::{SimCfg, SimRuntime};

const BS: usize = 8;

fn caps(max_len: usize, gang: usize) -> EngineCaps {
    EngineCaps { max_len, max_prompt: max_len, gang_batch: gang, bytes_per_token: 8 }
}

/// Distinct-per-request prompt material within the sim vocabulary.
fn prompt(id: u64, len: usize) -> Vec<i32> {
    (0..len).map(|i| ((id as usize * 31 + i * 7 + 3) % 96) as i32).collect()
}

struct Spec {
    prompt: Vec<i32>,
    max_new: usize,
    sampling: SampleCfg,
    priority: Priority,
    slo_ms: Option<f64>,
}

/// Run `specs` through a sim-backed engine; results come back sorted by
/// request id. Everything is submitted up front, so the scheduler's
/// behaviour is a pure function of (cfg, caps, specs).
fn run(cfg: &EngineConfig, caps: EngineCaps, specs: &[Spec]) -> (Vec<GenResult>, EngineMetrics) {
    let engine =
        Engine::with_backend(Box::new(SimRuntime::new(SimCfg::default())), caps, cfg.clone());
    let (tx, rx) = Engine::channel(cfg);
    let (reply, results) = channel();
    for (i, s) in specs.iter().enumerate() {
        tx.send(GenRequest {
            id: i as u64,
            prompt: s.prompt.clone(),
            max_new_tokens: s.max_new,
            stop_token: None,
            sampling: s.sampling,
            priority: s.priority,
            turn: 0,
            slo_ms: s.slo_ms,
            reply: reply.clone(),
        })
        .unwrap();
    }
    drop(tx);
    drop(reply);
    let metrics = engine.run(rx).unwrap();
    let mut got: Vec<GenResult> = results.try_iter().collect();
    got.sort_by_key(|r| r.id);
    (got, metrics)
}

fn mixed_specs() -> Vec<Spec> {
    vec![
        Spec {
            prompt: prompt(0, 24),
            max_new: 40,
            sampling: SampleCfg { temperature: 0.8, top_p: 0.9, seed: 100 },
            priority: Priority::Interactive,
            slo_ms: None,
        },
        Spec {
            prompt: prompt(1, 30),
            max_new: 48,
            sampling: SampleCfg { temperature: 0.7, top_p: 0.95, seed: 101 },
            priority: Priority::Interactive,
            slo_ms: None,
        },
        Spec {
            prompt: prompt(2, 20),
            max_new: 32,
            sampling: SampleCfg::greedy(),
            priority: Priority::Interactive,
            slo_ms: None,
        },
        Spec {
            prompt: prompt(3, 28),
            max_new: 36,
            sampling: SampleCfg { temperature: 1.0, top_p: 0.9, seed: 103 },
            priority: Priority::Interactive,
            slo_ms: None,
        },
    ]
}

fn assert_same_outputs(a: &[GenResult], b: &[GenResult]) {
    assert_eq!(a.len(), b.len());
    for (x, y) in a.iter().zip(b) {
        assert_eq!(x.id, y.id);
        assert_eq!(x.tokens, y.tokens, "request #{} tokens diverged", x.id);
        assert_eq!(x.text, y.text, "request #{} text diverged", x.id);
        assert_eq!(
            x.finished_reason, y.finished_reason,
            "request #{} finish reason diverged",
            x.id
        );
    }
}

/// Satellite (a): a preempted-then-resumed request produces exactly the
/// bytes it would have produced uncontended — through temperature
/// sampling, so the sampler-state save/restore is exercised too.
#[test]
fn preempted_then_resumed_output_is_byte_identical() {
    let specs = mixed_specs();
    let uncontended = EngineConfig {
        pool: PoolConfig { block_size: BS, num_blocks: 0, prefix_sharing: true },
        ..Default::default()
    };
    let (base, base_m) = run(&uncontended, caps(512, 2), &specs);
    assert_eq!(base_m.preemptions, 0, "worst-case pool must never preempt");
    assert_eq!(base.len(), 4);
    for r in &base {
        assert_eq!(r.finished_reason, FinishReason::MaxTokens);
    }

    // 16 blocks cannot hold the two longest requests' full footprints
    // (9 + 10 blocks) at once, so decode-time growth must preempt.
    let contended = EngineConfig {
        pool: PoolConfig { block_size: BS, num_blocks: 16, prefix_sharing: true },
        admission: AdmissionPolicy::Speculative { reserve_frac: 0.2, headroom_blocks: 1 },
        ..Default::default()
    };
    let (got, m) = run(&contended, caps(512, 2), &specs);
    assert!(m.preemptions > 0, "scenario failed to force preemption: {}", m.report());
    assert!(m.resumes > 0);
    assert!(m.recomputed_tokens > 0, "resumes must pay prefix recompute");
    assert_same_outputs(&base, &got);
    let per_request: usize = got.iter().map(|r| r.timing.preemptions).sum();
    assert_eq!(per_request as u64, m.preemptions, "per-request preemption tallies drift");
}

/// Satellite (b): pool sized so that admission fills it exactly and
/// *every* decode-time growth must preempt someone — the engine must
/// neither deadlock nor livelock, and still drain every request with
/// uncontended-identical output.
#[test]
fn saturated_pool_preempts_without_deadlock_and_stays_exact() {
    let specs: Vec<Spec> = (0..6)
        .map(|i| Spec {
            prompt: prompt(i, 8),
            max_new: 24,
            sampling: SampleCfg::greedy(),
            priority: Priority::Interactive,
            slo_ms: None,
        })
        .collect();
    let (base, _) = run(
        &EngineConfig {
            pool: PoolConfig { block_size: BS, num_blocks: 0, prefix_sharing: true },
            ..Default::default()
        },
        caps(128, 4),
        &specs,
    );

    // reserve_frac 0: each admission takes ceil((8+0+2)/8) = 2 blocks;
    // four lanes × 2 = 8 = the whole pool. Every subsequent grow finds
    // zero free blocks.
    let cfg = EngineConfig {
        pool: PoolConfig { block_size: BS, num_blocks: 8, prefix_sharing: true },
        admission: AdmissionPolicy::Speculative { reserve_frac: 0.0, headroom_blocks: 1 },
        ..Default::default()
    };
    let (got, m) = run(&cfg, caps(128, 4), &specs);
    assert_eq!(m.requests_done, 6, "drain stalled: {}", m.report());
    assert_eq!(m.requests_rejected, 0);
    assert!(m.preemptions > 0, "saturated pool must preempt: {}", m.report());
    assert!(m.grow_stalls > 0);
    for r in &got {
        assert_eq!(r.tokens.len(), 24);
        assert_eq!(r.finished_reason, FinishReason::MaxTokens);
    }
    assert_same_outputs(&base, &got);
}

/// Satellite (c): `ReserveFull` behaves exactly as PR 1's engine — no
/// preemptions, no growth, reproducible outputs, and impossible requests
/// rejected up front (by both policies, identically).
#[test]
fn reserve_full_results_are_unchanged_and_reproducible() {
    let specs = mixed_specs();
    let cfg = EngineConfig {
        pool: PoolConfig { block_size: BS, num_blocks: 0, prefix_sharing: true },
        admission: AdmissionPolicy::ReserveFull,
        ..Default::default()
    };
    let (a, ma) = run(&cfg, caps(512, 2), &specs);
    let (b, mb) = run(&cfg, caps(512, 2), &specs);
    assert_same_outputs(&a, &b);
    for m in [&ma, &mb] {
        assert_eq!(m.preemptions, 0);
        assert_eq!(m.resumes, 0);
        assert_eq!(m.grow_events, 0, "full reservation must never grow");
        assert_eq!(m.grow_stalls, 0);
        assert_eq!(m.requests_done, 4);
    }
}

#[test]
fn oversized_requests_are_rejected_by_both_policies() {
    // 4 blocks of 8 slots; a 600-token decode budget clamps to max_len
    // (256) and still needs 32 blocks — impossible, reject fast. A small
    // sibling request must be unaffected.
    for admission in [
        AdmissionPolicy::ReserveFull,
        AdmissionPolicy::Speculative { reserve_frac: 0.1, headroom_blocks: 2 },
    ] {
        let cfg = EngineConfig {
            pool: PoolConfig { block_size: BS, num_blocks: 4, prefix_sharing: true },
            admission,
            ..Default::default()
        };
        let specs = vec![
            Spec {
                prompt: prompt(0, 10),
                max_new: 600,
                sampling: SampleCfg::greedy(),
                priority: Priority::Interactive,
                slo_ms: None,
            },
            Spec {
                prompt: prompt(1, 10),
                max_new: 10,
                sampling: SampleCfg::greedy(),
                priority: Priority::Interactive,
                slo_ms: None,
            },
        ];
        let (got, m) = run(&cfg, caps(256, 2), &specs);
        assert_eq!(m.requests_rejected, 1, "{admission:?}");
        assert_eq!(got[0].finished_reason, FinishReason::CacheFull);
        assert!(got[0].tokens.is_empty(), "rejected request must not fabricate output");
        assert_eq!(got[1].tokens.len(), 10, "{admission:?}: small sibling must complete");
        assert_eq!(got[1].finished_reason, FinishReason::MaxTokens);
    }
}

/// The e2e acceptance criterion, deterministically: on a long-tail
/// workload through a constrained pool, `Speculative` sustains strictly
/// higher mean written-block occupancy and needs no more decode
/// iterations (≥ throughput at equal work), with zero output divergence
/// from `ReserveFull`.
#[test]
fn speculative_beats_reserve_full_on_long_tail_with_zero_divergence() {
    // Long-tail decode budgets: every 4th request runs 8× longer.
    let specs: Vec<Spec> = (0..12)
        .map(|i| Spec {
            prompt: prompt(i, 16),
            max_new: if i % 4 == 0 { 64 } else { 8 },
            sampling: if i % 2 == 0 {
                SampleCfg::greedy()
            } else {
                SampleCfg { temperature: 0.8, top_p: 0.9, seed: 200 + i }
            },
            priority: Priority::Interactive,
            slo_ms: None,
        })
        .collect();
    let pool = PoolConfig { block_size: BS, num_blocks: 24, prefix_sharing: true };
    let full_cfg = EngineConfig {
        pool,
        admission: AdmissionPolicy::ReserveFull,
        ..Default::default()
    };
    let spec_cfg = EngineConfig {
        pool,
        admission: AdmissionPolicy::Speculative { reserve_frac: 0.1, headroom_blocks: 1 },
        ..Default::default()
    };
    let (full, mf) = run(&full_cfg, caps(256, 4), &specs);
    let (spec, ms) = run(&spec_cfg, caps(256, 4), &specs);

    assert_same_outputs(&full, &spec);
    assert_eq!(mf.tokens_generated, ms.tokens_generated, "same work either way");
    assert_eq!(mf.requests_done, 12);
    assert_eq!(ms.requests_done, 12);
    assert!(ms.preemptions > 0, "constrained pool must exercise preemption");
    assert!(
        ms.mean_pool_occupancy() > mf.mean_pool_occupancy(),
        "speculative occupancy {:.4} must beat reserve-full {:.4}",
        ms.mean_pool_occupancy(),
        mf.mean_pool_occupancy()
    );
    assert!(
        ms.decode_steps <= mf.decode_steps,
        "speculative must not need more iterations ({} vs {})",
        ms.decode_steps,
        mf.decode_steps
    );
}

/// A contended mixed-priority long-tail workload: interactive requests
/// are short decodes with small distinct prompts; batch requests are
/// long decodes behind a *shared* 64-token system prompt (8 shared
/// blocks at `BS = 8`), submitted interleaved so plain FIFO would admit
/// batch work first. The shared prefix matters twice: it is what full
/// preemption re-prefills on every resume but partial preemption keeps
/// resident, and its blocks free nothing when released (refcounts), so
/// both modes pay eviction in comparable tail-block units.
fn mixed_priority_specs() -> Vec<Spec> {
    let shared: Vec<i32> = (0..64).map(|i| ((i * 5 + 1) % 96) as i32).collect();
    (0..12)
        .map(|i| {
            let batch = i % 2 == 0;
            let prompt = if batch {
                let mut p = shared.clone();
                p.extend(prompt(i, 8));
                p
            } else {
                prompt(i, 16)
            };
            Spec {
                prompt,
                max_new: if batch { 48 } else { 8 },
                sampling: if i % 3 == 0 {
                    SampleCfg { temperature: 0.8, top_p: 0.9, seed: 300 + i }
                } else {
                    SampleCfg::greedy()
                },
                priority: if batch { Priority::Batch } else { Priority::Interactive },
                slo_ms: None,
            }
        })
        .collect()
}

/// The PR 3 acceptance criterion, deterministically: under a contended
/// mixed-priority long-tail workload with the priority-aware victim
/// policy, (a) partial preemption recomputes strictly fewer tokens than
/// whole-sequence preemption, (b) `Interactive` gets strictly lower mean
/// TTFT than `Batch` (measured in decode steps — wall-clock-free), and
/// (c) every completed output is byte-identical to an uncontended run.
#[test]
fn priority_aware_partial_preemption_on_contended_mixed_long_tail() {
    let specs = mixed_priority_specs();

    // Uncontended baseline: worst-case pool, nothing can preempt.
    let base_cfg = EngineConfig {
        pool: PoolConfig { block_size: BS, num_blocks: 0, prefix_sharing: true },
        ..Default::default()
    };
    let (base, bm) = run(&base_cfg, caps(256, 4), &specs);
    assert_eq!(bm.preemptions, 0, "worst-case pool must never preempt");
    assert_eq!(bm.requests_done, 12);

    // Contended twins differing only in how much a preemption evicts.
    // A batch request's full footprint is 72 + 48 + 2 = 122 tokens → 16
    // blocks, of which 8 are the shared prompt: four batch lanes need
    // 8 + 4·8 = 40 blocks at peak, so a 32-block pool forces decode-time
    // growth to preempt (while any single request still fits: 16 ≤ 32).
    let contended = |preempt| EngineConfig {
        pool: PoolConfig { block_size: BS, num_blocks: 32, prefix_sharing: true },
        admission: AdmissionPolicy::Speculative { reserve_frac: 0.1, headroom_blocks: 1 },
        victim_policy: VictimPolicy::PriorityAware,
        preempt,
        ..Default::default()
    };
    let (full, mf) = run(&contended(PreemptMode::Full), caps(256, 4), &specs);
    let (part, mp) = run(&contended(PreemptMode::Partial), caps(256, 4), &specs);

    // (c) Scheduling must be invisible in outputs, under both modes.
    assert_same_outputs(&base, &full);
    assert_same_outputs(&base, &part);
    for (label, m) in [("full", &mf), ("partial", &mp)] {
        assert_eq!(m.requests_done, 12, "{label}: drain stalled: {}", m.report());
        assert_eq!(m.requests_rejected, 0, "{label}");
        assert!(m.preemptions > 0, "{label}: scenario failed to force preemption");
        assert!(m.resumes > 0, "{label}");
    }

    // (a) Partial preemption pays strictly less recompute.
    assert!(mp.partial_preemptions > 0, "no preemption kept a prefix: {}", mp.report());
    assert!(mp.recompute_saved_tokens > 0);
    assert!(
        mp.recomputed_tokens < mf.recomputed_tokens,
        "partial mode must recompute strictly fewer tokens ({} vs {})",
        mp.recomputed_tokens,
        mf.recomputed_tokens
    );

    // (b) The multi-class scheduler protects interactive latency. TTFT
    // is compared in decode steps, which the sim makes deterministic.
    for (label, m) in [("full", &mf), ("partial", &mp)] {
        let int = m.class(Priority::Interactive);
        let bat = m.class(Priority::Batch);
        assert_eq!((int.done, bat.done), (6, 6), "{label}");
        assert!(
            int.ttft_steps.mean() < bat.ttft_steps.mean(),
            "{label}: interactive mean TTFT {:.1} steps must beat batch {:.1}",
            int.ttft_steps.mean(),
            bat.ttft_steps.mean()
        );
        // Victim scoring points at batch lanes first.
        assert!(
            bat.preemptions >= int.preemptions,
            "{label}: batch must absorb at least as many preemptions"
        );
    }
}

/// `PreemptMode::Partial` is orthogonal to the victim policy: under the
/// default youngest-first scan it must still keep prefixes, still save
/// recompute, and still be invisible in outputs.
#[test]
fn partial_preemption_under_youngest_first_is_byte_identical() {
    let specs = mixed_specs();
    let uncontended = EngineConfig {
        pool: PoolConfig { block_size: BS, num_blocks: 0, prefix_sharing: true },
        ..Default::default()
    };
    let (base, _) = run(&uncontended, caps(512, 2), &specs);

    let contended = EngineConfig {
        pool: PoolConfig { block_size: BS, num_blocks: 16, prefix_sharing: true },
        admission: AdmissionPolicy::Speculative { reserve_frac: 0.2, headroom_blocks: 1 },
        preempt: PreemptMode::Partial,
        ..Default::default()
    };
    let (got, m) = run(&contended, caps(512, 2), &specs);
    assert!(m.preemptions > 0, "scenario failed to force preemption: {}", m.report());
    assert!(m.partial_preemptions > 0, "no preemption kept a prefix: {}", m.report());
    assert!(m.recompute_saved_tokens > 0, "kept prefixes must save recompute");
    assert_same_outputs(&base, &got);
}

/// The PR 4 acceptance criterion, deterministically: a sustained
/// interactive flood is queued on top of a parked batch backlog and
/// scheduled deadline-aware over 2 lanes with a worst-case pool (pure
/// queue scheduling — no preemption noise). The step accounting is
/// exact: interactive requests decode `INT_TOKENS` tokens each, so a
/// lane turns over every `INT_TOKENS` decode steps and the flood alone
/// drains in `N_FLOOD / 2 · INT_TOKENS` steps.
///
/// * With aging **off**, the backlog parks behind the whole flood (its
///   wait ≈ the flood drain time — unbounded in flood size).
/// * With aging **on** (bound `A`), each batch request is promoted at
///   wait `A` and takes the very next freed lane: max batch wait ≤
///   `A + 2·INT_TOKENS + 2` (promotion + both lanes turning over + the
///   first-token step).
/// * Interactive mean TTFT stays strictly below batch mean TTFT (the
///   flood is still served first; aging bounds starvation, it does not
///   invert the classes).
/// * Outputs are byte-identical to an uncontended default-policy run —
///   scheduling must never leak into content.
#[test]
fn aging_bounds_batch_starvation_under_interactive_flood() {
    const N_FLOOD: usize = 60;
    const INT_TOKENS: usize = 2;
    const BATCH_TOKENS: usize = 8;
    const AGING: u64 = 44;
    let specs: Vec<Spec> = (0..2)
        .map(|i| Spec {
            prompt: prompt(i, 16),
            max_new: BATCH_TOKENS,
            sampling: SampleCfg::greedy(),
            priority: Priority::Batch,
            slo_ms: None,
        })
        .chain((2..2 + N_FLOOD as u64).map(|i| Spec {
            prompt: prompt(i, 8),
            max_new: INT_TOKENS,
            sampling: SampleCfg::greedy(),
            priority: Priority::Interactive,
            // Generous wall-clock SLO: it exercises deadline stamping
            // and the hit metrics without making the *ordering* depend
            // on wall time (all flood deadlines are equal, so the
            // deterministic FIFO tiebreak decides within the band).
            slo_ms: Some(60_000.0),
        }))
        .collect();
    let pool = PoolConfig { block_size: BS, num_blocks: 0, prefix_sharing: true };

    // Uncontended baseline under the PR 2 default policy.
    let base_cfg = EngineConfig { pool, ..Default::default() };
    let (base, bm) = run(&base_cfg, caps(256, 2), &specs);
    assert_eq!(bm.requests_done, 2 + N_FLOOD as u64);
    assert_eq!(bm.aging_promotions, 0, "aging is deadline-policy-only");

    let deadline_cfg = |aging: Option<u64>| EngineConfig {
        pool,
        victim_policy: VictimPolicy::DeadlineAware,
        aging_steps: aging,
        ..Default::default()
    };
    let (starved, ms) = run(&deadline_cfg(None), caps(256, 2), &specs);
    let (aged, ma) = run(&deadline_cfg(Some(AGING)), caps(256, 2), &specs);

    // Scheduling is invisible in outputs, promoted or parked.
    assert_same_outputs(&base, &starved);
    assert_same_outputs(&base, &aged);

    // Aging promoted each batch request exactly once.
    assert_eq!(ms.aging_promotions, 0);
    assert_eq!(ma.aging_promotions, 2, "{}", ma.report());

    // The starvation bound: promotion + one turnover of both lanes +
    // the first-token step.
    let bound = AGING + 2 * INT_TOKENS as u64 + 2;
    let starved_wait = ms.class(Priority::Batch).max_wait_steps;
    let aged_wait = ma.class(Priority::Batch).max_wait_steps;
    assert!(
        aged_wait <= bound,
        "aged batch wait {aged_wait} exceeds the bound {bound}: {}",
        ma.report()
    );
    assert!(
        starved_wait > bound,
        "without aging the backlog must park past the bound \
         ({starved_wait} <= {bound}) or the scenario proves nothing"
    );
    assert!(
        aged_wait < starved_wait,
        "aging must strictly reduce the max batch wait ({aged_wait} vs {starved_wait})"
    );

    // Interactive latency stays protected, and every flood SLO is met.
    for m in [&ms, &ma] {
        let int = m.class(Priority::Interactive);
        let bat = m.class(Priority::Batch);
        assert_eq!((int.done, bat.done), (N_FLOOD as u64, 2));
        assert!(
            int.ttft_steps.mean() < bat.ttft_steps.mean(),
            "interactive mean TTFT {:.1} must stay below batch {:.1}: {}",
            int.ttft_steps.mean(),
            bat.ttft_steps.mean(),
            m.report()
        );
        assert_eq!(int.deadline_hits, N_FLOOD as u64);
        assert_eq!(int.deadline_misses, 0);
        assert_eq!(int.deadline_hit_rate(), 1.0);
        assert_eq!((bat.deadline_hits, bat.deadline_misses), (0, 0), "no SLO, no grade");
    }
}

/// Earliest-effective-deadline ordering within the interactive band: on
/// a single lane, requests are admitted tightest-deadline-first, and a
/// deadline-less request runs after every SLO'd one. The SLO spacing is
/// seconds-wide, so sub-millisecond submission jitter can never reorder
/// the keys.
#[test]
fn deadline_aware_admission_is_earliest_deadline_first() {
    let mk = |i: u64, slo_ms: Option<f64>| Spec {
        prompt: prompt(i, 8),
        max_new: 4,
        sampling: SampleCfg::greedy(),
        priority: Priority::Interactive,
        slo_ms,
    };
    // Submission order deliberately scrambled vs deadline order.
    let specs = vec![
        mk(0, Some(50_000.0)),
        mk(1, Some(500.0)),
        mk(2, None),
        mk(3, Some(5_000.0)),
    ];
    let cfg = EngineConfig {
        pool: PoolConfig { block_size: BS, num_blocks: 0, prefix_sharing: true },
        victim_policy: VictimPolicy::DeadlineAware,
        ..Default::default()
    };
    let (got, m) = run(&cfg, caps(256, 1), &specs);
    assert_eq!(m.requests_done, 4);
    let wait = |id: usize| got[id].timing.ttft_steps;
    assert!(
        wait(1) < wait(3) && wait(3) < wait(0) && wait(0) < wait(2),
        "admission order must be 500ms, 5s, 50s, no-SLO — got waits \
         [{} {} {} {}]",
        wait(0),
        wait(1),
        wait(2),
        wait(3)
    );
    // The FIFO twin: under the default policy the same submission order
    // is served in submission order.
    let fifo_cfg = EngineConfig {
        pool: PoolConfig { block_size: BS, num_blocks: 0, prefix_sharing: true },
        ..Default::default()
    };
    let (fifo, _) = run(&fifo_cfg, caps(256, 1), &specs);
    let fwait = |id: usize| fifo[id].timing.ttft_steps;
    assert!(fwait(0) < fwait(1) && fwait(1) < fwait(2) && fwait(2) < fwait(3));
    assert_same_outputs(&fifo, &got);
}

/// Satellite regression: the `PriorityAware` victim scorer prices
/// `Partial`-mode candidates by their **planned truncation depth**, not
/// their full history. Lane `Y`'s blocks are almost all shared (evicting
/// it degrades to a full release: planned cost = its whole 36-token
/// replay); lane `O` has twice the history but a cheap private tail
/// (planned cost ≈ 18 tokens). The full-history proxy would evict `Y`;
/// exact tail-cost scoring must evict `O` — and outputs stay
/// byte-identical either way.
#[test]
fn partial_victim_scoring_uses_planned_truncation_depth() {
    let shared: Vec<i32> = (0..32).map(|i| ((i * 5 + 1) % 96) as i32).collect();
    let with_shared = |suffix_seed: u64, suffix: usize| -> Vec<i32> {
        let mut p = shared.clone();
        p.extend(prompt(suffix_seed, suffix));
        p
    };
    let specs = vec![
        // Lane Z (interactive): co-holds the shared prefix so Y's shared
        // blocks stay refcount-2; never scored ahead of the batch lanes.
        Spec {
            prompt: with_shared(90, 8),
            max_new: 6,
            sampling: SampleCfg::greedy(),
            priority: Priority::Interactive,
            slo_ms: None,
        },
        // Lane Y (batch): 4 shared blocks + 1 private tail. Planned
        // truncation frees almost nothing → degrades to a full release →
        // planned cost = full 36-token replay.
        Spec {
            prompt: with_shared(91, 2),
            max_new: 4,
            sampling: SampleCfg::greedy(),
            priority: Priority::Batch,
            slo_ms: None,
        },
        // Lane O (batch): 48-token private prompt, 7 private blocks —
        // twice Y's history, but truncating 3 tail blocks keeps 32
        // tokens resident → planned cost ≈ 18 tokens.
        Spec {
            prompt: prompt(92, 48),
            max_new: 30,
            sampling: SampleCfg::greedy(),
            priority: Priority::Batch,
            slo_ms: None,
        },
        // Lane G (interactive): speculative grower that exhausts its
        // 1-block reservation and must preempt someone.
        Spec {
            prompt: prompt(93, 6),
            max_new: 20,
            sampling: SampleCfg::greedy(),
            priority: Priority::Interactive,
            slo_ms: None,
        },
    ];
    let (base, _) = run(
        &EngineConfig {
            pool: PoolConfig { block_size: BS, num_blocks: 0, prefix_sharing: true },
            ..Default::default()
        },
        caps(256, 4),
        &specs,
    );
    // 15 blocks = exactly the bootstrap footprint (6 + 1 + 7 + 1), so
    // G's first grow finds the pool dry and must preempt.
    let cfg = EngineConfig {
        pool: PoolConfig { block_size: BS, num_blocks: 15, prefix_sharing: true },
        admission: AdmissionPolicy::Speculative { reserve_frac: 0.0, headroom_blocks: 4 },
        victim_policy: VictimPolicy::PriorityAware,
        preempt: PreemptMode::Partial,
        ..Default::default()
    };
    let (got, m) = run(&cfg, caps(256, 4), &specs);
    assert_eq!(m.requests_done, 4, "drain stalled: {}", m.report());
    assert!(m.preemptions > 0, "scenario failed to force preemption: {}", m.report());
    assert!(m.partial_preemptions > 0, "no preemption kept a prefix: {}", m.report());
    assert!(m.recompute_saved_tokens > 0);
    assert!(
        got[2].timing.preemptions > 0,
        "O (cheap planned tail) must be the victim: {}",
        m.report()
    );
    assert_eq!(
        got[1].timing.preemptions, 0,
        "Y (shared-heavy, expensive planned cost) must be spared — the \
         full-history proxy would have evicted it"
    );
    assert_eq!(got[0].timing.preemptions, 0);
    assert_same_outputs(&base, &got);
}

/// The overload flood every shed test drives: `n` identical-budget
/// interactive requests, all submitted at once, each decoding exactly
/// `TOKENS` tokens (no stop token — decode lengths are deterministic,
/// which is what makes the predictor's occupancy model *exact* here).
/// On 2 lanes, the request at queue position `k` reaches its first
/// token at decode step `(k / 2) · TOKENS + 1`, so with an SLO of
/// `SLO_MS` steps-domain milliseconds (step_ms = 1), exactly the first
/// `2 · (⌊(SLO_MS − 1) / TOKENS⌋ + 1)` requests are reachable.
const FLOOD_TOKENS: usize = 6;
const FLOOD_SLO_MS: f64 = 13.0; // waves 0, 1, 2 reachable (ttft 1, 7, 13)
const FLOOD_N: usize = 24;

fn flood_specs() -> Vec<Spec> {
    (0..FLOOD_N as u64)
        .map(|i| Spec {
            prompt: prompt(i, 8),
            max_new: FLOOD_TOKENS,
            sampling: SampleCfg::greedy(),
            priority: Priority::Interactive,
            slo_ms: Some(FLOOD_SLO_MS),
        })
        .collect()
}

fn flood_cfg(shed: ShedPolicy) -> EngineConfig {
    EngineConfig {
        pool: PoolConfig { block_size: BS, num_blocks: 0, prefix_sharing: true },
        victim_policy: VictimPolicy::DeadlineAware,
        shed,
        // The deterministic decode-steps twin: 1 virtual ms per decode
        // step, free prefill — predictions, deadline grades, goodput
        // and wasted work are all bit-reproducible.
        clock: EngineClock::Steps { step_ms: 1.0, prefill_ms_per_token: 0.0 },
        ..Default::default()
    }
}

/// The PR 5 acceptance criterion, deterministically: under an overload
/// flood (12 waves of SLO'd work on 2 lanes, only 3 waves reachable),
/// `ShedPolicy::Strict` sheds exactly the doomed requests at admission
/// — zero shed errors, graded by replaying the same trace under `Off`
/// — and thereby wins strictly on goodput (deadline-hit tokens per
/// decode step) and strictly on wasted work. Completed outputs are
/// byte-identical across `Off`, `Strict` and the PR 2 default config:
/// shedding changes *which* requests run, never what they produce.
#[test]
fn strict_shedding_beats_off_on_overload_flood() {
    let specs = flood_specs();
    let (off, mo) = run(&flood_cfg(ShedPolicy::Off), caps(256, 2), &specs);
    let (strict, ms) = run(&flood_cfg(ShedPolicy::Strict), caps(256, 2), &specs);

    // Off pins PR 4: nothing shed, everything runs (and mostly dies).
    assert_eq!(mo.requests_shed, 0);
    assert_eq!(mo.requests_done, FLOOD_N as u64);
    let int_off = mo.class(Priority::Interactive);
    assert!(
        int_off.deadline_misses > 0,
        "the flood must actually overload the gang: {}",
        mo.report()
    );
    // ...and byte-identically matches the PR 2 default policy (same
    // FIFO order here: equal-SLO deadlines tie-break by submission).
    let base_cfg = EngineConfig {
        pool: PoolConfig { block_size: BS, num_blocks: 0, prefix_sharing: true },
        ..Default::default()
    };
    let (base, _) = run(&base_cfg, caps(256, 2), &specs);
    assert_same_outputs(&base, &off);

    // Strict sheds every doomed request up front and completes the rest.
    assert!(ms.requests_shed > 0, "overload must trigger shedding: {}", ms.report());
    assert_eq!(
        ms.requests_done + ms.requests_shed,
        FLOOD_N as u64,
        "every request is either completed or shed: {}",
        ms.report()
    );
    assert_eq!(
        ms.class(Priority::Interactive).requests_shed,
        ms.requests_shed,
        "sheds are tallied per class"
    );

    // Shed replies are structured: prediction + retry hint, no tokens.
    let mut shed_ids = Vec::new();
    for r in &strict {
        if r.finished_reason == FinishReason::Shed {
            shed_ids.push(r.id);
            assert!(r.tokens.is_empty(), "#{}: a shed request must not fabricate output", r.id);
            let info = r.shed.expect("shed reply carries ShedInfo");
            assert!(
                info.predicted_ttft_ms > FLOOD_SLO_MS,
                "#{}: shed prediction {} must exceed the deadline",
                r.id,
                info.predicted_ttft_ms
            );
            assert!(
                (info.retry_after_ms - (info.predicted_ttft_ms - FLOOD_SLO_MS)).abs() < 1e-9,
                "#{}: retry hint must be the predicted overshoot",
                r.id
            );
        } else {
            assert!(r.shed.is_none(), "completed requests carry no shed info");
        }
    }
    assert_eq!(shed_ids.len() as u64, ms.requests_shed);

    // Zero shed errors: every shed id provably missed in the Off replay.
    for &id in &shed_ids {
        assert_eq!(
            off[id as usize].timing.deadline_hit,
            Some(false),
            "#{id} was shed but its Off twin hit the deadline — a shed error"
        );
    }
    // And nothing reachable was shed: every Off-run hit also completed
    // (and hit) under Strict.
    for r in &off {
        if r.timing.deadline_hit == Some(true) {
            let twin = &strict[r.id as usize];
            assert_eq!(
                twin.finished_reason, r.finished_reason,
                "#{}: a reachable request must complete under Strict",
                r.id
            );
            assert_eq!(twin.tokens, r.tokens, "#{}: outputs must not diverge", r.id);
            assert_eq!(twin.timing.deadline_hit, Some(true));
        }
    }

    // The headline: strictly higher goodput, strictly lower waste.
    assert!(
        ms.goodput() > mo.goodput(),
        "strict goodput {:.3} must strictly beat off {:.3}",
        ms.goodput(),
        mo.goodput()
    );
    assert!(
        ms.wasted_work_tokens() < mo.wasted_work_tokens(),
        "strict wasted {} must be strictly below off {}",
        ms.wasted_work_tokens(),
        mo.wasted_work_tokens()
    );
    // Shedding never costs a deadline hit: the same requests that hit
    // under Off hit under Strict, and nothing Strict ran missed.
    let int_strict = ms.class(Priority::Interactive);
    assert_eq!(int_strict.deadline_hits, int_off.deadline_hits);
    assert_eq!(int_strict.deadline_misses, 0, "{}", ms.report());
    assert!(ms.decode_steps < mo.decode_steps, "doomed decode steps must disappear");

    // Deterministic steps-domain twin: an identical rerun reproduces
    // every shed decision, grade and metric bit-for-bit.
    let (strict2, ms2) = run(&flood_cfg(ShedPolicy::Strict), caps(256, 2), &specs);
    assert_same_outputs(&strict, &strict2);
    for (a, b) in strict.iter().zip(&strict2) {
        assert_eq!(a.shed, b.shed, "#{}: shed predictions must be deterministic", a.id);
        assert_eq!(a.timing.deadline_hit, b.timing.deadline_hit);
    }
    assert_eq!(ms.requests_shed, ms2.requests_shed);
    assert_eq!(ms.decode_steps, ms2.decode_steps);
    assert_eq!(ms.goodput().to_bits(), ms2.goodput().to_bits());
}

/// `Hedged { margin_frac }` sheds only requests predicted past the
/// deadline *by the margin*: on the same flood, the first doomed wave
/// (predicted 19 ms vs a 13 ms SLO — within 1.5×) is given the benefit
/// of the doubt and runs to a graded miss, while everything beyond the
/// margin is still shed. Goodput lands strictly between Off and Strict.
#[test]
fn hedged_shedding_spares_borderline_requests() {
    let specs = flood_specs();
    let (off, mo) = run(&flood_cfg(ShedPolicy::Off), caps(256, 2), &specs);
    let (strict, ms) = run(&flood_cfg(ShedPolicy::Strict), caps(256, 2), &specs);
    let (hedged, mh) =
        run(&flood_cfg(ShedPolicy::Hedged { margin_frac: 0.5 }), caps(256, 2), &specs);

    assert!(mh.requests_shed > 0, "the deep tail is past any margin: {}", mh.report());
    assert!(
        mh.requests_shed < ms.requests_shed,
        "the margin must spare borderline work ({} vs strict {})",
        mh.requests_shed,
        ms.requests_shed
    );
    assert_eq!(mh.requests_done + mh.requests_shed, FLOOD_N as u64);
    // The spared borderline requests run — and miss, which is exactly
    // the waste the margin buys as insurance against model error.
    let int = mh.class(Priority::Interactive);
    assert!(int.deadline_misses > 0, "spared borderline work grades as misses");
    assert!(mh.wasted_work_tokens() > ms.wasted_work_tokens());
    assert!(mh.wasted_work_tokens() < mo.wasted_work_tokens());
    assert!(mh.goodput() > mo.goodput(), "hedged still beats queueing-to-die");
    assert!(mh.goodput() <= ms.goodput(), "but pays for its insurance");
    // Whatever ran produced exactly the Off-twin bytes.
    for r in &hedged {
        if r.finished_reason != FinishReason::Shed {
            assert_eq!(r.tokens, off[r.id as usize].tokens, "#{} diverged", r.id);
        } else {
            assert_eq!(
                strict[r.id as usize].finished_reason,
                FinishReason::Shed,
                "#{}: anything hedged sheds, strict must shed too",
                r.id
            );
        }
    }
}

/// Satellite regression: first-token metrics are recorded exactly once
/// across preempt→resume. Lane B is admitted and immediately preempted
/// by lane A's growth *in the same scheduling iteration* — before
/// section 6 ever delivered B's first token — then resumed after A
/// completes. The proof that the preemption landed before the first
/// emission is `recomputed_tokens == 8`: B's resume re-prefilled its
/// prompt only, nothing produced. TTFT/deadline/max-wait bookkeeping
/// must fire once per request (at the real delivery), and outputs stay
/// byte-identical to the uncontended twin.
#[test]
fn first_token_metrics_recorded_once_across_preempt_resume() {
    let clock = EngineClock::Steps { step_ms: 1.0, prefill_ms_per_token: 0.0 };
    let specs = vec![
        // A: long decode; its speculative growth is the preemptor.
        Spec {
            prompt: prompt(0, 8),
            max_new: 16,
            sampling: SampleCfg::greedy(),
            priority: Priority::Interactive,
            slo_ms: None,
        },
        // C: finishes at decode step 8, freeing the lane B enters at
        // the exact iteration A's block table runs out.
        Spec {
            prompt: prompt(1, 8),
            max_new: 8,
            sampling: SampleCfg::greedy(),
            priority: Priority::Interactive,
            slo_ms: None,
        },
        // B: the victim — youngest at preemption time, SLO'd so the
        // deadline grade count is observable (steps clock: its eventual
        // ttft is far below 1000 virtual ms → exactly one hit).
        Spec {
            prompt: prompt(2, 8),
            max_new: 4,
            sampling: SampleCfg::greedy(),
            priority: Priority::Interactive,
            slo_ms: Some(1000.0),
        },
    ];
    let base_cfg = EngineConfig {
        pool: PoolConfig { block_size: BS, num_blocks: 0, prefix_sharing: true },
        clock,
        ..Default::default()
    };
    let (base, bm) = run(&base_cfg, caps(256, 2), &specs);
    assert_eq!(bm.preemptions, 0, "worst-case pool must never preempt");

    // 4 blocks: bootstrap (A: 2, C: 2) fills the pool; C's completion
    // frees 2, B takes them, and A's first grow finds nothing free.
    let contended = EngineConfig {
        pool: PoolConfig { block_size: BS, num_blocks: 4, prefix_sharing: true },
        admission: AdmissionPolicy::Speculative { reserve_frac: 0.0, headroom_blocks: 1 },
        clock,
        ..Default::default()
    };
    let (got, m) = run(&contended, caps(256, 2), &specs);
    assert_eq!(m.requests_done, 3, "drain stalled: {}", m.report());
    assert_eq!(m.preemptions, 1, "scenario must preempt exactly once: {}", m.report());
    assert_eq!(m.resumes, 1);
    assert_eq!(
        m.recomputed_tokens, 8,
        "resume must replay the prompt only — the preemption landed before \
         B's first token: {}",
        m.report()
    );
    assert_eq!(got[2].timing.preemptions, 1, "B carries its preemption count");
    assert_same_outputs(&base, &got);

    // Single-recording: one TTFT sample per request, fleet-wide and
    // per-class, and exactly one deadline grade for the one SLO'd
    // request — a double-graded resume would show up in every one of
    // these counters.
    assert_eq!(m.ttft.count(), 3, "{}", m.report());
    let int = m.class(Priority::Interactive);
    assert_eq!(int.ttft.count(), 3);
    assert_eq!(int.ttft_steps.count(), 3);
    assert_eq!(
        int.deadline_hits + int.deadline_misses,
        1,
        "B must be graded exactly once: {}",
        m.report()
    );
    assert_eq!(int.deadline_hits, 1);
    assert_eq!(got[2].timing.deadline_hit, Some(true));
    // B's delivered first token came after the preemption detour, so
    // its step-TTFT must exceed A's un-preempted first token.
    assert!(got[2].timing.ttft_steps > got[0].timing.ttft_steps);
    // max_wait tracks the worst first-token wait — B's detour.
    assert_eq!(int.max_wait_steps, got[2].timing.ttft_steps);
}

/// Satellite regression for the clock-grading fix: under the
/// deterministic steps clock the deadline grade is a pure function of
/// the recorded `ttft_steps` — the same stamp the reply echoes — so a
/// token produced in budget can never be graded a miss by a later
/// wall-clock read, and goodput/wasted-work accounting follows the
/// grade exactly.
#[test]
fn steps_clock_grades_deadlines_from_the_emission_stamp() {
    const STEP_MS: f64 = 1.0;
    const SLO: f64 = 5.0;
    let specs: Vec<Spec> = (0..2)
        .map(|i| Spec {
            prompt: prompt(i, 8),
            max_new: 10,
            sampling: SampleCfg::greedy(),
            priority: Priority::Interactive,
            slo_ms: Some(SLO),
        })
        .collect();
    let cfg = EngineConfig {
        pool: PoolConfig { block_size: BS, num_blocks: 0, prefix_sharing: true },
        clock: EngineClock::Steps { step_ms: STEP_MS, prefill_ms_per_token: 0.0 },
        ..Default::default()
    };
    // One lane: request 0 emits at step 1 (hit), request 1 waits the
    // full 10-step drain and emits at step 11 (miss).
    let (got, m) = run(&cfg, caps(256, 1), &specs);
    assert_eq!(m.requests_done, 2);
    for r in &got {
        let want = r.timing.ttft_steps as f64 * STEP_MS <= SLO;
        assert_eq!(
            r.timing.deadline_hit,
            Some(want),
            "#{}: grade must match the emission stamp (ttft {} steps, slo {SLO})",
            r.id,
            r.timing.ttft_steps
        );
    }
    assert_eq!(got[0].timing.deadline_hit, Some(true));
    assert_eq!(got[1].timing.deadline_hit, Some(false));
    let int = m.class(Priority::Interactive);
    assert_eq!((int.deadline_hits, int.deadline_misses), (1, 1));
    // Goodput follows the grades: 10 hit tokens over 20 decode steps,
    // 10 missed tokens wasted.
    assert_eq!(m.decode_steps, 20, "{}", m.report());
    assert!((m.goodput() - 0.5).abs() < 1e-12, "goodput {}", m.goodput());
    assert_eq!(m.wasted_work_tokens(), 10);

    // The virtual prefill cost is charged by the grader exactly as the
    // predictor prices it: 0.5 ms per prompt token puts request 0's
    // 8-token prompt right on the boundary (1·1.0 + 8·0.5 = 5 ≤ 5 —
    // still a hit), and request 1 further past it (11 + 4 = 15 > 5).
    // Charging prefill on the predictor side only would let `Strict`
    // shed requests this grader calls hits.
    let cfg = EngineConfig {
        clock: EngineClock::Steps { step_ms: STEP_MS, prefill_ms_per_token: 0.5 },
        ..cfg
    };
    let (got, m) = run(&cfg, caps(256, 1), &specs);
    assert_eq!(got[0].timing.deadline_hit, Some(true), "boundary: 1 + 8·0.5 = 5 ≤ 5");
    assert_eq!(got[1].timing.deadline_hit, Some(false));
    let int = m.class(Priority::Interactive);
    assert_eq!((int.deadline_hits, int.deadline_misses), (1, 1));
}

/// Satellite: the reservation formula is pinned — the old magic `+ 2` is
/// now `RESERVE_SLACK_TOKENS` and the exact block count for a known
/// prompt/max_new/block_size triple must never drift silently.
#[test]
fn reservation_formula_is_pinned() {
    assert_eq!(RESERVE_SLACK_TOKENS, 2);
    // prompt 100, max_new 50, block_size 16: 100 + 50 + 2 = 152 tokens
    // → exactly 10 blocks.
    let r = reserve_tokens(AdmissionPolicy::ReserveFull, 100, 50, 1024);
    assert_eq!(r, 152);
    let alloc = BlockAllocator::new(64, 16);
    assert_eq!(alloc.blocks_for(r), 10);
    // Speculative at 0.25 reserves ceil(50·0.25) = 13 of the budget.
    let s = reserve_tokens(
        AdmissionPolicy::Speculative { reserve_frac: 0.25, headroom_blocks: 2 },
        100,
        50,
        1024,
    );
    assert_eq!(s, 100 + 13 + 2);
    assert_eq!(alloc.blocks_for(s), 8);
    // Both clamp at the physical cache bound.
    assert_eq!(reserve_tokens(AdmissionPolicy::ReserveFull, 100, 5000, 1024), 1024);
    assert_eq!(
        reserve_tokens(
            AdmissionPolicy::Speculative { reserve_frac: 1.0, headroom_blocks: 2 },
            100,
            5000,
            1024
        ),
        1024
    );
}

/// Satellite regression: prefill attribution counts only the *real*
/// prompt tokens of an admitted batch — a mostly-padded bootstrap gang
/// must not credit its filler lanes. (Crediting padding diluted the
/// estimator's per-token prefill rate, under-pricing long prompts until
/// `Strict` admitted provably-doomed requests.)
#[test]
fn prefill_accounting_ignores_padding_lanes() {
    let cfg = EngineConfig {
        clock: EngineClock::Steps { step_ms: 1.0, prefill_ms_per_token: 1.0 },
        ..Default::default()
    };
    // One 8-token prompt into a gang of 4: three padding lanes ride
    // along in the batched bootstrap prefill.
    let specs = vec![Spec {
        prompt: prompt(0, 8),
        max_new: 4,
        sampling: SampleCfg::greedy(),
        priority: Priority::Interactive,
        slo_ms: None,
    }];
    let (got, m) = run(&cfg, caps(64, 4), &specs);
    assert_eq!(got.len(), 1);
    assert_eq!(
        m.prefill_tokens, 8,
        "bootstrap must bill the real prompt only, not its 3 padding lanes: {}",
        m.report()
    );
    // The charged virtual time follows the same count: 8 tokens at
    // 1 ms/token on the engine clock — not 11.
    assert!((m.prefill_charged_ms - 8.0).abs() < 1e-9, "charged {}", m.prefill_charged_ms);
}

/// Tentpole: the PR 5 first-token/preempt-resume scenario must hold on
/// the chunked-prefill path too. With the chunk covering the whole
/// prompt the schedule is the monolithic one (B is preempted as a
/// *busy* lane and resumed); with a smaller chunk the same round's
/// preemption lands mid-prefill — the item requeues *unopened* (a
/// fresh request stays fresh: no resume, nothing recomputed) and
/// restarts its prefill from token zero. Either way outputs are
/// byte-identical to the uncontended twin and first-token bookkeeping
/// fires exactly once per request.
#[test]
fn chunked_prefill_preempt_resume_is_byte_identical() {
    let clock = EngineClock::Steps { step_ms: 1.0, prefill_ms_per_token: 0.0 };
    // Same cast as `first_token_metrics_recorded_once_across_preempt_resume`:
    // A's speculative growth preempts B in the very round B is admitted
    // (C's completion freed the blocks B took).
    let specs = vec![
        Spec {
            prompt: prompt(0, 8),
            max_new: 16,
            sampling: SampleCfg::greedy(),
            priority: Priority::Interactive,
            slo_ms: None,
        },
        Spec {
            prompt: prompt(1, 8),
            max_new: 8,
            sampling: SampleCfg::greedy(),
            priority: Priority::Interactive,
            slo_ms: None,
        },
        Spec {
            prompt: prompt(2, 8),
            max_new: 4,
            sampling: SampleCfg::greedy(),
            priority: Priority::Interactive,
            slo_ms: Some(1000.0),
        },
    ];
    let base_cfg = EngineConfig {
        pool: PoolConfig { block_size: BS, num_blocks: 0, prefix_sharing: true },
        clock,
        ..Default::default()
    };
    let (base, bm) = run(&base_cfg, caps(256, 2), &specs);
    assert_eq!(bm.preemptions, 0, "worst-case pool must never preempt");

    let contended = |chunk: usize| EngineConfig {
        pool: PoolConfig { block_size: BS, num_blocks: 4, prefix_sharing: true },
        admission: AdmissionPolicy::Speculative { reserve_frac: 0.0, headroom_blocks: 1 },
        clock,
        prefill_chunk: Some(chunk),
        ..Default::default()
    };

    // Chunk ≥ prompt: every prefill is a single chunk, injected in its
    // admission round — the monolithic schedule, so B is preempted as a
    // busy lane before its first delivery and resumed with a
    // prompt-only replay.
    let (got, m) = run(&contended(8), caps(256, 2), &specs);
    assert_same_outputs(&base, &got);
    assert_eq!(m.requests_done, 3, "{}", m.report());
    assert_eq!((m.preemptions, m.resumes), (1, 1), "{}", m.report());
    assert_eq!(m.recomputed_tokens, 8, "resume replays the prompt only: {}", m.report());
    assert_eq!(got[2].timing.preemptions, 1, "B carries its preemption count");
    assert_eq!(m.ttft.count(), 3, "{}", m.report());
    let int = m.class(Priority::Interactive);
    assert_eq!(int.ttft_ms.count(), 3);
    assert_eq!(int.deadline_hits + int.deadline_misses, 1, "B graded exactly once");
    assert_eq!(int.deadline_hits, 1);
    // One chunk per admission: A, C, B, and B's resume.
    assert_eq!(m.prefill_chunks, 4, "{}", m.report());
    assert_eq!(m.prefill_stall.count(), 4);

    // Chunk smaller than the prompt: the same preemption lands while B
    // is still `Prefilling`. The partial batch-1 state is discarded,
    // the whole reservation returns, and the item re-enters its band
    // front unopened.
    let (got, m) = run(&contended(4), caps(256, 2), &specs);
    assert_same_outputs(&base, &got);
    assert_eq!(m.requests_done, 3, "{}", m.report());
    assert_eq!(m.preemptions, 1, "{}", m.report());
    assert_eq!(m.resumes, 0, "mid-prefill preemption requeues unopened: {}", m.report());
    assert_eq!(m.recomputed_tokens, 0, "{}", m.report());
    assert_eq!(got[2].timing.preemptions, 0, "a fresh restart carries no preemption count");
    assert_eq!(m.ttft.count(), 3, "{}", m.report());
    let int = m.class(Priority::Interactive);
    assert_eq!(int.ttft_ms.count(), 3);
    assert_eq!(int.deadline_hits + int.deadline_misses, 1, "B graded exactly once");
    assert_eq!(int.deadline_hits, 1);
    // A and C take 2 chunks apiece; B runs 1 chunk, forfeits it to the
    // preemption, and re-runs both from scratch.
    assert_eq!(m.prefill_chunks, 7, "{}", m.report());
    assert_eq!(m.prefill_stall.count(), 3, "only completed prefills record a stall");
}

/// Tentpole acceptance (deterministic twin of bench scenario 7): under
/// the steps clock with a nonzero per-token prefill charge, chunking a
/// long prompt drops interactive TTFT — their first tokens no longer
/// wait out the whole monolithic prefill charge — while completed
/// streams stay byte-identical, the long prompt's penalty is bounded by
/// one round per extra chunk, and a rerun reproduces everything.
#[test]
fn chunked_prefill_cuts_interactive_ttft_with_identical_outputs() {
    const CHUNK: usize = 16;
    let clock = EngineClock::Steps { step_ms: 1.0, prefill_ms_per_token: 0.5 };
    // All four requests fit the bootstrap gang, so the monolithic run
    // prefills the long prompt in the same batch as the interactive
    // turns — the worst case, where its whole 48 ms prefill charge
    // lands on the clock before every first token. (The interactive
    // band still sorts ahead of Batch in the queue; with one gang-wide
    // batch that only decides lane order, which nothing here observes.)
    let mut specs = vec![Spec {
        prompt: prompt(0, 96),
        max_new: 8,
        sampling: SampleCfg::greedy(),
        priority: Priority::Batch,
        slo_ms: Some(50.0),
    }];
    for i in 1..4u64 {
        specs.push(Spec {
            prompt: prompt(i, 8),
            max_new: 4,
            sampling: SampleCfg::greedy(),
            priority: Priority::Interactive,
            slo_ms: Some(400.0),
        });
    }
    let cfg = |chunk: Option<usize>| EngineConfig {
        gang_batch: 4,
        victim_policy: VictimPolicy::DeadlineAware,
        clock,
        prefill_chunk: chunk,
        ..Default::default()
    };
    let (mono, mono_m) = run(&cfg(None), caps(256, 4), &specs);
    let (chunked, chunked_m) = run(&cfg(Some(CHUNK)), caps(256, 4), &specs);
    assert_eq!(mono_m.requests_done, 4, "{}", mono_m.report());
    assert_eq!(chunked_m.requests_done, 4, "{}", chunked_m.report());
    assert_same_outputs(&mono, &chunked);

    // Interactive first tokens land between the long prompt's chunks.
    let mono_p99 = mono_m.class(Priority::Interactive).ttft_ms.percentile(99.0);
    let chunked_p99 = chunked_m.class(Priority::Interactive).ttft_ms.percentile(99.0);
    assert!(
        chunked_p99 < mono_p99,
        "chunked int ttft_ms p99 {chunked_p99} must beat monolithic {mono_p99}"
    );
    // Bounded penalty: at most one extra decode round per chunk after
    // the first.
    let extra_rounds = (96usize.div_ceil(CHUNK) - 1) as u64;
    assert!(
        chunked_m.decode_steps <= mono_m.decode_steps + extra_rounds,
        "decode steps {} must stay within {} + {}",
        chunked_m.decode_steps,
        mono_m.decode_steps,
        extra_rounds
    );
    // Chunk accounting is exact: 96/16 = 6 chunks for the long prompt,
    // one apiece for the three short ones; monolithic runs none.
    assert_eq!(chunked_m.prefill_chunks, 9, "{}", chunked_m.report());
    assert_eq!(chunked_m.chunked_prefill_tokens, 120);
    assert_eq!(chunked_m.prefill_stall.count(), 4);
    assert_eq!(mono_m.prefill_chunks, 0, "{}", mono_m.report());

    // Deterministic: a rerun reproduces the streams and the histogram.
    let (again, again_m) = run(&cfg(Some(CHUNK)), caps(256, 4), &specs);
    assert_same_outputs(&chunked, &again);
    let again_p99 = again_m.class(Priority::Interactive).ttft_ms.percentile(99.0);
    assert_eq!(again_p99, chunked_p99);
}
