//! Deterministic acceptance tests for the sharded serving frontend:
//! the [`Router`] splits a bursty multi-tenant shared-prefix trace
//! across two sim-backed engine replicas, and on the Steps clock the
//! whole fleet — routing decisions, per-replica token streams and
//! flight-recorder traces — must be a pure function of (trace, policy).
//!
//! This is the acceptance twin of e2e_serving scenario 8: the bench
//! reports the numbers, this file pins the orderings (prefix-affinity
//! strictly beats round-robin on fleet prefix-hit rate, charged TTFT
//! and goodput) plus the reproducibility and cross-replica-disjointness
//! invariants CI gates on.

use std::sync::mpsc::channel;

use loki::coordinator::request::{GenRequest, GenResult, Priority};
use loki::coordinator::sampler::SampleCfg;
use loki::coordinator::{
    Engine, EngineCaps, EngineClock, EngineConfig, EngineMetrics, PoolConfig, RouteDecision,
    RoutePolicy, Router, RouterCfg, VictimPolicy,
};
use loki::obs::export::{check_jsonl, cross_replica_violations, trace_hash, trace_jsonl};
use loki::runtime::{SimCfg, SimRuntime};

const GANG: usize = 4;
const BS: usize = 16;
const TENANTS: usize = 8;
const BURST: usize = GANG;
const ROUNDS: usize = 2;
const PREFIX_BLOCKS: usize = 8;
const SUFFIX: usize = 16;
// Charged-domain SLO: warm first tokens (prefix served from the shared
// index, only the 16 suffix tokens charged) land well inside it; cold
// ones are charged the full 144-token prefill and can never make it.
const SLO_MS: f64 = 80.0;

/// Distinct-per-request prompt material within the sim vocabulary.
fn sim_prompt(id: u64, len: usize) -> Vec<i32> {
    (0..len).map(|i| ((id as usize * 31 + i * 7 + 3) % 96) as i32).collect()
}

/// The scenario-8 trace shape: each tenant fires a gang-sized burst of
/// `prefix ++ unique suffix` prompts per round, tenants round-robining
/// the submission stream.
fn trace_prompts() -> Vec<Vec<i32>> {
    let mut prompts = Vec::new();
    for round in 0..ROUNDS {
        for tenant in 0..TENANTS {
            for slot in 0..BURST {
                let mut p = sim_prompt(10_000 + tenant as u64, PREFIX_BLOCKS * BS);
                let unique = (round * TENANTS * BURST + tenant * BURST + slot) as u64;
                p.extend(sim_prompt(20_000 + unique, SUFFIX));
                prompts.push(p);
            }
        }
    }
    prompts
}

struct ShardRun {
    assignment: Vec<usize>,
    decisions: Vec<RouteDecision>,
    replicas: Vec<(Vec<GenResult>, EngineMetrics)>,
    /// Per-replica flight-recorder JSONL bytes.
    traces: Vec<String>,
}

/// Route the trace up front, then run each replica's share through its
/// own sim-backed engine on the Steps clock — the same construction as
/// e2e_serving scenario 8, so the bench numbers and these assertions
/// grade the same system.
fn run_policy(policy: RoutePolicy) -> ShardRun {
    let prompts = trace_prompts();
    let mut router =
        Router::new(RouterCfg { replicas: 2, policy, block_size: BS, max_load_skew: 64 });
    let assignment: Vec<usize> =
        prompts.iter().enumerate().map(|(i, p)| router.route(i as u64, p)).collect();
    let caps = EngineCaps { max_len: 256, max_prompt: 256, gang_batch: GANG, bytes_per_token: 8 };
    let mut replicas = Vec::new();
    let mut traces = Vec::new();
    for r in 0..router.replicas() {
        let cfg = EngineConfig {
            gang_batch: GANG,
            victim_policy: VictimPolicy::DeadlineAware,
            clock: EngineClock::Steps { step_ms: 1.0, prefill_ms_per_token: 1.0 },
            pool: PoolConfig { block_size: BS, num_blocks: 0, prefix_sharing: true },
            prefix_prefill_discount: true,
            ..Default::default()
        };
        let engine =
            Engine::with_backend(Box::new(SimRuntime::new(SimCfg::default())), caps, cfg.clone());
        let (tx, rx) = Engine::channel(&cfg);
        let (reply, results) = channel();
        for (i, p) in prompts.iter().enumerate() {
            if assignment[i] != r {
                continue;
            }
            tx.send(GenRequest {
                id: i as u64,
                prompt: p.clone(),
                max_new_tokens: 4,
                stop_token: None,
                sampling: SampleCfg::greedy(),
                priority: Priority::Interactive,
                turn: 0,
                slo_ms: Some(SLO_MS),
                reply: reply.clone(),
            })
            .unwrap();
        }
        drop(tx);
        drop(reply);
        let metrics = engine.run(rx).unwrap();
        let mut got: Vec<GenResult> = results.try_iter().collect();
        got.sort_by_key(|x| x.id);
        traces.push(trace_jsonl(&metrics.trace));
        replicas.push((got, metrics));
    }
    ShardRun { assignment, decisions: router.decisions().to_vec(), replicas, traces }
}

/// Fleet numbers: (prefix-hit rate, charged-TTFT mean, goodput).
fn fleet(run: &ShardRun) -> (f64, f64, f64) {
    let (mut shared, mut refb, mut steps, mut hit_tokens) = (0u64, 0u64, 0u64, 0u64);
    let (mut ttft_w, mut ttft_n) = (0.0f64, 0usize);
    for (_, m) in &run.replicas {
        shared += m.prefix_shared_blocks;
        refb += m.prefix_ref_blocks;
        steps += m.decode_steps;
        let int = m.class(Priority::Interactive);
        hit_tokens += int.deadline_hit_tokens;
        ttft_w += int.ttft_ms.mean() * int.ttft_ms.count() as f64;
        ttft_n += int.ttft_ms.count();
    }
    (
        shared as f64 / refb as f64,
        ttft_w / ttft_n as f64,
        hit_tokens as f64 / steps as f64,
    )
}

#[test]
fn same_trace_same_seed_reruns_byte_identically() {
    for policy in [RoutePolicy::RoundRobin, RoutePolicy::PrefixAffinity] {
        let a = run_policy(policy);
        let b = run_policy(policy);
        assert_eq!(a.assignment, b.assignment, "routing must be reproducible ({policy:?})");
        assert_eq!(a.decisions, b.decisions, "decision log must be reproducible ({policy:?})");
        for r in 0..2 {
            assert_eq!(
                a.traces[r], b.traces[r],
                "replica {r} trace bytes diverged across reruns ({policy:?})"
            );
            assert_eq!(
                trace_hash(a.traces[r].as_bytes()),
                trace_hash(b.traces[r].as_bytes())
            );
            let (ra, rb) = (&a.replicas[r].0, &b.replicas[r].0);
            assert_eq!(ra.len(), rb.len());
            for (x, y) in ra.iter().zip(rb) {
                assert_eq!(x.id, y.id);
                assert_eq!(x.tokens, y.tokens, "id {} token stream diverged", x.id);
                assert_eq!(x.finished_reason, y.finished_reason);
            }
        }
    }
}

#[test]
fn affinity_beats_round_robin_on_locality_ttft_and_goodput() {
    let rr = run_policy(RoutePolicy::RoundRobin);
    let aff = run_policy(RoutePolicy::PrefixAffinity);
    let total = TENANTS * BURST * ROUNDS;
    for run in [&rr, &aff] {
        let done: usize = run.replicas.iter().map(|(r, _)| r.len()).sum();
        assert_eq!(done, total, "every routed request must complete");
        // Both policies keep the shard balanced on this trace.
        assert_eq!(run.replicas[0].0.len(), total / 2);
    }
    let (rr_hit, rr_ttft, rr_goodput) = fleet(&rr);
    let (aff_hit, aff_ttft, aff_goodput) = fleet(&aff);
    // Affinity lands each tenant burst on its home replica: one cold
    // prefill per gang wave instead of one per replica. Strictly more
    // shared blocks, strictly cheaper charged TTFT, strictly more
    // deadline-hit tokens per decode step.
    assert!(
        aff_hit > rr_hit,
        "prefix-hit rate: affinity {aff_hit:.3} must beat round-robin {rr_hit:.3}"
    );
    assert!(
        aff_ttft < rr_ttft,
        "charged TTFT: affinity {aff_ttft:.1}ms must beat round-robin {rr_ttft:.1}ms"
    );
    assert!(
        aff_goodput > rr_goodput,
        "goodput: affinity {aff_goodput:.3} must beat round-robin {rr_goodput:.3}"
    );
    // The routing layer itself must see the locality it created: every
    // post-first affinity decision matched its tenant's mirrored prefix.
    let matched: usize = aff.decisions.iter().map(|d| d.matched_blocks).sum();
    let rr_matched: usize = rr.decisions.iter().map(|d| d.matched_blocks).sum();
    assert!(matched > 0, "affinity decisions must report matched prefix blocks");
    assert_eq!(rr_matched, 0, "round-robin never scores a match");
}

#[test]
fn replica_traces_pass_conservation_and_are_disjoint() {
    let run = run_policy(RoutePolicy::PrefixAffinity);
    let mut labeled = Vec::new();
    for (r, trace) in run.traces.iter().enumerate() {
        let check = check_jsonl(trace).expect("replica trace must parse");
        assert!(
            check.ok(),
            "replica {r} conservation violations: {:?}",
            check.violations
        );
        assert!(check.admitted > 0);
        labeled.push((format!("replica-{r}"), check));
    }
    assert!(
        cross_replica_violations(&labeled).is_empty(),
        "a request routed to replica R must live its whole lifecycle on R"
    );
    // Sanity of the gate itself: a replica paired with its own copy
    // trivially double-admits every id.
    let copy = check_jsonl(&run.traces[0]).unwrap();
    let expected = copy.admitted_ids.len();
    let dup = vec![labeled.swap_remove(0), (String::from("copy"), copy)];
    assert_eq!(cross_replica_violations(&dup).len(), expected);
}
