//! Integration tests over the compiled-artifact runtime: prefill, decode
//! variants, cross-variant consistency (Lemma 4.1 / exact-top-k limits),
//! lane injection and the service thread.
//!
//! These tests require `make artifacts` to have run; they skip (with a
//! note) when the artifacts are absent so `cargo test` stays usable in a
//! fresh checkout.

use loki::runtime::{DecodeRequest, DecodeVariant, RuntimeService, RuntimeStack};
use loki::util::artifacts_dir;

fn have_artifacts() -> bool {
    let ok = artifacts_dir().join("manifest.json").exists();
    if !ok {
        eprintln!("skipping: run `make artifacts` first");
    }
    ok
}

fn prompt(text: &str) -> Vec<i32> {
    text.bytes().map(|b| b as i32).collect()
}

fn argmax(xs: &[f32]) -> usize {
    xs.iter()
        .enumerate()
        .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
        .map(|(i, _)| i)
        .unwrap()
}

#[test]
fn prefill_then_decode_full_runs() {
    if !have_artifacts() {
        return;
    }
    let stack = RuntimeStack::load(&artifacts_dir()).expect("load artifacts");
    let man = stack.manifest.clone();
    let (id, logits) = stack
        .prefill("wiki_pre", &[prompt("the code of ")])
        .expect("prefill");
    assert_eq!(logits.len(), 1);
    assert_eq!(logits[0].len(), man.model.vocab_size);
    assert!(logits[0].iter().all(|x| x.is_finite()));

    let out = stack
        .decode(&DecodeRequest {
            state: id,
            variant: DecodeVariant::Full,
            tokens: vec![b'a' as i32],
        })
        .expect("decode");
    assert!(out[0].iter().all(|x| x.is_finite()));
    assert_eq!(stack.state_len(id).unwrap()[0] as usize, "the code of ".len() + 1);
    stack.free(id);
    assert_eq!(stack.live_states(), 0);
}

#[test]
fn loki_with_full_mask_and_budget_matches_full_attention() {
    // DecodeVariant::Loki with d_mask = 1 and j_sel = max_len selects every
    // live slot -> logits must match decode_full to float tolerance
    // (Lemma 4.1: attention in the rotated basis is exact).
    if !have_artifacts() {
        return;
    }
    let stack = RuntimeStack::load(&artifacts_dir()).expect("load artifacts");
    let man = stack.manifest.clone();
    let p = prompt("repeat : torvenal keral ; torvenal");
    let (a, _) = stack.prefill("wiki_pre", &[p.clone()]).unwrap();
    let (b, _) = stack.prefill("wiki_pre", &[p]).unwrap();
    let tok = vec![b' ' as i32];
    let full = stack
        .decode(&DecodeRequest { state: a, variant: DecodeVariant::Full, tokens: tok.clone() })
        .unwrap();
    let loki = stack
        .decode(&DecodeRequest {
            state: b,
            variant: DecodeVariant::loki_fractions(&man, 1.0, 1.0),
            tokens: tok,
        })
        .unwrap();
    let max_diff = full[0]
        .iter()
        .zip(&loki[0])
        .map(|(x, y)| (x - y).abs())
        .fold(0.0f32, f32::max);
    assert!(max_diff < 2e-3, "loki(all) vs full logits diff {max_diff}");
}

#[test]
fn different_pca_bases_give_identical_full_attention() {
    // Lemma 4.1 again, stronger: FULL attention logits must be invariant
    // to the (orthogonal) basis the cache is stored in.
    if !have_artifacts() {
        return;
    }
    let stack = RuntimeStack::load(&artifacts_dir()).expect("load artifacts");
    let p = prompt("aelmorisse thalorn ondira");
    let tok = vec![b'.' as i32];
    let mut outs = Vec::new();
    for pca in ["wiki_pre", "book_post", "identity"] {
        let (id, _) = stack.prefill(pca, &[p.clone()]).unwrap();
        let o = stack
            .decode(&DecodeRequest { state: id, variant: DecodeVariant::Full, tokens: tok.clone() })
            .unwrap();
        outs.push(o[0].clone());
        stack.free(id);
    }
    for other in &outs[1..] {
        let d = outs[0]
            .iter()
            .zip(other)
            .map(|(x, y)| (x - y).abs())
            .fold(0.0f32, f32::max);
        assert!(d < 2e-3, "basis-dependent full attention! diff {d}");
    }
}

#[test]
fn greedy_decode_recalls_trained_fact() {
    // End-to-end quality smoke: the model was trained on fact sentences;
    // greedy decoding after "the code of <name> is" should regenerate
    // text (not collapse). We check it produces lowercase-ish bytes.
    if !have_artifacts() {
        return;
    }
    let stack = RuntimeStack::load(&artifacts_dir()).expect("load artifacts");
    let (id, logits) = stack.prefill("wiki_pre", &[prompt("the code of ")]).unwrap();
    let mut tok = argmax(&logits[0]) as i32;
    let mut generated = Vec::new();
    for _ in 0..12 {
        generated.push(tok as u8);
        let out = stack
            .decode(&DecodeRequest { state: id, variant: DecodeVariant::Full, tokens: vec![tok] })
            .unwrap();
        tok = argmax(&out[0]) as i32;
    }
    let text = String::from_utf8_lossy(&generated).to_string();
    assert!(
        generated.iter().all(|&b| b.is_ascii()),
        "non-ascii generation: {text:?}"
    );
    assert!(
        generated.iter().any(|&b| b.is_ascii_lowercase()),
        "degenerate generation: {text:?}"
    );
}

#[test]
fn variants_all_execute_at_paper_settings() {
    if !have_artifacts() {
        return;
    }
    let stack = RuntimeStack::load(&artifacts_dir()).expect("load artifacts");
    let man = stack.manifest.clone();
    let p = prompt("zapklik wubgo maxbiz netapp .");
    let variants = vec![
        DecodeVariant::Full,
        DecodeVariant::loki_fractions(&man, 0.25, 0.25),
        DecodeVariant::exact_topk(&man, 0.25),
        DecodeVariant::h2o_fraction(&man, 0.25),
        DecodeVariant::pcaattn_fraction(&man, 0.25),
    ];
    for v in variants {
        let (id, _) = stack.prefill("wiki_pre", &[p.clone()]).unwrap();
        let name = format!("{v:?}");
        let out = stack
            .decode(&DecodeRequest { state: id, variant: v, tokens: vec![b'x' as i32] })
            .unwrap_or_else(|e| panic!("{name}: {e}"));
        assert!(
            out[0].iter().all(|x| x.is_finite()),
            "{name} produced non-finite logits"
        );
        stack.free(id);
    }
}

#[test]
fn batch_gang_and_lane_injection() {
    if !have_artifacts() {
        return;
    }
    let stack = RuntimeStack::load(&artifacts_dir()).expect("load artifacts");
    // Gang of 3 -> bucket 8; decode advances all lanes.
    let prompts: Vec<Vec<i32>> = ["alpha one", "beta two two", "gamma"]
        .iter()
        .map(|s| prompt(s))
        .collect();
    let (gang, logits) = stack.prefill("wiki_pre", &prompts).unwrap();
    assert_eq!(stack.state_batch(gang), Some(8));
    assert_eq!(logits.len(), 8);
    let toks: Vec<i32> = vec![b'a' as i32; 8];
    stack
        .decode(&DecodeRequest { state: gang, variant: DecodeVariant::Full, tokens: toks })
        .unwrap();
    let lens = stack.state_len(gang).unwrap();
    assert_eq!(lens[0] as usize, "alpha one".len() + 1);
    assert_eq!(lens[2] as usize, "gamma".len() + 1);

    // Prefill a fresh lane and inject it into slot 1.
    let (lane, _) = stack.prefill("wiki_pre", &[prompt("replacement prompt")]).unwrap();
    assert_eq!(stack.state_batch(lane), Some(1));
    stack.inject(gang, lane, 1).unwrap();
    let lens = stack.state_len(gang).unwrap();
    assert_eq!(lens[1] as usize, "replacement prompt".len());
    // Lane state is consumed.
    assert_eq!(stack.live_states(), 1);
    // Gang still decodes after injection.
    let out = stack
        .decode(&DecodeRequest {
            state: gang,
            variant: DecodeVariant::Full,
            tokens: vec![b'b' as i32; 8],
        })
        .unwrap();
    assert!(out.iter().flatten().all(|x| x.is_finite()));
}

#[test]
fn service_thread_round_trip() {
    if !have_artifacts() {
        return;
    }
    let svc = RuntimeService::start(artifacts_dir()).expect("start service");
    let man = svc.manifest.clone();
    let h = svc.handle();
    // Parallel clients hammer the service from multiple threads.
    std::thread::scope(|s| {
        for t in 0..3 {
            let h = h.clone();
            let man = man.clone();
            s.spawn(move || {
                let (id, _) = h
                    .prefill("wiki_pre", vec![prompt(&format!("client {t} says hello"))])
                    .expect("prefill");
                for step in 0..4 {
                    let out = h
                        .decode(DecodeRequest {
                            state: id,
                            variant: if step % 2 == 0 {
                                DecodeVariant::Full
                            } else {
                                DecodeVariant::loki_fractions(&man, 0.25, 0.25)
                            },
                            tokens: vec![b'.' as i32],
                        })
                        .expect("decode");
                    assert!(out[0].iter().all(|x| x.is_finite()));
                }
                h.free(id);
            });
        }
    });
    let stats = h.stats().unwrap();
    assert!(stats.exec.values().map(|(n, _)| n).sum::<u64>() >= 12);
}
