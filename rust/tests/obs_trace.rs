//! Flight-recorder acceptance tests over the deterministic sim engine:
//! the trace a serving run leaves behind must *conserve* (every admitted
//! request reaches exactly one terminal event, preempts pair with
//! resumes, nothing is lost to ring overwrite) and, on the Steps clock,
//! must be byte-identical across runs — the property that lets CI pin
//! a scenario's JSONL dump with a content hash.
//!
//! The in-memory checker (`check_recorder`) and the JSONL checker
//! (`check_jsonl`) are both exercised against the same run, so the
//! serialized trace certifies exactly the contract the live one does.

use std::sync::mpsc::channel;

use loki::coordinator::request::{GenRequest, Priority};
use loki::coordinator::sampler::SampleCfg;
use loki::coordinator::{
    AdmissionPolicy, Engine, EngineCaps, EngineClock, EngineConfig, EngineMetrics, PoolConfig,
    ShedPolicy,
};
use loki::obs::export::{check_jsonl, check_recorder, trace_hash, trace_jsonl};
use loki::obs::{EventKind, PoolEvent};
use loki::runtime::{SimCfg, SimRuntime};

const BS: usize = 8;

fn caps(max_len: usize, gang: usize) -> EngineCaps {
    EngineCaps { max_len, max_prompt: max_len, gang_batch: gang, bytes_per_token: 8 }
}

/// Distinct-per-request prompt material within the sim vocabulary.
fn prompt(id: u64, len: usize) -> Vec<i32> {
    (0..len).map(|i| ((id as usize * 31 + i * 7 + 3) % 96) as i32).collect()
}

struct Spec {
    prompt: Vec<i32>,
    max_new: usize,
    sampling: SampleCfg,
    priority: Priority,
    slo_ms: Option<f64>,
}

/// Run `specs` through a sim-backed engine, everything submitted up
/// front, so the run — and therefore its trace — is a pure function of
/// (cfg, caps, specs).
fn run(cfg: &EngineConfig, caps: EngineCaps, specs: &[Spec]) -> EngineMetrics {
    let engine =
        Engine::with_backend(Box::new(SimRuntime::new(SimCfg::default())), caps, cfg.clone());
    let (tx, rx) = Engine::channel(cfg);
    let (reply, _results) = channel();
    for (i, s) in specs.iter().enumerate() {
        tx.send(GenRequest {
            id: i as u64,
            prompt: s.prompt.clone(),
            max_new_tokens: s.max_new,
            stop_token: None,
            sampling: s.sampling,
            priority: s.priority,
            turn: 0,
            slo_ms: s.slo_ms,
            reply: reply.clone(),
        })
        .unwrap();
    }
    drop(tx);
    drop(reply);
    engine.run(rx).unwrap()
}

/// The preemption-forcing scenario from `engine_admission.rs`: 16
/// blocks cannot hold the two longest requests' full footprints at
/// once, so decode-time growth must preempt and resume — on the Steps
/// clock, so every trace timestamp is deterministic.
fn contended_cfg() -> EngineConfig {
    EngineConfig {
        pool: PoolConfig { block_size: BS, num_blocks: 16, prefix_sharing: true },
        admission: AdmissionPolicy::Speculative { reserve_frac: 0.2, headroom_blocks: 1 },
        clock: EngineClock::Steps { step_ms: 1.0, prefill_ms_per_token: 0.0 },
        ..Default::default()
    }
}

fn contended_specs() -> Vec<Spec> {
    vec![
        Spec {
            prompt: prompt(0, 24),
            max_new: 40,
            sampling: SampleCfg { temperature: 0.8, top_p: 0.9, seed: 100 },
            priority: Priority::Interactive,
            slo_ms: None,
        },
        Spec {
            prompt: prompt(1, 30),
            max_new: 48,
            sampling: SampleCfg { temperature: 0.7, top_p: 0.95, seed: 101 },
            priority: Priority::Interactive,
            slo_ms: None,
        },
        Spec {
            prompt: prompt(2, 20),
            max_new: 32,
            sampling: SampleCfg::greedy(),
            priority: Priority::Interactive,
            slo_ms: None,
        },
        Spec {
            prompt: prompt(3, 28),
            max_new: 36,
            sampling: SampleCfg { temperature: 1.0, top_p: 0.9, seed: 103 },
            priority: Priority::Interactive,
            slo_ms: None,
        },
    ]
}

#[test]
fn preempt_heavy_trace_conserves_and_matches_metrics() {
    let m = run(&contended_cfg(), caps(512, 2), &contended_specs());
    assert!(m.preemptions > 0, "scenario failed to force preemption: {}", m.report());
    assert!(m.resumes > 0, "{}", m.report());

    let check = check_recorder(&m.trace);
    assert!(check.ok(), "violations: {:?}", check.violations);
    assert_eq!(check.events, m.trace.len());
    assert_eq!(check.admitted, m.requests_in);
    assert_eq!(check.finished, m.requests_done);
    assert_eq!(check.shed, 0);
    assert_eq!(check.rejected, 0);
    assert_eq!(check.in_flight, 0);

    // The recorder is default-on and bounded; this run fits the ring.
    assert_eq!(m.trace.dropped(), 0);
    assert_eq!(m.trace.recorded() as usize, m.trace.len());

    // Structural spot-checks: the lifecycle events the metrics counters
    // summarize are individually present in the trace.
    let count = |pred: &dyn Fn(&EventKind) -> bool| -> u64 {
        m.trace.iter().filter(|e| pred(&e.kind)).count() as u64
    };
    assert_eq!(
        count(&|k| matches!(k, EventKind::PreemptFull { .. } | EventKind::PreemptPartial { .. })),
        m.preemptions
    );
    assert_eq!(count(&|k| matches!(k, EventKind::Resume { .. })), m.resumes);
    assert_eq!(count(&|k| matches!(k, EventKind::FirstToken { .. })), m.requests_done);
    assert_eq!(count(&|k| matches!(k, EventKind::SchedRound { .. })), m.decode_steps);
    assert!(
        count(&|k| matches!(k, EventKind::Pool(PoolEvent::Alloc { .. }))) >= m.requests_in,
        "every admission allocates pool blocks"
    );
    assert!(count(&|k| matches!(k, EventKind::Pool(PoolEvent::Free { .. }))) > 0);

    // Score-path accounting: under the default Full variant the scan
    // reads all keys and the gather reads all values, so bytes-moved
    // equals the dense ceiling on every round with busy lanes.
    let mut busy_rounds = 0u64;
    for e in m.trace.iter() {
        if let EventKind::SchedRound { busy_lanes, score_bytes_moved, score_bytes_exact, .. } =
            e.kind
        {
            if busy_lanes > 0 {
                busy_rounds += 1;
                assert!(score_bytes_moved > 0);
                assert_eq!(score_bytes_moved, score_bytes_exact, "Full moves the dense ceiling");
            }
        }
    }
    assert!(busy_rounds > 0);
}

#[test]
fn trace_terminals_cover_finish_shed_and_reject() {
    // Steps clock with a 1000-virtual-ms decode step: any first token
    // costs ≥ 1000 ms, so a 500 ms SLO is provably doomed under strict
    // shedding even on an idle engine. A 600-token decode budget against
    // a 4-block pool is impossible — rejected at admission. A small
    // deadline-less request finishes normally.
    let cfg = EngineConfig {
        pool: PoolConfig { block_size: BS, num_blocks: 4, prefix_sharing: true },
        shed: ShedPolicy::Strict,
        clock: EngineClock::Steps { step_ms: 1000.0, prefill_ms_per_token: 0.0 },
        ..Default::default()
    };
    let specs = vec![
        Spec {
            prompt: prompt(0, 10),
            max_new: 8,
            sampling: SampleCfg::greedy(),
            priority: Priority::Interactive,
            slo_ms: None,
        },
        Spec {
            prompt: prompt(1, 10),
            max_new: 4,
            sampling: SampleCfg::greedy(),
            priority: Priority::Interactive,
            slo_ms: Some(500.0),
        },
        Spec {
            prompt: prompt(2, 10),
            max_new: 600,
            sampling: SampleCfg::greedy(),
            priority: Priority::Interactive,
            slo_ms: None,
        },
    ];
    let m = run(&cfg, caps(256, 2), &specs);
    assert!(m.requests_done >= 1, "{}", m.report());
    assert!(m.requests_shed >= 1, "{}", m.report());
    assert!(m.requests_rejected >= 1, "{}", m.report());

    let check = check_recorder(&m.trace);
    assert!(check.ok(), "violations: {:?}", check.violations);
    assert_eq!(check.admitted, m.requests_in);
    assert_eq!(check.finished, m.requests_done);
    assert_eq!(check.shed, m.requests_shed);
    assert_eq!(check.rejected, m.requests_rejected);
    assert_eq!(check.in_flight, 0);
    assert_eq!(check.admitted, check.finished + check.shed + check.rejected);
}

#[test]
fn steps_clock_trace_is_byte_identical_across_runs() {
    let a = run(&contended_cfg(), caps(512, 2), &contended_specs());
    let b = run(&contended_cfg(), caps(512, 2), &contended_specs());
    let ja = trace_jsonl(&a.trace);
    let jb = trace_jsonl(&b.trace);
    assert!(!ja.is_empty() && ja.lines().count() > 1);
    assert_eq!(ja, jb, "Steps-clock trace must be bit-reproducible");
    assert_eq!(trace_hash(ja.as_bytes()), trace_hash(jb.as_bytes()));

    // The serialized form certifies the same contract as the live one.
    let from_jsonl = check_jsonl(&ja).expect("well-formed JSONL");
    let live = check_recorder(&a.trace);
    assert!(from_jsonl.ok(), "violations: {:?}", from_jsonl.violations);
    assert_eq!(from_jsonl.events, live.events);
    assert_eq!(from_jsonl.admitted, live.admitted);
    assert_eq!(from_jsonl.finished, live.finished);
    assert_eq!(from_jsonl.shed, live.shed);
    assert_eq!(from_jsonl.rejected, live.rejected);
    assert_eq!(from_jsonl.in_flight, live.in_flight);
}
