//! Integration tests over the analysis pipeline: key dumps → Rust PCA →
//! paper §3 claims, plus the Eq.-5 model against substrate measurements.

use loki::analysis::rank::rank_table;
use loki::analysis::speedup::SpeedupModel;
use loki::analysis::KeyDump;
use loki::util::artifacts_dir;

fn have(name: &str) -> bool {
    let ok = artifacts_dir().join(name).exists();
    if !ok {
        eprintln!("skipping: artifacts/{name} missing (run `make artifacts`)");
    }
    ok
}

/// The paper's central observation, as an executable assertion: trained
/// attention keys have Rank@90 well below the head dimension.
#[test]
fn trained_keys_are_low_rank() {
    if !have("keys_wiki.npz") {
        return;
    }
    let dump = KeyDump::load(&artifacts_dir().join("keys_wiki.npz"), "k_post").unwrap();
    let stats = rank_table(&dump.pca_all(), 90.0);
    let mean = stats.model_mean();
    assert!(
        mean < 0.75 * dump.dim as f64,
        "post-rotary Rank@90 {mean:.1} not clearly below D={}",
        dump.dim
    );
    let pre = KeyDump::load(&artifacts_dir().join("keys_wiki.npz"), "k_pre").unwrap();
    let pre_mean = rank_table(&pre.pca_all(), 90.0).model_mean();
    // Rotary embeddings increase dimensionality (paper finding 3).
    assert!(
        pre_mean < mean,
        "pre-rotary rank {pre_mean:.1} should be below post-rotary {mean:.1}"
    );
}

/// Cross-corpus consistency (paper finding 2): per-layer rank profiles
/// computed from different calibration corpora agree closely.
#[test]
fn rank_profile_is_calibration_invariant() {
    if !have("keys_wiki.npz") || !have("keys_web.npz") || !have("keys_book.npz") {
        return;
    }
    let mut profiles = Vec::new();
    for p in ["wiki", "web", "book"] {
        let dump = KeyDump::load(&artifacts_dir().join(format!("keys_{p}.npz")), "k_post").unwrap();
        profiles.push(rank_table(&dump.pca_all(), 90.0).per_layer);
    }
    for l in 0..profiles[0].len() {
        let vals: Vec<f64> = profiles.iter().map(|p| p[l]).collect();
        let spread = vals.iter().cloned().fold(f64::MIN, f64::max)
            - vals.iter().cloned().fold(f64::MAX, f64::min);
        assert!(spread <= 8.0, "layer {l} cross-corpus spread {spread}");
    }
}

/// The untrained control sits meaningfully above every trained model
/// (our strengthening of the paper's claim).
#[test]
fn random_init_control_has_higher_rank() {
    if !have("family_loki-random.npz") || !have("keys_wiki.npz") {
        return;
    }
    let rand = KeyDump::load(&artifacts_dir().join("family_loki-random.npz"), "k_pre").unwrap();
    let trained = KeyDump::load(&artifacts_dir().join("keys_wiki.npz"), "k_pre").unwrap();
    let r_rand = rank_table(&rand.pca_all(), 90.0).model_mean();
    let r_trained = rank_table(&trained.pca_all(), 90.0).model_mean();
    assert!(
        r_rand > 1.3 * r_trained,
        "random {r_rand:.1} vs trained {r_trained:.1}: training should induce low rank"
    );
}

/// Eq. 5 closed form vs the substrate's measured byte movement: the Loki
/// byte fraction equals d_f/2 + k_f (+D/S rotation term) within 5%.
#[test]
fn eq5_matches_measured_bytes() {
    use loki::attnsim::variants::{decode_attend, AttnVariant, VariantParams};
    use loki::attnsim::AttnShape;
    use loki::util::rng::Xoshiro256;

    let d = 64;
    let s = 1024;
    let shape = AttnShape { lanes: 4, head_dim: d, max_len: s };
    let mut rng = Xoshiro256::new(99);
    let q = rng.normal_vec(shape.lanes * d);
    let kc = rng.normal_vec(shape.lanes * s * d);
    let vc = rng.normal_vec(shape.lanes * s * d);
    let full = decode_attend(
        &AttnVariant::Full,
        shape,
        &q,
        &kc,
        &vc,
        s * d,
        s,
        &VariantParams::default(),
        None,
    );
    for (k_f, d_f) in [(0.25, 0.25), (0.125, 0.5), (0.5, 0.125)] {
        let p = VariantParams {
            k_sel: (k_f * s as f64) as usize,
            d_sub: (d_f * d as f64) as usize,
            ..Default::default()
        };
        let loki = decode_attend(&AttnVariant::Loki, shape, &q, &kc, &vc, s * d, s, &p, None);
        let measured =
            loki.movement.cache_bytes_read as f64 / full.movement.cache_bytes_read as f64;
        let predicted = d_f / 2.0 + k_f;
        assert!(
            (measured - predicted).abs() < 0.05 * predicted + 0.01,
            "(k={k_f}, d={d_f}): measured {measured:.3} vs Eq.5 {predicted:.3}"
        );
        // And the speedup model is consistent with the same ratio.
        let m = SpeedupModel { d_full: d, seq: s };
        let cost_ratio = m.loki_cost(d_f, k_f) / m.vanilla_cost();
        assert!((cost_ratio - predicted).abs() < 0.1, "cost model drifted: {cost_ratio}");
    }
}

/// PCA spectra across q/k/v load and are normalized (guards the dump
/// format against silent python-side changes).
#[test]
fn dump_tensors_all_load_with_unit_spectra() {
    if !have("keys_wiki.npz") {
        return;
    }
    for kind in ["k_pre", "k_post", "q_pre", "q_post", "v"] {
        let dump = KeyDump::load(&artifacts_dir().join("keys_wiki.npz"), kind).unwrap();
        let basis = dump.pca(0, 0);
        let sum: f32 = basis.eigenvalues.iter().sum();
        assert!((sum - 1.0).abs() < 1e-3, "{kind}: eigensum {sum}");
    }
}
