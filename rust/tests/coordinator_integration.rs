//! Integration tests over the serving coordinator: continuous batching,
//! scheduler policies, backpressure, stop conditions, server protocol.

use std::sync::mpsc::channel;

use loki::coordinator::request::{FinishReason, GenRequest, Priority};
use loki::coordinator::sampler::SampleCfg;
use loki::coordinator::{Engine, EngineConfig, SchedulerPolicy};
use loki::model::ByteTokenizer;
use loki::runtime::{DecodeVariant, RuntimeService};
use loki::util::artifacts_dir;

fn have_artifacts() -> bool {
    let ok = artifacts_dir().join("manifest.json").exists();
    if !ok {
        eprintln!("skipping: run `make artifacts` first");
    }
    ok
}

fn request(
    id: u64,
    prompt: &str,
    max_new: usize,
    reply: std::sync::mpsc::Sender<loki::coordinator::request::GenResult>,
) -> GenRequest {
    GenRequest {
        id,
        prompt: ByteTokenizer.encode(prompt),
        max_new_tokens: max_new,
        stop_token: None,
        sampling: SampleCfg::greedy(),
        priority: Priority::Interactive,
        turn: 0,
        slo_ms: None,
        reply,
    }
}

#[test]
fn engine_completes_more_requests_than_lanes() {
    if !have_artifacts() {
        return;
    }
    let service = RuntimeService::start(artifacts_dir()).unwrap();
    let cfg = EngineConfig { verbose: false, ..Default::default() };
    let engine = Engine::new(&service, cfg.clone());
    let (tx, rx) = Engine::channel(&cfg);
    let (reply, results) = channel();
    // 12 requests through (at most) 8 lanes forces continuous batching.
    for i in 0..12 {
        tx.send(request(i, &format!("request number {i} says"), 6, reply.clone())).unwrap();
    }
    drop(tx);
    drop(reply);
    let metrics = engine.run(rx).unwrap();
    let got: Vec<_> = results.try_iter().collect();
    assert_eq!(got.len(), 12);
    assert_eq!(metrics.requests_done, 12);
    assert!(metrics.injections >= 4, "continuous batching should inject: {}", metrics.injections);
    let mut ids: Vec<u64> = got.iter().map(|r| r.id).collect();
    ids.sort_unstable();
    assert_eq!(ids, (0..12).collect::<Vec<_>>());
    for r in &got {
        assert_eq!(r.tokens.len(), 6);
        assert_eq!(r.finished_reason, FinishReason::MaxTokens);
        assert!(r.timing.ttft_s <= r.timing.total_s);
    }
}

#[test]
fn decode_first_policy_also_drains() {
    if !have_artifacts() {
        return;
    }
    let service = RuntimeService::start(artifacts_dir()).unwrap();
    let cfg = EngineConfig { scheduler: SchedulerPolicy::DecodeFirst, ..Default::default() };
    let engine = Engine::new(&service, cfg.clone());
    let (tx, rx) = Engine::channel(&cfg);
    let (reply, results) = channel();
    for i in 0..10 {
        tx.send(request(i, "short prompt", 4, reply.clone())).unwrap();
    }
    drop(tx);
    drop(reply);
    let metrics = engine.run(rx).unwrap();
    assert_eq!(metrics.requests_done, 10);
    assert_eq!(results.try_iter().count(), 10);
}

#[test]
fn stop_token_ends_generation_early() {
    if !have_artifacts() {
        return;
    }
    let service = RuntimeService::start(artifacts_dir()).unwrap();
    let cfg = EngineConfig::default();
    let engine = Engine::new(&service, cfg.clone());
    let (tx, rx) = Engine::channel(&cfg);
    let (reply, results) = channel();
    // Space is the most common byte in the corpus: greedy decode will hit
    // it quickly.
    tx.send(GenRequest {
        id: 1,
        prompt: ByteTokenizer.encode("the code of aelmor is"),
        max_new_tokens: 64,
        stop_token: Some(b' ' as i32),
        sampling: SampleCfg::greedy(),
        priority: Priority::Interactive,
        turn: 0,
        slo_ms: None,
        reply,
    })
    .unwrap();
    drop(tx);
    engine.run(rx).unwrap();
    let r = results.recv().unwrap();
    if r.finished_reason == FinishReason::StopToken {
        // The stop token itself is excluded from the output (vLLM-style).
        assert!(r.tokens.len() < 64);
        assert!(!r.tokens.contains(&(b' ' as i32)), "stop token leaked into output");
    } else {
        assert_eq!(r.tokens.len(), 64);
    }
}

#[test]
fn loki_variant_engine_output_is_plausible() {
    if !have_artifacts() {
        return;
    }
    let service = RuntimeService::start(artifacts_dir()).unwrap();
    let man = service.manifest.clone();
    let cfg = EngineConfig {
        variant: DecodeVariant::loki_fractions(&man, 0.25, 0.25),
        ..Default::default()
    };
    let engine = Engine::new(&service, cfg.clone());
    let (tx, rx) = Engine::channel(&cfg);
    let (reply, results) = channel();
    tx.send(request(7, "repeat : tor ven kal ; ", 12, reply)).unwrap();
    drop(tx);
    engine.run(rx).unwrap();
    let r = results.recv().unwrap();
    assert_eq!(r.tokens.len(), 12);
    assert!(r.text.bytes().all(|b| b.is_ascii()), "got {:?}", r.text);
}

#[test]
fn server_round_trip_over_tcp() {
    if !have_artifacts() {
        return;
    }
    let service = RuntimeService::start(artifacts_dir()).unwrap();
    let cfg = EngineConfig::default();
    let engine = Engine::new(&service, cfg.clone());
    let (tx, rx) = Engine::channel(&cfg);
    // Pick an ephemeral port by binding first.
    let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    drop(listener);
    let addr_str = addr.to_string();
    let server_tx = tx.clone();
    std::thread::spawn(move || {
        let _ = loki::server::serve(&addr_str, server_tx);
    });
    std::thread::sleep(std::time::Duration::from_millis(300));
    // The server thread keeps its queue sender alive for the lifetime of
    // the listener, so the engine never observes channel closure: run it
    // detached and assert on the client-visible response only (the
    // harness exits with live daemon threads).
    std::thread::spawn(move || {
        let _ = engine.run(rx);
    });

    let resp = loki::server::client_call(addr, "the code of ", 8).expect("server call");
    assert!(resp.get("text").and_then(|t| t.as_str()).is_some(), "{resp:?}");
    assert_eq!(resp.get("tokens").and_then(|t| t.as_usize()), Some(8));
    assert!(resp.get("error").is_none());
    assert!(resp.get("total_s").and_then(|t| t.as_f64()).unwrap_or(-1.0) >= 0.0);
    drop(tx);
}
