//! JSON-lines server regression tests over the deterministic sim-backed
//! engine: malformed JSON, empty prompts and absurd `max_tokens` each get
//! a structured `{"error": ...}` reply, and the connection stays usable
//! for the next request. No artifacts required — the engine runs on
//! [`SimRuntime`].

use std::io::{BufRead, BufReader, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::time::Duration;

use loki::coordinator::{Engine, EngineCaps, EngineClock, EngineConfig, ShedPolicy};
use loki::obs::new_hub;
use loki::runtime::{SimCfg, SimRuntime};
use loki::server::{client_stats, serve_listener, ServerCfg};
use loki::util::json::Json;

const MAX_TOKENS_CAP: usize = 64;

/// Boot a sim-backed engine + server on an ephemeral port. The threads
/// are daemons: the engine never sees channel closure (the server holds
/// a sender for the listener's lifetime) and the harness exits over them.
fn start_server() -> SocketAddr {
    start_server_with(EngineConfig { gang_batch: 2, ..Default::default() })
}

fn start_server_with(cfg: EngineConfig) -> SocketAddr {
    let caps =
        EngineCaps { max_len: 256, max_prompt: 256, gang_batch: 2, bytes_per_token: 8 };
    let hub = new_hub();
    let engine =
        Engine::with_backend(Box::new(SimRuntime::new(SimCfg::default())), caps, cfg.clone())
            .with_stats_hub(hub.clone());
    let (tx, rx) = Engine::channel(&cfg);
    std::thread::spawn(move || {
        let _ = engine.run(rx);
    });
    let listener = TcpListener::bind("127.0.0.1:0").expect("bind ephemeral port");
    let addr = listener.local_addr().unwrap();
    std::thread::spawn(move || {
        let cfg = ServerCfg { max_tokens_cap: MAX_TOKENS_CAP, ..Default::default() };
        let _ = serve_listener(listener, tx, cfg, Some(hub));
    });
    addr
}

struct Conn {
    writer: TcpStream,
    reader: BufReader<TcpStream>,
}

impl Conn {
    fn open(addr: SocketAddr) -> Self {
        let stream = connect_with_retry(addr);
        stream
            .set_read_timeout(Some(Duration::from_secs(30)))
            .expect("read timeout");
        let reader = BufReader::new(stream.try_clone().expect("clone stream"));
        Self { writer: stream, reader }
    }

    /// One protocol round-trip: write a line, read a line, parse it.
    fn round_trip(&mut self, line: &str) -> Json {
        self.writer.write_all(line.as_bytes()).expect("write");
        self.writer.write_all(b"\n").expect("write newline");
        let mut resp = String::new();
        self.reader.read_line(&mut resp).expect("read reply");
        assert!(!resp.is_empty(), "server closed the connection");
        Json::parse(&resp).unwrap_or_else(|e| panic!("unparseable reply {resp:?}: {e}"))
    }
}

fn connect_with_retry(addr: SocketAddr) -> TcpStream {
    for _ in 0..50 {
        if let Ok(s) = TcpStream::connect(addr) {
            return s;
        }
        std::thread::sleep(Duration::from_millis(20));
    }
    panic!("server never came up on {addr}");
}

fn error_of(resp: &Json) -> String {
    resp.get("error")
        .and_then(|e| e.as_str())
        .unwrap_or_else(|| panic!("expected an error reply, got {resp:?}"))
        .to_string()
}

fn assert_ok_generation(resp: &Json, max_tokens: usize) {
    assert!(resp.get("error").is_none(), "unexpected error: {resp:?}");
    assert!(resp.get("text").and_then(|t| t.as_str()).is_some());
    let tokens = resp.get("tokens").and_then(|t| t.as_usize()).expect("tokens field");
    assert!(tokens <= max_tokens, "{tokens} > {max_tokens}");
    let finish = resp.get("finish").and_then(|f| f.as_str()).expect("finish field");
    assert!(
        finish == "MaxTokens" || finish == "StopToken",
        "unexpected finish reason {finish}"
    );
}

#[test]
fn malformed_json_gets_structured_error_and_connection_survives() {
    let addr = start_server();
    let mut conn = Conn::open(addr);
    let resp = conn.round_trip("{this is not json");
    assert!(error_of(&resp).contains("bad request JSON"));
    // Same connection, next line: a valid request must still work.
    let resp = conn.round_trip(r#"{"prompt": "hello there", "max_tokens": 4}"#);
    assert_ok_generation(&resp, 4);
}

#[test]
fn missing_and_empty_prompts_are_rejected_individually() {
    let addr = start_server();
    let mut conn = Conn::open(addr);
    let resp = conn.round_trip(r#"{"max_tokens": 4}"#);
    assert!(error_of(&resp).contains("prompt"));
    let resp = conn.round_trip(r#"{"prompt": "", "max_tokens": 4}"#);
    assert!(error_of(&resp).contains("empty"));
    // The engine never saw either; the connection still serves.
    let resp = conn.round_trip(r#"{"prompt": "ok then", "max_tokens": 3}"#);
    assert_ok_generation(&resp, 3);
}

#[test]
fn absurd_max_tokens_is_rejected_before_the_queue() {
    let addr = start_server();
    let mut conn = Conn::open(addr);
    // Far beyond the cap: structured error, instantly (no queue entry).
    let resp = conn.round_trip(r#"{"prompt": "hi", "max_tokens": 1000000000}"#);
    let msg = error_of(&resp);
    assert!(msg.contains("max_tokens"), "{msg}");
    // Zero is as absurd as a billion.
    let resp = conn.round_trip(r#"{"prompt": "hi", "max_tokens": 0}"#);
    assert!(error_of(&resp).contains("max_tokens"));
    // Non-integer types are a protocol error, not a default.
    let resp = conn.round_trip(r#"{"prompt": "hi", "max_tokens": "lots"}"#);
    assert!(error_of(&resp).contains("max_tokens"));
    // The cap itself is inclusive and the connection is intact.
    let resp = conn.round_trip(&format!(
        r#"{{"prompt": "boundary", "max_tokens": {MAX_TOKENS_CAP}}}"#
    ));
    assert_ok_generation(&resp, MAX_TOKENS_CAP);
}

#[test]
fn priority_field_is_validated_and_echoed() {
    let addr = start_server();
    let mut conn = Conn::open(addr);
    // Valid classes round-trip and are echoed back.
    for class in ["interactive", "batch"] {
        let resp = conn.round_trip(&format!(
            r#"{{"prompt": "hello", "max_tokens": 3, "priority": "{class}"}}"#
        ));
        assert_ok_generation(&resp, 3);
        assert_eq!(resp.get("priority").and_then(|p| p.as_str()), Some(class));
    }
    // Omitted → the interactive default (never the eviction-first class).
    let resp = conn.round_trip(r#"{"prompt": "hello", "max_tokens": 3}"#);
    assert_ok_generation(&resp, 3);
    assert_eq!(resp.get("priority").and_then(|p| p.as_str()), Some("interactive"));
    // A typo must be a client error, not a silent class demotion.
    let resp = conn.round_trip(r#"{"prompt": "hi", "max_tokens": 3, "priority": "urgent"}"#);
    assert!(error_of(&resp).contains("priority"));
    // Wrong type is a protocol error too, and the connection survives.
    let resp = conn.round_trip(r#"{"prompt": "hi", "max_tokens": 3, "priority": 7}"#);
    assert!(error_of(&resp).contains("priority"));
    let resp = conn.round_trip(r#"{"prompt": "still alive", "max_tokens": 3}"#);
    assert_ok_generation(&resp, 3);
}

#[test]
fn slo_ms_is_validated_and_echoed_with_a_deadline_grade() {
    let addr = start_server();
    let mut conn = Conn::open(addr);
    // A generous valid SLO round-trips: echoed back with a boolean
    // deadline grade (the sim engine answers in microseconds, so a
    // 60-second budget always grades as hit).
    let resp = conn.round_trip(r#"{"prompt": "hello", "max_tokens": 3, "slo_ms": 60000}"#);
    assert_ok_generation(&resp, 3);
    assert_eq!(resp.get("slo_ms").and_then(|v| v.as_f64()), Some(60000.0));
    assert_eq!(resp.get("deadline_hit").and_then(|v| v.as_bool()), Some(true));
    // Omitted → no deadline fields at all (absence, not null noise).
    let resp = conn.round_trip(r#"{"prompt": "hello", "max_tokens": 3}"#);
    assert_ok_generation(&resp, 3);
    assert!(resp.get("slo_ms").is_none());
    assert!(resp.get("deadline_hit").is_none());
    // Negative, zero and absurd values are client errors — a mistyped
    // deadline must never silently schedule.
    for bad in ["-250", "0", "1e12"] {
        let resp = conn.round_trip(&format!(
            r#"{{"prompt": "hi", "max_tokens": 3, "slo_ms": {bad}}}"#
        ));
        assert!(error_of(&resp).contains("slo_ms"), "{bad} must be rejected");
    }
    // Wrong type is a protocol error too, and the connection survives.
    let resp = conn.round_trip(r#"{"prompt": "hi", "max_tokens": 3, "slo_ms": "fast"}"#);
    assert!(error_of(&resp).contains("slo_ms"));
    let resp = conn.round_trip(r#"{"prompt": "still alive", "max_tokens": 3}"#);
    assert_ok_generation(&resp, 3);
}

#[test]
fn doomed_slo_gets_a_structured_shed_reply_and_connection_survives() {
    // Strict shedding on the deterministic steps clock, with one decode
    // step priced at 1000 virtual ms: any first token costs ≥ 1000 ms,
    // so a 500 ms SLO is provably unreachable *even on an idle engine*
    // — the shed decision is race-free (no queue depth required).
    let addr = start_server_with(EngineConfig {
        gang_batch: 2,
        shed: ShedPolicy::Strict,
        clock: EngineClock::Steps { step_ms: 1000.0, prefill_ms_per_token: 0.0 },
        ..Default::default()
    });
    let mut conn = Conn::open(addr);
    let resp = conn.round_trip(r#"{"prompt": "urgent", "max_tokens": 3, "slo_ms": 500}"#);
    assert!(resp.get("error").is_none(), "a shed is not an error: {resp:?}");
    assert_eq!(resp.get("shed").and_then(|v| v.as_bool()), Some(true), "{resp:?}");
    let predicted = resp
        .get("predicted_ttft_ms")
        .and_then(|v| v.as_f64())
        .expect("shed reply carries the prediction");
    assert!(predicted >= 1000.0, "one decode step costs 1000 virtual ms: {predicted}");
    let retry = resp
        .get("retry_after_ms")
        .and_then(|v| v.as_f64())
        .expect("shed reply carries the retry hint");
    assert!((retry - (predicted - 500.0)).abs() < 1e-9, "{resp:?}");
    assert_eq!(resp.get("slo_ms").and_then(|v| v.as_f64()), Some(500.0), "SLO echoed");
    assert!(resp.get("text").is_none(), "nothing was generated: {resp:?}");
    assert!(resp.get("tokens").is_none());
    // A generous SLO on the same connection is served normally — with
    // its steps-domain deadline grade.
    let resp = conn.round_trip(r#"{"prompt": "patient", "max_tokens": 3, "slo_ms": 60000}"#);
    assert_ok_generation(&resp, 3);
    assert!(resp.get("shed").is_none(), "served requests carry no shed fields");
    assert_eq!(resp.get("deadline_hit").and_then(|v| v.as_bool()), Some(true));
    // And an SLO-less request is never shed, whatever the policy.
    let resp = conn.round_trip(r#"{"prompt": "whenever", "max_tokens": 3}"#);
    assert_ok_generation(&resp, 3);
}

#[test]
fn stats_scrape_returns_live_snapshot_mid_flight() {
    let addr = start_server();
    let mut conn = Conn::open(addr);
    // Drive the engine through two full requests: the per-round
    // snapshot publish precedes the completion section within a round,
    // so the *second* request's rounds are what make the first one's
    // completion provably visible to the scrape.
    let resp = conn.round_trip(r#"{"prompt": "warm up the counters", "max_tokens": 4}"#);
    assert_ok_generation(&resp, 4);
    let resp = conn.round_trip(r#"{"prompt": "make the first visible", "max_tokens": 4}"#);
    assert_ok_generation(&resp, 4);
    // Scrape on the SAME connection — the stats command shares the
    // protocol with generation requests.
    let resp = conn.round_trip(r#"{"stats": true}"#);
    assert!(resp.get("error").is_none(), "scrape failed: {resp:?}");
    let stats = resp.req("stats");
    assert!(stats.req("requests_in").as_f64().unwrap() >= 2.0, "{stats:?}");
    assert!(stats.req("requests_done").as_f64().unwrap() >= 1.0, "{stats:?}");
    assert!(stats.req("tokens_generated").as_f64().unwrap() >= 1.0, "{stats:?}");
    assert!(stats.req("trace_recorded").as_f64().unwrap() >= 1.0, "tracing is default-on");
    assert_eq!(stats.req("classes").as_arr().unwrap().len(), 2);
    let ttft = stats.req("ttft_s");
    assert!(ttft.req("count").as_f64().unwrap() >= 1.0, "{ttft:?}");
    assert!(ttft.req("p95").as_f64().unwrap() >= ttft.req("p50").as_f64().unwrap() - 1e-12);
    // Prometheus exposition rides along in the same reply.
    let prom = resp.req("prom").as_str().expect("prom text");
    assert!(prom.contains("# TYPE loki_requests_total counter"), "{prom}");
    assert!(prom.contains("loki_ttft_seconds{quantile=\"0.5\"}"), "{prom}");
    // The connection still generates after a scrape.
    let resp = conn.round_trip(r#"{"prompt": "still alive", "max_tokens": 2}"#);
    assert_ok_generation(&resp, 2);
    // And the one-shot client helper sees the same hub.
    let scrape = client_stats(addr).expect("client_stats");
    assert!(scrape.req("stats").req("requests_in").as_f64().unwrap() >= 1.0);
}

#[test]
fn sequential_clients_share_one_engine() {
    let addr = start_server();
    for i in 0..3 {
        let mut conn = Conn::open(addr);
        let resp = conn.round_trip(&format!(r#"{{"prompt": "client {i}", "max_tokens": 2}}"#));
        assert_ok_generation(&resp, 2);
    }
}
