//! Property tests over the paged KV-pool subsystem (same hand-rolled
//! deterministic-PRNG idiom as `substrate_properties.rs`: no proptest in
//! the offline crate set; failures reproduce from the printed trial seed).
//!
//! Three invariant families:
//! 1. allocator/table safety — random admit/fork/advance/free sequences
//!    never double-free, leak, or underflow a shared block's refcount;
//! 2. tiered-pool safety — random append/fork/free under an LRU budget
//!    keeps residency accounting exact;
//! 3. numerical equivalence — paged decode (through forked, copy-on-write
//!    block tables) is **bit-identical** to the flat `InPlace` path for
//!    every attention variant.

use loki::attnsim::variants::{
    decode_attend, decode_attend_paged, AttnVariant, H2oState, VariantParams,
};
use loki::attnsim::AttnShape;
use loki::kvpool::{BlockAllocator, TableSet, TieredKvPool, TieredPoolCfg};
use loki::util::rng::Xoshiro256;

const TRIALS: usize = 30;

/// Random admit / fork / advance / free traffic against the admission
/// tables: the allocator must stay exact (no leak, no double free, no
/// refcount underflow) and every failed admission must roll back fully.
#[test]
fn prop_allocator_traffic_never_leaks() {
    for trial in 0..TRIALS {
        let mut rng = Xoshiro256::new(9000 + trial as u64);
        let bs = [2, 4, 8][rng.below(3)];
        let num_blocks = rng.range(8, 48);
        let mut alloc = BlockAllocator::new(num_blocks, bs);
        let mut tables = TableSet::new(bs, rng.uniform() < 0.7);
        let mut live: Vec<u64> = Vec::new();
        for _ in 0..200 {
            match rng.below(10) {
                // Admit (common): small token alphabet so identical
                // prefixes actually occur and sharing paths get exercised.
                0..=4 => {
                    let plen = rng.range(1, 3 * bs);
                    let prompt: Vec<i32> = (0..plen).map(|_| rng.below(3) as i32).collect();
                    let reserve = plen + rng.range(0, 2 * bs);
                    let before = alloc.blocks_in_use();
                    match tables.admit(&mut alloc, &prompt, reserve) {
                        Ok(seq) => live.push(seq),
                        Err(_) => {
                            assert_eq!(
                                alloc.blocks_in_use(),
                                before,
                                "trial {trial}: failed admit must roll back"
                            );
                        }
                    }
                }
                5..=6 if !live.is_empty() => {
                    let seq = live[rng.below(live.len())];
                    if let Ok(child) = tables.fork(&mut alloc, seq) {
                        live.push(child);
                    }
                }
                7..=8 if !live.is_empty() => {
                    let seq = live.swap_remove(rng.below(live.len()));
                    tables.free(&mut alloc, seq);
                }
                _ if !live.is_empty() => {
                    let seq = live[rng.below(live.len())];
                    let t = tables.table(seq).unwrap();
                    if t.len < t.blocks.len() * bs {
                        tables.advance(seq);
                    }
                }
                _ => {}
            }
            alloc.check_invariants();
        }
        // Drain: every block must come home.
        for seq in live.drain(..) {
            tables.free(&mut alloc, seq);
        }
        assert_eq!(alloc.blocks_in_use(), 0, "trial {trial}: blocks leaked");
        assert_eq!(alloc.num_free(), num_blocks);
        alloc.check_invariants();
    }
}

/// Random append / fork / free traffic against the tiered data-plane
/// pool, under a tight LRU budget: residency never exceeds the budget,
/// tables never reference freed blocks, and full teardown returns every
/// block.
#[test]
fn prop_tiered_pool_traffic_holds_invariants() {
    for trial in 0..TRIALS {
        let mut rng = Xoshiro256::new(11_000 + trial as u64);
        let d = 8;
        let cfg = TieredPoolCfg {
            num_blocks: rng.range(8, 32),
            block_size: [2, 4][rng.below(2)],
            head_dim: d,
            d_hot: rng.range(1, d + 1),
            cold_resident_blocks: [0, 3][rng.below(2)],
        };
        let mut pool = TieredKvPool::new(cfg);
        let mut live: Vec<usize> = vec![pool.new_seq()];
        for _ in 0..150 {
            match rng.below(8) {
                0..=4 => {
                    let seq = live[rng.below(live.len())];
                    let row = rng.normal_vec(d);
                    // Exhaustion is a legal outcome, not a panic.
                    let _ = pool.append(seq, &row, &row);
                }
                5 => {
                    let seq = live[rng.below(live.len())];
                    live.push(pool.fork(seq));
                }
                6 if live.len() > 1 => {
                    let seq = live.swap_remove(rng.below(live.len()));
                    pool.free_seq(seq);
                }
                _ => {
                    let seq = live[rng.below(live.len())];
                    let len = pool.len(seq);
                    if len > 0 {
                        let slots: Vec<u32> =
                            (0..rng.range(1, 5)).map(|_| rng.below(len) as u32).collect();
                        pool.account_gather(seq, &slots);
                    }
                }
            }
            pool.check_invariants();
        }
        for seq in live.drain(..) {
            pool.free_seq(seq);
        }
        assert_eq!(pool.allocator().blocks_in_use(), 0, "trial {trial}: blocks leaked");
        pool.check_invariants();
    }
}

/// The acceptance-criteria equivalence, through the sharing machinery:
/// lanes are built in the pool by *forking* a common prefix and appending
/// divergent tails (so the block tables share prefix blocks copy-on-write
/// and tails were physically copied), while the flat caches hold the same
/// logical rows contiguously. Every variant must produce bit-identical
/// context vectors and selections (`==` on f32, no tolerance).
#[test]
fn prop_paged_decode_bit_identical_to_flat_under_cow_sharing() {
    for trial in 0..10 {
        let mut rng = Xoshiro256::new(13_000 + trial as u64);
        let lanes = rng.range(1, 5);
        let d = 16;
        let d_hot = 8;
        let prefix_len = rng.range(1, 40);
        let tail_len = rng.range(1, 24);
        let live = prefix_len + tail_len;
        let shape = AttnShape { lanes, head_dim: d, max_len: live };
        let stride = live * d;

        // Shared prefix rows + per-lane tails.
        let kp = rng.normal_vec(prefix_len * d);
        let vp = rng.normal_vec(prefix_len * d);
        let tails: Vec<(Vec<f32>, Vec<f32>)> = (0..lanes)
            .map(|_| (rng.normal_vec(tail_len * d), rng.normal_vec(tail_len * d)))
            .collect();

        // Flat layout: [lanes, live, d].
        let mut kc = vec![0.0f32; lanes * live * d];
        let mut vc = vec![0.0f32; lanes * live * d];
        for lane in 0..lanes {
            kc[lane * stride..lane * stride + prefix_len * d].copy_from_slice(&kp);
            vc[lane * stride..lane * stride + prefix_len * d].copy_from_slice(&vp);
            kc[lane * stride + prefix_len * d..(lane + 1) * stride]
                .copy_from_slice(&tails[lane].0);
            vc[lane * stride + prefix_len * d..(lane + 1) * stride]
                .copy_from_slice(&tails[lane].1);
        }

        // Paged layout: fork the prefix, append divergent tails (CoW).
        let mut pool = TieredKvPool::new(TieredPoolCfg {
            num_blocks: 4 * lanes * live, // generous
            block_size: [3, 4, 8][rng.below(3)],
            head_dim: d,
            d_hot,
            cold_resident_blocks: 0,
        });
        let base = pool.new_seq();
        pool.load_prefix(base, &kp, &vp, prefix_len).unwrap();
        let seqs: Vec<usize> = (0..lanes)
            .map(|lane| {
                let s = pool.fork(base);
                for j in 0..tail_len {
                    pool.append(
                        s,
                        &tails[lane].0[j * d..(j + 1) * d],
                        &tails[lane].1[j * d..(j + 1) * d],
                    )
                    .unwrap();
                }
                s
            })
            .collect();
        pool.free_seq(base);
        pool.check_invariants();

        let q = rng.normal_vec(lanes * d);
        let k_sel = rng.range(1, live + 1);
        let cases = [
            (AttnVariant::Full, VariantParams::default()),
            (AttnVariant::ExactTopK, VariantParams { k_sel, ..Default::default() }),
            (AttnVariant::Loki, VariantParams { k_sel, d_sub: 4, ..Default::default() }),
            (AttnVariant::SparQ, VariantParams { k_sel, d_sub: 6, ..Default::default() }),
            (AttnVariant::StreamingLlm, VariantParams { k_sel, ..Default::default() }),
            (AttnVariant::PcaAttn, VariantParams { d_sub: 8, ..Default::default() }),
        ];
        for (variant, p) in cases {
            let a = decode_attend(&variant, shape, &q, &kc, &vc, stride, live, &p, None);
            let b = decode_attend_paged(&variant, &mut pool, &seqs, &q, &p, None);
            assert_eq!(
                a.context, b.context,
                "trial {trial} {variant:?}: paged context diverged from flat"
            );
            assert_eq!(a.selected, b.selected, "trial {trial} {variant:?}: selection diverged");
        }
        // H2O threads accumulator state; run both paths in lockstep twice.
        let mut st_flat: H2oState = vec![vec![0.0; live]; lanes];
        let mut st_paged: H2oState = vec![vec![0.0; live]; lanes];
        let p = VariantParams { k_sel: k_sel.max(2), ..Default::default() };
        for _ in 0..2 {
            let a = decode_attend(
                &AttnVariant::H2O, shape, &q, &kc, &vc, stride, live, &p, Some(&mut st_flat),
            );
            let b = decode_attend_paged(
                &AttnVariant::H2O, &mut pool, &seqs, &q, &p, Some(&mut st_paged),
            );
            assert_eq!(a.context, b.context, "trial {trial} H2O: context diverged");
            assert_eq!(st_flat, st_paged, "trial {trial} H2O: accumulators diverged");
        }
        for s in seqs {
            pool.free_seq(s);
        }
        assert_eq!(pool.allocator().blocks_in_use(), 0, "trial {trial}: pool leaked");
    }
}

/// Preemption invariants over random admit / grow / advance / preempt
/// traffic (the speculative-admission lifecycle): a victim's release
/// never frees a block that another live sequence still references
/// (shared prefixes survive), and the allocator's alloc/free bookkeeping
/// balances exactly across admit/grow/preempt cycles.
#[test]
fn prop_preemption_spares_shared_blocks_and_balances_books() {
    for trial in 0..TRIALS {
        let mut rng = Xoshiro256::new(19_000 + trial as u64);
        let bs = [4, 8][rng.below(2)];
        let mut alloc = BlockAllocator::new(rng.range(16, 64), bs);
        let mut tables = TableSet::new(bs, true);
        let mut live: Vec<u64> = Vec::new();
        for _ in 0..250 {
            match rng.below(10) {
                // Speculative-style admit: reserve only part of the
                // budget. Tiny token alphabet so prefixes really share.
                0..=3 => {
                    let plen = rng.range(1, 4 * bs);
                    let prompt: Vec<i32> = (0..plen).map(|_| rng.below(2) as i32).collect();
                    let reserve = plen + rng.range(0, bs);
                    if let Ok(seq) = tables.admit(&mut alloc, &prompt, reserve) {
                        live.push(seq);
                    }
                }
                // Decode-time growth (partial grants allowed).
                4..=5 if !live.is_empty() => {
                    let seq = live[rng.below(live.len())];
                    let _ = tables.grow(&mut alloc, seq, rng.range(1, 4));
                }
                // Advance within the granted blocks.
                6..=7 if !live.is_empty() => {
                    let seq = live[rng.below(live.len())];
                    if !tables.needs_grow(seq) {
                        tables.advance(seq);
                    }
                }
                // Preempt a random victim; every block some *other* live
                // sequence references must survive with refcount ≥ 1.
                _ if !live.is_empty() => {
                    let victim = live.swap_remove(rng.below(live.len()));
                    let safeguarded: Vec<u32> = live
                        .iter()
                        .flat_map(|&s| tables.table(s).unwrap().blocks.clone())
                        .collect();
                    tables.preempt_free(&mut alloc, victim);
                    for &b in &safeguarded {
                        assert!(
                            alloc.ref_count(b) >= 1,
                            "trial {trial}: preemption freed shared block {b}"
                        );
                    }
                }
                _ => {}
            }
            // Bookkeeping balance: fresh allocs minus completed frees is
            // exactly the blocks currently referenced.
            assert_eq!(
                alloc.stats.allocs - alloc.stats.frees,
                alloc.blocks_in_use() as u64,
                "trial {trial}: alloc/free books diverged from in-use count"
            );
            alloc.check_invariants();
        }
        let preempts_before_drain = alloc.stats.preempt_frees;
        for seq in live.drain(..) {
            tables.free(&mut alloc, seq);
        }
        assert_eq!(alloc.blocks_in_use(), 0, "trial {trial}: blocks leaked");
        assert_eq!(alloc.stats.allocs, alloc.stats.frees, "trial {trial}: books must close");
        assert_eq!(
            alloc.stats.preempt_frees, preempts_before_drain,
            "trial {trial}: completion frees must not count as preemptions"
        );
        alloc.check_invariants();
    }
}

/// Evict-then-recompute is lossless in the data plane: truncating a
/// tiered sequence (preemption keeping only a prefix) and re-appending
/// the same rows restores both tiers bit-identically, under random
/// lengths, block sizes and truncation points — including through a
/// copy-on-write fork sharing the prefix.
#[test]
fn prop_truncate_then_reappend_is_bit_identical() {
    for trial in 0..TRIALS {
        let mut rng = Xoshiro256::new(21_000 + trial as u64);
        let d = 8;
        let bs = [2, 3, 4][rng.below(3)];
        let len = rng.range(2, 40);
        let keep = rng.below(len); // 0 ⇒ evict everything
        let mut pool = TieredKvPool::new(TieredPoolCfg {
            num_blocks: 4 * len,
            block_size: bs,
            head_dim: d,
            d_hot: rng.range(1, d + 1),
            cold_resident_blocks: 0,
        });
        let s = pool.new_seq();
        let rows: Vec<(Vec<f32>, Vec<f32>)> =
            (0..len).map(|_| (rng.normal_vec(d), rng.normal_vec(d))).collect();
        for (k, v) in &rows {
            pool.append(s, k, v).unwrap();
        }
        // A forked sibling pins the shared prefix: the victim's truncate
        // must not disturb it, and re-appends must CoW, not clobber.
        let sibling = pool.fork(s);
        pool.truncate(s, keep);
        assert_eq!(pool.len(s), keep, "trial {trial}");
        pool.check_invariants();
        for (k, v) in &rows[keep..] {
            pool.append(s, k, v).unwrap();
        }
        for (j, (k, v)) in rows.iter().enumerate() {
            let hot_w = pool.d_hot();
            assert_eq!(
                pool.hot_view().row(pool.blocks(s), j),
                &k[..hot_w],
                "trial {trial}: hot row {j} diverged after recompute"
            );
            assert_eq!(
                pool.cold_k_view().row(pool.blocks(s), j),
                &k[..],
                "trial {trial}: cold K row {j}"
            );
            assert_eq!(
                pool.cold_v_view().row(pool.blocks(s), j),
                &v[..],
                "trial {trial}: cold V row {j}"
            );
            // The sibling still reads the original, untouched data.
            assert_eq!(pool.cold_k_view().row(pool.blocks(sibling), j), &k[..]);
        }
        pool.free_seq(s);
        pool.free_seq(sibling);
        assert_eq!(pool.allocator().blocks_in_use(), 0, "trial {trial}: pool leaked");
        pool.check_invariants();
    }
}

/// Conversational fork trees (`branch_factor > 1` in the workload
/// generator): one root prompt forked into several siblings, each then
/// growing and advancing a private decode tail. Invariants, checked both
/// mid-flight and at teardown:
/// - every live block's refcount equals the number of live tables that
///   hold it (the radix tree and fork paths agree on sharing);
/// - copy-on-write isolation: the only blocks two branches may have in
///   common are the root's full prefix blocks — CoW tails and grown
///   decode blocks are private to their branch;
/// - alloc/free books balance, frees in arbitrary order strand nothing,
///   and the radix tree drains to empty with the pool.
#[test]
fn prop_fork_trees_isolate_cow_tails_and_balance_books() {
    use std::collections::HashMap;

    // Refcount == live holders, for every block any live table references
    // — and no block in use that no table holds.
    fn assert_refcounts_match_holders(
        tables: &TableSet,
        alloc: &BlockAllocator,
        live: &[u64],
        trial: usize,
    ) {
        let mut holders: HashMap<u32, u32> = HashMap::new();
        for &s in live {
            for &b in &tables.table(s).unwrap().blocks {
                *holders.entry(b).or_insert(0) += 1;
            }
        }
        assert_eq!(
            holders.len(),
            alloc.blocks_in_use(),
            "trial {trial}: blocks in use not accounted to any live table"
        );
        for (&b, &n) in &holders {
            assert_eq!(
                alloc.ref_count(b),
                n,
                "trial {trial}: block {b} refcount diverged from live holders"
            );
        }
    }

    for trial in 0..TRIALS {
        let mut rng = Xoshiro256::new(23_000 + trial as u64);
        let bs = [4, 8][rng.below(2)];
        let mut alloc = BlockAllocator::new(256, bs);
        let mut tables = TableSet::new(bs, true);

        // Root prompt: 1–3 full blocks plus, half the time, a partial
        // tail — so both fork paths (pure share, share + CoW copy) run.
        let full = rng.range(1, 4);
        let tail = if rng.below(2) == 0 { 0 } else { rng.range(1, bs) };
        let plen = full * bs + tail;
        let prompt: Vec<i32> = (0..plen).map(|_| rng.below(4) as i32).collect();
        let root = tables.admit(&mut alloc, &prompt, plen).unwrap();

        let branch = rng.range(2, 6);
        let mut live = vec![root];
        for _ in 0..branch {
            live.push(tables.fork(&mut alloc, root).unwrap());
        }
        assert_eq!(
            alloc.stats.forks, branch as u64,
            "trial {trial}: branch fan-out must be counted"
        );
        if tail > 0 {
            assert_eq!(
                alloc.stats.cow_copies, branch as u64,
                "trial {trial}: every fork of a partial tail copies exactly one block"
            );
        }

        // Each branch decodes a private tail of random length.
        for i in 0..live.len() {
            let seq = live[i];
            for _ in 0..rng.range(1, 2 * bs) {
                if tables.needs_grow(seq) && tables.grow(&mut alloc, seq, 1).is_err() {
                    break;
                }
                tables.advance(seq);
            }
            alloc.check_invariants();
        }
        assert_refcounts_match_holders(&tables, &alloc, &live, trial);

        // CoW isolation: any block two branches share must be one of the
        // root's full prefix blocks.
        let prefix: Vec<u32> = tables.table(root).unwrap().blocks[..full].to_vec();
        for (i, &a) in live.iter().enumerate() {
            let ta = tables.table(a).unwrap().blocks.clone();
            for &b in &live[i + 1..] {
                let tb = tables.table(b).unwrap();
                for blk in ta.iter().filter(|blk| tb.blocks.contains(blk)) {
                    assert!(
                        prefix.contains(blk),
                        "trial {trial}: branches {a} and {b} share non-prefix block {blk}"
                    );
                }
            }
        }

        // Free in random order (root included mid-stream): the shared
        // prefix must survive exactly as long as any holder does.
        while !live.is_empty() {
            let seq = live.swap_remove(rng.below(live.len()));
            tables.free(&mut alloc, seq);
            assert_refcounts_match_holders(&tables, &alloc, &live, trial);
            assert_eq!(
                alloc.stats.allocs - alloc.stats.frees,
                alloc.blocks_in_use() as u64,
                "trial {trial}: alloc/free books diverged"
            );
            alloc.check_invariants();
        }
        assert_eq!(alloc.blocks_in_use(), 0, "trial {trial}: blocks leaked");
        assert_eq!(tables.radix_nodes(), 0, "trial {trial}: radix tree must drain");
        assert_eq!(alloc.stats.allocs, alloc.stats.frees, "trial {trial}: books must close");
        alloc.check_invariants();
    }
}

/// Prefix sharing is real memory: admitting N identical prompts must cost
/// the full-prefix blocks once plus one private tail block per sequence.
#[test]
fn prop_identical_prompts_cost_one_prefix() {
    for trial in 0..TRIALS {
        let mut rng = Xoshiro256::new(17_000 + trial as u64);
        let bs = [4, 8][rng.below(2)];
        let n_seqs = rng.range(2, 9);
        let plen = rng.range(bs, 6 * bs);
        let prompt: Vec<i32> = (0..plen).map(|_| rng.below(256) as i32).collect();
        let mut alloc = BlockAllocator::new(128, bs);
        let mut tables = TableSet::new(bs, true);
        let full = plen / bs;
        let per_seq_blocks = plen.div_ceil(bs).max(1);
        let mut seqs = Vec::new();
        for _ in 0..n_seqs {
            seqs.push(tables.admit(&mut alloc, &prompt, plen).unwrap());
        }
        let tail = per_seq_blocks - full;
        assert_eq!(
            alloc.blocks_in_use(),
            full + n_seqs * tail,
            "trial {trial}: {n_seqs} seqs × {plen} tokens (bs {bs})"
        );
        // Unshared baseline for the same traffic:
        assert!(
            tables.shared_hits as usize == (n_seqs - 1) * full,
            "trial {trial}: every full prefix block after the first must be a shared hit"
        );
        for s in seqs {
            tables.free(&mut alloc, s);
        }
        assert_eq!(alloc.blocks_in_use(), 0);
        alloc.check_invariants();
    }
}
