//! Hand-rolled property tests over the pure-Rust substrates (proptest is
//! not in the offline crate set; we drive randomized cases from our own
//! deterministic PRNG — failures reproduce from the printed seed).

use loki::attnsim::kernels::{scores_indexed, FeatureAccess, Par};
use loki::attnsim::variants::{decode_attend, AttnVariant, VariantParams};
use loki::attnsim::AttnShape;
use loki::linalg::pca::Pca;
use loki::linalg::softmax::softmax_masked_inplace;
use loki::linalg::stats::jaccard;
use loki::linalg::topk::{top_k_indices, TopKAlgo};
use loki::util::rng::Xoshiro256;

const TRIALS: usize = 40;

/// Random shapes: score kernels agree across parallel structures and the
/// dense-copy baseline, including ragged lengths.
#[test]
fn prop_score_kernels_agree_on_random_shapes() {
    for trial in 0..TRIALS {
        let mut rng = Xoshiro256::new(1000 + trial as u64);
        let lanes = rng.range(1, 9);
        let d = [8, 16, 32, 64][rng.below(4)];
        let m = rng.range(4, 300);
        let live = rng.range(1, m + 1);
        let shape = AttnShape { lanes, head_dim: d, max_len: m };
        let q = rng.normal_vec(lanes * d);
        let kc = rng.normal_vec(lanes * m * d);
        let stride = m * d;
        let feat = match rng.below(3) {
            0 => FeatureAccess::Full,
            1 => FeatureAccess::Prefix(rng.range(1, d + 1)),
            _ => {
                let n = rng.range(1, d + 1);
                let mut ix: Vec<u16> = (0..d as u16).collect();
                rng.shuffle(&mut ix);
                ix.truncate(n);
                ix.sort_unstable();
                FeatureAccess::Gather(ix)
            }
        };
        let mut a = vec![0.0; lanes * live];
        let mut b = vec![0.0; lanes * live];
        scores_indexed(shape, &q, &kc, stride, live, &feat, 0.5, Par::Serial, Some(1), &mut a);
        scores_indexed(shape, &q, &kc, stride, live, &feat, 0.5, Par::Tiles2D, Some(3), &mut b);
        for i in 0..a.len() {
            assert!(
                (a[i] - b[i]).abs() < 1e-4,
                "trial {trial} ({lanes},{d},{m},{live}) {feat:?}: {} vs {}",
                a[i],
                b[i]
            );
        }
    }
}

/// Loki with d_sub = D must select exactly the exact-top-k set (ties
/// aside) and produce identical context vectors.
#[test]
fn prop_loki_full_d_equals_exact_topk() {
    for trial in 0..TRIALS {
        let mut rng = Xoshiro256::new(2000 + trial as u64);
        let lanes = rng.range(1, 5);
        let d = 16;
        let m = rng.range(16, 128);
        let shape = AttnShape { lanes, head_dim: d, max_len: m };
        let q = rng.normal_vec(lanes * d);
        let kc = rng.normal_vec(lanes * m * d);
        let vc = rng.normal_vec(lanes * m * d);
        let k_sel = rng.range(1, m + 1);
        let p = VariantParams { k_sel, d_sub: d, ..Default::default() };
        let a = decode_attend(&AttnVariant::ExactTopK, shape, &q, &kc, &vc, m * d, m, &p, None);
        let b = decode_attend(&AttnVariant::Loki, shape, &q, &kc, &vc, m * d, m, &p, None);
        for (x, y) in a.context.iter().zip(&b.context) {
            assert!((x - y).abs() < 1e-4, "trial {trial}");
        }
    }
}

/// Monotonicity: growing d_sub must not *decrease* top-k agreement with
/// the exact ranking (on average over trials).
#[test]
fn prop_selection_agreement_improves_with_d() {
    let mut total_low = 0.0;
    let mut total_high = 0.0;
    for trial in 0..TRIALS {
        let mut rng = Xoshiro256::new(3000 + trial as u64);
        let d = 32;
        let m = 128;
        let shape = AttnShape { lanes: 1, head_dim: d, max_len: m };
        let q = rng.normal_vec(d);
        // Anisotropic keys so leading dims carry more signal (PCA-like).
        let mut kc = rng.normal_vec(m * d);
        for row in kc.chunks_exact_mut(d) {
            for (j, x) in row.iter_mut().enumerate() {
                *x *= 1.0 / (1.0 + j as f32 * 0.2);
            }
        }
        let vc = rng.normal_vec(m * d);
        let k_sel = 16;
        let exact = decode_attend(
            &AttnVariant::ExactTopK,
            shape,
            &q,
            &kc,
            &vc,
            m * d,
            m,
            &VariantParams { k_sel, d_sub: d, ..Default::default() },
            None,
        );
        for (d_sub, total) in [(4usize, &mut total_low), (32, &mut total_high)] {
            let loki = decode_attend(
                &AttnVariant::Loki,
                shape,
                &q,
                &kc,
                &vc,
                m * d,
                m,
                &VariantParams { k_sel, d_sub, ..Default::default() },
                None,
            );
            *total += jaccard(&exact.selected[0], &loki.selected[0]);
        }
    }
    assert!(
        total_high >= total_low,
        "agreement should improve with d: d=4 {total_low:.2} vs d=32 {total_high:.2}"
    );
    // d_sub = D means exact scores: the selection must match exactly.
    assert!((total_high / TRIALS as f64) > 0.999, "full-d selection must be exact");
}

/// Top-k algorithms return value-identical selections on adversarial
/// inputs: sorted, reversed, constant, NaN-free extremes.
#[test]
fn prop_topk_adversarial_inputs() {
    let cases: Vec<Vec<f32>> = vec![
        (0..500).map(|i| i as f32).collect(),
        (0..500).rev().map(|i| i as f32).collect(),
        vec![1.0; 300],
        vec![f32::MIN, f32::MAX, 0.0, -0.0, 1e-38, -1e38],
        (0..257).map(|i| if i % 2 == 0 { -1e30 } else { 1e30 }).collect(),
    ];
    for (ci, scores) in cases.iter().enumerate() {
        for k in [0, 1, scores.len() / 2, scores.len()] {
            let vals = |ix: &[u32]| {
                let mut v: Vec<f32> = ix.iter().map(|&i| scores[i as usize]).collect();
                v.sort_by(|a, b| a.partial_cmp(b).unwrap());
                v
            };
            let a = vals(&top_k_indices(TopKAlgo::Sort, scores, k));
            let b = vals(&top_k_indices(TopKAlgo::Heap, scores, k));
            let c = vals(&top_k_indices(TopKAlgo::QuickSelect, scores, k));
            assert_eq!(a, b, "case {ci} k {k} heap");
            assert_eq!(a, c, "case {ci} k {k} quickselect");
        }
    }
}

/// PCA rotation must preserve pairwise dot products (Lemma 4.1 at the
/// substrate level) for any fitted basis.
#[test]
fn prop_pca_rotation_preserves_dot_products() {
    for trial in 0..TRIALS {
        let mut rng = Xoshiro256::new(4000 + trial as u64);
        let d = [4, 8, 16][rng.below(3)];
        let n = rng.range(50, 400);
        let samples = rng.normal_vec(n * d);
        let basis = Pca::fit(&samples, n, d);
        let x = rng.normal_vec(d);
        let y = rng.normal_vec(d);
        let mut xr = vec![0.0; d];
        let mut yr = vec![0.0; d];
        basis.rotate(&x, &mut xr);
        basis.rotate(&y, &mut yr);
        let dot = |a: &[f32], b: &[f32]| -> f32 { a.iter().zip(b).map(|(p, q)| p * q).sum() };
        let raw = dot(&x, &y);
        let rot = dot(&xr, &yr);
        assert!(
            (raw - rot).abs() < 1e-3 * (1.0 + raw.abs()),
            "trial {trial} d {d}: {raw} vs {rot}"
        );
    }
}

/// H2O invariants under random decode sequences: selection size respects
/// the budget, accumulators are monotone non-decreasing, and the newest
/// token is always kept.
#[test]
fn prop_h2o_invariants() {
    for trial in 0..20 {
        let mut rng = Xoshiro256::new(5000 + trial as u64);
        let d = 8;
        let m = 96;
        let lanes = 2;
        let shape = AttnShape { lanes, head_dim: d, max_len: m };
        let kc = rng.normal_vec(lanes * m * d);
        let vc = rng.normal_vec(lanes * m * d);
        let mut state = vec![vec![0.0f32; m]; lanes];
        let k_sel = rng.range(4, 32);
        let mut prev_sums = vec![0.0f32; lanes];
        for live in (k_sel + 1..m).step_by(7) {
            let q = rng.normal_vec(lanes * d);
            let p = VariantParams { k_sel, ..Default::default() };
            let out = decode_attend(
                &AttnVariant::H2O,
                shape,
                &q,
                &kc,
                &vc,
                m * d,
                live,
                &p,
                Some(&mut state),
            );
            for lane in 0..lanes {
                assert!(out.selected[lane].len() <= k_sel, "budget violated");
                assert!(out.selected[lane].contains(&((live - 1) as u32)), "newest evicted");
                let sum: f32 = state[lane].iter().sum();
                assert!(sum >= prev_sums[lane] - 1e-4, "acc decreased");
                prev_sums[lane] = sum;
            }
        }
    }
}

/// Masked softmax: output is a probability distribution over the mask for
/// random masks (including empty and singleton).
#[test]
fn prop_masked_softmax_is_distribution() {
    for trial in 0..TRIALS {
        let mut rng = Xoshiro256::new(6000 + trial as u64);
        let n = rng.range(1, 200);
        let mut scores = rng.normal_vec(n);
        let mask: Vec<bool> = (0..n).map(|_| rng.uniform() < 0.6).collect();
        softmax_masked_inplace(&mut scores, &mask);
        let sum: f32 = scores.iter().sum();
        let any = mask.iter().any(|&m| m);
        if any {
            assert!((sum - 1.0).abs() < 1e-4, "trial {trial}: sum {sum}");
        } else {
            assert_eq!(sum, 0.0);
        }
        for (s, &m) in scores.iter().zip(&mask) {
            assert!(*s >= 0.0);
            if !m {
                assert_eq!(*s, 0.0);
            }
        }
    }
}
