//! Fixture-corpus pins: every rule fires where expected, every waiver
//! suppresses, traps stay silent, and deleting any single waiver makes
//! the gate fail (the acceptance criterion from ISSUE 8).

use repro_lint::{
    lint_paths, lint_source, BAD_WAIVER, FLOAT_ORD, NONDET_ITER, PANIC_IN_HOT_PATH, RAW_CLOCK,
    UNBOUNDED_METRICS,
};
use std::path::{Path, PathBuf};

fn fixture(rel: &str) -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR")).join("tests/fixtures").join(rel)
}

/// (rule, basename, line) for every diagnostic under a fixture root.
fn rules_and_lines(root: &str) -> Vec<(String, String, usize)> {
    let report = lint_paths(&[fixture(root)]).expect("fixture tree readable");
    report
        .diagnostics
        .iter()
        .map(|d| {
            let file = d.path.rsplit('/').next().unwrap().to_string();
            (d.rule.clone(), file, d.line)
        })
        .collect()
}

#[test]
fn violating_tree_fires_exactly_the_expected_diagnostics() {
    let got = rules_and_lines("tree");
    let own = |r: &str, f: &str, l: usize| (r.to_string(), f.to_string(), l);
    // Files sort lexicographically; diagnostics sort by line within a file.
    let expected = vec![
        own(BAD_WAIVER, "bad_waiver.rs", 3),
        own(RAW_CLOCK, "bad_waiver.rs", 5),
        own(BAD_WAIVER, "bad_waiver.rs", 7),
        own(RAW_CLOCK, "bad_waiver.rs", 9),
        own(PANIC_IN_HOT_PATH, "engine.rs", 3),
        own(PANIC_IN_HOT_PATH, "engine.rs", 6),
        own(RAW_CLOCK, "raw_clock.rs", 4),
        own(PANIC_IN_HOT_PATH, "router.rs", 4),
        own(FLOAT_ORD, "choice_regression.rs", 6),
        own(NONDET_ITER, "nondet.rs", 5),
        own(NONDET_ITER, "nondet.rs", 8),
        own(NONDET_ITER, "radix.rs", 6),
        own(PANIC_IN_HOT_PATH, "radix.rs", 9),
        own(FLOAT_ORD, "float_ord.rs", 4),
        own(FLOAT_ORD, "parsim_regression.rs", 4),
        own(UNBOUNDED_METRICS, "metrics_vec.rs", 3),
        own(PANIC_IN_HOT_PATH, "frontend.rs", 3),
        own(PANIC_IN_HOT_PATH, "mod.rs", 3),
        own(PANIC_IN_HOT_PATH, "mod.rs", 5),
    ];
    assert_eq!(got, expected);
}

#[test]
fn reintroducing_either_fixed_partial_cmp_call_fails_the_gate() {
    for file in [
        "tree/rust/src/linalg/parsim_regression.rs",
        "tree/rust/src/eval/choice_regression.rs",
    ] {
        let got = lint_paths(&[fixture(file)]).expect("fixture readable");
        assert_eq!(
            got.diagnostics.len(),
            1,
            "{file} must fire exactly the float-ord regression"
        );
        assert_eq!(got.diagnostics[0].rule, FLOAT_ORD);
    }
}

#[test]
fn clean_tree_is_silent_and_counts_waivers() {
    let report = lint_paths(&[fixture("clean")]).expect("fixture tree readable");
    let msgs: Vec<String> = report.diagnostics.iter().map(|d| d.to_string()).collect();
    assert!(report.diagnostics.is_empty(), "clean tree fired:\n{}", msgs.join("\n"));
    assert_eq!(report.files_scanned, 4);
    assert_eq!(report.waived, 3);
}

#[test]
fn deleting_any_single_waiver_resurfaces_a_violation() {
    for file in [
        "clean/rust/src/coordinator/waived.rs",
        "clean/rust/src/coordinator/engine.rs",
    ] {
        let path = fixture(file);
        let src = std::fs::read_to_string(&path).expect("fixture readable");
        let waiver_lines: Vec<usize> = src
            .lines()
            .enumerate()
            .filter(|(_, l)| l.contains("lint:allow("))
            .map(|(i, _)| i)
            .collect();
        assert!(!waiver_lines.is_empty(), "{file} holds no waivers?");
        for &wl in &waiver_lines {
            let mutated: String = src
                .lines()
                .enumerate()
                .map(|(i, l)| {
                    if i == wl {
                        // Drop the waiver comment, keep any code on the line.
                        match l.find("//") {
                            Some(p) => &l[..p],
                            None => "",
                        }
                    } else {
                        l
                    }
                })
                .collect::<Vec<_>>()
                .join("\n");
            let result = lint_source(&path, &mutated);
            assert!(
                !result.diagnostics.is_empty(),
                "deleting the waiver on line {} of {file} must fail the gate",
                wl + 1
            );
        }
    }
}
