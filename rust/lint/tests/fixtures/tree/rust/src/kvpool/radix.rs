// fixture: the radix prefix tree is in both scopes — nondet-iter
// (kvpool is determinism-critical) and panic-in-hot-path (the tree is
// walked on every admission and physical free).
use std::collections::HashMap;
pub struct Tree {
    nodes: HashMap<u64, u32>,
}
pub fn resolve(t: &Tree, hash: u64) -> u32 {
    *t.nodes.get(&hash).expect("node must be indexed")
}
