// fixture: nondet-iter fires on declaration and turbofish sites
// (a bare `use std::collections::HashMap;` import does not fire).
use std::collections::HashMap;
pub struct Tables {
    tables: HashMap<u64, u32>,
}
pub fn build() -> usize {
    let m = HashMap::<u64, u32>::new();
    m.len()
}
