// fixture: panic-in-hot-path fires in the scheduling loop.
pub fn schedule(q: &mut Vec<u64>) -> u64 {
    q.pop().unwrap()
}
pub fn grade(x: Option<u64>) -> u64 {
    x.expect("graded")
}
