// fixture: raw-clock fires in coordinator code outside the clock module.
use std::time::Instant;
pub fn stamp() -> Instant {
    Instant::now()
}
