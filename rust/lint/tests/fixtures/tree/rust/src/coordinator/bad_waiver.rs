// fixture: malformed waivers report bad-waiver and suppress nothing.
use std::time::Instant;
// lint:allow(raw-clock)
pub fn missing_reason() -> Instant {
    Instant::now()
}
// lint:allow(no-such-rule): the rule name is unknown
pub fn unknown_rule() -> Instant {
    Instant::now()
}
