// fixture: panic-in-hot-path fires in the router decision core.
pub fn pick(outstanding: &[usize]) -> usize {
    let best = outstanding.iter().enumerate().min_by_key(|(_, o)| **o);
    best.unwrap().0
}
