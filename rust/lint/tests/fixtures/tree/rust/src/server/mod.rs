// fixture: panic-in-hot-path fires in the server connection handler.
pub fn handle(line: Option<&str>) {
    let req = line.unwrap();
    if req.is_empty() {
        panic!("empty request");
    }
}
