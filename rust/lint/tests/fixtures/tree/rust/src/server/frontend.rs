// fixture: panic-in-hot-path fires in the frontend dispatch path.
pub fn dispatch(replica: Option<usize>) -> usize {
    replica.expect("router always picks a live replica")
}
