// fixture: pins the acceptance criterion — re-introducing the exact
// pre-fix choice.rs argmax must fail the gate.
pub fn argmax(scored: &[(usize, f64)]) -> usize {
    scored
        .iter()
        .max_by(|a, b| a.1.partial_cmp(&b.1).unwrap())
        .map(|(i, _)| *i)
        .unwrap_or(0)
}
