// fixture: float-ord fires on real code even when the line above is a
// comment mentioning the old partial_cmp().unwrap() sort (a trap).
pub fn sort_scores(xs: &mut [f32]) {
    xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
}
