// fixture: pins the acceptance criterion — re-introducing the exact
// pre-fix parsim.rs sort must fail the gate.
pub fn makespan_sorted(sorted: &mut [f64]) {
    sorted.sort_by(|a, b| b.partial_cmp(a).unwrap());
}
