// fixture: unbounded-metrics fires on float Vec accumulators only.
pub struct Metrics {
    samples: Vec<f64>,
    counts: Vec<u64>,
}
