// fixture: a standalone waiver applies to the next code line, skipping
// blank and comment-only lines in between.
pub fn schedule(q: &mut Vec<u64>) -> u64 {
    // lint:allow(panic-in-hot-path): queue verified non-empty by caller

    // (another comment between the waiver and the code)
    q.pop().unwrap()
}
