// fixture: the clock module is the raw-clock allowlist — raw reads
// here are sanctioned without waivers.
use std::time::Instant;
pub fn wall_now() -> Instant {
    Instant::now()
}
