// fixture: valid waivers (reason mandatory) suppress each rule.
use std::time::Instant;
pub struct S {
    // lint:allow(nondet-iter): keyed access only, never iterated
    map: std::collections::HashMap<u64, u32>,
}
pub fn now() -> Instant {
    Instant::now() // lint:allow(raw-clock): wall-only metric, Steps twin unaffected
}
