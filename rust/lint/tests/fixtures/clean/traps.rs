// fixture: rule names in comments, strings, raw strings, and
// #[cfg(test)] regions must not trip:
// partial_cmp, Instant::now(), HashMap<u64, u64>, Vec<f64>, panic!.
pub fn traps() -> (usize, usize) {
    let s = "partial_cmp().unwrap() and Instant::now()";
    let r = r#"SystemTime::now() "HashMap<u8, u8>" panic!"#;
    (s.len(), r.len())
}
#[cfg(test)]
mod tests {
    #[test]
    fn nan_case_is_test_only() {
        let mut v = vec![1.0f64, f64::NAN];
        v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    }
}
