//! The gate itself, as a tier-1 test: the real repository tree must be
//! lint-clean. This is what makes the determinism/float-safety
//! invariants part of `cargo test`, not just a CI job.

use repro_lint::lint_paths;
use std::path::{Path, PathBuf};

#[test]
fn real_tree_is_clean_under_the_gate() {
    let repo = Path::new(env!("CARGO_MANIFEST_DIR")).join("../..");
    let roots: Vec<PathBuf> = ["rust/src", "rust/benches", "examples"]
        .iter()
        .map(|r| repo.join(r))
        .collect();
    let report = lint_paths(&roots).expect("repo tree readable");
    let msgs: Vec<String> = report.diagnostics.iter().map(|d| d.to_string()).collect();
    assert!(
        report.diagnostics.is_empty(),
        "the tree must be lint-clean (fix or waive with a reason):\n{}",
        msgs.join("\n")
    );
    // Coverage floors: if these shrink, the roots moved or the scan broke.
    assert!(
        report.files_scanned >= 80,
        "scanned only {} files — did the lint roots move?",
        report.files_scanned
    );
    assert!(
        report.waived >= 20,
        "waiver inventory shrank to {} — waivers deleted without fixing sites?",
        report.waived
    );
}
