//! End-to-end CLI pins: exit codes (0 clean / 1 violations / 2 usage),
//! human and JSON output shapes.

use std::path::PathBuf;
use std::process::Command;

fn bin() -> Command {
    Command::new(env!("CARGO_BIN_EXE_repro-lint"))
}

fn fixture(rel: &str) -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("tests/fixtures").join(rel)
}

#[test]
fn violating_tree_exits_one_with_file_line_diagnostics() {
    let out = bin()
        .arg("--check")
        .arg(fixture("tree"))
        .output()
        .expect("binary runs");
    assert_eq!(out.status.code(), Some(1));
    let stdout = String::from_utf8_lossy(&out.stdout);
    assert!(stdout.contains("float_ord.rs:4:"), "{stdout}");
    assert!(stdout.contains("[float-ord]"), "{stdout}");
    assert!(stdout.contains("19 violation(s)"), "{stdout}");
}

#[test]
fn clean_tree_exits_zero_and_reports_waivers() {
    let out = bin().arg(fixture("clean")).output().expect("binary runs");
    assert_eq!(out.status.code(), Some(0));
    let stdout = String::from_utf8_lossy(&out.stdout);
    assert!(stdout.contains("0 violation(s)"), "{stdout}");
    assert!(stdout.contains("3 waived"), "{stdout}");
}

#[test]
fn json_report_carries_rule_path_line_col() {
    let out = bin()
        .args(["--json"])
        .arg(fixture("tree"))
        .output()
        .expect("binary runs");
    assert_eq!(out.status.code(), Some(1));
    let stdout = String::from_utf8_lossy(&out.stdout);
    assert!(stdout.contains("\"violations\": ["), "{stdout}");
    assert!(stdout.contains("\"rule\": \"float-ord\""), "{stdout}");
    assert!(stdout.contains("\"line\": 4"), "{stdout}");
    assert!(stdout.contains("\"files_scanned\": 12"), "{stdout}");
}

#[test]
fn usage_and_io_errors_exit_two() {
    let no_args = bin().output().expect("binary runs");
    assert_eq!(no_args.status.code(), Some(2));

    let bad_flag = bin().arg("--frobnicate").output().expect("binary runs");
    assert_eq!(bad_flag.status.code(), Some(2));

    let missing = bin().arg(fixture("no/such/dir")).output().expect("binary runs");
    assert_eq!(missing.status.code(), Some(2));
}

#[test]
fn help_exits_zero_and_documents_waiver_syntax() {
    let out = bin().arg("--help").output().expect("binary runs");
    assert_eq!(out.status.code(), Some(0));
    let stdout = String::from_utf8_lossy(&out.stdout);
    assert!(stdout.contains("lint:allow(rule): reason"), "{stdout}");
}
