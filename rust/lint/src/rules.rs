//! The rule registry, per-module policy map, waiver parsing, and the
//! lint driver that ties them together.
//!
//! Every rule here fossilizes a bug class this repo has already paid
//! for (see the README "Static analysis" section for the PR history):
//!
//! * `float-ord` — `partial_cmp().unwrap()` NaN panics (PRs 3, 5, 6, 7).
//! * `raw-clock` — raw `Instant::now()` stamps leaking past the
//!   `EngineClock`, breaking Steps-clock trace byte-equality (PR 5's
//!   double-stamp bug).
//! * `nondet-iter` — `HashMap`/`HashSet` iteration order poisoning
//!   determinism-critical modules.
//! * `unbounded-metrics` — unbounded `Vec` accumulators in metrics hot
//!   paths (replaced by `StreamingHist` in PR 6).
//! * `panic-in-hot-path` — `unwrap`/`expect`/`panic!` in the engine
//!   scheduling loop, the router decision core, the radix prefix tree
//!   (walked on every admission and physical free), and the server /
//!   frontend dispatch path, where a panic drops every in-flight
//!   request (and, in the sharded frontend, poisons the router lock
//!   for every connection thread).
//!
//! Waiver syntax: `// lint:allow(rule): reason` (reason mandatory).
//! A waiver on a code line suppresses matches on that line; a waiver on
//! a comment-only line suppresses matches on the next line containing
//! code. Malformed waivers (missing reason, unknown rule) emit a
//! `bad-waiver` diagnostic and suppress nothing.

use crate::lexer::{self, is_ident};
use std::fmt;
use std::fs;
use std::io;
use std::path::{Path, PathBuf};

pub const FLOAT_ORD: &str = "float-ord";
pub const RAW_CLOCK: &str = "raw-clock";
pub const NONDET_ITER: &str = "nondet-iter";
pub const UNBOUNDED_METRICS: &str = "unbounded-metrics";
pub const PANIC_IN_HOT_PATH: &str = "panic-in-hot-path";
/// Pseudo-rule for malformed waivers; not waivable itself.
pub const BAD_WAIVER: &str = "bad-waiver";

/// Every enforceable rule, in severity-agnostic registry order.
pub const RULES: [&str; 5] = [
    FLOAT_ORD,
    RAW_CLOCK,
    NONDET_ITER,
    UNBOUNDED_METRICS,
    PANIC_IN_HOT_PATH,
];

struct Pattern {
    rule: &'static str,
    text: &'static str,
    /// Require a non-identifier char (or start of line) before the match.
    start_boundary: bool,
    /// Require a non-identifier char (or end of line) after the match.
    end_boundary: bool,
    message: &'static str,
}

const PATTERNS: [Pattern; 13] = [
    Pattern {
        rule: FLOAT_ORD,
        text: "partial_cmp",
        start_boundary: true,
        end_boundary: true,
        message: "float ordering via `partial_cmp` — use `total_cmp` (or `linalg::topk`) \
                  so NaN cannot panic or destabilize the sort",
    },
    Pattern {
        rule: RAW_CLOCK,
        text: "Instant::now",
        start_boundary: true,
        end_boundary: true,
        message: "raw `Instant::now()` outside the clock module — route through \
                  `EngineClock`/`coordinator::clock` so the Steps twin stays deterministic",
    },
    Pattern {
        rule: RAW_CLOCK,
        text: "SystemTime::now",
        start_boundary: true,
        end_boundary: true,
        message: "raw `SystemTime::now()` outside the clock module — route through \
                  `EngineClock`/`coordinator::clock` so the Steps twin stays deterministic",
    },
    Pattern {
        rule: NONDET_ITER,
        text: "HashMap<",
        start_boundary: true,
        end_boundary: false,
        message: "`HashMap` in a determinism-critical module — iteration order is \
                  nondeterministic; use `BTreeMap`, sort before iterating, or waive \
                  keyed-only access",
    },
    Pattern {
        rule: NONDET_ITER,
        text: "HashMap::<",
        start_boundary: true,
        end_boundary: false,
        message: "`HashMap` in a determinism-critical module — iteration order is \
                  nondeterministic; use `BTreeMap`, sort before iterating, or waive \
                  keyed-only access",
    },
    Pattern {
        rule: NONDET_ITER,
        text: "HashSet<",
        start_boundary: true,
        end_boundary: false,
        message: "`HashSet` in a determinism-critical module — iteration order is \
                  nondeterministic; use `BTreeSet`, sort before iterating, or waive \
                  keyed-only access",
    },
    Pattern {
        rule: NONDET_ITER,
        text: "HashSet::<",
        start_boundary: true,
        end_boundary: false,
        message: "`HashSet` in a determinism-critical module — iteration order is \
                  nondeterministic; use `BTreeSet`, sort before iterating, or waive \
                  keyed-only access",
    },
    Pattern {
        rule: UNBOUNDED_METRICS,
        text: "Vec<f32",
        start_boundary: true,
        end_boundary: false,
        message: "unbounded float `Vec` accumulator in a metrics path — use \
                  `obs::StreamingHist` (bounded log-bucketed histogram)",
    },
    Pattern {
        rule: UNBOUNDED_METRICS,
        text: "Vec<f64",
        start_boundary: true,
        end_boundary: false,
        message: "unbounded float `Vec` accumulator in a metrics path — use \
                  `obs::StreamingHist` (bounded log-bucketed histogram)",
    },
    Pattern {
        rule: PANIC_IN_HOT_PATH,
        text: ".unwrap()",
        start_boundary: false,
        end_boundary: false,
        message: "`unwrap()` in the scheduling loop / server handler — a panic here \
                  drops every in-flight request; handle the error or waive with the \
                  invariant that makes it unreachable",
    },
    Pattern {
        rule: PANIC_IN_HOT_PATH,
        text: ".expect(",
        start_boundary: false,
        end_boundary: false,
        message: "`expect()` in the scheduling loop / server handler — a panic here \
                  drops every in-flight request; handle the error or waive with the \
                  invariant that makes it unreachable",
    },
    Pattern {
        rule: PANIC_IN_HOT_PATH,
        text: "panic!",
        start_boundary: true,
        end_boundary: false,
        message: "`panic!` in the scheduling loop / server handler — a panic here \
                  drops every in-flight request; handle the error or waive with the \
                  invariant that makes it unreachable",
    },
    Pattern {
        rule: PANIC_IN_HOT_PATH,
        text: "unreachable!",
        start_boundary: true,
        end_boundary: false,
        message: "`unreachable!` in the scheduling loop / server handler — a panic \
                  here drops every in-flight request; handle the error or waive with \
                  the invariant that makes it unreachable",
    },
];

/// Normalize a path for policy matching: forward slashes, leading `/`
/// so `contains("/src/coordinator/")` works on relative inputs too.
fn norm(path: &Path) -> String {
    let mut s = path.to_string_lossy().replace('\\', "/");
    if !s.starts_with('/') {
        s.insert(0, '/');
    }
    s
}

/// The per-module policy map: which rule applies to which file.
///
/// Wall-clock serving code (`util::bench`, `experiments`, `eval`,
/// `main.rs`, benches, examples) may read real clocks; the deterministic
/// twin (`coordinator`, `runtime`, `obs`, `kvpool`) may not, except the
/// sanctioned `coordinator/clock.rs` module.
pub fn applicable(rule: &str, path: &Path) -> bool {
    let p = norm(path);
    match rule {
        FLOAT_ORD => true,
        RAW_CLOCK => {
            !p.ends_with("/src/coordinator/clock.rs")
                && ["/src/coordinator/", "/src/runtime/", "/src/obs/", "/src/kvpool/"]
                    .iter()
                    .any(|m| p.contains(m))
        }
        NONDET_ITER => [
            "/src/coordinator/",
            "/src/kvpool/",
            "/src/runtime/",
            "/src/obs/",
            "/src/attnsim/",
            "/src/linalg/",
            "/src/data/",
        ]
        .iter()
        .any(|m| p.contains(m)),
        UNBOUNDED_METRICS => {
            p.contains("/src/obs/") || p.ends_with("/src/coordinator/metrics.rs")
        }
        PANIC_IN_HOT_PATH => {
            p.ends_with("/src/coordinator/engine.rs")
                || p.ends_with("/src/coordinator/router.rs")
                || p.ends_with("/src/kvpool/radix.rs")
                || p.contains("/src/server/")
        }
        _ => false,
    }
}

/// A parsed `lint:allow` waiver, or why it failed to parse.
pub enum Waiver {
    /// Validated rule names this waiver suppresses.
    Rules(Vec<String>),
    /// Malformed: the contained message explains what is wrong. A
    /// malformed waiver suppresses nothing.
    Malformed(String),
}

/// Parse a waiver out of a line's comment view. Returns `None` when the
/// comment contains no `lint:allow(` marker at all.
pub fn parse_waiver(comment: &str) -> Option<Waiver> {
    let marker = "lint:allow(";
    let start = comment.find(marker)?;
    let after = &comment[start + marker.len()..];
    let close = match after.find(')') {
        Some(c) => c,
        None => {
            return Some(Waiver::Malformed(
                "unclosed waiver — expected `lint:allow(rule): reason`".to_string(),
            ))
        }
    };
    let rules: Vec<String> = after[..close]
        .split(',')
        .map(|r| r.trim().to_string())
        .filter(|r| !r.is_empty())
        .collect();
    if rules.is_empty() {
        return Some(Waiver::Malformed(
            "empty rule list — expected `lint:allow(rule): reason`".to_string(),
        ));
    }
    if let Some(bad) = rules.iter().find(|r| !RULES.contains(&r.as_str())) {
        return Some(Waiver::Malformed(format!(
            "unknown rule `{bad}` — known rules: {}",
            RULES.join(", ")
        )));
    }
    let rest = after[close + 1..].trim_start();
    let reason_ok = rest
        .strip_prefix(':')
        .map(|r| !r.trim().is_empty())
        .unwrap_or(false);
    if !reason_ok {
        return Some(Waiver::Malformed(
            "waiver reason is mandatory — `lint:allow(rule): reason`".to_string(),
        ));
    }
    Some(Waiver::Rules(rules))
}

/// One violation (or `bad-waiver`) at a file:line:col.
#[derive(Debug, Clone)]
pub struct Diagnostic {
    pub path: String,
    /// 1-based line number.
    pub line: usize,
    /// 1-based character column.
    pub col: usize,
    pub rule: String,
    pub message: String,
}

impl fmt::Display for Diagnostic {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "{}:{}:{}: [{}] {}",
            self.path, self.line, self.col, self.rule, self.message
        )
    }
}

/// Result of linting one source file.
pub struct FileResult {
    pub diagnostics: Vec<Diagnostic>,
    /// Number of matches suppressed by valid waivers.
    pub waived: usize,
}

/// Lint one file's contents. Pure — no filesystem access.
pub fn lint_source(path: &Path, src: &str) -> FileResult {
    let display = path.to_string_lossy().replace('\\', "/");
    let lines = lexer::strip(src);
    let mut diagnostics = Vec::new();
    let mut waived = 0usize;

    // Pass 1: resolve waivers. `active[i]` holds the rule names waived on
    // line i. A waiver on a comment-only line forwards to the next line
    // containing code (skipping blank and comment-only lines).
    let mut active: Vec<Vec<String>> = vec![Vec::new(); lines.len()];
    let mut pending: Vec<String> = Vec::new();
    for (i, line) in lines.iter().enumerate() {
        if line.in_test {
            pending.clear();
            continue;
        }
        let has_code = !line.code.trim().is_empty();
        if has_code && !pending.is_empty() {
            active[i].append(&mut pending);
        }
        match parse_waiver(&line.comment) {
            None => {}
            Some(Waiver::Malformed(msg)) => {
                let col = line.comment.find("lint:allow(").map_or(1, |c| c + 1);
                diagnostics.push(Diagnostic {
                    path: display.clone(),
                    line: i + 1,
                    col,
                    rule: BAD_WAIVER.to_string(),
                    message: msg,
                });
            }
            Some(Waiver::Rules(rules)) => {
                if has_code {
                    active[i].extend(rules);
                } else {
                    pending.extend(rules);
                }
            }
        }
    }

    // Pass 2: match patterns against the code view of each live line.
    for (i, line) in lines.iter().enumerate() {
        if line.in_test || line.code.trim().is_empty() {
            continue;
        }
        let code: Vec<char> = line.code.chars().collect();
        for pat in PATTERNS.iter() {
            if !applicable(pat.rule, path) {
                continue;
            }
            for col0 in find_matches(&code, pat) {
                if active[i].iter().any(|r| r == pat.rule) {
                    waived += 1;
                } else {
                    diagnostics.push(Diagnostic {
                        path: display.clone(),
                        line: i + 1,
                        col: col0 + 1,
                        rule: pat.rule.to_string(),
                        message: pat.message.to_string(),
                    });
                }
            }
        }
    }

    diagnostics.sort_by(|a, b| (a.line, a.col, &a.rule).cmp(&(b.line, b.col, &b.rule)));
    FileResult { diagnostics, waived }
}

/// All start positions (char columns, 0-based) where the pattern occurs
/// in a line's code view, honoring identifier boundaries.
fn find_matches(code: &[char], pat: &Pattern) -> Vec<usize> {
    let needle: Vec<char> = pat.text.chars().collect();
    let (n, m) = (code.len(), needle.len());
    let mut out = Vec::new();
    if m == 0 || n < m {
        return out;
    }
    for start in 0..=n - m {
        if code[start..start + m] != needle[..] {
            continue;
        }
        if pat.start_boundary && start > 0 && is_ident(code[start - 1]) {
            continue;
        }
        if pat.end_boundary && start + m < n && is_ident(code[start + m]) {
            continue;
        }
        out.push(start);
    }
    out
}

/// Aggregate result over a set of roots.
pub struct Report {
    pub diagnostics: Vec<Diagnostic>,
    pub files_scanned: usize,
    pub waived: usize,
}

/// Walk the given files/directories (recursively, `.rs` only, skipping
/// hidden entries and `target/`), lint each file, and aggregate. File
/// order is sorted so output and JSON are byte-deterministic.
pub fn lint_paths(roots: &[PathBuf]) -> io::Result<Report> {
    let mut files: Vec<PathBuf> = Vec::new();
    for root in roots {
        collect_rs(root, &mut files)?;
    }
    files.sort();
    files.dedup();
    let mut report = Report { diagnostics: Vec::new(), files_scanned: 0, waived: 0 };
    for file in &files {
        let src = fs::read_to_string(file)?;
        let result = lint_source(file, &src);
        report.files_scanned += 1;
        report.waived += result.waived;
        report.diagnostics.extend(result.diagnostics);
    }
    Ok(report)
}

fn collect_rs(path: &Path, out: &mut Vec<PathBuf>) -> io::Result<()> {
    let meta = fs::metadata(path)?;
    if meta.is_file() {
        if path.extension().is_some_and(|e| e == "rs") {
            out.push(path.to_path_buf());
        }
        return Ok(());
    }
    let mut entries: Vec<PathBuf> = fs::read_dir(path)?
        .map(|e| e.map(|e| e.path()))
        .collect::<io::Result<_>>()?;
    entries.sort();
    for entry in entries {
        let name = entry.file_name().and_then(|n| n.to_str()).unwrap_or_default();
        if name.starts_with('.') || name == "target" {
            continue;
        }
        if entry.is_dir() {
            collect_rs(&entry, out)?;
        } else if entry.extension().is_some_and(|e| e == "rs") {
            out.push(entry);
        }
    }
    Ok(())
}

/// Render a report as stable, hand-rolled JSON (no serde — the linter
/// must build hermetically).
pub fn to_json(report: &Report) -> String {
    let mut s = String::from("{\n");
    s.push_str(&format!(
        "  \"files_scanned\": {},\n  \"waived\": {},\n  \"violations\": [",
        report.files_scanned, report.waived
    ));
    for (i, d) in report.diagnostics.iter().enumerate() {
        if i > 0 {
            s.push(',');
        }
        s.push_str(&format!(
            "\n    {{\"path\": \"{}\", \"line\": {}, \"col\": {}, \"rule\": \"{}\", \"message\": \"{}\"}}",
            esc(&d.path),
            d.line,
            d.col,
            esc(&d.rule),
            esc(&d.message)
        ));
    }
    if !report.diagnostics.is_empty() {
        s.push_str("\n  ");
    }
    s.push_str("]\n}\n");
    s
}

fn esc(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for ch in s.chars() {
        match ch {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn lint(path: &str, src: &str) -> FileResult {
        lint_source(Path::new(path), src)
    }

    #[test]
    fn policy_map_scopes_rules_to_modules() {
        let coord = Path::new("rust/src/coordinator/engine.rs");
        let clock = Path::new("rust/src/coordinator/clock.rs");
        let linalg = Path::new("rust/src/linalg/topk.rs");
        let example = Path::new("examples/serve_batch.rs");
        assert!(applicable(FLOAT_ORD, coord) && applicable(FLOAT_ORD, example));
        assert!(applicable(RAW_CLOCK, coord));
        assert!(!applicable(RAW_CLOCK, clock), "clock module is the allowlist");
        assert!(!applicable(RAW_CLOCK, linalg));
        assert!(!applicable(RAW_CLOCK, example));
        assert!(applicable(PANIC_IN_HOT_PATH, coord));
        assert!(!applicable(PANIC_IN_HOT_PATH, linalg));
        let router = Path::new("rust/src/coordinator/router.rs");
        let frontend = Path::new("rust/src/server/frontend.rs");
        assert!(applicable(PANIC_IN_HOT_PATH, router), "router decision core is hot-path");
        assert!(applicable(PANIC_IN_HOT_PATH, frontend), "frontend dispatch is hot-path");
        let metrics = Path::new("rust/src/coordinator/metrics.rs");
        assert!(!applicable(PANIC_IN_HOT_PATH, metrics), "scope stays per-file, not per-dir");
        let radix = Path::new("rust/src/kvpool/radix.rs");
        let table = Path::new("rust/src/kvpool/table.rs");
        assert!(
            applicable(PANIC_IN_HOT_PATH, radix),
            "radix tree is walked on every admission — hot-path"
        );
        assert!(applicable(NONDET_ITER, radix), "kvpool is determinism-critical");
        assert!(
            !applicable(PANIC_IN_HOT_PATH, table),
            "panic scope widens per-file (radix.rs only), not to all of kvpool"
        );
    }

    #[test]
    fn waiver_requires_reason_and_known_rule() {
        assert!(matches!(
            parse_waiver(" lint:allow(float-ord): NaN-free by construction"),
            Some(Waiver::Rules(r)) if r == vec![FLOAT_ORD.to_string()]
        ));
        assert!(matches!(
            parse_waiver(" lint:allow(float-ord)"),
            Some(Waiver::Malformed(_))
        ));
        assert!(matches!(
            parse_waiver(" lint:allow(float-ord):   "),
            Some(Waiver::Malformed(_))
        ));
        assert!(matches!(
            parse_waiver(" lint:allow(no-such-rule): reason"),
            Some(Waiver::Malformed(_))
        ));
        assert!(parse_waiver(" just a comment").is_none());
    }

    #[test]
    fn violation_fires_with_column_and_waiver_suppresses() {
        let src = "let m = xs.iter().max_by(|a, b| a.partial_cmp(b).unwrap());\n";
        let r = lint("rust/src/linalg/x.rs", src);
        assert_eq!(r.diagnostics.len(), 1);
        assert_eq!(r.diagnostics[0].rule, FLOAT_ORD);
        assert_eq!(r.diagnostics[0].line, 1);
        assert_eq!(r.diagnostics[0].col, src.find("partial_cmp").unwrap() + 1);

        let waived = format!("{} // lint:allow(float-ord): test scaffold", src.trim_end());
        let r = lint("rust/src/linalg/x.rs", &waived);
        assert!(r.diagnostics.is_empty());
        assert_eq!(r.waived, 1);
    }

    #[test]
    fn standalone_waiver_applies_to_next_code_line() {
        let src = concat!(
            "// lint:allow(raw-clock): wall-only stat, Steps twin never runs this\n",
            "// (second comment line between waiver and code)\n",
            "\n",
            "let t0 = Instant::now();\n",
        );
        let r = lint("rust/src/runtime/stack.rs", src);
        assert!(r.diagnostics.is_empty(), "{:?}", r.diagnostics);
        assert_eq!(r.waived, 1);
    }

    #[test]
    fn comments_and_strings_do_not_trip_rules() {
        let src = concat!(
            "// the old partial_cmp().unwrap() sort panicked here\n",
            "let s = \"Instant::now() HashMap<u64, u64>\";\n",
            "let r = r#\"partial_cmp SystemTime::now\"#;\n",
        );
        let r = lint("rust/src/coordinator/engine.rs", src);
        assert!(r.diagnostics.is_empty(), "{:?}", r.diagnostics);
    }

    #[test]
    fn malformed_waiver_reports_and_does_not_suppress() {
        let src = "let t0 = Instant::now(); // lint:allow(raw-clock)\n";
        let r = lint("rust/src/kvpool/x.rs", src);
        let rules: Vec<&str> = r.diagnostics.iter().map(|d| d.rule.as_str()).collect();
        assert!(rules.contains(&BAD_WAIVER), "{rules:?}");
        assert!(rules.contains(&RAW_CLOCK), "{rules:?}");
        assert_eq!(r.waived, 0);
    }

    #[test]
    fn ident_boundaries_guard_lookalikes() {
        let src = concat!(
            "fn my_partial_cmp_helper() {}\n",
            "let x = not_partial_cmp();\n",
            "let y = v.unwrap_or(0);\n",
        );
        let r = lint("rust/src/coordinator/engine.rs", src);
        assert!(r.diagnostics.is_empty(), "{:?}", r.diagnostics);
    }

    #[test]
    fn cfg_test_code_is_exempt() {
        let src = concat!(
            "pub fn live() {}\n",
            "#[cfg(test)]\n",
            "mod tests {\n",
            "    fn t() { let _ = a.partial_cmp(b).unwrap(); }\n",
            "}\n",
        );
        let r = lint("rust/src/linalg/x.rs", src);
        assert!(r.diagnostics.is_empty(), "{:?}", r.diagnostics);
    }

    #[test]
    fn json_output_is_escaped_and_shaped() {
        let report = Report {
            diagnostics: vec![Diagnostic {
                path: "a\"b.rs".to_string(),
                line: 3,
                col: 7,
                rule: FLOAT_ORD.to_string(),
                message: "back\\slash".to_string(),
            }],
            files_scanned: 1,
            waived: 2,
        };
        let j = to_json(&report);
        assert!(j.contains("\"files_scanned\": 1"));
        assert!(j.contains("\"waived\": 2"));
        assert!(j.contains("a\\\"b.rs"));
        assert!(j.contains("back\\\\slash"));
    }
}
