//! `repro-lint` — a hermetic invariant linter for this repository.
//!
//! The headline claims of this reproduction (paged-vs-flat bit-identity,
//! chunked-vs-monolithic byte-identical streams, Steps-clock trace
//! byte-equality) rest on invariants that were re-broken and re-fixed by
//! hand across four PRs. This crate mechanizes them as a blocking CI
//! gate:
//!
//! | rule | forbids |
//! |------|---------|
//! | `float-ord` | `partial_cmp` on floats (NaN panics / unstable order) |
//! | `raw-clock` | `Instant::now`/`SystemTime::now` outside the clock module |
//! | `nondet-iter` | `HashMap`/`HashSet` in determinism-critical modules |
//! | `unbounded-metrics` | float `Vec` accumulators in metrics paths |
//! | `panic-in-hot-path` | `unwrap`/`expect`/`panic!` in engine/server hot paths |
//!
//! Matching is lexical but comment/string-aware ([`lexer`]): rule names
//! mentioned in comments, string literals, raw strings, or `#[cfg(test)]`
//! regions never trip. Violations are suppressed per-line with
//! `// lint:allow(rule): reason` — the reason is mandatory ([`rules`]).
//!
//! The crate is pure `std` with zero dependencies, by design: it gates
//! CI, so it must build hermetically under the same no-registry
//! constraint that forced the vendored `anyhow`/`xla` crates.

pub mod lexer;
pub mod rules;

pub use rules::{
    applicable, lint_paths, lint_source, parse_waiver, to_json, Diagnostic, FileResult, Report,
    Waiver, BAD_WAIVER, FLOAT_ORD, NONDET_ITER, PANIC_IN_HOT_PATH, RAW_CLOCK, RULES,
    UNBOUNDED_METRICS,
};
