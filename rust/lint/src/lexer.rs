//! Comment/string-aware lexical scanner for Rust sources.
//!
//! `repro-lint` cannot be a grep: after four PRs of fixing
//! `partial_cmp().unwrap()` panics, the tree is full of *comments* (and
//! test fixtures, and doc strings) that mention the very patterns the
//! rules forbid. This module classifies every character of a source file
//! as code, comment, or literal content, so the rule matchers in
//! [`crate::rules`] only ever see real code.
//!
//! The scanner is a hand-rolled state machine, not a full parser. It
//! understands:
//!
//! * line comments (`//`, `///`, `//!`) and *nested* block comments,
//! * string literals with escapes, raw strings (`r"…"`, `r#"…"#`, any
//!   number of hashes), byte strings (`b"…"`, `br#"…"#`),
//! * char / byte-char literals vs lifetimes (`'a'` vs `&'a str`),
//! * raw identifiers (`r#match` is code, not a raw string),
//! * `#[cfg(test)]` regions — brace-matched and excluded from linting,
//!   so unit tests can exercise forbidden patterns without waivers.
//!
//! Columns are preserved: the `code` and `comment` views of a line are
//! the original line with out-of-class characters blanked to spaces, so
//! diagnostics point at the true source column (char columns, not bytes).

/// One source line split into aligned per-class views.
#[derive(Debug)]
pub struct Line {
    /// The original line, verbatim (no trailing newline).
    pub raw: String,
    /// Code characters only; comments and literal contents blanked.
    pub code: String,
    /// Comment characters only; waivers are parsed from this view.
    pub comment: String,
    /// Inside a `#[cfg(test)]` region — excluded from linting.
    pub in_test: bool,
}

#[derive(Clone, Copy, PartialEq)]
enum Class {
    Code,
    Comment,
    Literal,
}

/// Split a source file into per-line code/comment views with
/// `#[cfg(test)]` regions marked.
pub fn strip(src: &str) -> Vec<Line> {
    let chars: Vec<char> = src.chars().collect();
    let classes = classify(&chars);
    let mut lines = split_lines(&chars, &classes);
    mark_test_regions(&mut lines);
    lines
}

pub(crate) fn is_ident(ch: char) -> bool {
    ch.is_ascii_alphanumeric() || ch == '_'
}

fn classify(c: &[char]) -> Vec<Class> {
    let n = c.len();
    let mut k = vec![Class::Code; n];
    let mut i = 0;
    while i < n {
        let ch = c[i];
        if ch == '/' && i + 1 < n && c[i + 1] == '/' {
            while i < n && c[i] != '\n' {
                k[i] = Class::Comment;
                i += 1;
            }
        } else if ch == '/' && i + 1 < n && c[i + 1] == '*' {
            let mut depth = 0usize;
            while i < n {
                if i + 1 < n && c[i] == '/' && c[i + 1] == '*' {
                    depth += 1;
                    k[i] = Class::Comment;
                    k[i + 1] = Class::Comment;
                    i += 2;
                } else if i + 1 < n && c[i] == '*' && c[i + 1] == '/' {
                    depth = depth.saturating_sub(1);
                    k[i] = Class::Comment;
                    k[i + 1] = Class::Comment;
                    i += 2;
                    if depth == 0 {
                        break;
                    }
                } else {
                    k[i] = Class::Comment;
                    i += 1;
                }
            }
        } else if ch == '"' {
            i = consume_string(c, &mut k, i);
        } else if (ch == 'r' || ch == 'b') && (i == 0 || !is_ident(c[i - 1])) {
            match consume_prefixed(c, &mut k, i) {
                Some(next) => i = next,
                None => i += 1,
            }
        } else if ch == '\'' {
            i = consume_char_or_lifetime(c, &mut k, i);
        } else {
            i += 1;
        }
    }
    k
}

/// Consume a `"…"` literal starting at the opening quote; the quotes
/// stay code (harmless to matchers), the contents become `Literal`.
/// Returns the index just past the closing quote.
fn consume_string(c: &[char], k: &mut [Class], open: usize) -> usize {
    let n = c.len();
    let mut i = open + 1;
    while i < n {
        if c[i] == '\\' && i + 1 < n {
            k[i] = Class::Literal;
            k[i + 1] = Class::Literal;
            i += 2;
        } else if c[i] == '"' {
            return i + 1;
        } else {
            k[i] = Class::Literal;
            i += 1;
        }
    }
    i
}

/// At an `r`/`b` that may prefix a literal: consume `b"…"`, `b'…'`,
/// `r"…"`, `r#"…"#`, `br#"…"#`. Returns `None` for plain identifiers
/// and raw identifiers (`r#match`).
fn consume_prefixed(c: &[char], k: &mut [Class], i: usize) -> Option<usize> {
    let n = c.len();
    let (raw, body) = match c[i] {
        'b' if i + 1 < n && c[i + 1] == 'r' => (true, i + 2),
        'b' => (false, i + 1),
        'r' => (true, i + 1),
        _ => return None,
    };
    if !raw {
        if body < n && c[body] == '"' {
            return Some(consume_string(c, k, body));
        }
        if body < n && c[body] == '\'' {
            return Some(consume_char_or_lifetime(c, k, body));
        }
        return None;
    }
    let mut j = body;
    let mut hashes = 0usize;
    while j < n && c[j] == '#' {
        hashes += 1;
        j += 1;
    }
    if j >= n || c[j] != '"' {
        // `r#match` raw identifier, or a plain ident starting with r/br.
        return None;
    }
    j += 1;
    // Raw strings have no escapes; they close at `"` + `hashes` hashes.
    while j < n {
        let closed = c[j] == '"'
            && c.get(j + 1..j + 1 + hashes).is_some_and(|h| h.iter().all(|&x| x == '#'));
        if closed {
            return Some(j + 1 + hashes);
        }
        k[j] = Class::Literal;
        j += 1;
    }
    Some(j)
}

/// At a `'`: a char literal (`'x'`, `'\n'`, `'\u{1F600}'`) consumes
/// through its closing quote with contents blanked; a lifetime or loop
/// label (`'a`, `'static`, `'outer:`) stays code.
fn consume_char_or_lifetime(c: &[char], k: &mut [Class], i: usize) -> usize {
    let n = c.len();
    if i + 1 < n && c[i + 1] == '\\' {
        let mut j = i + 1;
        while j < n && c[j] != '\'' {
            if c[j] == '\\' {
                k[j] = Class::Literal;
                if j + 1 < n {
                    k[j + 1] = Class::Literal;
                }
                j += 2;
            } else {
                k[j] = Class::Literal;
                j += 1;
            }
        }
        return (j + 1).min(n);
    }
    if i + 2 < n && c[i + 2] == '\'' && c[i + 1] != '\'' {
        k[i + 1] = Class::Literal;
        return i + 3;
    }
    i + 1
}

fn split_lines(c: &[char], k: &[Class]) -> Vec<Line> {
    let mut lines = Vec::new();
    let mut raw = String::new();
    let mut code = String::new();
    let mut comment = String::new();
    for (i, &ch) in c.iter().enumerate() {
        if ch == '\n' {
            lines.push(Line {
                raw: std::mem::take(&mut raw),
                code: std::mem::take(&mut code),
                comment: std::mem::take(&mut comment),
                in_test: false,
            });
            continue;
        }
        raw.push(ch);
        match k[i] {
            Class::Code => {
                code.push(ch);
                comment.push(' ');
            }
            Class::Comment => {
                code.push(' ');
                comment.push(ch);
            }
            Class::Literal => {
                code.push(' ');
                comment.push(' ');
            }
        }
    }
    if !raw.is_empty() {
        lines.push(Line { raw, code, comment, in_test: false });
    }
    lines
}

fn brace_delta(depth: usize, code: &str) -> usize {
    let opens = code.matches('{').count();
    let closes = code.matches('}').count();
    (depth + opens).saturating_sub(closes)
}

/// Mark every line inside a `#[cfg(test)]`-gated item. The attribute
/// arms a pending state; the next `{` opens a brace-matched region.
/// A `;` before any `{` disarms it (`#[cfg(test)] use …;`). Braces are
/// counted on the code view only, so literals/comments never desync.
fn mark_test_regions(lines: &mut [Line]) {
    let mut depth = 0usize;
    let mut pending = false;
    for line in lines.iter_mut() {
        if depth > 0 {
            line.in_test = true;
            depth = brace_delta(depth, &line.code);
            continue;
        }
        if pending {
            line.in_test = true;
            if line.code.contains('{') {
                depth = brace_delta(0, &line.code);
                pending = false;
            } else if line.code.contains(';') {
                pending = false;
            }
            continue;
        }
        if line.code.contains("#[cfg(test)]") {
            line.in_test = true;
            let attr_end = line.code.find("#[cfg(test)]").map(|p| p + 12).unwrap_or(0);
            let rest = &line.code[attr_end..];
            if rest.contains('{') {
                depth = brace_delta(0, rest);
            } else if !rest.contains(';') {
                pending = true;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn code_of(src: &str) -> Vec<String> {
        strip(src).into_iter().map(|l| l.code).collect()
    }

    #[test]
    fn line_and_nested_block_comments_are_stripped() {
        let src = "let x = 1; // partial_cmp here\n/* a /* nested */ b */ let y = 2;\n";
        let code = code_of(src);
        assert!(!code[0].contains("partial_cmp"));
        assert!(code[0].contains("let x = 1;"));
        assert!(!code[1].contains('a'), "block comment body must be blanked: {:?}", code[1]);
        assert!(code[1].contains("let y = 2;"));
    }

    #[test]
    fn comment_view_keeps_comment_text_for_waivers() {
        let src = "let x = 1; // lint:allow(float-ord): why\n";
        let lines = strip(src);
        assert!(lines[0].comment.contains("lint:allow(float-ord): why"));
        assert!(!lines[0].code.contains("lint"));
    }

    #[test]
    fn string_contents_are_blanked_but_quotes_remain() {
        let src = "let s = \"Instant::now() { } \\\" quoted\";\n";
        let code = &code_of(src)[0];
        assert!(!code.contains("Instant"));
        assert!(!code.contains('{'));
        assert_eq!(code.matches('"').count(), 2);
    }

    #[test]
    fn raw_and_byte_strings_are_blanked() {
        let src = concat!(
            "let a = r#\"partial_cmp() \"inner\" \"#;\n",
            "let b = r\"SystemTime::now()\";\n",
            "let c = b\"HashMap<u8>\";\n",
            "let d = br##\"Vec<f64>\"##;\n",
        );
        for line in code_of(src) {
            assert!(!line.contains("partial_cmp"), "{line:?}");
            assert!(!line.contains("SystemTime"), "{line:?}");
            assert!(!line.contains("HashMap"), "{line:?}");
            assert!(!line.contains("Vec<f64"), "{line:?}");
        }
    }

    #[test]
    fn raw_identifiers_are_code_not_strings() {
        let src = "let r#match = 1; let after = r#match + 1;\n";
        let code = &code_of(src)[0];
        assert!(code.contains("let after"), "{code:?}");
    }

    #[test]
    fn char_literals_blank_but_lifetimes_survive() {
        let src = "fn f<'a>(s: &'a str) -> (char, char) { ('{', '\\'') }\n";
        let code = &code_of(src)[0];
        assert!(code.contains("fn f<'a>(s: &'a str)"), "{code:?}");
        assert_eq!(code.matches('{').count(), 1, "brace char literal must blank: {code:?}");
    }

    #[test]
    fn columns_are_preserved() {
        let src = "abc /* x */ def\n";
        let lines = strip(src);
        assert_eq!(lines[0].code.find("def"), src.find("def"));
    }

    #[test]
    fn cfg_test_regions_are_marked_and_brace_matched() {
        let src = concat!(
            "pub fn live() {}\n",
            "#[cfg(test)]\n",
            "mod tests {\n",
            "    #[test]\n",
            "    fn t() { let s = \"}\"; }\n",
            "}\n",
            "pub fn live_again() {}\n",
        );
        let lines = strip(src);
        let flags: Vec<bool> = lines.iter().map(|l| l.in_test).collect();
        assert_eq!(flags, vec![false, true, true, true, true, true, false]);
    }

    #[test]
    fn cfg_test_on_a_bodyless_item_does_not_swallow_the_file() {
        let src = "#[cfg(test)]\nuse std::fmt::Debug;\npub fn live() { let x = 1; }\n";
        let lines = strip(src);
        assert!(!lines[2].in_test, "code after `#[cfg(test)] use …;` must stay live");
    }
}
