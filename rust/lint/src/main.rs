//! CLI for `repro-lint`. Exit codes: 0 = clean, 1 = violations,
//! 2 = usage or I/O error.

use std::path::PathBuf;
use std::process::ExitCode;

const USAGE: &str = "\
repro-lint — hermetic invariant linter (determinism + float safety)

USAGE:
    repro-lint [--check] [--json] <path>...

ARGS:
    <path>...   Files or directories to scan (recursively, *.rs only;
                hidden entries and target/ are skipped)

FLAGS:
    --check     Explicitly request gate semantics (the default — exit 1
                on any violation); accepted so CI invocations read clearly
    --json      Emit the report as JSON instead of human-readable lines
    -h, --help  Show this help

RULES:
    float-ord           no `partial_cmp` on floats — use `total_cmp`/`linalg::topk`
    raw-clock           no raw `Instant::now`/`SystemTime::now` in
                        coordinator/runtime/obs/kvpool (clock module exempt)
    nondet-iter         no `HashMap`/`HashSet` in determinism-critical modules
    unbounded-metrics   no float `Vec` accumulators in metrics paths
    panic-in-hot-path   no `unwrap`/`expect`/`panic!` in engine/server hot paths

WAIVERS:
    // lint:allow(rule): reason     (reason mandatory; on its own line,
                                     applies to the next line of code)
";

fn main() -> ExitCode {
    let mut json = false;
    let mut roots: Vec<PathBuf> = Vec::new();
    for arg in std::env::args().skip(1) {
        match arg.as_str() {
            "--json" => json = true,
            "--check" => {}
            "-h" | "--help" => {
                print!("{USAGE}");
                return ExitCode::SUCCESS;
            }
            flag if flag.starts_with('-') => {
                eprintln!("repro-lint: unknown flag `{flag}`\n\n{USAGE}");
                return ExitCode::from(2);
            }
            _ => roots.push(PathBuf::from(arg)),
        }
    }
    if roots.is_empty() {
        eprintln!("repro-lint: no paths given\n\n{USAGE}");
        return ExitCode::from(2);
    }
    let report = match repro_lint::lint_paths(&roots) {
        Ok(report) => report,
        Err(err) => {
            eprintln!("repro-lint: {err}");
            return ExitCode::from(2);
        }
    };
    if json {
        print!("{}", repro_lint::to_json(&report));
    } else {
        for d in &report.diagnostics {
            println!("{d}");
        }
        println!(
            "repro-lint: {} file(s) scanned, {} violation(s), {} waived",
            report.files_scanned,
            report.diagnostics.len(),
            report.waived
        );
    }
    if report.diagnostics.is_empty() {
        ExitCode::SUCCESS
    } else {
        ExitCode::from(1)
    }
}
