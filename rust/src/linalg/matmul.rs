//! Matrix multiplication kernels, including the Appendix-C comparison pair.
//!
//! The paper's kernel contribution (Appendix C) is that SparQ's Triton
//! kernels parallelize an `m×k · k×n` product only along `m` — which in
//! decode attention is proportional to *batch·heads* and therefore tiny —
//! while Loki's kernels add the `n` (sequence) dimension. We reproduce the
//! pair as thread-parallel CPU kernels with identical inner loops:
//!
//! * [`matmul_threaded_1d`] — work split over rows of the output only
//!   (SparQ-style). With `m < threads` most cores idle.
//! * [`matmul_threaded_2d`] — work split over (row-block × col-block)
//!   tiles (Loki-style): full parallelism even at batch size 1.
//!
//! Both handle arbitrary (non-power-of-2) `n`, the second SparQ defect
//! the paper fixes. `cargo bench --bench kernel_1d_vs_2d` regenerates
//! Figure 16 with these kernels.

/// How a kernel distributes work.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Parallelism {
    Serial,
    /// Split output rows across threads (SparQ-style "m-only").
    Rows1D,
    /// Split (row, column) tiles across threads (Loki-style).
    Tiles2D,
}

/// `c[m,n] = a[m,k] · b[k,n]` — naive serial reference (tests oracle).
pub fn matmul(a: &[f32], b: &[f32], c: &mut [f32], m: usize, k: usize, n: usize) {
    assert_eq!(a.len(), m * k);
    assert_eq!(b.len(), k * n);
    assert_eq!(c.len(), m * n);
    for i in 0..m {
        let arow = &a[i * k..(i + 1) * k];
        let crow = &mut c[i * n..(i + 1) * n];
        crow.fill(0.0);
        for (l, &av) in arow.iter().enumerate() {
            let brow = &b[l * n..(l + 1) * n];
            for j in 0..n {
                crow[j] += av * brow[j];
            }
        }
    }
}

/// Cache-blocked serial matmul (the building block the threaded variants
/// call per tile).
pub fn matmul_blocked(a: &[f32], b: &[f32], c: &mut [f32], m: usize, k: usize, n: usize) {
    const BK: usize = 64;
    assert_eq!(a.len(), m * k);
    assert_eq!(b.len(), k * n);
    assert_eq!(c.len(), m * n);
    c.fill(0.0);
    let mut l0 = 0;
    while l0 < k {
        let lend = (l0 + BK).min(k);
        for i in 0..m {
            let crow = &mut c[i * n..(i + 1) * n];
            for l in l0..lend {
                let av = a[i * k + l];
                if av == 0.0 {
                    continue;
                }
                let brow = &b[l * n..(l + 1) * n];
                for j in 0..n {
                    crow[j] += av * brow[j];
                }
            }
        }
        l0 = lend;
    }
}

fn default_threads() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4)
}

/// SparQ-style kernel: parallelism only across output **rows**. When
/// `m < threads` (decode attention at small batch), the surplus threads
/// have nothing to do — reproducing the Figure-16 pathology.
pub fn matmul_threaded_1d(
    a: &[f32],
    b: &[f32],
    c: &mut [f32],
    m: usize,
    k: usize,
    n: usize,
    threads: usize,
) {
    assert_eq!(a.len(), m * k);
    assert_eq!(b.len(), k * n);
    assert_eq!(c.len(), m * n);
    let threads = threads.max(1).min(m.max(1));
    if threads <= 1 || m == 0 {
        return matmul_blocked(a, b, c, m, k, n);
    }
    let rows_per = m.div_ceil(threads);
    std::thread::scope(|scope| {
        let mut rest = c;
        let mut row0 = 0;
        while row0 < m {
            let rows = rows_per.min(m - row0);
            let (chunk, tail) = rest.split_at_mut(rows * n);
            rest = tail;
            let a_chunk = &a[row0 * k..(row0 + rows) * k];
            scope.spawn(move || {
                matmul_blocked(a_chunk, b, chunk, rows, k, n);
            });
            row0 += rows;
        }
    });
}

/// Loki-style kernel: parallelism across **(row, column) tiles**, so the
/// sequence dimension (`n`, the KV-cache length) feeds every core even at
/// batch size 1. Handles ragged (non-power-of-2) `n` by construction.
pub fn matmul_threaded_2d(
    a: &[f32],
    b: &[f32],
    c: &mut [f32],
    m: usize,
    k: usize,
    n: usize,
    threads: usize,
) {
    assert_eq!(a.len(), m * k);
    assert_eq!(b.len(), k * n);
    assert_eq!(c.len(), m * n);
    let threads = threads.max(1);
    if threads <= 1 {
        return matmul_blocked(a, b, c, m, k, n);
    }
    // Choose a column-tile width so that m × col_tiles ≈ 4× threads
    // (enough slack for load balancing without scheduling overhead).
    let want_tiles = threads * 4;
    let col_tiles = want_tiles.div_ceil(m.max(1)).max(1).min(n.max(1));
    let tile_w = n.div_ceil(col_tiles).max(1);

    // Tiles share no output bytes (each owns rows × [j0, j1) columns), but
    // Rust can't see that through a single &mut: hand out raw sub-ranges.
    struct SendPtr(*mut f32);
    unsafe impl Send for SendPtr {}
    let c_ptr = SendPtr(c.as_mut_ptr());
    let c_addr = c_ptr.0 as usize;

    let mut tiles: Vec<(usize, usize)> = Vec::new();
    let mut j0 = 0;
    while j0 < n {
        let j1 = (j0 + tile_w).min(n);
        tiles.push((j0, j1));
        j0 = j1;
    }
    let next = std::sync::atomic::AtomicUsize::new(0);
    std::thread::scope(|scope| {
        let next_ref = &next;
        let tiles_ref = &tiles;
        for _ in 0..threads.min(tiles.len() * m) {
            scope.spawn(move || {
                loop {
                    let t = next_ref.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                    let total = tiles_ref.len() * m;
                    if t >= total {
                        break;
                    }
                    let i = t / tiles_ref.len();
                    let (j0, j1) = tiles_ref[t % tiles_ref.len()];
                    let arow = &a[i * k..(i + 1) * k];
                    // SAFETY: tile (i, j0..j1) is written by exactly one task.
                    let crow = unsafe {
                        let base = (c_addr as *mut f32).add(i * n + j0);
                        std::slice::from_raw_parts_mut(base, j1 - j0)
                    };
                    crow.fill(0.0);
                    for (l, &av) in arow.iter().enumerate() {
                        if av == 0.0 {
                            continue;
                        }
                        let brow = &b[l * n + j0..l * n + j1];
                        for (cj, &bv) in crow.iter_mut().zip(brow) {
                            *cj += av * bv;
                        }
                    }
                }
            });
        }
    });
}

/// Dispatch helper used by benches and the attnsim kernels.
pub fn matmul_with(
    par: Parallelism,
    a: &[f32],
    b: &[f32],
    c: &mut [f32],
    m: usize,
    k: usize,
    n: usize,
    threads: Option<usize>,
) {
    let t = threads.unwrap_or_else(default_threads);
    match par {
        Parallelism::Serial => matmul_blocked(a, b, c, m, k, n),
        Parallelism::Rows1D => matmul_threaded_1d(a, b, c, m, k, n, t),
        Parallelism::Tiles2D => matmul_threaded_2d(a, b, c, m, k, n, t),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Xoshiro256;

    fn check_all_agree(m: usize, k: usize, n: usize) {
        let mut rng = Xoshiro256::new((m * 31 + k * 7 + n) as u64);
        let a = rng.normal_vec(m * k);
        let b = rng.normal_vec(k * n);
        let mut c0 = vec![0.0; m * n];
        let mut c1 = vec![0.0; m * n];
        let mut c2 = vec![0.0; m * n];
        let mut c3 = vec![0.0; m * n];
        matmul(&a, &b, &mut c0, m, k, n);
        matmul_blocked(&a, &b, &mut c1, m, k, n);
        matmul_threaded_1d(&a, &b, &mut c2, m, k, n, 4);
        matmul_threaded_2d(&a, &b, &mut c3, m, k, n, 4);
        for i in 0..m * n {
            assert!((c0[i] - c1[i]).abs() < 1e-3, "blocked differs at {i}");
            assert!((c0[i] - c2[i]).abs() < 1e-3, "1d differs at {i}");
            assert!((c0[i] - c3[i]).abs() < 1e-3, "2d differs at {i}");
        }
    }

    #[test]
    fn variants_agree_square() {
        check_all_agree(16, 16, 16);
    }

    #[test]
    fn variants_agree_ragged() {
        // Non-power-of-2 n is exactly the case SparQ's kernels couldn't
        // handle (Appendix C); ours must.
        check_all_agree(3, 64, 1023);
        check_all_agree(1, 17, 513);
        check_all_agree(40, 128, 999);
    }

    #[test]
    fn degenerate_shapes() {
        check_all_agree(1, 1, 1);
        let mut c = vec![];
        matmul(&[], &[], &mut c, 0, 4, 0);
    }
}
