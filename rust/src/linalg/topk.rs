//! Top-k selection over score vectors.
//!
//! The paper observes (§6.4) that PyTorch's top-k is nearly as expensive
//! as the sparse matmuls themselves and calls a custom kernel future work
//! — so we implement three algorithms and ablate them
//! (`cargo bench --bench topk_bench`): full sort O(S log S) — the paper's
//! complexity model, binary heap O(S log k), and quickselect O(S) expected.
//!
//! All three rank by the same strict total order so the ablation compares
//! identical selections: scores descend by IEEE-754 total order
//! (`f32::total_cmp` semantics — positive NaN above +inf, negative NaN
//! below −inf, −0.0 below +0.0) and exact ties break toward the lower
//! index. Every algorithm therefore returns the same index *set* for any
//! input, NaNs and duplicates included.

/// Selection algorithm choice (ablation axis).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TopKAlgo {
    Sort,
    Heap,
    QuickSelect,
}

/// IEEE-754 total-order key: `key(a) < key(b)` ⟺ `a.total_cmp(&b)` is
/// `Less`. Shared by all three algorithms so they agree on NaN and ±0.0.
#[inline]
fn total_order_key(x: f32) -> i32 {
    let b = x.to_bits() as i32;
    b ^ (((b >> 31) as u32) >> 1) as i32
}

/// Strict total rank for index `i`: higher is better. Score descends by
/// total order; equal scores break toward the lower index (`!i` descends
/// as `i` ascends). Distinct for distinct indices, so partitioning and
/// heap replacement never see an equal pair.
#[inline]
fn rank(scores: &[f32], i: u32) -> i64 {
    ((total_order_key(scores[i as usize]) as i64) << 32) | (!i as i64 & 0xFFFF_FFFF)
}

/// Dispatch. Returns the indices of the k largest scores (order
/// unspecified; exact ties broken toward the lower index, identically
/// across algorithms). k is clamped to len.
pub fn top_k_indices(algo: TopKAlgo, scores: &[f32], k: usize) -> Vec<u32> {
    match algo {
        TopKAlgo::Sort => top_k_sort(scores, k),
        TopKAlgo::Heap => top_k_heap(scores, k),
        TopKAlgo::QuickSelect => top_k_quickselect(scores, k),
    }
}

/// Full argsort then prefix — O(S log S).
pub fn top_k_sort(scores: &[f32], k: usize) -> Vec<u32> {
    let k = k.min(scores.len());
    let mut idx: Vec<u32> = (0..scores.len() as u32).collect();
    idx.sort_unstable_by_key(|&i| std::cmp::Reverse(rank(scores, i)));
    idx.truncate(k);
    idx
}

/// Min-heap of size k — O(S log k); wins when k ≪ S.
pub fn top_k_heap(scores: &[f32], k: usize) -> Vec<u32> {
    use std::cmp::Reverse;
    use std::collections::BinaryHeap;
    let k = k.min(scores.len());
    if k == 0 {
        return Vec::new();
    }
    // Heap top is the worst kept rank; a candidate replaces it only when
    // strictly better (ranks are distinct, so no equal case exists).
    let mut heap: BinaryHeap<Reverse<(i64, u32)>> = BinaryHeap::with_capacity(k + 1);
    for i in 0..scores.len() as u32 {
        let r = rank(scores, i);
        if heap.len() < k {
            heap.push(Reverse((r, i)));
        } else if let Some(&Reverse((min_rank, _))) = heap.peek() {
            if r > min_rank {
                heap.pop();
                heap.push(Reverse((r, i)));
            }
        }
    }
    heap.into_iter().map(|Reverse((_, i))| i).collect()
}

/// Hoare-partition quickselect — O(S) expected, in-place on an index array.
pub fn top_k_quickselect(scores: &[f32], k: usize) -> Vec<u32> {
    let n = scores.len();
    let k = k.min(n);
    if k == 0 {
        return Vec::new();
    }
    if k == n {
        return (0..n as u32).collect();
    }
    let mut idx: Vec<u32> = (0..n as u32).collect();
    let mut lo = 0usize;
    let mut hi = n;
    // Invariant: the k largest (by `rank`) end up in idx[..k].
    let mut rng_state = 0x9E3779B97F4A7C15u64 ^ (n as u64);
    while hi - lo > 1 {
        // Random-ish pivot to dodge adversarial patterns.
        rng_state ^= rng_state << 13;
        rng_state ^= rng_state >> 7;
        rng_state ^= rng_state << 17;
        let pivot_i = lo + (rng_state as usize) % (hi - lo);
        let pivot = rank(scores, idx[pivot_i]);
        // Partition: higher-ranked-than-pivot first.
        let mut store = lo;
        idx.swap(pivot_i, hi - 1);
        for i in lo..hi - 1 {
            if rank(scores, idx[i]) > pivot {
                idx.swap(i, store);
                store += 1;
            }
        }
        idx.swap(store, hi - 1);
        match store.cmp(&k) {
            std::cmp::Ordering::Equal => break,
            std::cmp::Ordering::Less => lo = store + 1,
            std::cmp::Ordering::Greater => hi = store,
        }
        if lo >= k {
            break;
        }
    }
    idx.truncate(k);
    idx
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Xoshiro256;

    fn as_sorted_set(v: &[u32]) -> Vec<u32> {
        let mut v = v.to_vec();
        v.sort_unstable();
        v
    }

    #[test]
    fn algorithms_agree_on_random_inputs() {
        let mut rng = Xoshiro256::new(11);
        for trial in 0..50 {
            let n = rng.range(1, 500);
            let k = rng.range(0, n + 1);
            let scores = rng.normal_vec(n);
            let a = as_sorted_set(&top_k_sort(&scores, k));
            let b = as_sorted_set(&top_k_heap(&scores, k));
            let c = as_sorted_set(&top_k_quickselect(&scores, k));
            // The shared total order makes selections identical by
            // *index*, not just by value.
            assert_eq!(a, b, "trial {trial} heap");
            assert_eq!(a, c, "trial {trial} quickselect");
        }
    }

    #[test]
    fn algorithms_agree_on_nan_duplicate_and_signed_zero_inputs() {
        // Adversarial rows for the old mixed-comparator bug: NaN-laden
        // (the partial_cmp-based sort treated NaN as equal-to-anything
        // while the heap total-ordered it), heavy exact duplicates, and
        // ±0.0 (total order separates them; `==` does not).
        let nan = f32::NAN;
        let cases: Vec<Vec<f32>> = vec![
            vec![nan, 1.0, 2.0, nan, 0.5],
            vec![nan; 6],
            vec![1.0, nan, f32::INFINITY, f32::NEG_INFINITY, -nan, 0.0],
            vec![3.0, 3.0, 3.0, 3.0, 3.0],
            vec![0.0, -0.0, 0.0, -0.0, 1.0, -1.0],
            vec![-0.0, 0.0],
            vec![2.0, 2.0, nan, 2.0, nan, -0.0, 0.0, 2.0],
            vec![f32::MIN, f32::MAX, 0.0, nan, -0.0, f32::EPSILON, -f32::EPSILON],
        ];
        for (ci, scores) in cases.iter().enumerate() {
            for k in 0..=scores.len() {
                let a = as_sorted_set(&top_k_sort(scores, k));
                let b = as_sorted_set(&top_k_heap(scores, k));
                let c = as_sorted_set(&top_k_quickselect(scores, k));
                assert_eq!(a, b, "case {ci} k {k} heap");
                assert_eq!(a, c, "case {ci} k {k} quickselect");
                assert_eq!(a.len(), k, "case {ci} k {k} cardinality");
            }
        }
        // Ties break toward the lower index, so selections are exact:
        // five equal scores, k=2 → indices {0, 1}.
        let tied = vec![3.0, 3.0, 3.0, 3.0, 3.0];
        for algo in [TopKAlgo::Sort, TopKAlgo::Heap, TopKAlgo::QuickSelect] {
            assert_eq!(as_sorted_set(&top_k_indices(algo, &tied, 2)), vec![0, 1], "{algo:?}");
        }
        // total_cmp semantics: positive NaN outranks +inf, +0.0 outranks
        // -0.0.
        let mixed = vec![f32::INFINITY, nan, 5.0];
        for algo in [TopKAlgo::Sort, TopKAlgo::Heap, TopKAlgo::QuickSelect] {
            assert_eq!(as_sorted_set(&top_k_indices(algo, &mixed, 1)), vec![1], "{algo:?}");
        }
        let zeros = vec![-0.0, 0.0];
        for algo in [TopKAlgo::Sort, TopKAlgo::Heap, TopKAlgo::QuickSelect] {
            assert_eq!(as_sorted_set(&top_k_indices(algo, &zeros, 1)), vec![1], "{algo:?}");
        }
    }

    #[test]
    fn selects_the_actual_top() {
        let scores = vec![0.1, 5.0, -2.0, 3.0, 3.0, 0.0];
        for algo in [TopKAlgo::Sort, TopKAlgo::Heap, TopKAlgo::QuickSelect] {
            let got = as_sorted_set(&top_k_indices(algo, &scores, 3));
            // top-3 values are 5.0, 3.0, 3.0 at indices {1, 3, 4}
            assert_eq!(got, vec![1, 3, 4], "{algo:?}");
        }
    }

    #[test]
    fn k_edge_cases() {
        let scores = vec![1.0, 2.0];
        for algo in [TopKAlgo::Sort, TopKAlgo::Heap, TopKAlgo::QuickSelect] {
            assert!(top_k_indices(algo, &scores, 0).is_empty());
            assert_eq!(top_k_indices(algo, &scores, 5).len(), 2);
        }
    }

    #[test]
    fn handles_neg_inf_scores() {
        let mut scores = vec![super::super::softmax::NEG_INF; 64];
        scores[7] = 1.0;
        scores[13] = 2.0;
        for algo in [TopKAlgo::Sort, TopKAlgo::Heap, TopKAlgo::QuickSelect] {
            let got = as_sorted_set(&top_k_indices(algo, &scores, 2));
            assert_eq!(got, vec![7, 13], "{algo:?}");
        }
    }
}
