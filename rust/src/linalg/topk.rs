//! Top-k selection over score vectors.
//!
//! The paper observes (§6.4) that PyTorch's top-k is nearly as expensive
//! as the sparse matmuls themselves and calls a custom kernel future work
//! — so we implement three algorithms and ablate them
//! (`cargo bench --bench topk_bench`): full sort O(S log S) — the paper's
//! complexity model, binary heap O(S log k), and quickselect O(S) expected.

/// Selection algorithm choice (ablation axis).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TopKAlgo {
    Sort,
    Heap,
    QuickSelect,
}

/// Dispatch. Returns the indices of the k largest scores (order
/// unspecified; ties broken arbitrarily). k is clamped to len.
pub fn top_k_indices(algo: TopKAlgo, scores: &[f32], k: usize) -> Vec<u32> {
    match algo {
        TopKAlgo::Sort => top_k_sort(scores, k),
        TopKAlgo::Heap => top_k_heap(scores, k),
        TopKAlgo::QuickSelect => top_k_quickselect(scores, k),
    }
}

/// Full argsort then prefix — O(S log S).
pub fn top_k_sort(scores: &[f32], k: usize) -> Vec<u32> {
    let k = k.min(scores.len());
    let mut idx: Vec<u32> = (0..scores.len() as u32).collect();
    idx.sort_unstable_by(|&a, &b| {
        scores[b as usize]
            .partial_cmp(&scores[a as usize])
            .unwrap_or(std::cmp::Ordering::Equal)
    });
    idx.truncate(k);
    idx
}

/// Min-heap of size k — O(S log k); wins when k ≪ S.
pub fn top_k_heap(scores: &[f32], k: usize) -> Vec<u32> {
    use std::cmp::Reverse;
    use std::collections::BinaryHeap;
    let k = k.min(scores.len());
    if k == 0 {
        return Vec::new();
    }
    // f32 isn't Ord; use the IEEE-754 total-order trick on bits.
    fn key(x: f32) -> i32 {
        let b = x.to_bits() as i32;
        b ^ (((b >> 31) as u32) >> 1) as i32
    }
    let mut heap: BinaryHeap<Reverse<(i32, u32)>> = BinaryHeap::with_capacity(k + 1);
    for (i, &s) in scores.iter().enumerate() {
        let item = Reverse((key(s), i as u32));
        if heap.len() < k {
            heap.push(item);
        } else if let Some(&Reverse((min_key, _))) = heap.peek() {
            if key(s) > min_key {
                heap.pop();
                heap.push(item);
            }
        }
    }
    heap.into_iter().map(|Reverse((_, i))| i).collect()
}

/// Hoare-partition quickselect — O(S) expected, in-place on an index array.
pub fn top_k_quickselect(scores: &[f32], k: usize) -> Vec<u32> {
    let n = scores.len();
    let k = k.min(n);
    if k == 0 {
        return Vec::new();
    }
    if k == n {
        return (0..n as u32).collect();
    }
    let mut idx: Vec<u32> = (0..n as u32).collect();
    let mut lo = 0usize;
    let mut hi = n;
    // Invariant: the k largest end up in idx[..k].
    let mut rng_state = 0x9E3779B97F4A7C15u64 ^ (n as u64);
    while hi - lo > 1 {
        // Random-ish pivot to dodge adversarial patterns.
        rng_state ^= rng_state << 13;
        rng_state ^= rng_state >> 7;
        rng_state ^= rng_state << 17;
        let pivot_i = lo + (rng_state as usize) % (hi - lo);
        let pivot = scores[idx[pivot_i] as usize];
        // Partition: larger-than-pivot first.
        let mut store = lo;
        idx.swap(pivot_i, hi - 1);
        for i in lo..hi - 1 {
            if scores[idx[i] as usize] > pivot {
                idx.swap(i, store);
                store += 1;
            }
        }
        idx.swap(store, hi - 1);
        match store.cmp(&k) {
            std::cmp::Ordering::Equal => break,
            std::cmp::Ordering::Less => lo = store + 1,
            std::cmp::Ordering::Greater => hi = store,
        }
        if lo >= k {
            break;
        }
    }
    idx.truncate(k);
    idx
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Xoshiro256;

    fn as_sorted_set(v: &[u32]) -> Vec<u32> {
        let mut v = v.to_vec();
        v.sort_unstable();
        v
    }

    #[test]
    fn algorithms_agree_on_random_inputs() {
        let mut rng = Xoshiro256::new(11);
        for trial in 0..50 {
            let n = rng.range(1, 500);
            let k = rng.range(0, n + 1);
            let scores = rng.normal_vec(n);
            let a = as_sorted_set(&top_k_sort(&scores, k));
            let b = as_sorted_set(&top_k_heap(&scores, k));
            let c = as_sorted_set(&top_k_quickselect(&scores, k));
            // With ties possible, compare selected *values* not indices.
            let vals = |ix: &[u32]| {
                let mut v: Vec<f32> = ix.iter().map(|&i| scores[i as usize]).collect();
                v.sort_by(|x, y| x.partial_cmp(y).unwrap());
                v
            };
            assert_eq!(vals(&a), vals(&b), "trial {trial} heap");
            assert_eq!(vals(&a), vals(&c), "trial {trial} quickselect");
        }
    }

    #[test]
    fn selects_the_actual_top() {
        let scores = vec![0.1, 5.0, -2.0, 3.0, 3.0, 0.0];
        for algo in [TopKAlgo::Sort, TopKAlgo::Heap, TopKAlgo::QuickSelect] {
            let got = as_sorted_set(&top_k_indices(algo, &scores, 3));
            // top-3 values are 5.0, 3.0, 3.0 at indices {1, 3, 4}
            assert_eq!(got, vec![1, 3, 4], "{algo:?}");
        }
    }

    #[test]
    fn k_edge_cases() {
        let scores = vec![1.0, 2.0];
        for algo in [TopKAlgo::Sort, TopKAlgo::Heap, TopKAlgo::QuickSelect] {
            assert!(top_k_indices(algo, &scores, 0).is_empty());
            assert_eq!(top_k_indices(algo, &scores, 5).len(), 2);
        }
    }

    #[test]
    fn handles_neg_inf_scores() {
        let mut scores = vec![super::super::softmax::NEG_INF; 64];
        scores[7] = 1.0;
        scores[13] = 2.0;
        for algo in [TopKAlgo::Sort, TopKAlgo::Heap, TopKAlgo::QuickSelect] {
            let got = as_sorted_set(&top_k_indices(algo, &scores, 2));
            assert_eq!(got, vec![7, 13], "{algo:?}");
        }
    }
}
