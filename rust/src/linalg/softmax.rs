//! Numerically stable softmax primitives for the attention substrate.

pub const NEG_INF: f32 = -1e30;

/// In-place stable softmax over a score slice.
pub fn softmax_inplace(scores: &mut [f32]) {
    if scores.is_empty() {
        return;
    }
    let max = scores.iter().copied().fold(f32::NEG_INFINITY, f32::max);
    let mut sum = 0.0f32;
    for s in scores.iter_mut() {
        *s = (*s - max).exp();
        sum += *s;
    }
    if sum > 0.0 {
        let inv = 1.0 / sum;
        for s in scores.iter_mut() {
            *s *= inv;
        }
    }
}

/// Softmax over only the positions where `mask` is true; masked-out
/// entries are set to exactly 0 probability.
pub fn softmax_masked_inplace(scores: &mut [f32], mask: &[bool]) {
    assert_eq!(scores.len(), mask.len());
    let mut max = f32::NEG_INFINITY;
    for (s, &m) in scores.iter().zip(mask) {
        if m && *s > max {
            max = *s;
        }
    }
    if max == f32::NEG_INFINITY {
        scores.fill(0.0);
        return;
    }
    let mut sum = 0.0f32;
    for (s, &m) in scores.iter_mut().zip(mask) {
        if m {
            *s = (*s - max).exp();
            sum += *s;
        } else {
            *s = 0.0;
        }
    }
    if sum > 0.0 {
        let inv = 1.0 / sum;
        for s in scores.iter_mut() {
            *s *= inv;
        }
    }
}

/// log-sum-exp of a slice (perplexity bookkeeping).
pub fn log_sum_exp(xs: &[f32]) -> f32 {
    let max = xs.iter().copied().fold(f32::NEG_INFINITY, f32::max);
    if max == f32::NEG_INFINITY {
        return f32::NEG_INFINITY;
    }
    let sum: f32 = xs.iter().map(|x| (x - max).exp()).sum();
    max + sum.ln()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sums_to_one() {
        let mut s = vec![1.0, 2.0, 3.0, -1.0];
        softmax_inplace(&mut s);
        let sum: f32 = s.iter().sum();
        assert!((sum - 1.0).abs() < 1e-6);
        assert!(s[2] > s[1] && s[1] > s[0] && s[0] > s[3]);
    }

    #[test]
    fn stable_for_huge_scores() {
        let mut s = vec![1e20, 1e20 + 1.0];
        softmax_inplace(&mut s);
        assert!(s.iter().all(|x| x.is_finite()));
    }

    #[test]
    fn masked_zeroes_dead_slots() {
        let mut s = vec![5.0, 1.0, 100.0, 2.0];
        let mask = vec![true, true, false, true];
        softmax_masked_inplace(&mut s, &mask);
        assert_eq!(s[2], 0.0);
        assert!((s.iter().sum::<f32>() - 1.0).abs() < 1e-6);
    }

    #[test]
    fn all_masked_is_zero() {
        let mut s = vec![1.0, 2.0];
        softmax_masked_inplace(&mut s, &[false, false]);
        assert_eq!(s, vec![0.0, 0.0]);
    }

    #[test]
    fn lse_matches_naive() {
        let xs = vec![0.5f32, -1.0, 2.0];
        let naive = xs.iter().map(|x| x.exp()).sum::<f32>().ln();
        assert!((log_sum_exp(&xs) - naive).abs() < 1e-5);
    }
}
