//! Parallel-execution simulator for the Appendix-C kernel study.
//!
//! The paper benchmarks GPU kernels whose difference is *grid shape*: how
//! the score matmul's work is cut into schedulable units (SparQ: one unit
//! per output row; Loki: units over rows × sequence blocks). This repo
//! runs on hosts where wall-clock threading cannot expose that effect (CI
//! machines here have a single core), so Figure 16 is regenerated with a
//! calibrated simulator instead:
//!
//!  * each kernel variant is decomposed into its actual work units (MACs);
//!  * units are list-scheduled (LPT) onto `workers` virtual executors —
//!    the SM-occupancy model of a GPU launch;
//!  * makespan converts to seconds via a *measured* serial MAC throughput
//!    plus a per-unit launch overhead.
//!
//! The real threaded kernels (`linalg::matmul`, `attnsim::kernels`) stay
//! in the build and are correctness-tested; only the Fig-16 *timing*
//! comes from the simulator. DESIGN.md documents the substitution.

/// Virtual machine model. `workers` defaults to 64 (the SM-count regime
/// the paper's A100 kernels schedule onto — enough that batch·heads at
/// batch 1 underfills the machine, which is exactly SparQ's pathology).
#[derive(Clone, Copy, Debug)]
pub struct ParSimCfg {
    pub workers: usize,
    /// Multiply-accumulates per second of one worker (calibrate with
    /// [`calibrate_mac_rate`]).
    pub mac_per_sec: f64,
    /// Fixed cost to launch one work unit (scheduling/launch latency).
    pub unit_overhead_s: f64,
}

impl Default for ParSimCfg {
    fn default() -> Self {
        Self { workers: 64, mac_per_sec: 2.0e9, unit_overhead_s: 2.0e-6 }
    }
}

/// Greedy longest-processing-time makespan on `workers` executors.
/// Units are given in MACs; returns seconds.
pub fn makespan(units: &[f64], cfg: &ParSimCfg) -> f64 {
    if units.is_empty() {
        return 0.0;
    }
    let mut sorted: Vec<f64> = units.to_vec();
    // Descending total order (stable sort → lower index wins ties, the
    // same discipline as `linalg::topk`). The old `partial_cmp().unwrap()`
    // panicked on NaN units; `total_cmp` ranks NaN deterministically and
    // the ns conversion below saturates it to zero work.
    sorted.sort_by(|a, b| b.total_cmp(a));
    // Min-heap of worker finish times.
    use std::cmp::Reverse;
    use std::collections::BinaryHeap;
    let mut heap: BinaryHeap<Reverse<u64>> =
        (0..cfg.workers.max(1)).map(|_| Reverse(0u64)).collect();
    // Work in nanoseconds to keep ordering integral.
    let to_ns =
        |macs: f64| -> u64 { ((macs / cfg.mac_per_sec + cfg.unit_overhead_s) * 1e9) as u64 };
    let mut max_finish = 0u64;
    for u in sorted {
        let Reverse(t) = heap.pop().unwrap();
        let finish = t + to_ns(u);
        max_finish = max_finish.max(finish);
        heap.push(Reverse(finish));
    }
    max_finish as f64 / 1e9
}

/// Work decomposition of the decode score matmul
/// (`[lanes, d_used] · [d_used, live]` per lane).
pub fn score_units_1d(lanes: usize, live: usize, d_used: usize) -> Vec<f64> {
    // SparQ-style: one unit per lane (m-dimension only).
    vec![(live * d_used) as f64; lanes]
}

pub fn score_units_2d(lanes: usize, live: usize, d_used: usize, block: usize) -> Vec<f64> {
    // Loki-style: units over (lane × sequence blocks).
    let blocks = live.div_ceil(block).max(1);
    let mut units = Vec::with_capacity(lanes * blocks);
    for _ in 0..lanes {
        let mut rest = live;
        for _ in 0..blocks {
            let b = rest.min(block);
            units.push((b * d_used) as f64);
            rest -= b;
        }
    }
    units
}

/// Measure this host's serial MAC throughput so simulated absolute times
/// are anchored to reality.
#[allow(clippy::disallowed_methods)] // genuine wall measurement: calibration anchors sim time
pub fn calibrate_mac_rate() -> f64 {
    let n = 4_000_000usize;
    let a: Vec<f32> = (0..n).map(|i| (i as f32 * 0.37).sin()).collect();
    let b: Vec<f32> = (0..n).map(|i| (i as f32 * 0.11).cos()).collect();
    let t0 = std::time::Instant::now();
    let mut acc = 0.0f32;
    for i in 0..n {
        acc += a[i] * b[i];
    }
    std::hint::black_box(acc);
    let dt = t0.elapsed().as_secs_f64();
    (n as f64 / dt).max(1e8)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg(workers: usize) -> ParSimCfg {
        ParSimCfg { workers, mac_per_sec: 1e9, unit_overhead_s: 0.0 }
    }

    #[test]
    fn perfect_split_halves_time() {
        let units = vec![1e9, 1e9];
        assert!((makespan(&units, &cfg(1)) - 2.0).abs() < 1e-6);
        assert!((makespan(&units, &cfg(2)) - 1.0).abs() < 1e-6);
    }

    #[test]
    fn few_units_underfill_the_machine() {
        // 4 equal units on 64 workers: time = one unit, not total/64 —
        // the SparQ batch-1 pathology.
        let units_1d = score_units_1d(4, 1000, 32);
        let t_1d = makespan(&units_1d, &cfg(64));
        let units_2d = score_units_2d(4, 1000, 32, 64);
        let t_2d = makespan(&units_2d, &cfg(64));
        assert!(t_1d > 2.0 * t_2d, "1d {t_1d} vs 2d {t_2d}");
        // Total work identical.
        let w1: f64 = units_1d.iter().sum();
        let w2: f64 = units_2d.iter().sum();
        assert!((w1 - w2).abs() < 1e-6);
    }

    #[test]
    fn overhead_penalizes_tiny_blocks() {
        let c = ParSimCfg { workers: 4, mac_per_sec: 1e9, unit_overhead_s: 1e-3 };
        let coarse = score_units_2d(4, 1024, 32, 1024);
        let fine = score_units_2d(4, 1024, 32, 8);
        assert!(makespan(&fine, &c) > makespan(&coarse, &c));
    }

    #[test]
    fn ragged_lengths_covered() {
        let units = score_units_2d(3, 1023, 16, 256);
        // 3 lanes × ceil(1023/256)=4 blocks.
        assert_eq!(units.len(), 12);
        let total: f64 = units.iter().sum();
        assert!((total - (3 * 1023 * 16) as f64).abs() < 1e-6);
    }

    #[test]
    fn nan_units_are_deterministic_not_a_panic() {
        // Regression: the old `partial_cmp().unwrap()` sort aborted on a
        // NaN unit. Now NaN ranks totally and casts to zero-time work, so
        // the makespan is the same wherever the NaN sits — and the same
        // as an explicit zero unit.
        let a = makespan(&[2e9, f64::NAN, 1e9], &cfg(2));
        let b = makespan(&[f64::NAN, 2e9, 1e9], &cfg(2));
        let c = makespan(&[2e9, 0.0, 1e9], &cfg(2));
        assert_eq!(a, b);
        assert_eq!(a, c);
        assert!((a - 2.0).abs() < 1e-6, "{a}");
    }

    #[test]
    fn calibration_returns_sane_rate() {
        let r = calibrate_mac_rate();
        assert!(r > 1e7 && r < 1e12, "{r}");
    }
}
