//! Dense linear algebra substrates: blocked/threaded matmuls, a symmetric
//! eigensolver (PCA), stable softmax, top-k selection and summary
//! statistics. Everything operates on plain `&[f32]` row-major slices so
//! the attention kernels in [`crate::attnsim`] can run zero-copy.

pub mod matmul;
pub mod parsim;
pub mod pca;
pub mod softmax;
pub mod stats;
pub mod topk;

pub use matmul::{matmul, matmul_blocked, matmul_threaded_1d, matmul_threaded_2d, Parallelism};
pub use pca::{Pca, PcaBasis};
pub use softmax::{softmax_inplace, softmax_masked_inplace};
pub use stats::{jaccard, mean, percentile, std_dev, Summary};
pub use topk::{top_k_heap, top_k_indices, top_k_quickselect, top_k_sort, TopKAlgo};
