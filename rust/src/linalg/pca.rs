//! PCA via covariance + cyclic Jacobi eigendecomposition.
//!
//! Mirrors `python/compile/pca.py::pca_basis`; the pytest/rust test pair
//! cross-validates the two implementations on the exported key dumps.
//! D is a head dimension (≤ 128 here), so Jacobi — O(D³) per sweep with a
//! handful of sweeps — is plenty fast and numerically robust for the
//! symmetric PSD covariance matrices PCA produces.

/// An eigendecomposition of a key-covariance matrix for one (layer, head).
#[derive(Clone, Debug)]
pub struct PcaBasis {
    pub dim: usize,
    /// Normalized eigenvalues, descending (sum = 1 unless all-zero input).
    pub eigenvalues: Vec<f32>,
    /// Row-major `dim × dim`; **columns** are the principal components,
    /// matching numpy's `eigh` convention: `x_rotated = x · basis`.
    pub basis: Vec<f32>,
}

impl PcaBasis {
    /// Eq. 2 of the paper: smallest d whose leading eigenvalues explain
    /// `v_pct`% of the variance.
    pub fn rank_at(&self, v_pct: f64) -> usize {
        let target = v_pct / 100.0 - 1e-12;
        let mut cum = 0.0f64;
        for (i, &e) in self.eigenvalues.iter().enumerate() {
            cum += e as f64;
            if cum >= target {
                return i + 1;
            }
        }
        self.dim
    }

    /// Rotate a row vector into PCA space: `y = x · basis`.
    pub fn rotate(&self, x: &[f32], out: &mut [f32]) {
        let d = self.dim;
        assert_eq!(x.len(), d);
        assert_eq!(out.len(), d);
        for j in 0..d {
            let mut s = 0.0;
            for i in 0..d {
                s += x[i] * self.basis[i * d + j];
            }
            out[j] = s;
        }
    }
}

/// PCA fitting over row-major samples.
pub struct Pca;

impl Pca {
    /// Fit from `n` samples of dimension `d` (row-major `n × d`).
    pub fn fit(samples: &[f32], n: usize, d: usize) -> PcaBasis {
        assert_eq!(samples.len(), n * d);
        assert!(n > 1, "need at least 2 samples");
        // Mean.
        let mut mean = vec![0.0f64; d];
        for row in samples.chunks_exact(d) {
            for (m, &x) in mean.iter_mut().zip(row) {
                *m += x as f64;
            }
        }
        for m in &mut mean {
            *m /= n as f64;
        }
        // Covariance (f64 accumulation for stability).
        let mut cov = vec![0.0f64; d * d];
        for row in samples.chunks_exact(d) {
            for i in 0..d {
                let xi = row[i] as f64 - mean[i];
                for j in i..d {
                    cov[i * d + j] += xi * (row[j] as f64 - mean[j]);
                }
            }
        }
        let denom = (n - 1) as f64;
        for i in 0..d {
            for j in i..d {
                let v = cov[i * d + j] / denom;
                cov[i * d + j] = v;
                cov[j * d + i] = v;
            }
        }
        Self::eigh(&cov, d)
    }

    /// Symmetric eigendecomposition by cyclic Jacobi; returns descending
    /// eigenvalues (normalized) and the orthogonal eigenvector matrix.
    pub fn eigh(sym: &[f64], d: usize) -> PcaBasis {
        assert_eq!(sym.len(), d * d);
        let mut a = sym.to_vec();
        let mut v = vec![0.0f64; d * d];
        for i in 0..d {
            v[i * d + i] = 1.0;
        }
        let max_sweeps = 64;
        for _sweep in 0..max_sweeps {
            // Off-diagonal Frobenius norm.
            let mut off = 0.0f64;
            for i in 0..d {
                for j in (i + 1)..d {
                    off += a[i * d + j] * a[i * d + j];
                }
            }
            // Converged — or poisoned: a NaN covariance (NaN keys
            // reaching calibration) can never converge, so bail to the
            // sanitization below instead of burning every sweep on it.
            let off_norm = off.sqrt();
            if off_norm.is_nan() || off_norm < 1e-12 {
                break;
            }
            for p in 0..d {
                for q in (p + 1)..d {
                    let apq = a[p * d + q];
                    if apq.abs() < 1e-300 {
                        continue;
                    }
                    let app = a[p * d + p];
                    let aqq = a[q * d + q];
                    let theta = (aqq - app) / (2.0 * apq);
                    let t = theta.signum() / (theta.abs() + (theta * theta + 1.0).sqrt());
                    let c = 1.0 / (t * t + 1.0).sqrt();
                    let s = t * c;
                    // Rotate rows/cols p and q of A.
                    for i in 0..d {
                        let aip = a[i * d + p];
                        let aiq = a[i * d + q];
                        a[i * d + p] = c * aip - s * aiq;
                        a[i * d + q] = s * aip + c * aiq;
                    }
                    for j in 0..d {
                        let apj = a[p * d + j];
                        let aqj = a[q * d + j];
                        a[p * d + j] = c * apj - s * aqj;
                        a[q * d + j] = s * apj + c * aqj;
                    }
                    // Accumulate eigenvectors.
                    for i in 0..d {
                        let vip = v[i * d + p];
                        let viq = v[i * d + q];
                        v[i * d + p] = c * vip - s * viq;
                        v[i * d + q] = s * vip + c * viq;
                    }
                }
            }
        }
        // Extract, sanitize, clamp, sort descending. A degenerate
        // covariance (e.g. NaN keys reaching calibration) surfaces here
        // as NaN diagonal entries: sanitize them to 0 *before*
        // normalization — mirroring the sampler's degenerate-logit
        // guard — and sort with `total_cmp`, which NaN can never panic
        // (the old `partial_cmp().unwrap()` aborted the whole fit).
        let mut order: Vec<usize> = (0..d).collect();
        let eigs: Vec<f64> = (0..d)
            .map(|i| {
                let v = a[i * d + i];
                if v.is_nan() {
                    0.0
                } else {
                    v.max(0.0)
                }
            })
            .collect();
        order.sort_by(|&i, &j| eigs[j].total_cmp(&eigs[i]));
        let total: f64 = eigs.iter().sum();
        let norm = if total > 0.0 { total } else { 1.0 };
        let eigenvalues: Vec<f32> = order.iter().map(|&i| (eigs[i] / norm) as f32).collect();
        let mut basis = vec![0.0f32; d * d];
        for (newj, &oldj) in order.iter().enumerate() {
            for i in 0..d {
                basis[i * d + newj] = v[i * d + oldj] as f32;
            }
        }
        PcaBasis { dim: d, eigenvalues, basis }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Xoshiro256;

    /// Build samples with a known anisotropic spectrum: x = z · diag(s) · Qᵀ.
    fn aniso_samples(n: usize, d: usize, scales: &[f32], seed: u64) -> Vec<f32> {
        let mut rng = Xoshiro256::new(seed);
        let mut out = vec![0.0; n * d];
        for row in out.chunks_exact_mut(d) {
            for (j, x) in row.iter_mut().enumerate() {
                *x = rng.normal_f32() * scales[j];
            }
        }
        out
    }

    #[test]
    fn recovers_axis_aligned_spectrum() {
        let d = 8;
        let scales: Vec<f32> = (0..d).map(|i| 2.0f32.powi(-(i as i32))).collect();
        let samples = aniso_samples(4000, d, &scales, 1);
        let basis = Pca::fit(&samples, 4000, d);
        // Eigenvalues should be ~ scales² normalized, descending.
        let mut expect: Vec<f32> = scales.iter().map(|s| s * s).collect();
        let tot: f32 = expect.iter().sum();
        for e in &mut expect {
            *e /= tot;
        }
        for i in 0..d {
            assert!(
                (basis.eigenvalues[i] - expect[i]).abs() < 0.02,
                "eig {i}: {} vs {}",
                basis.eigenvalues[i],
                expect[i]
            );
        }
    }

    #[test]
    fn basis_is_orthogonal() {
        let samples = aniso_samples(1000, 16, &[1.0; 16], 2);
        let b = Pca::fit(&samples, 1000, 16);
        let d = 16;
        for i in 0..d {
            for j in 0..d {
                let mut dot = 0.0f64;
                for k in 0..d {
                    dot += (b.basis[k * d + i] * b.basis[k * d + j]) as f64;
                }
                let want = if i == j { 1.0 } else { 0.0 };
                assert!((dot - want).abs() < 1e-4, "col {i}·col {j} = {dot}");
            }
        }
    }

    #[test]
    fn rank_at_thresholds() {
        let b = PcaBasis {
            dim: 4,
            eigenvalues: vec![0.6, 0.3, 0.08, 0.02],
            basis: vec![0.0; 16],
        };
        assert_eq!(b.rank_at(50.0), 1);
        assert_eq!(b.rank_at(90.0), 2);
        assert_eq!(b.rank_at(99.0), 4);
        assert_eq!(b.rank_at(100.0), 4);
    }

    #[test]
    fn rotation_preserves_norm() {
        let samples = aniso_samples(500, 12, &[1.0; 12], 3);
        let b = Pca::fit(&samples, 500, 12);
        let mut rng = Xoshiro256::new(4);
        let x = rng.normal_vec(12);
        let mut y = vec![0.0; 12];
        b.rotate(&x, &mut y);
        let nx: f32 = x.iter().map(|v| v * v).sum();
        let ny: f32 = y.iter().map(|v| v * v).sum();
        assert!((nx - ny).abs() / nx < 1e-4);
    }

    #[test]
    fn nan_covariance_is_sanitized_not_a_panic() {
        // Fully poisoned: every entry NaN. The old
        // `partial_cmp().unwrap()` sort panicked here; now the fit
        // degrades to an all-zero (finite, normalized-by-1) spectrum.
        let d = 6;
        let sym = vec![f64::NAN; d * d];
        let b = Pca::eigh(&sym, d);
        assert_eq!(b.eigenvalues.len(), d);
        for (i, &e) in b.eigenvalues.iter().enumerate() {
            assert!(e.is_finite(), "eig {i} must be finite, got {e}");
            assert_eq!(e, 0.0, "NaN eigenvalues sanitize to 0");
        }
        // Downstream consumers keep working on the degenerate basis.
        assert_eq!(b.rank_at(90.0), d);

        // Partially poisoned: one NaN entry in an otherwise valid
        // diagonal matrix. No panic, finite spectrum, still descending.
        let mut sym = vec![0.0f64; d * d];
        for i in 0..d {
            sym[i * d + i] = (d - i) as f64;
        }
        sym[1] = f64::NAN; // (0, 1)
        sym[d] = f64::NAN; // (1, 0)
        let b = Pca::eigh(&sym, d);
        for w in b.eigenvalues.windows(2) {
            assert!(w[0].is_finite() && w[1].is_finite());
            assert!(w[0] >= w[1], "spectrum must stay descending: {:?}", b.eigenvalues);
        }
    }

    #[test]
    fn nan_samples_do_not_panic_the_fit() {
        // A single NaN key row poisons the whole covariance (every
        // accumulation touches it) — exactly the calibration-input
        // failure the satellite names. The fit must survive.
        let d = 8;
        let n = 64;
        let mut samples = aniso_samples(n, d, &[1.0; 8], 11);
        samples[3 * d + 2] = f32::NAN;
        let b = Pca::fit(&samples, n, d);
        assert_eq!(b.eigenvalues.len(), d);
        for &e in &b.eigenvalues {
            assert!(e.is_finite(), "fit on NaN input must sanitize, got {e}");
        }
        assert!(b.rank_at(90.0) >= 1);
    }

    #[test]
    fn low_rank_data_has_low_rank_at_90() {
        // Samples confined to a 3-dim subspace of 32 dims.
        let d = 32;
        let mut scales = vec![0.001f32; d];
        scales[0] = 3.0;
        scales[1] = 2.0;
        scales[2] = 1.0;
        let samples = aniso_samples(2000, d, &scales, 5);
        let b = Pca::fit(&samples, 2000, d);
        assert!(b.rank_at(90.0) <= 3, "rank {}", b.rank_at(90.0));
    }
}
