//! Summary statistics + set-similarity helpers used across the
//! experiment harnesses (latency summaries, Jaccard top-k agreement).

/// Arithmetic mean; 0 for empty input.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        0.0
    } else {
        xs.iter().sum::<f64>() / xs.len() as f64
    }
}

/// Sample standard deviation.
pub fn std_dev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / (xs.len() - 1) as f64).sqrt()
}

/// Percentile by linear interpolation on a copy (p in [0, 100]).
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut v = xs.to_vec();
    // `total_cmp`, not `partial_cmp().unwrap()`: a single NaN sample
    // (same panic class as the `Pca::eigh` fix) must not abort a
    // metrics render. IEEE total order sorts NaN above +inf.
    v.sort_by(|a, b| a.total_cmp(b));
    let rank = (p / 100.0) * (v.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        v[lo]
    } else {
        v[lo] + (v[hi] - v[lo]) * (rank - lo as f64)
    }
}

/// Jaccard similarity |A∩B| / |A∪B| of two index sets (Fig. 6 left:
/// agreement between Loki's approximate top-k and the exact top-k).
pub fn jaccard(a: &[u32], b: &[u32]) -> f64 {
    if a.is_empty() && b.is_empty() {
        return 1.0;
    }
    // lint:allow(nondet-iter): intersection/union *counts* are order-independent
    let sa: std::collections::HashSet<u32> = a.iter().copied().collect();
    // lint:allow(nondet-iter): intersection/union *counts* are order-independent
    let sb: std::collections::HashSet<u32> = b.iter().copied().collect();
    let inter = sa.intersection(&sb).count();
    let union = sa.union(&sb).count();
    inter as f64 / union as f64
}

/// One-pass latency / value summary for metrics and bench output.
#[derive(Clone, Debug, Default)]
pub struct Summary {
    values: Vec<f64>,
}

impl Summary {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn push(&mut self, v: f64) {
        self.values.push(v);
    }

    pub fn count(&self) -> usize {
        self.values.len()
    }

    pub fn mean(&self) -> f64 {
        mean(&self.values)
    }

    pub fn std_dev(&self) -> f64 {
        std_dev(&self.values)
    }

    pub fn percentile(&self, p: f64) -> f64 {
        percentile(&self.values, p)
    }

    pub fn min(&self) -> f64 {
        self.values.iter().copied().fold(f64::INFINITY, f64::min)
    }

    pub fn max(&self) -> f64 {
        self.values.iter().copied().fold(f64::NEG_INFINITY, f64::max)
    }

    pub fn sum(&self) -> f64 {
        self.values.iter().sum()
    }

    /// "mean ± std [p50 p95 p99]" display string (units up to caller).
    pub fn display(&self) -> String {
        format!(
            "{:.3} ± {:.3} [p50 {:.3}, p95 {:.3}, p99 {:.3}] n={}",
            self.mean(),
            self.std_dev(),
            self.percentile(50.0),
            self.percentile(95.0),
            self.percentile(99.0),
            self.count()
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_std() {
        let xs = vec![1.0, 2.0, 3.0, 4.0];
        assert!((mean(&xs) - 2.5).abs() < 1e-12);
        assert!((std_dev(&xs) - (5.0f64 / 3.0).sqrt()).abs() < 1e-12);
    }

    #[test]
    fn percentile_interpolates() {
        let xs = vec![0.0, 10.0];
        assert!((percentile(&xs, 50.0) - 5.0).abs() < 1e-12);
        assert_eq!(percentile(&xs, 0.0), 0.0);
        assert_eq!(percentile(&xs, 100.0), 10.0);
    }

    #[test]
    fn percentile_survives_nan() {
        // Regression: the old `partial_cmp().unwrap()` sort panicked on
        // any NaN sample. Now NaN sorts last (IEEE total order) and
        // finite quantiles stay meaningful.
        let xs = vec![1.0, f64::NAN, 3.0, 2.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert!((percentile(&xs, 50.0) - 2.5).abs() < 1e-12);
        let mut s = Summary::new();
        s.push(f64::NAN);
        s.push(5.0);
        let _ = s.display(); // must not panic
    }

    #[test]
    fn jaccard_cases() {
        assert_eq!(jaccard(&[1, 2, 3], &[1, 2, 3]), 1.0);
        assert_eq!(jaccard(&[1, 2], &[3, 4]), 0.0);
        assert!((jaccard(&[1, 2, 3], &[2, 3, 4]) - 0.5).abs() < 1e-12);
        assert_eq!(jaccard(&[], &[]), 1.0);
    }

    #[test]
    fn summary_roundtrip() {
        let mut s = Summary::new();
        for i in 0..100 {
            s.push(i as f64);
        }
        assert_eq!(s.count(), 100);
        assert!((s.mean() - 49.5).abs() < 1e-9);
        assert_eq!(s.min(), 0.0);
        assert_eq!(s.max(), 99.0);
        assert!((s.percentile(50.0) - 49.5).abs() < 1e-9);
    }
}
