//! Experiment harnesses — one module per paper table/figure.
//!
//! Every harness prints the paper-shaped table and writes
//! `results/<id>.{txt,json}`. Regenerate any of them with
//! `repro-experiments <id>`; `repro-experiments all` runs the full
//! evaluation section. See DESIGN.md §5 for the experiment index and
//! EXPERIMENTS.md for recorded paper-vs-measured outcomes.

pub mod fig1_rank_models;
pub mod fig2_rank_layers;
pub mod fig3_quality_sweep;
pub mod fig4_longbench;
pub mod fig5_downstream;
pub mod fig6_append;
pub mod fig6_calib;
pub mod fig6_jaccard;
pub mod fig7_attn_time;
pub mod fig15_variable_df;
pub mod fig16_kernels;
pub mod hlo_cost;
pub mod roofline_report;
pub mod table1_speedup;
pub mod table2_ppl;
pub mod table5_pcaattn;

use std::path::PathBuf;

use crate::util::json::Json;

/// Write `results/<id>.json`.
pub fn write_json(id: &str, value: &Json) -> PathBuf {
    let path = crate::util::results_dir().join(format!("{id}.json"));
    if let Err(e) = std::fs::write(&path, value.to_string()) {
        eprintln!("warn: could not write {}: {e}", path.display());
    }
    path
}

/// Quick-mode scaling: experiments honor `--quick` (or LOKI_QUICK=1) by
/// shrinking item counts ~4x; useful for CI smoke runs.
pub fn scale(quick: bool, n: usize) -> usize {
    if quick {
        (n / 4).max(2)
    } else {
        n
    }
}
