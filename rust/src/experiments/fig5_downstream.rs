//! Figure 5: short-context downstream accuracy for Full / Exact-TopK /
//! H2O / Loki at k_f = 0.25 (d_f = 0.25 for Loki), per task and averaged.

use anyhow::Result;

use crate::data::tasks::{ShortTaskKind, TaskSuite};
use crate::eval::{score_choices_batch, VariantSpec};
use crate::runtime::RuntimeStack;
use crate::util::artifacts_dir;
use crate::util::json::{self, Json};
use crate::util::table::{fnum, Table};

pub fn run(stack: &RuntimeStack, quick: bool) -> Result<Json> {
    let suite = TaskSuite::load(&artifacts_dir())?;
    let tok = suite.tokenizer();
    let items = super::scale(quick, 24);
    let pca = stack.manifest.default_pca.clone();

    let specs = vec![
        ("full", VariantSpec::Full),
        ("exact-topk", VariantSpec::TopK { k_f: 0.25 }),
        ("h2o", VariantSpec::H2o { k_f: 0.25 }),
        ("loki", VariantSpec::Loki { k_f: 0.25, d_f: 0.25 }),
    ];
    let mut headers = vec!["task".to_string()];
    headers.extend(specs.iter().map(|(n, _)| n.to_string()));
    let mut table = Table::new(
        "Fig 5: short-context tasks, k_f = 0.25 — accuracy (agreement-with-full)",
        &headers.iter().map(|s| s.as_str()).collect::<Vec<_>>(),
    );
    let mut rows = Vec::new();
    let mut sums = vec![0.0f64; specs.len()];
    let mut agree_sums = vec![0.0f64; specs.len()];
    for kind in ShortTaskKind::all() {
        let tasks = suite.short_tasks(kind, items, 3);
        let mut cells = vec![kind.name().to_string()];
        let mut obj = vec![("task", json::s(kind.name()))];
        let mut full_preds: Vec<usize> = Vec::new();
        for (si, (name, spec)) in specs.iter().enumerate() {
            let mut correct = 0usize;
            let mut preds = Vec::with_capacity(tasks.len());
            for t in &tasks {
                let prompt = tok.encode(&t.prompt);
                let choices: Vec<Vec<i32>> = t.choices.iter().map(|c| tok.encode(c)).collect();
                let out = score_choices_batch(stack, &pca, spec, &prompt, &choices, t.correct)?;
                if out.is_correct() {
                    correct += 1;
                }
                preds.push(out.predicted);
            }
            let acc = correct as f64 / tasks.len() as f64;
            // Behaviour-fidelity vs full attention (column per method).
            if si == 0 {
                full_preds = preds.clone();
            }
            let agree = preds.iter().zip(&full_preds).filter(|(a, b)| a == b).count()
                as f64
                / tasks.len() as f64;
            sums[si] += acc;
            agree_sums[si] += agree;
            cells.push(format!("{} ({})", fnum(acc, 2), fnum(agree, 2)));
            obj.push((Box::leak(name.to_string().into_boxed_str()) as &str, json::num(acc)));
            obj.push((
                Box::leak(format!("{name}_agree").into_boxed_str()) as &str,
                json::num(agree),
            ));
        }
        table.row(cells);
        rows.push(json::obj(obj));
        println!("  {} done", kind.name());
    }
    let mut mean = vec!["mean".to_string()];
    for (s, a) in sums.iter().zip(&agree_sums) {
        let n = ShortTaskKind::all().len() as f64;
        mean.push(format!("{} ({})", fnum(s / n, 2), fnum(a / n, 2)));
    }
    table.row(mean);
    table.emit("fig5_downstream");
    let out = json::arr(rows);
    super::write_json("fig5_downstream", &out);
    println!("(paper: Loki ≈ full > H2O; exact-topk is Loki's upper bound)");
    Ok(out)
}
