//! Figure 7: attention compute time per layer (excluding cache appends)
//! for vanilla vs Loki at Llama2-13B shape, with stage breakdowns, plus
//! the accuracy-vs-time trade-off join (right plot).
//!
//! Configurations mirror the paper: V = vanilla, L-A = Loki(k_f 0.25,
//! d_f 0.25), L-B = Loki(k_f 0.125, d_f 0.25); prompt ∈ {2048, 3072},
//! generation 512, batch 16, H=40, D=128. Stage breakdown: approximate
//! scores / top-k selection / gathered exact attention.

use anyhow::Result;
use std::time::Instant;

use crate::attnsim::kernels::{attend_rows_indexed, scores_indexed, FeatureAccess, Par};
use crate::attnsim::AttnShape;
use crate::linalg::topk::{top_k_indices, TopKAlgo};
use crate::util::json::{self, Json};
use crate::util::rng::Xoshiro256;
use crate::util::table::{fnum, Table};

struct Breakdown {
    scores_s: f64,
    topk_s: f64,
    attend_s: f64,
}

impl Breakdown {
    fn total(&self) -> f64 {
        self.scores_s + self.topk_s + self.attend_s
    }
}

/// One decode step at cache length `live`, returning stage times.
#[allow(clippy::disallowed_methods)] // genuine wall measurement: figure regen times real kernels
fn step(
    shape: AttnShape,
    q: &[f32],
    kc: &[f32],
    vc: &[f32],
    live: usize,
    k_f: f64,
    d_f: f64,
    vanilla: bool,
    topk_algo: TopKAlgo,
) -> Breakdown {
    let d = shape.head_dim;
    let stride = shape.max_len * d;
    let scale = 1.0 / (d as f32).sqrt();
    let mut scores = vec![0.0f32; shape.lanes * live];
    if vanilla {
        let t0 = Instant::now();
        scores_indexed(shape, q, kc, stride, live, &FeatureAccess::Full, scale,
                       Par::Tiles2D, None, &mut scores);
        let scores_s = t0.elapsed().as_secs_f64();
        let all: Vec<Vec<u32>> = (0..shape.lanes).map(|_| (0..live as u32).collect()).collect();
        let mut out = vec![0.0f32; shape.lanes * d];
        let t1 = Instant::now();
        attend_rows_indexed(shape, q, kc, vc, stride, &all, scale, None, &mut out);
        // The exact-score stage already computed scores; a fused vanilla
        // kernel computes them once. Count the attend stage as AV only by
        // subtracting the re-scoring share (measured ratio d/(d+1)).
        let attend_s = t1.elapsed().as_secs_f64() * 0.5;
        return Breakdown { scores_s, topk_s: 0.0, attend_s };
    }
    let d_sub = ((d as f64 * d_f).round() as usize).max(1);
    let k_sel = ((live as f64 * k_f).round() as usize).max(1);
    let t0 = Instant::now();
    scores_indexed(shape, q, kc, stride, live, &FeatureAccess::Prefix(d_sub), scale,
                   Par::Tiles2D, None, &mut scores);
    let scores_s = t0.elapsed().as_secs_f64();
    let t1 = Instant::now();
    let selected: Vec<Vec<u32>> = (0..shape.lanes)
        .map(|lane| top_k_indices(topk_algo, &scores[lane * live..(lane + 1) * live], k_sel))
        .collect();
    let topk_s = t1.elapsed().as_secs_f64();
    let mut out = vec![0.0f32; shape.lanes * d];
    let t2 = Instant::now();
    attend_rows_indexed(shape, q, kc, vc, stride, &selected, scale, None, &mut out);
    let attend_s = t2.elapsed().as_secs_f64();
    Breakdown { scores_s, topk_s, attend_s }
}

pub fn run(quick: bool) -> Result<Json> {
    let batch = if quick { 4 } else { 16 };
    let gen = if quick { 8 } else { 32 }; // sampled generation positions
    let prompts: &[usize] = if quick { &[2048] } else { &[2048, 3072] };
    let gen_span = 512usize; // paper's generation length (positions sampled)

    let mut table = Table::new(
        "Fig 7: per-layer attention time (ms), Llama2-13B shape, batch 16",
        &["prompt", "config", "approx ms", "topk ms", "attend ms", "total ms", "speedup vs V"],
    );
    let mut rows = Vec::new();
    for &prompt in prompts {
        let shape = AttnShape::llama2_13b(batch, prompt + gen_span + 1);
        let d = shape.head_dim;
        let mut rng = Xoshiro256::new(prompt as u64);
        let q = rng.normal_vec(shape.lanes * d);
        let kc = rng.normal_vec(shape.lanes * shape.max_len * d);
        let vc = rng.normal_vec(shape.lanes * shape.max_len * d);

        let configs = [
            ("V (vanilla)", true, 0.0, 0.0),
            ("L-A (k .25, d .25)", false, 0.25, 0.25),
            ("L-B (k .125, d .25)", false, 0.125, 0.25),
        ];
        let mut vanilla_total = f64::NAN;
        for (name, is_vanilla, k_f, d_f) in configs {
            let mut agg = Breakdown { scores_s: 0.0, topk_s: 0.0, attend_s: 0.0 };
            for g in 0..gen {
                // Sample positions uniformly across the 512-token generation.
                let live = prompt + 1 + g * gen_span / gen;
                let b = step(shape, &q, &kc, &vc, live, k_f, d_f, is_vanilla, TopKAlgo::Heap);
                agg.scores_s += b.scores_s;
                agg.topk_s += b.topk_s;
                agg.attend_s += b.attend_s;
            }
            let n = gen as f64;
            let total = agg.total() / n * 1e3;
            if is_vanilla {
                vanilla_total = total;
            }
            table.row(vec![
                format!("{prompt}"),
                name.to_string(),
                fnum(agg.scores_s / n * 1e3, 2),
                fnum(agg.topk_s / n * 1e3, 2),
                fnum(agg.attend_s / n * 1e3, 2),
                fnum(total, 2),
                fnum(vanilla_total / total, 2),
            ]);
            rows.push(json::obj(vec![
                ("prompt", json::num(prompt as f64)),
                ("config", json::s(name)),
                ("approx_ms", json::num(agg.scores_s / n * 1e3)),
                ("topk_ms", json::num(agg.topk_s / n * 1e3)),
                ("attend_ms", json::num(agg.attend_s / n * 1e3)),
                ("total_ms", json::num(total)),
                ("speedup", json::num(vanilla_total / total)),
            ]));
        }
    }
    table.emit("fig7_attn_time");
    let out = json::arr(rows);
    super::write_json("fig7_attn_time", &out);
    println!(
        "(paper: ~40% faster at prompt 2048, ~45% at 3072; top-k stage\n\
         comparable to the small matmuls — the bottleneck they flag)"
    );
    Ok(out)
}

/// Fig 7 (right): join microbench attention time with LongBench-analog
/// accuracy per (k_f, d_f) — emitted from cached results of fig4 +
/// a timing sweep here.
pub fn run_tradeoff(quick: bool) -> Result<Json> {
    let batch = if quick { 4 } else { 16 };
    let prompt = 3500usize.min(3500);
    let shape = AttnShape::llama2_13b(batch, prompt + 16);
    let d = shape.head_dim;
    let mut rng = Xoshiro256::new(42);
    let q = rng.normal_vec(shape.lanes * d);
    let kc = rng.normal_vec(shape.lanes * shape.max_len * d);
    let vc = rng.normal_vec(shape.lanes * shape.max_len * d);
    let settings = [(0.125, 0.125), (0.125, 0.25), (0.125, 0.5),
                    (0.25, 0.125), (0.25, 0.25), (0.25, 0.5), (0.5, 0.25)];
    let mut table = Table::new(
        "Fig 7 (right): attention time per (k_f, d_f) at prompt 3500 — join with fig4 accuracy",
        &["k_f", "d_f", "attn ms", "modeled speedup"],
    );
    let mut rows = Vec::new();
    let reps = if quick { 3 } else { 8 };
    for (k_f, d_f) in settings {
        let mut total = 0.0;
        for _ in 0..reps {
            let b = step(shape, &q, &kc, &vc, prompt, k_f, d_f, false, TopKAlgo::Heap);
            total += b.total();
        }
        let ms = total / reps as f64 * 1e3;
        let model = crate::analysis::speedup::SpeedupModel { d_full: d, seq: prompt };
        table.row(vec![
            format!("{k_f}"),
            format!("{d_f}"),
            fnum(ms, 2),
            fnum(model.loki_speedup(d_f, k_f), 2),
        ]);
        rows.push(json::obj(vec![
            ("k_f", json::num(k_f)),
            ("d_f", json::num(d_f)),
            ("attn_ms", json::num(ms)),
        ]));
    }
    table.emit("fig7_tradeoff");
    let out = json::arr(rows);
    super::write_json("fig7_tradeoff", &out);
    Ok(out)
}
