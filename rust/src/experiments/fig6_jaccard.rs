//! Figure 6 (left): top-k agreement (Jaccard) between Loki's
//! reduced-dimensional ranking and the exact ranking.
//!
//! Uses the *real* key/query dumps from the trained model: for each
//! (layer, head) we rotate keys and queries into the calibrated PCA basis,
//! rank cache slots by d-component approximate scores vs full-D exact
//! scores, and measure the Jaccard similarity of the top-k sets across a
//! (k_f, d_f) grid — the paper's explanation for *why* Loki works.

use anyhow::Result;

use crate::analysis::KeyDump;
use crate::linalg::stats::jaccard;
use crate::linalg::topk::{top_k_indices, TopKAlgo};
use crate::util::artifacts_dir;
use crate::util::json::{self, Json};
use crate::util::table::{fnum, Table};

pub fn run(quick: bool) -> Result<Json> {
    let dir = artifacts_dir();
    let keys = KeyDump::load(&dir.join("keys_wiki.npz"), "k_post")?;
    let queries = KeyDump::load(&dir.join("keys_wiki.npz"), "q_post")?;
    let d = keys.dim;
    let k_fracs = [0.125, 0.25, 0.5];
    let d_fracs = [0.125, 0.25, 0.5, 1.0];
    let n_ctx = 256.min(keys.samples); // cache size per trial
    let n_queries = super::scale(quick, 32);

    let mut table = Table::new(
        "Fig 6 (left): Jaccard(top-k by approx, top-k exact), mean over layers/heads",
        &["k_f \\ d_f (jaccard (mass-recall))", "0.125", "0.25", "0.5", "1.0"],
    );
    let mut rows = Vec::new();
    for &kf in &k_fracs {
        let mut row = vec![format!("{kf}")];
        let mut obj = vec![("k_f", json::num(kf))];
        for &df in &d_fracs {
            let d_sub = ((d as f64 * df).round() as usize).max(1);
            let k_sel = ((n_ctx as f64 * kf).round() as usize).max(1);
            let mut sims = Vec::new();
            let mut mass = Vec::new();
            for l in 0..keys.layers {
                for h in 0..keys.heads {
                    let basis = keys.pca(l, h);
                    let kblock = keys.block(l, h);
                    let qblock = queries.block(l, h);
                    // Rotate the first n_ctx keys once.
                    let mut rot_keys = vec![0.0f32; n_ctx * d];
                    for (i, out_row) in rot_keys.chunks_exact_mut(d).enumerate() {
                        basis.rotate(&kblock[i * d..(i + 1) * d], out_row);
                    }
                    let mut qrot = vec![0.0f32; d];
                    for qi in 0..n_queries {
                        let q = &qblock[(n_ctx + qi) % queries.samples * d..][..d];
                        basis.rotate(q, &mut qrot);
                        let mut exact = vec![0.0f32; n_ctx];
                        let mut approx = vec![0.0f32; n_ctx];
                        for (j, krow) in rot_keys.chunks_exact(d).enumerate() {
                            let mut se = 0.0;
                            let mut sa = 0.0;
                            for c in 0..d {
                                let p = qrot[c] * krow[c];
                                se += p;
                                if c < d_sub {
                                    sa += p;
                                }
                            }
                            exact[j] = se;
                            approx[j] = sa;
                        }
                        let te = top_k_indices(TopKAlgo::Sort, &exact, k_sel);
                        let ta = top_k_indices(TopKAlgo::Sort, &approx, k_sel);
                        sims.push(jaccard(&te, &ta));
                        // Attention-mass recall: how much of the true
                        // softmax mass the approximate selection captures
                        // (ties in byte-level scores make set-Jaccard
                        // pessimistic; mass recall is what quality sees).
                        let scale = 1.0 / (d as f32).sqrt();
                        let mut probs: Vec<f32> = exact.iter().map(|&x| x * scale).collect();
                        crate::linalg::softmax::softmax_inplace(&mut probs);
                        let covered: f32 = ta.iter().map(|&i| probs[i as usize]).sum();
                        mass.push(covered as f64);
                    }
                }
            }
            let mean = sims.iter().sum::<f64>() / sims.len() as f64;
            let mean_mass = mass.iter().sum::<f64>() / mass.len() as f64;
            row.push(format!("{} ({})", fnum(mean, 2), fnum(mean_mass, 2)));
            obj.push((
                Box::leak(format!("d_{df}").into_boxed_str()) as &str,
                json::num(mean),
            ));
        }
        table.row(row);
        rows.push(json::obj(obj));
    }
    table.emit("fig6_jaccard");
    let out = json::arr(rows);
    super::write_json("fig6_jaccard", &out);
    println!(
        "(paper: ≈0.9 at the evaluated settings k_f=0.25/d_f=0.25 and k_f=0.125/d_f=0.5;\n\
         d_f = 1.0 column must be exactly 1.0 — exactness sanity check)"
    );
    Ok(out)
}
