//! Figure 1 (left): Rank@90 of attention keys across models.
//!
//! The paper shows that across Llama/Mistral/Mixtral-class models the
//! mean Rank@90 sits far below the head dimension. Our model family
//! (trained from scratch at different widths/depths) plays that role; the
//! `loki-random` entry is our added *untrained control* — its keys should
//! sit near full rank, evidencing that training induces the structure.

use anyhow::Result;

use crate::analysis::rank::rank_table;
use crate::analysis::KeyDump;
use crate::util::json::{self, Json};
use crate::util::table::{fnum, Table};
use crate::util::{artifacts_dir, json::Json as J};

pub fn run(v_pct: f64) -> Result<Json> {
    let dir = artifacts_dir();
    let manifest = crate::runtime::Manifest::load(&dir)?;
    let mut models: Vec<String> = manifest.family_models.clone();
    models.insert(0, manifest.model.name.clone());

    let mut table = Table::new(
        &format!("Fig 1 (left): mean Rank@{v_pct:.0} across models (full dim = last column)"),
        &["model", "pre-rotary", "post-rotary", "D", "pre/D", "post/D"],
    );
    let mut rows = Vec::new();
    for name in &models {
        // Main model's dump lives in keys_wiki.npz; family models in
        // family_<name>.npz.
        let path = if *name == manifest.model.name {
            dir.join("keys_wiki.npz")
        } else {
            dir.join(format!("family_{name}.npz"))
        };
        if !path.exists() {
            eprintln!("skipping {name}: {} missing", path.display());
            continue;
        }
        let pre = KeyDump::load(&path, "k_pre")?;
        let post = KeyDump::load(&path, "k_post")?;
        let rp = rank_table(&pre.pca_all(), v_pct).model_mean();
        let ro = rank_table(&post.pca_all(), v_pct).model_mean();
        let d = pre.dim as f64;
        table.row(vec![
            name.clone(),
            fnum(rp, 1),
            fnum(ro, 1),
            format!("{}", pre.dim),
            fnum(rp / d, 2),
            fnum(ro / d, 2),
        ]);
        rows.push(json::obj(vec![
            ("model", json::s(name)),
            ("rank_pre", json::num(rp)),
            ("rank_post", json::num(ro)),
            ("dim", json::num(d)),
        ]));
    }
    table.emit("fig1_rank_models");
    let out: J = json::arr(rows);
    super::write_json("fig1_rank_models", &out);
    Ok(out)
}
