//! Appendix E / Table 5: PCAAttn (softmax directly over reduced-dim
//! scores, no top-k rescue) collapses while Exact-TopK and H2O hold —
//! the negative result motivating Loki's two-stage design.

use anyhow::Result;

use crate::data::tasks::{ShortTaskKind, TaskSuite};
use crate::data::EvalDocs;
use crate::eval::{perplexity, score_choices_batch, VariantSpec};
use crate::runtime::RuntimeStack;
use crate::util::artifacts_dir;
use crate::util::json::{self, Json};
use crate::util::table::{fnum, Table};

pub fn run(stack: &RuntimeStack, quick: bool) -> Result<Json> {
    let docs = EvalDocs::load(&artifacts_dir(), "wiki")?;
    let docs: Vec<Vec<i32>> = docs.docs.into_iter().take(super::scale(quick, 8)).collect();
    let max_tokens = if quick { 120 } else { 400 };
    let items = super::scale(quick, 16);
    let suite = TaskSuite::load(&artifacts_dir())?;
    let tok = suite.tokenizer();
    let pca = stack.manifest.default_pca.clone();

    let settings = vec![
        ("Full Attention", VariantSpec::Full),
        ("Exact TopK k=.5", VariantSpec::TopK { k_f: 0.5 }),
        ("H2O k=.5", VariantSpec::H2o { k_f: 0.5 }),
        ("PCAAttn d=.5", VariantSpec::PcaAttn { d_f: 0.5 }),
        ("Exact TopK k=.25", VariantSpec::TopK { k_f: 0.25 }),
        ("H2O k=.25", VariantSpec::H2o { k_f: 0.25 }),
        ("PCAAttn d=.25", VariantSpec::PcaAttn { d_f: 0.25 }),
    ];
    let mut table = Table::new(
        "Table 5: PCAAttn vs baselines (ppl + mean short-task accuracy)",
        &["method", "ppl", "task acc"],
    );
    let mut rows = Vec::new();
    for (name, spec) in settings {
        let ppl = perplexity(stack, &pca, &spec, &docs, 16, max_tokens)?.perplexity();
        let mut total = 0.0;
        let mut n = 0;
        for kind in ShortTaskKind::all() {
            for t in suite.short_tasks(kind, items, 9) {
                let prompt = tok.encode(&t.prompt);
                let choices: Vec<Vec<i32>> = t.choices.iter().map(|c| tok.encode(c)).collect();
                if score_choices_batch(stack, &pca, &spec, &prompt, &choices, t.correct)?
                    .is_correct()
                {
                    total += 1.0;
                }
                n += 1;
            }
        }
        let acc = total / n as f64;
        table.row(vec![name.to_string(), fnum(ppl, 4), fnum(acc, 3)]);
        rows.push(json::obj(vec![
            ("method", json::s(name)),
            ("ppl", json::num(ppl)),
            ("acc", json::num(acc)),
        ]));
        println!("  {name}: ppl {ppl:.4} acc {acc:.3}");
    }
    table.emit("table5_pcaattn");
    let out = json::arr(rows);
    super::write_json("table5_pcaattn", &out);
    println!(
        "(paper: PCAAttn perplexity explodes (38→933 at d=.5/.25) — ours should blow up too)"
    );
    Ok(out)
}
