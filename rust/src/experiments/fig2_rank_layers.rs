//! Figures 2 / 8–13: layer-wise Rank@90, eigen-spectra, head×layer
//! heatmaps, and query/value dimensionality — the full §3 + Appendix A
//! analysis, recomputed with the Rust PCA over the exported key dumps.

use anyhow::Result;

use crate::analysis::rank::rank_table;
use crate::analysis::KeyDump;
use crate::util::artifacts_dir;
use crate::util::json::{self, Json};
use crate::util::table::{fnum, Table};

/// Fig 2 / App Fig 8: per-layer Rank@v for pre/post-rotary keys × corpora.
pub fn run_layers(v_pct: f64) -> Result<Json> {
    let dir = artifacts_dir();
    let profiles = ["wiki", "web", "book"];
    let mut table = Table::new(
        &format!("Fig 2: per-layer Rank@{v_pct:.0} (head-mean), pre/post rotary × corpus"),
        &["layer", "wiki pre", "wiki post", "web pre", "web post", "book pre", "book post"],
    );
    let mut stats = Vec::new();
    for prof in profiles {
        let path = dir.join(format!("keys_{prof}.npz"));
        let pre = KeyDump::load(&path, "k_pre")?;
        let post = KeyDump::load(&path, "k_post")?;
        stats.push((rank_table(&pre.pca_all(), v_pct), rank_table(&post.pca_all(), v_pct)));
    }
    let layers = stats[0].0.per_layer.len();
    let mut rows = Vec::new();
    for l in 0..layers {
        let mut row = vec![format!("{l}")];
        let mut obj = vec![("layer", json::num(l as f64))];
        for (i, prof) in profiles.iter().enumerate() {
            row.push(fnum(stats[i].0.per_layer[l], 1));
            row.push(fnum(stats[i].1.per_layer[l], 1));
            let pre_key = Box::leak(format!("{prof}_pre").into_boxed_str());
            obj.push((pre_key, json::num(stats[i].0.per_layer[l])));
            let post_key = Box::leak(format!("{prof}_post").into_boxed_str());
            obj.push((post_key, json::num(stats[i].1.per_layer[l])));
        }
        table.row(row);
        rows.push(json::obj(obj));
    }
    table.emit("fig2_rank_layers");
    let out = json::arr(rows);
    super::write_json("fig2_rank_layers", &out);

    // Consistency check the paper emphasises: per-layer profiles agree
    // across calibration corpora.
    let mut max_spread = 0.0f64;
    for l in 0..layers {
        let vals: Vec<f64> = stats.iter().map(|(p, _)| p.per_layer[l]).collect();
        let spread = vals.iter().cloned().fold(f64::MIN, f64::max)
            - vals.iter().cloned().fold(f64::MAX, f64::min);
        max_spread = max_spread.max(spread);
    }
    println!("max cross-corpus spread of per-layer rank: {max_spread:.1} (consistency claim)");
    Ok(out)
}

/// App Fig 9: normalized eigen-spectra for a few (layer, head) pairs.
pub fn run_spectra() -> Result<Json> {
    let dir = artifacts_dir();
    let dump = KeyDump::load(&dir.join("keys_wiki.npz"), "k_post")?;
    let picks = [(0usize, 0usize), (dump.layers - 1, dump.heads - 1)];
    let mut rows = Vec::new();
    let mut table = Table::new(
        "Fig 9: normalized eigenvalues (first 12 components)",
        &["layer,head", "spectrum (λ1..λ12)", "Rank@90"],
    );
    for (l, h) in picks {
        let basis = dump.pca(l, h);
        let spec: Vec<String> =
            basis.eigenvalues.iter().take(12).map(|e| format!("{e:.3}")).collect();
        table.row(vec![
            format!("L{l},H{h}"),
            spec.join(" "),
            format!("{}", basis.rank_at(90.0)),
        ]);
        rows.push(json::obj(vec![
            ("layer", json::num(l as f64)),
            ("head", json::num(h as f64)),
            ("eigenvalues", json::arr(basis.eigenvalues.iter().map(|&e| json::num(e as f64)))),
        ]));
    }
    table.emit("fig9_spectra");
    let out = json::arr(rows);
    super::write_json("fig9_spectra", &out);
    Ok(out)
}

/// App Figs 10/11: head × layer Rank@90 heatmap (pre and post rotary).
pub fn run_heatmap(v_pct: f64) -> Result<Json> {
    let dir = artifacts_dir();
    let mut objs = Vec::new();
    for kind in ["k_pre", "k_post"] {
        let dump = KeyDump::load(&dir.join("keys_wiki.npz"), kind)?;
        let stats = rank_table(&dump.pca_all(), v_pct);
        let mut headers = vec!["layer".to_string()];
        headers.extend((0..dump.heads).map(|h| format!("head {h}")));
        let mut table = Table::new(
            &format!("Fig 10/11: Rank@{v_pct:.0} heatmap ({kind})"),
            &headers.iter().map(|s| s.as_str()).collect::<Vec<_>>(),
        );
        for (l, row) in stats.per_head.iter().enumerate() {
            let mut cells = vec![format!("{l}")];
            cells.extend(row.iter().map(|r| format!("{r}")));
            table.row(cells);
        }
        table.emit(&format!("fig10_heatmap_{kind}"));
        objs.push(json::obj(vec![
            ("kind", json::s(kind)),
            (
                "ranks",
                json::arr(stats.per_head.iter().map(|row| {
                    json::arr(row.iter().map(|&r| json::num(r as f64)))
                })),
            ),
        ]));
    }
    let out = json::arr(objs);
    super::write_json("fig10_heatmap", &out);
    Ok(out)
}

/// App Figs 12/13: query and value dimensionality (queries low, values
/// near-full — the asymmetry the paper reports).
pub fn run_qv(v_pct: f64) -> Result<Json> {
    let dir = artifacts_dir();
    let mut table = Table::new(
        &format!("Fig 12/13: Rank@{v_pct:.0} of Q and V per layer (wiki)"),
        &["layer", "q_post", "v", "k_post (ref)"],
    );
    let q = KeyDump::load(&dir.join("keys_wiki.npz"), "q_post")?;
    let v = KeyDump::load(&dir.join("keys_wiki.npz"), "v")?;
    let k = KeyDump::load(&dir.join("keys_wiki.npz"), "k_post")?;
    let rq = rank_table(&q.pca_all(), v_pct);
    let rv = rank_table(&v.pca_all(), v_pct);
    let rk = rank_table(&k.pca_all(), v_pct);
    let mut rows = Vec::new();
    for l in 0..rq.per_layer.len() {
        table.row(vec![
            format!("{l}"),
            fnum(rq.per_layer[l], 1),
            fnum(rv.per_layer[l], 1),
            fnum(rk.per_layer[l], 1),
        ]);
        rows.push(json::obj(vec![
            ("layer", json::num(l as f64)),
            ("q", json::num(rq.per_layer[l])),
            ("v", json::num(rv.per_layer[l])),
            ("k", json::num(rk.per_layer[l])),
        ]));
    }
    table.row(vec![
        "mean".into(),
        fnum(rq.model_mean(), 1),
        fnum(rv.model_mean(), 1),
        fnum(rk.model_mean(), 1),
    ]);
    table.emit("fig12_qv_ranks");
    let out = json::arr(rows);
    super::write_json("fig12_qv_ranks", &out);
    Ok(out)
}
