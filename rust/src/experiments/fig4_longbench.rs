//! Figure 4: long-context (LongBench-analog) task accuracy for Loki
//! configurations vs full attention.

use anyhow::Result;

use crate::data::tasks::{LongTaskKind, TaskSuite};
use crate::eval::{score_choices_batch, VariantSpec};
use crate::runtime::RuntimeStack;
use crate::util::artifacts_dir;
use crate::util::json::{self, Json};
use crate::util::table::{fnum, Table};

pub fn run(stack: &RuntimeStack, quick: bool) -> Result<Json> {
    let suite = TaskSuite::load(&artifacts_dir())?;
    let tok = suite.tokenizer();
    // Target length just under the 512-token prefill bucket so the needle
    // never falls off the clamped prompt.
    let target_len = 470usize;
    let items = super::scale(quick, 16);
    let pca = stack.manifest.default_pca.clone();

    let specs = vec![
        ("full", VariantSpec::Full),
        ("loki k=.25 d=.25", VariantSpec::Loki { k_f: 0.25, d_f: 0.25 }),
        ("loki k=.125 d=.5", VariantSpec::Loki { k_f: 0.125, d_f: 0.5 }),
        ("loki k=.125 d=.25", VariantSpec::Loki { k_f: 0.125, d_f: 0.25 }),
    ];

    let mut headers = vec!["task".to_string()];
    headers.extend(specs.iter().map(|(n, _)| n.to_string()));
    let mut table = Table::new(
        "Fig 4: long-context tasks — accuracy (agreement-with-full)",
        &headers.iter().map(|s| s.as_str()).collect::<Vec<_>>(),
    );
    let mut rows = Vec::new();
    let mut col_sums = vec![0.0f64; specs.len()];
    for kind in LongTaskKind::all() {
        let tasks = suite.long_tasks(kind, items, target_len, 11);
        let mut cells = vec![kind.name().to_string()];
        let mut obj = vec![("task", json::s(kind.name()))];
        let mut full_preds: Vec<usize> = Vec::new();
        for (si, (name, spec)) in specs.iter().enumerate() {
            let mut correct = 0usize;
            let mut preds = Vec::with_capacity(tasks.len());
            for t in &tasks {
                let prompt = tok.encode(&t.prompt);
                let choices: Vec<Vec<i32>> = t.choices.iter().map(|c| tok.encode(c)).collect();
                let out = score_choices_batch(stack, &pca, spec, &prompt, &choices, t.correct)?;
                if out.is_correct() {
                    correct += 1;
                }
                preds.push(out.predicted);
            }
            if si == 0 {
                full_preds = preds.clone();
            }
            let agree = preds.iter().zip(&full_preds).filter(|(a, b)| a == b).count()
                as f64
                / tasks.len() as f64;
            let acc = correct as f64 / tasks.len() as f64;
            col_sums[si] += acc;
            cells.push(format!("{} ({})", fnum(acc, 2), fnum(agree, 2)));
            obj.push((Box::leak(name.to_string().into_boxed_str()) as &str, json::num(acc)));
            obj.push((
                Box::leak(format!("{name}_agree").into_boxed_str()) as &str,
                json::num(agree),
            ));
        }
        println!("  {} done", kind.name());
        table.row(cells);
        rows.push(json::obj(obj));
    }
    let mut mean_cells = vec!["mean".to_string()];
    for s in &col_sums {
        mean_cells.push(fnum(s / LongTaskKind::all().len() as f64, 2));
    }
    table.row(mean_cells);
    table.emit("fig4_longbench");
    let out = json::arr(rows);
    super::write_json("fig4_longbench", &out);
    println!(
        "(paper: Loki ≈ full on few-shot/code-ish categories; QA-style\n\
         retrieval drops a few points — the same asymmetry should show)"
    );
    Ok(out)
}
