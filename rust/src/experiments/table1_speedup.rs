//! Table 1 / Eq. 5: theoretical speedups and memory savings per method,
//! validated against the byte movement the substrate kernels actually
//! perform.

use anyhow::Result;

use crate::analysis::speedup::{memory_saving, SpeedupModel};
use crate::attnsim::variants::{decode_attend, AttnVariant, VariantParams};
use crate::attnsim::AttnShape;
use crate::util::json::{self, Json};
use crate::util::rng::Xoshiro256;
use crate::util::table::{fnum, Table};

pub fn run() -> Result<Json> {
    let d = 128usize;
    let s = 3072usize;
    let model = SpeedupModel { d_full: d, seq: s };

    let mut table = Table::new(
        &format!("Table 1: method budgets and modeled speedups (D={d}, S={s})"),
        &[
            "method",
            "k_f",
            "d_f",
            "speedup (Eq.5)",
            "asymptote",
            "mem saving",
            "bytes vs full (measured)",
        ],
    );

    // Measure actual bytes moved by the substrate kernels.
    let shape = AttnShape { lanes: 8, head_dim: d, max_len: s };
    let mut rng = Xoshiro256::new(table1());
    let q = rng.normal_vec(shape.lanes * d);
    let kc = rng.normal_vec(shape.lanes * s * d);
    let vc = rng.normal_vec(shape.lanes * s * d);
    let stride = s * d;
    let measure = |variant: &AttnVariant, k_f: f64, d_f: f64| -> f64 {
        let params = VariantParams {
            k_sel: (k_f * s as f64) as usize,
            d_sub: (d_f * d as f64) as usize,
            ..Default::default()
        };
        let mut h2o_state = vec![vec![0.5f32; s]; shape.lanes];
        let h2o = matches!(variant, AttnVariant::H2O).then_some(&mut h2o_state);
        let out = decode_attend(variant, shape, &q, &kc, &vc, stride, s, &params, h2o);
        out.movement.cache_bytes_read as f64
    };
    let full_bytes = measure(&AttnVariant::Full, 1.0, 1.0);

    let rows_spec = vec![
        ("Exact Top-K", AttnVariant::ExactTopK, 0.25, 1.0, f64::NAN, f64::NAN),
        ("H2O", AttnVariant::H2O, 0.25, 1.0, 1.0 / 0.25, 4.0),
        (
            "Loki (A)",
            AttnVariant::Loki,
            0.25,
            0.25,
            SpeedupModel::loki_speedup_asymptote(0.25, 0.25),
            1.0,
        ),
        (
            "Loki (B)",
            AttnVariant::Loki,
            0.125,
            0.5,
            SpeedupModel::loki_speedup_asymptote(0.5, 0.125),
            1.0,
        ),
    ];
    let mut rows = Vec::new();
    for (name, variant, k_f, d_f, asym, _mem) in rows_spec {
        let modeled = match variant {
            AttnVariant::Loki => model.vanilla_cost() / model.loki_cost(d_f, k_f),
            AttnVariant::ExactTopK => model.vanilla_cost() / model.exact_topk_cost(k_f),
            AttnVariant::H2O => model.vanilla_cost() / model.h2o_cost(k_f),
            _ => 1.0,
        };
        let bytes = measure(&variant, k_f, d_f);
        let key = match variant {
            AttnVariant::H2O => "h2o",
            _ => "other",
        };
        table.row(vec![
            name.to_string(),
            fnum(k_f, 3),
            if matches!(variant, AttnVariant::Loki) { fnum(d_f, 3) } else { "full".into() },
            fnum(modeled, 2),
            fnum(asym, 2),
            fnum(memory_saving(key, k_f), 1),
            fnum(bytes / full_bytes, 3),
        ]);
        rows.push(json::obj(vec![
            ("method", json::s(name)),
            ("k_f", json::num(k_f)),
            ("d_f", json::num(d_f)),
            ("speedup_modeled", json::num(modeled)),
            ("bytes_frac_vs_full", json::num(bytes / full_bytes)),
        ]));
    }
    table.emit("table1_speedup");
    let out = json::arr(rows);
    super::write_json("table1_speedup", &out);
    println!(
        "(Eq.5 check: Loki byte fraction should approach d_f/2 + k_f = {:.3} for (0.25, 0.25))",
        0.25 / 2.0 + 0.25
    );
    Ok(out)
}

#[allow(non_snake_case)]
fn table1() -> u64 {
    0x7AB1E
}
