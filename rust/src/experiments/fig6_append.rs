//! Figure 6 (right): where decode time actually goes — the KV-cache
//! *append* dominates a HuggingFace-style stack regardless of attention
//! variant.
//!
//! Two measurements:
//!  1. Substrate: decode steps at Llama2-13B shape with a reallocating
//!     (`torch.cat`-style) cache vs a preallocated in-place cache, broken
//!     into append vs attention time — the Fig-6-right bars.
//!  2. Compiled path: the runtime's own decode-step stats (our serving
//!     stack appends in place inside the graph; reported for contrast).

use anyhow::Result;

use crate::attnsim::cache::{AppendPolicy, KvCache};
use crate::attnsim::variants::{decode_attend, AttnVariant, VariantParams};
use crate::attnsim::AttnShape;
use crate::util::json::{self, Json};
use crate::util::rng::Xoshiro256;
use crate::util::table::{fnum, Table};

#[allow(clippy::disallowed_methods)] // genuine wall measurement: figure regen times real kernels
pub fn run(quick: bool) -> Result<Json> {
    // Llama2-13B per-layer shape (H=40, D=128), paper's microbench setup:
    // prompt 3072, +gen steps, batch scaled down on quick runs.
    let batch = if quick { 2 } else { 8 };
    let gen = if quick { 16 } else { 64 };
    let prompt = 3072usize;
    let shape = AttnShape::llama2_13b(batch, prompt + gen + 1);
    let d = shape.head_dim;
    let mut rng = Xoshiro256::new(6);

    let mut rows = Vec::new();
    let mut table = Table::new(
        "Fig 6 (right): per-step decode time (ms), append vs attention",
        &["cache policy", "variant", "append ms", "attn ms", "append %"],
    );
    for policy in [AppendPolicy::Realloc, AppendPolicy::InPlace] {
        for (vname, variant) in [
            ("vanilla", AttnVariant::Full),
            ("loki 0.25/0.25", AttnVariant::Loki),
        ] {
            let mut kcache = KvCache::new(shape, policy);
            let mut vcache = KvCache::new(shape, policy);
            let prefix = rng.normal_vec(shape.lanes * prompt * d);
            kcache.load_prefix(&prefix, prompt);
            vcache.load_prefix(&prefix, prompt);
            let params = VariantParams {
                k_sel: (0.25 * prompt as f64) as usize,
                d_sub: d / 4,
                ..Default::default()
            };
            let mut t_append = 0.0f64;
            let mut t_attn = 0.0f64;
            let new_rows = rng.normal_vec(shape.lanes * d);
            let q = rng.normal_vec(shape.lanes * d);
            for _ in 0..gen {
                let t0 = std::time::Instant::now();
                kcache.append(&new_rows);
                vcache.append(&new_rows);
                t_append += t0.elapsed().as_secs_f64();
                let t1 = std::time::Instant::now();
                let _ = decode_attend(
                    &variant,
                    shape,
                    &q,
                    kcache.data(),
                    vcache.data(),
                    kcache.lane_stride(),
                    kcache.len(),
                    &params,
                    None,
                );
                t_attn += t1.elapsed().as_secs_f64();
            }
            let per_append = t_append / gen as f64 * 1e3;
            let per_attn = t_attn / gen as f64 * 1e3;
            let pct = 100.0 * per_append / (per_append + per_attn);
            let pname = match policy {
                AppendPolicy::Realloc => "realloc (HF torch.cat)",
                AppendPolicy::InPlace => "in-place (serving)",
                // Paged append cost is in-place cost by construction; its
                // residency story is benched by `cargo bench kvpool_bench`.
                AppendPolicy::Paged { .. } => "paged (kvpool)",
            };
            table.row(vec![
                pname.to_string(),
                vname.to_string(),
                fnum(per_append, 2),
                fnum(per_attn, 2),
                fnum(pct, 1),
            ]);
            rows.push(json::obj(vec![
                ("policy", json::s(pname)),
                ("variant", json::s(vname)),
                ("append_ms", json::num(per_append)),
                ("attn_ms", json::num(per_attn)),
                ("append_pct", json::num(pct)),
            ]));
        }
    }
    table.emit("fig6_append");
    let out = json::arr(rows);
    super::write_json("fig6_append", &out);
    println!(
        "(paper: >80% of HF decode time is the cache append, shared by both\n\
         variants — which is why Fig. 7 isolates attention-only time)"
    );
    Ok(out)
}
