//! `repro-experiments roofline` — TPU roofline estimates for the L1
//! kernel plan at paper shapes (DESIGN.md §Perf: real-TPU performance is
//! estimated from VMEM footprint + bytes streamed, since CPU-interpret
//! timing is not a TPU proxy).

use anyhow::Result;

use crate::analysis::roofline::{KernelPlan, TpuModel};
use crate::util::json::{self, Json};
use crate::util::table::{fnum, Table};

pub fn run() -> Result<Json> {
    let tpu = TpuModel::default();
    let mut table = Table::new(
        "TPU-v4 roofline estimates, Llama2-13B decode attention (batch 16)",
        &[
            "config",
            "S",
            "VMEM/step KiB",
            "HBM MB/step",
            "AI flop/B",
            "t_bw µs",
            "t_mxu µs",
            "speedup vs vanilla",
        ],
    );
    let mut rows = Vec::new();
    for s in [2048usize, 3072, 4096] {
        let vanilla = KernelPlan::paper_13b(16, s, 1.0, 1.0);
        let tv = vanilla.estimate(&tpu).t_bandwidth;
        for (name, k_f, d_f) in [("vanilla", 1.0, 1.0), ("loki .25/.25", 0.25, 0.25),
                                 ("loki .125/.5", 0.125, 0.5)] {
            let plan = KernelPlan::paper_13b(16, s, k_f, d_f);
            let est = plan.estimate(&tpu);
            table.row(vec![
                name.to_string(),
                format!("{s}"),
                fnum(est.vmem_per_step as f64 / 1024.0, 1),
                fnum(est.hbm_bytes as f64 / 1e6, 2),
                fnum(est.arithmetic_intensity, 2),
                fnum(est.t_bandwidth * 1e6, 1),
                fnum(est.t_compute * 1e6, 2),
                fnum(tv / est.t_bandwidth, 2),
            ]);
            rows.push(json::obj(vec![
                ("config", json::s(name)),
                ("seq", json::num(s as f64)),
                ("hbm_bytes", json::num(est.hbm_bytes as f64)),
                ("speedup", json::num(tv / est.t_bandwidth)),
            ]));
        }
    }
    table.emit("roofline");
    let out = json::arr(rows);
    super::write_json("roofline", &out);
    println!("(decode attention is bandwidth-bound: AI ~2 flop/B vs v4 balance ~229;\n\
        the bandwidth-time ratio IS the Eq.5 speedup — Loki's claim on real HW)");
    Ok(out)
}
