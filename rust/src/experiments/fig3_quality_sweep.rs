//! Figure 3 / Tables 3–4: perplexity and short-task accuracy across the
//! (k_f, d_f) grid, for pre- vs post-rotary PCA transforms.

use anyhow::Result;

use crate::data::tasks::{ShortTaskKind, TaskSuite};
use crate::data::EvalDocs;
use crate::eval::{perplexity, score_choices_batch, VariantSpec};
use crate::runtime::RuntimeStack;
use crate::util::artifacts_dir;
use crate::util::json::{self, Json};
use crate::util::table::{fnum, Table};

/// Per-item predictions for one task kind under a variant.
pub fn short_predictions(
    stack: &RuntimeStack,
    pca: &str,
    spec: &VariantSpec,
    kind: ShortTaskKind,
    items: usize,
    seed: u64,
) -> Result<(Vec<usize>, f64)> {
    let suite = TaskSuite::load(&artifacts_dir())?;
    let tok = suite.tokenizer();
    let tasks = suite.short_tasks(kind, items, seed);
    let mut preds = Vec::with_capacity(tasks.len());
    let mut correct = 0usize;
    for t in &tasks {
        let prompt = tok.encode(&t.prompt);
        let choices: Vec<Vec<i32>> = t.choices.iter().map(|c| tok.encode(c)).collect();
        let out = score_choices_batch(stack, pca, spec, &prompt, &choices, t.correct)?;
        if out.is_correct() {
            correct += 1;
        }
        preds.push(out.predicted);
    }
    Ok((preds, correct as f64 / tasks.len() as f64))
}

/// Mean short-task accuracy + per-kind predictions across the suite.
///
/// Besides raw accuracy we track **agreement with full attention**: the
/// fraction of items where the variant picks the same choice as the
/// unapproximated model. At this model scale raw task skill is near
/// chance (see EXPERIMENTS.md §Notes), so agreement is the sensitive
/// fidelity signal — it answers the paper's actual question ("does the
/// approximation change the model's behavior?") directly.
pub fn short_accuracy(
    stack: &RuntimeStack,
    pca: &str,
    spec: &VariantSpec,
    items_per_kind: usize,
    seed: u64,
) -> Result<(f64, Vec<Vec<usize>>)> {
    let mut accs = Vec::new();
    let mut preds = Vec::new();
    for kind in ShortTaskKind::all() {
        let (p, a) = short_predictions(stack, pca, spec, kind, items_per_kind, seed)?;
        accs.push(a);
        preds.push(p);
    }
    Ok((accs.iter().sum::<f64>() / accs.len() as f64, preds))
}

/// Fraction of identical predictions between two prediction sets.
pub fn agreement(a: &[Vec<usize>], b: &[Vec<usize>]) -> f64 {
    let total: usize = a.iter().map(|v| v.len()).sum();
    let same: usize = a
        .iter()
        .zip(b)
        .map(|(x, y)| x.iter().zip(y).filter(|(p, q)| p == q).count())
        .sum();
    same as f64 / total.max(1) as f64
}

pub fn run(stack: &RuntimeStack, quick: bool, full_grid: bool) -> Result<Json> {
    let docs = EvalDocs::load(&artifacts_dir(), "wiki")?;
    let docs: Vec<Vec<i32>> = docs.docs.into_iter().take(super::scale(quick, 8)).collect();
    let max_tokens = if quick { 120 } else { 400 };
    let items = super::scale(quick, 16);

    let grid: Vec<(f64, f64)> = if full_grid {
        // Tables 3/4: the full 3×3 grid.
        [0.5, 0.25, 0.125]
            .iter()
            .flat_map(|&k| [0.5, 0.25, 0.125].iter().map(move |&d| (k, d)))
            .collect()
    } else {
        // Fig 3's highlighted settings.
        vec![(0.5, 0.5), (0.25, 0.25), (0.25, 0.125), (0.125, 0.5), (0.125, 0.25)]
    };

    let mut table = Table::new(
        "Fig 3 / Tables 3-4: Loki quality across (k_f, d_f) and PCA transform",
        &["pca", "k_f", "d_f", "ppl", "Δppl", "task acc", "agree-vs-full"],
    );
    let mut rows = Vec::new();
    for pca in ["wiki_pre", "wiki_post"] {
        let full_rep = perplexity(stack, pca, &VariantSpec::Full, &docs, 16, max_tokens)?;
        let full_ppl = full_rep.perplexity();
        let (full_acc, full_preds) = short_accuracy(stack, pca, &VariantSpec::Full, items, 5)?;
        table.row(vec![
            pca.into(),
            "-".into(),
            "-".into(),
            fnum(full_ppl, 4),
            "-".into(),
            fnum(full_acc, 3),
            "1.000".into(),
        ]);
        for &(k_f, d_f) in &grid {
            let spec = VariantSpec::Loki { k_f, d_f };
            let ppl = perplexity(stack, pca, &spec, &docs, 16, max_tokens)?.perplexity();
            let (acc, preds) = short_accuracy(stack, pca, &spec, items, 5)?;
            let agree = agreement(&full_preds, &preds);
            table.row(vec![
                pca.into(),
                format!("{k_f}"),
                format!("{d_f}"),
                fnum(ppl, 4),
                fnum(ppl - full_ppl, 4),
                fnum(acc, 3),
                fnum(agree, 3),
            ]);
            rows.push(json::obj(vec![
                ("pca", json::s(pca)),
                ("k_f", json::num(k_f)),
                ("d_f", json::num(d_f)),
                ("ppl", json::num(ppl)),
                ("ppl_delta", json::num(ppl - full_ppl)),
                ("acc", json::num(acc)),
                ("agreement_vs_full", json::num(agree)),
            ]));
            println!("  [{pca}] k={k_f} d={d_f}: ppl {ppl:.4} acc {acc:.3} agree {agree:.3}");
        }
    }
    let id = if full_grid { "table3_sweep" } else { "fig3_quality_sweep" };
    table.emit(id);
    let out = json::arr(rows);
    super::write_json(id, &out);
    Ok(out)
}
