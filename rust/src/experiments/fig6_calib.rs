//! Figure 6 (middle): calibration-dataset generalizability — Loki quality
//! with PCA bases calibrated on each corpus (wiki/web/book), pre and post
//! rotary, evaluated on the wiki split.

use anyhow::Result;

use crate::data::EvalDocs;
use crate::eval::{perplexity, VariantSpec};
use crate::runtime::RuntimeStack;
use crate::util::artifacts_dir;
use crate::util::json::{self, Json};
use crate::util::table::{fnum, Table};

pub fn run(stack: &RuntimeStack, quick: bool) -> Result<Json> {
    let docs = EvalDocs::load(&artifacts_dir(), "wiki")?;
    let docs: Vec<Vec<i32>> = docs.docs.into_iter().take(super::scale(quick, 8)).collect();
    let max_tokens = if quick { 120 } else { 400 };
    let spec = VariantSpec::Loki { k_f: 0.25, d_f: 0.25 };

    let full = perplexity(stack, "wiki_post", &VariantSpec::Full, &docs, 16, max_tokens)?
        .perplexity();
    let mut table = Table::new(
        "Fig 6 (middle): Loki ppl by calibration corpus (k_f=0.25, d_f=0.25; \
         full ppl shown for reference)",
        &["calibration", "pre-rotary ppl", "post-rotary ppl"],
    );
    let mut rows = Vec::new();
    for corpus in &stack.manifest.calibration_datasets.clone() {
        let pre = perplexity(stack, &format!("{corpus}_pre"), &spec, &docs, 16, max_tokens)?
            .perplexity();
        let post = perplexity(stack, &format!("{corpus}_post"), &spec, &docs, 16, max_tokens)?
            .perplexity();
        table.row(vec![corpus.clone(), fnum(pre, 4), fnum(post, 4)]);
        rows.push(json::obj(vec![
            ("calibration", json::s(corpus)),
            ("ppl_pre", json::num(pre)),
            ("ppl_post", json::num(post)),
        ]));
        println!("  {corpus}: pre {pre:.4} post {post:.4}");
    }
    table.row(vec!["(full attention)".into(), fnum(full, 4), fnum(full, 4)]);
    table.emit("fig6_calib");
    let out = json::arr(rows);
    super::write_json("fig6_calib", &out);
    println!("(paper: performance is consistent across calibration datasets)");
    Ok(out)
}
