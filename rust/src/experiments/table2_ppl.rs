//! Table 2: perplexity of Full / Exact-TopK / H2O / Loki at k_f = 0.25
//! (+ d_f = 0.25 for Loki) on the wiki eval split.

use anyhow::Result;

use crate::data::EvalDocs;
use crate::eval::{perplexity, VariantSpec};
use crate::runtime::RuntimeStack;
use crate::util::artifacts_dir;
use crate::util::json::{self, Json};
use crate::util::table::{fnum, Table};

pub fn run(stack: &RuntimeStack, quick: bool) -> Result<Json> {
    let docs = EvalDocs::load(&artifacts_dir(), "wiki")?;
    let n_docs = super::scale(quick, docs.docs.len());
    let docs: Vec<Vec<i32>> = docs.docs.into_iter().take(n_docs).collect();
    let max_tokens = if quick { 160 } else { 620 };
    let pca = stack.manifest.default_pca.clone();

    let specs = vec![
        ("Full Attention", VariantSpec::Full),
        ("Exact-TopK", VariantSpec::TopK { k_f: 0.25 }),
        ("H2O", VariantSpec::H2o { k_f: 0.25 }),
        ("Loki", VariantSpec::Loki { k_f: 0.25, d_f: 0.25 }),
    ];
    let mut table = Table::new(
        "Table 2: perplexity (lower is better)",
        &["method", "k_f", "d_f", "ppl", "Δ vs full"],
    );
    let mut rows = Vec::new();
    let mut full = f64::NAN;
    for (name, spec) in specs {
        let rep = perplexity(stack, &pca, &spec, &docs, 16, max_tokens)?;
        let ppl = rep.perplexity();
        if name == "Full Attention" {
            full = ppl;
        }
        let (kf, df) = match &spec {
            VariantSpec::Full => ("-".to_string(), "-".to_string()),
            VariantSpec::TopK { k_f } | VariantSpec::H2o { k_f } => (format!("{k_f}"), "-".into()),
            VariantSpec::Loki { k_f, d_f } => (format!("{k_f}"), format!("{d_f}")),
            _ => ("-".into(), "-".into()),
        };
        table.row(vec![name.to_string(), kf, df, fnum(ppl, 4), fnum(ppl - full, 4)]);
        rows.push(json::obj(vec![
            ("method", json::s(name)),
            ("ppl", json::num(ppl)),
            ("delta_vs_full", json::num(ppl - full)),
            ("n_tokens", json::num(rep.n_tokens as f64)),
        ]));
    }
    table.emit("table2_ppl");
    let out = json::arr(rows);
    super::write_json("table2_ppl", &out);
    println!(
        "(paper: Loki within 0.1 of full — the accepted approximation\n\
         threshold — while H2O drifts ~0.2; ordering Full≈TopK≤Loki<H2O)"
    );
    Ok(out)
}
