//! `repro-experiments hlo-cost` — static op census / FLOP / traffic
//! comparison of the compiled decode graphs (the L2 §Perf evidence).

use anyhow::Result;

use crate::runtime::hlo_inspect::analyze_file;
use crate::util::artifacts_dir;
use crate::util::json::{self, Json};
use crate::util::table::{fnum, Table};

pub fn run() -> Result<Json> {
    let dir = artifacts_dir();
    let graphs = ["decode_full_b8", "decode_loki_b8", "decode_h2o_b8",
                  "decode_pcaattn_b8", "prefill_b8_p512", "inject_b8"];
    let mut table = Table::new(
        "HLO cost census per compiled graph",
        &["graph", "instrs", "dots", "whiles", "est MFLOP", "result MB", "top opcodes"],
    );
    let mut rows = Vec::new();
    for g in graphs {
        let path = dir.join(format!("{g}.hlo.txt"));
        if !path.exists() {
            continue;
        }
        let r = analyze_file(&path)?;
        let tops: Vec<String> = r.top_opcodes(4).iter().map(|(o, c)| format!("{o}:{c}")).collect();
        table.row(vec![
            g.to_string(),
            format!("{}", r.instr_count),
            format!("{}", r.dot_count),
            format!("{}", r.while_count),
            fnum(r.flops as f64 / 1e6, 1),
            fnum(r.result_bytes as f64 / 1e6, 1),
            tops.join(" "),
        ]);
        rows.push(json::obj(vec![
            ("graph", json::s(g)),
            ("instrs", json::num(r.instr_count as f64)),
            ("dots", json::num(r.dot_count as f64)),
            ("flops", json::num(r.flops as f64)),
        ]));
    }
    table.emit("hlo_cost");
    let out = json::arr(rows);
    super::write_json("hlo_cost", &out);
    Ok(out)
}
