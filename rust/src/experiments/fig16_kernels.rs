//! Appendix C / Figure 16: our 2-D-parallel kernel vs the SparQ-style
//! 1-D kernel for the Q·Kᵀ score stage, across batch sizes and cache
//! lengths — including non-power-of-2 lengths.
//!
//! Two measurements compose the figure:
//!
//!  * **Parallelism** (the paper's headline effect) — this host is
//!    single-core, so grid-shape effects are regenerated with the
//!    calibrated execution simulator (`linalg::parsim`, 64 virtual
//!    workers, measured MAC rate): SparQ's 1-D grid has only
//!    batch·heads schedulable units and starves the machine at batch 1;
//!    the 2-D grid tiles the sequence dimension and fills it.
//!  * **Data movement** (real wall-clock, valid on one core) — the
//!    dense-copy (PyTorch-style indexing) baseline vs in-place indexed
//!    access, the §4.3 temporaries argument.
//!
//! Shapes follow the paper: Llama2-7B attention (H=32, D=128), d_f = 0.25.

use anyhow::Result;

use crate::attnsim::kernels::{scores_dense_copy, scores_indexed, FeatureAccess, Par};
use crate::attnsim::AttnShape;
use crate::linalg::parsim::{
    calibrate_mac_rate, makespan, score_units_1d, score_units_2d, ParSimCfg,
};
use crate::util::bench::{bench, BenchConfig};
use crate::util::json::{self, Json};
use crate::util::rng::Xoshiro256;
use crate::util::table::{fnum, Table};

pub fn run(quick: bool) -> Result<Json> {
    let batches: &[usize] = if quick { &[1, 16] } else { &[1, 4, 16, 64] };
    let seqs: &[usize] = if quick { &[512, 2047] } else { &[512, 1024, 2047, 4096] };
    let heads = 32usize;
    let d = 128usize;
    let d_sub = 32usize; // d_f = 0.25
    let block = 128usize;

    // 108 virtual workers = A100 SM count (the machine the paper's Triton
    // kernels schedule onto); 0.5µs per-unit launch overhead.
    let sim = ParSimCfg {
        workers: 108,
        mac_per_sec: calibrate_mac_rate(),
        unit_overhead_s: 0.5e-6,
    };
    println!(
        "simulator: {} workers, {:.2} GMAC/s (calibrated), {:.1}µs/unit overhead",
        sim.workers,
        sim.mac_per_sec / 1e9,
        sim.unit_overhead_s * 1e6
    );

    let mut table = Table::new(
        "Fig 16: QKᵀ scoring — simulated grid time (ms) + measured copy overhead",
        &[
            "batch",
            "S",
            "2-D ms (sim)",
            "1-D ms (sim)",
            "1-D/2-D",
            "indexed ms (real)",
            "dense-copy ms (real)",
            "dense/indexed",
        ],
    );
    let cfg = if quick { BenchConfig::quick() } else { BenchConfig::default() };
    let mut rows = Vec::new();
    for &b in batches {
        for &s in seqs {
            let lanes = b * heads;
            // --- simulated parallel grid times --------------------------
            let t2d = makespan(&score_units_2d(lanes, s, d_sub, block), &sim);
            let t1d = makespan(&score_units_1d(lanes, s, d_sub), &sim);

            // --- measured single-core data movement ----------------------
            // (kept small enough to stay cache-honest but uses the real
            // kernels; dominated by the gather/copy traffic difference)
            let shape = AttnShape { lanes, head_dim: d, max_len: s };
            let mut rng = Xoshiro256::new((b * 131 + s) as u64);
            let q = rng.normal_vec(lanes * d);
            let kc = rng.normal_vec(lanes * s * d);
            let stride = s * d;
            let mut out = vec![0.0f32; lanes * s];
            let feat = FeatureAccess::Prefix(d_sub);
            let scale = 1.0 / (d as f32).sqrt();
            let t_indexed = bench(&format!("idx b{b} s{s}"), &cfg, || {
                scores_indexed(shape, &q, &kc, stride, s, &feat, scale, Par::Serial, Some(1),
                               std::hint::black_box(&mut out));
            })
            .median_secs();
            let t_dense = bench(&format!("dense b{b} s{s}"), &cfg, || {
                scores_dense_copy(shape, &q, &kc, stride, s, &feat, scale,
                                  std::hint::black_box(&mut out));
            })
            .median_secs();

            table.row(vec![
                format!("{b}"),
                format!("{s}"),
                fnum(t2d * 1e3, 3),
                fnum(t1d * 1e3, 3),
                fnum(t1d / t2d, 2),
                fnum(t_indexed * 1e3, 2),
                fnum(t_dense * 1e3, 2),
                fnum(t_dense / t_indexed, 2),
            ]);
            rows.push(json::obj(vec![
                ("batch", json::num(b as f64)),
                ("seq", json::num(s as f64)),
                ("t_2d_sim_s", json::num(t2d)),
                ("t_1d_sim_s", json::num(t1d)),
                ("ratio_1d_2d", json::num(t1d / t2d)),
                ("t_indexed_s", json::num(t_indexed)),
                ("t_dense_s", json::num(t_dense)),
            ]));
        }
    }
    table.emit("fig16_kernels");
    let out = json::arr(rows);
    super::write_json("fig16_kernels", &out);
    println!(
        "(paper: ~2.8x over SparQ at batch 1 / S 4096, gap closing as batch\n\
         grows; S=2047 exercises the non-power-of-2 case SparQ rejected)"
    );
    Ok(out)
}
