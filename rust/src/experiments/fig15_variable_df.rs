//! Appendix B.2 / Figure 15: per-layer variable d_f policy — set each
//! layer's component count from its explained-variance threshold and
//! compare against the fixed-d_f policy at matched compression.

use anyhow::Result;

use crate::analysis::KeyDump;
use crate::data::EvalDocs;
use crate::eval::{perplexity, VariantSpec};
use crate::runtime::RuntimeStack;
use crate::util::artifacts_dir;
use crate::util::json::{self, Json};
use crate::util::table::{fnum, Table};

pub fn run(stack: &RuntimeStack, quick: bool) -> Result<Json> {
    let docs = EvalDocs::load(&artifacts_dir(), "wiki")?;
    let docs: Vec<Vec<i32>> = docs.docs.into_iter().take(super::scale(quick, 8)).collect();
    let max_tokens = if quick { 120 } else { 400 };
    let man = stack.manifest.clone();
    let d = man.model.head_dim;
    let k_f = 0.25;

    // Per-layer rank at several explained-variance thresholds (head-mean),
    // computed from the post-rotary key dump.
    let dump = KeyDump::load(&artifacts_dir().join("keys_wiki.npz"), "k_post")?;
    let bases = dump.pca_all();

    let mut table = Table::new(
        "Fig 15: fixed vs variable per-layer d_f (k_f = 0.25)",
        &["policy", "per-layer d", "compression d̄/D", "ppl", "Δ vs full"],
    );
    let full = perplexity(stack, &man.default_pca, &VariantSpec::Full, &docs, 16, max_tokens)?
        .perplexity();
    let mut rows = Vec::new();

    // Fixed policies.
    for d_f in [0.5, 0.25, 0.125] {
        let spec = VariantSpec::Loki { k_f, d_f };
        let ppl = perplexity(stack, &man.default_pca, &spec, &docs, 16, max_tokens)?.perplexity();
        table.row(vec![
            format!("fixed d_f={d_f}"),
            format!("{}", (d as f64 * d_f) as usize),
            fnum(d_f, 3),
            fnum(ppl, 4),
            fnum(ppl - full, 4),
        ]);
        rows.push(json::obj(vec![
            ("policy", json::s(&format!("fixed_{d_f}"))),
            ("compression", json::num(d_f)),
            ("ppl", json::num(ppl)),
        ]));
    }
    // Variable policies from explained-variance thresholds (paper: 0.5–0.8).
    for v_pct in [50.0, 65.0, 80.0] {
        let d_per_layer: Vec<usize> = bases
            .iter()
            .map(|row| {
                let mean: f64 = row.iter().map(|b| b.rank_at(v_pct) as f64).sum::<f64>()
                    / row.len() as f64;
                (mean.round() as usize).clamp(1, d)
            })
            .collect();
        let compression =
            d_per_layer.iter().sum::<usize>() as f64 / (d_per_layer.len() * d) as f64;
        let spec = VariantSpec::LokiVariable { k_f, d_per_layer: d_per_layer.clone() };
        let ppl = perplexity(stack, &man.default_pca, &spec, &docs, 16, max_tokens)?.perplexity();
        table.row(vec![
            format!("var @{v_pct:.0}% evar"),
            format!("{d_per_layer:?}"),
            fnum(compression, 3),
            fnum(ppl, 4),
            fnum(ppl - full, 4),
        ]);
        rows.push(json::obj(vec![
            ("policy", json::s(&format!("variable_{v_pct}"))),
            ("compression", json::num(compression)),
            ("ppl", json::num(ppl)),
            ("d_per_layer", json::arr(d_per_layer.iter().map(|&x| json::num(x as f64)))),
        ]));
        println!("  variable @{v_pct}%: d={d_per_layer:?} ppl {ppl:.4}");
    }
    table.emit("fig15_variable_df");
    let out = json::arr(rows);
    super::write_json("fig15_variable_df", &out);
    println!("(paper: variable d_f does not significantly beat fixed — same verdict expected)");
    Ok(out)
}
