//! Minimal JSON parser + writer (offline crate set has no `serde`).
//!
//! Supports the full JSON grammar minus exotic number forms; numbers are
//! held as `f64` (manifest values fit comfortably). Used to read
//! `artifacts/manifest.json` / `tasks.json` and to write `results/*.json`.

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(src: &str) -> Result<Json, JsonError> {
        let mut p = Parser { bytes: src.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    pub fn parse_file(path: &std::path::Path) -> Result<Json, JsonError> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| JsonError { msg: format!("{}: {e}", path.display()), pos: 0 })?;
        Self::parse(&text)
    }

    // -- typed accessors ---------------------------------------------------

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    pub fn as_i64(&self) -> Option<i64> {
        self.as_f64().map(|n| n as i64)
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// `obj["a"]["b"]` convenience: get nested key or panic with context.
    pub fn req(&self, key: &str) -> &Json {
        self.get(key)
            .unwrap_or_else(|| panic!("missing JSON key {key:?} in {self:.60?}"))
    }

    // -- writer --------------------------------------------------------------

    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 9e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(v) => {
                out.push('[');
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    x.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(out, k);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

/// Builder helpers so experiment code stays readable.
pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
    Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

pub fn arr<I: IntoIterator<Item = Json>>(items: I) -> Json {
    Json::Arr(items.into_iter().collect())
}

pub fn num(n: f64) -> Json {
    Json::Num(n)
}

pub fn s(v: &str) -> Json {
    Json::Str(v.to_string())
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

#[derive(Debug)]
pub struct JsonError {
    pub msg: String,
    pub pos: usize,
}

impl std::fmt::Display for JsonError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "JSON error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { msg: msg.to_string(), pos: self.pos }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let b = self.peek();
        if b.is_some() {
            self.pos += 1;
        }
        b
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), JsonError> {
        if self.bump() == Some(b) {
            Ok(())
        } else {
            Err(self.err(&format!("expected {:?}", b as char)))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, JsonError> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected {word}")))
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(
            self.peek(),
            Some(c) if c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-')
        )
        {
            self.pos += 1;
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.bump() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => return Ok(out),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => out.push('"'),
                    Some(b'\\') => out.push('\\'),
                    Some(b'/') => out.push('/'),
                    Some(b'b') => out.push('\u{8}'),
                    Some(b'f') => out.push('\u{c}'),
                    Some(b'n') => out.push('\n'),
                    Some(b'r') => out.push('\r'),
                    Some(b't') => out.push('\t'),
                    Some(b'u') => {
                        let mut code = 0u32;
                        for _ in 0..4 {
                            let c = self.bump().ok_or_else(|| self.err("bad \\u"))?;
                            code = code * 16
                                + (c as char).to_digit(16).ok_or_else(|| self.err("bad \\u"))?;
                        }
                        // Surrogate pairs: combine if a high surrogate.
                        if (0xD800..0xDC00).contains(&code) {
                            if self.bump() == Some(b'\\') && self.bump() == Some(b'u') {
                                let mut low = 0u32;
                                for _ in 0..4 {
                                    let c = self.bump().ok_or_else(|| self.err("bad \\u"))?;
                                    low = low * 16
                                        + (c as char)
                                            .to_digit(16)
                                            .ok_or_else(|| self.err("bad \\u"))?;
                                }
                                code = 0x10000 + ((code - 0xD800) << 10) + (low - 0xDC00);
                            } else {
                                return Err(self.err("lone surrogate"));
                            }
                        }
                        out.push(char::from_u32(code).unwrap_or('\u{FFFD}'));
                    }
                    _ => return Err(self.err("bad escape")),
                },
                // Raw UTF-8 passthrough: collect continuation bytes.
                Some(c) if c < 0x80 => out.push(c as char),
                Some(c) => {
                    let len = if c >= 0xF0 {
                        4
                    } else if c >= 0xE0 {
                        3
                    } else {
                        2
                    };
                    let start = self.pos - 1;
                    for _ in 1..len {
                        self.bump();
                    }
                    let chunk = &self.bytes[start..self.pos];
                    out.push_str(std::str::from_utf8(chunk).map_err(|_| self.err("bad utf8"))?);
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            items.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Arr(items)),
                _ => return Err(self.err("expected , or ]")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Obj(map)),
                _ => return Err(self.err("expected , or }")),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_basic() {
        let src = r#"{"a": [1, 2.5, -3], "b": {"c": true, "d": null}, "e": "hi\nthere"}"#;
        let v = Json::parse(src).unwrap();
        assert_eq!(v.req("a").as_arr().unwrap().len(), 3);
        assert_eq!(v.req("b").req("c").as_bool(), Some(true));
        assert_eq!(v.req("e").as_str(), Some("hi\nthere"));
        // Round trip through the writer.
        let v2 = Json::parse(&v.to_string()).unwrap();
        assert_eq!(v, v2);
    }

    #[test]
    fn parses_unicode_escapes() {
        let v = Json::parse(r#""é😀""#).unwrap();
        assert_eq!(v.as_str(), Some("é😀"));
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("{\"a\" 1}").is_err());
        assert!(Json::parse("12 34").is_err());
    }

    #[test]
    fn number_forms() {
        assert_eq!(Json::parse("-0.5e2").unwrap().as_f64(), Some(-50.0));
        assert_eq!(Json::parse("123").unwrap().as_i64(), Some(123));
    }
}
