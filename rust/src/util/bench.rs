//! Micro-benchmark harness (criterion is not in the offline crate set).
//!
//! Methodology: warmup runs until the timer stabilizes or `warmup_time`
//! elapses, then fixed-count measurement batches; reports min / median /
//! mean / p95 and median-absolute-deviation. Used by every `cargo bench`
//! target and by the experiment harnesses that need wall-clock numbers
//! (Figs. 6 right, 7, 16).

use std::time::{Duration, Instant};

#[derive(Clone, Debug)]
pub struct BenchConfig {
    pub warmup_iters: usize,
    pub min_iters: usize,
    pub max_iters: usize,
    pub target_time: Duration,
}

impl Default for BenchConfig {
    fn default() -> Self {
        Self {
            warmup_iters: 3,
            min_iters: 10,
            max_iters: 200,
            target_time: Duration::from_millis(900),
        }
    }
}

impl BenchConfig {
    /// Quick preset for slow end-to-end benches.
    pub fn quick() -> Self {
        Self {
            warmup_iters: 1,
            min_iters: 3,
            max_iters: 30,
            target_time: Duration::from_millis(600),
        }
    }
}

#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub min: Duration,
    pub median: Duration,
    pub mean: Duration,
    pub p95: Duration,
    /// Median absolute deviation — robust spread estimate.
    pub mad: Duration,
}

impl BenchResult {
    pub fn median_secs(&self) -> f64 {
        self.median.as_secs_f64()
    }

    pub fn summary(&self) -> String {
        format!(
            "{:<42} {:>12} median  {:>12} mean  {:>12} p95  ({} iters, mad {})",
            self.name,
            fmt_dur(self.median),
            fmt_dur(self.mean),
            fmt_dur(self.p95),
            self.iters,
            fmt_dur(self.mad),
        )
    }
}

pub fn fmt_dur(d: Duration) -> String {
    let ns = d.as_nanos();
    if ns < 1_000 {
        format!("{ns}ns")
    } else if ns < 1_000_000 {
        format!("{:.2}µs", ns as f64 / 1e3)
    } else if ns < 1_000_000_000 {
        format!("{:.2}ms", ns as f64 / 1e6)
    } else {
        format!("{:.3}s", ns as f64 / 1e9)
    }
}

/// Run `f` repeatedly, returning robust timing statistics. The closure
/// should perform one complete operation; use `std::hint::black_box` on
/// inputs/outputs to defeat const-folding.
#[allow(clippy::disallowed_methods)] // genuine wall measurement: this *is* the stopwatch
pub fn bench<F: FnMut()>(name: &str, cfg: &BenchConfig, mut f: F) -> BenchResult {
    for _ in 0..cfg.warmup_iters {
        f();
    }
    let mut samples: Vec<Duration> = Vec::with_capacity(cfg.max_iters);
    let start = Instant::now();
    while samples.len() < cfg.max_iters
        && (samples.len() < cfg.min_iters || start.elapsed() < cfg.target_time)
    {
        let t = Instant::now();
        f();
        samples.push(t.elapsed());
    }
    summarize(name, &mut samples)
}

fn summarize(name: &str, samples: &mut [Duration]) -> BenchResult {
    samples.sort_unstable();
    let n = samples.len();
    let median = samples[n / 2];
    let mean = samples.iter().sum::<Duration>() / n as u32;
    let p95 = samples[((n as f64 * 0.95) as usize).min(n - 1)];
    let mut devs: Vec<i128> = samples
        .iter()
        .map(|s| (s.as_nanos() as i128 - median.as_nanos() as i128).abs())
        .collect();
    devs.sort_unstable();
    let mad = Duration::from_nanos(devs[n / 2] as u64);
    BenchResult {
        name: name.to_string(),
        iters: n,
        min: samples[0],
        median,
        mean,
        p95,
        mad,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_reports_sane_stats() {
        let cfg = BenchConfig {
            warmup_iters: 1,
            min_iters: 5,
            max_iters: 20,
            target_time: Duration::from_millis(50),
        };
        let r = bench("spin", &cfg, || {
            std::hint::black_box((0..1000).sum::<u64>());
        });
        assert!(r.iters >= 5);
        assert!(r.min <= r.median && r.median <= r.p95);
    }
}
