//! Tiny CLI argument parser (no `clap` offline).
//!
//! Supports `--flag`, `--key value`, `--key=value` and positional
//! arguments, with typed getters and defaults. Enough for the `repro` /
//! `repro-experiments` CLIs and the bench harnesses.

use std::collections::BTreeMap;

#[derive(Debug, Default, Clone)]
pub struct Args {
    pub positional: Vec<String>,
    pub options: BTreeMap<String, String>,
    pub flags: Vec<String>,
}

impl Args {
    /// Parse from `std::env::args()` (skipping argv[0]).
    pub fn from_env() -> Self {
        Self::parse(std::env::args().skip(1))
    }

    pub fn parse<I: IntoIterator<Item = String>>(iter: I) -> Self {
        let mut out = Args::default();
        let mut it = iter.into_iter().peekable();
        while let Some(a) = it.next() {
            if let Some(rest) = a.strip_prefix("--") {
                if let Some((k, v)) = rest.split_once('=') {
                    out.options.insert(k.to_string(), v.to_string());
                } else if it
                    .peek()
                    .map(|n| !n.starts_with("--"))
                    .unwrap_or(false)
                {
                    let v = it.next().unwrap();
                    out.options.insert(rest.to_string(), v);
                } else {
                    out.flags.push(rest.to_string());
                }
            } else {
                out.positional.push(a);
            }
        }
        out
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(|s| s.as_str())
    }

    pub fn str_or(&self, name: &str, default: &str) -> String {
        self.get(name).unwrap_or(default).to_string()
    }

    pub fn usize_or(&self, name: &str, default: usize) -> usize {
        self.get(name)
            .map(|v| v.parse().unwrap_or_else(|_| panic!("--{name} expects an integer, got {v:?}")))
            .unwrap_or(default)
    }

    pub fn f64_or(&self, name: &str, default: f64) -> f64 {
        self.get(name)
            .map(|v| v.parse().unwrap_or_else(|_| panic!("--{name} expects a number, got {v:?}")))
            .unwrap_or(default)
    }

    /// Comma-separated list of floats, e.g. `--kf 0.5,0.25,0.125`.
    pub fn f64_list_or(&self, name: &str, default: &[f64]) -> Vec<f64> {
        match self.get(name) {
            None => default.to_vec(),
            Some(v) => v
                .split(',')
                .map(|x| {
                    x.trim().parse().unwrap_or_else(|_| panic!("bad float in --{name}: {x:?}"))
                })
                .collect(),
        }
    }

    pub fn usize_list_or(&self, name: &str, default: &[usize]) -> Vec<usize> {
        match self.get(name) {
            None => default.to_vec(),
            Some(v) => v
                .split(',')
                .map(|x| x.trim().parse().unwrap_or_else(|_| panic!("bad int in --{name}: {x:?}")))
                .collect(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(|x| x.to_string()))
    }

    #[test]
    fn mixed_forms() {
        // NB: a bare `--flag` followed by a non-dash token would consume it
        // as a value; flags that precede positionals must come last or use
        // `=` (see flag_before_positional).
        let a = parse("serve extra --batch 8 --variant=loki --verbose");
        assert_eq!(a.positional, vec!["serve", "extra"]);
        assert_eq!(a.usize_or("batch", 1), 8);
        assert_eq!(a.str_or("variant", "full"), "loki");
        assert!(a.flag("verbose"));
        assert!(!a.flag("quiet"));
    }

    #[test]
    fn lists_and_defaults() {
        let a = parse("--kf 0.5,0.25");
        assert_eq!(a.f64_list_or("kf", &[1.0]), vec![0.5, 0.25]);
        assert_eq!(a.f64_list_or("df", &[1.0]), vec![1.0]);
        assert_eq!(a.usize_or("missing", 7), 7);
    }

    #[test]
    fn flag_before_positional() {
        // `--verbose serve` treats `serve` as the flag's value candidate;
        // by convention flags that precede positionals must use `=`.
        let a = parse("--threads=4 run");
        assert_eq!(a.usize_or("threads", 1), 4);
        assert_eq!(a.positional, vec!["run"]);
    }
}
