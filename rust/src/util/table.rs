//! Plain-text / markdown table rendering for experiment output.
//!
//! Every experiment harness prints a paper-shaped table via [`Table`] and
//! also serializes it to `results/<id>.txt`; keeping the renderer in one
//! place keeps the tables visually consistent.

#[derive(Clone, Debug, Default)]
pub struct Table {
    pub title: String,
    pub headers: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, headers: &[&str]) -> Self {
        Self {
            title: title.to_string(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) -> &mut Self {
        assert_eq!(
            cells.len(),
            self.headers.len(),
            "row width mismatch in table {:?}",
            self.title
        );
        self.rows.push(cells);
        self
    }

    /// Render with box-drawing separators, padded columns.
    pub fn render(&self) -> String {
        let ncol = self.headers.len();
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.chars().count()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.chars().count());
            }
        }
        let sep: String = widths
            .iter()
            .map(|w| format!("+{}", "-".repeat(w + 2)))
            .collect::<String>()
            + "+";
        let fmt_row = |cells: &[String]| -> String {
            let mut line = String::from("|");
            for i in 0..ncol {
                let pad = widths[i] - cells[i].chars().count();
                line.push_str(&format!(" {}{} |", cells[i], " ".repeat(pad)));
            }
            line
        };
        let mut out = String::new();
        if !self.title.is_empty() {
            out.push_str(&format!("## {}\n", self.title));
        }
        out.push_str(&sep);
        out.push('\n');
        out.push_str(&fmt_row(&self.headers));
        out.push('\n');
        out.push_str(&sep);
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row));
            out.push('\n');
        }
        out.push_str(&sep);
        out.push('\n');
        out
    }

    /// Print to stdout and persist under `results/<id>.txt`.
    pub fn emit(&self, id: &str) {
        let text = self.render();
        println!("{text}");
        let path = super::results_dir().join(format!("{id}.txt"));
        if let Err(e) = std::fs::write(&path, &text) {
            eprintln!("warn: could not write {}: {e}", path.display());
        }
    }
}

/// Format a float with fixed decimals, `-` for NaN (missing cells).
pub fn fnum(x: f64, decimals: usize) -> String {
    if x.is_nan() {
        "-".to_string()
    } else {
        format!("{x:.decimals$}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new("demo", &["method", "ppl"]);
        t.row(vec!["full".into(), "5.11".into()]);
        t.row(vec!["loki (k=0.25,d=0.25)".into(), "5.20".into()]);
        let r = t.render();
        assert!(r.contains("| method"));
        assert!(r.lines().all(|l| {
            l.is_empty() || l.starts_with('+') || l.starts_with('|') || l.starts_with("##")
        }));
    }

    #[test]
    #[should_panic]
    fn rejects_ragged_rows() {
        let mut t = Table::new("x", &["a", "b"]);
        t.row(vec!["only-one".into()]);
    }

    #[test]
    fn fnum_handles_nan() {
        assert_eq!(fnum(f64::NAN, 2), "-");
        assert_eq!(fnum(1.2345, 2), "1.23");
    }
}
