//! Self-contained utility substrates.
//!
//! The offline crate set has no `clap`/`serde`/`criterion`/`rand`, so this
//! module provides the equivalents the rest of the crate builds on:
//! deterministic PRNGs ([`rng`]), a JSON parser/writer ([`json`]), a CLI
//! argument parser ([`args`]), a statistics-aware micro-benchmark harness
//! ([`bench`]) and plain-text table rendering ([`table`]).

pub mod args;
pub mod bench;
pub mod json;
pub mod rng;
pub mod table;

use std::path::{Path, PathBuf};

/// Locate the repository root by walking up from the current directory
/// until a `Cargo.toml` with the `loki` package is found. Lets binaries,
/// tests and benches run from any working directory inside the repo.
pub fn repo_root() -> PathBuf {
    let mut dir = std::env::current_dir().unwrap_or_else(|_| PathBuf::from("."));
    loop {
        if dir.join("Cargo.toml").exists() && dir.join("artifacts").exists()
            || dir.join("Cargo.toml").exists() && dir.join("python").exists()
        {
            return dir;
        }
        match dir.parent() {
            Some(p) => dir = p.to_path_buf(),
            None => return PathBuf::from("."),
        }
    }
}

/// `repo_root()/artifacts`, overridable with `LOKI_ARTIFACTS`.
pub fn artifacts_dir() -> PathBuf {
    if let Ok(p) = std::env::var("LOKI_ARTIFACTS") {
        return PathBuf::from(p);
    }
    repo_root().join(crate::ARTIFACTS_DIR)
}

/// `repo_root()/results`, created on demand.
pub fn results_dir() -> PathBuf {
    let d = repo_root().join(crate::RESULTS_DIR);
    let _ = std::fs::create_dir_all(&d);
    d
}

/// Write a string to a file, creating parent directories.
pub fn write_file(path: &Path, contents: &str) -> std::io::Result<()> {
    if let Some(parent) = path.parent() {
        std::fs::create_dir_all(parent)?;
    }
    std::fs::write(path, contents)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn repo_root_contains_cargo_toml() {
        assert!(repo_root().join("Cargo.toml").exists());
    }
}
