//! Deterministic PRNGs (the offline crate set has no `rand`).
//!
//! [`SplitMix64`] matches `python/compile/datagen.py::SplitMix64` bit for
//! bit (cross-checked in tests against recorded values), so seeded
//! generation is reproducible across the language boundary.
//! [`Xoshiro256`] (xoshiro256**) is the general-purpose generator used by
//! workloads, samplers and the property-test harness.

/// SplitMix64 — tiny, solid 64-bit generator; also used to seed Xoshiro.
#[derive(Clone, Debug)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }

    pub fn below(&mut self, n: usize) -> usize {
        (self.next_u64() % n as u64) as usize
    }

    pub fn uniform(&mut self) -> f64 {
        self.next_u64() as f64 / 2f64.powi(64)
    }
}

/// xoshiro256** by Blackman & Vigna — fast, 256-bit state.
#[derive(Clone, Debug)]
pub struct Xoshiro256 {
    s: [u64; 4],
}

impl Xoshiro256 {
    pub fn new(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        Self { s: [sm.next_u64(), sm.next_u64(), sm.next_u64(), sm.next_u64()] }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn uniform(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in [0, 1).
    #[inline]
    pub fn uniform_f32(&mut self) -> f32 {
        self.uniform() as f32
    }

    /// Uniform integer in [0, n).
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        (self.next_u64() % n as u64) as usize
    }

    /// Uniform integer in [lo, hi).
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        lo + self.below(hi - lo)
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = self.uniform().max(1e-300);
        let u2 = self.uniform();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    pub fn normal_f32(&mut self) -> f32 {
        self.normal() as f32
    }

    /// Vector of standard normals.
    pub fn normal_vec(&mut self, n: usize) -> Vec<f32> {
        (0..n).map(|_| self.normal_f32()).collect()
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Pick a random element.
    pub fn choice<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.below(xs.len())]
    }

    /// Exponential with the given rate (for Poisson arrivals).
    pub fn exponential(&mut self, rate: f64) -> f64 {
        -self.uniform().max(1e-300).ln() / rate
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn splitmix_matches_python_reference() {
        // Values recorded from python/compile/datagen.py::SplitMix64(42).
        let mut rng = SplitMix64::new(42);
        let expected: [u64; 4] = [
            13679457532755275413,
            2949826092126892291,
            5139283748462763858,
            6349198060258255764,
        ];
        for e in expected {
            assert_eq!(rng.next_u64(), e);
        }
    }

    #[test]
    fn xoshiro_uniform_bounds_and_determinism() {
        let mut a = Xoshiro256::new(7);
        let mut b = Xoshiro256::new(7);
        for _ in 0..1000 {
            let u = a.uniform();
            assert!((0.0..1.0).contains(&u));
            assert_eq!(b.uniform().to_bits(), u.to_bits());
        }
    }

    #[test]
    fn normal_moments() {
        let mut rng = Xoshiro256::new(3);
        let n = 20_000;
        let xs: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.05, "mean {mean}");
        assert!((var - 1.0).abs() < 0.1, "var {var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut rng = Xoshiro256::new(9);
        let mut xs: Vec<usize> = (0..100).collect();
        rng.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }
}
