//! Model-facing helpers shared by the coordinator and the eval harnesses:
//! the byte-level tokenizer (mirror of the python side) and logit math.

pub mod tokenizer;

pub use tokenizer::ByteTokenizer;

use crate::linalg::softmax::log_sum_exp;

/// Index of the highest logit.
pub fn argmax(logits: &[f32]) -> usize {
    let mut best = 0;
    for (i, &v) in logits.iter().enumerate() {
        if v > logits[best] {
            best = i;
        }
    }
    best
}

/// log p(token) under the logits (softmax log-prob).
pub fn log_prob(logits: &[f32], token: usize) -> f32 {
    logits[token] - log_sum_exp(logits)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn argmax_and_logprob() {
        let logits = vec![0.0, 2.0, -1.0];
        assert_eq!(argmax(&logits), 1);
        let lp: f32 = (0..3).map(|t| log_prob(&logits, t).exp()).sum();
        assert!((lp - 1.0).abs() < 1e-5);
        assert!(log_prob(&logits, 1) > log_prob(&logits, 0));
    }
}
