//! Byte-level tokenizer — the exact mirror of
//! `python/compile/datagen.py::tokenize` (identity over UTF-8 bytes).

#[derive(Clone, Copy, Debug, Default)]
pub struct ByteTokenizer;

impl ByteTokenizer {
    pub fn vocab_size(&self) -> usize {
        256
    }

    pub fn encode(&self, text: &str) -> Vec<i32> {
        text.bytes().map(|b| b as i32).collect()
    }

    pub fn decode(&self, tokens: &[i32]) -> String {
        let bytes: Vec<u8> = tokens.iter().map(|&t| (t & 0xFF) as u8).collect();
        String::from_utf8_lossy(&bytes).into_owned()
    }

    pub fn decode_one(&self, token: i32) -> char {
        ((token & 0xFF) as u8) as char
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ascii_round_trip() {
        let t = ByteTokenizer;
        let s = "the code of zorvik is blue-42 .";
        assert_eq!(t.decode(&t.encode(s)), s);
    }

    #[test]
    fn utf8_multibyte_round_trip() {
        let t = ByteTokenizer;
        let s = "héllo 🎉";
        assert_eq!(t.decode(&t.encode(s)), s);
        assert_eq!(t.encode(s).len(), s.len()); // bytes, not chars
    }

    #[test]
    fn tokens_in_range() {
        let t = ByteTokenizer;
        assert!(t.encode("å").iter().all(|&x| (0..256).contains(&x)));
    }
}
