//! Task suites standing in for the paper's LM-harness and LongBench
//! evaluations, built from the corpus material the model was trained on
//! (`artifacts/tasks.json`: facts, filler sentence pool).
//!
//! Short-context suite (Figs. 3/5, Tables 2–4 stand-ins):
//! * **FactQA**       — "the code of <name> is" → multiple-choice over the
//!   true value and 3 distractor values, scored by sequence log-prob
//!   (the MMLU/ARC analog: knowledge retrieval).
//! * **Copy**         — "repeat : w1 w2 w3 ; " → must echo w1 (direct
//!   attention dependence — the Hellaswag-ish continuation analog).
//! * **Induction**    — "a b a b a " → must produce b (in-context pattern,
//!   the Winogrande-ish analog).
//!
//! Long-context suite (Fig. 4 stand-in), prompts padded with filler to a
//! target length:
//! * **NeedleQA**     — one fact sentence hidden in filler; query at the
//!   end (Single-Doc QA).
//! * **MultiNeedleQA**— several facts hidden; query one (Multi-Doc QA).
//! * **FewShot**      — unseen pattern shown k times in-context (Few-shot
//!   learning).
//! * **CopyFar**      — copy drill whose source sits at the far start
//!   (Code-completion-ish: long-range verbatim reuse).

use std::path::Path;

use anyhow::{Context, Result};

use crate::model::ByteTokenizer;
use crate::util::json::Json;
use crate::util::rng::Xoshiro256;

#[derive(Clone, Debug)]
pub struct Fact {
    pub name: String,
    pub value: String,
}

#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum ShortTaskKind {
    FactQA,
    Copy,
    Induction,
}

impl ShortTaskKind {
    pub fn all() -> [ShortTaskKind; 3] {
        [ShortTaskKind::FactQA, ShortTaskKind::Copy, ShortTaskKind::Induction]
    }

    pub fn name(&self) -> &'static str {
        match self {
            ShortTaskKind::FactQA => "fact_qa",
            ShortTaskKind::Copy => "copy",
            ShortTaskKind::Induction => "induction",
        }
    }
}

/// One multiple-choice item: prompt + candidate continuations, index of
/// the correct one. Scored by total byte log-prob of each continuation.
#[derive(Clone, Debug)]
pub struct ShortTask {
    pub kind: ShortTaskKind,
    pub prompt: String,
    pub choices: Vec<String>,
    pub correct: usize,
}

#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum LongTaskKind {
    NeedleQA,
    MultiNeedleQA,
    FewShot,
    CopyFar,
}

impl LongTaskKind {
    pub fn all() -> [LongTaskKind; 4] {
        [
            LongTaskKind::NeedleQA,
            LongTaskKind::MultiNeedleQA,
            LongTaskKind::FewShot,
            LongTaskKind::CopyFar,
        ]
    }

    pub fn name(&self) -> &'static str {
        match self {
            LongTaskKind::NeedleQA => "needle_qa",
            LongTaskKind::MultiNeedleQA => "multi_needle_qa",
            LongTaskKind::FewShot => "few_shot",
            LongTaskKind::CopyFar => "copy_far",
        }
    }
}

#[derive(Clone, Debug)]
pub struct LongTask {
    pub kind: LongTaskKind,
    pub prompt: String,
    pub choices: Vec<String>,
    pub correct: usize,
}

/// Loaded task source material + generators.
pub struct TaskSuite {
    pub facts: Vec<Fact>,
    pub fillers: Vec<String>,
    tokenizer: ByteTokenizer,
}

impl TaskSuite {
    pub fn load(artifacts: &Path) -> Result<Self> {
        let j = Json::parse_file(&artifacts.join("tasks.json")).context("tasks.json")?;
        let facts = j
            .req("facts")
            .as_arr()
            .context("facts")?
            .iter()
            .filter_map(|f| {
                Some(Fact {
                    name: f.get("name")?.as_str()?.to_string(),
                    value: f.get("value")?.as_str()?.to_string(),
                })
            })
            .collect::<Vec<_>>();
        let fillers = j
            .req("fillers")
            .req("wiki")
            .as_arr()
            .context("fillers.wiki")?
            .iter()
            .filter_map(|s| s.as_str().map(|x| x.to_string()))
            .collect::<Vec<_>>();
        if facts.is_empty() || fillers.is_empty() {
            anyhow::bail!("tasks.json has no facts/fillers");
        }
        Ok(Self { facts, fillers, tokenizer: ByteTokenizer })
    }

    pub fn tokenizer(&self) -> ByteTokenizer {
        self.tokenizer
    }

    // -- short-context -------------------------------------------------------

    pub fn short_tasks(&self, kind: ShortTaskKind, n: usize, seed: u64) -> Vec<ShortTask> {
        let mut rng = Xoshiro256::new(seed ^ kind.name().len() as u64);
        (0..n).map(|_| self.short_task(kind, &mut rng)).collect()
    }

    fn short_task(&self, kind: ShortTaskKind, rng: &mut Xoshiro256) -> ShortTask {
        match kind {
            ShortTaskKind::FactQA => {
                let f = rng.choice(&self.facts);
                let mut choices = vec![f.value.clone()];
                while choices.len() < 4 {
                    let d = &rng.choice(&self.facts).value;
                    if !choices.contains(d) {
                        choices.push(d.clone());
                    }
                }
                rng.shuffle(&mut choices);
                let correct = choices.iter().position(|c| *c == f.value).unwrap();
                ShortTask {
                    kind,
                    prompt: format!("the code of {} is", f.name),
                    choices: choices.iter().map(|c| format!(" {c}")).collect(),
                    correct,
                }
            }
            ShortTaskKind::Copy => {
                // In-distribution: "repeat : w1 w2 w3 ; w1 w2 w3 ."
                let words = self.sample_words(rng, 3);
                let prompt = format!("repeat : {} ; ", words.join(" "));
                self.choice_task(kind, prompt, &words[0], rng)
            }
            ShortTaskKind::Induction => {
                let w = self.sample_words(rng, 2);
                let (a, b) = (&w[0], &w[1]);
                let reps = 3;
                let mut prompt = String::new();
                for _ in 0..reps {
                    prompt.push_str(&format!("{a} {b} "));
                }
                prompt.push_str(a);
                prompt.push(' ');
                self.choice_task(kind, prompt, b, rng)
            }
        }
    }

    /// Build a 4-way choice task with `answer` + 3 distractor words.
    fn choice_task(
        &self,
        kind: ShortTaskKind,
        prompt: String,
        answer: &str,
        rng: &mut Xoshiro256,
    ) -> ShortTask {
        let mut choices = vec![answer.to_string()];
        while choices.len() < 4 {
            let w = self.sample_words(rng, 1).remove(0);
            if !choices.contains(&w) {
                choices.push(w);
            }
        }
        rng.shuffle(&mut choices);
        let correct = choices.iter().position(|c| c == answer).unwrap();
        ShortTask { kind, prompt, choices, correct }
    }

    /// Words drawn from the filler pool (in-distribution vocabulary).
    fn sample_words(&self, rng: &mut Xoshiro256, n: usize) -> Vec<String> {
        let mut out = Vec::with_capacity(n);
        while out.len() < n {
            let sent = rng.choice(&self.fillers);
            let words: Vec<&str> =
                sent.split_whitespace().filter(|w| w.len() > 2 && *w != ".").collect();
            if let Some(w) = words.get(rng.below(words.len().max(1))) {
                out.push(w.to_string());
            }
        }
        out
    }

    // -- long-context --------------------------------------------------------

    pub fn long_tasks(
        &self,
        kind: LongTaskKind,
        n: usize,
        target_len_bytes: usize,
        seed: u64,
    ) -> Vec<LongTask> {
        let mut rng = Xoshiro256::new(seed ^ (target_len_bytes as u64) << 8);
        (0..n).map(|_| self.long_task(kind, target_len_bytes, &mut rng)).collect()
    }

    fn filler_block(&self, rng: &mut Xoshiro256, bytes: usize) -> String {
        let mut s = String::new();
        while s.len() < bytes {
            let f: &String = rng.choice(&self.fillers);
            s.push_str(f);
            s.push(' ');
        }
        s.truncate(bytes);
        // Don't cut mid-word: trim back to last space.
        if let Some(i) = s.rfind(' ') {
            s.truncate(i + 1);
        }
        s
    }

    fn long_task(&self, kind: LongTaskKind, target: usize, rng: &mut Xoshiro256) -> LongTask {
        match kind {
            LongTaskKind::NeedleQA => {
                let f = rng.choice(&self.facts).clone();
                let needle = format!("the code of {} is {} . ", f.name, f.value);
                let query = format!("the code of {} is", f.name);
                let body = target.saturating_sub(needle.len() + query.len() + 2);
                // Needle placed at a random depth.
                let pre = body * rng.range(10, 80) / 100;
                let prompt = format!(
                    "{}{}{}{}",
                    self.filler_block(rng, pre),
                    needle,
                    self.filler_block(rng, body - pre),
                    query
                );
                self.fact_choices(kind, prompt, &f, rng)
            }
            LongTaskKind::MultiNeedleQA => {
                let k = 4;
                let mut fs: Vec<Fact> = (0..k).map(|_| rng.choice(&self.facts).clone()).collect();
                fs.dedup_by(|a, b| a.name == b.name);
                let ask = fs[rng.below(fs.len())].clone();
                let query = format!("the code of {} is", ask.name);
                let seg = target / (fs.len() + 1);
                let mut prompt = String::new();
                for f in &fs {
                    prompt.push_str(&self.filler_block(rng, seg.saturating_sub(40)));
                    prompt.push_str(&format!("the code of {} is {} . ", f.name, f.value));
                }
                prompt.push_str(&self.filler_block(rng, seg / 2));
                prompt.push_str(&query);
                self.fact_choices(kind, prompt, &ask, rng)
            }
            LongTaskKind::FewShot => {
                // Unseen mapping demonstrated k times: "<x> maps to <y> ."
                let words = self.sample_words(rng, 8);
                let (x, y) = (&words[0], &words[1]);
                let shots = 3;
                let mut demo = String::new();
                for _ in 0..shots {
                    demo.push_str(&format!("{x} maps to {y} . "));
                }
                let body = target.saturating_sub(demo.len() * 2);
                let prompt = format!(
                    "{}{}{}{x} maps to",
                    demo,
                    self.filler_block(rng, body),
                    demo
                );
                let mut t = self.choice_task(ShortTaskKind::Copy, prompt, y, rng);
                t.choices = t.choices.iter().map(|c| format!(" {c}")).collect();
                LongTask { kind, prompt: t.prompt, choices: t.choices, correct: t.correct }
            }
            LongTaskKind::CopyFar => {
                let words = self.sample_words(rng, 4);
                let head = format!("repeat : {} ; ", words.join(" "));
                let body = target.saturating_sub(head.len() * 2);
                let prompt = format!("{}{}{}", head, self.filler_block(rng, body), head.trim_end());
                let mut t = self.choice_task(ShortTaskKind::Copy, prompt, &words[0], rng);
                t.choices = t.choices.iter().map(|c| format!(" {c}")).collect();
                LongTask { kind, prompt: t.prompt, choices: t.choices, correct: t.correct }
            }
        }
    }

    fn fact_choices(
        &self,
        kind: LongTaskKind,
        prompt: String,
        f: &Fact,
        rng: &mut Xoshiro256,
    ) -> LongTask {
        let mut choices = vec![f.value.clone()];
        while choices.len() < 4 {
            let d = &rng.choice(&self.facts).value;
            if !choices.contains(d) {
                choices.push(d.clone());
            }
        }
        rng.shuffle(&mut choices);
        let correct = choices.iter().position(|c| *c == f.value).unwrap();
        LongTask {
            kind,
            prompt,
            choices: choices.iter().map(|c| format!(" {c}")).collect(),
            correct,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::artifacts_dir;

    fn suite() -> Option<TaskSuite> {
        let dir = artifacts_dir();
        if !dir.join("tasks.json").exists() {
            eprintln!("skipping: artifacts not built");
            return None;
        }
        Some(TaskSuite::load(&dir).unwrap())
    }

    #[test]
    fn short_tasks_are_well_formed() {
        let Some(s) = suite() else { return };
        for kind in ShortTaskKind::all() {
            let tasks = s.short_tasks(kind, 20, 1);
            assert_eq!(tasks.len(), 20);
            for t in &tasks {
                assert_eq!(t.choices.len(), 4);
                assert!(t.correct < 4);
                assert!(!t.prompt.is_empty());
                // Choices must be distinct.
                let mut c = t.choices.clone();
                c.sort();
                c.dedup();
                assert_eq!(c.len(), 4, "{t:?}");
            }
        }
    }

    #[test]
    fn long_tasks_hit_target_length() {
        let Some(s) = suite() else { return };
        for kind in LongTaskKind::all() {
            for t in s.long_tasks(kind, 5, 600, 2) {
                assert!(
                    (400..=700).contains(&t.prompt.len()),
                    "{kind:?} prompt len {}",
                    t.prompt.len()
                );
                // The correct answer string must actually appear in the
                // prompt body for retrieval tasks.
                if matches!(kind, LongTaskKind::NeedleQA | LongTaskKind::MultiNeedleQA) {
                    let ans = t.choices[t.correct].trim();
                    assert!(t.prompt.contains(ans), "{kind:?}: answer not in prompt");
                }
            }
        }
    }

    #[test]
    fn deterministic_generation() {
        let Some(s) = suite() else { return };
        let a = s.short_tasks(ShortTaskKind::FactQA, 5, 7);
        let b = s.short_tasks(ShortTaskKind::FactQA, 5, 7);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.prompt, y.prompt);
            assert_eq!(x.correct, y.correct);
        }
    }
}
