//! Evaluation data: exported corpora/eval docs, task suites and serving
//! workload generation.
//!
//! * [`tasks`]    — loads `artifacts/tasks.json` (facts, filler pool) and
//!   builds the short-context suite (fact QA, copy, induction — the
//!   LM-harness stand-ins) and the LongBench-analog long-context suite
//!   (needle QA, multi-needle QA, few-shot patterns, code-ish completion).
//! * [`evaldocs`] — perplexity documents exported by aot.py.
//! * [`workload`] — Poisson/burst request traces for the serving benches.

pub mod evaldocs;
pub mod tasks;
pub mod workload;

pub use evaldocs::EvalDocs;
pub use tasks::{LongTask, LongTaskKind, ShortTask, ShortTaskKind, TaskSuite};
pub use workload::{Workload, WorkloadCfg};
