//! Serving workload traces: request streams with Poisson or bursty
//! arrivals, prompt/generation length distributions. Drives the
//! e2e_serving bench and `repro serve --trace`.

use crate::util::rng::Xoshiro256;

#[derive(Clone, Debug)]
pub struct WorkloadCfg {
    pub n_requests: usize,
    /// Mean arrival rate (requests/second); 0 → all arrive at t=0.
    pub rate: f64,
    /// Burstiness: probability that a request arrives back-to-back with
    /// the previous one instead of waiting an exponential gap.
    pub burst_p: f64,
    pub prompt_len: (usize, usize),
    pub gen_len: (usize, usize),
    /// Shared system-prompt bytes prepended *identically* to every
    /// request (multi-tenant serving: one app prompt, many user turns).
    /// The byte tokenizer maps equal text to equal tokens, so this is
    /// exactly what the kvpool's content-addressed prefix sharing
    /// deduplicates. 0 disables.
    pub shared_prefix_len: usize,
    pub seed: u64,
}

impl Default for WorkloadCfg {
    fn default() -> Self {
        Self {
            n_requests: 32,
            rate: 0.0,
            burst_p: 0.0,
            prompt_len: (32, 200),
            gen_len: (16, 64),
            shared_prefix_len: 0,
            seed: 0,
        }
    }
}

#[derive(Clone, Debug)]
pub struct TraceItem {
    /// Seconds after trace start.
    pub arrival_s: f64,
    pub prompt: String,
    pub max_new_tokens: usize,
}

/// A generated request trace.
#[derive(Clone, Debug)]
pub struct Workload {
    pub items: Vec<TraceItem>,
}

impl Workload {
    /// Build a trace using filler sentences as prompt material. When
    /// `shared_prefix_len > 0`, one system prompt of exactly that many
    /// bytes is built first and prepended verbatim to every request on
    /// top of the per-request (`prompt_len`-sized) user suffix.
    pub fn generate(cfg: &WorkloadCfg, fillers: &[String]) -> Self {
        assert!(!fillers.is_empty());
        let mut rng = Xoshiro256::new(cfg.seed ^ w0rkload_seed());
        let shared = Self::filler_text(&mut rng, cfg.shared_prefix_len, fillers);
        let mut t = 0.0f64;
        let mut items = Vec::with_capacity(cfg.n_requests);
        for _ in 0..cfg.n_requests {
            if cfg.rate > 0.0 && rng.uniform() >= cfg.burst_p {
                t += rng.exponential(cfg.rate);
            }
            let plen = rng.range(cfg.prompt_len.0, cfg.prompt_len.1 + 1);
            let mut prompt = shared.clone();
            prompt.push_str(&Self::filler_text(&mut rng, plen, fillers));
            items.push(TraceItem {
                arrival_s: t,
                prompt,
                max_new_tokens: rng.range(cfg.gen_len.0, cfg.gen_len.1 + 1),
            });
        }
        Self { items }
    }

    /// Exactly `len` bytes of filler prose.
    fn filler_text(rng: &mut Xoshiro256, len: usize, fillers: &[String]) -> String {
        let mut text = String::new();
        while text.len() < len {
            let f: &String = rng.choice(fillers);
            text.push_str(f);
            text.push(' ');
        }
        text.truncate(len);
        text
    }

    pub fn duration_s(&self) -> f64 {
        self.items.last().map(|i| i.arrival_s).unwrap_or(0.0)
    }
}

// Tiny helper so the seed constant reads as intent, not magic.
#[allow(non_snake_case)]
fn w0rkload_seed() -> u64 {
    0x57AC_E0FD
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fillers() -> Vec<String> {
        vec!["tor ven al ker .".to_string(), "pol gra tec his cen .".to_string()]
    }

    #[test]
    fn arrivals_are_monotone() {
        let cfg = WorkloadCfg { n_requests: 50, rate: 10.0, ..Default::default() };
        let w = Workload::generate(&cfg, &fillers());
        assert_eq!(w.items.len(), 50);
        for pair in w.items.windows(2) {
            assert!(pair[1].arrival_s >= pair[0].arrival_s);
        }
        assert!(w.duration_s() > 0.0);
    }

    #[test]
    fn zero_rate_is_batch_arrival() {
        let cfg = WorkloadCfg { n_requests: 10, rate: 0.0, ..Default::default() };
        let w = Workload::generate(&cfg, &fillers());
        assert!(w.items.iter().all(|i| i.arrival_s == 0.0));
    }

    #[test]
    fn shared_prefix_is_byte_identical_across_requests() {
        let cfg = WorkloadCfg {
            n_requests: 12,
            shared_prefix_len: 64,
            prompt_len: (10, 20),
            ..Default::default()
        };
        let w = Workload::generate(&cfg, &fillers());
        let prefix = &w.items[0].prompt[..64];
        for i in &w.items {
            assert_eq!(&i.prompt[..64], prefix, "system prompt must be verbatim-shared");
            assert!(i.prompt.len() >= 64 + 10 && i.prompt.len() <= 64 + 20);
        }
        // Suffixes must still vary (they are the per-user part).
        let distinct: std::collections::HashSet<&str> =
            w.items.iter().map(|i| &i.prompt[64..]).collect();
        assert!(distinct.len() > 1, "user suffixes should differ");
    }

    #[test]
    fn lengths_respect_bounds() {
        let cfg = WorkloadCfg {
            n_requests: 40,
            prompt_len: (50, 60),
            gen_len: (5, 8),
            ..Default::default()
        };
        let w = Workload::generate(&cfg, &fillers());
        for i in &w.items {
            assert!(i.prompt.len() <= 60);
            assert!((5..=8).contains(&i.max_new_tokens));
        }
    }
}
