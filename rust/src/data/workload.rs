//! Serving workload traces: request streams with Poisson or bursty
//! arrivals, prompt/generation length distributions and mixed priority
//! classes. Drives the e2e_serving bench and `repro serve --trace`.

use crate::coordinator::request::Priority;
use crate::util::rng::Xoshiro256;

/// Distribution of per-request `max_new_tokens`.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum GenLenDist {
    /// Uniform over the configured `gen_len` range.
    Uniform,
    /// Exponential tail with the given mean, truncated to `[1, cap]`:
    /// most requests ask for a short decode, a long tail runs far. This
    /// is the regime where full-budget KV reservation wastes the pool —
    /// the mean footprint is `mean` tokens but admission must price every
    /// request at `cap`-ish — and where speculative admission pays off.
    LongTail { mean: f64, cap: usize },
}

#[derive(Clone, Debug)]
pub struct WorkloadCfg {
    pub n_requests: usize,
    /// Mean arrival rate (requests/second); 0 → all arrive at t=0.
    pub rate: f64,
    /// Burstiness: probability that a request arrives back-to-back with
    /// the previous one instead of waiting an exponential gap.
    pub burst_p: f64,
    pub prompt_len: (usize, usize),
    pub gen_len: (usize, usize),
    /// How `max_new_tokens` is drawn; `Uniform` uses `gen_len`,
    /// `LongTail` ignores it.
    pub gen_len_dist: GenLenDist,
    /// Shared system-prompt bytes prepended *identically* to every
    /// request (multi-tenant serving: one app prompt, many user turns).
    /// The byte tokenizer maps equal text to equal tokens, so this is
    /// exactly what the kvpool's content-addressed prefix sharing
    /// deduplicates. 0 disables.
    pub shared_prefix_len: usize,
    /// Number of *distinct* shared prefixes ("tenants"): each request
    /// draws one of `prefix_group_count` system prompts (all exactly
    /// `shared_prefix_len` bytes) instead of the single global one — the
    /// multi-tenant regime prefix-affinity routing shards across
    /// replicas. Group prefixes beyond the first and the per-request
    /// group draw come from a dedicated RNG stream, so raising the count
    /// never perturbs arrivals, user suffixes, lengths, classes or SLOs.
    /// 1 (the default) pins the single-prefix traces byte-identically.
    pub prefix_group_count: usize,
    /// Probability a request is `Priority::Batch` (0 → all interactive,
    /// the single-class traces every earlier scenario used; 1 → all
    /// batch). Drawn per request, deterministic for a fixed seed — the
    /// mixed-priority contention scenarios behind the priority-aware
    /// victim policy.
    pub batch_frac: f64,
    /// Optional TTFT SLO (milliseconds) stamped on every `Interactive`
    /// request — the arrival-relative deadline the engine's
    /// `DeadlineAware` policy schedules by and the deadline-hit metrics
    /// grade against. `None` (the default) emits the SLO-less traces
    /// every earlier scenario used.
    pub slo_ms_interactive: Option<f64>,
    /// Same, for `Batch` requests (throughput jobs usually run without
    /// one — aging, not a deadline, is what bounds their wait).
    pub slo_ms_batch: Option<f64>,
    /// Uniform per-request jitter on the stamped SLO: each SLO'd
    /// request draws its budget from `slo_ms · [1 − j, 1 + j]`, so a
    /// trace carries a *spread* of deadlines rather than one value —
    /// what exercises earliest-deadline ordering and the shed
    /// predictor's per-request margins. Drawn from a dedicated RNG
    /// stream (one draw per request, SLO'd or not), so enabling jitter
    /// never perturbs arrivals, prompts, lengths or classes, and the
    /// draw at index `i` is the same whichever class lands there.
    /// Clamped to `[0, 0.9]` (a jitter of 1 could stamp a zero budget,
    /// which the protocol rejects). 0 (the default) pins every earlier
    /// trace byte-identically.
    pub slo_jitter_frac: f64,
    /// Conversation turns per session (`--turns`). Each base request
    /// becomes turn 0 of a session; every follow-up turn's prompt is the
    /// previous turn's prompt extended with a simulated assistant reply
    /// plus a fresh user message, so a session's full history prefix is
    /// byte-identical across turns — exactly what the kvpool radix tree
    /// deduplicates. Follow-up material comes from a dedicated RNG
    /// stream, so raising this never perturbs the base trace. 1 (the
    /// default) emits single-shot traces byte-identically.
    pub turns_per_session: usize,
    /// Seconds between a session's consecutive turns (`--think-time`):
    /// the client-side "think time" separating a reply from the next
    /// user message. 0 lands every turn at the session's base arrival.
    pub think_time_gap: f64,
    /// Sibling requests per turn (`--branch-factor`): > 1 emits that
    /// many *identical-prompt* requests per turn (regeneration forks —
    /// the tree-of-turns workload behind fork/COW refcount accounting).
    /// 1 (the default) emits linear sessions.
    pub branch_factor: usize,
    pub seed: u64,
}

impl Default for WorkloadCfg {
    fn default() -> Self {
        Self {
            n_requests: 32,
            rate: 0.0,
            burst_p: 0.0,
            prompt_len: (32, 200),
            gen_len: (16, 64),
            gen_len_dist: GenLenDist::Uniform,
            shared_prefix_len: 0,
            prefix_group_count: 1,
            batch_frac: 0.0,
            slo_ms_interactive: None,
            slo_ms_batch: None,
            slo_jitter_frac: 0.0,
            turns_per_session: 1,
            think_time_gap: 0.0,
            branch_factor: 1,
            seed: 0,
        }
    }
}

#[derive(Clone, Debug)]
pub struct TraceItem {
    /// Seconds after trace start.
    pub arrival_s: f64,
    pub prompt: String,
    pub max_new_tokens: usize,
    /// Importance class for the engine's multi-class scheduler.
    pub priority: Priority,
    /// Per-class TTFT SLO from the workload config (`None` → no
    /// deadline; the engine stamps `arrival + slo_ms` at submission).
    pub slo_ms: Option<f64>,
    /// Conversation session this request belongs to (`None` on
    /// single-shot traces — stamped only when the multi-turn generator
    /// is active, keyed by the base request's index).
    pub session: Option<u64>,
    /// Zero-based turn within the session (0 = first turn/single-shot).
    pub turn: u32,
}

/// A generated request trace.
#[derive(Clone, Debug)]
pub struct Workload {
    pub items: Vec<TraceItem>,
}

impl Workload {
    /// Build a trace using filler sentences as prompt material. When
    /// `shared_prefix_len > 0`, one system prompt of exactly that many
    /// bytes is built first and prepended verbatim to every request on
    /// top of the per-request (`prompt_len`-sized) user suffix; with
    /// `prefix_group_count > 1` each request instead draws one of that
    /// many distinct equal-length system prompts (tenants).
    pub fn generate(cfg: &WorkloadCfg, fillers: &[String]) -> Self {
        assert!(!fillers.is_empty());
        let mut rng = Xoshiro256::new(cfg.seed ^ w0rkload_seed());
        // Separate stream for class draws: annotating a trace with
        // priorities must not perturb its arrivals, prompts or lengths
        // (the contended scenarios compare against single-class twins).
        let mut class_rng = Xoshiro256::new(cfg.seed ^ 0xC1A5_5BAD);
        // And a third stream for SLO jitter, same reasoning: deadline
        // spread must ride along without reshuffling the trace.
        let mut slo_rng = Xoshiro256::new(cfg.seed ^ 0x510_D1CE);
        let jitter = cfg.slo_jitter_frac.clamp(0.0, 0.9);
        let shared = Self::filler_text(&mut rng, cfg.shared_prefix_len, fillers);
        // Fourth stream for multi-tenant prefix groups: extra group
        // prefixes and the per-request group draw must ride along
        // without reshuffling the base trace (group 0 is the original
        // main-stream system prompt, so `prefix_group_count == 1` never
        // touches this stream at all).
        let mut group_rng = Xoshiro256::new(cfg.seed ^ 0xAFF1_717E);
        let groups = cfg.prefix_group_count.max(1);
        let mut prefixes = vec![shared];
        for _ in 1..groups {
            prefixes.push(Self::filler_text(&mut group_rng, cfg.shared_prefix_len, fillers));
        }
        let turns = cfg.turns_per_session.max(1);
        let branches = cfg.branch_factor.max(1);
        // Only a *multi-turn* trace carries session keys: the default
        // (1 turn, 1 branch) must leave every base item byte-identical,
        // session-less and turn-0, and never touch the turn stream.
        let multi = turns > 1 || branches > 1;
        let mut t = 0.0f64;
        let mut items = Vec::with_capacity(cfg.n_requests * turns * branches);
        for i in 0..cfg.n_requests {
            if cfg.rate > 0.0 && rng.uniform() >= cfg.burst_p {
                t += rng.exponential(cfg.rate);
            }
            let plen = rng.range(cfg.prompt_len.0, cfg.prompt_len.1 + 1);
            let group = if groups > 1 { group_rng.range(0, groups) } else { 0 };
            let mut prompt = prefixes[group].clone();
            prompt.push_str(&Self::filler_text(&mut rng, plen, fillers));
            let max_new_tokens = Self::draw_gen_len(&mut rng, cfg);
            let priority = if class_rng.uniform() < cfg.batch_frac {
                Priority::Batch
            } else {
                Priority::Interactive
            };
            // One jitter draw per request regardless of class or SLO
            // presence, so the stream stays index-aligned across
            // configs that differ only in class mix or SLO settings.
            let jitter_draw = 1.0 + jitter * (2.0 * slo_rng.uniform() - 1.0);
            let slo_ms = match priority {
                Priority::Interactive => cfg.slo_ms_interactive,
                Priority::Batch => cfg.slo_ms_batch,
            }
            .map(|ms| if jitter > 0.0 { ms * jitter_draw } else { ms });
            let session = if multi { Some(i as u64) } else { None };
            items.push(TraceItem {
                arrival_s: t,
                prompt,
                max_new_tokens,
                priority,
                slo_ms,
                session,
                turn: 0,
            });
        }
        if multi {
            // Fifth stream: follow-up turns and regeneration forks ride
            // along without perturbing the base trace above.
            let mut turn_rng = Xoshiro256::new(cfg.seed ^ 0x5E55_10E5);
            let gap = cfg.think_time_gap.max(0.0);
            let base_count = items.len();
            for s in 0..base_count {
                let mut history = items[s].prompt.clone();
                let base_arrival = items[s].arrival_s;
                let priority = items[s].priority;
                let slo_ms = items[s].slo_ms;
                // Turn-0 regeneration forks: identical prompt, same
                // arrival — siblings share every full prompt block and
                // diverge only in their decoded (COW) tails.
                for _ in 1..branches {
                    let max_new_tokens = Self::draw_gen_len(&mut turn_rng, cfg);
                    items.push(TraceItem {
                        arrival_s: base_arrival,
                        prompt: history.clone(),
                        max_new_tokens,
                        priority,
                        slo_ms,
                        session: Some(s as u64),
                        turn: 0,
                    });
                }
                for turn in 1..turns {
                    // The session history grows by a simulated assistant
                    // reply (gen_len-sized) plus the next user message
                    // (prompt_len-sized); the previous turn's prompt is
                    // a strict byte prefix of this one, so the radix
                    // tree resolves the whole history at admission.
                    let rlen = turn_rng.range(cfg.gen_len.0, cfg.gen_len.1 + 1);
                    history.push_str(&Self::filler_text(&mut turn_rng, rlen, fillers));
                    let ulen = turn_rng.range(cfg.prompt_len.0, cfg.prompt_len.1 + 1);
                    history.push_str(&Self::filler_text(&mut turn_rng, ulen, fillers));
                    let arrival = base_arrival + turn as f64 * gap;
                    for _ in 0..branches {
                        let max_new_tokens = Self::draw_gen_len(&mut turn_rng, cfg);
                        items.push(TraceItem {
                            arrival_s: arrival,
                            prompt: history.clone(),
                            max_new_tokens,
                            priority,
                            slo_ms,
                            session: Some(s as u64),
                            turn: turn as u32,
                        });
                    }
                }
            }
            // Re-interleave sessions by arrival. Stable sort + total
            // ordering keeps ties (zero rate or zero gap) in insertion
            // order, so the trace stays deterministic.
            items.sort_by(|a, b| a.arrival_s.total_cmp(&b.arrival_s));
        }
        Self { items }
    }

    /// Draw one `max_new_tokens` from the configured distribution.
    fn draw_gen_len(rng: &mut Xoshiro256, cfg: &WorkloadCfg) -> usize {
        match cfg.gen_len_dist {
            GenLenDist::Uniform => rng.range(cfg.gen_len.0, cfg.gen_len.1 + 1),
            GenLenDist::LongTail { mean, cap } => {
                // Exponential with the configured mean (rate 1/mean),
                // rounded and truncated. With cap ≫ mean the truncation
                // bias is negligible — pinned by the `long_tail_*`
                // tests below.
                let draw = rng.exponential(1.0 / mean.max(1e-9));
                (draw.round() as usize).clamp(1, cap.max(1))
            }
        }
    }

    /// Exactly `len` bytes of filler prose.
    fn filler_text(rng: &mut Xoshiro256, len: usize, fillers: &[String]) -> String {
        let mut text = String::new();
        while text.len() < len {
            let f: &String = rng.choice(fillers);
            text.push_str(f);
            text.push(' ');
        }
        text.truncate(len);
        text
    }

    pub fn duration_s(&self) -> f64 {
        self.items.last().map(|i| i.arrival_s).unwrap_or(0.0)
    }
}

// Tiny helper so the seed constant reads as intent, not magic.
#[allow(non_snake_case)]
fn w0rkload_seed() -> u64 {
    0x57AC_E0FD
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fillers() -> Vec<String> {
        vec!["tor ven al ker .".to_string(), "pol gra tec his cen .".to_string()]
    }

    #[test]
    fn arrivals_are_monotone() {
        let cfg = WorkloadCfg { n_requests: 50, rate: 10.0, ..Default::default() };
        let w = Workload::generate(&cfg, &fillers());
        assert_eq!(w.items.len(), 50);
        for pair in w.items.windows(2) {
            assert!(pair[1].arrival_s >= pair[0].arrival_s);
        }
        assert!(w.duration_s() > 0.0);
    }

    #[test]
    fn zero_rate_is_batch_arrival() {
        let cfg = WorkloadCfg { n_requests: 10, rate: 0.0, ..Default::default() };
        let w = Workload::generate(&cfg, &fillers());
        assert!(w.items.iter().all(|i| i.arrival_s == 0.0));
    }

    #[test]
    fn shared_prefix_is_byte_identical_across_requests() {
        let cfg = WorkloadCfg {
            n_requests: 12,
            shared_prefix_len: 64,
            prompt_len: (10, 20),
            ..Default::default()
        };
        let w = Workload::generate(&cfg, &fillers());
        let prefix = &w.items[0].prompt[..64];
        for i in &w.items {
            assert_eq!(&i.prompt[..64], prefix, "system prompt must be verbatim-shared");
            assert!(i.prompt.len() >= 64 + 10 && i.prompt.len() <= 64 + 20);
        }
        // Suffixes must still vary (they are the per-user part).
        let distinct: std::collections::HashSet<&str> =
            w.items.iter().map(|i| &i.prompt[64..]).collect();
        assert!(distinct.len() > 1, "user suffixes should differ");
    }

    #[test]
    fn prefix_groups_ride_along_without_perturbing_the_trace() {
        let base = WorkloadCfg {
            n_requests: 48,
            shared_prefix_len: 64,
            prompt_len: (10, 20),
            seed: 7,
            ..Default::default()
        };
        let single = Workload::generate(&base, &fillers());
        let multi = Workload::generate(
            &WorkloadCfg { prefix_group_count: 4, ..base.clone() },
            &fillers(),
        );
        // Grouping must only swap the leading 64 bytes: arrivals, user
        // suffixes and lengths stay byte-identical to the single-tenant
        // trace.
        let mut groups_seen = std::collections::HashSet::new();
        for (a, b) in single.items.iter().zip(&multi.items) {
            assert_eq!(a.arrival_s, b.arrival_s);
            assert_eq!(a.max_new_tokens, b.max_new_tokens);
            assert_eq!(&a.prompt[64..], &b.prompt[64..], "user suffix must ride along");
            groups_seen.insert(b.prompt[..64].to_string());
        }
        assert!(
            groups_seen.len() > 1 && groups_seen.len() <= 4,
            "4 tenants must yield 2–4 distinct prefixes, got {}",
            groups_seen.len()
        );
        // Deterministic: the same seed redraws the same groups.
        let again = Workload::generate(
            &WorkloadCfg { prefix_group_count: 4, ..base.clone() },
            &fillers(),
        );
        for (a, b) in multi.items.iter().zip(&again.items) {
            assert_eq!(a.prompt, b.prompt);
        }
        // Default (1) pins the single-prefix trace byte-identically.
        let one = Workload::generate(
            &WorkloadCfg { prefix_group_count: 1, ..base.clone() },
            &fillers(),
        );
        for (a, b) in single.items.iter().zip(&one.items) {
            assert_eq!(a.prompt, b.prompt);
        }
    }

    #[test]
    fn long_tail_is_deterministic_for_a_fixed_seed() {
        let cfg = WorkloadCfg {
            n_requests: 64,
            gen_len_dist: GenLenDist::LongTail { mean: 24.0, cap: 256 },
            seed: 41,
            ..Default::default()
        };
        let a = Workload::generate(&cfg, &fillers());
        let b = Workload::generate(&cfg, &fillers());
        let lens_a: Vec<usize> = a.items.iter().map(|i| i.max_new_tokens).collect();
        let lens_b: Vec<usize> = b.items.iter().map(|i| i.max_new_tokens).collect();
        assert_eq!(lens_a, lens_b, "same seed must reproduce the same tail draws");
        for (x, y) in a.items.iter().zip(&b.items) {
            assert_eq!(x.prompt, y.prompt);
        }
        // A different seed draws a different trace.
        let c = Workload::generate(&WorkloadCfg { seed: 42, ..cfg }, &fillers());
        let lens_c: Vec<usize> = c.items.iter().map(|i| i.max_new_tokens).collect();
        assert_ne!(lens_a, lens_c);
    }

    #[test]
    fn long_tail_mean_and_bounds_hold() {
        let mean = 32.0;
        let cap = 512; // cap ≫ mean: truncation bias ≪ the tolerance
        let cfg = WorkloadCfg {
            n_requests: 4000,
            prompt_len: (4, 8),
            gen_len_dist: GenLenDist::LongTail { mean, cap },
            seed: 9,
            ..Default::default()
        };
        let w = Workload::generate(&cfg, &fillers());
        let mut sum = 0usize;
        let mut long = 0usize;
        for i in &w.items {
            assert!((1..=cap).contains(&i.max_new_tokens));
            sum += i.max_new_tokens;
            if i.max_new_tokens as f64 > 2.0 * mean {
                long += 1;
            }
        }
        let empirical = sum as f64 / w.items.len() as f64;
        assert!(
            (empirical - mean).abs() < 0.1 * mean,
            "empirical mean {empirical:.2} strayed from configured {mean}"
        );
        // An exponential tail has mass beyond 2×mean (≈ e⁻² ≈ 13.5%) —
        // the long-tail shape, not just the mean, is what stresses
        // full-budget reservation.
        let frac = long as f64 / w.items.len() as f64;
        assert!((0.08..=0.20).contains(&frac), "P(len > 2·mean) = {frac:.3}");
    }

    #[test]
    fn batch_frac_mixes_classes_deterministically() {
        let base = WorkloadCfg { n_requests: 64, seed: 13, ..Default::default() };
        // Default is the single-class trace every earlier scenario used.
        let w0 = Workload::generate(&base, &fillers());
        assert!(w0.items.iter().all(|i| i.priority == Priority::Interactive));
        let w1 = Workload::generate(
            &WorkloadCfg { batch_frac: 1.0, ..base.clone() },
            &fillers(),
        );
        assert!(w1.items.iter().all(|i| i.priority == Priority::Batch));
        let cfg = WorkloadCfg { batch_frac: 0.5, ..base.clone() };
        let wa = Workload::generate(&cfg, &fillers());
        let wb = Workload::generate(&cfg, &fillers());
        let classes: Vec<Priority> = wa.items.iter().map(|i| i.priority).collect();
        assert_eq!(
            classes,
            wb.items.iter().map(|i| i.priority).collect::<Vec<_>>(),
            "same seed must draw the same classes"
        );
        let batch = classes.iter().filter(|&&p| p == Priority::Batch).count();
        assert!(
            (16..=48).contains(&batch),
            "half-and-half mix badly skewed: {batch}/64 batch"
        );
        // The class draw must not perturb the rest of the trace.
        for (a, b) in w0.items.iter().zip(&wa.items) {
            assert_eq!(a.prompt, b.prompt);
            assert_eq!(a.max_new_tokens, b.max_new_tokens);
        }
    }

    #[test]
    fn slo_annotation_is_per_class_and_does_not_perturb_the_trace() {
        let base = WorkloadCfg { n_requests: 32, batch_frac: 0.5, seed: 21, ..Default::default() };
        let plain = Workload::generate(&base, &fillers());
        assert!(plain.items.iter().all(|i| i.slo_ms.is_none()), "default is SLO-less");
        let slod = Workload::generate(
            &WorkloadCfg {
                slo_ms_interactive: Some(250.0),
                slo_ms_batch: Some(60_000.0),
                ..base.clone()
            },
            &fillers(),
        );
        for (a, b) in plain.items.iter().zip(&slod.items) {
            // Annotation must ride along, never reshuffle the trace.
            assert_eq!(a.prompt, b.prompt);
            assert_eq!(a.max_new_tokens, b.max_new_tokens);
            assert_eq!(a.priority, b.priority);
            let want = match b.priority {
                Priority::Interactive => Some(250.0),
                Priority::Batch => Some(60_000.0),
            };
            assert_eq!(b.slo_ms, want);
        }
    }

    #[test]
    fn slo_jitter_spreads_deadlines_without_perturbing_the_trace() {
        let base = WorkloadCfg {
            n_requests: 48,
            batch_frac: 0.25,
            slo_ms_interactive: Some(200.0),
            slo_ms_batch: Some(40_000.0),
            seed: 33,
            ..Default::default()
        };
        let plain = Workload::generate(&base, &fillers());
        let jittered = Workload::generate(
            &WorkloadCfg { slo_jitter_frac: 0.5, ..base.clone() },
            &fillers(),
        );
        let mut distinct = std::collections::HashSet::new();
        for (a, b) in plain.items.iter().zip(&jittered.items) {
            // Jitter rides along: everything else byte-identical.
            assert_eq!(a.prompt, b.prompt);
            assert_eq!(a.max_new_tokens, b.max_new_tokens);
            assert_eq!(a.priority, b.priority);
            assert_eq!(a.arrival_s, b.arrival_s);
            let (base_ms, got) = (a.slo_ms.unwrap(), b.slo_ms.unwrap());
            assert!(
                got >= base_ms * 0.5 - 1e-9 && got <= base_ms * 1.5 + 1e-9,
                "jittered SLO {got} outside ±50% of {base_ms}"
            );
            assert!(got > 0.0, "jitter must never stamp a non-positive budget");
            distinct.insert(got.to_bits());
        }
        assert!(distinct.len() > 1, "a 0.5 jitter must actually spread deadlines");
        // Deterministic: the same seed redraws the same jitter.
        let again = Workload::generate(
            &WorkloadCfg { slo_jitter_frac: 0.5, ..base.clone() },
            &fillers(),
        );
        for (a, b) in jittered.items.iter().zip(&again.items) {
            assert_eq!(a.slo_ms, b.slo_ms);
        }
        // Default (0) pins the un-jittered stamping byte-identically.
        let zero = Workload::generate(&base, &fillers());
        for (a, b) in plain.items.iter().zip(&zero.items) {
            assert_eq!(a.slo_ms, b.slo_ms);
        }
    }

    #[test]
    fn multi_turn_sessions_extend_history_and_ride_along() {
        let base = WorkloadCfg {
            n_requests: 8,
            rate: 10.0,
            prompt_len: (10, 20),
            gen_len: (4, 8),
            seed: 7,
            ..Default::default()
        };
        let single = Workload::generate(&base, &fillers());
        assert!(single.items.iter().all(|i| i.session.is_none() && i.turn == 0));
        let multi = Workload::generate(
            &WorkloadCfg { turns_per_session: 3, think_time_gap: 0.5, ..base.clone() },
            &fillers(),
        );
        assert_eq!(multi.items.len(), 8 * 3);
        // Turn-0 items are the base trace, byte-identical and in the
        // same relative order (turns ride along, never reshuffle).
        let turn0: Vec<&TraceItem> = multi.items.iter().filter(|i| i.turn == 0).collect();
        assert_eq!(turn0.len(), 8);
        for (a, b) in single.items.iter().zip(&turn0) {
            assert_eq!(a.prompt, b.prompt);
            assert_eq!(a.arrival_s, b.arrival_s);
            assert_eq!(a.max_new_tokens, b.max_new_tokens);
        }
        // Within a session: each turn's prompt strictly extends the
        // previous turn's (the radix-shared history) and arrives one
        // think-time gap later.
        for s in 0..8u64 {
            let mut turns: Vec<&TraceItem> =
                multi.items.iter().filter(|i| i.session == Some(s)).collect();
            turns.sort_by_key(|i| i.turn);
            assert_eq!(turns.len(), 3);
            for w in turns.windows(2) {
                assert!(
                    w[1].prompt.starts_with(&w[0].prompt)
                        && w[1].prompt.len() > w[0].prompt.len(),
                    "turn {} must extend turn {}'s history",
                    w[1].turn,
                    w[0].turn
                );
                assert!((w[1].arrival_s - w[0].arrival_s - 0.5).abs() < 1e-12);
                assert_eq!(w[1].priority, w[0].priority);
            }
        }
        // Arrival-sorted and deterministic.
        for pair in multi.items.windows(2) {
            assert!(pair[1].arrival_s >= pair[0].arrival_s);
        }
        let again = Workload::generate(
            &WorkloadCfg { turns_per_session: 3, think_time_gap: 0.5, ..base.clone() },
            &fillers(),
        );
        for (a, b) in multi.items.iter().zip(&again.items) {
            assert_eq!(a.prompt, b.prompt);
            assert_eq!(a.arrival_s, b.arrival_s);
            assert_eq!((a.session, a.turn), (b.session, b.turn));
        }
    }

    #[test]
    fn branch_factor_forks_identical_sibling_prompts() {
        let base = WorkloadCfg {
            n_requests: 4,
            prompt_len: (10, 20),
            gen_len: (4, 8),
            seed: 11,
            ..Default::default()
        };
        let w = Workload::generate(
            &WorkloadCfg { turns_per_session: 2, branch_factor: 3, ..base.clone() },
            &fillers(),
        );
        // 4 sessions × 2 turns × 3 branches.
        assert_eq!(w.items.len(), 4 * 2 * 3);
        for s in 0..4u64 {
            for turn in 0..2u32 {
                let sibs: Vec<&TraceItem> = w
                    .items
                    .iter()
                    .filter(|i| i.session == Some(s) && i.turn == turn)
                    .collect();
                assert_eq!(sibs.len(), 3, "session {s} turn {turn}");
                // Regeneration forks: byte-identical prompts at the
                // same arrival — full prompt-block sharing, decoded
                // tails diverge via COW.
                for sib in &sibs {
                    assert_eq!(sib.prompt, sibs[0].prompt);
                    assert_eq!(sib.arrival_s, sibs[0].arrival_s);
                }
            }
        }
        // Branching alone (single turn) still forks the base prompt.
        let forked = Workload::generate(
            &WorkloadCfg { branch_factor: 2, ..base.clone() },
            &fillers(),
        );
        assert_eq!(forked.items.len(), 4 * 2);
        let base_trace = Workload::generate(&base, &fillers());
        for s in 0..4u64 {
            let sibs: Vec<&TraceItem> =
                forked.items.iter().filter(|i| i.session == Some(s)).collect();
            assert_eq!(sibs.len(), 2);
            assert_eq!(sibs[0].prompt, sibs[1].prompt);
            assert_eq!(sibs[0].prompt, base_trace.items[s as usize].prompt);
        }
    }

    #[test]
    fn lengths_respect_bounds() {
        let cfg = WorkloadCfg {
            n_requests: 40,
            prompt_len: (50, 60),
            gen_len: (5, 8),
            ..Default::default()
        };
        let w = Workload::generate(&cfg, &fillers());
        for i in &w.items {
            assert!(i.prompt.len() <= 60);
            assert!((5..=8).contains(&i.max_new_tokens));
        }
    }
}
