//! Perplexity eval documents (`artifacts/eval_{profile}.npz`).

use std::path::Path;

use anyhow::{anyhow, Result};
use xla::{FromRawBytes, Literal};

/// Token matrix `[n_docs, doc_len]` for one corpus profile.
pub struct EvalDocs {
    pub profile: String,
    pub docs: Vec<Vec<i32>>,
}

impl EvalDocs {
    pub fn load(artifacts: &Path, profile: &str) -> Result<Self> {
        let path = artifacts.join(format!("eval_{profile}.npz"));
        let lits = Literal::read_npz_by_name(&path, &(), &["tokens"])
            .map_err(|e| anyhow!("loading {}: {e}", path.display()))?;
        let lit = &lits[0];
        let shape = lit.array_shape().map_err(|e| anyhow!("{e}"))?;
        let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
        let flat = lit.to_vec::<i32>().map_err(|e| anyhow!("{e}"))?;
        let (n, len) = (dims[0], dims[1]);
        let docs = (0..n).map(|i| flat[i * len..(i + 1) * len].to_vec()).collect();
        Ok(Self { profile: profile.to_string(), docs })
    }

    pub fn doc_len(&self) -> usize {
        self.docs.first().map(|d| d.len()).unwrap_or(0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::artifacts_dir;

    #[test]
    fn loads_eval_docs() {
        let dir = artifacts_dir();
        if !dir.join("eval_wiki.npz").exists() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let docs = EvalDocs::load(&dir, "wiki").unwrap();
        assert!(!docs.docs.is_empty());
        assert!(docs.doc_len() >= 128);
        assert!(docs.docs.iter().flatten().all(|&t| (0..256).contains(&t)));
    }
}
