//! `repro` — the Loki serving CLI (leader entrypoint).
//!
//! Subcommands:
//!   info                         — print manifest / model summary
//!   generate --prompt "..."      — one-shot generation
//!   serve --listen HOST:PORT     — JSON-lines TCP inference server
//!   bench-serve                  — offline throughput run over a trace
//!   trace-check FILE.jsonl       — verify a flight-recorder trace's
//!                                  conservation invariants
//!
//! Attention variant flags (all subcommands): --variant full|loki|topk|
//! h2o|pcaattn, --kf FRAC, --df FRAC, --pca NAME.
//!
//! `--trace-out FILE.jsonl` (generate/serve/bench-serve) dumps the
//! engine's flight recorder after the run: the JSONL event log plus a
//! Chrome `trace_event` sibling (`FILE.chrome.json`) loadable in
//! `chrome://tracing` / Perfetto.

use std::sync::mpsc::channel;

use anyhow::{bail, Context, Result};

use loki::coordinator::{
    AdmissionPolicy, Engine, EngineClock, EngineConfig, PoolConfig, PreemptMode,
    SchedulerPolicy, ShedPolicy, VictimPolicy,
};
use loki::coordinator::request::{GenRequest, Priority};
use loki::coordinator::sampler::SampleCfg;
use loki::data::workload::{Workload, WorkloadCfg};
use loki::data::TaskSuite;
use loki::model::ByteTokenizer;
use loki::runtime::{DecodeVariant, RuntimeService};
use loki::util::args::Args;
use loki::util::artifacts_dir;

fn main() -> Result<()> {
    let args = Args::from_env();
    let cmd = args.positional.first().map(|s| s.as_str()).unwrap_or("help");
    match cmd {
        "info" => info(),
        "generate" => generate(&args),
        "serve" => serve(&args),
        "bench-serve" => bench_serve(&args),
        "trace-check" => trace_check(&args),
        _ => {
            eprintln!(
                "usage: repro <info|generate|serve|bench-serve|trace-check> [options]\n\
                 \n\
                 common options:\n\
                 \x20 --variant full|loki|topk|h2o|pcaattn   (default full)\n\
                 \x20 --kf 0.25 --df 0.25                    Loki budgets\n\
                 \x20 --pca wiki_pre                          calibration basis\n\
                 \x20 --scheduler prefill-first|decode-first\n\
                 \x20 --block-size 16                         KV-pool page size (tokens)\n\
                 \x20 --pool-blocks 0                         pool blocks (0 = worst-case)\n\
                 \x20 --no-prefix-share                       disable prompt-block sharing\n\
                 \x20 --admission full|speculative            KV reservation policy\n\
                 \x20 --reserve-frac 0.25                     speculative decode-budget fraction\n\
                 \x20 --headroom-blocks 2                     blocks per speculative grow\n\
                 \x20 --victim-policy youngest|priority|deadline|idle-leaf\n\
                 \x20                                         preemption victim selection\n\
                 \x20                                         (idle-leaf: most private radix-\n\
                 \x20                                         leaf blocks first)\n\
                 \x20 --preempt full|partial                  whole vs tail-block eviction\n\
                 \x20 --aging-steps N                         cross-class aging bound in decode\n\
                 \x20                                         steps (deadline policy; 0 = off)\n\
                 \x20 --shed-policy off|strict|hedged         predictive early load shedding\n\
                 \x20 --shed-margin 0.1                       (hedged) shed only past this\n\
                 \x20                                         fraction over the deadline\n\
                 \x20 --prefill-chunk N                       chunked prefill: N tokens per\n\
                 \x20                                         scheduling round (0 = monolithic)\n\
                 \x20 --trace-out FILE.jsonl                  dump the flight recorder after\n\
                 \x20                                         the run (+ FILE.chrome.json)\n\
                 \x20 --prefix-prefill-discount               Steps clock: charge no prefill\n\
                 \x20                                         time for prefix-shared blocks\n\
                 sharded serving (serve / bench-serve):\n\
                 \x20 --replicas N                            engine replicas (default 1)\n\
                 \x20 --route-policy round-robin|prefix-affinity\n\
                 \x20 --max-load-skew N                       affinity's load-override bound\n\
                 generate: --prompt STR --max-tokens N --temperature T\n\
                 \x20         --priority interactive|batch --slo-ms MS\n\
                 serve:    --listen 127.0.0.1:7077   (scrape live metrics with a\n\
                 \x20        {{\"stats\": true}} protocol line)\n\
                 bench-serve: --requests N --rate R --shared-prefix BYTES --batch-frac F\n\
                 \x20            --prefix-groups N (distinct shared prefixes)\n\
                 \x20            --slo-ms MS (interactive SLO) --batch-slo-ms MS\n\
                 \x20            --slo-jitter F (per-request SLO jitter fraction)\n\
                 \x20            --turns N (conversation turns per session)\n\
                 \x20            --think-time S (seconds between a session's turns)\n\
                 \x20            --branch-factor N (identical-prompt forks per turn)\n\
                 \x20            --shed-retries N (resubmit shed requests after their\n\
                 \x20            retry_after_ms hint; default 1)\n\
                 trace-check: FILE.jsonl [FILE.jsonl ...] — exit non-zero on lifecycle\n\
                 \x20            violations; multiple files also enforce disjoint\n\
                 \x20            per-replica admission"
            );
            Ok(())
        }
    }
}

/// Parse the shared attention-variant flags.
fn variant_from_args(args: &Args, svc: &RuntimeService) -> Result<DecodeVariant> {
    let man = &svc.manifest;
    let kf = args.f64_or("kf", 0.25);
    let df = args.f64_or("df", 0.25);
    Ok(match args.str_or("variant", "full").as_str() {
        "full" => DecodeVariant::Full,
        "loki" => DecodeVariant::loki_fractions(man, kf, df),
        "topk" => DecodeVariant::exact_topk(man, kf),
        "h2o" => DecodeVariant::h2o_fraction(man, kf),
        "pcaattn" => DecodeVariant::pcaattn_fraction(man, df),
        v => bail!("unknown --variant {v}"),
    })
}

fn engine_config(args: &Args, svc: &RuntimeService) -> Result<EngineConfig> {
    Ok(EngineConfig {
        pca: args.str_or("pca", &svc.manifest.default_pca),
        variant: variant_from_args(args, svc)?,
        gang_batch: args.usize_or("batch", usize::MAX),
        scheduler: match args.str_or("scheduler", "prefill-first").as_str() {
            "decode-first" => SchedulerPolicy::DecodeFirst,
            _ => SchedulerPolicy::PrefillFirst,
        },
        max_queue: args.usize_or("max-queue", 256),
        pool: PoolConfig {
            block_size: args.usize_or("block-size", 16),
            num_blocks: args.usize_or("pool-blocks", 0),
            prefix_sharing: !args.flag("no-prefix-share"),
        },
        admission: match args.str_or("admission", "full").as_str() {
            "speculative" | "spec" => AdmissionPolicy::Speculative {
                reserve_frac: args.f64_or("reserve-frac", 0.25),
                headroom_blocks: args.usize_or("headroom-blocks", 2),
            },
            "full" => AdmissionPolicy::ReserveFull,
            other => bail!("unknown --admission {other} (full|speculative)"),
        },
        victim_policy: match args.str_or("victim-policy", "youngest").as_str() {
            "youngest" | "youngest-first" => VictimPolicy::YoungestFirst,
            "priority" | "priority-aware" => VictimPolicy::PriorityAware,
            "deadline" | "deadline-aware" => VictimPolicy::DeadlineAware,
            "idle-leaf" | "idle" => VictimPolicy::IdleLeaf,
            other => {
                bail!("unknown --victim-policy {other} (youngest|priority|deadline|idle-leaf)")
            }
        },
        preempt: match args.str_or("preempt", "full").as_str() {
            "full" => PreemptMode::Full,
            "partial" => PreemptMode::Partial,
            other => bail!("unknown --preempt {other} (full|partial)"),
        },
        aging_steps: match args.usize_or("aging-steps", 0) {
            0 => None,
            n => Some(n as u64),
        },
        shed: {
            let spelled = args.str_or("shed-policy", "off");
            let margin = args.f64_or("shed-margin", 0.1);
            match ShedPolicy::parse(&spelled, margin) {
                Some(p) => p,
                None => bail!("unknown --shed-policy {spelled} (off|strict|hedged)"),
            }
        },
        // Serving always runs on the wall clock; the deterministic
        // decode-steps twin is a test/bench harness knob.
        clock: EngineClock::Wall,
        prefill_chunk: match args.usize_or("prefill-chunk", 0) {
            0 => None,
            n => Some(n),
        },
        prefix_prefill_discount: args.flag("prefix-prefill-discount"),
        verbose: args.flag("verbose"),
    })
}

/// Parse the sharded-serving flags shared by `serve` and `bench-serve`:
/// `--replicas N` (default 1) and `--route-policy round-robin|
/// prefix-affinity` (default round-robin), plus the affinity policy's
/// `--max-load-skew` bound. `block_size` comes from the engine config so
/// the router hashes prompts at the replicas' actual page size.
fn router_cfg_from_args(args: &Args, cfg: &EngineConfig) -> Result<loki::coordinator::RouterCfg> {
    let spelled = args.str_or("route-policy", "round-robin");
    let policy = match loki::coordinator::RoutePolicy::parse(&spelled) {
        Some(p) => p,
        None => bail!("unknown --route-policy {spelled} (round-robin|prefix-affinity)"),
    };
    Ok(loki::coordinator::RouterCfg {
        replicas: args.usize_or("replicas", 1).max(1),
        policy,
        block_size: cfg.pool.block_size,
        max_load_skew: args.usize_or("max-load-skew", 8),
    })
}

/// Optional `--slo-ms`-style flag: absent → no deadline; present → must
/// pass [`loki::server::validate_slo_ms`], the same rule the server
/// applies to the JSON `"slo_ms"` field (positive, finite, ≤ the
/// default cap) — the CLI must never accept a deadline the protocol
/// would reject.
fn slo_ms_arg(args: &Args, name: &str) -> Result<Option<f64>> {
    // A bare `--slo-ms` (no value — the parser files it as a flag) must
    // be an error, not a silently-undeadlined request.
    if args.flag(name) {
        bail!("--{name} needs a value in milliseconds");
    }
    match args.get(name) {
        None => Ok(None),
        Some(raw) => {
            let ms: f64 = raw
                .parse()
                .map_err(|_| anyhow::anyhow!("--{name} expects a number, got {raw:?}"))?;
            loki::server::validate_slo_ms(ms, loki::server::DEFAULT_SLO_MS_CAP)
                .map_err(|e| anyhow::anyhow!("--{name}: {e}"))?;
            Ok(Some(ms))
        }
    }
}

/// `--trace-out FILE.jsonl`: after the run, dump the engine's flight
/// recorder as a JSONL event log plus a Chrome `trace_event` sibling.
/// Absent flag → no files touched (tracing still ran in-memory).
fn maybe_write_trace(args: &Args, metrics: &loki::coordinator::EngineMetrics) -> Result<()> {
    if args.flag("trace-out") {
        bail!("--trace-out needs a file path");
    }
    let Some(raw) = args.get("trace-out") else {
        return Ok(());
    };
    let path = std::path::PathBuf::from(raw);
    loki::obs::export::write_jsonl(&metrics.trace, &path)?;
    let chrome = loki::obs::export::chrome_sibling(&path);
    loki::obs::export::write_chrome(&metrics.trace, &chrome)?;
    eprintln!(
        "[trace] {} events ({} dropped) -> {} + {}",
        metrics.trace.len(),
        metrics.trace.dropped(),
        path.display(),
        chrome.display()
    );
    Ok(())
}

/// `repro trace-check FILE.jsonl [FILE.jsonl …]` — parse one or more
/// flight-recorder dumps and verify their lifecycle conservation
/// invariants (every admitted request reaches exactly one terminal;
/// admitted = finished + shed + rejected + in-flight; no ring
/// overwrites). With multiple files — the per-replica traces of one
/// sharded run — it additionally enforces the routing invariant: a
/// request routed to replica R lives its whole lifecycle on R, so no id
/// may be admitted in more than one trace. Non-zero exit on any
/// violation, so CI can gate on it.
fn trace_check(args: &Args) -> Result<()> {
    let paths = &args.positional[1..];
    if paths.is_empty() {
        bail!("usage: repro trace-check FILE.jsonl [FILE.jsonl ...]");
    }
    let mut labeled = Vec::with_capacity(paths.len());
    let mut total_violations = 0usize;
    for path in paths {
        let src = std::fs::read_to_string(path).with_context(|| format!("read {path}"))?;
        let check = loki::obs::export::check_jsonl(&src)?;
        println!(
            "{path}: {} events | admitted {} = finished {} + shed {} + rejected {} + in-flight {}",
            check.events,
            check.admitted,
            check.finished,
            check.shed,
            check.rejected,
            check.in_flight
        );
        for v in &check.violations {
            eprintln!("violation: {v}");
        }
        total_violations += check.violations.len();
        labeled.push((path.clone(), check));
    }
    if labeled.len() > 1 {
        let cross = loki::obs::export::cross_replica_violations(&labeled);
        for v in &cross {
            eprintln!("violation: {v}");
        }
        if cross.is_empty() {
            println!("cross-replica: {} traces admit disjoint id sets", labeled.len());
        }
        total_violations += cross.len();
    }
    if total_violations == 0 {
        println!("conservation: OK");
        Ok(())
    } else {
        bail!("{total_violations} conservation violation(s)");
    }
}

fn info() -> Result<()> {
    let svc = RuntimeService::start(artifacts_dir()).context("starting runtime")?;
    let m = &svc.manifest;
    println!("model: {} ({} params approx)", m.model.name, approx_params(m));
    println!(
        "  d_model={} layers={} heads={} head_dim={} d_ff={} vocab={} max_len={}",
        m.model.d_model,
        m.model.n_layers,
        m.model.n_heads,
        m.model.head_dim,
        m.model.d_ff,
        m.model.vocab_size,
        m.model.max_len
    );
    println!("batch buckets: {:?} | prefill buckets: {:?}", m.batch_buckets, m.prefill_buckets);
    println!("graphs ({}):", m.graphs.len());
    for name in m.graphs.keys() {
        println!("  {name}");
    }
    let pca_names: Vec<_> = m.pca.keys().collect();
    println!("pca calibrations: {pca_names:?} (default {})", m.default_pca);
    Ok(())
}

fn approx_params(m: &loki::runtime::Manifest) -> String {
    let d = m.model.d_model;
    let qkv = m.model.n_heads * m.model.head_dim;
    let per_layer = 4 * d * qkv + 3 * d * m.model.d_ff + 2 * d;
    let n = m.model.vocab_size * d * 2 + m.model.n_layers * per_layer + d;
    if n > 1_000_000 {
        format!("{:.1}M", n as f64 / 1e6)
    } else {
        format!("{:.0}K", n as f64 / 1e3)
    }
}

fn generate(args: &Args) -> Result<()> {
    let prompt = args.str_or("prompt", "the code of ");
    let max_tokens = args.usize_or("max-tokens", 48);
    let svc = RuntimeService::start(artifacts_dir()).context("starting runtime")?;
    let cfg = engine_config(args, &svc)?;
    let engine = Engine::new(&svc, cfg.clone());
    let (tx, rx) = Engine::channel(&cfg);
    let (reply, result_rx) = channel();
    let tok = ByteTokenizer;
    let priority = match Priority::parse(&args.str_or("priority", "interactive")) {
        Some(p) => p,
        None => bail!("unknown --priority (interactive|batch)"),
    };
    let slo_ms = slo_ms_arg(args, "slo-ms")?;
    tx.send(GenRequest {
        id: 1,
        prompt: tok.encode(&prompt),
        max_new_tokens: max_tokens,
        stop_token: Some(b'\n' as i32),
        sampling: SampleCfg {
            temperature: args.f64_or("temperature", 0.0) as f32,
            top_p: 0.95,
            seed: 1,
        },
        priority,
        turn: 0,
        slo_ms,
        reply,
    })
    .ok();
    drop(tx);
    let metrics = engine.run(rx)?;
    let res = result_rx.recv().context("no result")?;
    println!("prompt:  {prompt}");
    println!("output:  {}", res.text);
    println!(
        "({} tokens, {:?}, ttft {:.3}s, total {:.3}s)",
        res.tokens.len(),
        res.finished_reason,
        res.timing.ttft_s,
        res.timing.total_s
    );
    if args.flag("report") {
        println!("\n{}", metrics.report());
    }
    maybe_write_trace(args, &metrics)?;
    Ok(())
}

fn serve(args: &Args) -> Result<()> {
    let listen = args.str_or("listen", "127.0.0.1:7077");
    let svc = RuntimeService::start(artifacts_dir()).context("starting runtime")?;
    let cfg = engine_config(args, &svc)?;
    let router_cfg = router_cfg_from_args(args, &cfg)?;
    // Protocol-level cap: asking for more decode than the cache can hold
    // is a client error answered immediately, not a queue entry.
    let server_cfg = loki::server::ServerCfg {
        max_tokens_cap: svc.manifest.model.max_len,
        ..Default::default()
    };
    if router_cfg.replicas == 1 {
        // Single-replica shape, unchanged: engine on the main thread,
        // listener on a helper.
        let hub = loki::obs::new_hub();
        let engine = Engine::new(&svc, cfg.clone()).with_stats_hub(hub.clone());
        let (tx, rx) = Engine::channel(&cfg);
        let server_tx = tx.clone();
        let server = std::thread::spawn(move || {
            let listener = std::net::TcpListener::bind(&listen)
                .unwrap_or_else(|e| panic!("bind {listen}: {e}"));
            loki::server::serve_listener(listener, server_tx, server_cfg, Some(hub))
                .expect("server")
        });
        let metrics = engine.run(rx)?;
        println!("{}", metrics.report());
        maybe_write_trace(args, &metrics)?;
        let _ = server.join();
        return Ok(());
    }
    // Sharded serving: one engine (own KV pool, own queue, own stats
    // hub) per replica on its own thread; the frontend routes every
    // connection's requests across them.
    let mut submits = Vec::with_capacity(router_cfg.replicas);
    let mut hubs = Vec::with_capacity(router_cfg.replicas);
    let mut workers = Vec::with_capacity(router_cfg.replicas);
    let mut evict_rxs = Vec::with_capacity(router_cfg.replicas);
    for i in 0..router_cfg.replicas {
        let hub = loki::obs::new_hub();
        // Eviction feedback: each engine reports physically freed prefix
        // blocks so the frontend can erase them from the router's
        // per-replica affinity mirror instead of routing on stale hashes.
        let (etx, erx) = channel();
        evict_rxs.push(erx);
        let engine = Engine::new(&svc, cfg.clone())
            .with_stats_hub(hub.clone())
            .with_evict_feedback(etx);
        let (tx, rx) = Engine::channel(&cfg);
        submits.push(tx);
        hubs.push(hub);
        workers.push(
            std::thread::Builder::new()
                .name(format!("engine-{i}"))
                .spawn(move || engine.run(rx))
                .with_context(|| format!("spawn engine {i}"))?,
        );
    }
    let fe = std::sync::Arc::new(
        loki::server::Frontend::new(router_cfg, submits, hubs)?.with_evict_feedback(evict_rxs)?,
    );
    let listener =
        std::net::TcpListener::bind(&listen).with_context(|| format!("bind {listen}"))?;
    loki::server::serve_frontend(listener, fe, server_cfg)?;
    for w in workers {
        match w.join() {
            Ok(Ok(metrics)) => println!("{}", metrics.report()),
            Ok(Err(e)) => eprintln!("[serve] engine error: {e}"),
            Err(_) => eprintln!("[serve] engine thread panicked"),
        }
    }
    Ok(())
}

/// In-flight bookkeeping for the bench client: which trace item a
/// request id belongs to, which retry attempt it is, and which replica
/// it was routed to.
#[derive(Clone, Copy)]
struct InFlight {
    item: usize,
    attempt: usize,
    replica: usize,
}

/// `foo.jsonl` → `foo-r2.jsonl`: per-replica trace paths for sharded
/// bench runs.
fn replica_trace_path(raw: &str, i: usize) -> std::path::PathBuf {
    let p = std::path::Path::new(raw);
    let stem = p.file_stem().and_then(|s| s.to_str()).unwrap_or("trace");
    match p.extension().and_then(|s| s.to_str()) {
        Some(ext) => p.with_file_name(format!("{stem}-r{i}.{ext}")),
        None => p.with_file_name(format!("{stem}-r{i}")),
    }
}

#[allow(clippy::disallowed_methods)] // genuine wall measurement: client-side E2E latency
fn bench_serve(args: &Args) -> Result<()> {
    use std::collections::HashMap;
    use std::sync::atomic::{AtomicU64, Ordering};
    use std::sync::{Arc, Mutex};

    let svc = RuntimeService::start(artifacts_dir()).context("starting runtime")?;
    let cfg = engine_config(args, &svc)?;
    let router_cfg = router_cfg_from_args(args, &cfg)?;
    // Shed-aware client backoff: a shed reply is resubmitted after its
    // `retry_after_ms` hint, up to this many times per request. Retries
    // route through the frontend again, so with >1 replica they land on
    // a sibling of the replica that shed them.
    let shed_retries = args.usize_or("shed-retries", 1);
    let suite = TaskSuite::load(&artifacts_dir())?;
    let wl = Workload::generate(
        &WorkloadCfg {
            n_requests: args.usize_or("requests", 24),
            rate: args.f64_or("rate", 0.0),
            shared_prefix_len: args.usize_or("shared-prefix", 0),
            prefix_group_count: args.usize_or("prefix-groups", 1),
            batch_frac: args.f64_or("batch-frac", 0.0),
            slo_ms_interactive: slo_ms_arg(args, "slo-ms")?,
            slo_ms_batch: slo_ms_arg(args, "batch-slo-ms")?,
            slo_jitter_frac: args.f64_or("slo-jitter", 0.0),
            turns_per_session: args.usize_or("turns", 1),
            think_time_gap: args.f64_or("think-time", 0.0),
            branch_factor: args.usize_or("branch-factor", 1),
            ..Default::default()
        },
        &suite.fillers,
    );
    let mut submits = Vec::with_capacity(router_cfg.replicas);
    let mut workers = Vec::with_capacity(router_cfg.replicas);
    let mut evict_rxs = Vec::with_capacity(router_cfg.replicas);
    for i in 0..router_cfg.replicas {
        let (etx, erx) = channel();
        evict_rxs.push(erx);
        let engine = Engine::new(&svc, cfg.clone()).with_evict_feedback(etx);
        let (tx, rx) = Engine::channel(&cfg);
        submits.push(tx);
        workers.push(
            std::thread::Builder::new()
                .name(format!("engine-{i}"))
                .spawn(move || engine.run(rx))
                .with_context(|| format!("spawn engine {i}"))?,
        );
    }
    let fe = Arc::new(
        loki::server::Frontend::new(router_cfg, submits, Vec::new())?
            .with_evict_feedback(evict_rxs)?,
    );
    let (reply, results) = channel();
    // id → in-flight record. Inserted under the lock *around* the
    // dispatch, so the collector can never receive a result whose id it
    // cannot resolve.
    let in_flight: Arc<Mutex<HashMap<u64, InFlight>>> = Arc::new(Mutex::new(HashMap::new()));
    let items = Arc::new(wl.items);
    let total = items.len();

    let submit = {
        let fe = fe.clone();
        let in_flight = in_flight.clone();
        let items = items.clone();
        let reply = reply.clone();
        std::thread::spawn(move || {
            let tok = ByteTokenizer;
            let start = std::time::Instant::now();
            for (i, item) in items.iter().enumerate() {
                let wait = item.arrival_s - start.elapsed().as_secs_f64();
                if wait > 0.0 {
                    std::thread::sleep(std::time::Duration::from_secs_f64(wait));
                }
                let req = GenRequest {
                    id: i as u64,
                    prompt: tok.encode(&item.prompt),
                    max_new_tokens: item.max_new_tokens,
                    stop_token: None,
                    sampling: SampleCfg::greedy(),
                    priority: item.priority,
                    turn: item.turn,
                    slo_ms: item.slo_ms,
                    reply: reply.clone(),
                };
                let Ok(mut m) = in_flight.lock() else { return };
                if let Ok(replica) = fe.dispatch(req) {
                    m.insert(i as u64, InFlight { item: i, attempt: 0, replica });
                }
                // A failed dispatch means a dead replica; the
                // collector's timeout ends the run.
            }
        })
    };

    let mut finished = 0usize;
    let mut shed_final = 0u64;
    let mut retries_sent = 0u64;
    let sibling_landings = Arc::new(AtomicU64::new(0));
    let mut retry_threads = Vec::new();
    while finished < total {
        let res = match results.recv_timeout(std::time::Duration::from_secs(120)) {
            Ok(r) => r,
            Err(_) => {
                eprintln!("[bench-serve] timed out waiting for {} result(s)", total - finished);
                break;
            }
        };
        let fl = in_flight.lock().ok().and_then(|mut m| m.remove(&res.id));
        let Some(fl) = fl else {
            finished += 1;
            continue;
        };
        if let Some(shed) = res.shed {
            fe.note_shed(fl.replica);
            if fl.attempt < shed_retries {
                retries_sent += 1;
                let fe = fe.clone();
                let in_flight = in_flight.clone();
                let items = items.clone();
                let reply = reply.clone();
                let sibling_landings = sibling_landings.clone();
                retry_threads.push(std::thread::spawn(move || {
                    std::thread::sleep(std::time::Duration::from_secs_f64(
                        (shed.retry_after_ms / 1000.0).max(0.0),
                    ));
                    let tok = ByteTokenizer;
                    let item = &items[fl.item];
                    // Fresh id per attempt: retries must never collide
                    // with first-attempt ids (one disjoint generation
                    // per attempt number).
                    let new_id = (fl.attempt as u64 + 1) * 1_000_000 + fl.item as u64;
                    let req = GenRequest {
                        id: new_id,
                        prompt: tok.encode(&item.prompt),
                        max_new_tokens: item.max_new_tokens,
                        stop_token: None,
                        sampling: SampleCfg::greedy(),
                        priority: item.priority,
                        turn: item.turn,
                        slo_ms: item.slo_ms,
                        reply,
                    };
                    let Ok(mut m) = in_flight.lock() else { return };
                    if let Ok(replica) = fe.dispatch_retry(req, fl.replica) {
                        if replica != fl.replica {
                            sibling_landings.fetch_add(1, Ordering::Relaxed);
                        }
                        m.insert(
                            new_id,
                            InFlight { item: fl.item, attempt: fl.attempt + 1, replica },
                        );
                    }
                }));
                // The retry's own result closes this item.
                continue;
            }
            shed_final += 1;
        } else {
            fe.note_done(fl.replica);
        }
        finished += 1;
    }
    drop(reply);
    let _ = submit.join();
    for t in retry_threads {
        let _ = t.join();
    }
    if retries_sent > 0 || shed_final > 0 {
        println!(
            "[bench-serve] shed backoff: {retries_sent} resubmitted ({} landed on a sibling), {shed_final} shed after retries",
            sibling_landings.load(Ordering::Relaxed)
        );
    }
    // Dropping the frontend drops every submit channel; the engines
    // drain and exit.
    drop(fe);
    let mut reports = Vec::new();
    for w in workers {
        match w.join() {
            Ok(Ok(m)) => reports.push(m),
            Ok(Err(e)) => eprintln!("[bench-serve] engine error: {e}"),
            Err(_) => eprintln!("[bench-serve] engine thread panicked"),
        }
    }
    for (i, m) in reports.iter().enumerate() {
        if reports.len() > 1 {
            println!("=== replica {i} ===");
        }
        println!("{}", m.report());
    }
    if reports.len() == 1 {
        maybe_write_trace(args, &reports[0])?;
    } else {
        if args.flag("trace-out") {
            bail!("--trace-out needs a file path");
        }
        if let Some(raw) = args.get("trace-out") {
            for (i, m) in reports.iter().enumerate() {
                let path = replica_trace_path(raw, i);
                loki::obs::export::write_jsonl(&m.trace, &path)?;
                let chrome = loki::obs::export::chrome_sibling(&path);
                loki::obs::export::write_chrome(&m.trace, &chrome)?;
                eprintln!("[trace] replica {i} -> {}", path.display());
            }
        }
    }
    Ok(())
}
