//! Sharded serving frontend: the glue between the deterministic
//! [`Router`] decision core and N live engine replicas.
//!
//! The frontend owns one submission channel and one stats hub per
//! replica plus a mutex-guarded router. Connection threads call
//! [`Frontend::dispatch`], which makes the placement decision under the
//! lock (so the router's load view and prefix mirrors are always
//! consistent) and then submits on the chosen replica's channel
//! *outside* any per-replica state — a full replica backpressures only
//! its own queue. Terminal replies feed [`Frontend::note_done`] /
//! [`Frontend::note_shed`] back into the router's outstanding counts,
//! closing the global admission loop: a replica that sheds drains its
//! routed load, so the skew override steers follow-up traffic to
//! siblings that can absorb it.
//!
//! A single-replica frontend (`Frontend::single`) is the exact old
//! server shape — `serve_listener`'s public signature and the JSON
//! protocol are unchanged for it, which keeps `tests/server_protocol.rs`
//! green without edits.

use std::sync::mpsc::{Receiver, SyncSender};
use std::sync::Mutex;

use anyhow::{bail, Context, Result};

use crate::coordinator::request::GenRequest;
use crate::coordinator::router::{RoutePolicy, Router, RouterCfg};
use crate::obs::{StatsHub, StatsSnapshot};
use crate::util::json::{self, Json};

pub struct Frontend {
    router: Mutex<Router>,
    submits: Vec<SyncSender<GenRequest>>,
    /// One hub per replica (parallel to `submits`), or empty when the
    /// server runs without stats publishing.
    hubs: Vec<StatsHub>,
    /// One eviction-feedback receiver per replica (parallel to
    /// `submits`), or empty when feedback is disabled. Each engine
    /// forwards the hash of every `PoolEvent::PrefixReleased` here; the
    /// frontend drains them into [`Router::note_evicted`] under the
    /// router lock on every dispatch, so the affinity mirror never
    /// counts a prefix the pool has already physically freed.
    evict: Vec<Mutex<Receiver<u64>>>,
}

impl Frontend {
    /// Multi-replica frontend. `submits` must match `cfg.replicas`;
    /// `hubs` must be empty (stats disabled) or match too.
    pub fn new(
        cfg: RouterCfg,
        submits: Vec<SyncSender<GenRequest>>,
        hubs: Vec<StatsHub>,
    ) -> Result<Self> {
        if submits.is_empty() || submits.len() != cfg.replicas.max(1) {
            bail!(
                "frontend needs one submit channel per replica (got {} for {} replicas)",
                submits.len(),
                cfg.replicas.max(1)
            );
        }
        if !hubs.is_empty() && hubs.len() != submits.len() {
            bail!(
                "frontend stats hubs must match replicas (got {} for {})",
                hubs.len(),
                submits.len()
            );
        }
        Ok(Self { router: Mutex::new(Router::new(cfg)), submits, hubs, evict: Vec::new() })
    }

    /// Attach per-replica pool-eviction feedback channels (one
    /// `Receiver<u64>` of released prefix hashes per replica, parallel
    /// to the submit channels). Engines built with
    /// `Engine::with_evict_feedback` send on the matching `Sender`.
    pub fn with_evict_feedback(mut self, rxs: Vec<Receiver<u64>>) -> Result<Self> {
        if rxs.len() != self.submits.len() {
            bail!(
                "eviction feedback needs one receiver per replica (got {} for {})",
                rxs.len(),
                self.submits.len()
            );
        }
        self.evict = rxs.into_iter().map(Mutex::new).collect();
        Ok(self)
    }

    /// The pre-sharding server shape: one replica, trivially routed.
    pub fn single(submit: SyncSender<GenRequest>, stats: Option<StatsHub>) -> Self {
        Self {
            router: Mutex::new(Router::new(RouterCfg {
                replicas: 1,
                policy: RoutePolicy::RoundRobin,
                ..RouterCfg::default()
            })),
            submits: vec![submit],
            hubs: stats.into_iter().collect(),
            evict: Vec::new(),
        }
    }

    pub fn replicas(&self) -> usize {
        self.submits.len()
    }

    pub fn policy(&self) -> RoutePolicy {
        match self.router.lock() {
            Ok(r) => r.policy(),
            Err(_) => RoutePolicy::RoundRobin,
        }
    }

    /// Route and submit one request; returns the replica index it
    /// landed on (the caller pairs it with the terminal reply to call
    /// `note_done`/`note_shed`).
    pub fn dispatch(&self, req: GenRequest) -> Result<usize> {
        self.dispatch_inner(req, None)
    }

    /// Route and submit a shed-retry, steering it away from the replica
    /// that shed it (with >1 replica the retry always lands on a
    /// sibling).
    pub fn dispatch_retry(&self, req: GenRequest, prior: usize) -> Result<usize> {
        self.dispatch_inner(req, Some(prior))
    }

    fn dispatch_inner(&self, req: GenRequest, prior: Option<usize>) -> Result<usize> {
        let replica = {
            let mut router = self
                .router
                .lock()
                .map_err(|_| anyhow::anyhow!("router lock poisoned"))?;
            // Apply pending pool-eviction feedback before deciding, so
            // the affinity score never counts a dead mirror entry.
            for (r, rx) in self.evict.iter().enumerate() {
                if let Ok(rx) = rx.lock() {
                    while let Ok(hash) = rx.try_recv() {
                        router.note_evicted(r, hash);
                    }
                }
            }
            match prior {
                Some(p) => router.route_retry(req.id, &req.prompt, p),
                None => router.route(req.id, &req.prompt),
            }
        };
        let submit = self
            .submits
            .get(replica)
            .context("router picked an unknown replica")?;
        if submit.send(req).is_err() {
            // The replica's engine hung up; release the routed load so
            // the router stops steering traffic at a corpse.
            self.note_done(replica);
            bail!("engine replica {replica} is down");
        }
        Ok(replica)
    }

    /// A dispatched request reached any non-shed terminal reply.
    pub fn note_done(&self, replica: usize) {
        if let Ok(mut router) = self.router.lock() {
            router.note_done(replica);
        }
    }

    /// A dispatched request was shed by its replica.
    pub fn note_shed(&self, replica: usize) {
        if let Ok(mut router) = self.router.lock() {
            router.note_shed(replica);
        }
    }

    /// Requests ever routed, per replica (tests / diagnostics).
    pub fn routed_counts(&self) -> Vec<u64> {
        match self.router.lock() {
            Ok(r) => r.routed().to_vec(),
            Err(_) => Vec::new(),
        }
    }

    /// Render the `{"stats": true}` scrape reply. Single replica keeps
    /// the original `{"stats": …, "prom": …}` shape byte-for-byte;
    /// multi-replica returns the fleet merge in those same fields plus
    /// a `"replicas"` array of per-replica snapshots (`null` for a
    /// replica that has not published a round yet).
    pub fn stats_reply(&self) -> Result<Json> {
        if self.hubs.is_empty() {
            bail!("stats not enabled on this server");
        }
        let mut snaps: Vec<Option<StatsSnapshot>> = Vec::with_capacity(self.hubs.len());
        for hub in &self.hubs {
            let slot = hub
                .lock()
                .map_err(|_| anyhow::anyhow!("stats hub poisoned"))?
                .clone();
            snaps.push(slot);
        }
        if self.hubs.len() == 1 {
            let snap = snaps
                .pop()
                .flatten()
                .context("no stats yet: engine has not completed a scheduling round")?;
            return Ok(json::obj(vec![
                ("stats", snap.to_json()),
                ("prom", json::s(&snap.prometheus())),
            ]));
        }
        let published: Vec<StatsSnapshot> = snaps.iter().flatten().cloned().collect();
        if published.is_empty() {
            bail!("no stats yet: no replica has completed a scheduling round");
        }
        let merged = StatsSnapshot::merged(&published);
        let per_replica: Vec<Json> = snaps
            .iter()
            .map(|s| s.as_ref().map_or(Json::Null, |snap| snap.to_json()))
            .collect();
        Ok(json::obj(vec![
            ("stats", merged.to_json()),
            ("prom", json::s(&merged.prometheus())),
            ("replicas", Json::Arr(per_replica)),
        ]))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::request::Priority;
    use crate::coordinator::sampler::SampleCfg;
    use crate::obs::new_hub;
    use std::sync::mpsc::sync_channel;

    fn req(id: u64, prompt: Vec<i32>) -> (GenRequest, std::sync::mpsc::Receiver<crate::coordinator::request::GenResult>) {
        let (reply, rx) = std::sync::mpsc::channel();
        (
            GenRequest {
                id,
                prompt,
                max_new_tokens: 4,
                stop_token: None,
                sampling: SampleCfg { temperature: 0.0, top_p: 0.95, seed: id },
                priority: Priority::Interactive,
                turn: 0,
                slo_ms: None,
                reply,
            },
            rx,
        )
    }

    #[test]
    fn shape_validation() {
        let (tx, _rx) = sync_channel(4);
        assert!(Frontend::new(RouterCfg { replicas: 2, ..Default::default() }, vec![tx], vec![])
            .is_err());
        let (tx, _rx) = sync_channel::<GenRequest>(4);
        let err = Frontend::new(
            RouterCfg { replicas: 1, ..Default::default() },
            vec![tx],
            vec![new_hub(), new_hub()],
        );
        assert!(err.is_err());
    }

    #[test]
    fn dispatch_routes_round_robin_across_replicas() {
        let (tx0, rx0) = sync_channel(8);
        let (tx1, rx1) = sync_channel(8);
        let fe = Frontend::new(
            RouterCfg { replicas: 2, policy: RoutePolicy::RoundRobin, ..Default::default() },
            vec![tx0, tx1],
            vec![],
        )
        .unwrap();
        let mut landed = Vec::new();
        for id in 0..4 {
            let (r, _reply_rx) = req(id, vec![1, 2, 3]);
            landed.push(fe.dispatch(r).unwrap());
        }
        assert_eq!(landed, vec![0, 1, 0, 1]);
        assert_eq!(rx0.try_iter().count(), 2);
        assert_eq!(rx1.try_iter().count(), 2);
        assert_eq!(fe.routed_counts(), vec![2, 2]);
        for r in landed {
            fe.note_done(r);
        }
    }

    #[test]
    fn evict_feedback_drains_into_the_router_mirror() {
        let bs = RouterCfg::default().block_size;
        let (tx0, _rx0) = sync_channel(8);
        let (tx1, _rx1) = sync_channel(8);
        let (ev0_tx, ev0_rx) = std::sync::mpsc::channel();
        let (ev1_tx, ev1_rx) = std::sync::mpsc::channel();
        let fe = Frontend::new(
            RouterCfg { replicas: 2, policy: RoutePolicy::PrefixAffinity, ..Default::default() },
            vec![tx0, tx1],
            vec![],
        )
        .unwrap()
        .with_evict_feedback(vec![ev0_rx, ev1_rx])
        .unwrap();
        // Shape validation: receiver count must match replicas.
        let (tx, _rx) = sync_channel::<GenRequest>(1);
        let (_etx, erx) = std::sync::mpsc::channel();
        assert!(Frontend::single(tx, None).with_evict_feedback(vec![erx, {
            let (_t, r) = std::sync::mpsc::channel();
            r
        }])
        .is_err());
        // Route a prompt with two full blocks; its hashes are mirrored
        // on the replica it landed on.
        let prompt: Vec<i32> = (0..(2 * bs) as i32).collect();
        let (r0, _reply0) = req(0, prompt.clone());
        let home = fe.dispatch(r0).unwrap();
        fe.note_done(home);
        // The pool releases those prefixes; the next dispatch drains the
        // feedback before routing, so the repeat scores zero matches.
        let ev = [&ev0_tx, &ev1_tx][home];
        for h in crate::kvpool::prefix_block_hashes(&prompt, bs) {
            ev.send(h).unwrap();
        }
        let (r1, _reply1) = req(1, prompt.clone());
        fe.dispatch(r1).unwrap();
        let decided = fe.router.lock().unwrap().decisions().to_vec();
        assert_eq!(decided[1].matched_blocks, 0, "evicted entries must not match");
        fe.note_done(decided[1].replica);
    }

    #[test]
    fn dead_replica_is_an_error_not_a_panic() {
        let (tx, rx) = sync_channel(1);
        let fe = Frontend::single(tx, None);
        drop(rx);
        let (r, _reply_rx) = req(1, vec![1]);
        let err = fe.dispatch(r).unwrap_err();
        assert!(err.to_string().contains("down"), "{err}");
        assert_eq!(fe.routed_counts(), vec![1]);
    }

    #[test]
    fn stats_reply_shapes() {
        let (tx, _rx) = sync_channel::<GenRequest>(1);
        let fe = Frontend::single(tx, None);
        assert!(fe.stats_reply().unwrap_err().to_string().contains("not enabled"));

        let (tx0, _rx0) = sync_channel::<GenRequest>(1);
        let (tx1, _rx1) = sync_channel::<GenRequest>(1);
        let h0 = new_hub();
        let h1 = new_hub();
        let fe = Frontend::new(
            RouterCfg { replicas: 2, ..Default::default() },
            vec![tx0, tx1],
            vec![h0.clone(), h1.clone()],
        )
        .unwrap();
        assert!(fe.stats_reply().unwrap_err().to_string().contains("no stats yet"));
        *h0.lock().unwrap() = Some(StatsSnapshot { requests_in: 3, ..Default::default() });
        *h1.lock().unwrap() = Some(StatsSnapshot { requests_in: 4, ..Default::default() });
        let j = fe.stats_reply().unwrap();
        assert_eq!(j.req("stats").req("requests_in").as_i64(), Some(7));
        assert_eq!(j.req("replicas").as_arr().unwrap().len(), 2);
    }
}
