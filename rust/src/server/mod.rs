//! Minimal TCP JSON-lines inference server over the engine.
//!
//! Protocol: one JSON object per line.
//!   → {"prompt": "...", "max_tokens": 32, "temperature": 0.0,
//!      "priority": "interactive", "slo_ms": 250}
//!   ← {"id": 1, "text": "...", "tokens": 32, "ttft_s": 0.01, "total_s": 0.2}
//!
//! `"priority"` is optional (`"interactive"` | `"batch"`, default
//! interactive) and feeds the engine's multi-class scheduler: under the
//! priority-aware victim policy, batch requests are admitted behind and
//! preempted before interactive ones. Unknown values are a client error.
//!
//! `"slo_ms"` is an optional time-to-first-token SLO in milliseconds,
//! arrival-stamped into an absolute deadline the engine's deadline-aware
//! policy schedules by. It must be a finite number in
//! `(0, slo_ms_cap]` — a negative, zero, non-finite or absurdly large
//! value is a client error, not a silent default. Valid values are
//! echoed back along with `"deadline_hit"` (did the first token beat the
//! deadline).
//!
//! Under a shed policy (`serve --shed-policy strict|hedged`), an SLO'd
//! request whose predicted TTFT provably misses its deadline is answered
//! with a structured **shed reply** instead of queueing to die:
//!   ← {"id": 7, "shed": true, "predicted_ttft_ms": 812.0,
//!      "retry_after_ms": 562.0, "slo_ms": 250, "priority": "interactive"}
//! `predicted_ttft_ms` is the engine's service-rate prediction at the
//! moment of shedding; `retry_after_ms` is how far past the deadline it
//! sat — a hint for client backoff. A shed is not an `"error"`: the
//! request was well-formed, the engine just refused to burn compute on
//! a deadline it proved unreachable.
//!
//! A line of `{"stats": true}` is a **metrics scrape**, not a
//! generation request: the reply carries the engine's latest
//! per-scheduling-round [`crate::obs::StatsSnapshot`] twice — once as
//! structured JSON under `"stats"` and once as a Prometheus text
//! exposition under `"prom"`:
//!   → {"stats": true}
//!   ← {"stats": {"uptime_s": ..., "ttft_s": {...}, ...}, "prom": "..."}
//! Before the engine's first round (or when the server was started
//! without a stats hub) the scrape gets a structured `{"error": ...}`
//! like any other client-visible condition.
//!
//! Malformed or invalid requests get a structured `{"error": "..."}`
//! reply and the connection stays usable for the next line — client bugs
//! must never wedge a connection, let alone the engine behind it
//! (regression-tested in `rust/tests/server_protocol.rs`).
//!
//! `repro serve --listen 127.0.0.1:7077` starts it; `server::client_call`
//! is a tiny blocking client used by tests and demos. Thread-per-
//! connection: the engine's bounded queue provides backpressure.
//!
//! **Sharded serving** (`repro serve --replicas N --route-policy …`):
//! every connection dispatches through a [`Frontend`], which routes each
//! request to one of N engine replicas (see
//! [`crate::coordinator::router`]) and feeds terminal replies back into
//! the router's load view. The wire protocol is unchanged for a single
//! replica; with N > 1 generation and shed replies gain a `"replica"`
//! field (which replica served the request) and the `"stats"` scrape
//! returns the fleet-merged snapshot plus a per-replica array.

pub mod frontend;

pub use frontend::Frontend;

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream, ToSocketAddrs};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{channel, SyncSender};
use std::sync::Arc;

use anyhow::{bail, Context, Result};

use crate::coordinator::request::{GenRequest, Priority};
use crate::coordinator::sampler::SampleCfg;
use crate::model::ByteTokenizer;
use crate::obs::StatsHub;
use crate::util::json::{self, Json};

static NEXT_ID: AtomicU64 = AtomicU64::new(1);

/// Request-validation limits. The default `max_tokens_cap` is a generous
/// protocol bound; `repro serve` tightens it to the model's `max_len`
/// (asking for more decode than the cache can hold is a client error,
/// not a queue entry).
#[derive(Clone, Copy, Debug)]
pub struct ServerCfg {
    pub max_tokens_cap: usize,
    /// Largest accepted `"slo_ms"`. A deadline further out than this is
    /// almost certainly a client unit bug (seconds vs milliseconds, or a
    /// sentinel) — reject it rather than schedule around nonsense.
    pub slo_ms_cap: f64,
}

/// 24 hours — far beyond any serving SLO, tight enough to catch unit
/// mix-ups.
pub const DEFAULT_SLO_MS_CAP: f64 = 86_400_000.0;

/// The single SLO validation rule, shared by the JSON protocol and the
/// CLI flags (`--slo-ms`/`--batch-slo-ms`): positive, finite, at most
/// `cap` milliseconds. Everything else is a client error — scheduling
/// by a mistyped deadline would be an SLO bug twice over.
pub fn validate_slo_ms(ms: f64, cap: f64) -> Result<()> {
    if !ms.is_finite() || ms <= 0.0 {
        bail!("\"slo_ms\" must be a positive number of milliseconds (got {ms})");
    }
    if ms > cap {
        bail!("\"slo_ms\" must be at most {cap} (got {ms})");
    }
    Ok(())
}

impl Default for ServerCfg {
    fn default() -> Self {
        Self { max_tokens_cap: 4096, slo_ms_cap: DEFAULT_SLO_MS_CAP }
    }
}

/// Serve forever on `addr` with default limits.
pub fn serve(addr: &str, submit: SyncSender<GenRequest>) -> Result<()> {
    serve_cfg(addr, submit, ServerCfg::default())
}

/// Serve forever on `addr`, forwarding requests into the engine queue.
pub fn serve_cfg(addr: &str, submit: SyncSender<GenRequest>, cfg: ServerCfg) -> Result<()> {
    let listener = TcpListener::bind(addr).with_context(|| format!("bind {addr}"))?;
    serve_listener(listener, submit, cfg, None)
}

/// Serve forever on an already-bound listener. Tests bind port 0 first
/// to learn the ephemeral address, then hand the listener over.
/// `stats`, when given, backs the `{"stats": true}` scrape command with
/// the engine's live snapshot hub. Single-replica convenience shape —
/// sharded serving builds a [`Frontend`] and calls [`serve_frontend`].
pub fn serve_listener(
    listener: TcpListener,
    submit: SyncSender<GenRequest>,
    cfg: ServerCfg,
    stats: Option<StatsHub>,
) -> Result<()> {
    serve_frontend(listener, Arc::new(Frontend::single(submit, stats)), cfg)
}

/// Serve forever on an already-bound listener, dispatching every
/// request through the frontend's router.
pub fn serve_frontend(listener: TcpListener, fe: Arc<Frontend>, cfg: ServerCfg) -> Result<()> {
    if let Ok(addr) = listener.local_addr() {
        eprintln!(
            "[server] listening on {addr} ({} replica(s), {})",
            fe.replicas(),
            fe.policy().name()
        );
    }
    for stream in listener.incoming() {
        let stream = match stream {
            Ok(s) => s,
            Err(e) => {
                eprintln!("[server] accept error: {e}");
                continue;
            }
        };
        let fe = fe.clone();
        std::thread::spawn(move || {
            if let Err(e) = handle_conn(stream, &fe, cfg) {
                eprintln!("[server] connection error: {e}");
            }
        });
    }
    Ok(())
}

fn handle_conn(stream: TcpStream, fe: &Frontend, cfg: ServerCfg) -> Result<()> {
    let peer = stream.peer_addr().ok();
    let mut writer = stream.try_clone()?;
    let reader = BufReader::new(stream);
    let tok = ByteTokenizer;
    for line in reader.lines() {
        let line = line?;
        if line.trim().is_empty() {
            continue;
        }
        // Errors become structured replies; the read loop continues, so
        // one bad line never poisons the connection.
        let resp = match handle_line(&line, fe, &tok, cfg) {
            Ok(j) => j,
            Err(e) => json::obj(vec![("error", json::s(&e.to_string()))]),
        };
        writer.write_all(resp.to_string().as_bytes())?;
        writer.write_all(b"\n")?;
    }
    eprintln!("[server] {peer:?} disconnected");
    Ok(())
}

fn handle_line(line: &str, fe: &Frontend, tok: &ByteTokenizer, cfg: ServerCfg) -> Result<Json> {
    let req = Json::parse(line).map_err(|e| anyhow::anyhow!("bad request JSON: {e}"))?;
    // A stats scrape is not a generation request: no prompt, no queue
    // entry, answered from the hubs' latest published snapshots (merged
    // across replicas when sharded).
    if req.get("stats").and_then(|v| v.as_bool()) == Some(true) {
        return fe.stats_reply();
    }
    let prompt = req
        .get("prompt")
        .and_then(|p| p.as_str())
        .context("missing \"prompt\"")?;
    if prompt.is_empty() {
        bail!("empty \"prompt\"");
    }
    let max_tokens = match req.get("max_tokens") {
        None => 32,
        Some(v) => v
            .as_usize()
            .context("\"max_tokens\" must be a non-negative integer")?,
    };
    if max_tokens == 0 || max_tokens > cfg.max_tokens_cap {
        bail!("\"max_tokens\" must be in 1..={} (got {max_tokens})", cfg.max_tokens_cap);
    }
    let temperature = req.get("temperature").and_then(|x| x.as_f64()).unwrap_or(0.0) as f32;
    // Optional importance class; an unknown value is a client error (a
    // typo silently demoted to the default would be an SLO bug).
    let priority = match req.get("priority") {
        None => Priority::Interactive,
        Some(v) => {
            let s = v.as_str().context("\"priority\" must be a string")?;
            Priority::parse(s).with_context(|| {
                format!("unknown \"priority\" {s:?} (expected \"interactive\" or \"batch\")")
            })?
        }
    };
    // Optional TTFT SLO; a value outside (0, cap] is a client error.
    let slo_ms = match req.get("slo_ms") {
        None => None,
        Some(v) => {
            let ms = v.as_f64().context("\"slo_ms\" must be a number (milliseconds)")?;
            validate_slo_ms(ms, cfg.slo_ms_cap)?;
            Some(ms)
        }
    };
    // Optional conversation-turn index (0 = first turn). Only feeds
    // per-turn metrics attribution; never changes scheduling.
    let turn = match req.get("turn") {
        None => 0,
        Some(v) => v.as_f64().context("\"turn\" must be a number")? as u32,
    };
    let id = NEXT_ID.fetch_add(1, Ordering::Relaxed);
    let (reply, rx) = channel();
    let replica = fe.dispatch(GenRequest {
        id,
        prompt: tok.encode(prompt),
        max_new_tokens: max_tokens,
        stop_token: Some(b'\n' as i32),
        sampling: SampleCfg { temperature, top_p: 0.95, seed: id },
        priority,
        turn,
        slo_ms,
        reply,
    })?;
    let res = match rx.recv() {
        Ok(r) => r,
        Err(_) => {
            fe.note_done(replica);
            bail!("engine dropped request");
        }
    };
    if res.shed.is_some() {
        fe.note_shed(replica);
    } else {
        fe.note_done(replica);
    }
    if let Some(shed) = res.shed {
        // Predictive admission refused the request: a structured shed
        // reply (not an error — the request was valid, its deadline was
        // just provably unreachable) with the prediction and a backoff
        // hint. No generation fields: nothing was generated.
        let mut fields = vec![
            ("id", json::num(res.id as f64)),
            ("shed", Json::Bool(true)),
            ("predicted_ttft_ms", json::num(shed.predicted_ttft_ms)),
            ("retry_after_ms", json::num(shed.retry_after_ms)),
            ("priority", json::s(priority.name())),
        ];
        if let Some(ms) = slo_ms {
            fields.push(("slo_ms", json::num(ms)));
        }
        if fe.replicas() > 1 {
            fields.push(("replica", json::num(replica as f64)));
        }
        return Ok(json::obj(fields));
    }
    let mut fields = vec![
        ("id", json::num(res.id as f64)),
        ("text", json::s(&res.text)),
        ("tokens", json::num(res.tokens.len() as f64)),
        ("finish", json::s(&format!("{:?}", res.finished_reason))),
        ("priority", json::s(priority.name())),
        ("ttft_s", json::num(res.timing.ttft_s)),
        ("total_s", json::num(res.timing.total_s)),
        ("preemptions", json::num(res.timing.preemptions as f64)),
    ];
    if let Some(ms) = slo_ms {
        fields.push(("slo_ms", json::num(ms)));
        fields.push((
            "deadline_hit",
            res.timing.deadline_hit.map_or(Json::Null, Json::Bool),
        ));
    }
    if fe.replicas() > 1 {
        fields.push(("replica", json::num(replica as f64)));
    }
    Ok(json::obj(fields))
}

/// Blocking one-shot client (tests / demos).
pub fn client_call<A: ToSocketAddrs>(addr: A, prompt: &str, max_tokens: usize) -> Result<Json> {
    let mut stream = TcpStream::connect(addr)?;
    let req = json::obj(vec![
        ("prompt", json::s(prompt)),
        ("max_tokens", json::num(max_tokens as f64)),
    ]);
    stream.write_all(req.to_string().as_bytes())?;
    stream.write_all(b"\n")?;
    let mut reader = BufReader::new(stream);
    let mut line = String::new();
    reader.read_line(&mut line)?;
    Json::parse(&line).map_err(|e| anyhow::anyhow!("bad response: {e}"))
}

/// Blocking one-shot stats scrape (tests / dashboards).
pub fn client_stats<A: ToSocketAddrs>(addr: A) -> Result<Json> {
    let mut stream = TcpStream::connect(addr)?;
    stream.write_all(json::obj(vec![("stats", Json::Bool(true))]).to_string().as_bytes())?;
    stream.write_all(b"\n")?;
    let mut reader = BufReader::new(stream);
    let mut line = String::new();
    reader.read_line(&mut line)?;
    Json::parse(&line).map_err(|e| anyhow::anyhow!("bad response: {e}"))
}
