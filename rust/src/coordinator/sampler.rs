//! Token sampling: greedy, temperature, nucleus (top-p).

use crate::util::rng::Xoshiro256;

#[derive(Clone, Copy, Debug)]
pub struct SampleCfg {
    /// 0.0 → greedy argmax.
    pub temperature: f32,
    /// Nucleus mass; 1.0 disables the top-p cut.
    pub top_p: f32,
    pub seed: u64,
}

impl Default for SampleCfg {
    fn default() -> Self {
        Self { temperature: 0.0, top_p: 1.0, seed: 0 }
    }
}

impl SampleCfg {
    pub fn greedy() -> Self {
        Self::default()
    }

    pub fn creative(seed: u64) -> Self {
        Self { temperature: 0.8, top_p: 0.95, seed }
    }
}

/// Stateful sampler (one per lane; deterministic given the seed).
#[derive(Clone, Debug)]
pub struct Sampler {
    cfg: SampleCfg,
    rng: Xoshiro256,
}

impl Sampler {
    pub fn new(cfg: SampleCfg) -> Self {
        Self { cfg, rng: Xoshiro256::new(cfg.seed ^ 0x5A17_AB1E) }
    }

    pub fn sample(&mut self, logits: &[f32]) -> usize {
        if self.cfg.temperature <= 0.0 {
            return crate::model::argmax(logits);
        }
        // Scale, softmax. `f32::max` skips NaN operands, so `max` is the
        // largest *well-defined* logit; if none exists (all -inf / NaN)
        // there is no distribution to draw from — fall back to argmax
        // (deterministic, NaN-comparisons-false) instead of propagating
        // NaN probabilities into a silent token-0 draw.
        let inv_t = 1.0 / self.cfg.temperature;
        let max = logits.iter().copied().fold(f32::NEG_INFINITY, f32::max);
        if !max.is_finite() {
            return crate::model::argmax(logits);
        }
        // NaN logits exp to NaN: sanitize to zero mass so a single bad
        // entry cannot poison the cumulative draw below.
        let mut probs: Vec<(usize, f32)> = logits
            .iter()
            .enumerate()
            .map(|(i, &l)| {
                let e = ((l - max) * inv_t).exp();
                (i, if e.is_finite() { e } else { 0.0 })
            })
            .collect();
        let sum: f32 = probs.iter().map(|(_, p)| p).sum();
        // Some finite logit equals `max`, so sum >= 1 — but keep the guard:
        // a zero/non-finite normalizer must never divide through.
        if !(sum.is_finite() && sum > 0.0) {
            return crate::model::argmax(logits);
        }
        for p in &mut probs {
            p.1 /= sum;
        }
        // Nucleus cut. `total_cmp` gives a NaN-safe total order (the
        // masses are already sanitized, but a sort must never panic).
        if self.cfg.top_p < 1.0 {
            probs.sort_by(|a, b| b.1.total_cmp(&a.1));
            let mut cum = 0.0;
            let mut keep = probs.len();
            for (i, (_, p)) in probs.iter().enumerate() {
                cum += p;
                if cum >= self.cfg.top_p {
                    keep = i + 1;
                    break;
                }
            }
            probs.truncate(keep);
            let s: f32 = probs.iter().map(|(_, p)| p).sum();
            if !(s.is_finite() && s > 0.0) {
                // Degenerate nucleus (can only happen with adversarial
                // masses): the head of the sorted list is the mode.
                return probs.first().map(|(i, _)| *i).unwrap_or(0);
            }
            for p in &mut probs {
                p.1 /= s;
            }
        }
        // Inverse-CDF draw.
        let u = self.rng.uniform_f32();
        let mut cum = 0.0;
        for (i, p) in &probs {
            cum += p;
            if u <= cum {
                return *i;
            }
        }
        probs.last().map(|(i, _)| *i).unwrap_or(0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn greedy_is_argmax() {
        let mut s = Sampler::new(SampleCfg::greedy());
        let logits = vec![0.1, 3.0, -2.0, 1.0];
        for _ in 0..5 {
            assert_eq!(s.sample(&logits), 1);
        }
    }

    #[test]
    fn temperature_sampling_covers_support() {
        let mut s = Sampler::new(SampleCfg { temperature: 1.0, top_p: 1.0, seed: 1 });
        let logits = vec![1.0, 1.0, 1.0, 1.0];
        let mut seen = [false; 4];
        for _ in 0..200 {
            seen[s.sample(&logits)] = true;
        }
        assert!(seen.iter().all(|&x| x), "uniform logits should hit all tokens");
    }

    #[test]
    fn top_p_excludes_tail() {
        // One dominant token (p > 0.9) with top_p=0.5 → always chosen.
        let mut s = Sampler::new(SampleCfg { temperature: 1.0, top_p: 0.5, seed: 2 });
        let logits = vec![10.0, 0.0, 0.0, 0.0];
        for _ in 0..50 {
            assert_eq!(s.sample(&logits), 0);
        }
    }

    #[test]
    fn degenerate_all_neg_inf_logits_fall_back_to_argmax() {
        // All -inf: softmax would be 0/0 → NaN probabilities → the old
        // code silently drew token 0 from a poisoned CDF. The fallback
        // must be the explicit argmax and identical on every call.
        let mut s = Sampler::new(SampleCfg { temperature: 0.9, top_p: 0.9, seed: 3 });
        let logits = vec![f32::NEG_INFINITY; 8];
        let expect = crate::model::argmax(&logits);
        for _ in 0..10 {
            assert_eq!(s.sample(&logits), expect);
        }
    }

    #[test]
    fn nan_logits_never_panic_and_never_win() {
        // partial_cmp(..).unwrap() used to panic on any NaN logit; now
        // NaN mass is sanitized to zero and the sort is total-ordered.
        let mut s = Sampler::new(SampleCfg { temperature: 1.0, top_p: 0.9, seed: 4 });
        let logits = vec![1.0, f32::NAN, 3.0, f32::NAN, 0.5];
        for _ in 0..50 {
            let tok = s.sample(&logits);
            assert!(tok < logits.len());
            assert!(!logits[tok].is_nan(), "NaN logit {tok} must carry zero mass");
        }
        // All-NaN is the fully degenerate case: deterministic fallback.
        let all_nan = vec![f32::NAN; 4];
        let expect = crate::model::argmax(&all_nan);
        for _ in 0..5 {
            assert_eq!(s.sample(&all_nan), expect);
        }
    }

    #[test]
    fn deterministic_given_seed() {
        let cfg = SampleCfg { temperature: 0.7, top_p: 0.9, seed: 42 };
        let mut a = Sampler::new(cfg);
        let mut b = Sampler::new(cfg);
        let logits: Vec<f32> = (0..32).map(|i| (i as f32 * 0.37).sin()).collect();
        for _ in 0..20 {
            assert_eq!(a.sample(&logits), b.sample(&logits));
        }
    }
}
