//! Fleet-level engine metrics: throughput, latency distributions,
//! scheduler activity, KV-pool occupancy. Rendered by `repro serve
//! --report` and the e2e_serving bench.

use std::time::Instant;

use crate::linalg::stats::Summary;

use super::request::{Priority, PRIORITY_CLASSES};

/// Latency and scheduler activity for one priority class — the
/// multi-class SLO view (`per_class[Priority::Interactive.index()]` vs
/// `per_class[Priority::Batch.index()]`).
#[derive(Debug)]
pub struct ClassMetrics {
    pub done: u64,
    /// Mid-flight evictions of lanes in this class.
    pub preemptions: u64,
    /// SLO'd requests whose first token beat / missed their
    /// arrival-stamped deadline (requests without `slo_ms` count in
    /// neither; rejected requests never reach a first token and are
    /// reported under `requests_rejected` instead).
    pub deadline_hits: u64,
    pub deadline_misses: u64,
    /// Requests rejected at admission by predictive load shedding
    /// (they count in neither `done` nor the deadline grades — no
    /// first token was ever attempted).
    pub requests_shed: u64,
    /// Sheds whose deadline was actually reachable. The engine cannot
    /// observe the counterfactual online, so this stays 0 until a
    /// replay harness (e2e_serving scenario 6, the deterministic
    /// acceptance test) grades each shed id against a `ShedPolicy::Off`
    /// twin of the same trace and fills it in.
    pub shed_errors: u64,
    /// Tokens delivered by requests that beat their deadline — or had
    /// none to violate. The numerator of [`EngineMetrics::goodput`].
    pub deadline_hit_tokens: u64,
    /// Tokens delivered by requests whose first token missed its
    /// deadline: decode work that produced no SLO-compliant value.
    pub deadline_missed_tokens: u64,
    /// Largest observed decode-step wait to first token — the observable
    /// behind the cross-class aging starvation bound (for `Batch` under
    /// `DeadlineAware` + aging it must stay within `aging_steps` plus
    /// one lane-drain).
    pub max_wait_steps: u64,
    /// Seconds to first token.
    pub ttft: Summary,
    /// Decode iterations to first token — the wall-clock-free TTFT the
    /// deterministic scheduler tests compare across classes.
    pub ttft_steps: Summary,
    pub e2e: Summary,
}

impl ClassMetrics {
    fn new() -> Self {
        Self {
            done: 0,
            preemptions: 0,
            deadline_hits: 0,
            deadline_misses: 0,
            requests_shed: 0,
            shed_errors: 0,
            deadline_hit_tokens: 0,
            deadline_missed_tokens: 0,
            max_wait_steps: 0,
            ttft: Summary::new(),
            ttft_steps: Summary::new(),
            e2e: Summary::new(),
        }
    }

    /// Fraction of SLO'd first tokens that beat their deadline (1.0 when
    /// the class saw no SLO'd requests — nothing was violated).
    pub fn deadline_hit_rate(&self) -> f64 {
        let total = self.deadline_hits + self.deadline_misses;
        if total == 0 {
            1.0
        } else {
            self.deadline_hits as f64 / total as f64
        }
    }
}

#[derive(Debug)]
pub struct EngineMetrics {
    started: Instant,
    pub requests_in: u64,
    pub requests_done: u64,
    /// Requests that can never fit the configured pool (failed fast with
    /// `FinishReason::CacheFull` instead of queueing forever).
    pub requests_rejected: u64,
    /// Requests rejected at admission by predictive load shedding (the
    /// sum of the per-class `requests_shed` counters — kept engine-wide
    /// too so the overload scenarios read in one line).
    pub requests_shed: u64,
    pub tokens_generated: u64,
    pub prefills: u64,
    pub decode_steps: u64,
    pub injections: u64,
    /// Padding-lane re-blanks at the physical cache bound (busy lanes
    /// never reset — admission keeps them within their reservations).
    pub lane_resets: u64,
    /// Scheduler iterations where the head-of-line request had to wait
    /// for pool blocks (eviction backpressure, the old lane-reset path).
    pub admission_blocked: u64,
    /// Mid-flight evictions under speculative admission: a lane's private
    /// blocks were released and its request re-queued for resumption.
    pub preemptions: u64,
    /// Preemptions that kept a prefix in the pool (`PreemptMode::Partial`
    /// with at least one tail block actually freed).
    pub partial_preemptions: u64,
    /// Kept prefixes reclaimed from *queued* requests under unresolvable
    /// pressure (second-tier victims; their resume pays full recompute).
    pub kept_reclaims: u64,
    /// Queued `Batch` requests promoted to interactive-equivalent
    /// scheduling by cross-class aging (`DeadlineAware` + `aging_steps`;
    /// each request is counted at most once).
    pub aging_promotions: u64,
    /// Preempted requests re-admitted (prefix recompute + sampler-state
    /// restore). `preemptions - resumes` requests are still queued or
    /// were finished as `CacheFull` after shrinking pools.
    pub resumes: u64,
    /// Tokens re-prefilled by resume recomputes (the preemption tax:
    /// prompt + produced tokens per full resume, only the truncated
    /// suffix for a kept-prefix resume).
    pub recomputed_tokens: u64,
    /// Tokens whose KV survived preemption in kept prefix blocks —
    /// recompute that partial preemption avoided.
    pub recompute_saved_tokens: u64,
    /// Successful speculative block-table growths and blocks they added.
    pub grow_events: u64,
    pub grown_blocks: u64,
    /// Growth attempts that found the pool empty (each triggers a
    /// preemption round or a yield).
    pub grow_stalls: u64,
    /// KV-pool sizing: total blocks and the KV bytes one block mirrors.
    pub pool_blocks_total: u64,
    pub pool_block_bytes: u64,
    /// Peak simultaneously-granted blocks over the run.
    pub pool_blocks_peak: u64,
    /// Prompt blocks obtained by prefix sharing instead of allocation.
    pub prefix_shared_blocks: u64,
    /// What a flat `[gang, max_len]` K+V cache holds for the same gang —
    /// the baseline the paged pool is measured against.
    pub kv_flat_bytes: u64,
    /// Per-iteration *written*-block fraction of the pool (blocks holding
    /// real KV over total blocks; reserved-but-unwritten blocks do not
    /// count). The utilization number speculative admission exists to
    /// raise — its mean is the e2e acceptance metric vs `ReserveFull`.
    pub pool_occupancy: Summary,
    /// Seconds.
    pub ttft: Summary,
    pub e2e_latency: Summary,
    pub queue_wait: Summary,
    pub decode_step_time: Summary,
    /// Per-priority-class latency/activity, indexed by
    /// [`Priority::index`].
    pub per_class: [ClassMetrics; PRIORITY_CLASSES],
}

impl Default for EngineMetrics {
    fn default() -> Self {
        Self {
            started: Instant::now(),
            requests_in: 0,
            requests_done: 0,
            requests_rejected: 0,
            requests_shed: 0,
            tokens_generated: 0,
            prefills: 0,
            decode_steps: 0,
            injections: 0,
            lane_resets: 0,
            admission_blocked: 0,
            preemptions: 0,
            partial_preemptions: 0,
            kept_reclaims: 0,
            aging_promotions: 0,
            resumes: 0,
            recomputed_tokens: 0,
            recompute_saved_tokens: 0,
            grow_events: 0,
            grown_blocks: 0,
            grow_stalls: 0,
            pool_blocks_total: 0,
            pool_block_bytes: 0,
            pool_blocks_peak: 0,
            prefix_shared_blocks: 0,
            kv_flat_bytes: 0,
            pool_occupancy: Summary::new(),
            ttft: Summary::new(),
            e2e_latency: Summary::new(),
            queue_wait: Summary::new(),
            decode_step_time: Summary::new(),
            per_class: [ClassMetrics::new(), ClassMetrics::new()],
        }
    }
}

impl EngineMetrics {
    pub fn uptime_s(&self) -> f64 {
        self.started.elapsed().as_secs_f64()
    }

    /// Generated tokens per second of wall time.
    pub fn throughput_tok_s(&self) -> f64 {
        let t = self.uptime_s();
        if t > 0.0 {
            self.tokens_generated as f64 / t
        } else {
            0.0
        }
    }

    /// Record a scheduler-loop snapshot of the pool: granted blocks (for
    /// the peak), *written* blocks (for the occupancy series) and the
    /// running prefix-sharing tally.
    pub fn note_pool(&mut self, blocks_in_use: usize, written_blocks: usize, shared_hits: u64) {
        self.pool_blocks_peak = self.pool_blocks_peak.max(blocks_in_use as u64);
        self.prefix_shared_blocks = shared_hits;
        if self.pool_blocks_total > 0 {
            self.pool_occupancy
                .push(written_blocks as f64 / self.pool_blocks_total as f64);
        }
    }

    /// Mean written-block pool occupancy over the run (0.0 when nothing
    /// was recorded).
    pub fn mean_pool_occupancy(&self) -> f64 {
        if self.pool_occupancy.count() == 0 {
            0.0
        } else {
            self.pool_occupancy.mean()
        }
    }

    /// Per-class view (`metrics.class(Priority::Interactive).ttft…`).
    pub fn class(&self, p: Priority) -> &ClassMetrics {
        &self.per_class[p.index()]
    }

    /// **Goodput**: deadline-hit tokens per decode step — tokens whose
    /// requests beat their TTFT deadline (or carried none to violate)
    /// divided by the decode iterations the whole run spent. The number
    /// predictive shedding exists to raise: decode steps burned on
    /// doomed requests inflate the denominator without adding to the
    /// numerator. 0.0 when nothing decoded (never NaN).
    pub fn goodput(&self) -> f64 {
        if self.decode_steps == 0 {
            return 0.0;
        }
        let good: u64 = self.per_class.iter().map(|c| c.deadline_hit_tokens).sum();
        good as f64 / self.decode_steps as f64
    }

    /// Decode and recompute work that produced no SLO-compliant value:
    /// tokens delivered by requests that missed their deadline, plus
    /// every token re-prefilled by preemption resumes. The quantity
    /// scenario 6 pins strictly lower under `ShedPolicy::Strict`.
    pub fn wasted_work_tokens(&self) -> u64 {
        let missed: u64 = self.per_class.iter().map(|c| c.deadline_missed_tokens).sum();
        missed + self.recomputed_tokens
    }

    /// Replay-graded shed errors across classes (0 until a Sim replay
    /// harness fills the per-class counters — see
    /// [`ClassMetrics::shed_errors`]).
    pub fn shed_errors(&self) -> u64 {
        self.per_class.iter().map(|c| c.shed_errors).sum()
    }

    /// Peak KV bytes the paged pool actually had granted.
    pub fn kv_resident_bytes_peak(&self) -> u64 {
        self.pool_blocks_peak * self.pool_block_bytes
    }

    /// How many × smaller the paged peak is than the flat per-lane cache
    /// (≥ 1.0 means the pool won; 0.0 when nothing ran).
    pub fn kv_savings_vs_flat(&self) -> f64 {
        let resident = self.kv_resident_bytes_peak();
        if resident == 0 {
            0.0
        } else {
            self.kv_flat_bytes as f64 / resident as f64
        }
    }

    pub fn report(&self) -> String {
        let mut s = format!(
            "requests: {} in / {} done / {} rejected / {} shed | tokens: {} ({:.1} tok/s)\n\
             prefills: {} | decode steps: {} | injections: {} | lane resets: {}\n\
             kv pool:   peak {}/{} blocks ({:.1} MB resident vs {:.1} MB flat, {:.2}x) | \
             shared {} | blocked {}\n\
             admission: mean occupancy {:.1}% | preempts {} ({} partial, {} kept-reclaims) \
             / resumes {} ({} tok recomputed, {} saved) | grows {} (+{} blocks, {} stalls) \
             | aging promotions {}\n\
             goodput:   {:.3} tok/step (deadline-hit tokens) | wasted {} tok \
             (missed-deadline + recompute) | shed errors {}\n\
             ttft_s:    {}\n\
             e2e_s:     {}\n\
             queue_s:   {}\n\
             step_s:    {}",
            self.requests_in,
            self.requests_done,
            self.requests_rejected,
            self.requests_shed,
            self.tokens_generated,
            self.throughput_tok_s(),
            self.prefills,
            self.decode_steps,
            self.injections,
            self.lane_resets,
            self.pool_blocks_peak,
            self.pool_blocks_total,
            self.kv_resident_bytes_peak() as f64 / 1e6,
            self.kv_flat_bytes as f64 / 1e6,
            self.kv_savings_vs_flat(),
            self.prefix_shared_blocks,
            self.admission_blocked,
            self.mean_pool_occupancy() * 100.0,
            self.preemptions,
            self.partial_preemptions,
            self.kept_reclaims,
            self.resumes,
            self.recomputed_tokens,
            self.recompute_saved_tokens,
            self.grow_events,
            self.grown_blocks,
            self.grow_stalls,
            self.aging_promotions,
            self.goodput(),
            self.wasted_work_tokens(),
            self.shed_errors(),
            self.ttft.display(),
            self.e2e_latency.display(),
            self.queue_wait.display(),
            self.decode_step_time.display(),
        );
        for (p, c) in [Priority::Interactive, Priority::Batch]
            .into_iter()
            .zip(&self.per_class)
        {
            if c.done == 0 && c.ttft.count() == 0 && c.requests_shed == 0 {
                continue;
            }
            s.push_str(&format!(
                "\nclass {:<11} done {} | preempts {} | ttft mean {:.4}s \
                 ({:.1} steps, max wait {}) | e2e mean {:.4}s | \
                 deadline hits {}/{} ({:.0}%) | shed {}",
                p.name(),
                c.done,
                c.preemptions,
                c.ttft.mean(),
                c.ttft_steps.mean(),
                c.max_wait_steps,
                c.e2e.mean(),
                c.deadline_hits,
                c.deadline_hits + c.deadline_misses,
                c.deadline_hit_rate() * 100.0,
                c.requests_shed,
            ));
        }
        s
    }
}

#[cfg(test)]
// `EngineMetrics` keeps a private `started` stamp, so tests build it via
// `default()` and then set the counters they need.
#[allow(clippy::field_reassign_with_default)]
mod tests {
    use super::*;

    #[test]
    fn throughput_math() {
        let mut m = EngineMetrics::default();
        m.tokens_generated = 100;
        std::thread::sleep(std::time::Duration::from_millis(20));
        assert!(m.throughput_tok_s() > 0.0);
        assert!(m.report().contains("tokens: 100"));
    }

    #[test]
    fn pool_accounting() {
        let mut m = EngineMetrics::default();
        m.pool_blocks_total = 64;
        m.pool_block_bytes = 1024;
        m.kv_flat_bytes = 64 * 1024;
        m.note_pool(10, 8, 3);
        m.note_pool(7, 4, 5);
        assert_eq!(m.pool_blocks_peak, 10, "peak keeps the maximum");
        assert_eq!(m.prefix_shared_blocks, 5, "sharing tracks the latest");
        assert_eq!(m.kv_resident_bytes_peak(), 10 * 1024);
        assert!((m.kv_savings_vs_flat() - 6.4).abs() < 1e-9);
        // Occupancy averages the *written* fraction: (8/64 + 4/64) / 2.
        assert!((m.mean_pool_occupancy() - 6.0 / 64.0).abs() < 1e-12);
        assert!(m.report().contains("peak 10/64 blocks"));
    }

    #[test]
    fn deadline_hit_rate_counts_only_slod_requests() {
        let mut c = ClassMetrics::new();
        assert_eq!(c.deadline_hit_rate(), 1.0, "no SLOs → nothing violated");
        c.deadline_hits = 3;
        c.deadline_misses = 1;
        assert!((c.deadline_hit_rate() - 0.75).abs() < 1e-12);
        let mut m = EngineMetrics::default();
        m.per_class[Priority::Batch.index()].deadline_misses = 2;
        m.per_class[Priority::Batch.index()].max_wait_steps = 41;
        m.per_class[Priority::Batch.index()].done = 2;
        m.aging_promotions = 5;
        let report = m.report();
        assert!(report.contains("aging promotions 5"), "{report}");
        assert!(report.contains("max wait 41"), "{report}");
        assert!(report.contains("deadline hits 0/2 (0%)"), "{report}");
    }

    #[test]
    fn goodput_counts_only_deadline_hit_tokens_per_step() {
        let mut m = EngineMetrics::default();
        // Nothing decoded: goodput is 0.0, never NaN.
        assert_eq!(m.goodput(), 0.0);
        assert_eq!(m.wasted_work_tokens(), 0);
        m.decode_steps = 40;
        let int = Priority::Interactive.index();
        let bat = Priority::Batch.index();
        m.per_class[int].deadline_hit_tokens = 24;
        m.per_class[bat].deadline_hit_tokens = 6;
        m.per_class[int].deadline_missed_tokens = 10;
        m.recomputed_tokens = 5;
        assert!((m.goodput() - 30.0 / 40.0).abs() < 1e-12);
        assert_eq!(m.wasted_work_tokens(), 15, "missed tokens + resume recompute");
    }

    #[test]
    fn goodput_with_zero_slod_requests_counts_all_delivered_tokens() {
        // No request carried an SLO: nothing was violated, so every
        // delivered token is goodput and the hit rate stays 1.0 —
        // ShedPolicy::Off on an SLO-less trace scores the same as PR 4.
        let mut m = EngineMetrics::default();
        m.decode_steps = 16;
        m.per_class[Priority::Interactive.index()].deadline_hit_tokens = 16;
        assert_eq!(m.class(Priority::Interactive).deadline_hit_rate(), 1.0);
        assert!((m.goodput() - 1.0).abs() < 1e-12);
        assert_eq!(m.wasted_work_tokens(), 0);
    }

    #[test]
    fn all_shed_class_grades_nothing_and_contributes_no_goodput() {
        // Every request of a class shed at admission: no first token
        // was attempted, so the deadline grades stay empty (hit rate
        // 1.0 — nothing violated), goodput numerator stays 0, and the
        // class still shows up in the report via its shed count.
        let mut m = EngineMetrics::default();
        m.decode_steps = 8;
        let c = &mut m.per_class[Priority::Batch.index()];
        c.requests_shed = 7;
        assert_eq!(c.done, 0);
        assert_eq!(c.deadline_hit_rate(), 1.0);
        m.requests_shed = 7;
        assert_eq!(m.goodput(), 0.0);
        assert_eq!(m.shed_errors(), 0, "no replay grading → no claimed errors");
        let report = m.report();
        assert!(report.contains("7 shed"), "{report}");
        assert!(report.contains("class batch"), "all-shed class must not vanish: {report}");
        assert!(report.contains("shed 7"), "{report}");
    }

    #[test]
    fn shed_then_retry_counts_one_shed_and_one_completion() {
        // A client sheds once, retries with a fresh request, and the
        // retry completes in budget: the class carries both the shed
        // and the hit, and only the retry's tokens enter goodput.
        let mut m = EngineMetrics::default();
        m.decode_steps = 10;
        let c = &mut m.per_class[Priority::Interactive.index()];
        c.requests_shed = 1;
        c.done = 1;
        c.deadline_hits = 1;
        c.deadline_hit_tokens = 8;
        m.requests_shed = 1;
        m.requests_done = 1;
        assert_eq!(m.class(Priority::Interactive).deadline_hit_rate(), 1.0);
        assert!((m.goodput() - 0.8).abs() < 1e-12);
        assert_eq!(m.wasted_work_tokens(), 0, "the shed itself burned no decode work");
    }

    #[test]
    fn occupancy_is_zero_without_snapshots() {
        let m = EngineMetrics::default();
        assert_eq!(m.mean_pool_occupancy(), 0.0);
        let mut m = EngineMetrics::default();
        // No pool configured (total 0): snapshots are ignored, not NaN.
        m.note_pool(3, 3, 0);
        assert_eq!(m.mean_pool_occupancy(), 0.0);
    }
}
