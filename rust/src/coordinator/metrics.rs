//! Fleet-level engine metrics: throughput, latency distributions,
//! scheduler activity, KV-pool occupancy. Rendered by `repro serve
//! --report` and the e2e_serving bench.

use std::time::Instant;

use crate::linalg::stats::Summary;

#[derive(Debug)]
pub struct EngineMetrics {
    started: Instant,
    pub requests_in: u64,
    pub requests_done: u64,
    /// Requests that can never fit the configured pool (failed fast with
    /// `FinishReason::CacheFull` instead of queueing forever).
    pub requests_rejected: u64,
    pub tokens_generated: u64,
    pub prefills: u64,
    pub decode_steps: u64,
    pub injections: u64,
    /// Padding-lane re-blanks at the physical cache bound (busy lanes
    /// never reset — admission keeps them within their reservations).
    pub lane_resets: u64,
    /// Scheduler iterations where the head-of-line request had to wait
    /// for pool blocks (eviction backpressure, the old lane-reset path).
    pub admission_blocked: u64,
    /// KV-pool sizing: total blocks and the KV bytes one block mirrors.
    pub pool_blocks_total: u64,
    pub pool_block_bytes: u64,
    /// Peak simultaneously-granted blocks over the run.
    pub pool_blocks_peak: u64,
    /// Prompt blocks obtained by prefix sharing instead of allocation.
    pub prefix_shared_blocks: u64,
    /// What a flat `[gang, max_len]` K+V cache holds for the same gang —
    /// the baseline the paged pool is measured against.
    pub kv_flat_bytes: u64,
    /// Seconds.
    pub ttft: Summary,
    pub e2e_latency: Summary,
    pub queue_wait: Summary,
    pub decode_step_time: Summary,
}

impl Default for EngineMetrics {
    fn default() -> Self {
        Self {
            started: Instant::now(),
            requests_in: 0,
            requests_done: 0,
            requests_rejected: 0,
            tokens_generated: 0,
            prefills: 0,
            decode_steps: 0,
            injections: 0,
            lane_resets: 0,
            admission_blocked: 0,
            pool_blocks_total: 0,
            pool_block_bytes: 0,
            pool_blocks_peak: 0,
            prefix_shared_blocks: 0,
            kv_flat_bytes: 0,
            ttft: Summary::new(),
            e2e_latency: Summary::new(),
            queue_wait: Summary::new(),
            decode_step_time: Summary::new(),
        }
    }
}

impl EngineMetrics {
    pub fn uptime_s(&self) -> f64 {
        self.started.elapsed().as_secs_f64()
    }

    /// Generated tokens per second of wall time.
    pub fn throughput_tok_s(&self) -> f64 {
        let t = self.uptime_s();
        if t > 0.0 {
            self.tokens_generated as f64 / t
        } else {
            0.0
        }
    }

    /// Record a scheduler-loop snapshot of the pool.
    pub fn note_pool(&mut self, blocks_in_use: usize, shared_hits: u64) {
        self.pool_blocks_peak = self.pool_blocks_peak.max(blocks_in_use as u64);
        self.prefix_shared_blocks = shared_hits;
    }

    /// Peak KV bytes the paged pool actually had granted.
    pub fn kv_resident_bytes_peak(&self) -> u64 {
        self.pool_blocks_peak * self.pool_block_bytes
    }

    /// How many × smaller the paged peak is than the flat per-lane cache
    /// (≥ 1.0 means the pool won; 0.0 when nothing ran).
    pub fn kv_savings_vs_flat(&self) -> f64 {
        let resident = self.kv_resident_bytes_peak();
        if resident == 0 {
            0.0
        } else {
            self.kv_flat_bytes as f64 / resident as f64
        }
    }

    pub fn report(&self) -> String {
        format!(
            "requests: {} in / {} done / {} rejected | tokens: {} ({:.1} tok/s)\n\
             prefills: {} | decode steps: {} | injections: {} | lane resets: {}\n\
             kv pool:   peak {}/{} blocks ({:.1} MB resident vs {:.1} MB flat, {:.2}x) | \
             shared {} | blocked {}\n\
             ttft_s:    {}\n\
             e2e_s:     {}\n\
             queue_s:   {}\n\
             step_s:    {}",
            self.requests_in,
            self.requests_done,
            self.requests_rejected,
            self.tokens_generated,
            self.throughput_tok_s(),
            self.prefills,
            self.decode_steps,
            self.injections,
            self.lane_resets,
            self.pool_blocks_peak,
            self.pool_blocks_total,
            self.kv_resident_bytes_peak() as f64 / 1e6,
            self.kv_flat_bytes as f64 / 1e6,
            self.kv_savings_vs_flat(),
            self.prefix_shared_blocks,
            self.admission_blocked,
            self.ttft.display(),
            self.e2e_latency.display(),
            self.queue_wait.display(),
            self.decode_step_time.display(),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn throughput_math() {
        let mut m = EngineMetrics::default();
        m.tokens_generated = 100;
        std::thread::sleep(std::time::Duration::from_millis(20));
        assert!(m.throughput_tok_s() > 0.0);
        assert!(m.report().contains("tokens: 100"));
    }

    #[test]
    fn pool_accounting() {
        let mut m = EngineMetrics::default();
        m.pool_blocks_total = 64;
        m.pool_block_bytes = 1024;
        m.kv_flat_bytes = 64 * 1024;
        m.note_pool(10, 3);
        m.note_pool(7, 5);
        assert_eq!(m.pool_blocks_peak, 10, "peak keeps the maximum");
        assert_eq!(m.prefix_shared_blocks, 5, "sharing tracks the latest");
        assert_eq!(m.kv_resident_bytes_peak(), 10 * 1024);
        assert!((m.kv_savings_vs_flat() - 6.4).abs() < 1e-9);
        assert!(m.report().contains("peak 10/64 blocks"));
    }
}
