//! Fleet-level engine metrics: throughput, latency distributions,
//! scheduler activity, KV-pool occupancy. Rendered by `repro serve
//! --report` and the e2e_serving bench.
//!
//! Latency series are `obs::StreamingHist` — constant-memory
//! log-bucketed histograms whose mean/sum are bit-identical to the old
//! `Vec`-backed `Summary` (same push-order accumulation) and whose
//! percentiles are within one log bucket (~19%) of exact. A serving
//! process that runs for days no longer grows its metrics without
//! bound; the experiment harnesses keep exact `Summary` where order
//! statistics must be precise.
//!
//! `EngineMetrics` also owns the flight recorder: `record(kind)` stamps
//! a trace event with the engine-clock timestamp (`decode_steps ×
//! step_ms` under `EngineClock::Steps`, wall elapsed under `Wall`), so
//! traces from the deterministic twin are bit-identical across runs.

use std::time::Instant;

use crate::obs::{
    ClassSnap, EventKind, FlightRecorder, HistSnap, StatsSnapshot, StreamingHist, TURN_BUCKETS,
};

use super::clock::{wall_now, EngineClock};
use super::request::{Priority, PRIORITY_CLASSES};

/// Per-turn TTFT buckets: conversation turns 0, 1, 2 exactly, and a
/// tail bucket accumulating every turn ≥ 3. Aliases the snapshot
/// layer's [`TURN_BUCKETS`] so engine histograms and exposition arrays
/// can never drift apart.
pub const TURN_TTFT_BUCKETS: usize = TURN_BUCKETS;

/// Latency and scheduler activity for one priority class — the
/// multi-class SLO view (`per_class[Priority::Interactive.index()]` vs
/// `per_class[Priority::Batch.index()]`).
#[derive(Debug)]
pub struct ClassMetrics {
    pub done: u64,
    /// Mid-flight evictions of lanes in this class.
    pub preemptions: u64,
    /// SLO'd requests whose first token beat / missed their
    /// arrival-stamped deadline (requests without `slo_ms` count in
    /// neither; rejected requests never reach a first token and are
    /// reported under `requests_rejected` instead).
    pub deadline_hits: u64,
    pub deadline_misses: u64,
    /// Requests rejected at admission by predictive load shedding
    /// (they count in neither `done` nor the deadline grades — no
    /// first token was ever attempted).
    pub requests_shed: u64,
    /// Sheds whose deadline was actually reachable. The engine cannot
    /// observe the counterfactual online, so this stays 0 until a
    /// replay harness (e2e_serving scenario 6, the deterministic
    /// acceptance test) grades each shed id against a `ShedPolicy::Off`
    /// twin of the same trace and fills it in.
    pub shed_errors: u64,
    /// Tokens delivered by requests that beat their deadline — or had
    /// none to violate. The numerator of [`EngineMetrics::goodput`].
    pub deadline_hit_tokens: u64,
    /// Tokens delivered by requests whose first token missed its
    /// deadline: decode work that produced no SLO-compliant value.
    pub deadline_missed_tokens: u64,
    /// Largest observed decode-step wait to first token — the observable
    /// behind the cross-class aging starvation bound (for `Batch` under
    /// `DeadlineAware` + aging it must stay within `aging_steps` plus
    /// one lane-drain).
    pub max_wait_steps: u64,
    /// Seconds to first token.
    pub ttft: StreamingHist,
    /// Decode iterations to first token — the wall-clock-free TTFT the
    /// deterministic scheduler tests compare across classes.
    pub ttft_steps: StreamingHist,
    /// Engine-clock milliseconds to first token, measured from the
    /// submission stamp. Under `EngineClock::Steps` this is the
    /// *charged* domain — decode steps plus the virtual prefill charge
    /// (`prefill_charged_ms`) — so chunked-vs-monolithic TTFT
    /// comparisons see the head-of-line blocking a monolithic prefill
    /// imposes, which the raw `ttft_steps` counter cannot.
    pub ttft_ms: StreamingHist,
    /// Prefill chunks executed for this class's requests (0 unless the
    /// engine runs with `prefill_chunk` set).
    pub prefill_chunks: u64,
    pub e2e: StreamingHist,
}

impl ClassMetrics {
    fn new() -> Self {
        Self {
            done: 0,
            preemptions: 0,
            deadline_hits: 0,
            deadline_misses: 0,
            requests_shed: 0,
            shed_errors: 0,
            deadline_hit_tokens: 0,
            deadline_missed_tokens: 0,
            max_wait_steps: 0,
            ttft: StreamingHist::new(),
            ttft_steps: StreamingHist::new(),
            ttft_ms: StreamingHist::new(),
            prefill_chunks: 0,
            e2e: StreamingHist::new(),
        }
    }

    /// Fraction of SLO'd first tokens that beat their deadline (1.0 when
    /// the class saw no SLO'd requests — nothing was violated).
    pub fn deadline_hit_rate(&self) -> f64 {
        let total = self.deadline_hits + self.deadline_misses;
        if total == 0 {
            1.0
        } else {
            self.deadline_hits as f64 / total as f64
        }
    }
}

#[derive(Debug)]
pub struct EngineMetrics {
    started: Instant,
    /// Which clock timestamps and elapsed-time metrics route through.
    /// Set by `Engine::run` from its config; `Wall` by default. Under
    /// `Steps` both `uptime_s` and trace timestamps derive from
    /// `decode_steps`, so the deterministic twin reports deterministic
    /// throughput and bit-identical traces.
    pub clock: EngineClock,
    /// Default-on flight recorder (bounded ring; see `obs::recorder`).
    /// Passive unless exported: with export off, engine outputs are
    /// byte-identical to a build without it.
    pub trace: FlightRecorder,
    pub requests_in: u64,
    pub requests_done: u64,
    /// Requests that can never fit the configured pool (failed fast with
    /// `FinishReason::CacheFull` instead of queueing forever).
    pub requests_rejected: u64,
    /// Requests rejected at admission by predictive load shedding (the
    /// sum of the per-class `requests_shed` counters — kept engine-wide
    /// too so the overload scenarios read in one line).
    pub requests_shed: u64,
    pub tokens_generated: u64,
    pub prefills: u64,
    /// Real prompt tokens prefilled (padding lanes excluded) — the same
    /// token count billed to the service-rate estimator, kept as a
    /// counter so the padded-gang regression is observable.
    pub prefill_tokens: u64,
    /// Prefill chunks executed across all classes (0 under monolithic
    /// prefill).
    pub prefill_chunks: u64,
    /// Prompt tokens prefilled through the chunked path specifically.
    pub chunked_prefill_tokens: u64,
    /// Blank re-prefills of padding lanes at the physical cache bound —
    /// real backend work that used to be invisible to accounting (it
    /// now also feeds the estimator and the flight recorder).
    pub lane_reset_prefills: u64,
    /// Per-completed-chunked-prefill stall: decode steps the gang ran
    /// between a request's first chunk and its injection — how long the
    /// chunked prefill was interleaved with (not blocking) decode.
    pub prefill_stall: StreamingHist,
    /// Virtual milliseconds of prefill work charged to the Steps clock
    /// (`tokens × prefill_ms_per_token` per physical prefill). Folded
    /// into `uptime_s`/`now_ms` so a monolithic prefill's head-of-line
    /// blocking is visible in the charged time domain; 0.0 whenever
    /// `prefill_ms_per_token` is 0.0 (every pinned scenario) and under
    /// the wall clock (real time already includes prefill).
    pub prefill_charged_ms: f64,
    pub decode_steps: u64,
    pub injections: u64,
    /// Padding-lane re-blanks at the physical cache bound (busy lanes
    /// never reset — admission keeps them within their reservations).
    pub lane_resets: u64,
    /// Scheduler iterations where the head-of-line request had to wait
    /// for pool blocks (eviction backpressure, the old lane-reset path).
    pub admission_blocked: u64,
    /// Mid-flight evictions under speculative admission: a lane's private
    /// blocks were released and its request re-queued for resumption.
    pub preemptions: u64,
    /// Preemptions that kept a prefix in the pool (`PreemptMode::Partial`
    /// with at least one tail block actually freed).
    pub partial_preemptions: u64,
    /// Kept prefixes reclaimed from *queued* requests under unresolvable
    /// pressure (second-tier victims; their resume pays full recompute).
    pub kept_reclaims: u64,
    /// Queued `Batch` requests promoted to interactive-equivalent
    /// scheduling by cross-class aging (`DeadlineAware` + `aging_steps`;
    /// each request is counted at most once).
    pub aging_promotions: u64,
    /// Preempted requests re-admitted (prefix recompute + sampler-state
    /// restore). `preemptions - resumes` requests are still queued or
    /// were finished as `CacheFull` after shrinking pools.
    pub resumes: u64,
    /// Tokens re-prefilled by resume recomputes (the preemption tax:
    /// prompt + produced tokens per full resume, only the truncated
    /// suffix for a kept-prefix resume).
    pub recomputed_tokens: u64,
    /// Tokens whose KV survived preemption in kept prefix blocks —
    /// recompute that partial preemption avoided.
    pub recompute_saved_tokens: u64,
    /// Successful speculative block-table growths and blocks they added.
    pub grow_events: u64,
    pub grown_blocks: u64,
    /// Growth attempts that found the pool empty (each triggers a
    /// preemption round or a yield).
    pub grow_stalls: u64,
    /// KV-pool sizing: total blocks and the KV bytes one block mirrors.
    pub pool_blocks_total: u64,
    pub pool_block_bytes: u64,
    /// Peak simultaneously-granted blocks over the run.
    pub pool_blocks_peak: u64,
    /// Prompt blocks obtained by prefix sharing instead of allocation.
    pub prefix_shared_blocks: u64,
    /// Full prompt blocks probed against the prefix index at admission
    /// (fresh requests plus full-preemption recomputes — every admission
    /// that *could* have shared) — the denominator of
    /// [`EngineMetrics::prefix_hit_rate`], matching the numerator's
    /// `shared_hits` tally block for block.
    pub prefix_ref_blocks: u64,
    /// Prompt tokens whose Steps-clock prefill charge was waived by
    /// [`EngineConfig::prefix_prefill_discount`] because their blocks
    /// were served from the shared prefix index instead of prefilled.
    pub prefill_discounted_tokens: u64,
    /// Live node count of the kvpool radix tree (latest scheduler-round
    /// snapshot): one node per distinct resident shared prompt block.
    pub radix_nodes: u64,
    /// Cumulative admission-walk hits the radix tree has resolved
    /// (`RadixTree::hit_blocks`; latest snapshot of a monotone counter).
    pub radix_hit_blocks: u64,
    /// Full prompt blocks probed at admission by follow-up conversation
    /// turns (turn ≥ 1) — the denominator of
    /// [`EngineMetrics::turn_cache_hit_rate`].
    pub turn_ref_blocks: u64,
    /// Of those, blocks served from the radix tree instead of freshly
    /// prefilled — the numerator.
    pub turn_shared_blocks: u64,
    /// Charged-domain TTFT (same domain as [`ClassMetrics::ttft_ms`])
    /// bucketed by conversation turn: indices 0–2 are exact turns,
    /// index 3 folds in every turn ≥ 3. The multi-turn scenarios grade
    /// turn ≥ 1 buckets against turn 0 to show what radix-tree prefix
    /// reuse buys in first-token latency.
    pub turn_ttft_ms: [StreamingHist; TURN_TTFT_BUCKETS],
    /// What a flat `[gang, max_len]` K+V cache holds for the same gang —
    /// the baseline the paged pool is measured against.
    pub kv_flat_bytes: u64,
    /// Per-iteration *written*-block fraction of the pool (blocks holding
    /// real KV over total blocks; reserved-but-unwritten blocks do not
    /// count). The utilization number speculative admission exists to
    /// raise — its mean is the e2e acceptance metric vs `ReserveFull`.
    pub pool_occupancy: StreamingHist,
    /// Seconds.
    pub ttft: StreamingHist,
    pub e2e_latency: StreamingHist,
    pub queue_wait: StreamingHist,
    pub decode_step_time: StreamingHist,
    /// Per-priority-class latency/activity, indexed by
    /// [`Priority::index`].
    pub per_class: [ClassMetrics; PRIORITY_CLASSES],
}

impl Default for EngineMetrics {
    fn default() -> Self {
        Self {
            started: wall_now(),
            clock: EngineClock::Wall,
            trace: FlightRecorder::default(),
            requests_in: 0,
            requests_done: 0,
            requests_rejected: 0,
            requests_shed: 0,
            tokens_generated: 0,
            prefills: 0,
            prefill_tokens: 0,
            prefill_chunks: 0,
            chunked_prefill_tokens: 0,
            lane_reset_prefills: 0,
            prefill_stall: StreamingHist::new(),
            prefill_charged_ms: 0.0,
            decode_steps: 0,
            injections: 0,
            lane_resets: 0,
            admission_blocked: 0,
            preemptions: 0,
            partial_preemptions: 0,
            kept_reclaims: 0,
            aging_promotions: 0,
            resumes: 0,
            recomputed_tokens: 0,
            recompute_saved_tokens: 0,
            grow_events: 0,
            grown_blocks: 0,
            grow_stalls: 0,
            pool_blocks_total: 0,
            pool_block_bytes: 0,
            pool_blocks_peak: 0,
            prefix_shared_blocks: 0,
            prefix_ref_blocks: 0,
            prefill_discounted_tokens: 0,
            radix_nodes: 0,
            radix_hit_blocks: 0,
            turn_ref_blocks: 0,
            turn_shared_blocks: 0,
            turn_ttft_ms: std::array::from_fn(|_| StreamingHist::new()),
            kv_flat_bytes: 0,
            pool_occupancy: StreamingHist::new(),
            ttft: StreamingHist::new(),
            e2e_latency: StreamingHist::new(),
            queue_wait: StreamingHist::new(),
            decode_step_time: StreamingHist::new(),
            per_class: [ClassMetrics::new(), ClassMetrics::new()],
        }
    }
}

impl EngineMetrics {
    /// Elapsed engine time in seconds, routed through the engine clock:
    /// wall elapsed under `Wall`, `decode_steps × step_ms` under
    /// `Steps`. The deterministic twin used to leak wall time here and
    /// report nondeterministic throughput; now two identical Steps runs
    /// report identical uptime and tok/s.
    pub fn uptime_s(&self) -> f64 {
        match self.clock {
            EngineClock::Wall => self.started.elapsed().as_secs_f64(),
            EngineClock::Steps { step_ms, .. } => {
                (self.decode_steps as f64 * step_ms + self.prefill_charged_ms) / 1e3
            }
        }
    }

    /// Milliseconds on the engine clock, for trace timestamps and the
    /// charged-domain TTFT stamps. Under `Steps` this is decode steps
    /// *plus* the virtual prefill charge, so time spent blocked behind
    /// a monolithic prefill is visible even though no decode step ran.
    pub fn now_ms(&self) -> f64 {
        match self.clock {
            EngineClock::Wall => self.started.elapsed().as_secs_f64() * 1e3,
            EngineClock::Steps { step_ms, .. } => {
                self.decode_steps as f64 * step_ms + self.prefill_charged_ms
            }
        }
    }

    /// Record a flight-recorder event stamped with the engine clock and
    /// the current decode-step counter.
    pub fn record(&mut self, kind: EventKind) {
        let ts_ms = self.now_ms();
        let step = self.decode_steps;
        self.trace.record(ts_ms, step, kind);
    }

    /// Generated tokens per second of uptime (clock-routed).
    pub fn throughput_tok_s(&self) -> f64 {
        let t = self.uptime_s();
        if t > 0.0 {
            self.tokens_generated as f64 / t
        } else {
            0.0
        }
    }

    /// Record a scheduler-loop snapshot of the pool: granted blocks (for
    /// the peak), *written* blocks (for the occupancy series) and the
    /// running prefix-sharing tally.
    pub fn note_pool(&mut self, blocks_in_use: usize, written_blocks: usize, shared_hits: u64) {
        self.pool_blocks_peak = self.pool_blocks_peak.max(blocks_in_use as u64);
        self.prefix_shared_blocks = shared_hits;
        if self.pool_blocks_total > 0 {
            self.pool_occupancy
                .push(written_blocks as f64 / self.pool_blocks_total as f64);
        }
    }

    /// Record the radix tree's scheduler-round gauges: live node count
    /// and the cumulative admission hits it has resolved so far.
    pub fn note_radix(&mut self, nodes: usize, hit_blocks: u64) {
        self.radix_nodes = nodes as u64;
        self.radix_hit_blocks = hit_blocks;
    }

    /// Push one charged-domain first-token latency into its conversation
    /// turn's bucket (turn ≥ 3 folds into the tail bucket).
    pub fn note_turn_ttft(&mut self, turn: u32, ms: f64) {
        let idx = (turn as usize).min(TURN_TTFT_BUCKETS - 1);
        if let Some(h) = self.turn_ttft_ms.get_mut(idx) {
            h.push(ms);
        }
    }

    /// Conversational prefix-hit rate: the fraction of turn ≥ 1 full
    /// prompt blocks served from the radix tree instead of freshly
    /// prefilled. 1.0 when no follow-up turn ever probed — nothing was
    /// missable (same convention as [`Self::prefix_hit_rate`]).
    pub fn turn_cache_hit_rate(&self) -> f64 {
        if self.turn_ref_blocks == 0 {
            return 1.0;
        }
        self.turn_shared_blocks as f64 / self.turn_ref_blocks as f64
    }

    /// Mean written-block pool occupancy over the run (0.0 when nothing
    /// was recorded).
    pub fn mean_pool_occupancy(&self) -> f64 {
        if self.pool_occupancy.count() == 0 {
            0.0
        } else {
            self.pool_occupancy.mean()
        }
    }

    /// Per-class view (`metrics.class(Priority::Interactive).ttft…`).
    pub fn class(&self, p: Priority) -> &ClassMetrics {
        &self.per_class[p.index()]
    }

    /// **Goodput**: deadline-hit tokens per decode step — tokens whose
    /// requests beat their TTFT deadline (or carried none to violate)
    /// divided by the decode iterations the whole run spent. The number
    /// predictive shedding exists to raise: decode steps burned on
    /// doomed requests inflate the denominator without adding to the
    /// numerator. 0.0 when nothing decoded (never NaN).
    pub fn goodput(&self) -> f64 {
        if self.decode_steps == 0 {
            return 0.0;
        }
        let good: u64 = self.per_class.iter().map(|c| c.deadline_hit_tokens).sum();
        good as f64 / self.decode_steps as f64
    }

    /// Fraction of fresh-admission full prompt blocks served from the
    /// content-addressed prefix index instead of freshly prefilled — the
    /// per-replica locality number affinity routing is graded on. 1.0
    /// when no full blocks were ever probed (nothing was missable).
    pub fn prefix_hit_rate(&self) -> f64 {
        if self.prefix_ref_blocks == 0 {
            return 1.0;
        }
        self.prefix_shared_blocks as f64 / self.prefix_ref_blocks as f64
    }

    /// Decode and recompute work that produced no SLO-compliant value:
    /// tokens delivered by requests that missed their deadline, plus
    /// every token re-prefilled by preemption resumes. The quantity
    /// scenario 6 pins strictly lower under `ShedPolicy::Strict`.
    pub fn wasted_work_tokens(&self) -> u64 {
        let missed: u64 = self.per_class.iter().map(|c| c.deadline_missed_tokens).sum();
        missed + self.recomputed_tokens
    }

    /// Replay-graded shed errors across classes (0 until a Sim replay
    /// harness fills the per-class counters — see
    /// [`ClassMetrics::shed_errors`]).
    pub fn shed_errors(&self) -> u64 {
        self.per_class.iter().map(|c| c.shed_errors).sum()
    }

    /// Peak KV bytes the paged pool actually had granted.
    pub fn kv_resident_bytes_peak(&self) -> u64 {
        self.pool_blocks_peak * self.pool_block_bytes
    }

    /// How many × smaller the paged peak is than the flat per-lane cache
    /// (≥ 1.0 means the pool won; 0.0 when nothing ran).
    pub fn kv_savings_vs_flat(&self) -> f64 {
        let resident = self.kv_resident_bytes_peak();
        if resident == 0 {
            0.0
        } else {
            self.kv_flat_bytes as f64 / resident as f64
        }
    }

    /// Flat snapshot for the live `"stats"` exposition. The engine
    /// calls this once per scheduling round with its instantaneous
    /// queue/lane/pool state and publishes the result into a
    /// `StatsHub`.
    pub fn snapshot(
        &self,
        queue_depth: usize,
        busy_lanes: usize,
        pool_blocks_in_use: usize,
    ) -> StatsSnapshot {
        let mut classes = [ClassSnap::default(); 2];
        for (i, c) in self.per_class.iter().enumerate() {
            classes[i] = ClassSnap {
                done: c.done,
                preemptions: c.preemptions,
                shed: c.requests_shed,
                deadline_hits: c.deadline_hits,
                deadline_misses: c.deadline_misses,
                ttft: HistSnap::of(&c.ttft),
            };
        }
        StatsSnapshot {
            uptime_s: self.uptime_s(),
            throughput_tok_s: self.throughput_tok_s(),
            requests_in: self.requests_in,
            requests_done: self.requests_done,
            requests_rejected: self.requests_rejected,
            requests_shed: self.requests_shed,
            tokens_generated: self.tokens_generated,
            prefills: self.prefills,
            prefill_chunks: self.prefill_chunks,
            lane_reset_prefills: self.lane_reset_prefills,
            decode_steps: self.decode_steps,
            preemptions: self.preemptions,
            resumes: self.resumes,
            queue_depth: queue_depth as u64,
            busy_lanes: busy_lanes as u64,
            pool_blocks_total: self.pool_blocks_total,
            pool_blocks_in_use: pool_blocks_in_use as u64,
            pool_blocks_peak: self.pool_blocks_peak,
            goodput_tok_per_step: self.goodput(),
            wasted_work_tokens: self.wasted_work_tokens(),
            radix_nodes: self.radix_nodes,
            radix_hit_blocks: self.radix_hit_blocks,
            turn_ref_blocks: self.turn_ref_blocks,
            turn_shared_blocks: self.turn_shared_blocks,
            turn_ttft_ms: std::array::from_fn(|i| {
                self.turn_ttft_ms.get(i).map(HistSnap::of).unwrap_or_default()
            }),
            ttft: HistSnap::of(&self.ttft),
            e2e: HistSnap::of(&self.e2e_latency),
            queue_wait: HistSnap::of(&self.queue_wait),
            decode_step: HistSnap::of(&self.decode_step_time),
            trace_recorded: self.trace.recorded(),
            trace_dropped: self.trace.dropped(),
            classes,
        }
    }

    pub fn report(&self) -> String {
        let mut s = format!(
            "requests: {} in / {} done / {} rejected / {} shed | tokens: {} ({:.1} tok/s)\n\
             prefills: {} | decode steps: {} | injections: {} | lane resets: {}\n\
             kv pool:   peak {}/{} blocks ({:.1} MB resident vs {:.1} MB flat, {:.2}x) | \
             shared {} | blocked {}\n\
             admission: mean occupancy {:.1}% | preempts {} ({} partial, {} kept-reclaims) \
             / resumes {} ({} tok recomputed, {} saved) | grows {} (+{} blocks, {} stalls) \
             | aging promotions {}\n\
             radix:     {} nodes | {} tree hits | turn>=1 hit rate {:.1}% ({}/{} blocks)\n\
             prefill:   {} tok real | chunks {} ({} tok chunked) | lane-reset prefills {} \
             | stall_steps: {}\n\
             goodput:   {:.3} tok/step (deadline-hit tokens) | wasted {} tok \
             (missed-deadline + recompute) | shed errors {}\n\
             ttft_s:    {}\n\
             e2e_s:     {}\n\
             queue_s:   {}\n\
             step_s:    {}",
            self.requests_in,
            self.requests_done,
            self.requests_rejected,
            self.requests_shed,
            self.tokens_generated,
            self.throughput_tok_s(),
            self.prefills,
            self.decode_steps,
            self.injections,
            self.lane_resets,
            self.pool_blocks_peak,
            self.pool_blocks_total,
            self.kv_resident_bytes_peak() as f64 / 1e6,
            self.kv_flat_bytes as f64 / 1e6,
            self.kv_savings_vs_flat(),
            self.prefix_shared_blocks,
            self.admission_blocked,
            self.mean_pool_occupancy() * 100.0,
            self.preemptions,
            self.partial_preemptions,
            self.kept_reclaims,
            self.resumes,
            self.recomputed_tokens,
            self.recompute_saved_tokens,
            self.grow_events,
            self.grown_blocks,
            self.grow_stalls,
            self.aging_promotions,
            self.radix_nodes,
            self.radix_hit_blocks,
            self.turn_cache_hit_rate() * 100.0,
            self.turn_shared_blocks,
            self.turn_ref_blocks,
            self.prefill_tokens,
            self.prefill_chunks,
            self.chunked_prefill_tokens,
            self.lane_reset_prefills,
            self.prefill_stall.display(),
            self.goodput(),
            self.wasted_work_tokens(),
            self.shed_errors(),
            self.ttft.display(),
            self.e2e_latency.display(),
            self.queue_wait.display(),
            self.decode_step_time.display(),
        );
        for (p, c) in [Priority::Interactive, Priority::Batch]
            .into_iter()
            .zip(&self.per_class)
        {
            if c.done == 0 && c.ttft.count() == 0 && c.requests_shed == 0 {
                continue;
            }
            s.push_str(&format!(
                "\nclass {:<11} done {} | preempts {} | ttft mean {:.4}s \
                 ({:.1} steps, max wait {}) | e2e mean {:.4}s | \
                 deadline hits {}/{} ({:.0}%) | shed {} | chunks {}",
                p.name(),
                c.done,
                c.preemptions,
                c.ttft.mean(),
                c.ttft_steps.mean(),
                c.max_wait_steps,
                c.e2e.mean(),
                c.deadline_hits,
                c.deadline_hits + c.deadline_misses,
                c.deadline_hit_rate() * 100.0,
                c.requests_shed,
                c.prefill_chunks,
            ));
        }
        // Per-turn charged-domain TTFT: only buckets that saw traffic
        // print, so single-shot runs keep their exact report shape plus
        // one `turn 0` line and multi-turn runs show the reuse gradient.
        for (i, h) in self.turn_ttft_ms.iter().enumerate() {
            if h.count() == 0 {
                continue;
            }
            let label = if i + 1 == TURN_TTFT_BUCKETS {
                format!("{i}+")
            } else {
                i.to_string()
            };
            s.push_str(&format!("\nturn {label:<3} ttft_ms: {}", h.display()));
        }
        s
    }
}

#[cfg(test)]
// `EngineMetrics` keeps a private `started` stamp, so tests build it via
// `default()` and then set the counters they need.
#[allow(clippy::field_reassign_with_default)]
mod tests {
    use super::*;

    #[test]
    fn throughput_math() {
        let mut m = EngineMetrics::default();
        m.tokens_generated = 100;
        std::thread::sleep(std::time::Duration::from_millis(20));
        assert!(m.throughput_tok_s() > 0.0);
        assert!(m.report().contains("tokens: 100"));
    }

    #[test]
    fn pool_accounting() {
        let mut m = EngineMetrics::default();
        m.pool_blocks_total = 64;
        m.pool_block_bytes = 1024;
        m.kv_flat_bytes = 64 * 1024;
        m.note_pool(10, 8, 3);
        m.note_pool(7, 4, 5);
        assert_eq!(m.pool_blocks_peak, 10, "peak keeps the maximum");
        assert_eq!(m.prefix_shared_blocks, 5, "sharing tracks the latest");
        assert_eq!(m.kv_resident_bytes_peak(), 10 * 1024);
        assert!((m.kv_savings_vs_flat() - 6.4).abs() < 1e-9);
        // Occupancy averages the *written* fraction: (8/64 + 4/64) / 2.
        assert!((m.mean_pool_occupancy() - 6.0 / 64.0).abs() < 1e-12);
        assert!(m.report().contains("peak 10/64 blocks"));
    }

    #[test]
    fn prefix_hit_rate_is_shared_over_probed_blocks() {
        let mut m = EngineMetrics::default();
        assert_eq!(m.prefix_hit_rate(), 1.0, "no probes → nothing missable");
        m.prefix_ref_blocks = 8;
        m.note_pool(4, 4, 6);
        assert!((m.prefix_hit_rate() - 0.75).abs() < 1e-12);
    }

    #[test]
    fn deadline_hit_rate_counts_only_slod_requests() {
        let mut c = ClassMetrics::new();
        assert_eq!(c.deadline_hit_rate(), 1.0, "no SLOs → nothing violated");
        c.deadline_hits = 3;
        c.deadline_misses = 1;
        assert!((c.deadline_hit_rate() - 0.75).abs() < 1e-12);
        let mut m = EngineMetrics::default();
        m.per_class[Priority::Batch.index()].deadline_misses = 2;
        m.per_class[Priority::Batch.index()].max_wait_steps = 41;
        m.per_class[Priority::Batch.index()].done = 2;
        m.aging_promotions = 5;
        let report = m.report();
        assert!(report.contains("aging promotions 5"), "{report}");
        assert!(report.contains("max wait 41"), "{report}");
        assert!(report.contains("deadline hits 0/2 (0%)"), "{report}");
    }

    #[test]
    fn goodput_counts_only_deadline_hit_tokens_per_step() {
        let mut m = EngineMetrics::default();
        // Nothing decoded: goodput is 0.0, never NaN.
        assert_eq!(m.goodput(), 0.0);
        assert_eq!(m.wasted_work_tokens(), 0);
        m.decode_steps = 40;
        let int = Priority::Interactive.index();
        let bat = Priority::Batch.index();
        m.per_class[int].deadline_hit_tokens = 24;
        m.per_class[bat].deadline_hit_tokens = 6;
        m.per_class[int].deadline_missed_tokens = 10;
        m.recomputed_tokens = 5;
        assert!((m.goodput() - 30.0 / 40.0).abs() < 1e-12);
        assert_eq!(m.wasted_work_tokens(), 15, "missed tokens + resume recompute");
    }

    #[test]
    fn goodput_with_zero_slod_requests_counts_all_delivered_tokens() {
        // No request carried an SLO: nothing was violated, so every
        // delivered token is goodput and the hit rate stays 1.0 —
        // ShedPolicy::Off on an SLO-less trace scores the same as PR 4.
        let mut m = EngineMetrics::default();
        m.decode_steps = 16;
        m.per_class[Priority::Interactive.index()].deadline_hit_tokens = 16;
        assert_eq!(m.class(Priority::Interactive).deadline_hit_rate(), 1.0);
        assert!((m.goodput() - 1.0).abs() < 1e-12);
        assert_eq!(m.wasted_work_tokens(), 0);
    }

    #[test]
    fn all_shed_class_grades_nothing_and_contributes_no_goodput() {
        // Every request of a class shed at admission: no first token
        // was attempted, so the deadline grades stay empty (hit rate
        // 1.0 — nothing violated), goodput numerator stays 0, and the
        // class still shows up in the report via its shed count.
        let mut m = EngineMetrics::default();
        m.decode_steps = 8;
        let c = &mut m.per_class[Priority::Batch.index()];
        c.requests_shed = 7;
        assert_eq!(c.done, 0);
        assert_eq!(c.deadline_hit_rate(), 1.0);
        m.requests_shed = 7;
        assert_eq!(m.goodput(), 0.0);
        assert_eq!(m.shed_errors(), 0, "no replay grading → no claimed errors");
        let report = m.report();
        assert!(report.contains("7 shed"), "{report}");
        assert!(report.contains("class batch"), "all-shed class must not vanish: {report}");
        assert!(report.contains("shed 7"), "{report}");
    }

    #[test]
    fn shed_then_retry_counts_one_shed_and_one_completion() {
        // A client sheds once, retries with a fresh request, and the
        // retry completes in budget: the class carries both the shed
        // and the hit, and only the retry's tokens enter goodput.
        let mut m = EngineMetrics::default();
        m.decode_steps = 10;
        let c = &mut m.per_class[Priority::Interactive.index()];
        c.requests_shed = 1;
        c.done = 1;
        c.deadline_hits = 1;
        c.deadline_hit_tokens = 8;
        m.requests_shed = 1;
        m.requests_done = 1;
        assert_eq!(m.class(Priority::Interactive).deadline_hit_rate(), 1.0);
        assert!((m.goodput() - 0.8).abs() < 1e-12);
        assert_eq!(m.wasted_work_tokens(), 0, "the shed itself burned no decode work");
    }

    #[test]
    fn uptime_routes_through_steps_clock() {
        let mut m = EngineMetrics::default();
        m.clock = EngineClock::Steps { step_ms: 2.5, prefill_ms_per_token: 0.0 };
        m.decode_steps = 400;
        m.tokens_generated = 800;
        // 400 steps × 2.5 ms = 1.0 s — exact, regardless of wall time.
        assert_eq!(m.uptime_s(), 1.0);
        assert_eq!(m.throughput_tok_s(), 800.0);
        // And the pin: the same state always reports the same numbers
        // (the old wall-clock leak made this nondeterministic).
        let again = (m.uptime_s(), m.throughput_tok_s());
        std::thread::sleep(std::time::Duration::from_millis(5));
        assert_eq!((m.uptime_s(), m.throughput_tok_s()), again);
    }

    #[test]
    fn report_renders_synthetic_state() {
        // Snapshot-test the rendered lines, not just the arithmetic:
        // build a synthetic metrics state under the Steps clock (so
        // tok/s is deterministic) and pin every line's shape.
        let mut m = EngineMetrics::default();
        m.clock = EngineClock::Steps { step_ms: 10.0, prefill_ms_per_token: 0.0 };
        m.requests_in = 5;
        m.requests_done = 3;
        m.requests_rejected = 1;
        m.requests_shed = 1;
        m.tokens_generated = 24;
        m.prefills = 4;
        m.decode_steps = 12;
        m.injections = 4;
        m.lane_resets = 1;
        m.admission_blocked = 2;
        m.preemptions = 2;
        m.partial_preemptions = 1;
        m.kept_reclaims = 1;
        m.aging_promotions = 1;
        m.resumes = 2;
        m.recomputed_tokens = 6;
        m.recompute_saved_tokens = 4;
        m.grow_events = 3;
        m.grown_blocks = 5;
        m.grow_stalls = 1;
        m.pool_blocks_total = 64;
        m.pool_block_bytes = 1_000_000;
        m.kv_flat_bytes = 128_000_000;
        m.note_pool(32, 32, 7);
        for v in [0.1, 0.2, 0.3] {
            m.ttft.push(v);
            m.e2e_latency.push(v * 2.0);
            m.queue_wait.push(v / 2.0);
            m.decode_step_time.push(0.01);
        }
        let c = &mut m.per_class[Priority::Interactive.index()];
        c.done = 3;
        c.preemptions = 2;
        c.deadline_hits = 2;
        c.deadline_misses = 1;
        c.deadline_hit_tokens = 18;
        c.deadline_missed_tokens = 6;
        c.max_wait_steps = 9;
        c.ttft.push(0.2);
        c.ttft_steps.push(4.0);
        c.e2e.push(0.4);
        c.requests_shed = 1;
        let report = m.report();
        let expected = "requests: 5 in / 3 done / 1 rejected / 1 shed | tokens: 24 (200.0 tok/s)\n\
             prefills: 4 | decode steps: 12 | injections: 4 | lane resets: 1\n\
             kv pool:   peak 32/64 blocks (32.0 MB resident vs 128.0 MB flat, 4.00x) | shared 7 | blocked 2\n\
             admission: mean occupancy 50.0% | preempts 2 (1 partial, 1 kept-reclaims) / resumes 2 (6 tok recomputed, 4 saved) | grows 3 (+5 blocks, 1 stalls) | aging promotions 1";
        assert!(report.starts_with(expected), "report drifted:\n{report}");
        assert!(
            report.contains("goodput:   1.500 tok/step (deadline-hit tokens) | wasted 12 tok (missed-deadline + recompute) | shed errors 0"),
            "{report}"
        );
        assert!(report.contains("ttft_s:    0.200 ± 0.100 [p50 "), "{report}");
        assert!(
            report.contains("class interactive done 3 | preempts 2 | ttft mean 0.2000s (4.0 steps, max wait 9) | e2e mean 0.4000s | deadline hits 2/3 (67%) | shed 1"),
            "{report}"
        );
        // Batch saw nothing: its class line is suppressed.
        assert!(!report.contains("class batch"), "{report}");
    }

    #[test]
    fn snapshot_carries_live_and_aggregate_state() {
        let mut m = EngineMetrics::default();
        m.clock = EngineClock::Steps { step_ms: 1.0, prefill_ms_per_token: 0.0 };
        m.requests_in = 4;
        m.requests_done = 2;
        m.decode_steps = 100;
        m.tokens_generated = 50;
        m.pool_blocks_total = 32;
        m.ttft.push(0.25);
        m.per_class[Priority::Batch.index()].done = 1;
        let s = m.snapshot(3, 2, 17);
        assert_eq!(s.queue_depth, 3);
        assert_eq!(s.busy_lanes, 2);
        assert_eq!(s.pool_blocks_in_use, 17);
        assert_eq!(s.requests_in, 4);
        assert_eq!(s.uptime_s, 0.1);
        assert_eq!(s.throughput_tok_s, 500.0);
        assert_eq!(s.ttft.count, 1);
        assert_eq!(s.classes[Priority::Batch.index()].done, 1);
        // Renders without panicking and round-trips as JSON.
        assert!(s.prometheus().contains("loki_requests_total 4"));
        assert!(s.to_json().to_string().contains("\"requests_in\":4"));
    }

    #[test]
    fn record_stamps_steps_clock_timestamps() {
        let mut m = EngineMetrics::default();
        m.clock = EngineClock::Steps { step_ms: 2.0, prefill_ms_per_token: 0.0 };
        m.decode_steps = 5;
        m.record(EventKind::RequestRejected { id: 1 });
        let ev = m.trace.iter().next().unwrap();
        assert_eq!(ev.ts_ms, 10.0);
        assert_eq!(ev.step, 5);
    }

    #[test]
    fn prefill_charge_extends_the_steps_clock() {
        let mut m = EngineMetrics::default();
        m.clock = EngineClock::Steps { step_ms: 2.0, prefill_ms_per_token: 0.5 };
        m.decode_steps = 10;
        assert_eq!(m.now_ms(), 20.0);
        assert_eq!(m.uptime_s(), 0.02);
        // A 16-token prefill at 0.5 ms/tok advances the charged domain
        // without consuming a decode step.
        m.prefill_charged_ms += 8.0;
        assert_eq!(m.now_ms(), 28.0);
        assert!((m.uptime_s() - 0.028).abs() < 1e-15);
        // Events recorded after the charge carry the charged stamp.
        m.record(EventKind::RequestRejected { id: 1 });
        assert_eq!(m.trace.iter().next().unwrap().ts_ms, 28.0);
    }

    #[test]
    fn report_renders_prefill_accounting_line() {
        let mut m = EngineMetrics::default();
        m.prefill_tokens = 40;
        m.prefill_chunks = 5;
        m.chunked_prefill_tokens = 33;
        m.lane_reset_prefills = 2;
        let report = m.report();
        assert!(
            report.contains(
                "prefill:   40 tok real | chunks 5 (33 tok chunked) | lane-reset prefills 2"
            ),
            "{report}"
        );
    }

    #[test]
    fn turn_metrics_bucket_and_rate() {
        let mut m = EngineMetrics::default();
        assert_eq!(m.turn_cache_hit_rate(), 1.0, "no follow-up probes → nothing missable");
        m.turn_ref_blocks = 8;
        m.turn_shared_blocks = 6;
        assert!((m.turn_cache_hit_rate() - 0.75).abs() < 1e-12);
        // Turns 0..=2 land in their own bucket; 3 and beyond fold into
        // the tail.
        m.note_turn_ttft(0, 10.0);
        m.note_turn_ttft(1, 20.0);
        m.note_turn_ttft(2, 30.0);
        m.note_turn_ttft(3, 40.0);
        m.note_turn_ttft(9, 50.0);
        assert_eq!(m.turn_ttft_ms[0].count(), 1);
        assert_eq!(m.turn_ttft_ms[1].count(), 1);
        assert_eq!(m.turn_ttft_ms[2].count(), 1);
        assert_eq!(m.turn_ttft_ms[3].count(), 2, "turn ≥ 3 folds into the tail bucket");
        assert!((m.turn_ttft_ms[3].mean() - 45.0).abs() < 1e-12);
        m.note_radix(12, 34);
        let report = m.report();
        assert!(report.contains("radix:     12 nodes | 34 tree hits"), "{report}");
        assert!(report.contains("turn>=1 hit rate 75.0% (6/8 blocks)"), "{report}");
        assert!(report.contains("\nturn 0   ttft_ms:"), "{report}");
        assert!(report.contains("\nturn 3+  ttft_ms:"), "{report}");
    }

    #[test]
    fn report_has_no_turn_lines_without_turn_traffic() {
        let m = EngineMetrics::default();
        let report = m.report();
        assert!(!report.contains("\nturn "), "{report}");
        assert!(report.contains("radix:     0 nodes | 0 tree hits"), "{report}");
    }

    #[test]
    fn occupancy_is_zero_without_snapshots() {
        let m = EngineMetrics::default();
        assert_eq!(m.mean_pool_occupancy(), 0.0);
        let mut m = EngineMetrics::default();
        // No pool configured (total 0): snapshots are ignored, not NaN.
        m.note_pool(3, 3, 0);
        assert_eq!(m.mean_pool_occupancy(), 0.0);
    }
}
