//! Fleet-level engine metrics: throughput, latency distributions,
//! scheduler activity. Rendered by `repro serve --report` and the
//! e2e_serving bench.

use std::time::Instant;

use crate::linalg::stats::Summary;

#[derive(Debug)]
pub struct EngineMetrics {
    started: Instant,
    pub requests_in: u64,
    pub requests_done: u64,
    pub tokens_generated: u64,
    pub prefills: u64,
    pub decode_steps: u64,
    pub injections: u64,
    pub lane_resets: u64,
    /// Seconds.
    pub ttft: Summary,
    pub e2e_latency: Summary,
    pub queue_wait: Summary,
    pub decode_step_time: Summary,
}

impl Default for EngineMetrics {
    fn default() -> Self {
        Self {
            started: Instant::now(),
            requests_in: 0,
            requests_done: 0,
            tokens_generated: 0,
            prefills: 0,
            decode_steps: 0,
            injections: 0,
            lane_resets: 0,
            ttft: Summary::new(),
            e2e_latency: Summary::new(),
            queue_wait: Summary::new(),
            decode_step_time: Summary::new(),
        }
    }
}

impl EngineMetrics {
    pub fn uptime_s(&self) -> f64 {
        self.started.elapsed().as_secs_f64()
    }

    /// Generated tokens per second of wall time.
    pub fn throughput_tok_s(&self) -> f64 {
        let t = self.uptime_s();
        if t > 0.0 {
            self.tokens_generated as f64 / t
        } else {
            0.0
        }
    }

    pub fn report(&self) -> String {
        format!(
            "requests: {} in / {} done | tokens: {} ({:.1} tok/s)\n\
             prefills: {} | decode steps: {} | injections: {} | lane resets: {}\n\
             ttft_s:    {}\n\
             e2e_s:     {}\n\
             queue_s:   {}\n\
             step_s:    {}",
            self.requests_in,
            self.requests_done,
            self.tokens_generated,
            self.throughput_tok_s(),
            self.prefills,
            self.decode_steps,
            self.injections,
            self.lane_resets,
            self.ttft.display(),
            self.e2e_latency.display(),
            self.queue_wait.display(),
            self.decode_step_time.display(),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn throughput_math() {
        let mut m = EngineMetrics::default();
        m.tokens_generated = 100;
        std::thread::sleep(std::time::Duration::from_millis(20));
        assert!(m.throughput_tok_s() > 0.0);
        assert!(m.report().contains("tokens: 100"));
    }
}
