//! The engine's time authority: [`EngineClock`] (wall time vs the
//! deterministic decode-steps twin) plus the *only* sanctioned raw
//! wall-clock reads in the coordinator/runtime/obs/kvpool subtree.
//!
//! `repro-lint`'s `raw-clock` rule forbids `Instant::now()` everywhere
//! else in those modules: PR 5's double-stamp bug (a first token graded
//! against a *second* `Instant::now()` taken after the first stamp) is
//! exactly the drift class that breaks Steps-clock trace byte-equality.
//! Wall time enters through [`wall_now`]/[`WallTimer`] here, and the
//! Steps twin never observes it.

use std::time::Instant;

/// The single sanctioned raw wall-clock read. Call sites take one stamp
/// per scheduling decision and pass the `Instant` around instead of
/// re-reading — re-reads are how double-stamp bugs happen.
#[allow(clippy::disallowed_methods)] // the allowlisted read everything else routes through
pub fn wall_now() -> Instant {
    Instant::now()
}

/// Scoped wall-duration measurement for rate observations
/// (`ServiceRateEstimator::observe_*` and the runtime perf counters).
/// Exists so hot-path timing reads as intent and the raw clock stays in
/// this module.
#[derive(Clone, Copy, Debug)]
pub struct WallTimer(Instant);

impl WallTimer {
    pub fn start() -> Self {
        WallTimer(wall_now())
    }

    /// Seconds elapsed since [`WallTimer::start`].
    pub fn elapsed_s(&self) -> f64 {
        self.0.elapsed().as_secs_f64()
    }
}

/// Which clock the predictor and the deadline grader run on.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub enum EngineClock {
    /// Real time: rates are EWMA-estimated from measured step/prefill
    /// wall time, deadlines are graded against the emission `Instant`.
    /// The serving default.
    #[default]
    Wall,
    /// The deterministic decode-steps twin for `SimRuntime` tests: one
    /// decode step costs exactly `step_ms` virtual milliseconds and
    /// prefill costs `prefill_ms_per_token` per prompt token; a
    /// request's elapsed time is `(now_step - submitted_step) ·
    /// step_ms` and its first token is graded `hit` iff `ttft_steps ·
    /// step_ms + prefill_ms_per_token · prompt_len ≤ slo_ms` — the
    /// grader charges exactly what the predictor prices, so a `Strict`
    /// shed can never disagree with the grade it preempted. No wall
    /// clock anywhere — shed decisions, deadline grades and goodput
    /// are bit-reproducible.
    Steps {
        /// Virtual milliseconds one decode step costs.
        step_ms: f64,
        /// Virtual milliseconds one prefilled prompt token costs.
        prefill_ms_per_token: f64,
    },
}

impl EngineClock {
    /// Milliseconds a queued request has already waited, in this
    /// clock's domain. The *same* conversion the grader uses — both
    /// sides of the shed decision must price time identically, or a
    /// `Strict` shed could disagree with the grade it preempted.
    pub fn waited_ms(
        &self,
        now: Instant,
        submitted: Instant,
        now_step: u64,
        submitted_step: u64,
    ) -> f64 {
        match *self {
            EngineClock::Wall => now.saturating_duration_since(submitted).as_secs_f64() * 1e3,
            EngineClock::Steps { step_ms, .. } => {
                now_step.saturating_sub(submitted_step) as f64 * step_ms
            }
        }
    }

    /// Grade a first token against its deadline. `Wall` compares the
    /// emission instant to the arrival-stamped deadline; `Steps` prices
    /// the emission in the virtual domain — decode steps *plus* the
    /// prompt-proportional prefill cost, exactly what the predictor
    /// charges, so the zero-shed-error invariant is structural rather
    /// than comment-enforced.
    pub fn deadline_hit(
        &self,
        emitted: Instant,
        deadline: Instant,
        ttft_steps: u64,
        prompt_tokens: usize,
        slo_ms: f64,
    ) -> bool {
        match *self {
            EngineClock::Wall => emitted <= deadline,
            EngineClock::Steps { step_ms, prefill_ms_per_token } => {
                let virtual_ms =
                    ttft_steps as f64 * step_ms + prefill_ms_per_token * prompt_tokens as f64;
                virtual_ms <= slo_ms
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    use std::time::Duration;

    #[test]
    fn clock_domains_price_time_consistently() {
        let steps = EngineClock::Steps { step_ms: 2.0, prefill_ms_per_token: 0.5 };
        let t0 = wall_now();
        // Steps domain ignores wall instants entirely: waited is a pure
        // function of the step delta.
        assert_eq!(steps.waited_ms(t0, t0, 7, 3), 8.0);
        assert_eq!(steps.waited_ms(t0, t0, 3, 7), 0.0, "pre-submission clamps to 0");
        // Grading charges steps *and* the prompt-proportional prefill:
        // 4 steps · 2 ms + 8 tokens · 0.5 ms = 12 ms.
        assert!(steps.deadline_hit(t0, t0, 4, 8, 12.0), "boundary is inclusive");
        assert!(!steps.deadline_hit(t0, t0, 4, 8, 11.9));
        // Wall domain compares instants and ignores the step fields.
        let wall = EngineClock::Wall;
        let deadline = t0 + Duration::from_millis(50);
        assert!(wall.deadline_hit(t0, deadline, u64::MAX, usize::MAX, 0.0));
        assert!(!wall.deadline_hit(deadline + Duration::from_millis(1), deadline, 0, 0, 0.0));
        let waited = wall.waited_ms(t0 + Duration::from_millis(25), t0, 0, 0);
        assert!((waited - 25.0).abs() < 1.0, "wall waited ≈ 25 ms, got {waited}");
    }

    #[test]
    fn engine_clock_defaults_to_wall() {
        assert_eq!(EngineClock::default(), EngineClock::Wall);
    }

    #[test]
    fn wall_timer_measures_nonnegative_monotonic_time() {
        let t = WallTimer::start();
        let a = t.elapsed_s();
        let b = t.elapsed_s();
        assert!(a >= 0.0);
        assert!(b >= a, "elapsed must be monotonic: {a} then {b}");
    }
}
