//! Front-door request routing over N engine replicas.
//!
//! The single [`super::Engine`] is policy-rich but one box; the
//! millions-of-users step is a sharded frontend that picks *which*
//! replica serves each request. The router is deliberately a pure,
//! deterministic decision core — it owns no channels, spawns no
//! threads, and never touches an engine-owned `TableSet`. The
//! [`crate::server::Frontend`] wires its decisions to real submission
//! channels; the e2e bench drives it directly.
//!
//! [`RoutePolicy::PrefixAffinity`] keys on the same content-addressed
//! block hashes the kvpool's prefix-sharing tables register
//! ([`crate::kvpool::prefix_block_hashes`]): the router mirrors, per
//! replica, the full-block hashes of every prompt it routed there, so
//! "which replica already holds this prompt's prefix blocks" is a set
//! intersection — no cross-thread peeking into live pool state, and
//! byte-identical decisions for a fixed request sequence. A bounded
//! load-skew override gives the affinity policy a global admission
//! view: when the affinity pick is running too far ahead of its least
//! loaded sibling (queued work the PR 5 predictor would shed), the
//! request is routed to the least loaded replica instead — the hot
//! replica sheds, siblings absorb.

use crate::kvpool::prefix_block_hashes;
use std::collections::BTreeSet;

/// Which replica a request lands on.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum RoutePolicy {
    /// Cycle through replicas in submission order — the locality-blind
    /// baseline affinity routing is graded against.
    #[default]
    RoundRobin,
    /// Route to the replica whose routed-prompt mirror shares the most
    /// prefix blocks with this prompt (ties: least outstanding work,
    /// then lowest index), subject to the load-skew override.
    PrefixAffinity,
}

impl RoutePolicy {
    /// Stable CLI name (`--route-policy round-robin|prefix-affinity`).
    pub fn name(&self) -> &'static str {
        match self {
            RoutePolicy::RoundRobin => "round-robin",
            RoutePolicy::PrefixAffinity => "prefix-affinity",
        }
    }

    /// Parse a CLI spelling; `None` for unknown input.
    pub fn parse(s: &str) -> Option<Self> {
        match s {
            "round-robin" | "rr" => Some(RoutePolicy::RoundRobin),
            "prefix-affinity" | "affinity" => Some(RoutePolicy::PrefixAffinity),
            _ => None,
        }
    }
}

/// Router shape and policy knobs.
#[derive(Clone, Copy, Debug)]
pub struct RouterCfg {
    /// Number of engine replicas behind the frontend (≥ 1; 0 clamps).
    pub replicas: usize,
    pub policy: RoutePolicy,
    /// KV block size the replicas run — affinity hashes prompts at this
    /// granularity, and it must match the engines' `PoolConfig` or the
    /// mirror would disagree with the tables it models.
    pub block_size: usize,
    /// Global-admission override for `PrefixAffinity`: when the
    /// affinity pick has more than this many outstanding requests above
    /// the least loaded replica, route there instead. Locality is worth
    /// a bounded queue imbalance, not an unbounded one — past the bound
    /// the hot replica would only shed what a sibling could absorb.
    pub max_load_skew: usize,
}

impl Default for RouterCfg {
    fn default() -> Self {
        Self {
            replicas: 2,
            policy: RoutePolicy::RoundRobin,
            block_size: 16,
            max_load_skew: 8,
        }
    }
}

/// One routing decision, kept for determinism pinning and trace
/// cross-checks (request id → replica index).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct RouteDecision {
    pub id: u64,
    pub replica: usize,
    /// Prefix blocks of this prompt already mirrored on the chosen
    /// replica at decision time (the affinity score it won with; 0
    /// under `RoundRobin`).
    pub matched_blocks: usize,
}

/// Deterministic replica chooser. See the module docs for the design.
pub struct Router {
    cfg: RouterCfg,
    /// Next replica under `RoundRobin`.
    rr_next: usize,
    /// Per-replica mirror of the full-block prefix hashes of every
    /// prompt routed there. Sorted sets: membership-checked and never
    /// hashed-iterated, so decisions are reproducible by construction.
    mirror: Vec<BTreeSet<u64>>,
    /// Requests routed to each replica and not yet completed/shed — the
    /// router's global load view.
    outstanding: Vec<usize>,
    /// Total requests ever routed to each replica.
    routed: Vec<u64>,
    /// Shed replies observed per replica (fed back by the frontend).
    shed: Vec<u64>,
    decisions: Vec<RouteDecision>,
}

impl Router {
    pub fn new(cfg: RouterCfg) -> Self {
        let n = cfg.replicas.max(1);
        Self {
            cfg: RouterCfg { replicas: n, ..cfg },
            rr_next: 0,
            mirror: vec![BTreeSet::new(); n],
            outstanding: vec![0; n],
            routed: vec![0; n],
            shed: vec![0; n],
            decisions: Vec::new(),
        }
    }

    pub fn replicas(&self) -> usize {
        self.cfg.replicas
    }

    pub fn policy(&self) -> RoutePolicy {
        self.cfg.policy
    }

    /// Route one request: pick a replica, mirror the prompt's prefix
    /// hashes there, and log the decision.
    pub fn route(&mut self, id: u64, prompt: &[i32]) -> usize {
        self.route_inner(id, prompt, None)
    }

    /// Route a shed-retry, excluding the replica that shed it — with
    /// more than one replica, a resubmitted request always lands on a
    /// sibling (which, under affinity, may then warm its own mirror).
    pub fn route_retry(&mut self, id: u64, prompt: &[i32], prior: usize) -> usize {
        let avoid = if self.cfg.replicas > 1 { Some(prior) } else { None };
        self.route_inner(id, prompt, avoid)
    }

    fn route_inner(&mut self, id: u64, prompt: &[i32], avoid: Option<usize>) -> usize {
        let hashes = prefix_block_hashes(prompt, self.cfg.block_size);
        let (replica, matched) = match self.cfg.policy {
            RoutePolicy::RoundRobin => {
                let mut r = self.rr_next % self.cfg.replicas;
                if Some(r) == avoid {
                    self.rr_next += 1;
                    r = self.rr_next % self.cfg.replicas;
                }
                self.rr_next += 1;
                (r, 0)
            }
            RoutePolicy::PrefixAffinity => self.affinity_pick(&hashes, avoid),
        };
        for h in &hashes {
            self.mirror[replica].insert(*h);
        }
        self.outstanding[replica] += 1;
        self.routed[replica] += 1;
        self.decisions.push(RouteDecision { id, replica, matched_blocks: matched });
        replica
    }

    /// Affinity core: max prefix-block overlap, tie-broken by least
    /// outstanding then lowest index, overridden to the least loaded
    /// replica when the winner's load skew exceeds the bound.
    fn affinity_pick(&self, hashes: &[u64], avoid: Option<usize>) -> (usize, usize) {
        let mut best: Option<(usize, usize)> = None; // (replica, matched)
        let mut least: Option<usize> = None; // least-outstanding replica
        for r in 0..self.cfg.replicas {
            if Some(r) == avoid {
                continue;
            }
            let matched = hashes.iter().filter(|h| self.mirror[r].contains(h)).count();
            let better = match best {
                None => true,
                Some((br, bm)) => {
                    matched > bm
                        || (matched == bm && self.outstanding[r] < self.outstanding[br])
                }
            };
            if better {
                best = Some((r, matched));
            }
            let lighter = match least {
                None => true,
                Some(lr) => self.outstanding[r] < self.outstanding[lr],
            };
            if lighter {
                least = Some(r);
            }
        }
        let (br, bm) = match best {
            Some(b) => b,
            // Unreachable shape (≥ 1 replica, avoid only set when > 1),
            // but the hot path degrades to replica 0 instead of
            // panicking the dispatch thread.
            None => (0, 0),
        };
        let lr = least.unwrap_or(br);
        if self.outstanding[br] > self.outstanding[lr] + self.cfg.max_load_skew {
            (lr, hashes.iter().filter(|h| self.mirror[lr].contains(h)).count())
        } else {
            (br, bm)
        }
    }

    /// Pool eviction feedback: replica `replica` physically freed the
    /// prefix block keyed by `hash` (a `PoolEvent::PrefixReleased`
    /// drained by the frontend), so the mirror entry is dead — affinity
    /// must stop counting it toward longest-match. This is what keeps a
    /// long-lived mirror honest: before the feedback channel the mirror
    /// was append-only per run while the pools released drained
    /// refcounts underneath it.
    pub fn note_evicted(&mut self, replica: usize, hash: u64) {
        if let Some(m) = self.mirror.get_mut(replica) {
            m.remove(&hash);
        }
    }

    /// Mirrored prefix entries per replica (gauge for stats/tests).
    pub fn mirror_len(&self, replica: usize) -> usize {
        self.mirror.get(replica).map(|m| m.len()).unwrap_or(0)
    }

    /// A routed request finished (any terminal reply but a shed).
    pub fn note_done(&mut self, replica: usize) {
        if let Some(o) = self.outstanding.get_mut(replica) {
            *o = o.saturating_sub(1);
        }
    }

    /// A routed request was shed by its replica — load is released and
    /// the shed feeds the router's global view.
    pub fn note_shed(&mut self, replica: usize) {
        if let Some(o) = self.outstanding.get_mut(replica) {
            *o = o.saturating_sub(1);
        }
        if let Some(s) = self.shed.get_mut(replica) {
            *s += 1;
        }
    }

    /// Requests currently routed-but-unfinished, per replica.
    pub fn outstanding(&self) -> &[usize] {
        &self.outstanding
    }

    /// Total requests ever routed, per replica.
    pub fn routed(&self) -> &[u64] {
        &self.routed
    }

    /// Shed replies observed, per replica.
    pub fn shed_counts(&self) -> &[u64] {
        &self.shed
    }

    /// Every decision made, in submission order.
    pub fn decisions(&self) -> &[RouteDecision] {
        &self.decisions
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn prompt(tag: i32, len: usize) -> Vec<i32> {
        (0..len as i32).map(|i| tag * 1000 + i).collect()
    }

    /// `prefix ++ unique tail` prompts, the shape affinity exists for.
    fn tenant_prompt(tenant: i32, user: i32, bs: usize) -> Vec<i32> {
        let mut p = prompt(tenant, 4 * bs);
        p.extend((0..bs as i32 / 2).map(|i| 900_000 + user * 100 + i));
        p
    }

    #[test]
    fn policy_names_round_trip() {
        for p in [RoutePolicy::RoundRobin, RoutePolicy::PrefixAffinity] {
            assert_eq!(RoutePolicy::parse(p.name()), Some(p));
        }
        assert_eq!(RoutePolicy::parse("rr"), Some(RoutePolicy::RoundRobin));
        assert_eq!(RoutePolicy::parse("affinity"), Some(RoutePolicy::PrefixAffinity));
        assert_eq!(RoutePolicy::parse("nope"), None);
    }

    #[test]
    fn round_robin_cycles_and_logs_decisions() {
        let mut r = Router::new(RouterCfg { replicas: 3, ..Default::default() });
        let p = prompt(1, 40);
        let picks: Vec<usize> = (0..6).map(|i| r.route(i, &p)).collect();
        assert_eq!(picks, vec![0, 1, 2, 0, 1, 2]);
        assert_eq!(r.routed(), &[2, 2, 2]);
        assert_eq!(r.decisions().len(), 6);
        assert_eq!(r.decisions()[3], RouteDecision { id: 3, replica: 0, matched_blocks: 0 });
    }

    #[test]
    fn affinity_pins_a_tenant_to_one_replica() {
        let bs = 16;
        let cfg = RouterCfg {
            replicas: 2,
            policy: RoutePolicy::PrefixAffinity,
            block_size: bs,
            max_load_skew: 64,
        };
        let mut r = Router::new(cfg);
        // First sight of each tenant: no overlap anywhere, ties go to
        // the least loaded replica — tenants spread out.
        let a0 = r.route(0, &tenant_prompt(1, 0, bs));
        let b0 = r.route(1, &tenant_prompt(2, 0, bs));
        assert_ne!(a0, b0, "fresh tenants spread across idle replicas");
        // Every later request of a tenant follows its prefix.
        for i in 0..8 {
            assert_eq!(r.route(100 + i, &tenant_prompt(1, 1 + i as i32, bs)), a0);
            assert_eq!(r.route(200 + i, &tenant_prompt(2, 1 + i as i32, bs)), b0);
        }
        let d = r.decisions();
        assert!(d[2].matched_blocks >= 4, "repeat tenant must match its prefix blocks");
    }

    #[test]
    fn load_skew_override_sheds_to_the_least_loaded_sibling() {
        let bs = 8;
        let cfg = RouterCfg {
            replicas: 2,
            policy: RoutePolicy::PrefixAffinity,
            block_size: bs,
            max_load_skew: 2,
        };
        let mut r = Router::new(cfg);
        let t = tenant_prompt(7, 0, bs);
        let home = r.route(0, &t);
        // Pile outstanding work onto the tenant's home replica without
        // completing any of it; past the skew bound the router must
        // absorb on the sibling despite the affinity score.
        let mut overflowed = None;
        for i in 1..8 {
            let got = r.route(i, &tenant_prompt(7, i as i32, bs));
            if got != home {
                overflowed = Some(i);
                break;
            }
        }
        let flip = overflowed.expect("skew bound must eventually override affinity");
        assert!(flip >= 3, "override must not fire before the bound (fired at {flip})");
        // Completions drain the home replica; affinity resumes.
        for _ in 0..6 {
            r.note_done(home);
        }
        assert_eq!(r.route(99, &tenant_prompt(7, 99, bs)), home);
    }

    #[test]
    fn retry_routing_lands_on_a_sibling() {
        let bs = 8;
        let mut r = Router::new(RouterCfg {
            replicas: 2,
            policy: RoutePolicy::PrefixAffinity,
            block_size: bs,
            max_load_skew: 1000,
        });
        let t = tenant_prompt(3, 0, bs);
        let home = r.route(0, &t);
        r.note_shed(home);
        assert_eq!(r.shed_counts()[home], 1);
        let retry = r.route_retry(1, &t, home);
        assert_ne!(retry, home, "retry must land on a sibling replica");
        // Single replica: nothing to avoid, retry goes back.
        let mut solo = Router::new(RouterCfg { replicas: 1, ..Default::default() });
        assert_eq!(solo.route_retry(0, &t, 0), 0);
    }

    #[test]
    fn router_mirror_tracks_pool_evictions() {
        let bs = 16;
        let cfg = RouterCfg {
            replicas: 2,
            policy: RoutePolicy::PrefixAffinity,
            block_size: bs,
            max_load_skew: 64,
        };
        let mut r = Router::new(cfg);
        let t = tenant_prompt(5, 0, bs);
        let home = r.route(0, &t);
        r.note_done(home);
        let mirrored = r.mirror_len(home);
        assert!(mirrored >= 4, "routing must mirror the prompt's full blocks");
        // The pool on `home` drains the tenant's prefix refcounts and
        // emits PrefixReleased per block; the frontend feeds them back.
        for h in prefix_block_hashes(&t, bs) {
            r.note_evicted(home, h);
        }
        assert_eq!(r.mirror_len(home), 0, "dead entries must leave the mirror");
        // With the mirror honest, the next request of that tenant scores
        // zero matches — it ties on overlap and goes to the least loaded
        // replica, not to the stale home.
        let again = r.route(1, &tenant_prompt(5, 1, bs));
        let d = r.decisions()[1];
        assert_eq!(d.matched_blocks, 0, "affinity must not count evicted entries");
        assert_eq!(again, d.replica);
        // Eviction feedback for an unknown replica or hash is a no-op.
        r.note_evicted(99, 1234);
        r.note_evicted(home, 0xDEAD_BEEF);
    }

    #[test]
    fn identical_request_sequences_decide_identically() {
        let bs = 16;
        let cfg = RouterCfg {
            replicas: 3,
            policy: RoutePolicy::PrefixAffinity,
            block_size: bs,
            max_load_skew: 4,
        };
        let run = || {
            let mut r = Router::new(cfg);
            let mut out = Vec::new();
            for i in 0..64u64 {
                let tenant = (i % 5) as i32;
                let user = (i / 5) as i32;
                out.push(r.route(i, &tenant_prompt(tenant, user, bs)));
                if i % 3 == 0 {
                    r.note_done(out[i as usize]);
                }
            }
            (out, r.decisions().to_vec())
        };
        let (a, da) = run();
        let (b, db) = run();
        assert_eq!(a, b, "same sequence must route identically");
        assert_eq!(da, db);
    }
}
