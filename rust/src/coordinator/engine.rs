//! The continuous-batching generation engine.
//!
//! One persistent decode **gang** (a compiled batch bucket of lanes)
//! advances every iteration; finished lanes are refilled by prefilling the
//! next queued request as a batch-1 state and *injecting* it into the gang
//! between iterations (iteration-level scheduling, Orca-style). The
//! attention variant — Full / Loki(k_f, d_f) / H2O / PCAAttn — is a gang
//!-level serving config: Loki drops in as a scheduler choice, not a model
//! fork, which is exactly the deployment story the paper argues for.
//!
//! Memory: the engine mirrors the device-resident KV cache with a
//! [`crate::kvpool`] block allocator + per-sequence block tables, under a
//! configurable [`AdmissionPolicy`]:
//!
//! * [`AdmissionPolicy::ReserveFull`] — a request is injected **only when
//!   the allocator can grant every block of its reservation** (prompt +
//!   whole decode budget). Conservative: admitted work can never OOM
//!   mid-flight, but long-tail `max_new_tokens` leaves most reserved
//!   blocks unwritten and the gang under-occupied.
//! * [`AdmissionPolicy::Speculative`] — admit on a partial reservation
//!   (`reserve_frac` of the decode budget) and **grow** block tables on
//!   demand at decode time, `headroom_blocks` at a time. When a grow
//!   finds the pool empty, the engine **preempts** the youngest other
//!   lane holding private blocks: its non-shared blocks return to the
//!   allocator (shared prefixes survive via refcounts) and the request
//!   is re-queued at the front with its generated tokens. Resumption
//!   re-prefills `prompt ++ produced` — prefix recompute — and restores
//!   the sampler state, so the resumed output is byte-identical to an
//!   uncontended run. Loki makes this cheap: the hot low-rank K̂ tier is
//!   a small fraction of the cache, and shared prompt blocks never left.
//!
//! Preemption itself is a policy surface:
//!
//! * [`VictimPolicy`] picks *who* is evicted. `YoungestFirst` is the
//!   single-class default; `PriorityAware` turns the engine into a
//!   multi-class scheduler — requests carry a
//!   [`Priority`](super::request::Priority) class (`Interactive` /
//!   `Batch`), victims are scored by (class, recompute cost, age), a
//!   grower never evicts strictly-higher-priority work, and the pending
//!   queue is kept class-banded so interactive traffic is admitted ahead
//!   of queued batch work. `DeadlineAware` adds arrival-stamped SLO
//!   deadlines (`GenRequest::slo_ms`) — the pending queue is re-ordered
//!   earliest-effective-deadline-first every scheduling round — and
//!   cross-class aging ([`EngineConfig::aging_steps`]): a batch request
//!   that has waited the configured number of decode steps is promoted
//!   ahead of later interactive work, bounding batch starvation under a
//!   sustained interactive flood.
//! * [`PreemptMode`] picks *how much* is evicted. `Full` releases the
//!   victim's whole table; `Partial` frees only the tail blocks the
//!   grower needs ([`TableSet::truncate_tail`]) and leaves the prefix
//!   granted, so the resume recomputes just the truncated suffix —
//!   byte-identical outputs, strictly fewer recomputed tokens, paid for
//!   with pool capacity parked on queued work. (The deterministic sim
//!   backend still re-prefills the full history to rebuild its state;
//!   the pool tables and the recompute counters model what a block-
//!   table-aware device cache — where the kept prefix never left —
//!   would actually recompute.) Kept prefixes are second-tier victims:
//!   when no busy lane can be preempted, the engine reclaims them from
//!   the queue before giving up, so a lone grower can never be starved
//!   by parked blocks.
//!
//! Full prompt blocks are shared copy-on-write across requests with equal
//! prefixes (content-addressed, vLLM-style), so gang-wide system prompts
//! are paid for once in the pool accounting. This replaces the old
//! `lane_reset_frac` hygiene hack; resets remain only for the physical
//! edge case of a *padding* lane drifting into the cache bound.
//!
//! Execution goes through the [`DecodeBackend`] trait, so the whole state
//! machine — admission, growth, preemption, resumption — runs unchanged
//! over the PJRT runtime or the deterministic
//! [`crate::runtime::SimRuntime`] test harness.
//!
//! Backpressure: submissions go through a bounded `SyncSender`; when the
//! queue is full, callers block (admission control at the front door).
//!
//! Overload: queueing discipline alone cannot save a request whose TTFT
//! deadline is already unreachable — it can only make it die in a
//! better-ordered line, wasting the prefill and decode steps it consumes
//! on the way. [`EngineConfig::shed`] adds **predictive admission**: an
//! online service-rate estimator ([`super::predictor`]) prices every
//! queued SLO'd request's TTFT against the lanes ahead of it each
//! scheduling round, and [`ShedPolicy::Strict`] /
//! [`ShedPolicy::Hedged`] reject provably-doomed requests at admission
//! with a structured shed reply (predicted TTFT + retry hint) instead
//! of queueing them to die. `Off` (default) pins PR 4 bit-identically;
//! [`EngineClock::Steps`] is the deterministic decode-steps twin that
//! keeps the `SimRuntime` overload tests wall-clock-free.

use std::cmp::Reverse;
use std::collections::VecDeque;
use std::sync::mpsc::{sync_channel, Receiver, Sender, SyncSender, TryRecvError};
use std::time::Instant;

use anyhow::Result;

use crate::kvpool::{BlockAllocator, SeqId, TableSet};
use crate::model::ByteTokenizer;
use crate::obs::{EventKind, FinishCode, PoolEvent, StatsHub};
use crate::runtime::{DecodeBackend, DecodeRequest, RuntimeService, StateId};

use super::clock::{wall_now, EngineClock, WallTimer};
use super::metrics::EngineMetrics;
use super::predictor::{ServiceRateEstimator, ShedPolicy};
use super::request::{
    FinishReason, GenRequest, GenResult, Priority, QueuedRequest, RequestTiming, ShedInfo,
};
use super::sampler::Sampler;

/// Token slots reserved beyond `prompt + decode budget`: one for the
/// first token sampled from prefill logits (fed before any decode ran)
/// and one guard slot at the stop-condition boundary. Changing this
/// changes every admission decision — see the pinned regression test in
/// `tests/engine_admission.rs`.
pub const RESERVE_SLACK_TOKENS: usize = 2;

/// Prefill-vs-decode priority (the classic serving trade-off: filling
/// lanes fast boosts throughput; decoding first protects inter-token
/// latency of running requests).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SchedulerPolicy {
    /// Fill every free lane before the next decode iteration.
    PrefillFirst,
    /// At most one injection per decode iteration.
    DecodeFirst,
}

/// How much of a request's decode budget admission must secure up front
/// (`repro serve --admission full|speculative --reserve-frac F
/// --headroom-blocks N`).
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum AdmissionPolicy {
    /// Reserve `prompt + max_new + RESERVE_SLACK_TOKENS` slots at
    /// admission; decode can never outgrow its grant.
    ReserveFull,
    /// Reserve `prompt + ceil(reserve_frac · max_new) + slack` and grow
    /// on demand, preempting the youngest lane under pool pressure.
    /// Caveat when the prefill bound is tighter than `max_len`: a lane
    /// whose `prompt ++ produced` recompute no longer fits the prefill
    /// bound cannot be preempted faithfully; under unresolvable pressure
    /// it finishes early with `CacheFull` (delivering everything decoded
    /// so far) rather than silently truncating its resume history.
    Speculative {
        /// Fraction of `max_new_tokens` secured at admission (clamped to
        /// [0, 1]; 1.0 behaves like `ReserveFull` with a grow path).
        reserve_frac: f64,
        /// Blocks requested per grow — headroom beyond the immediately
        /// needed block is opportunistic (partial grants are fine).
        headroom_blocks: usize,
    },
}

impl Default for AdmissionPolicy {
    fn default() -> Self {
        AdmissionPolicy::ReserveFull
    }
}

/// How `grow_or_preempt` picks its victim when the pool runs dry — and,
/// for the multi-class policies, how the pending queue is ordered
/// (`repro serve --victim-policy youngest|priority|deadline`).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum VictimPolicy {
    /// The youngest other eligible lane (highest admission tick) — the
    /// single-class default; PR 2's admission tests pin this behavior.
    #[default]
    YoungestFirst,
    /// Multi-class scheduling. Victims are scored by (priority class,
    /// recompute cost, age): `Batch` lanes are evicted before
    /// `Interactive` ones, then the cheapest resume, then the youngest;
    /// a grower never evicts a lane of strictly higher priority (it
    /// yields its own lane instead). The pending queue is kept
    /// class-banded — `Interactive` ahead of `Batch`, resumes at the
    /// front of their band — so latency-sensitive work is also
    /// *admitted* first, not merely preempted last. Under
    /// [`PreemptMode::Partial`] the recompute-cost term is the *planned
    /// truncation depth* ([`TableSet::planned_truncation`]) — the tokens
    /// the resume would actually recompute — not the full-history proxy.
    PriorityAware,
    /// Everything `PriorityAware` does, plus deadlines and aging:
    ///
    /// * **Admission** re-orders the pending queue every scheduling
    ///   round by *earliest effective deadline*: interactive work (and
    ///   batch work promoted by aging) ahead of batch, SLO'd requests by
    ///   their arrival-stamped deadline within the band, deadline-less
    ///   ones FIFO behind them; preempted resumes and aged requests are
    ///   overdue by definition, so their effective deadline is their
    ///   arrival instant (earliest in the band).
    /// * **Cross-class aging** ([`EngineConfig::aging_steps`]) promotes
    ///   a `Batch` request to interactive-equivalent scheduling once it
    ///   has waited that many decode steps, bounding batch starvation
    ///   under a sustained interactive flood: a batch request submitted
    ///   at step `s` is schedulable ahead of all later interactive work
    ///   from step `s + aging_steps`, so its wait is at most
    ///   `aging_steps` plus one lane-drain (the longest running decode)
    ///   — deterministic in decode steps, pinned by
    ///   `tests/engine_admission.rs`.
    /// * **Victim scoring** adds an SLO-slack term: among equal-class
    ///   candidates the lane with the *most* remaining deadline slack
    ///   (deadline-less lanes count as infinite) is evicted first, then
    ///   the cheapest planned recompute, then the youngest.
    DeadlineAware,
    /// Radix-tree-aware single-class policy (`--victim-policy
    /// idle-leaf`): evict the lane holding the most *private* (leaf)
    /// blocks first, breaking ties youngest-first. Leaves of the prefix
    /// tree free the most memory per preemption while — structurally —
    /// never releasing an ancestor block another live sequence still
    /// references: shared interior blocks carry one refcount per
    /// descendant table, so evicting a leaf returns exactly its private
    /// tail. Queueing discipline is identical to [`Self::YoungestFirst`]
    /// (single-class FIFO); only victim scoring changes.
    IdleLeaf,
}

/// How much of a victim's KV a preemption releases
/// (`repro serve --preempt full|partial`).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum PreemptMode {
    /// Release the victim's entire block table (PR 2 behavior): the
    /// resume recomputes the whole `prompt ++ produced` history.
    #[default]
    Full,
    /// Release only the tail blocks the grower needs
    /// ([`TableSet::truncate_tail`]): the victim keeps its prefix blocks
    /// granted while queued and resumes by recomputing just the
    /// truncated suffix — byte-identical outputs, strictly fewer
    /// recomputed tokens, at the cost of pool capacity held by
    /// preempted work (reclaimed as second-tier victims under
    /// unresolvable pressure).
    Partial,
}

/// Token slots a request reserves at admission under `policy`. The pure
/// admission formula, exposed for tests and capacity planning.
pub fn reserve_tokens(
    policy: AdmissionPolicy,
    prompt_len: usize,
    max_new: usize,
    max_len: usize,
) -> usize {
    let decode_budget = match policy {
        AdmissionPolicy::ReserveFull => max_new,
        AdmissionPolicy::Speculative { reserve_frac, .. } => {
            (max_new as f64 * reserve_frac.clamp(0.0, 1.0)).ceil() as usize
        }
    };
    (prompt_len + decode_budget + RESERVE_SLACK_TOKENS).min(max_len)
}

/// KV-pool sizing and sharing knobs (`repro serve --block-size
/// --pool-blocks --no-prefix-share`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct PoolConfig {
    /// Token slots per block (the paging granularity).
    pub block_size: usize,
    /// Total pool blocks; 0 sizes the pool to the worst case
    /// (`gang_batch · ceil(max_len / block_size)`), i.e. admission can
    /// only tighten things when set below that.
    pub num_blocks: usize,
    /// Share full prompt blocks across requests with identical prefixes.
    pub prefix_sharing: bool,
}

impl Default for PoolConfig {
    fn default() -> Self {
        Self { block_size: 16, num_blocks: 0, prefix_sharing: true }
    }
}

#[derive(Clone, Debug)]
pub struct EngineConfig {
    pub pca: String,
    pub variant: crate::runtime::DecodeVariant,
    /// Desired gang width; clamped to the largest compiled bucket.
    pub gang_batch: usize,
    pub scheduler: SchedulerPolicy,
    /// Bound of the submission queue (backpressure).
    pub max_queue: usize,
    /// KV-pool admission control (replaces the old `lane_reset_frac`).
    pub pool: PoolConfig,
    /// Reservation policy: full-budget or speculative-with-preemption.
    pub admission: AdmissionPolicy,
    /// Who gets preempted under pool pressure (and, under the
    /// multi-class policies, how the pending queue is ordered).
    pub victim_policy: VictimPolicy,
    /// How much of a victim's KV a preemption releases.
    pub preempt: PreemptMode,
    /// Cross-class aging bound in decode steps (`repro serve
    /// --aging-steps N`; `None` disables). Only consulted by
    /// [`VictimPolicy::DeadlineAware`]: a queued `Batch` request that
    /// has waited this many decode steps is promoted to
    /// interactive-equivalent scheduling, which bounds its remaining
    /// wait by one lane-drain. `None` pins the PR 3 behavior where
    /// batch starvation under sustained interactive load is unbounded.
    pub aging_steps: Option<u64>,
    /// Predictive early load shedding (`repro serve --shed-policy
    /// off|strict|hedged --shed-margin F`): every scheduling round the
    /// engine predicts each queued SLO'd request's TTFT from the lanes
    /// ahead of it (online service-rate estimator — EWMA decode-step
    /// cost + prompt-length-proportional prefill cost) and rejects
    /// requests whose prediction misses their deadline by the policy's
    /// margin, with a structured shed reply instead of queueing them to
    /// die. `Off` (default) pins PR 4 bit-identically.
    pub shed: ShedPolicy,
    /// Clock the predictor and deadline grader run on: `Wall` (serving
    /// default) or the deterministic decode-steps twin
    /// ([`EngineClock::Steps`]) the `SimRuntime` tests use to keep shed
    /// decisions, deadline grades and goodput wall-clock-free.
    pub clock: EngineClock,
    /// Chunked prefill (`repro serve --prefill-chunk N`): split every
    /// prefill into `N`-token chunks advanced one per scheduling round,
    /// interleaved with decode steps of the running lanes — an admitted
    /// request occupies a [`Lane::Prefilling`] slot and is injected into
    /// the gang only when its last chunk lands. Bounds the head-of-line
    /// blocking a long prompt inflicts on interactive first tokens, at
    /// the cost of `ceil(len / N) − 1` extra rounds for the long prompt
    /// itself. `None` (default) prefills monolithically, pinning the
    /// prior behavior bit-identically.
    pub prefill_chunk: Option<usize>,
    /// Steps-clock prefill pricing for prefix-shared blocks: when on, a
    /// fresh admission whose leading prompt blocks were served from the
    /// content-addressed prefix index is charged prefill time only for
    /// the blocks it actually materialized — a replica already holding a
    /// prompt's prefix delivers a cheaper (virtual-time) first token,
    /// which is the locality win prefix-affinity routing is graded on.
    /// The same discounted token count feeds that request's deadline
    /// grade. The shed predictor deliberately keeps pricing the *full*
    /// prompt (conservative: it can over-predict, never under-predict),
    /// so the scenario-6 zero-shed-error invariant only holds with the
    /// discount off. `false` (default) pins every earlier Steps trace
    /// bit-identically; `Wall` ignores it (real prefills cost real
    /// time). Estimator observations always bill real tokens either way.
    pub prefix_prefill_discount: bool,
    pub verbose: bool,
}

impl Default for EngineConfig {
    fn default() -> Self {
        Self {
            pca: "wiki_pre".to_string(),
            variant: crate::runtime::DecodeVariant::Full,
            gang_batch: usize::MAX,
            scheduler: SchedulerPolicy::PrefillFirst,
            max_queue: 256,
            pool: PoolConfig::default(),
            admission: AdmissionPolicy::ReserveFull,
            victim_policy: VictimPolicy::YoungestFirst,
            preempt: PreemptMode::Full,
            aging_steps: None,
            shed: ShedPolicy::Off,
            clock: EngineClock::Wall,
            prefill_chunk: None,
            prefix_prefill_discount: false,
            verbose: false,
        }
    }
}

/// Runtime limits the scheduler needs, decoupled from `Manifest` so the
/// engine can run over any [`DecodeBackend`] (notably the deterministic
/// sim harness, which has no artifacts to read them from).
#[derive(Clone, Copy, Debug)]
pub struct EngineCaps {
    /// Physical KV length bound per lane.
    pub max_len: usize,
    /// Largest prompt the prefill path accepts.
    pub max_prompt: usize,
    /// The *resolved* gang width the engine will run — callers pick a
    /// width the backend can actually decode (`Engine::new` rounds the
    /// requested width to a compiled batch bucket; re-clamping it here
    /// would produce a non-bucket width the device graphs reject).
    pub gang_batch: usize,
    /// KV bytes one token occupies across all layers/heads (K + V, f32).
    pub bytes_per_token: u64,
}

enum Lane {
    Free,
    Busy(Box<BusyLane>),
    /// Chunked-prefill mode only: the lane is reserved (pool blocks
    /// granted, `lane_seq` live) but its request is still being
    /// prefilled chunk-by-chunk into a batch-1 side state; the gang
    /// lane at this index keeps advancing padding until injection.
    Prefilling(Box<PrefillLane>),
}

/// In-flight chunked prefill occupying a lane slot
/// ([`EngineConfig::prefill_chunk`]). Holds the queue item unopened —
/// first-token sampling / resume restoration happen at injection, via
/// the same [`Engine::lane_for`] path the monolithic prefill uses — so
/// a mid-prefill preemption can requeue the item byte-identically.
struct PrefillLane {
    item: PendingItem,
    /// Full token sequence to prefill (clamped prompt, or
    /// `prompt ++ produced` for a resume).
    tokens: Vec<i32>,
    /// Tokens already materialized in `state` (`tokens[..done]`).
    done: usize,
    /// Batch-1 backend state holding the partial prefix; `None` until
    /// the first chunk runs.
    state: Option<StateId>,
    /// Prefix-shared prompt tokens this admission was granted (blocks ×
    /// block size) — the deadline grade's discount under
    /// [`EngineConfig::prefix_prefill_discount`].
    shared_tokens: usize,
    /// Shared tokens not yet consumed by chunk charging: the leading
    /// chunks cover the shared prefix, so each chunk's Steps-clock
    /// charge draws down this credit first.
    discount_left: usize,
    /// Admission tick (assigned at admission, not injection, so victim
    /// age ranks mid-prefill lanes as the youngest occupants).
    tick: u64,
    /// `decode_steps` when the first chunk ran — the prefill-stall
    /// histogram measures decode interleaving from here to injection.
    start_step: u64,
}

struct BusyLane {
    req: QueuedRequest,
    /// The (clamped) prompt actually prefilled — resumption re-prefills
    /// exactly this plus `produced`, so it must be kept verbatim.
    prompt: Vec<i32>,
    sampler: Sampler,
    produced: Vec<i32>,
    next_token: i32,
    ttft_s: Option<f64>,
    /// Decode iteration at which the first token was emitted — the
    /// deterministic TTFT the multi-class tests compare across classes.
    ttft_step: Option<u64>,
    /// Whether the first token beat the request's SLO deadline (`None`
    /// until the first token, or forever when no SLO was set).
    deadline_hit: Option<bool>,
    /// Prompt tokens this request's deadline grade charges prefill time
    /// for: the full (clamped) prompt, minus the prefix-shared tokens of
    /// its original admission when
    /// [`EngineConfig::prefix_prefill_discount`] is on — set once at
    /// first admission and kept across preempt/resume cycles, like the
    /// rest of the first-token bookkeeping.
    grade_prompt_tokens: usize,
    /// Times this request was evicted mid-flight and re-queued.
    preempted: u32,
    /// Original admission tick — *kept* across preempt/resume cycles so
    /// the youngest-victim policy measures true age; handing resumes a
    /// fresh tick would make the most-recently-victimized lane the
    /// preferred victim again (preemption thrash).
    tick: u64,
}

/// Prefix blocks a partially-preempted sequence kept granted in the pool
/// while it waits in the queue: `seq` is still a live table and resume
/// recomputes only `history_len - len` tokens.
#[derive(Clone, Copy, Debug)]
struct KeptPrefix {
    seq: SeqId,
    /// Token positions the kept blocks cover.
    len: usize,
}

/// Queue entries: fresh submissions and preempted requests awaiting
/// re-admission. Resumes carry their full generation state (plus any
/// kept prefix under partial preemption) and re-enter at the front of
/// the queue — or, under `VictimPolicy::PriorityAware`, at the front of
/// their class band — which is what makes the preemption loop
/// livelock-free within a class.
enum PendingItem {
    Fresh(QueuedRequest),
    Resume {
        lane: Box<BusyLane>,
        kept: Option<KeptPrefix>,
    },
}

/// Importance class of a queue entry (class-banded queue ordering).
fn item_priority(item: &PendingItem) -> Priority {
    item_queued(item).req.priority
}

/// The queued-request record behind either entry kind.
fn item_queued(item: &PendingItem) -> &QueuedRequest {
    match item {
        PendingItem::Fresh(q) => q,
        PendingItem::Resume { lane, .. } => &lane.req,
    }
}

/// Mutable twin of [`item_queued`] (aging promotion flips `aged`).
fn item_queued_mut(item: &mut PendingItem) -> &mut QueuedRequest {
    match item {
        PendingItem::Fresh(q) => q,
        PendingItem::Resume { lane, .. } => &mut lane.req,
    }
}

/// Effective-deadline ordering key under [`VictimPolicy::DeadlineAware`]
/// — smaller schedules first. Fields: effective band (interactive or
/// aging-promoted batch before batch), urgency (overdue/deadlined before
/// deadline-less), effective deadline (resumes and aged requests are
/// overdue, so theirs is their arrival instant; deadline-less entries
/// fall back to arrival for FIFO), and the deterministic submission-step
/// tiebreak.
fn effective_deadline_key(item: &PendingItem) -> (u8, u8, Instant, u64) {
    let overdue = matches!(item, PendingItem::Resume { .. });
    let q = item_queued(item);
    let band = if q.req.priority == Priority::Interactive || q.aged { 0 } else { 1 };
    match (overdue || q.aged, q.deadline) {
        (true, _) => (band, 0, q.submitted, q.submitted_step),
        (false, Some(d)) => (band, 0, d, q.submitted_step),
        (false, None) => (band, 1, q.submitted, q.submitted_step),
    }
}

/// Microseconds of SLO slack a running lane still has (deadline-less
/// lanes have infinite slack — they are the preferred victims among
/// equals).
fn slack_micros(deadline: Option<Instant>, now: Instant) -> u128 {
    match deadline {
        None => u128::MAX,
        Some(d) => d.saturating_duration_since(now).as_micros(),
    }
}

/// Importance class of a lane's occupant (`None` for free lanes).
fn lane_priority(lane: &Lane) -> Option<Priority> {
    match lane {
        Lane::Busy(b) => Some(b.req.req.priority),
        Lane::Prefilling(p) => Some(item_queued(&p.item).req.priority),
        Lane::Free => None,
    }
}

/// Whether a lane slot is occupied (decoding or mid-chunked-prefill) —
/// the engine's idle/exit/refill checks all key off occupancy, while
/// decode-only sections key off [`Lane::Busy`] specifically.
fn lane_occupied(lane: &Lane) -> bool {
    !matches!(lane, Lane::Free)
}

/// Admission tick for a queue item entering a lane: fresh work draws the
/// next tick, resumes keep their original (see [`BusyLane::tick`]).
fn assign_tick(item: &PendingItem, admit_tick: &mut u64) -> u64 {
    match item {
        PendingItem::Fresh(_) => {
            *admit_tick += 1;
            *admit_tick
        }
        PendingItem::Resume { lane, .. } => lane.tick,
    }
}

/// Outcome of a pool-admission attempt.
enum Admit {
    /// Blocks granted; the sequence owns its reservation and the prefill
    /// tokens were materialized (built lazily — Backpressure iterations
    /// never clone token vectors). The trailing count is how many full
    /// prompt blocks this admission *shared* from the prefix index
    /// (always 0 for resumes — their prefix never left the table), the
    /// input to the Steps-clock prefill discount and the hit-rate tally.
    Granted(SeqId, Vec<i32>, usize),
    /// Not enough free blocks *right now* — wait for a completion.
    Backpressure,
    /// The request can never fit the configured pool; fail it fast.
    NeverFits,
}

/// Map the engine's [`FinishReason`] onto the trace layer's plain-data
/// [`FinishCode`] (`obs` is a leaf module — it cannot name coordinator
/// types, so the engine translates at the emission site).
fn finish_code(r: FinishReason) -> FinishCode {
    match r {
        FinishReason::MaxTokens => FinishCode::MaxTokens,
        FinishReason::StopToken => FinishCode::StopToken,
        FinishReason::CacheFull => FinishCode::CacheFull,
        FinishReason::EngineShutdown => FinishCode::EngineShutdown,
        FinishReason::Shed => FinishCode::Shed,
    }
}

/// Admission age of a lane (0 for free lanes — never a preemption
/// candidate anyway).
fn busy_tick(lane: &Lane) -> u64 {
    match lane {
        Lane::Busy(b) => b.tick,
        Lane::Prefilling(p) => p.tick,
        Lane::Free => 0,
    }
}

/// The engine: owns the decode backend and the scheduling loop.
pub struct Engine {
    backend: Box<dyn DecodeBackend>,
    cfg: EngineConfig,
    max_len: usize,
    max_prompt: usize,
    gang_batch: usize,
    /// KV bytes one token occupies across all layers/heads (K + V, f32) —
    /// converts pool blocks into the bytes the device cache would hold.
    bytes_per_token: u64,
    tokenizer: ByteTokenizer,
    /// Live-metrics publication slot (`"stats"` server command); `None`
    /// outside serving — publishing is skipped entirely then.
    stats: Option<StatsHub>,
    /// Eviction-feedback channel: every physically freed prefix block's
    /// chain hash (`PoolEvent::PrefixReleased`) is forwarded here so the
    /// frontend can keep the router's per-replica affinity mirror
    /// honest. `None` outside sharded serving — forwarding is skipped.
    evict_tx: Option<Sender<u64>>,
}

impl Engine {
    /// Bounded submission channel for this engine config.
    pub fn channel(cfg: &EngineConfig) -> (SyncSender<GenRequest>, Receiver<GenRequest>) {
        sync_channel(cfg.max_queue)
    }

    pub fn new(service: &RuntimeService, cfg: EngineConfig) -> Self {
        let man = &service.manifest;
        let largest = man.batch_buckets.iter().copied().max().unwrap_or(1);
        let m = &man.model;
        let caps = EngineCaps {
            max_len: m.max_len,
            max_prompt: man.prefill_buckets.iter().copied().max().unwrap_or(0),
            gang_batch: man.pick_batch_bucket(cfg.gang_batch.min(largest)),
            bytes_per_token: (m.n_layers * m.n_heads * m.head_dim * 2 * 4) as u64,
        };
        Self::with_backend(Box::new(service.handle()), caps, cfg)
    }

    /// Build an engine over an arbitrary backend — the deterministic
    /// test-harness entrypoint (`SimRuntime` + explicit caps), also the
    /// seam for future multi-backend serving. `caps.gang_batch` is used
    /// as-is: it is the already-resolved width (a compiled bucket on the
    /// PJRT path), not a request to be clamped further.
    pub fn with_backend(
        backend: Box<dyn DecodeBackend>,
        caps: EngineCaps,
        cfg: EngineConfig,
    ) -> Self {
        let gang_batch = caps.gang_batch.max(1);
        Self {
            backend,
            max_len: caps.max_len,
            max_prompt: caps.max_prompt,
            gang_batch,
            bytes_per_token: caps.bytes_per_token,
            cfg,
            tokenizer: ByteTokenizer,
            stats: None,
            evict_tx: None,
        }
    }

    /// Attach a [`StatsHub`]: the engine publishes a fresh
    /// [`crate::obs::StatsSnapshot`] into it every scheduling round, so a
    /// server thread can answer `"stats"` queries mid-flight without
    /// touching engine state.
    pub fn with_stats_hub(mut self, hub: StatsHub) -> Self {
        self.stats = Some(hub);
        self
    }

    /// Attach an eviction-feedback channel: the engine forwards the
    /// chain hash of every physically freed prefix block
    /// (`PoolEvent::PrefixReleased`) as it drains pool events each
    /// scheduling round. The sharded frontend gives each replica engine
    /// one of these and drains the receivers into
    /// [`super::router::Router::note_evicted`] before routing, so the
    /// affinity mirror never advertises prefix blocks the pool has
    /// already reclaimed.
    pub fn with_evict_feedback(mut self, tx: Sender<u64>) -> Self {
        self.evict_tx = Some(tx);
        self
    }

    /// Account one physical prefill of `tokens` *real* tokens: the
    /// real-token counter feeds the report's prefill line, and under
    /// [`EngineClock::Steps`] the virtual per-token prefill cost is
    /// charged onto the engine clock (`EngineMetrics::prefill_charged_ms`
    /// — folded into `now_ms`/`uptime_s`), so prefill work advances the
    /// deterministic clock the same way the wall clock would move.
    /// `prefill_ms_per_token == 0.0` (every pinned scenario) charges
    /// nothing, keeping prior traces bit-identical. `shared_tokens` is
    /// the prefix-shared portion of this prefill: with
    /// [`EngineConfig::prefix_prefill_discount`] on, those tokens are
    /// charged no virtual time — modeling the suffix-aware device
    /// prefill a block-table-aware cache performs (the pool accounting
    /// already skips shared blocks; this makes the Steps clock agree).
    fn charge_prefill(&self, metrics: &mut EngineMetrics, tokens: usize, shared_tokens: usize) {
        metrics.prefill_tokens += tokens as u64;
        let discount = if self.cfg.prefix_prefill_discount {
            let d = shared_tokens.min(tokens);
            metrics.prefill_discounted_tokens += d as u64;
            d
        } else {
            0
        };
        if let EngineClock::Steps { prefill_ms_per_token, .. } = self.cfg.clock {
            metrics.prefill_charged_ms += (tokens - discount) as f64 * prefill_ms_per_token;
        }
    }

    /// Publish a snapshot into the stats hub, if one is attached.
    fn publish_stats(
        &self,
        metrics: &EngineMetrics,
        queue_depth: usize,
        busy_lanes: usize,
        pool_in_use: usize,
    ) {
        let Some(hub) = &self.stats else { return };
        if let Ok(mut slot) = hub.lock() {
            *slot = Some(metrics.snapshot(queue_depth, busy_lanes, pool_in_use));
        }
    }

    /// Single queue-insertion rule for both entry kinds, so the two band
    /// comparators can never drift apart. Under `YoungestFirst` the queue
    /// is a plain deque (back for fresh work, front for resumes — the
    /// FIFO age priority that keeps the preemption loop livelock-free).
    /// Under `PriorityAware` (and `DeadlineAware`, whose dynamic pick
    /// starts from the same static order) the queue is class-banded:
    /// fresh work lands at the *back* of its band (after every
    /// same-or-higher-priority entry), resumes at the *front* of it — so
    /// a preempted `Batch` request never jumps ahead of waiting
    /// `Interactive` work, and within a band resumes still precede fresh
    /// submissions.
    fn enqueue(&self, pending: &mut VecDeque<PendingItem>, item: PendingItem, front_of_band: bool) {
        match self.cfg.victim_policy {
            // Idle-leaf scoring only changes *victim* choice; queueing
            // stays single-class FIFO, same as youngest-first.
            VictimPolicy::YoungestFirst | VictimPolicy::IdleLeaf => {
                if front_of_band {
                    pending.push_front(item);
                } else {
                    pending.push_back(item);
                }
            }
            VictimPolicy::PriorityAware | VictimPolicy::DeadlineAware => {
                let c = item_priority(&item);
                let pos = pending
                    .iter()
                    .position(|it| {
                        let p = item_priority(it);
                        if front_of_band {
                            p >= c
                        } else {
                            p > c
                        }
                    })
                    .unwrap_or(pending.len());
                pending.insert(pos, item);
            }
        }
    }

    /// Cross-class aging pass, run **once per scheduler iteration**
    /// (decode steps only advance once per iteration, so scanning more
    /// often can never promote anything new): queued `Batch` work that
    /// has waited [`EngineConfig::aging_steps`] decode steps is
    /// promoted, sticky and counted once. Promotion is measured in
    /// decode steps — wall-clock-free — which is what makes the
    /// starvation bound provable: from the promoting step onward the
    /// aged request outranks every unaged and later-arrived entry, so it
    /// takes the very next admitted slot. Other policies: no-op.
    fn age_pending(
        &self,
        pending: &mut VecDeque<PendingItem>,
        now_step: u64,
        metrics: &mut EngineMetrics,
    ) {
        if self.cfg.victim_policy != VictimPolicy::DeadlineAware {
            return;
        }
        let Some(bound) = self.cfg.aging_steps else { return };
        for item in pending.iter_mut() {
            let q = item_queued_mut(item);
            if q.req.priority == Priority::Batch
                && !q.aged
                && now_step.saturating_sub(q.submitted_step) >= bound
            {
                q.aged = true;
                metrics.aging_promotions += 1;
            }
        }
    }

    /// The `DeadlineAware` head pick, run before every head-of-line
    /// admission attempt: rotate the earliest-effective-deadline entry
    /// to the queue front (the deadline ordering is dynamic — aging and
    /// resumes change it between admissions — so the static band order
    /// alone is not enough). Other policies: no-op.
    fn schedule_head(&self, pending: &mut VecDeque<PendingItem>) {
        if self.cfg.victim_policy != VictimPolicy::DeadlineAware || pending.len() < 2 {
            return;
        }
        let best = pending
            .iter()
            .enumerate()
            .min_by_key(|(_, it)| effective_deadline_key(it))
            .map(|(i, _)| i)
            .unwrap_or(0);
        if best != 0 {
            // lint:allow(panic-in-hot-path): `best` indexes the same deque enumerated this round
            let item = pending.remove(best).expect("index in range");
            pending.push_front(item);
        }
    }

    fn enqueue_fresh(&self, pending: &mut VecDeque<PendingItem>, q: QueuedRequest) {
        self.enqueue(pending, PendingItem::Fresh(q), false);
    }

    fn requeue_resume(
        &self,
        pending: &mut VecDeque<PendingItem>,
        lane: Box<BusyLane>,
        kept: Option<KeptPrefix>,
    ) {
        self.enqueue(pending, PendingItem::Resume { lane, kept }, true);
    }

    /// Evict a busy lane. Under [`PreemptMode::Full`] every pool block
    /// the victim holds is released (shared prefixes survive via
    /// refcounts — `release` only returns a block at refcount zero);
    /// under [`PreemptMode::Partial`] only the `need_blocks` tail blocks
    /// the grower asked for are freed ([`TableSet::truncate_tail`]) and
    /// the kept prefix rides along in the queue for a cheaper resume.
    /// Either way the request re-enters the pending queue with its
    /// accumulated state for byte-identical resumption by prefix (or
    /// suffix) recompute.
    #[allow(clippy::too_many_arguments)]
    fn preempt(
        &self,
        lane: usize,
        need_blocks: usize,
        lanes: &mut [Lane],
        lane_seq: &mut [Option<SeqId>],
        tables: &mut TableSet,
        pool: &mut BlockAllocator,
        pending: &mut VecDeque<PendingItem>,
        metrics: &mut EngineMetrics,
    ) {
        let Some(seq) = lane_seq[lane].take() else { return };
        let mut b = match std::mem::replace(&mut lanes[lane], Lane::Free) {
            Lane::Busy(b) => b,
            Lane::Prefilling(mut p) => {
                // Mid-prefill eviction: the partial batch-1 state is
                // worthless without the chunks behind it, so discard it
                // and release the whole reservation; the item re-enters
                // its band front *unopened* (a fresh request stays
                // fresh — no first token was sampled, no Resume event —
                // and re-admission reopens the trace episode with a new
                // `prefill_start`). The chunks already run are the
                // eviction's recompute cost; `select_victim` priced
                // exactly that.
                if let Some(s) = p.state.take() {
                    self.backend.free(s);
                }
                let free_before = pool.num_free();
                tables.preempt_free(pool, seq);
                metrics.preemptions += 1;
                if let PendingItem::Resume { lane: b, kept } = &mut p.item {
                    b.preempted += 1;
                    // The kept prefix was folded into `seq` at admission
                    // (`resume_extend`) and just freed with it.
                    *kept = None;
                }
                let q = item_queued(&p.item);
                metrics.per_class[q.req.priority.index()].preemptions += 1;
                metrics.record(EventKind::PreemptFull {
                    id: q.req.id,
                    lane: lane as u32,
                    freed_blocks: pool.num_free().saturating_sub(free_before) as u32,
                });
                self.enqueue(pending, p.item, true);
                return;
            }
            Lane::Free => {
                // Unreachable — preemption targets occupied lanes — but
                // a seq must never leak if it ever fires.
                tables.preempt_free(pool, seq);
                return;
            }
        };
        // What the resume will re-prefill. The table's mirror length can
        // sit one position past this: the step-5 pass advances the mirror
        // for the in-flight token *before* section 6 would have delivered
        // it into `produced` — a preempted lane skips that delivery and
        // recomputes the token instead, so the kept prefix must be
        // clamped to the replay or `resume_extend` would see a kept
        // position the replay cannot cover.
        let replay = b.prompt.len() + b.produced.len();
        let free_before = pool.num_free();
        let kept = match self.cfg.preempt {
            PreemptMode::Full => {
                tables.preempt_free(pool, seq);
                None
            }
            PreemptMode::Partial => {
                let out = tables.truncate_tail(pool, seq, need_blocks);
                if out.freed == 0 || out.kept_len == 0 || replay == 0 {
                    // Nothing came free (fully-shared tail) or nothing
                    // was worth keeping: degrade to a whole-sequence
                    // release so the grow loop is guaranteed progress.
                    tables.preempt_free(pool, seq);
                    None
                } else {
                    tables.clamp_len(seq, replay);
                    pool.stats.preempt_frees += 1;
                    metrics.partial_preemptions += 1;
                    Some(KeptPrefix { seq, len: out.kept_len.min(replay) })
                }
            }
        };
        metrics.preemptions += 1;
        b.preempted += 1;
        metrics.per_class[b.req.req.priority.index()].preemptions += 1;
        let freed_blocks = pool.num_free().saturating_sub(free_before) as u32;
        let id = b.req.req.id;
        match &kept {
            Some(k) => metrics.record(EventKind::PreemptPartial {
                id,
                lane: lane as u32,
                freed_blocks,
                kept_len: k.len as u32,
            }),
            None => metrics.record(EventKind::PreemptFull {
                id,
                lane: lane as u32,
                freed_blocks,
            }),
        }
        self.requeue_resume(pending, b, kept);
    }

    /// Tokens a resume would recompute if this lane were preempted right
    /// now for `need_blocks` blocks — the recompute-cost term of the
    /// multi-class victim scores. Under [`PreemptMode::Full`] that is the
    /// whole `prompt ++ produced` replay; under [`PreemptMode::Partial`]
    /// it is the *planned truncation depth*: the dry-run twin of the
    /// eviction [`Engine::preempt`] would actually perform, including its
    /// degrade-to-full conditions (nothing frees, nothing kept, nothing
    /// to replay), so candidates are priced by what preempting them
    /// would really cost — not by the full-history proxy that overcharged
    /// long-running lanes with cheap tails.
    fn victim_cost(
        &self,
        b: &BusyLane,
        seq: SeqId,
        need_blocks: usize,
        tables: &TableSet,
        pool: &BlockAllocator,
    ) -> usize {
        let replay = b.prompt.len() + b.produced.len();
        match self.cfg.preempt {
            PreemptMode::Full => replay,
            PreemptMode::Partial => {
                let plan = tables.planned_truncation(pool, seq, need_blocks);
                if plan.freed == 0 || plan.kept_len == 0 || replay == 0 {
                    replay
                } else {
                    replay - plan.kept_len.min(replay)
                }
            }
        }
    }

    /// Victim choice when a grow finds the pool dry, over the lanes that
    /// (a) would actually return blocks — a lane whose blocks are all
    /// shared frees nothing — and (b) can be resumed faithfully (their
    /// `prompt ++ produced` recompute fits the prefill bound).
    /// `need_blocks` is what the grower is asking for — partial-mode
    /// scoring prices each candidate by the tail it would actually lose.
    #[allow(clippy::too_many_arguments)]
    fn select_victim(
        &self,
        grower: usize,
        need_blocks: usize,
        lanes: &[Lane],
        lane_seq: &[Option<SeqId>],
        lane_tick: &[u64],
        tables: &TableSet,
        pool: &BlockAllocator,
    ) -> Option<usize> {
        let candidates = (0..lanes.len()).filter(|&l| {
            l != grower
                && self.resumable(&lanes[l])
                && lane_seq[l].is_some_and(|s| tables.private_blocks(pool, s) > 0)
        });
        match self.cfg.victim_policy {
            VictimPolicy::YoungestFirst => candidates.max_by_key(|&l| lane_tick[l]),
            // Most private (leaf-tail) blocks first — the eviction that
            // returns the most capacity per preemption — then the
            // youngest. Ancestor blocks shared with another live
            // sequence carry a refcount per sharer, so this can only
            // ever free a leaf's private tail, never an interior node a
            // live descendant still references.
            VictimPolicy::IdleLeaf => candidates.max_by_key(|&l| {
                let private = lane_seq[l].map_or(0, |s| tables.private_blocks(pool, s));
                (private, lane_tick[l])
            }),
            VictimPolicy::PriorityAware | VictimPolicy::DeadlineAware => {
                let own = lane_priority(&lanes[grower]).unwrap_or(Priority::Batch);
                let deadline_aware = self.cfg.victim_policy == VictimPolicy::DeadlineAware;
                let now = wall_now();
                candidates
                    // Never evict strictly-higher-priority work; the
                    // grower yields its own lane instead (the caller's
                    // no-victim path).
                    .filter(|&l| lane_priority(&lanes[l]).is_some_and(|p| p >= own))
                    .max_by_key(|&l| {
                        // lint:allow(panic-in-hot-path): the candidate filter keeps only occupied lanes
                        let seq = lane_seq[l].expect("candidates hold live seqs");
                        // Score: lowest class first (Batch > Interactive
                        // in the Ord), then — deadline-aware only — the
                        // most SLO slack, then the cheapest planned
                        // recompute, then the youngest admission.
                        let (priority, deadline, cost) = match &lanes[l] {
                            Lane::Busy(b) => (
                                b.req.req.priority,
                                b.req.deadline,
                                self.victim_cost(b, seq, need_blocks, tables, pool),
                            ),
                            // Evicting a mid-prefill lane forfeits the
                            // chunks already run — re-admission restarts
                            // the prefill from token zero.
                            Lane::Prefilling(p) => {
                                let q = item_queued(&p.item);
                                (q.req.priority, q.deadline, p.done)
                            }
                            // lint:allow(panic-in-hot-path): the candidate filter keeps only occupied lanes
                            Lane::Free => unreachable!("candidates are occupied lanes"),
                        };
                        let slack = if deadline_aware {
                            slack_micros(deadline, now)
                        } else {
                            u128::MAX
                        };
                        (priority, slack, Reverse(cost), lane_tick[l])
                    })
            }
        }
    }

    /// Second-tier victims: prefixes kept in the pool by queued
    /// (already-preempted) requests. Reclaiming one only raises that
    /// request's recompute on resume — never its output — so this runs
    /// before a grower gives up or yields. Walks from the back of the
    /// queue (lowest band first) in two passes: first only prefixes
    /// holding private (refcount-1) blocks, which actually return
    /// capacity; then, only if nothing came free, the rest — entries
    /// whose blocks are shared free nothing *individually*, but
    /// releasing all sharers does, so the fallback pass keeps the
    /// lone-grower guarantee intact. Returns whether a block came free.
    fn reclaim_queued_kept(
        &self,
        pending: &mut VecDeque<PendingItem>,
        tables: &mut TableSet,
        pool: &mut BlockAllocator,
        metrics: &mut EngineMetrics,
    ) -> bool {
        let before = pool.num_free();
        for productive_only in [true, false] {
            for item in pending.iter_mut().rev() {
                let PendingItem::Resume { kept, .. } = item else { continue };
                let Some(k) = *kept else { continue };
                if productive_only && tables.private_blocks(pool, k.seq) == 0 {
                    continue;
                }
                *kept = None;
                tables.preempt_free(pool, k.seq);
                metrics.kept_reclaims += 1;
                if pool.num_free() > before {
                    return true;
                }
            }
            if pool.num_free() > before {
                break;
            }
        }
        pool.num_free() > before
    }

    /// Run until the submission channel closes and all work drains.
    /// Returns the fleet metrics.
    pub fn run(&self, rx: Receiver<GenRequest>) -> Result<EngineMetrics> {
        let mut metrics = EngineMetrics::default();
        // Trace timestamps route through the engine clock: wall time in
        // serving, decode-step-derived (bit-deterministic) under `Steps`.
        metrics.clock = self.cfg.clock;
        // Analytic score-path cost of the configured attention variant —
        // turns Loki's reduced-data-movement claim into a per-round
        // observable on every `SchedRound` event.
        let (score_d_frac, score_j_sel) = self.cfg.variant.score_cost_params();
        let mut pending: VecDeque<PendingItem> = VecDeque::new();
        let mut lanes: Vec<Lane> = (0..self.gang_batch).map(|_| Lane::Free).collect();
        let mut lane_len: Vec<usize> = vec![0; self.gang_batch];
        // Admission age per lane (monotone tick): preemption always picks
        // the *youngest* victim, protecting requests with sunk decode work.
        let mut lane_tick: Vec<u64> = vec![0; self.gang_batch];
        let mut admit_tick: u64 = 0;
        let mut gang: Option<StateId> = None;
        let mut rx_open = true;

        // ---- KV pool: the admission-control mirror of the device cache.
        let bs = self.cfg.pool.block_size.max(1);
        let blocks_per_lane = self.max_len.div_ceil(bs);
        let num_blocks = if self.cfg.pool.num_blocks == 0 {
            self.gang_batch * blocks_per_lane
        } else {
            self.cfg.pool.num_blocks
        };
        let mut pool = BlockAllocator::new(num_blocks, bs);
        let mut tables = TableSet::new(bs, self.cfg.pool.prefix_sharing);
        let mut lane_seq: Vec<Option<SeqId>> = vec![None; self.gang_batch];
        // Online service-rate estimator behind predictive shedding:
        // fed by every timed prefill/decode below; fixed-rate under the
        // deterministic steps clock.
        let mut est = ServiceRateEstimator::new(self.cfg.clock);
        metrics.pool_blocks_total = num_blocks as u64;
        metrics.pool_block_bytes = bs as u64 * self.bytes_per_token;
        metrics.kv_flat_bytes = (self.gang_batch * self.max_len) as u64 * self.bytes_per_token;
        // Seed the stats hub before the first round so a `"stats"` query
        // racing engine startup sees an (empty) snapshot, not an error.
        self.publish_stats(&metrics, 0, 0, 0);

        loop {
            // ---- 1. admit into the queue ----------------------------------
            loop {
                match rx.try_recv() {
                    Ok(req) => {
                        metrics.requests_in += 1;
                        metrics.record(EventKind::RequestAdmitted {
                            id: req.id,
                            class: req.priority.index() as u8,
                            prompt_len: req.prompt.len() as u32,
                            max_new: req.max_new_tokens as u32,
                        });
                        self.enqueue_fresh(
                            &mut pending,
                            QueuedRequest::stamp(req, metrics.decode_steps, metrics.now_ms()),
                        );
                    }
                    Err(TryRecvError::Empty) => break,
                    Err(TryRecvError::Disconnected) => {
                        rx_open = false;
                        break;
                    }
                }
            }
            // Occupied = decoding *or* mid-chunked-prefill: a lane with
            // chunks left must keep the loop turning (and must block
            // the idle `recv` below from parking the engine on it).
            let any_occupied = lanes.iter().any(lane_occupied);
            if !rx_open && pending.is_empty() && !any_occupied {
                break;
            }
            if pending.is_empty() && !any_occupied {
                // Idle: block for the next submission.
                match rx.recv() {
                    Ok(req) => {
                        metrics.requests_in += 1;
                        metrics.record(EventKind::RequestAdmitted {
                            id: req.id,
                            class: req.priority.index() as u8,
                            prompt_len: req.prompt.len() as u32,
                            max_new: req.max_new_tokens as u32,
                        });
                        self.enqueue_fresh(
                            &mut pending,
                            QueuedRequest::stamp(req, metrics.decode_steps, metrics.now_ms()),
                        );
                    }
                    Err(_) => break,
                }
            }
            // Cross-class aging: once per iteration (decode_steps is
            // constant until section 5, so this is exactly as often as
            // promotions can change).
            self.age_pending(&mut pending, metrics.decode_steps, &mut metrics);
            // Predictive admission: shed queued SLO'd requests whose
            // predicted TTFT provably misses their deadline, before any
            // prefill or pool capacity is spent on them.
            self.shed_doomed(&mut pending, &lanes, &est, &mut metrics);

            // ---- 2. bootstrap the gang with a batched prefill -------------
            if gang.is_none() && !pending.is_empty() && self.cfg.prefill_chunk.is_some() {
                // Chunked mode bootstraps the gang with pure padding so
                // *every* admission — the first included — flows through
                // the incremental chunk path in section 3b; nothing is
                // ever prefilled monolithically. No real tokens: nothing
                // to observe, bill, or charge.
                let (id, _) =
                    self.backend.prefill(&self.cfg.pca, vec![vec![0]; self.gang_batch])?;
                gang = Some(id);
                metrics.prefills += 1;
                for len in lane_len.iter_mut() {
                    *len = 1; // padding prompt [0]
                }
            }
            if gang.is_none() && !pending.is_empty() {
                let mut batch: Vec<(PendingItem, Vec<i32>, SeqId, usize)> = Vec::new();
                while batch.len() < self.gang_batch {
                    self.schedule_head(&mut pending);
                    let Some(front) = pending.front() else { break };
                    match self.try_admit(&mut pool, &mut tables, front) {
                        Admit::Granted(seq, tokens, shared) => {
                            // lint:allow(panic-in-hot-path): front() admitted above, so the queue is non-empty
                            let item = pending.pop_front().unwrap();
                            self.note_prefix_probe(&mut metrics, &item, &tokens, shared);
                            batch.push((item, tokens, seq, shared));
                        }
                        Admit::Backpressure => {
                            metrics.admission_blocked += 1;
                            // Standstill guard: with nothing running and
                            // nothing admitted this round, the only
                            // reclaimable capacity is prefixes kept by
                            // queued preempted requests — without this,
                            // parked kept blocks could backpressure the
                            // queue head forever.
                            if batch.is_empty()
                                && !lanes.iter().any(|l| matches!(l, Lane::Busy(_)))
                            {
                                self.reclaim_queued_kept(
                                    &mut pending, &mut tables, &mut pool, &mut metrics,
                                );
                            }
                            break;
                        }
                        Admit::NeverFits => {
                            // lint:allow(panic-in-hot-path): front() admitted above, so the queue is non-empty
                            let item = pending.pop_front().unwrap();
                            self.fail_item(item, &mut pool, &mut tables, &mut metrics);
                        }
                    }
                }
                if !batch.is_empty() {
                    let mut prompts: Vec<Vec<i32>> =
                        batch.iter().map(|(_, t, _, _)| t.clone()).collect();
                    // Pad to the configured gang width so the persistent
                    // gang lands in the right batch bucket even under
                    // light load.
                    while prompts.len() < self.gang_batch {
                        prompts.push(vec![0]);
                    }
                    // Estimator attribution counts only the *real*
                    // prompt tokens of the admitted batch. Padding lanes
                    // ride along in the padded bucket call, but crediting
                    // their filler tokens diluted the per-token rate:
                    // `prefill_ms(len)` then under-priced every future
                    // prompt, and `Strict` admitted provably-doomed
                    // requests instead of shedding them.
                    let prefill_tokens: usize = batch.iter().map(|(_, t, _, _)| t.len()).sum();
                    let bs = self.cfg.pool.block_size.max(1);
                    let shared_tokens: usize = batch.iter().map(|(_, _, _, s)| s * bs).sum();
                    for (lane, (item, tokens, _, _)) in batch.iter().enumerate() {
                        metrics.record(EventKind::PrefillStart {
                            id: item_queued(item).req.id,
                            lane: lane as u32,
                            tokens: tokens.len() as u32,
                        });
                    }
                    let t0 = WallTimer::start();
                    let (id, logits) = self.backend.prefill(&self.cfg.pca, prompts)?;
                    est.observe_prefill(prefill_tokens, t0.elapsed_s());
                    self.charge_prefill(&mut metrics, prefill_tokens, shared_tokens);
                    metrics.prefills += 1;
                    gang = Some(id);
                    let n = batch.len();
                    for (lane, (item, tokens, seq, shared)) in batch.into_iter().enumerate() {
                        metrics.record(EventKind::PrefillEnd {
                            id: item_queued(&item).req.id,
                            lane: lane as u32,
                            tokens: tokens.len() as u32,
                        });
                        lane_len[lane] = tokens.len();
                        lane_seq[lane] = Some(seq);
                        let tick = assign_tick(&item, &mut admit_tick);
                        lanes[lane] = self.lane_for(
                            item,
                            tokens,
                            shared * bs,
                            &logits[lane],
                            lane,
                            tick,
                            &mut metrics,
                        );
                        lane_tick[lane] = busy_tick(&lanes[lane]);
                    }
                    for lane in n..self.gang_batch {
                        lane_len[lane] = 1; // padding prompt [0]
                    }
                }
            }
            let gang_id = match gang {
                Some(g) => g,
                None => continue,
            };

            // ---- 3. refill free lanes (scheduler policy × pool admission) -
            let budget = match self.cfg.scheduler {
                SchedulerPolicy::PrefillFirst => self.gang_batch,
                SchedulerPolicy::DecodeFirst => 1,
            };
            let mut injected = 0;
            for lane in 0..self.gang_batch {
                if injected >= budget || pending.is_empty() {
                    break;
                }
                if lane_occupied(&lanes[lane]) {
                    continue;
                }
                self.schedule_head(&mut pending);
                // lint:allow(panic-in-hot-path): the loop breaks first when the queue is empty
                let front = pending.front().unwrap();
                match self.try_admit(&mut pool, &mut tables, front) {
                    Admit::Granted(seq, tokens, shared) => {
                        // lint:allow(panic-in-hot-path): front() admitted above, so the queue is non-empty
                        let item = pending.pop_front().unwrap();
                        self.note_prefix_probe(&mut metrics, &item, &tokens, shared);
                        let shared_tokens = shared * self.cfg.pool.block_size.max(1);
                        let id = item_queued(&item).req.id;
                        metrics.record(EventKind::PrefillStart {
                            id,
                            lane: lane as u32,
                            tokens: tokens.len() as u32,
                        });
                        lane_seq[lane] = Some(seq);
                        let tick = assign_tick(&item, &mut admit_tick);
                        if self.cfg.prefill_chunk.is_some() {
                            // Chunked mode: the lane slot (and its pool
                            // reservation) is taken now, but the tokens
                            // land chunk-by-chunk in section 3b; the
                            // gang lane keeps its padding until the
                            // last chunk injects. `lane_len` keeps
                            // tracking that padding for hygiene.
                            lanes[lane] = Lane::Prefilling(Box::new(PrefillLane {
                                item,
                                tokens,
                                done: 0,
                                state: None,
                                shared_tokens,
                                discount_left: shared_tokens,
                                tick,
                                start_step: metrics.decode_steps,
                            }));
                        } else {
                            let t0 = WallTimer::start();
                            let (lane_id, logits) =
                                self.backend.prefill(&self.cfg.pca, vec![tokens.clone()])?;
                            est.observe_prefill(tokens.len(), t0.elapsed_s());
                            self.charge_prefill(&mut metrics, tokens.len(), shared_tokens);
                            metrics.prefills += 1;
                            self.backend.inject(gang_id, lane_id, lane)?;
                            metrics.injections += 1;
                            metrics.record(EventKind::PrefillEnd {
                                id,
                                lane: lane as u32,
                                tokens: tokens.len() as u32,
                            });
                            lane_len[lane] = tokens.len();
                            lanes[lane] = self.lane_for(
                                item,
                                tokens,
                                shared_tokens,
                                &logits[0],
                                lane,
                                tick,
                                &mut metrics,
                            );
                        }
                        lane_tick[lane] = busy_tick(&lanes[lane]);
                        injected += 1;
                    }
                    Admit::Backpressure => {
                        // Head-of-line request waits for blocks to free up;
                        // completions (and preempted-lane releases) are
                        // what unblock it. If *nothing* is running, the
                        // only blocks that can ever free are prefixes
                        // kept by queued preempted requests — reclaim
                        // them rather than spinning forever.
                        metrics.admission_blocked += 1;
                        if !lanes.iter().any(lane_occupied) {
                            self.reclaim_queued_kept(
                                &mut pending, &mut tables, &mut pool, &mut metrics,
                            );
                        }
                        break;
                    }
                    Admit::NeverFits => {
                        // lint:allow(panic-in-hot-path): front() admitted above, so the queue is non-empty
                        let item = pending.pop_front().unwrap();
                        self.fail_item(item, &mut pool, &mut tables, &mut metrics);
                    }
                }
            }

            // ---- 3b. advance chunked prefills -----------------------------
            // One chunk per mid-prefill lane per scheduling round, in
            // lane order: a long prompt spreads its prefill across
            // `ceil(len / chunk)` rounds while the busy lanes keep
            // decoding in between — the head-of-line blocking bound
            // chunked prefill exists for. The final chunk injects the
            // finished batch-1 state and opens the lane via the same
            // `lane_for` path as a monolithic prefill (first-token
            // sampling for fresh work, sampler restore for resumes).
            if let Some(chunk) = self.cfg.prefill_chunk {
                let chunk = chunk.max(1);
                for lane in 0..self.gang_batch {
                    if !matches!(lanes[lane], Lane::Prefilling(_)) {
                        continue;
                    }
                    let Lane::Prefilling(mut p) =
                        std::mem::replace(&mut lanes[lane], Lane::Free)
                    else {
                        // lint:allow(panic-in-hot-path): the enclosing match arm just matched Prefilling
                        unreachable!("matched Prefilling above");
                    };
                    let total = p.tokens.len();
                    let n = chunk.min(total - p.done);
                    let id = item_queued(&p.item).req.id;
                    let (state, logits) = if n == 0 {
                        // Degenerate empty target (empty prompt admitted):
                        // nothing to chunk — one plain prefill opens and
                        // finishes the episode.
                        let t0 = WallTimer::start();
                        let (s, mut l) = self.backend.prefill(&self.cfg.pca, vec![Vec::new()])?;
                        est.observe_prefill(total, t0.elapsed_s());
                        (s, l.swap_remove(0))
                    } else {
                        let prior = p.state.take().unwrap_or(0);
                        let t0 = WallTimer::start();
                        let out = self
                            .backend
                            .prefill_extend(&self.cfg.pca, prior, &p.tokens, p.done, n)?;
                        est.observe_prefill(n, t0.elapsed_s());
                        let disc = p.discount_left.min(n);
                        p.discount_left -= disc;
                        self.charge_prefill(&mut metrics, n, disc);
                        p.done += n;
                        metrics.prefill_chunks += 1;
                        metrics.chunked_prefill_tokens += n as u64;
                        metrics.per_class[item_queued(&p.item).req.priority.index()]
                            .prefill_chunks += 1;
                        metrics.record(EventKind::PrefillChunk {
                            id,
                            lane: lane as u32,
                            done: p.done as u32,
                            total: total as u32,
                        });
                        out
                    };
                    if p.done < total {
                        p.state = Some(state);
                        lanes[lane] = Lane::Prefilling(p);
                        continue;
                    }
                    // Last chunk landed: inject and open the lane.
                    self.backend.inject(gang_id, state, lane)?;
                    metrics.injections += 1;
                    metrics.prefills += 1;
                    let stall = metrics.decode_steps.saturating_sub(p.start_step);
                    metrics.prefill_stall.push(stall as f64);
                    metrics.record(EventKind::PrefillEnd {
                        id,
                        lane: lane as u32,
                        tokens: total as u32,
                    });
                    lane_len[lane] = total;
                    let PrefillLane { item, tokens, shared_tokens, tick, .. } = *p;
                    lanes[lane] = self
                        .lane_for(item, tokens, shared_tokens, &logits, lane, tick, &mut metrics);
                    lane_tick[lane] = busy_tick(&lanes[lane]);
                }
            }

            // ---- 4. padding-lane hygiene ----------------------------------
            // Non-busy lanes still advance with the gang (a mid-prefill
            // lane's gang slot is padding too — its real tokens live in
            // the batch-1 side state until injection). They hold no pool
            // blocks, but the *device* cache behind them is physically
            // bounded, so re-blank one exactly when the next step would
            // hit max_len (the old 0.75·max_len fraction heuristic is
            // gone; this fires once per max_len idle steps at most).
            // The blank prefill is real backend work: it is observed by
            // the estimator, billed to its own counter, charged to the
            // steps clock, and traced — an unattributed prefill would
            // make `prefills`-vs-trace reconciliation come up short.
            for lane in 0..self.gang_batch {
                if matches!(lanes[lane], Lane::Busy(_)) {
                    continue;
                }
                if lane_len[lane] + 1 >= self.max_len {
                    let t0 = WallTimer::start();
                    let (blank, _) = self.backend.prefill(&self.cfg.pca, vec![vec![0]])?;
                    est.observe_prefill(1, t0.elapsed_s());
                    self.charge_prefill(&mut metrics, 1, 0);
                    self.backend.inject(gang_id, blank, lane)?;
                    lane_len[lane] = 1;
                    metrics.lane_resets += 1;
                    metrics.lane_reset_prefills += 1;
                    metrics.record(EventKind::LaneReset { lane: lane as u32 });
                }
            }

            // ---- 5. decode iteration --------------------------------------
            if !lanes.iter().any(|l| matches!(l, Lane::Busy(_))) {
                continue;
            }
            let tokens: Vec<i32> = lanes
                .iter()
                .map(|l| match l {
                    Lane::Busy(b) => b.next_token,
                    // Free and mid-prefill lanes feed padding; a
                    // prefilling lane's real tokens live in its batch-1
                    // side state, not the gang slot.
                    Lane::Free | Lane::Prefilling(_) => 0,
                })
                .collect();
            let t0 = WallTimer::start();
            let logits = self.backend.decode(DecodeRequest {
                state: gang_id,
                variant: self.cfg.variant.clone(),
                tokens,
            })?;
            metrics.decode_steps += 1;
            let step_s = t0.elapsed_s();
            metrics.decode_step_time.push(step_s);
            est.observe_step(step_s);
            for len in lane_len.iter_mut() {
                *len += 1;
            }
            // Mirror the device-side append in the pool tables. Under
            // `ReserveFull` the reservation covers this by construction;
            // under `Speculative` a lane at the edge of its grant grows
            // first — possibly preempting the youngest other lane (whose
            // just-decoded token is then recomputed on resume, before its
            // sampler ever advances, keeping resumption byte-identical).
            // Mid-prefill lanes hold a live seq but did not decode this
            // step (the gang slot advanced padding, their real state is
            // batch-1 on the side), so their mirror neither advances nor
            // grows — the admission reservation already covers their
            // whole target sequence.
            for lane in 0..self.gang_batch {
                if !matches!(lanes[lane], Lane::Busy(_)) {
                    continue;
                }
                let Some(seq) = lane_seq[lane] else { continue };
                if tables.needs_grow(seq) {
                    self.grow_or_preempt(
                        lane,
                        seq,
                        &mut pool,
                        &mut tables,
                        &mut lanes,
                        &mut lane_seq,
                        &lane_tick,
                        &mut pending,
                        &mut metrics,
                    );
                }
                if lane_seq[lane].is_some() {
                    tables.advance(seq);
                }
            }
            metrics.note_pool(pool.blocks_in_use(), tables.written_blocks(), tables.shared_hits);
            metrics.note_radix(tables.radix_nodes(), tables.radix_hit_blocks());
            // Scheduler-round trace event: lane occupancy, queue depth,
            // free pool and the per-step attention score-path bytes —
            // moved (under the configured variant) vs exact-attention.
            let mut busy_now = 0u32;
            let mut score_moved = 0u64;
            let mut score_exact = 0u64;
            for lane in 0..self.gang_batch {
                if !matches!(lanes[lane], Lane::Busy(_)) {
                    continue;
                }
                busy_now += 1;
                score_moved += crate::attnsim::score_path_bytes(
                    lane_len[lane],
                    self.bytes_per_token,
                    score_d_frac,
                    score_j_sel,
                );
                score_exact += lane_len[lane] as u64 * self.bytes_per_token;
            }
            metrics.record(EventKind::SchedRound {
                busy_lanes: busy_now,
                queue_depth: pending.len() as u32,
                free_blocks: pool.num_free() as u32,
                score_bytes_moved: score_moved,
                score_bytes_exact: score_exact,
            });
            // Drain the kvpool's event side-channel into the recorder —
            // the engine stamps the clock, keeping `kvpool` a leaf. Any
            // `PrefixReleased` hash is also forwarded to the eviction-
            // feedback channel so the router mirror stays honest.
            for pe in tables.events.drain() {
                if let (PoolEvent::PrefixReleased { hash }, Some(tx)) = (pe, &self.evict_tx) {
                    let _ = tx.send(hash);
                }
                metrics.record(EventKind::Pool(pe));
            }
            self.publish_stats(&metrics, pending.len(), busy_now as usize, pool.blocks_in_use());

            // ---- 6. per-lane sampling + completion ------------------------
            for lane in 0..self.gang_batch {
                let finished = {
                    let b = match &mut lanes[lane] {
                        Lane::Busy(b) => b,
                        Lane::Free | Lane::Prefilling(_) => continue,
                    };
                    metrics.tokens_generated += 1;
                    // First-token bookkeeping fires exactly once per
                    // request: `ttft_s` survives preempt→resume inside
                    // the requeued lane record, so a request preempted
                    // *after* its first emission is never re-graded when
                    // the resume recomputes that token, and one preempted
                    // *before* it is graded at its one real delivery.
                    if b.ttft_s.is_none() {
                        // Stamp the emission instant once; TTFT, the
                        // deadline grade and the echoed reply all derive
                        // from this same stamp. (Previously the grade
                        // took a second `Instant::now()` after the
                        // bookkeeping above it, so a token produced
                        // before the deadline could still be graded a
                        // miss under scheduler jitter.)
                        let emitted = wall_now();
                        let t = emitted.saturating_duration_since(b.req.submitted).as_secs_f64();
                        // Steps since the request entered the queue — a
                        // deterministic, uptime-independent TTFT.
                        let steps = metrics.decode_steps.saturating_sub(b.req.submitted_step);
                        // Engine-clock milliseconds since enqueue: under
                        // `Steps` this includes the virtual prefill
                        // charge, so chunked-vs-monolithic TTFT is
                        // comparable in one deterministic domain.
                        let ms = (metrics.now_ms() - b.req.submitted_ms).max(0.0);
                        b.ttft_s = Some(t);
                        b.ttft_step = Some(steps);
                        metrics.ttft.push(t);
                        // Per-turn TTFT in the same charged domain:
                        // turn ≥ 1 requests extend a resident history,
                        // so their bucket shows what the radix tree's
                        // prefix reuse buys in first-token latency.
                        metrics.note_turn_ttft(b.req.req.turn, ms);
                        let class = &mut metrics.per_class[b.req.req.priority.index()];
                        class.ttft.push(t);
                        class.ttft_steps.push(steps as f64);
                        class.ttft_ms.push(ms);
                        // Max wait is tracked per *original* class even
                        // when aging promoted the request — the bound it
                        // observes is the batch-starvation bound.
                        class.max_wait_steps = class.max_wait_steps.max(steps);
                        if let Some(deadline) = b.req.deadline {
                            // The clock grades in the same domain the
                            // shed predictor prices (steps twin: decode
                            // steps plus the virtual prompt-
                            // proportional prefill cost) — see
                            // [`EngineClock::deadline_hit`].
                            let hit = self.cfg.clock.deadline_hit(
                                emitted,
                                deadline,
                                steps,
                                b.grade_prompt_tokens,
                                b.req.req.slo_ms.unwrap_or(f64::INFINITY),
                            );
                            b.deadline_hit = Some(hit);
                            if hit {
                                class.deadline_hits += 1;
                            } else {
                                class.deadline_misses += 1;
                            }
                        }
                        let id = b.req.req.id;
                        metrics.record(EventKind::FirstToken { id, ttft_steps: steps });
                    }
                    // The admission-sampled token is only stop-checked
                    // here (it was drawn from prefill logits before any
                    // decode ran); stop tokens never enter the output.
                    if Some(b.next_token) == b.req.req.stop_token {
                        Some(FinishReason::StopToken)
                    } else {
                        let tok = b.sampler.sample(&logits[lane]) as i32;
                        b.produced.push(b.next_token);
                        b.next_token = tok;
                        if Some(tok) == b.req.req.stop_token {
                            Some(FinishReason::StopToken)
                        } else if b.produced.len() >= b.req.req.max_new_tokens {
                            Some(FinishReason::MaxTokens)
                        } else if lane_len[lane] + 1 >= self.max_len {
                            Some(FinishReason::CacheFull)
                        } else {
                            None
                        }
                    }
                };
                if let Some(reason) = finished {
                    if let Some(seq) = lane_seq[lane].take() {
                        tables.free(&mut pool, seq);
                    }
                    let lane_state = std::mem::replace(&mut lanes[lane], Lane::Free);
                    if let Lane::Busy(b) = lane_state {
                        self.complete(*b, reason, &mut metrics);
                    }
                }
            }
        }
        if let Some(g) = gang {
            self.backend.free(g);
        }
        metrics.note_pool(pool.blocks_in_use(), tables.written_blocks(), tables.shared_hits);
        metrics.note_radix(tables.radix_nodes(), tables.radix_hit_blocks());
        // Final drain: pool events emitted after the last decode round
        // (terminal frees, drain-path truncations) must still land.
        for pe in tables.events.drain() {
            if let (PoolEvent::PrefixReleased { hash }, Some(tx)) = (pe, &self.evict_tx) {
                let _ = tx.send(hash);
            }
            metrics.record(EventKind::Pool(pe));
        }
        self.publish_stats(&metrics, pending.len(), 0, pool.blocks_in_use());
        Ok(metrics)
    }

    /// Prefill length + remaining decode budget for a queue item —
    /// computed without materializing any token vector, so the scheduler
    /// can evaluate (and re-evaluate, under backpressure) the head of
    /// the queue every iteration for free.
    fn plan_dims(&self, item: &PendingItem) -> (usize, usize) {
        match item {
            PendingItem::Fresh(q) => {
                (q.req.prompt.len().min(self.prompt_budget(&q.req)), q.req.max_new_tokens)
            }
            PendingItem::Resume { lane: b, .. } => (
                (b.prompt.len() + b.produced.len()).min(self.max_prompt),
                b.req.req.max_new_tokens.saturating_sub(b.produced.len()),
            ),
        }
    }

    /// Materialize the prefill tokens for an item being admitted. Fresh
    /// requests prefill their (clamped) prompt; resumed requests prefill
    /// `prompt ++ produced` — the prefix recompute that restores their
    /// KV state exactly. Must agree with [`Engine::plan_dims`] on length.
    fn plan_tokens(&self, item: &PendingItem) -> Vec<i32> {
        match item {
            PendingItem::Fresh(q) => self.clamped_prompt(&q.req),
            PendingItem::Resume { lane: b, .. } => {
                let mut toks = b.prompt.clone();
                toks.extend_from_slice(&b.produced);
                // Defensive clamp for real prefill buckets — unreachable
                // in practice because victim selection refuses to preempt
                // a lane whose recompute would not fit `max_prompt`
                // (truncation would break byte-identity).
                if toks.len() > self.max_prompt {
                    let cut = toks.len() - self.max_prompt;
                    toks.drain(..cut);
                }
                toks
            }
        }
    }

    /// Tally an admission's full prompt blocks into the prefix-hit-rate
    /// denominator. Kept-prefix resumes never probe the index (their
    /// table is still live), so they are excluded; everything else —
    /// fresh work and full-preemption recomputes — walks the shared
    /// index at admit and counts. `shared` is the radix-tree hits this
    /// admission resolved: follow-up turns (turn ≥ 1) also feed the
    /// per-turn conversational hit rate the multi-turn scenarios grade.
    fn note_prefix_probe(
        &self,
        metrics: &mut EngineMetrics,
        item: &PendingItem,
        tokens: &[i32],
        shared: usize,
    ) {
        if matches!(item, PendingItem::Resume { kept: Some(_), .. }) {
            return;
        }
        let full_blocks = (tokens.len() / self.cfg.pool.block_size.max(1)) as u64;
        metrics.prefix_ref_blocks += full_blocks;
        if item_queued(item).req.turn >= 1 {
            metrics.turn_ref_blocks += full_blocks;
            metrics.turn_shared_blocks += (shared as u64).min(full_blocks);
        }
    }

    /// Pool admission: grant the policy's reservation or don't touch the
    /// pool at all.
    fn try_admit(
        &self,
        pool: &mut BlockAllocator,
        tables: &mut TableSet,
        item: &PendingItem,
    ) -> Admit {
        let (len, remaining) = self.plan_dims(item);
        // Shared prefix blocks still occupy pool capacity (they are live
        // allocations, merely refcounted), so a request whose *worst
        // case* exceeds the whole pool can never be satisfied by waiting
        // — or by preempting. The filter is identical for both policies,
        // so `Speculative` never admits work `ReserveFull` would reject
        // outright (this is what keeps their completed outputs aligned).
        let full_need = reserve_tokens(AdmissionPolicy::ReserveFull, len, remaining, self.max_len);
        if pool.blocks_for(full_need) > pool.num_blocks() {
            return Admit::NeverFits;
        }
        let reserve = reserve_tokens(self.cfg.admission, len, remaining, self.max_len);
        let total_blocks = pool.blocks_for(reserve.max(len).max(1));
        // A partially-preempted resume still owns its kept prefix blocks:
        // re-extend that table to the reservation instead of admitting a
        // fresh sequence (the kept blocks never left the pool, so only
        // the difference must be free).
        if let PendingItem::Resume { kept: Some(k), .. } = item {
            let kept_blocks = tables.table(k.seq).map_or(0, |t| t.blocks.len());
            if pool.num_free() < total_blocks.saturating_sub(kept_blocks) {
                return Admit::Backpressure;
            }
            let tokens = self.plan_tokens(item);
            return match tables.resume_extend(pool, k.seq, tokens.len(), total_blocks) {
                Ok(()) => Admit::Granted(k.seq, tokens, 0),
                Err(_) => Admit::Backpressure,
            };
        }
        // Cheap lower bound before cloning tokens: even a fully-shared
        // prompt leaves `total - full_prompt_blocks` fresh allocations
        // (tails are always private), so fewer free blocks than that is
        // a guaranteed Err — the common backpressure iteration costs no
        // allocation at all.
        let shareable = if tables.sharing_enabled() { len / tables.block_size() } else { 0 };
        if pool.num_free() < total_blocks.saturating_sub(shareable) {
            return Admit::Backpressure;
        }
        let tokens = self.plan_tokens(item);
        // The admit walk bumps `shared_hits` once per block it serves
        // from the prefix index; the delta is exactly this admission's
        // share count (resumes take the branch above, so only fresh
        // work — Resume{kept: None} recomputes included — lands here,
        // and recomputes legitimately re-share their own prefix).
        let hits_before = tables.shared_hits;
        match tables.admit(pool, &tokens, reserve) {
            Ok(seq) => {
                let shared = (tables.shared_hits - hits_before) as usize;
                Admit::Granted(seq, tokens, shared)
            }
            Err(_) => Admit::Backpressure,
        }
    }

    /// Prompt-token budget for a fresh request (prefill bucket bound and
    /// room for the decode budget within `max_len`).
    fn prompt_budget(&self, req: &GenRequest) -> usize {
        self.max_prompt
            .min(self.max_len.saturating_sub(req.max_new_tokens + RESERVE_SLACK_TOKENS))
            .max(1)
    }

    /// Grow `seq`'s block table so its next advance fits, preempting the
    /// youngest other lane when the pool has nothing free. Growth is
    /// capped at the lane's full-reservation block count, so speculative
    /// lanes never hold more than `ReserveFull` would have granted them —
    /// which also guarantees a lane running *alone* always grows (its
    /// worst case passed the admission NeverFits filter).
    #[allow(clippy::too_many_arguments)]
    fn grow_or_preempt(
        &self,
        lane: usize,
        seq: SeqId,
        pool: &mut BlockAllocator,
        tables: &mut TableSet,
        lanes: &mut [Lane],
        lane_seq: &mut [Option<SeqId>],
        lane_tick: &[u64],
        pending: &mut VecDeque<PendingItem>,
        metrics: &mut EngineMetrics,
    ) {
        let (cap_blocks, headroom) = {
            let Lane::Busy(b) = &lanes[lane] else { return };
            // Same formula as the admission NeverFits filter — the two
            // must agree exactly or a lane could grow past what the
            // filter certified as fitting the pool.
            let full = reserve_tokens(
                AdmissionPolicy::ReserveFull,
                b.prompt.len(),
                b.req.req.max_new_tokens,
                self.max_len,
            );
            let headroom = match self.cfg.admission {
                AdmissionPolicy::Speculative { headroom_blocks, .. } => headroom_blocks.max(1),
                // Unreachable in practice — full reservations cover the
                // decode budget — but single-block growth keeps the
                // fallback local instead of panicking in `advance`.
                AdmissionPolicy::ReserveFull => 1,
            };
            (pool.blocks_for(full), headroom)
        };
        loop {
            let have = tables.table(seq).map_or(0, |t| t.blocks.len());
            let want = headroom.min(cap_blocks.saturating_sub(have)).max(1);
            match tables.grow(pool, seq, want) {
                Ok(n) => {
                    metrics.grow_events += 1;
                    metrics.grown_blocks += n as u64;
                    return;
                }
                Err(_) => {
                    metrics.grow_stalls += 1;
                    let victim =
                        self.select_victim(lane, want, lanes, lane_seq, lane_tick, tables, pool);
                    match victim {
                        Some(v) => {
                            self.preempt(
                                v, want, lanes, lane_seq, tables, pool, pending, metrics,
                            );
                            if self.cfg.verbose {
                                eprintln!(
                                    "[engine] preempted lane {v} to grow lane {lane} \
                                     ({} free blocks after release)",
                                    pool.num_free()
                                );
                            }
                        }
                        None => {
                            // Before yielding or giving up, reclaim
                            // prefixes kept in the pool by queued
                            // partially-preempted requests — the only
                            // cost is their recompute on resume.
                            if self.reclaim_queued_kept(pending, tables, pool, metrics) {
                                continue;
                            }
                            // Mid-prefill lanes count as occupied: they
                            // will inject, decode and free capacity, so
                            // yielding beats finishing early.
                            let others_busy = (0..lanes.len())
                                .any(|l| l != lane && lane_occupied(&lanes[l]));
                            if others_busy && self.resumable(&lanes[lane]) {
                                // Nothing preemptible frees blocks: yield
                                // our own lane and wait at the queue
                                // front for completions to free capacity.
                                self.preempt(
                                    lane, want, lanes, lane_seq, tables, pool, pending,
                                    metrics,
                                );
                            } else {
                                // Alone and still starved (footprint
                                // exceeds the pool — admission's
                                // NeverFits filter makes that
                                // unreachable) or past the faithful-
                                // resume bound (only possible when
                                // max_prompt < max_len): finish
                                // explicitly instead of spinning or
                                // silently diverging. The token fed to
                                // this iteration's decode is real output
                                // (it was stop-checked when sampled), so
                                // deliver it exactly as the step-6
                                // cache-bound path would have.
                                if let Some(s) = lane_seq[lane].take() {
                                    tables.free(pool, s);
                                }
                                if let Lane::Busy(mut b) =
                                    std::mem::replace(&mut lanes[lane], Lane::Free)
                                {
                                    b.produced.push(b.next_token);
                                    let reason =
                                        if b.produced.len() >= b.req.req.max_new_tokens {
                                            FinishReason::MaxTokens
                                        } else {
                                            FinishReason::CacheFull
                                        };
                                    self.complete(*b, reason, metrics);
                                }
                            }
                            return;
                        }
                    }
                }
            }
        }
    }

    /// A lane is a legal preemption victim only if its resume recompute
    /// (`prompt ++ produced`) fits the prefill bound — otherwise
    /// `plan_tokens` would have to truncate history and the resumed
    /// output would silently diverge from the uncontended run.
    fn resumable(&self, lane: &Lane) -> bool {
        match lane {
            Lane::Busy(b) => b.prompt.len() + b.produced.len() <= self.max_prompt,
            // A mid-prefill lane's recompute is exactly its target
            // sequence, which already passed the prompt budget at
            // admission — always faithfully restartable.
            Lane::Prefilling(_) => true,
            Lane::Free => false,
        }
    }

    /// Fail the queue head when it can never be admitted: fresh requests
    /// are rejected outright; resumed requests deliver the tokens they
    /// already produced (their footprint grew past the pool mid-flight),
    /// returning any kept prefix blocks to the pool.
    fn fail_item(
        &self,
        item: PendingItem,
        pool: &mut BlockAllocator,
        tables: &mut TableSet,
        metrics: &mut EngineMetrics,
    ) {
        match item {
            PendingItem::Fresh(q) => self.reject(q, metrics),
            PendingItem::Resume { lane, kept } => {
                if let Some(k) = kept {
                    tables.free(pool, k.seq);
                }
                self.complete(*lane, FinishReason::CacheFull, metrics);
            }
        }
    }

    /// Fail a request that can never be admitted under the configured
    /// pool (clearer than queueing it forever behind backpressure).
    fn reject(&self, q: QueuedRequest, metrics: &mut EngineMetrics) {
        metrics.requests_rejected += 1;
        metrics.record(EventKind::RequestRejected { id: q.req.id });
        let total = q.submitted.elapsed().as_secs_f64();
        let result = GenResult {
            id: q.req.id,
            tokens: Vec::new(),
            text: String::new(),
            finished_reason: FinishReason::CacheFull,
            shed: None,
            timing: RequestTiming { total_s: total, ..Default::default() },
        };
        if self.cfg.verbose {
            eprintln!("[engine] rejected #{} (exceeds pool capacity)", result.id);
        }
        let _ = q.req.reply.send(result);
    }

    /// Predictive admission with early load shedding, run once per
    /// scheduling round. The pending queue is replayed against the
    /// lanes ahead of it in scheduled order: each busy lane frees in
    /// `max_new − produced` decode steps (its occupancy upper bound —
    /// exact when decode lengths are deterministic, conservative under
    /// stop-token early exits), each queued entry then takes the
    /// earliest-free lane and holds it for its remaining decode budget,
    /// and the entry's first token lands one decode step after its
    /// slot opens. The estimator converts that step count (plus the
    /// prompt-length-proportional prefill cost and the time already
    /// waited) into milliseconds; a **fresh SLO'd** request whose
    /// prediction exceeds its deadline by the policy margin is removed
    /// and answered with a structured shed reply — resumes (sunk decode
    /// work) and deadline-less requests are never shed, but they do
    /// occupy lanes in the replay. With no evidence yet (cold wall
    /// estimator) nothing is shed: rejecting work on a guess would be
    /// an SLO bug, not load shedding.
    ///
    /// The model deliberately ignores pool contention: preemption churn
    /// only delays first tokens further, so ignoring it keeps the
    /// prediction optimistic — a shed stays provable, never premature
    /// (`Hedged` exists for the regimes where the *occupancy* bound is
    /// the loose side).
    fn shed_doomed(
        &self,
        pending: &mut VecDeque<PendingItem>,
        lanes: &[Lane],
        est: &ServiceRateEstimator,
        metrics: &mut EngineMetrics,
    ) {
        let Some(margin) = self.cfg.shed.margin_frac() else { return };
        if pending.is_empty() {
            return;
        }
        // Nothing sheddable queued (the common case for deadline-less
        // or resume-only traffic): skip the whole replay — allocations,
        // the deadline sort and the wall-clock read included.
        let any_sheddable = pending
            .iter()
            .any(|it| matches!(it, PendingItem::Fresh(q) if q.deadline.is_some()));
        if !any_sheddable {
            return;
        }
        let Some(step_ms) = est.step_ms() else { return };
        // Decode steps until each lane can take an injection.
        let mut free_in: Vec<u64> = lanes
            .iter()
            .map(|l| match l {
                Lane::Busy(b) => {
                    b.req.req.max_new_tokens.saturating_sub(b.produced.len()) as u64
                }
                // A mid-prefill lane frees after its remaining chunk
                // rounds (one per scheduling round, so decode steps are
                // the right unit) plus its decode budget.
                Lane::Prefilling(p) => {
                    let chunk = self.cfg.prefill_chunk.unwrap_or(usize::MAX).max(1);
                    let rounds = (p.tokens.len() - p.done).div_ceil(chunk) as u64;
                    let remaining = match &p.item {
                        PendingItem::Fresh(q) => q.req.max_new_tokens,
                        PendingItem::Resume { lane: b, .. } => {
                            b.req.req.max_new_tokens.saturating_sub(b.produced.len())
                        }
                    };
                    rounds + remaining as u64
                }
                Lane::Free => 0,
            })
            .collect();
        // Predict in the order the queue will actually be served: the
        // deadline policy re-orders dynamically, the others serve the
        // static band order as-is.
        let mut order: Vec<usize> = (0..pending.len()).collect();
        if self.cfg.victim_policy == VictimPolicy::DeadlineAware {
            order.sort_by_key(|&i| effective_deadline_key(&pending[i]));
        }
        let now = wall_now();
        let now_step = metrics.decode_steps;
        let mut doomed: Vec<(usize, f64)> = Vec::new();
        for &i in &order {
            let item = &pending[i];
            let (len, remaining) = self.plan_dims(item);
            let slot = free_in
                .iter()
                .enumerate()
                .min_by_key(|(_, f)| **f)
                .map(|(l, _)| l)
                .unwrap_or(0);
            let wait = free_in[slot];
            let q = item_queued(item);
            let sheddable = matches!(item, PendingItem::Fresh(_)) && q.deadline.is_some();
            let mut shed = false;
            if sheddable {
                if let Some(slo_ms) = q.req.slo_ms {
                    // Milliseconds already burned in the queue, in the
                    // configured clock's domain — the same conversion
                    // the grader applies at emission.
                    let waited_ms =
                        self.cfg.clock.waited_ms(now, q.submitted, now_step, q.submitted_step);
                    // Chunked prefill pays an extra decode round per
                    // chunk after the first — `prefill_cost_ms` folds
                    // that in; `None` is exactly `prefill_ms`.
                    let predicted_ttft_ms = waited_ms
                        + est.prefill_cost_ms(len, self.cfg.prefill_chunk)
                        + (wait + 1) as f64 * step_ms;
                    if predicted_ttft_ms > slo_ms * (1.0 + margin) {
                        doomed.push((i, predicted_ttft_ms));
                        shed = true;
                    }
                }
            }
            if !shed {
                // The entry will occupy its lane for its remaining
                // decode budget; shed entries consume nothing, which is
                // exactly what makes room for the work behind them.
                free_in[slot] = wait + remaining.max(1) as u64;
            }
        }
        // Remove back-to-front so earlier queue indices stay valid.
        doomed.sort_by_key(|&(i, _)| Reverse(i));
        for (i, predicted_ttft_ms) in doomed {
            let Some(item) = pending.remove(i) else { continue };
            let PendingItem::Fresh(q) = item else {
                // lint:allow(panic-in-hot-path): only Fresh entries enter `doomed` two lines up
                unreachable!("only fresh SLO'd entries are marked doomed")
            };
            self.shed(q, predicted_ttft_ms, metrics);
        }
    }

    /// Answer a shed request: a structured reply carrying the doomed
    /// prediction and a retry hint, no tokens, no prefill ever spent.
    fn shed(&self, q: QueuedRequest, predicted_ttft_ms: f64, metrics: &mut EngineMetrics) {
        metrics.requests_shed += 1;
        metrics.per_class[q.req.priority.index()].requests_shed += 1;
        metrics.record(EventKind::RequestShed {
            id: q.req.id,
            class: q.req.priority.index() as u8,
            predicted_ttft_ms,
        });
        let slo_ms = q.req.slo_ms.unwrap_or(0.0);
        let retry_after_ms = (predicted_ttft_ms - slo_ms).max(0.0);
        let total = q.submitted.elapsed().as_secs_f64();
        let result = GenResult {
            id: q.req.id,
            tokens: Vec::new(),
            text: String::new(),
            finished_reason: FinishReason::Shed,
            shed: Some(ShedInfo { predicted_ttft_ms, retry_after_ms }),
            timing: RequestTiming { total_s: total, ..Default::default() },
        };
        if self.cfg.verbose {
            eprintln!(
                "[engine] shed #{} (predicted ttft {predicted_ttft_ms:.1} ms vs slo \
                 {slo_ms:.1} ms; retry after {retry_after_ms:.1} ms)",
                result.id
            );
        }
        let _ = q.req.reply.send(result);
    }

    fn clamped_prompt(&self, req: &GenRequest) -> Vec<i32> {
        let budget = self.prompt_budget(req);
        if req.prompt.len() <= budget {
            req.prompt.clone()
        } else {
            // Keep the *tail* of over-long prompts (recency matters more
            // for generation than the head).
            req.prompt[req.prompt.len() - budget..].to_vec()
        }
    }

    /// Build the busy-lane record for an admitted queue item. Fresh
    /// requests sample their first token from the prefill logits; resumed
    /// requests already hold their next token and sampler state — the
    /// prefill only reconstructed their KV prefix, so its logits are
    /// deliberately unused (consuming them would double-advance the
    /// sampler and break byte-identity). `tick` comes from
    /// [`assign_tick`] — drawn at admission, which is this call for the
    /// monolithic path but an earlier round for a chunked prefill.
    fn lane_for(
        &self,
        item: PendingItem,
        tokens: Vec<i32>,
        shared_tokens: usize,
        logits: &[f32],
        lane_idx: usize,
        tick: u64,
        metrics: &mut EngineMetrics,
    ) -> Lane {
        match item {
            PendingItem::Fresh(q) => {
                self.admit_lane(q, tokens, shared_tokens, logits, tick, metrics)
            }
            // Resumes keep their original admission tick: age is measured
            // from first admission, so a victim does not become the
            // youngest (i.e. next) victim merely by having been evicted.
            PendingItem::Resume { lane: b, kept } => {
                metrics.resumes += 1;
                // A kept prefix never left the pool, so only the
                // truncated suffix counts as recompute (the tally a
                // block-table-aware cache would pay).
                let kept_len = kept.map_or(0, |k| k.len.min(tokens.len()));
                metrics.recomputed_tokens += (tokens.len() - kept_len) as u64;
                metrics.recompute_saved_tokens += kept_len as u64;
                metrics.record(EventKind::Resume {
                    id: b.req.req.id,
                    lane: lane_idx as u32,
                    recomputed_tokens: (tokens.len() - kept_len) as u32,
                    kept_tokens: kept_len as u32,
                });
                if self.cfg.verbose {
                    eprintln!(
                        "[engine] resumed #{} at {} produced tokens ({} kept)",
                        b.req.req.id,
                        b.produced.len(),
                        kept_len
                    );
                }
                Lane::Busy(b)
            }
        }
    }

    /// Sample the first generated token from prefill logits and build the
    /// busy-lane record.
    fn admit_lane(
        &self,
        q: QueuedRequest,
        prompt: Vec<i32>,
        shared_tokens: usize,
        logits: &[f32],
        tick: u64,
        metrics: &mut EngineMetrics,
    ) -> Lane {
        metrics
            .queue_wait
            .push(q.submitted.elapsed().as_secs_f64());
        let grade_prompt_tokens = if self.cfg.prefix_prefill_discount {
            prompt.len().saturating_sub(shared_tokens)
        } else {
            prompt.len()
        };
        let mut sampler = Sampler::new(q.req.sampling);
        let first = sampler.sample(logits) as i32;
        Lane::Busy(Box::new(BusyLane {
            req: q,
            prompt,
            sampler,
            produced: Vec::new(),
            next_token: first,
            ttft_s: None,
            ttft_step: None,
            deadline_hit: None,
            grade_prompt_tokens,
            preempted: 0,
            tick,
        }))
    }

    fn complete(&self, b: BusyLane, reason: FinishReason, metrics: &mut EngineMetrics) {
        metrics.record(EventKind::Finish {
            id: b.req.req.id,
            reason: finish_code(reason),
            tokens: b.produced.len() as u32,
        });
        metrics.requests_done += 1;
        let total = b.req.submitted.elapsed().as_secs_f64();
        metrics.e2e_latency.push(total);
        let class = &mut metrics.per_class[b.req.req.priority.index()];
        class.done += 1;
        class.e2e.push(total);
        // Goodput accounting: tokens of a deadline-missing request are
        // work the SLO never got value from; a hit — or no deadline at
        // all — makes every delivered token goodput.
        match b.deadline_hit {
            Some(false) => class.deadline_missed_tokens += b.produced.len() as u64,
            _ => class.deadline_hit_tokens += b.produced.len() as u64,
        }
        let timing = RequestTiming {
            queue_s: 0.0,
            ttft_s: b.ttft_s.unwrap_or(total),
            ttft_steps: b.ttft_step.unwrap_or(0),
            total_s: total,
            decode_steps: b.produced.len(),
            preemptions: b.preempted as usize,
            deadline_hit: b.deadline_hit,
        };
        let text = self.tokenizer.decode(&b.produced);
        let result = GenResult {
            id: b.req.req.id,
            tokens: b.produced,
            text,
            finished_reason: reason,
            shed: None,
            timing,
        };
        if self.cfg.verbose {
            eprintln!(
                "[engine] done #{} ({} tok, {:?}, {:.3}s, {} preemptions)",
                result.id,
                result.tokens.len(),
                reason,
                result.timing.total_s,
                result.timing.preemptions
            );
        }
        let _ = b.req.req.reply.send(result);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pool_config_auto_sizing_is_worst_case() {
        // Engine construction needs compiled artifacts (see
        // rust/tests/coordinator_integration.rs for end-to-end tests);
        // check the sizing rule the engine applies in run().
        let cfg = EngineConfig::default();
        assert_eq!(cfg.pool.num_blocks, 0, "default pool auto-sizes");
        let (max_len, gang, bs) = (256usize, 8usize, cfg.pool.block_size);
        let auto = gang * max_len.div_ceil(bs);
        // Worst case: every lane full — admission can then never reject a
        // request the flat cache would have accepted.
        assert_eq!(auto, 8 * 16);
    }

    #[test]
    fn default_admission_is_reserve_full() {
        assert_eq!(EngineConfig::default().admission, AdmissionPolicy::ReserveFull);
    }

    #[test]
    fn default_preemption_policy_is_pr2_behavior() {
        // Youngest-first whole-sequence preemption is the pinned default:
        // every PR 2 admission test runs unchanged under it.
        let cfg = EngineConfig::default();
        assert_eq!(cfg.victim_policy, VictimPolicy::YoungestFirst);
        assert_eq!(cfg.preempt, PreemptMode::Full);
        assert_eq!(cfg.aging_steps, None, "no aging unless asked — PR 3 pinned");
        assert_eq!(cfg.shed, ShedPolicy::Off, "no shedding unless asked — PR 4 pinned");
        assert_eq!(cfg.clock, EngineClock::Wall, "wall grading unless a test asks");
        assert_eq!(VictimPolicy::default(), VictimPolicy::YoungestFirst);
        assert_eq!(PreemptMode::default(), PreemptMode::Full);
    }

    #[test]
    fn effective_deadline_keys_band_and_order() {
        use super::super::sampler::SampleCfg;
        use std::sync::mpsc::channel;

        let mk = |priority, slo_ms: Option<f64>, step: u64| {
            let (reply, _rx) = channel();
            let q = QueuedRequest::stamp(
                GenRequest {
                    id: 0,
                    prompt: vec![1],
                    max_new_tokens: 1,
                    stop_token: None,
                    sampling: SampleCfg::greedy(),
                    priority,
                    turn: 0,
                    slo_ms,
                    reply,
                },
                step,
                0.0,
            );
            PendingItem::Fresh(q)
        };
        // Interactive before batch, regardless of deadlines.
        let int_none = mk(Priority::Interactive, None, 5);
        let bat_slo = mk(Priority::Batch, Some(1.0), 0);
        assert!(effective_deadline_key(&int_none) < effective_deadline_key(&bat_slo));
        // Within a band, an SLO'd entry precedes a deadline-less one...
        let int_slo = mk(Priority::Interactive, Some(60_000.0), 9);
        assert!(effective_deadline_key(&int_slo) < effective_deadline_key(&int_none));
        // ...and earlier deadlines precede later ones.
        let int_tight = mk(Priority::Interactive, Some(10.0), 9);
        assert!(effective_deadline_key(&int_tight) < effective_deadline_key(&int_slo));
        // An aged batch request is overdue: effectively interactive with
        // an arrival-time deadline, outranking every unaged entry above.
        let mut bat_aged = mk(Priority::Batch, None, 0);
        item_queued_mut(&mut bat_aged).aged = true;
        for other in [&int_none, &int_slo, &int_tight, &bat_slo] {
            assert!(effective_deadline_key(&bat_aged) < effective_deadline_key(other));
        }
        // Invalid SLOs never stamp a deadline.
        for bad in [Some(0.0), Some(-5.0), Some(f64::NAN), Some(f64::INFINITY)] {
            assert!(item_queued(&mk(Priority::Interactive, bad, 0)).deadline.is_none());
        }
    }

    #[test]
    fn priority_orders_interactive_before_batch() {
        use super::Priority;
        // The victim scorer relies on this Ord: "greater" means "evict
        // first", and the class-banded queue puts smaller classes ahead.
        assert!(Priority::Interactive < Priority::Batch);
        assert_eq!(Priority::default(), Priority::Interactive);
        assert_eq!(Priority::parse("batch"), Some(Priority::Batch));
        assert_eq!(Priority::parse("Interactive"), Some(Priority::Interactive));
        assert_eq!(Priority::parse("urgent"), None);
    }

    #[test]
    fn speculative_reserve_interpolates_between_prompt_and_full() {
        let (p, m, cap) = (40usize, 100usize, 4096usize);
        let full = reserve_tokens(AdmissionPolicy::ReserveFull, p, m, cap);
        let none = reserve_tokens(
            AdmissionPolicy::Speculative { reserve_frac: 0.0, headroom_blocks: 1 },
            p,
            m,
            cap,
        );
        let all = reserve_tokens(
            AdmissionPolicy::Speculative { reserve_frac: 1.0, headroom_blocks: 1 },
            p,
            m,
            cap,
        );
        assert_eq!(none, p + RESERVE_SLACK_TOKENS);
        assert_eq!(all, full);
        let half = reserve_tokens(
            AdmissionPolicy::Speculative { reserve_frac: 0.5, headroom_blocks: 1 },
            p,
            m,
            cap,
        );
        assert!(none < half && half < full);
        // Out-of-range fractions clamp instead of over/under-reserving.
        let wild = reserve_tokens(
            AdmissionPolicy::Speculative { reserve_frac: 7.5, headroom_blocks: 1 },
            p,
            m,
            cap,
        );
        assert_eq!(wild, full);
    }
}
