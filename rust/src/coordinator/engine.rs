//! The continuous-batching generation engine.
//!
//! One persistent decode **gang** (a compiled batch bucket of lanes)
//! advances every iteration; finished lanes are refilled by prefilling the
//! next queued request as a batch-1 state and *injecting* it into the gang
//! between iterations (iteration-level scheduling, Orca-style). The
//! attention variant — Full / Loki(k_f, d_f) / H2O / PCAAttn — is a gang
//!-level serving config: Loki drops in as a scheduler choice, not a model
//! fork, which is exactly the deployment story the paper argues for.
//!
//! Backpressure: submissions go through a bounded `SyncSender`; when the
//! queue is full, callers block (admission control at the front door).

use std::collections::VecDeque;
use std::sync::mpsc::{sync_channel, Receiver, SyncSender, TryRecvError};
use std::time::Instant;

use anyhow::Result;

use crate::runtime::{DecodeRequest, DecodeVariant, RuntimeHandle, RuntimeService, StateId};
use crate::model::ByteTokenizer;

use super::metrics::EngineMetrics;
use super::request::{FinishReason, GenRequest, GenResult, QueuedRequest, RequestTiming};
use super::sampler::Sampler;

/// Prefill-vs-decode priority (the classic serving trade-off: filling
/// lanes fast boosts throughput; decoding first protects inter-token
/// latency of running requests).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SchedulerPolicy {
    /// Fill every free lane before the next decode iteration.
    PrefillFirst,
    /// At most one injection per decode iteration.
    DecodeFirst,
}

#[derive(Clone, Debug)]
pub struct EngineConfig {
    pub pca: String,
    pub variant: DecodeVariant,
    /// Desired gang width; clamped to the largest compiled bucket.
    pub gang_batch: usize,
    pub scheduler: SchedulerPolicy,
    /// Bound of the submission queue (backpressure).
    pub max_queue: usize,
    /// Reset a free lane's cache once it exceeds this fraction of max_len
    /// (free lanes still advance; without hygiene they would exhaust the
    /// static cache and stall the gang).
    pub lane_reset_frac: f64,
    pub verbose: bool,
}

impl Default for EngineConfig {
    fn default() -> Self {
        Self {
            pca: "wiki_pre".to_string(),
            variant: DecodeVariant::Full,
            gang_batch: usize::MAX,
            scheduler: SchedulerPolicy::PrefillFirst,
            max_queue: 256,
            lane_reset_frac: 0.75,
            verbose: false,
        }
    }
}

enum Lane {
    Free,
    Busy(Box<BusyLane>),
}

struct BusyLane {
    req: QueuedRequest,
    sampler: Sampler,
    produced: Vec<i32>,
    next_token: i32,
    ttft_s: Option<f64>,
}

/// The engine: owns the runtime service and the scheduling loop.
pub struct Engine {
    handle: RuntimeHandle,
    cfg: EngineConfig,
    max_len: usize,
    max_prompt: usize,
    gang_batch: usize,
    tokenizer: ByteTokenizer,
}

impl Engine {
    /// Bounded submission channel for this engine config.
    pub fn channel(cfg: &EngineConfig) -> (SyncSender<GenRequest>, Receiver<GenRequest>) {
        sync_channel(cfg.max_queue)
    }

    pub fn new(service: &RuntimeService, cfg: EngineConfig) -> Self {
        let man = &service.manifest;
        let largest = man.batch_buckets.iter().copied().max().unwrap_or(1);
        let gang_batch = man.pick_batch_bucket(cfg.gang_batch.min(largest));
        let max_prompt = man.prefill_buckets.iter().copied().max().unwrap_or(0);
        Self {
            handle: service.handle(),
            max_len: man.model.max_len,
            max_prompt,
            gang_batch,
            cfg,
            tokenizer: ByteTokenizer,
        }
    }

    /// Run until the submission channel closes and all work drains.
    /// Returns the fleet metrics.
    pub fn run(&self, rx: Receiver<GenRequest>) -> Result<EngineMetrics> {
        let mut metrics = EngineMetrics::default();
        let mut pending: VecDeque<QueuedRequest> = VecDeque::new();
        let mut lanes: Vec<Lane> = (0..self.gang_batch).map(|_| Lane::Free).collect();
        let mut lane_len: Vec<usize> = vec![0; self.gang_batch];
        let mut gang: Option<StateId> = None;
        let mut rx_open = true;

        loop {
            // ---- 1. admit -------------------------------------------------
            loop {
                match rx.try_recv() {
                    Ok(req) => {
                        metrics.requests_in += 1;
                        pending.push_back(QueuedRequest { req, submitted: Instant::now() });
                    }
                    Err(TryRecvError::Empty) => break,
                    Err(TryRecvError::Disconnected) => {
                        rx_open = false;
                        break;
                    }
                }
            }
            let any_busy = lanes.iter().any(|l| matches!(l, Lane::Busy(_)));
            if !rx_open && pending.is_empty() && !any_busy {
                break;
            }
            if pending.is_empty() && !any_busy {
                // Idle: block for the next submission.
                match rx.recv() {
                    Ok(req) => {
                        metrics.requests_in += 1;
                        pending.push_back(QueuedRequest { req, submitted: Instant::now() });
                    }
                    Err(_) => break,
                }
            }

            // ---- 2. bootstrap the gang with a batched prefill -------------
            if gang.is_none() && !pending.is_empty() {
                let n = pending.len().min(self.gang_batch);
                let mut batch: Vec<QueuedRequest> = pending.drain(..n).collect();
                let mut prompts: Vec<Vec<i32>> =
                    batch.iter().map(|q| self.clamped_prompt(&q.req)).collect();
                // Pad to the configured gang width so the persistent gang
                // lands in the right batch bucket even under light load.
                while prompts.len() < self.gang_batch {
                    prompts.push(vec![0]);
                }
                let (id, logits) = self.handle.prefill(&self.cfg.pca, prompts.clone())?;
                metrics.prefills += 1;
                gang = Some(id);
                for (lane, q) in batch.drain(..).enumerate() {
                    lane_len[lane] = prompts[lane].len();
                    lanes[lane] = self.admit_lane(q, &logits[lane], &mut metrics);
                }
                for lane in n..self.gang_batch {
                    lane_len[lane] = prompts[lane].len();
                }
            }
            let gang_id = match gang {
                Some(g) => g,
                None => continue,
            };

            // ---- 3. refill free lanes (scheduler policy) ------------------
            let budget = match self.cfg.scheduler {
                SchedulerPolicy::PrefillFirst => self.gang_batch,
                SchedulerPolicy::DecodeFirst => 1,
            };
            let mut injected = 0;
            for lane in 0..self.gang_batch {
                if injected >= budget || pending.is_empty() {
                    break;
                }
                if matches!(lanes[lane], Lane::Busy(_)) {
                    continue;
                }
                let q = pending.pop_front().unwrap();
                let prompt = self.clamped_prompt(&q.req);
                let (lane_id, logits) = self.handle.prefill(&self.cfg.pca, vec![prompt.clone()])?;
                metrics.prefills += 1;
                self.handle.inject(gang_id, lane_id, lane)?;
                metrics.injections += 1;
                lane_len[lane] = prompt.len();
                lanes[lane] = self.admit_lane(q, &logits[0], &mut metrics);
                injected += 1;
            }

            // ---- 4. free-lane hygiene -------------------------------------
            for lane in 0..self.gang_batch {
                if matches!(lanes[lane], Lane::Busy(_)) {
                    continue;
                }
                if (lane_len[lane] as f64) > self.cfg.lane_reset_frac * self.max_len as f64 {
                    let (blank, _) = self.handle.prefill(&self.cfg.pca, vec![vec![0]])?;
                    self.handle.inject(gang_id, blank, lane)?;
                    lane_len[lane] = 1;
                    metrics.lane_resets += 1;
                }
            }

            // ---- 5. decode iteration --------------------------------------
            if !lanes.iter().any(|l| matches!(l, Lane::Busy(_))) {
                continue;
            }
            let tokens: Vec<i32> = lanes
                .iter()
                .map(|l| match l {
                    Lane::Busy(b) => b.next_token,
                    Lane::Free => 0,
                })
                .collect();
            let t0 = Instant::now();
            let logits = self.handle.decode(DecodeRequest {
                state: gang_id,
                variant: self.cfg.variant.clone(),
                tokens,
            })?;
            metrics.decode_steps += 1;
            metrics.decode_step_time.push(t0.elapsed().as_secs_f64());
            for len in lane_len.iter_mut() {
                *len += 1;
            }

            // ---- 6. per-lane sampling + completion ------------------------
            for lane in 0..self.gang_batch {
                let finished = {
                    let b = match &mut lanes[lane] {
                        Lane::Busy(b) => b,
                        Lane::Free => continue,
                    };
                    metrics.tokens_generated += 1;
                    if b.ttft_s.is_none() {
                        let t = b.req.submitted.elapsed().as_secs_f64();
                        b.ttft_s = Some(t);
                        metrics.ttft.push(t);
                    }
                    // The admission-sampled token is only stop-checked
                    // here (it was drawn from prefill logits before any
                    // decode ran); stop tokens never enter the output.
                    if Some(b.next_token) == b.req.req.stop_token {
                        Some(FinishReason::StopToken)
                    } else {
                    let tok = b.sampler.sample(&logits[lane]) as i32;
                    b.produced.push(b.next_token);
                    b.next_token = tok;
                    if Some(tok) == b.req.req.stop_token {
                        Some(FinishReason::StopToken)
                    } else if b.produced.len() >= b.req.req.max_new_tokens {
                        Some(FinishReason::MaxTokens)
                    } else if lane_len[lane] + 1 >= self.max_len {
                        Some(FinishReason::CacheFull)
                    } else {
                        None
                    }
                    }
                };
                if let Some(reason) = finished {
                    let lane_state = std::mem::replace(&mut lanes[lane], Lane::Free);
                    if let Lane::Busy(b) = lane_state {
                        self.complete(*b, reason, &mut metrics);
                    }
                }
            }
        }
        if let Some(g) = gang {
            self.handle.free(g);
        }
        Ok(metrics)
    }

    fn clamped_prompt(&self, req: &GenRequest) -> Vec<i32> {
        let budget = self
            .max_prompt
            .min(self.max_len.saturating_sub(req.max_new_tokens + 2))
            .max(1);
        if req.prompt.len() <= budget {
            req.prompt.clone()
        } else {
            // Keep the *tail* of over-long prompts (recency matters more
            // for generation than the head).
            req.prompt[req.prompt.len() - budget..].to_vec()
        }
    }

    /// Sample the first generated token from prefill logits and build the
    /// busy-lane record.
    fn admit_lane(&self, q: QueuedRequest, logits: &[f32], metrics: &mut EngineMetrics) -> Lane {
        metrics
            .queue_wait
            .push(q.submitted.elapsed().as_secs_f64());
        let mut sampler = Sampler::new(q.req.sampling);
        let first = sampler.sample(logits) as i32;
        Lane::Busy(Box::new(BusyLane {
            req: q,
            sampler,
            produced: Vec::new(),
            next_token: first,
            ttft_s: None,
        }))
    }

    fn complete(&self, b: BusyLane, reason: FinishReason, metrics: &mut EngineMetrics) {
        metrics.requests_done += 1;
        let total = b.req.submitted.elapsed().as_secs_f64();
        metrics.e2e_latency.push(total);
        let timing = RequestTiming {
            queue_s: 0.0,
            ttft_s: b.ttft_s.unwrap_or(total),
            total_s: total,
            decode_steps: b.produced.len(),
        };
        let text = self.tokenizer.decode(&b.produced);
        let result = GenResult {
            id: b.req.req.id,
            tokens: b.produced,
            text,
            finished_reason: reason,
            timing,
        };
        if self.cfg.verbose {
            eprintln!(
                "[engine] done #{} ({} tok, {:?}, {:.3}s)",
                result.id,
                result.tokens.len(),
                reason,
                result.timing.total_s
            );
        }
        let _ = b.req.req.reply.send(result);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn clamp_keeps_prompt_tail() {
        // Pure logic test (no runtime): build an engine-shaped struct via
        // a fake manifest is heavy; test the clamp math directly instead.
        let cfg = EngineConfig::default();
        let _ = cfg; // engine construction needs artifacts; see
                     // rust/tests/coordinator_integration.rs for the real
                     // end-to-end engine tests.
    }
}
