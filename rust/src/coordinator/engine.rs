//! The continuous-batching generation engine.
//!
//! One persistent decode **gang** (a compiled batch bucket of lanes)
//! advances every iteration; finished lanes are refilled by prefilling the
//! next queued request as a batch-1 state and *injecting* it into the gang
//! between iterations (iteration-level scheduling, Orca-style). The
//! attention variant — Full / Loki(k_f, d_f) / H2O / PCAAttn — is a gang
//!-level serving config: Loki drops in as a scheduler choice, not a model
//! fork, which is exactly the deployment story the paper argues for.
//!
//! Memory: the engine mirrors the device-resident KV cache with a
//! [`crate::kvpool`] block allocator + per-sequence block tables. A
//! request is injected **only when the allocator can grant every block of
//! its reservation** (prompt + decode budget); otherwise it waits in the
//! queue — eviction backpressure at the scheduler, not silent lane resets.
//! Full prompt blocks are shared copy-on-write across requests with equal
//! prefixes (content-addressed, vLLM-style), so gang-wide system prompts
//! are paid for once in the pool accounting. This replaces the old
//! `lane_reset_frac` hygiene hack; resets remain only for the physical
//! edge case of a *padding* lane drifting into the cache bound.
//!
//! Backpressure: submissions go through a bounded `SyncSender`; when the
//! queue is full, callers block (admission control at the front door).

use std::collections::VecDeque;
use std::sync::mpsc::{sync_channel, Receiver, SyncSender, TryRecvError};
use std::time::Instant;

use anyhow::Result;

use crate::kvpool::{BlockAllocator, SeqId, TableSet};
use crate::model::ByteTokenizer;
use crate::runtime::{DecodeRequest, DecodeVariant, RuntimeHandle, RuntimeService, StateId};

use super::metrics::EngineMetrics;
use super::request::{FinishReason, GenRequest, GenResult, QueuedRequest, RequestTiming};
use super::sampler::Sampler;

/// Prefill-vs-decode priority (the classic serving trade-off: filling
/// lanes fast boosts throughput; decoding first protects inter-token
/// latency of running requests).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SchedulerPolicy {
    /// Fill every free lane before the next decode iteration.
    PrefillFirst,
    /// At most one injection per decode iteration.
    DecodeFirst,
}

/// KV-pool sizing and sharing knobs (`repro serve --block-size
/// --pool-blocks --no-prefix-share`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct PoolConfig {
    /// Token slots per block (the paging granularity).
    pub block_size: usize,
    /// Total pool blocks; 0 sizes the pool to the worst case
    /// (`gang_batch · ceil(max_len / block_size)`), i.e. admission can
    /// only tighten things when set below that.
    pub num_blocks: usize,
    /// Share full prompt blocks across requests with identical prefixes.
    pub prefix_sharing: bool,
}

impl Default for PoolConfig {
    fn default() -> Self {
        Self { block_size: 16, num_blocks: 0, prefix_sharing: true }
    }
}

#[derive(Clone, Debug)]
pub struct EngineConfig {
    pub pca: String,
    pub variant: DecodeVariant,
    /// Desired gang width; clamped to the largest compiled bucket.
    pub gang_batch: usize,
    pub scheduler: SchedulerPolicy,
    /// Bound of the submission queue (backpressure).
    pub max_queue: usize,
    /// KV-pool admission control (replaces the old `lane_reset_frac`).
    pub pool: PoolConfig,
    pub verbose: bool,
}

impl Default for EngineConfig {
    fn default() -> Self {
        Self {
            pca: "wiki_pre".to_string(),
            variant: DecodeVariant::Full,
            gang_batch: usize::MAX,
            scheduler: SchedulerPolicy::PrefillFirst,
            max_queue: 256,
            pool: PoolConfig::default(),
            verbose: false,
        }
    }
}

enum Lane {
    Free,
    Busy(Box<BusyLane>),
}

struct BusyLane {
    req: QueuedRequest,
    sampler: Sampler,
    produced: Vec<i32>,
    next_token: i32,
    ttft_s: Option<f64>,
}

/// Outcome of a pool-admission attempt.
enum Admit {
    /// Blocks granted; the sequence owns its reservation.
    Granted(SeqId),
    /// Not enough free blocks *right now* — wait for a completion.
    Backpressure,
    /// The request can never fit the configured pool; fail it fast.
    NeverFits,
}

/// The engine: owns the runtime service and the scheduling loop.
pub struct Engine {
    handle: RuntimeHandle,
    cfg: EngineConfig,
    max_len: usize,
    max_prompt: usize,
    gang_batch: usize,
    /// KV bytes one token occupies across all layers/heads (K + V, f32) —
    /// converts pool blocks into the bytes the device cache would hold.
    bytes_per_token: u64,
    tokenizer: ByteTokenizer,
}

impl Engine {
    /// Bounded submission channel for this engine config.
    pub fn channel(cfg: &EngineConfig) -> (SyncSender<GenRequest>, Receiver<GenRequest>) {
        sync_channel(cfg.max_queue)
    }

    pub fn new(service: &RuntimeService, cfg: EngineConfig) -> Self {
        let man = &service.manifest;
        let largest = man.batch_buckets.iter().copied().max().unwrap_or(1);
        let gang_batch = man.pick_batch_bucket(cfg.gang_batch.min(largest));
        let max_prompt = man.prefill_buckets.iter().copied().max().unwrap_or(0);
        let m = &man.model;
        let bytes_per_token = (m.n_layers * m.n_heads * m.head_dim * 2 * 4) as u64;
        Self {
            handle: service.handle(),
            max_len: man.model.max_len,
            max_prompt,
            gang_batch,
            bytes_per_token,
            cfg,
            tokenizer: ByteTokenizer,
        }
    }

    /// Run until the submission channel closes and all work drains.
    /// Returns the fleet metrics.
    pub fn run(&self, rx: Receiver<GenRequest>) -> Result<EngineMetrics> {
        let mut metrics = EngineMetrics::default();
        let mut pending: VecDeque<QueuedRequest> = VecDeque::new();
        let mut lanes: Vec<Lane> = (0..self.gang_batch).map(|_| Lane::Free).collect();
        let mut lane_len: Vec<usize> = vec![0; self.gang_batch];
        let mut gang: Option<StateId> = None;
        let mut rx_open = true;

        // ---- KV pool: the admission-control mirror of the device cache.
        let bs = self.cfg.pool.block_size.max(1);
        let blocks_per_lane = self.max_len.div_ceil(bs);
        let num_blocks = if self.cfg.pool.num_blocks == 0 {
            self.gang_batch * blocks_per_lane
        } else {
            self.cfg.pool.num_blocks
        };
        let mut pool = BlockAllocator::new(num_blocks, bs);
        let mut tables = TableSet::new(bs, self.cfg.pool.prefix_sharing);
        let mut lane_seq: Vec<Option<SeqId>> = vec![None; self.gang_batch];
        metrics.pool_blocks_total = num_blocks as u64;
        metrics.pool_block_bytes = bs as u64 * self.bytes_per_token;
        metrics.kv_flat_bytes = (self.gang_batch * self.max_len) as u64 * self.bytes_per_token;

        loop {
            // ---- 1. admit into the queue ----------------------------------
            loop {
                match rx.try_recv() {
                    Ok(req) => {
                        metrics.requests_in += 1;
                        pending.push_back(QueuedRequest { req, submitted: Instant::now() });
                    }
                    Err(TryRecvError::Empty) => break,
                    Err(TryRecvError::Disconnected) => {
                        rx_open = false;
                        break;
                    }
                }
            }
            let any_busy = lanes.iter().any(|l| matches!(l, Lane::Busy(_)));
            if !rx_open && pending.is_empty() && !any_busy {
                break;
            }
            if pending.is_empty() && !any_busy {
                // Idle: block for the next submission.
                match rx.recv() {
                    Ok(req) => {
                        metrics.requests_in += 1;
                        pending.push_back(QueuedRequest { req, submitted: Instant::now() });
                    }
                    Err(_) => break,
                }
            }

            // ---- 2. bootstrap the gang with a batched prefill -------------
            if gang.is_none() && !pending.is_empty() {
                let mut batch: Vec<(QueuedRequest, Vec<i32>, SeqId)> = Vec::new();
                while batch.len() < self.gang_batch {
                    let Some(front) = pending.front() else { break };
                    let prompt = self.clamped_prompt(&front.req);
                    match self.try_admit(&mut pool, &mut tables, &prompt, front.req.max_new_tokens)
                    {
                        Admit::Granted(seq) => {
                            let q = pending.pop_front().unwrap();
                            batch.push((q, prompt, seq));
                        }
                        Admit::Backpressure => {
                            metrics.admission_blocked += 1;
                            break;
                        }
                        Admit::NeverFits => {
                            let q = pending.pop_front().unwrap();
                            self.reject(q, &mut metrics);
                        }
                    }
                }
                if !batch.is_empty() {
                    let mut prompts: Vec<Vec<i32>> =
                        batch.iter().map(|(_, p, _)| p.clone()).collect();
                    // Pad to the configured gang width so the persistent
                    // gang lands in the right batch bucket even under
                    // light load.
                    while prompts.len() < self.gang_batch {
                        prompts.push(vec![0]);
                    }
                    let (id, logits) = self.handle.prefill(&self.cfg.pca, prompts)?;
                    metrics.prefills += 1;
                    gang = Some(id);
                    let n = batch.len();
                    for (lane, (q, prompt, seq)) in batch.into_iter().enumerate() {
                        lane_len[lane] = prompt.len();
                        lane_seq[lane] = Some(seq);
                        lanes[lane] = self.admit_lane(q, &logits[lane], &mut metrics);
                    }
                    for lane in n..self.gang_batch {
                        lane_len[lane] = 1; // padding prompt [0]
                    }
                }
            }
            let gang_id = match gang {
                Some(g) => g,
                None => continue,
            };

            // ---- 3. refill free lanes (scheduler policy × pool admission) -
            let budget = match self.cfg.scheduler {
                SchedulerPolicy::PrefillFirst => self.gang_batch,
                SchedulerPolicy::DecodeFirst => 1,
            };
            let mut injected = 0;
            for lane in 0..self.gang_batch {
                if injected >= budget || pending.is_empty() {
                    break;
                }
                if matches!(lanes[lane], Lane::Busy(_)) {
                    continue;
                }
                let front = pending.front().unwrap();
                let prompt = self.clamped_prompt(&front.req);
                match self.try_admit(&mut pool, &mut tables, &prompt, front.req.max_new_tokens) {
                    Admit::Granted(seq) => {
                        let q = pending.pop_front().unwrap();
                        let (lane_id, logits) =
                            self.handle.prefill(&self.cfg.pca, vec![prompt.clone()])?;
                        metrics.prefills += 1;
                        self.handle.inject(gang_id, lane_id, lane)?;
                        metrics.injections += 1;
                        lane_len[lane] = prompt.len();
                        lane_seq[lane] = Some(seq);
                        lanes[lane] = self.admit_lane(q, &logits[0], &mut metrics);
                        injected += 1;
                    }
                    Admit::Backpressure => {
                        // Head-of-line request waits for blocks to free up;
                        // completions (not resets) are what unblock it.
                        metrics.admission_blocked += 1;
                        break;
                    }
                    Admit::NeverFits => {
                        let q = pending.pop_front().unwrap();
                        self.reject(q, &mut metrics);
                    }
                }
            }

            // ---- 4. padding-lane hygiene ----------------------------------
            // Free lanes still advance with the gang. They hold no pool
            // blocks, but the *device* cache behind them is physically
            // bounded, so re-blank one exactly when the next step would
            // hit max_len (the old 0.75·max_len fraction heuristic is
            // gone; this fires once per max_len idle steps at most).
            for lane in 0..self.gang_batch {
                if matches!(lanes[lane], Lane::Busy(_)) {
                    continue;
                }
                if lane_len[lane] + 1 >= self.max_len {
                    let (blank, _) = self.handle.prefill(&self.cfg.pca, vec![vec![0]])?;
                    self.handle.inject(gang_id, blank, lane)?;
                    lane_len[lane] = 1;
                    metrics.lane_resets += 1;
                }
            }

            // ---- 5. decode iteration --------------------------------------
            if !lanes.iter().any(|l| matches!(l, Lane::Busy(_))) {
                continue;
            }
            let tokens: Vec<i32> = lanes
                .iter()
                .map(|l| match l {
                    Lane::Busy(b) => b.next_token,
                    Lane::Free => 0,
                })
                .collect();
            let t0 = Instant::now();
            let logits = self.handle.decode(DecodeRequest {
                state: gang_id,
                variant: self.cfg.variant.clone(),
                tokens,
            })?;
            metrics.decode_steps += 1;
            metrics.decode_step_time.push(t0.elapsed().as_secs_f64());
            for len in lane_len.iter_mut() {
                *len += 1;
            }
            // Mirror the device-side append in the pool tables (stays
            // within the admission reservation by construction).
            for lane in 0..self.gang_batch {
                if let (Lane::Busy(_), Some(seq)) = (&lanes[lane], lane_seq[lane]) {
                    tables.advance(seq);
                }
            }
            metrics.note_pool(pool.blocks_in_use(), tables.shared_hits);

            // ---- 6. per-lane sampling + completion ------------------------
            for lane in 0..self.gang_batch {
                let finished = {
                    let b = match &mut lanes[lane] {
                        Lane::Busy(b) => b,
                        Lane::Free => continue,
                    };
                    metrics.tokens_generated += 1;
                    if b.ttft_s.is_none() {
                        let t = b.req.submitted.elapsed().as_secs_f64();
                        b.ttft_s = Some(t);
                        metrics.ttft.push(t);
                    }
                    // The admission-sampled token is only stop-checked
                    // here (it was drawn from prefill logits before any
                    // decode ran); stop tokens never enter the output.
                    if Some(b.next_token) == b.req.req.stop_token {
                        Some(FinishReason::StopToken)
                    } else {
                        let tok = b.sampler.sample(&logits[lane]) as i32;
                        b.produced.push(b.next_token);
                        b.next_token = tok;
                        if Some(tok) == b.req.req.stop_token {
                            Some(FinishReason::StopToken)
                        } else if b.produced.len() >= b.req.req.max_new_tokens {
                            Some(FinishReason::MaxTokens)
                        } else if lane_len[lane] + 1 >= self.max_len {
                            Some(FinishReason::CacheFull)
                        } else {
                            None
                        }
                    }
                };
                if let Some(reason) = finished {
                    if let Some(seq) = lane_seq[lane].take() {
                        tables.free(&mut pool, seq);
                    }
                    let lane_state = std::mem::replace(&mut lanes[lane], Lane::Free);
                    if let Lane::Busy(b) = lane_state {
                        self.complete(*b, reason, &mut metrics);
                    }
                }
            }
        }
        if let Some(g) = gang {
            self.handle.free(g);
        }
        metrics.note_pool(pool.blocks_in_use(), tables.shared_hits);
        Ok(metrics)
    }

    /// Pool admission: grant the full reservation (prompt + generation
    /// budget, rounded up to blocks) or don't touch the pool at all.
    fn try_admit(
        &self,
        pool: &mut BlockAllocator,
        tables: &mut TableSet,
        prompt: &[i32],
        max_new: usize,
    ) -> Admit {
        let reserve = (prompt.len() + max_new + 2).min(self.max_len);
        match tables.admit(pool, prompt, reserve) {
            Ok(seq) => Admit::Granted(seq),
            Err(_) => {
                // Shared prefix blocks still occupy pool capacity (they
                // are live allocations, merely refcounted), so a grant
                // always needs the request's *total* block count to fit
                // the pool. More than that can never be satisfied by
                // waiting; anything else is unblocked by completions.
                if pool.blocks_for(reserve) > pool.num_blocks() {
                    Admit::NeverFits
                } else {
                    Admit::Backpressure
                }
            }
        }
    }

    /// Fail a request that can never be admitted under the configured
    /// pool (clearer than queueing it forever behind backpressure).
    fn reject(&self, q: QueuedRequest, metrics: &mut EngineMetrics) {
        metrics.requests_rejected += 1;
        let total = q.submitted.elapsed().as_secs_f64();
        let result = GenResult {
            id: q.req.id,
            tokens: Vec::new(),
            text: String::new(),
            finished_reason: FinishReason::CacheFull,
            timing: RequestTiming { total_s: total, ..Default::default() },
        };
        if self.cfg.verbose {
            eprintln!("[engine] rejected #{} (exceeds pool capacity)", result.id);
        }
        let _ = q.req.reply.send(result);
    }

    fn clamped_prompt(&self, req: &GenRequest) -> Vec<i32> {
        let budget = self
            .max_prompt
            .min(self.max_len.saturating_sub(req.max_new_tokens + 2))
            .max(1);
        if req.prompt.len() <= budget {
            req.prompt.clone()
        } else {
            // Keep the *tail* of over-long prompts (recency matters more
            // for generation than the head).
            req.prompt[req.prompt.len() - budget..].to_vec()
        }
    }

    /// Sample the first generated token from prefill logits and build the
    /// busy-lane record.
    fn admit_lane(&self, q: QueuedRequest, logits: &[f32], metrics: &mut EngineMetrics) -> Lane {
        metrics
            .queue_wait
            .push(q.submitted.elapsed().as_secs_f64());
        let mut sampler = Sampler::new(q.req.sampling);
        let first = sampler.sample(logits) as i32;
        Lane::Busy(Box::new(BusyLane {
            req: q,
            sampler,
            produced: Vec::new(),
            next_token: first,
            ttft_s: None,
        }))
    }

    fn complete(&self, b: BusyLane, reason: FinishReason, metrics: &mut EngineMetrics) {
        metrics.requests_done += 1;
        let total = b.req.submitted.elapsed().as_secs_f64();
        metrics.e2e_latency.push(total);
        let timing = RequestTiming {
            queue_s: 0.0,
            ttft_s: b.ttft_s.unwrap_or(total),
            total_s: total,
            decode_steps: b.produced.len(),
        };
        let text = self.tokenizer.decode(&b.produced);
        let result = GenResult {
            id: b.req.req.id,
            tokens: b.produced,
            text,
            finished_reason: reason,
            timing,
        };
        if self.cfg.verbose {
            eprintln!(
                "[engine] done #{} ({} tok, {:?}, {:.3}s)",
                result.id,
                result.tokens.len(),
                reason,
                result.timing.total_s
            );
        }
        let _ = b.req.req.reply.send(result);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pool_config_auto_sizing_is_worst_case() {
        // Engine construction needs compiled artifacts (see
        // rust/tests/coordinator_integration.rs for end-to-end tests);
        // check the sizing rule the engine applies in run().
        let cfg = EngineConfig::default();
        assert_eq!(cfg.pool.num_blocks, 0, "default pool auto-sizes");
        let (max_len, gang, bs) = (256usize, 8usize, cfg.pool.block_size);
        let auto = gang * max_len.div_ceil(bs);
        // Worst case: every lane full — admission can then never reject a
        // request the flat cache would have accepted.
        assert_eq!(auto, 8 * 16);
    }
}
