//! Request / response types for the serving engine.

use std::sync::mpsc::Sender;
use std::time::{Duration, Instant};

use super::clock::wall_now;
use super::sampler::SampleCfg;

/// Request importance class, the scheduling signal behind the engine's
/// priority-aware victim policy: under KV-pool pressure, `Batch` lanes
/// are preempted before `Interactive` ones, and (under
/// [`super::engine::VictimPolicy::PriorityAware`]) `Interactive`
/// submissions are admitted ahead of queued `Batch` work. Ordering is
/// deliberate: `Interactive < Batch` so "greater" means "evict first".
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum Priority {
    /// Latency-sensitive traffic (chat turns, autocomplete). The default:
    /// an unannotated request is never the preferred eviction victim.
    #[default]
    Interactive,
    /// Throughput traffic (offline eval, summarization jobs): evicted
    /// first under memory pressure, admitted behind interactive work.
    Batch,
}

/// Number of priority classes (sizes per-class metric arrays).
pub const PRIORITY_CLASSES: usize = 2;

impl Priority {
    /// Dense index for per-class metric arrays.
    pub fn index(self) -> usize {
        self as usize
    }

    pub fn name(self) -> &'static str {
        match self {
            Priority::Interactive => "interactive",
            Priority::Batch => "batch",
        }
    }

    /// Parse the wire/CLI spelling (`"interactive"` / `"batch"`).
    pub fn parse(s: &str) -> Option<Priority> {
        match s.to_ascii_lowercase().as_str() {
            "interactive" => Some(Priority::Interactive),
            "batch" => Some(Priority::Batch),
            _ => None,
        }
    }
}

/// A generation request submitted to the engine.
#[derive(Debug)]
pub struct GenRequest {
    pub id: u64,
    pub prompt: Vec<i32>,
    pub max_new_tokens: usize,
    /// Stop generation when this byte is produced (e.g. b'\n').
    pub stop_token: Option<i32>,
    pub sampling: SampleCfg,
    /// Importance class for the scheduler's victim/admission policies.
    pub priority: Priority,
    /// Zero-based conversation turn this request represents (0 = first
    /// turn / single-shot). Pure annotation from the workload layer: it
    /// never changes scheduling, but the metrics bucket TTFT and
    /// prefix-hit rates per turn with it — turn ≥ 1 prompts extend a
    /// resident history, so their radix-tree hit rate is the signal the
    /// multi-turn scenarios grade.
    pub turn: u32,
    /// Optional time-to-first-token SLO in milliseconds. The engine
    /// stamps an absolute deadline (`arrival + slo_ms`) at submission;
    /// under [`super::engine::VictimPolicy::DeadlineAware`] the pending
    /// queue is ordered earliest-effective-deadline-first and victim
    /// scoring protects the least slack. Always observable: completion
    /// reports whether the first token beat the deadline
    /// ([`RequestTiming::deadline_hit`]), SLO'd or not scheduled by it.
    pub slo_ms: Option<f64>,
    /// Where to deliver the result.
    pub reply: Sender<GenResult>,
}

/// Timing of a single request through the engine.
#[derive(Clone, Debug, Default)]
pub struct RequestTiming {
    pub queue_s: f64,
    /// Time-to-first-token measured from submission.
    pub ttft_s: f64,
    /// Engine decode iterations elapsed when the first token was emitted —
    /// the deterministic (wall-clock-free) TTFT used by the scheduler
    /// tests to compare classes.
    pub ttft_steps: u64,
    pub total_s: f64,
    pub decode_steps: usize,
    /// Times this request was preempted mid-flight and resumed by prefix
    /// recompute (0 under `AdmissionPolicy::ReserveFull`).
    pub preemptions: usize,
    /// Whether the first token beat the request's SLO deadline (`None`
    /// when no `slo_ms` was set, or the request never emitted a token).
    pub deadline_hit: Option<bool>,
}

#[derive(Clone, Debug)]
pub struct GenResult {
    pub id: u64,
    pub tokens: Vec<i32>,
    pub text: String,
    pub finished_reason: FinishReason,
    /// Present exactly when `finished_reason == FinishReason::Shed`:
    /// the prediction that doomed the request and a retry hint.
    pub shed: Option<ShedInfo>,
    pub timing: RequestTiming,
}

/// Why predictive admission rejected a request, echoed to the client in
/// the structured JSON shed reply.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct ShedInfo {
    /// Predicted time-to-first-token (milliseconds from arrival) at the
    /// moment of shedding — provably past the deadline under the
    /// configured [`super::engine::EngineConfig::shed`] margin.
    pub predicted_ttft_ms: f64,
    /// How many milliseconds of backlog stand between the prediction
    /// and the deadline (`predicted_ttft_ms − slo_ms`, floored at 0):
    /// a client retrying after roughly this long sees a queue that has
    /// drained enough for an identical request to be admittable.
    pub retry_after_ms: f64,
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FinishReason {
    MaxTokens,
    StopToken,
    CacheFull,
    EngineShutdown,
    /// Rejected at admission by predictive load shedding: the engine's
    /// service-rate estimator proved the TTFT deadline unreachable
    /// given the lanes ahead, so no prefill or decode was spent on it.
    Shed,
}

/// Internal: a request plus its admission timestamp.
#[derive(Debug)]
pub struct QueuedRequest {
    pub req: GenRequest,
    pub submitted: Instant,
    /// Engine decode-step counter when the request entered the queue —
    /// `ttft_steps` is measured relative to this, so the step-based TTFT
    /// is scheduling latency (queue wait + admission) even for traces
    /// that arrive mid-run, not an absolute uptime counter.
    pub submitted_step: u64,
    /// Engine-clock milliseconds when the request entered the queue
    /// (`EngineMetrics::now_ms`, which under `Steps` includes the
    /// virtual prefill charge). First tokens are stamped against this
    /// into the charged-domain `ClassMetrics::ttft_ms` histogram.
    pub submitted_ms: f64,
    /// Absolute SLO deadline, arrival-stamped (`submitted + slo_ms`).
    /// `None` when the request carries no SLO.
    pub deadline: Option<Instant>,
    /// Cross-class aging already promoted this `Batch` request to
    /// interactive-equivalent scheduling (sticky: once a request has
    /// waited out the aging bound it never demotes, and the promotion is
    /// counted exactly once in the metrics).
    pub aged: bool,
}

impl QueuedRequest {
    /// Stamp a freshly submitted request: deadline is arrival-relative,
    /// so a request queued behind a backlog keeps the SLO its client
    /// measured from, not from whenever the scheduler first saw it idle.
    pub fn stamp(req: GenRequest, submitted_step: u64, submitted_ms: f64) -> Self {
        let submitted = wall_now();
        let deadline = req
            .slo_ms
            .filter(|ms| ms.is_finite() && *ms > 0.0)
            .map(|ms| submitted + Duration::from_secs_f64(ms / 1000.0));
        Self { req, submitted, submitted_step, submitted_ms, deadline, aged: false }
    }
}
