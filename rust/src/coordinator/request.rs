//! Request / response types for the serving engine.

use std::sync::mpsc::Sender;
use std::time::Instant;

use super::sampler::SampleCfg;

/// A generation request submitted to the engine.
#[derive(Debug)]
pub struct GenRequest {
    pub id: u64,
    pub prompt: Vec<i32>,
    pub max_new_tokens: usize,
    /// Stop generation when this byte is produced (e.g. b'\n').
    pub stop_token: Option<i32>,
    pub sampling: SampleCfg,
    /// Where to deliver the result.
    pub reply: Sender<GenResult>,
}

/// Timing of a single request through the engine.
#[derive(Clone, Debug, Default)]
pub struct RequestTiming {
    pub queue_s: f64,
    /// Time-to-first-token measured from submission.
    pub ttft_s: f64,
    pub total_s: f64,
    pub decode_steps: usize,
    /// Times this request was preempted mid-flight and resumed by prefix
    /// recompute (0 under `AdmissionPolicy::ReserveFull`).
    pub preemptions: usize,
}

#[derive(Clone, Debug)]
pub struct GenResult {
    pub id: u64,
    pub tokens: Vec<i32>,
    pub text: String,
    pub finished_reason: FinishReason,
    pub timing: RequestTiming,
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FinishReason {
    MaxTokens,
    StopToken,
    CacheFull,
    EngineShutdown,
}

/// Internal: a request plus its admission timestamp.
#[derive(Debug)]
pub struct QueuedRequest {
    pub req: GenRequest,
    pub submitted: Instant,
}
