//! Request / response types for the serving engine.

use std::sync::mpsc::Sender;
use std::time::Instant;

use super::sampler::SampleCfg;

/// Request importance class, the scheduling signal behind the engine's
/// priority-aware victim policy: under KV-pool pressure, `Batch` lanes
/// are preempted before `Interactive` ones, and (under
/// [`super::engine::VictimPolicy::PriorityAware`]) `Interactive`
/// submissions are admitted ahead of queued `Batch` work. Ordering is
/// deliberate: `Interactive < Batch` so "greater" means "evict first".
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum Priority {
    /// Latency-sensitive traffic (chat turns, autocomplete). The default:
    /// an unannotated request is never the preferred eviction victim.
    #[default]
    Interactive,
    /// Throughput traffic (offline eval, summarization jobs): evicted
    /// first under memory pressure, admitted behind interactive work.
    Batch,
}

/// Number of priority classes (sizes per-class metric arrays).
pub const PRIORITY_CLASSES: usize = 2;

impl Priority {
    /// Dense index for per-class metric arrays.
    pub fn index(self) -> usize {
        self as usize
    }

    pub fn name(self) -> &'static str {
        match self {
            Priority::Interactive => "interactive",
            Priority::Batch => "batch",
        }
    }

    /// Parse the wire/CLI spelling (`"interactive"` / `"batch"`).
    pub fn parse(s: &str) -> Option<Priority> {
        match s.to_ascii_lowercase().as_str() {
            "interactive" => Some(Priority::Interactive),
            "batch" => Some(Priority::Batch),
            _ => None,
        }
    }
}

/// A generation request submitted to the engine.
#[derive(Debug)]
pub struct GenRequest {
    pub id: u64,
    pub prompt: Vec<i32>,
    pub max_new_tokens: usize,
    /// Stop generation when this byte is produced (e.g. b'\n').
    pub stop_token: Option<i32>,
    pub sampling: SampleCfg,
    /// Importance class for the scheduler's victim/admission policies.
    pub priority: Priority,
    /// Where to deliver the result.
    pub reply: Sender<GenResult>,
}

/// Timing of a single request through the engine.
#[derive(Clone, Debug, Default)]
pub struct RequestTiming {
    pub queue_s: f64,
    /// Time-to-first-token measured from submission.
    pub ttft_s: f64,
    /// Engine decode iterations elapsed when the first token was emitted —
    /// the deterministic (wall-clock-free) TTFT used by the scheduler
    /// tests to compare classes.
    pub ttft_steps: u64,
    pub total_s: f64,
    pub decode_steps: usize,
    /// Times this request was preempted mid-flight and resumed by prefix
    /// recompute (0 under `AdmissionPolicy::ReserveFull`).
    pub preemptions: usize,
}

#[derive(Clone, Debug)]
pub struct GenResult {
    pub id: u64,
    pub tokens: Vec<i32>,
    pub text: String,
    pub finished_reason: FinishReason,
    pub timing: RequestTiming,
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FinishReason {
    MaxTokens,
    StopToken,
    CacheFull,
    EngineShutdown,
}

/// Internal: a request plus its admission timestamp.
#[derive(Debug)]
pub struct QueuedRequest {
    pub req: GenRequest,
    pub submitted: Instant,
    /// Engine decode-step counter when the request entered the queue —
    /// `ttft_steps` is measured relative to this, so the step-based TTFT
    /// is scheduling latency (queue wait + admission) even for traces
    /// that arrive mid-run, not an absolute uptime counter.
    pub submitted_step: u64,
}
