//! L3 serving coordinator — the system the paper's method plugs into.
//!
//! A vLLM-style single-node inference engine built on the channel-fronted
//! PJRT runtime:
//!
//! * [`request`] — request/response types and per-request latency records.
//! * [`sampler`] — greedy / temperature / top-p sampling.
//! * [`engine`]  — the continuous batcher: a persistent decode *gang* of
//!   bucket-size lanes; finished lanes are refilled by prefilling the next
//!   queued request as a batch-1 state and *injecting* it between decode
//!   iterations (iteration-level scheduling à la Orca). Prefill-vs-decode
//!   priority is a scheduler knob. KV memory is governed by a
//!   [`crate::kvpool`] block allocator under a configurable
//!   [`engine::AdmissionPolicy`]: `ReserveFull` admits only fully-backed
//!   reservations (backpressure, not resets); `Speculative` admits on a
//!   partial reservation, grows block tables at decode time and preempts
//!   the youngest lane under pressure, resuming it byte-identically via
//!   prefix recompute. Full prompt blocks are prefix-shared across
//!   identical prefixes either way.
//! * [`clock`] — the engine's time authority: [`clock::EngineClock`]
//!   (wall vs deterministic decode-steps twin) and the subtree's only
//!   sanctioned raw wall-clock reads (`repro-lint` enforces this).
//! * [`metrics`] — fleet counters + latency summaries.
//! * [`router`] — the sharded-frontend decision core: a deterministic
//!   replica chooser ([`router::RoutePolicy::PrefixAffinity`] keys on the
//!   kvpool's content-addressed prefix-block hashes, with a bounded
//!   load-skew override) that [`crate::server::Frontend`] wires to real
//!   engine channels.
//! * [`predictor`] — the online service-rate estimator (EWMA decode-step
//!   cost + prompt-proportional prefill cost) behind predictive
//!   admission: under an [`engine::EngineConfig::shed`] policy, queued
//!   SLO'd requests whose predicted TTFT provably misses their deadline
//!   are shed at admission with a structured reply instead of queueing
//!   to die.
//!
//! Loki enters as the engine's `DecodeVariant`: the scheduler chooses the
//! attention graph (full / loki / h2o / pcaattn) per gang, making sparse
//! attention a serving-config rather than a model fork.

pub mod clock;
pub mod engine;
pub mod metrics;
pub mod predictor;
pub mod request;
pub mod router;
pub mod sampler;

pub use clock::{wall_now, EngineClock, WallTimer};
pub use engine::{
    reserve_tokens, AdmissionPolicy, Engine, EngineCaps, EngineConfig, PoolConfig,
    PreemptMode, SchedulerPolicy, VictimPolicy, RESERVE_SLACK_TOKENS,
};
pub use metrics::{ClassMetrics, EngineMetrics, TURN_TTFT_BUCKETS};
pub use predictor::{ServiceRateEstimator, ShedPolicy, EWMA_ALPHA};
pub use request::{GenRequest, GenResult, Priority, RequestTiming, ShedInfo};
pub use router::{RouteDecision, RoutePolicy, Router, RouterCfg};
pub use sampler::{SampleCfg, Sampler};
