//! Predictive admission: the online service-rate estimator behind
//! [`ShedPolicy`] early load shedding.
//!
//! Loki's win is cutting compute per decoded token; that win is
//! squandered when the engine spends prefill and decode cycles on
//! requests whose TTFT deadline is already unreachable. The estimator
//! tracks two rates online:
//!
//! * **decode-step cost** — an EWMA over measured decode-iteration wall
//!   time (one observation per gang step), and
//! * **prefill cost** — a prompt-length-proportional model: an EWMA over
//!   measured seconds *per prefilled token*.
//!
//! Every scheduling round the engine replays the pending queue against
//! the lanes ahead of it (earliest-lane-free simulation, see
//! `Engine::shed_doomed`) and converts each queued request's predicted
//! first-token step into milliseconds through these rates. A request
//! whose predicted TTFT misses its deadline by the policy's margin is
//! rejected *at admission* with a structured shed reply instead of
//! queueing to die.
//!
//! Determinism: wall-clock EWMAs would make scheduler tests flaky, so
//! [`EngineClock::Steps`] is the deterministic decode-steps twin — one
//! decode step costs exactly `step_ms` virtual milliseconds and prefill
//! costs `prefill_ms_per_token` per token. Under the steps clock the
//! estimator ignores wall-time observations entirely and deadline
//! grading happens in the same steps domain, so a `SimRuntime` trace
//! sheds, grades and reports identically on every run.

use super::clock::EngineClock;

/// EWMA smoothing factor for both online rates. One fifth of each new
/// observation: noisy individual steps cannot whipsaw admission, but a
/// genuine regime change (bigger gang, longer contexts) converges in a
/// few dozen steps.
pub const EWMA_ALPHA: f64 = 0.2;

/// Early load shedding policy (`repro serve --shed-policy
/// off|strict|hedged --shed-margin F`). Applied on top of the pending
/// queue every scheduling round; designed for
/// [`super::engine::VictimPolicy::DeadlineAware`], where the queue order
/// being predicted is also the order being served.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub enum ShedPolicy {
    /// No prediction, no shedding — pins PR 4 behavior bit-identically.
    #[default]
    Off,
    /// Shed an SLO'd request the moment its predicted TTFT exceeds its
    /// deadline. Exact (zero shed errors) when decode lengths are
    /// deterministic; with stop-token early exits the occupancy model
    /// is an upper bound, so `Strict` can over-shed borderline work —
    /// that is what `Hedged` is for.
    Strict,
    /// Shed only when the predicted TTFT exceeds the deadline by more
    /// than `margin_frac` of the deadline (e.g. 0.5 → only requests
    /// predicted ≥ 1.5× over budget are shed). The margin absorbs
    /// model error from early-stopping lanes and preemption churn.
    Hedged {
        /// Fractional slack on top of the deadline before a shed fires
        /// (clamped to ≥ 0; 0 behaves like `Strict`).
        margin_frac: f64,
    },
}

impl ShedPolicy {
    /// The policy's shed margin: `None` disables shedding entirely,
    /// `Some(m)` sheds when `predicted > deadline · (1 + m)`.
    pub fn margin_frac(&self) -> Option<f64> {
        match *self {
            ShedPolicy::Off => None,
            ShedPolicy::Strict => Some(0.0),
            ShedPolicy::Hedged { margin_frac } => Some(margin_frac.max(0.0)),
        }
    }

    /// Parse the CLI spelling (`"off"` / `"strict"` / `"hedged"`, the
    /// margin rides on a separate flag).
    pub fn parse(s: &str, margin_frac: f64) -> Option<ShedPolicy> {
        match s.to_ascii_lowercase().as_str() {
            "off" => Some(ShedPolicy::Off),
            "strict" => Some(ShedPolicy::Strict),
            "hedged" => Some(ShedPolicy::Hedged { margin_frac }),
            _ => None,
        }
    }
}

/// Online service-rate estimator: decode-step and per-prefill-token
/// cost, EWMA-smoothed under [`EngineClock::Wall`], fixed under the
/// deterministic [`EngineClock::Steps`] twin.
#[derive(Clone, Copy, Debug)]
pub struct ServiceRateEstimator {
    clock: EngineClock,
    /// EWMA of decode-iteration seconds (`None` until the first step).
    step_ewma_s: Option<f64>,
    /// EWMA of prefill seconds per prompt token (`None` until the
    /// first prefill).
    prefill_tok_ewma_s: Option<f64>,
}

impl ServiceRateEstimator {
    pub fn new(clock: EngineClock) -> Self {
        Self { clock, step_ewma_s: None, prefill_tok_ewma_s: None }
    }

    /// Fold one measured decode-iteration duration into the step EWMA.
    /// A no-op under the steps clock (its rate is fixed by config) and
    /// for non-finite or negative observations.
    pub fn observe_step(&mut self, seconds: f64) {
        if matches!(self.clock, EngineClock::Steps { .. }) {
            return;
        }
        if !seconds.is_finite() || seconds < 0.0 {
            return;
        }
        self.step_ewma_s = Some(match self.step_ewma_s {
            None => seconds,
            Some(e) => EWMA_ALPHA * seconds + (1.0 - EWMA_ALPHA) * e,
        });
    }

    /// Fold one measured prefill (of `tokens` prompt tokens) into the
    /// per-token prefill EWMA. Same guards as [`Self::observe_step`].
    pub fn observe_prefill(&mut self, tokens: usize, seconds: f64) {
        if matches!(self.clock, EngineClock::Steps { .. }) {
            return;
        }
        if !seconds.is_finite() || seconds < 0.0 || tokens == 0 {
            return;
        }
        let per_tok = seconds / tokens as f64;
        self.prefill_tok_ewma_s = Some(match self.prefill_tok_ewma_s {
            None => per_tok,
            Some(e) => EWMA_ALPHA * per_tok + (1.0 - EWMA_ALPHA) * e,
        });
    }

    /// Estimated milliseconds per decode step. `None` means the
    /// estimator has no evidence yet — the shed pass must never reject
    /// work on a guess, so `None` disables shedding for the round.
    pub fn step_ms(&self) -> Option<f64> {
        match self.clock {
            EngineClock::Steps { step_ms, .. } => Some(step_ms),
            EngineClock::Wall => self.step_ewma_s.map(|s| s * 1e3),
        }
    }

    /// Prompt-length-proportional prefill cost in milliseconds. Zero
    /// until the first wall observation (under-predicting TTFT only
    /// makes shedding more conservative, never wrong).
    pub fn prefill_ms(&self, tokens: usize) -> f64 {
        match self.clock {
            EngineClock::Steps { prefill_ms_per_token, .. } => {
                prefill_ms_per_token * tokens as f64
            }
            EngineClock::Wall => self.prefill_tok_ewma_s.unwrap_or(0.0) * 1e3 * tokens as f64,
        }
    }

    /// Admission-to-injection prefill cost for a prompt of `tokens`
    /// under the engine's chunking config — the remaining-chunks signal
    /// the shed replay prices in-flight and queued prefills with.
    ///
    /// Monolithic (`chunk == None`) is exactly [`Self::prefill_ms`]:
    /// the PR 5 length-proportional model, bit-identical. Chunked
    /// prefill does the same token work but spreads it over
    /// `ceil(tokens / chunk)` scheduling rounds, and every round after
    /// the first rides behind one decode step of the running gang, so
    /// the extra interleaving delay is `(rounds − 1) · step_ms`.
    pub fn prefill_cost_ms(&self, tokens: usize, chunk: Option<usize>) -> f64 {
        let base = self.prefill_ms(tokens);
        match chunk {
            None | Some(0) => base,
            Some(c) => {
                let rounds = tokens.div_ceil(c).max(1);
                base + (rounds - 1) as f64 * self.step_ms().unwrap_or(0.0)
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ewma_converges_to_a_constant_rate() {
        let mut est = ServiceRateEstimator::new(EngineClock::Wall);
        assert_eq!(est.step_ms(), None, "no evidence → no estimate");
        for _ in 0..64 {
            est.observe_step(0.004);
        }
        let ms = est.step_ms().expect("warm after observations");
        assert!((ms - 4.0).abs() < 1e-9, "constant input must converge exactly: {ms}");
        // A regime change is tracked: after enough 8 ms steps the
        // estimate has moved most of the way there.
        for _ in 0..32 {
            est.observe_step(0.008);
        }
        let ms = est.step_ms().unwrap();
        assert!(ms > 7.9 && ms <= 8.0, "EWMA must track the new rate: {ms}");
    }

    #[test]
    fn ewma_weights_recent_observations() {
        let mut est = ServiceRateEstimator::new(EngineClock::Wall);
        est.observe_step(0.010);
        est.observe_step(0.002);
        // 0.2·2 ms + 0.8·10 ms = 8.4 ms.
        let ms = est.step_ms().unwrap();
        assert!((ms - 8.4).abs() < 1e-9, "{ms}");
    }

    #[test]
    fn degenerate_observations_are_ignored() {
        let mut est = ServiceRateEstimator::new(EngineClock::Wall);
        est.observe_step(f64::NAN);
        est.observe_step(f64::INFINITY);
        est.observe_step(-1.0);
        assert_eq!(est.step_ms(), None, "poison must never warm the estimator");
        est.observe_prefill(0, 1.0);
        est.observe_prefill(8, f64::NAN);
        assert_eq!(est.prefill_ms(100), 0.0);
        est.observe_step(0.004);
        est.observe_step(f64::NAN);
        assert!((est.step_ms().unwrap() - 4.0).abs() < 1e-12, "NaN must not perturb");
    }

    #[test]
    fn prefill_cost_is_prompt_length_proportional() {
        let mut est = ServiceRateEstimator::new(EngineClock::Wall);
        assert_eq!(est.prefill_ms(1000), 0.0, "cold model under-predicts, never guesses");
        // 128 tokens in 6.4 ms → 0.05 ms/token.
        est.observe_prefill(128, 0.0064);
        assert!((est.prefill_ms(100) - 5.0).abs() < 1e-9);
        assert!((est.prefill_ms(200) - 10.0).abs() < 1e-9, "cost must scale with length");
    }

    #[test]
    fn steps_twin_is_fixed_and_ignores_wall_observations() {
        let clock = EngineClock::Steps { step_ms: 2.5, prefill_ms_per_token: 0.125 };
        let mut est = ServiceRateEstimator::new(clock);
        assert_eq!(est.step_ms(), Some(2.5), "steps twin is warm from construction");
        assert!((est.prefill_ms(16) - 2.0).abs() < 1e-12);
        // Wall noise must not leak into the deterministic twin.
        est.observe_step(123.456);
        est.observe_prefill(8, 99.0);
        assert_eq!(est.step_ms(), Some(2.5));
        assert!((est.prefill_ms(16) - 2.0).abs() < 1e-12);
    }

    // `clock_domains_price_time_consistently` moved to
    // `super::clock::tests` along with `EngineClock` itself.

    #[test]
    fn shed_policy_margins() {
        assert_eq!(ShedPolicy::Off.margin_frac(), None);
        assert_eq!(ShedPolicy::Strict.margin_frac(), Some(0.0));
        assert_eq!(ShedPolicy::Hedged { margin_frac: 0.5 }.margin_frac(), Some(0.5));
        // A negative margin clamps to Strict semantics instead of
        // shedding work that was predicted to *make* its deadline.
        assert_eq!(ShedPolicy::Hedged { margin_frac: -3.0 }.margin_frac(), Some(0.0));
        assert_eq!(ShedPolicy::default(), ShedPolicy::Off, "PR 4 pinned");
    }

    #[test]
    fn shed_policy_parses_cli_spellings() {
        assert_eq!(ShedPolicy::parse("off", 0.0), Some(ShedPolicy::Off));
        assert_eq!(ShedPolicy::parse("Strict", 0.0), Some(ShedPolicy::Strict));
        assert_eq!(
            ShedPolicy::parse("hedged", 0.25),
            Some(ShedPolicy::Hedged { margin_frac: 0.25 })
        );
        assert_eq!(ShedPolicy::parse("aggressive", 0.0), None);
    }
}
