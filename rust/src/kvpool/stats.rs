//! Pool occupancy / eviction / sharing counters.
//!
//! Two counter sets with different owners: [`PoolStats`] belongs to the
//! block allocator (alloc/free/fork traffic and the free-list high-water
//! mark), [`TierStats`] to the tiered store (hot-tier hits, cold-page
//! faults, LRU demotions). Both are analytic tallies in the spirit of
//! `attnsim::DataMovement`: on CPU everything is resident, but the
//! counters measure what a faithful two-tier (HBM + host / CXL) backend
//! would have to allocate and move.

/// Allocator-side counters (owned by [`super::BlockAllocator`]).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct PoolStats {
    /// Blocks handed out by `alloc` (fresh blocks, refcount 0 → 1).
    pub allocs: u64,
    /// Blocks returned to the free list (refcount → 0).
    pub frees: u64,
    /// Refcount increments (`retain`): prefix sharing and sequence forks.
    pub forks: u64,
    /// Copy-on-write block duplications (a shared block was written).
    pub cow_copies: u64,
    /// Blocks granted *after* admission by [`super::TableSet::grow`] —
    /// speculative reservations growing toward their true decode length.
    pub grown_blocks: u64,
    /// Sequences released by preemption ([`super::TableSet::preempt_free`])
    /// rather than completion.
    pub preempt_frees: u64,
    /// `alloc` calls that failed because the free list was empty.
    pub failed_allocs: u64,
    /// Peak simultaneous blocks-in-use over the pool's lifetime.
    pub peak_blocks_in_use: u64,
}

impl PoolStats {
    pub fn note_in_use(&mut self, in_use: usize) {
        self.peak_blocks_in_use = self.peak_blocks_in_use.max(in_use as u64);
    }
}

/// Tiered-store counters (owned by [`super::TieredKvPool`]).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct TierStats {
    /// Score passes answered entirely from the hot low-rank tier.
    pub hot_hits: u64,
    /// Cold pages gathered while not resident (had to be faulted in).
    pub gather_faults: u64,
    /// Cold pages gathered while already resident (LRU hit).
    pub gather_hits: u64,
    /// Resident cold pages pushed out by the LRU budget.
    pub demotions: u64,
    /// Bytes a two-tier backend would transfer for the faults above.
    pub bytes_faulted: u64,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn peak_tracks_maximum() {
        let mut s = PoolStats::default();
        s.note_in_use(3);
        s.note_in_use(7);
        s.note_in_use(5);
        assert_eq!(s.peak_blocks_in_use, 7);
    }
}
