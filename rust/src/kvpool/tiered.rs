//! Tiered paged KV storage: hot low-rank K̂ tier + cold full-KV tier.
//!
//! This is where Loki's low-rank keys pay off twice. The pool keeps two
//! arenas, both indexed by the same block table:
//!
//! * **hot tier** — the leading `d_hot` components of every rotated key
//!   K̂ (PCA orders components, so a prefix slice is the paper's d_f·D
//!   budget). This tier is always resident: it is what Loki *ranks* with,
//!   and it is `d_hot / (2·D)` the size of the full cache.
//! * **cold tier** — full-D K and V pages, subject to an LRU residency
//!   budget. Only the pages holding top-k *selected* slots are gathered,
//!   so a faithful two-tier backend (GPU HBM + host memory, à la Double
//!   Sparsity's offloading variant) moves `k_f` of the cache instead of
//!   all of it. [`TieredKvPool::account_gather`] models the faults.
//!
//! On CPU both arenas are plain `Vec<f32>`s and "residency" is an analytic
//! counter set (like `attnsim::DataMovement`): the numbers say what the
//! tiering policy *would* transfer, while the math stays bit-identical to
//! the flat cache — verified by `tests/kvpool_properties.rs`.
//!
//! Blocks are ref-counted ([`BlockAllocator`]), so [`TieredKvPool::fork`]
//! shares every block of the parent copy-on-write: the first append a
//! forked sequence makes into a shared tail block copies that block
//! (hot + cold) before writing.

use crate::obs::{PoolEvent, PoolEventLog};

use super::block::{BlockAllocator, BlockId, PoolExhausted};
use super::stats::TierStats;
use super::table::BlockTable;

/// Sequence handle within a [`TieredKvPool`] (dense index, not recycled).
pub type PoolSeqId = usize;

/// Immutable view of one arena for the paged attention kernels: `data` is
/// `[num_blocks, block_size, width]` row-major, a block table maps token
/// positions to blocks.
#[derive(Clone, Copy)]
pub struct PagedArena<'a> {
    pub data: &'a [f32],
    pub block_size: usize,
    pub width: usize,
}

impl<'a> PagedArena<'a> {
    /// Row of token position `j` under `table` (one sequence's blocks).
    #[inline]
    pub fn row(&self, table: &[BlockId], j: usize) -> &'a [f32] {
        let b = table[j / self.block_size] as usize;
        let off = (b * self.block_size + j % self.block_size) * self.width;
        &self.data[off..off + self.width]
    }
}

#[derive(Clone, Copy, Debug)]
pub struct TieredPoolCfg {
    pub num_blocks: usize,
    /// Token slots per block.
    pub block_size: usize,
    pub head_dim: usize,
    /// Leading key components kept always-hot (Loki's d_f·D knob).
    pub d_hot: usize,
    /// LRU budget for resident cold pages; 0 = unbounded (everything
    /// stays resident and only fault-on-first-touch is counted).
    pub cold_resident_blocks: usize,
}

pub struct TieredKvPool {
    cfg: TieredPoolCfg,
    alloc: BlockAllocator,
    /// `[num_blocks, block_size, d_hot]`, grown lazily per block.
    hot_k: Vec<f32>,
    /// `[num_blocks, block_size, head_dim]` each, grown lazily per block.
    cold_k: Vec<f32>,
    cold_v: Vec<f32>,
    tables: Vec<Option<BlockTable>>,
    resident: Vec<bool>,
    last_touch: Vec<u64>,
    resident_count: usize,
    tick: u64,
    pub tier_stats: TierStats,
    /// Bounded trace side-channel (faults/demotions); drained by
    /// whoever owns the clock, same contract as `TableSet::events`.
    pub events: PoolEventLog,
}

impl TieredKvPool {
    pub fn new(cfg: TieredPoolCfg) -> Self {
        assert!(cfg.d_hot >= 1 && cfg.d_hot <= cfg.head_dim, "d_hot must be in [1, D]");
        Self {
            alloc: BlockAllocator::new(cfg.num_blocks, cfg.block_size),
            hot_k: Vec::new(),
            cold_k: Vec::new(),
            cold_v: Vec::new(),
            tables: Vec::new(),
            resident: vec![false; cfg.num_blocks],
            last_touch: vec![0; cfg.num_blocks],
            resident_count: 0,
            tick: 0,
            tier_stats: TierStats::default(),
            events: PoolEventLog::default(),
            cfg,
        }
    }

    pub fn head_dim(&self) -> usize {
        self.cfg.head_dim
    }

    pub fn d_hot(&self) -> usize {
        self.cfg.d_hot
    }

    pub fn block_size(&self) -> usize {
        self.cfg.block_size
    }

    pub fn allocator(&self) -> &BlockAllocator {
        &self.alloc
    }

    pub fn new_seq(&mut self) -> PoolSeqId {
        self.tables.push(Some(BlockTable::default()));
        self.tables.len() - 1
    }

    pub fn len(&self, seq: PoolSeqId) -> usize {
        self.table_ref(seq).len
    }

    pub fn is_empty(&self, seq: PoolSeqId) -> bool {
        self.len(seq) == 0
    }

    pub fn blocks(&self, seq: PoolSeqId) -> &[BlockId] {
        &self.table_ref(seq).blocks
    }

    fn table_ref(&self, seq: PoolSeqId) -> &BlockTable {
        self.tables[seq].as_ref().expect("freed sequence")
    }

    /// Append one token's K and V rows (`head_dim` floats each). The hot
    /// tier receives the leading `d_hot` components of `k_row` — callers
    /// on the Loki path pass *rotated* keys K̂, exactly as the flat cache
    /// stores them.
    pub fn append(
        &mut self,
        seq: PoolSeqId,
        k_row: &[f32],
        v_row: &[f32],
    ) -> Result<(), PoolExhausted> {
        let (bs, d) = (self.cfg.block_size, self.cfg.head_dim);
        assert_eq!(k_row.len(), d, "k_row must be head_dim floats");
        assert_eq!(v_row.len(), d, "v_row must be head_dim floats");
        let pos = self.table_ref(seq).len;
        let bi = pos / bs;
        if bi == self.table_ref(seq).blocks.len() {
            let b = self.alloc.alloc()?;
            self.ensure_block(b);
            self.touch_write(b);
            self.tables[seq].as_mut().expect("freed sequence").blocks.push(b);
        } else {
            let b = self.table_ref(seq).blocks[bi];
            if self.alloc.ref_count(b) > 1 {
                let fresh = self.cow_block(b)?;
                self.tables[seq].as_mut().expect("freed sequence").blocks[bi] = fresh;
            }
        }
        let b = self.table_ref(seq).blocks[bi] as usize;
        let off = pos % bs;
        let hot = (b * bs + off) * self.cfg.d_hot;
        self.hot_k[hot..hot + self.cfg.d_hot].copy_from_slice(&k_row[..self.cfg.d_hot]);
        let cold = (b * bs + off) * d;
        self.cold_k[cold..cold + d].copy_from_slice(k_row);
        self.cold_v[cold..cold + d].copy_from_slice(v_row);
        self.touch_write(b as BlockId);
        self.tables[seq].as_mut().expect("freed sequence").len = pos + 1;
        Ok(())
    }

    /// Bulk-load a prefill prefix: `k`/`v` are `[len, head_dim]` row-major.
    pub fn load_prefix(
        &mut self,
        seq: PoolSeqId,
        k: &[f32],
        v: &[f32],
        len: usize,
    ) -> Result<(), PoolExhausted> {
        let d = self.cfg.head_dim;
        assert_eq!(k.len(), len * d);
        assert_eq!(v.len(), len * d);
        for j in 0..len {
            self.append(seq, &k[j * d..(j + 1) * d], &v[j * d..(j + 1) * d])?;
        }
        Ok(())
    }

    /// Fork a sequence copy-on-write: the child shares *every* block of
    /// the parent (refcount++), including a partial tail — the first
    /// divergent append copies that tail block. Never allocates.
    pub fn fork(&mut self, parent: PoolSeqId) -> PoolSeqId {
        let t = self.table_ref(parent).clone();
        for &b in &t.blocks {
            self.alloc.retain(b);
        }
        self.tables.push(Some(t));
        self.tables.len() - 1
    }

    /// Truncate a sequence to `len` tokens, releasing whole blocks past
    /// the new tail. This is preemption-to-prefix for the data plane:
    /// keep the (typically shared) prefix resident and recompute the
    /// evicted tail on resume — cheap under Loki, where the hot tier's
    /// rotated keys K̂ are re-projected, not re-attended. The kept
    /// partial tail block remains subject to normal copy-on-write on the
    /// next append, and re-appending the evicted rows restores the cache
    /// bit-identically (see `tests/kvpool_properties.rs`).
    pub fn truncate(&mut self, seq: PoolSeqId, len: usize) {
        let bs = self.cfg.block_size;
        let dropped: Vec<BlockId> = {
            let t = self.tables[seq].as_mut().expect("freed sequence");
            assert!(len <= t.len, "truncate can only shrink ({len} > {})", t.len);
            let keep = len.div_ceil(bs);
            t.len = len;
            t.blocks.drain(keep..).collect()
        };
        for b in dropped {
            if self.alloc.release(b) && self.resident[b as usize] {
                self.resident[b as usize] = false;
                self.resident_count -= 1;
            }
        }
    }

    /// Data-plane twin of the coordinator's partial preemption
    /// (`TableSet::truncate_tail`): release whole blocks from the tail
    /// until `need_free` have physically returned to the free list
    /// (shared blocks only drop a reference), keeping the prefix — hot
    /// low-rank rows included — resident for the resume. Returns the new
    /// live length; re-appending the evicted rows restores both tiers
    /// bit-identically (see [`TieredKvPool::truncate`]).
    pub fn truncate_tail_blocks(&mut self, seq: PoolSeqId, need_free: usize) -> usize {
        let bs = self.cfg.block_size;
        let need_free = need_free.max(1);
        let mut freed = 0usize;
        while freed < need_free {
            let b = {
                let t = self.tables[seq].as_mut().expect("freed sequence");
                match t.blocks.pop() {
                    Some(b) => b,
                    None => break,
                }
            };
            if self.alloc.release(b) {
                freed += 1;
                if self.resident[b as usize] {
                    self.resident[b as usize] = false;
                    self.resident_count -= 1;
                }
            }
        }
        let t = self.tables[seq].as_mut().expect("freed sequence");
        t.len = t.len.min(t.blocks.len() * bs);
        t.len
    }

    pub fn free_seq(&mut self, seq: PoolSeqId) {
        let t = self.tables[seq].take().expect("double free of sequence");
        for b in t.blocks {
            if self.alloc.release(b) && self.resident[b as usize] {
                self.resident[b as usize] = false;
                self.resident_count -= 1;
            }
        }
    }

    /// Hot-tier arena (`width = d_hot`) — Loki's ranking reads.
    pub fn hot_view(&self) -> PagedArena<'_> {
        PagedArena { data: &self.hot_k, block_size: self.cfg.block_size, width: self.cfg.d_hot }
    }

    /// Cold full-D key arena.
    pub fn cold_k_view(&self) -> PagedArena<'_> {
        PagedArena { data: &self.cold_k, block_size: self.cfg.block_size, width: self.cfg.head_dim }
    }

    /// Cold full-D value arena.
    pub fn cold_v_view(&self) -> PagedArena<'_> {
        PagedArena { data: &self.cold_v, block_size: self.cfg.block_size, width: self.cfg.head_dim }
    }

    /// Record one score pass answered from the hot tier.
    pub fn account_hot_pass(&mut self) {
        self.tier_stats.hot_hits += 1;
    }

    /// Model the cold-tier gather for the selected slots of a sequence:
    /// pages not resident fault in (counted, byte-tallied) and may demote
    /// the least-recently-used resident page beyond the budget.
    pub fn account_gather(&mut self, seq: PoolSeqId, slots: &[u32]) {
        let bs = self.cfg.block_size;
        let page_bytes = (2 * bs * self.cfg.head_dim * 4) as u64; // K + V
        let mut touched: Vec<BlockId> = slots
            .iter()
            .map(|&j| self.table_ref(seq).blocks[j as usize / bs])
            .collect();
        touched.sort_unstable();
        touched.dedup();
        let mut faulted_pages = 0u32;
        for b in touched {
            let bi = b as usize;
            if self.resident[bi] {
                self.tier_stats.gather_hits += 1;
            } else {
                self.resident[bi] = true;
                self.resident_count += 1;
                self.tier_stats.gather_faults += 1;
                self.tier_stats.bytes_faulted += page_bytes;
                faulted_pages += 1;
            }
            self.tick += 1;
            self.last_touch[bi] = self.tick;
        }
        if faulted_pages > 0 {
            self.events.push(PoolEvent::Fault {
                seq: seq as u64,
                pages: faulted_pages,
                bytes: faulted_pages as u64 * page_bytes,
            });
        }
        self.enforce_budget();
    }

    /// Bytes a two-tier backend would keep hot right now: the full hot
    /// tier for every in-use block, plus the resident cold pages.
    pub fn resident_kv_bytes(&self) -> u64 {
        let bs = self.cfg.block_size;
        let hot = (self.alloc.blocks_in_use() * bs * self.cfg.d_hot * 4) as u64;
        let cold_blocks = if self.cfg.cold_resident_blocks == 0 {
            self.alloc.blocks_in_use()
        } else {
            self.resident_count
        };
        hot + (cold_blocks * 2 * bs * self.cfg.head_dim * 4) as u64
    }

    /// What a flat `[seqs, max_len, D]` K+V cache would hold for the same
    /// sequences (the `lane_reset_frac`-era baseline this pool replaces).
    pub fn flat_equivalent_bytes(&self, max_len: usize) -> u64 {
        let live = self.tables.iter().filter(|t| t.is_some()).count();
        (live * max_len * self.cfg.head_dim * 2 * 4) as u64
    }

    pub fn check_invariants(&self) {
        self.alloc.check_invariants();
        let resident = self.resident.iter().filter(|&&r| r).count();
        assert_eq!(resident, self.resident_count, "resident count drift");
        for t in self.tables.iter().flatten() {
            assert!(t.len <= t.blocks.len() * self.cfg.block_size, "table len beyond blocks");
            for &b in &t.blocks {
                assert!(self.alloc.ref_count(b) > 0, "table references freed block {b}");
            }
        }
        if self.cfg.cold_resident_blocks > 0 {
            assert!(
                self.resident_count <= self.cfg.cold_resident_blocks,
                "LRU budget exceeded: {} > {}",
                self.resident_count,
                self.cfg.cold_resident_blocks
            );
        }
    }

    fn ensure_block(&mut self, b: BlockId) {
        let bs = self.cfg.block_size;
        let need_hot = (b as usize + 1) * bs * self.cfg.d_hot;
        if self.hot_k.len() < need_hot {
            self.hot_k.resize(need_hot, 0.0);
        }
        let need_cold = (b as usize + 1) * bs * self.cfg.head_dim;
        if self.cold_k.len() < need_cold {
            self.cold_k.resize(need_cold, 0.0);
            self.cold_v.resize(need_cold, 0.0);
        }
    }

    /// Appends write the cold tier directly (a serving backend appends
    /// into whatever tier holds the write head): mark resident, no fault.
    fn touch_write(&mut self, b: BlockId) {
        let bi = b as usize;
        if !self.resident[bi] {
            self.resident[bi] = true;
            self.resident_count += 1;
        }
        self.tick += 1;
        self.last_touch[bi] = self.tick;
        self.enforce_budget();
    }

    fn enforce_budget(&mut self) {
        let budget = self.cfg.cold_resident_blocks;
        if budget == 0 {
            return;
        }
        let mut demoted = 0u32;
        while self.resident_count > budget {
            let victim = self
                .resident
                .iter()
                .enumerate()
                .filter(|(_, &r)| r)
                .min_by_key(|&(i, _)| self.last_touch[i])
                .map(|(i, _)| i)
                .expect("resident_count > 0");
            self.resident[victim] = false;
            self.resident_count -= 1;
            self.tier_stats.demotions += 1;
            demoted += 1;
        }
        if demoted > 0 {
            self.events.push(PoolEvent::Demotion { pages: demoted });
        }
    }

    /// Copy a shared block (hot + cold arenas) into a fresh private one.
    fn cow_block(&mut self, b: BlockId) -> Result<BlockId, PoolExhausted> {
        let fresh = self.alloc.alloc()?;
        self.ensure_block(fresh);
        let bs = self.cfg.block_size;
        let (src, dst) = (b as usize, fresh as usize);
        let hw = bs * self.cfg.d_hot;
        self.hot_k.copy_within(src * hw..(src + 1) * hw, dst * hw);
        let cw = bs * self.cfg.head_dim;
        self.cold_k.copy_within(src * cw..(src + 1) * cw, dst * cw);
        self.cold_v.copy_within(src * cw..(src + 1) * cw, dst * cw);
        self.alloc.release(b);
        self.alloc.stats.cow_copies += 1;
        self.touch_write(fresh);
        Ok(fresh)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Xoshiro256;

    fn pool(num_blocks: usize, bs: usize, d: usize, d_hot: usize) -> TieredKvPool {
        TieredKvPool::new(TieredPoolCfg {
            num_blocks,
            block_size: bs,
            head_dim: d,
            d_hot,
            cold_resident_blocks: 0,
        })
    }

    #[test]
    fn append_and_read_back_both_tiers() {
        let mut p = pool(8, 4, 8, 2);
        let s = p.new_seq();
        let mut rng = Xoshiro256::new(11);
        let mut ks = Vec::new();
        for _ in 0..10 {
            let k = rng.normal_vec(8);
            let v = rng.normal_vec(8);
            p.append(s, &k, &v).unwrap();
            ks.push(k);
        }
        assert_eq!(p.len(s), 10);
        assert_eq!(p.blocks(s).len(), 3);
        let hot = p.hot_view();
        let cold = p.cold_k_view();
        let table = p.blocks(s);
        for (j, k) in ks.iter().enumerate() {
            assert_eq!(hot.row(table, j), &k[..2], "hot row {j}");
            assert_eq!(cold.row(table, j), &k[..], "cold row {j}");
        }
        p.check_invariants();
    }

    #[test]
    fn fork_shares_then_cow_on_divergence() {
        let mut p = pool(8, 4, 4, 2);
        let parent = p.new_seq();
        let mut rng = Xoshiro256::new(5);
        for _ in 0..6 {
            let r = rng.normal_vec(4);
            p.append(parent, &r, &r).unwrap();
        }
        let child = p.fork(parent);
        assert_eq!(p.blocks(parent), p.blocks(child));
        assert_eq!(p.allocator().blocks_in_use(), 2, "fork allocates nothing");

        // Parent's view before divergence.
        let before: Vec<f32> =
            (0..6).map(|j| p.cold_k_view().row(p.blocks(parent), j)[0]).collect();
        let k = rng.normal_vec(4);
        p.append(child, &k, &k).unwrap();
        // Tail block (positions 4..) was copied for the child; full block
        // stays shared.
        assert_eq!(p.blocks(parent)[0], p.blocks(child)[0]);
        assert_ne!(p.blocks(parent)[1], p.blocks(child)[1]);
        assert_eq!(p.allocator().stats.cow_copies, 1);
        let after: Vec<f32> = (0..6).map(|j| p.cold_k_view().row(p.blocks(parent), j)[0]).collect();
        assert_eq!(before, after, "parent unchanged by child append");
        // The child sees the shared prefix plus its own token.
        assert_eq!(p.cold_k_view().row(p.blocks(child), 6), &k[..]);
        assert_eq!(
            p.cold_k_view().row(p.blocks(child), 3),
            p.cold_k_view().row(p.blocks(parent), 3)
        );
        p.free_seq(parent);
        p.free_seq(child);
        assert_eq!(p.allocator().blocks_in_use(), 0);
        p.check_invariants();
    }

    #[test]
    fn lru_budget_demotes_cold_pages() {
        let mut p = TieredKvPool::new(TieredPoolCfg {
            num_blocks: 8,
            block_size: 2,
            head_dim: 4,
            d_hot: 2,
            cold_resident_blocks: 2,
        });
        let s = p.new_seq();
        let row = vec![1.0f32; 4];
        for _ in 0..8 {
            p.append(s, &row, &row).unwrap();
        }
        // 4 blocks written through a residency budget of 2.
        assert!(p.tier_stats.demotions >= 2);
        p.check_invariants();
        // Gathering an old (demoted) slot faults its page back in.
        let faults = p.tier_stats.gather_faults;
        p.account_gather(s, &[0]);
        assert_eq!(p.tier_stats.gather_faults, faults + 1);
        p.check_invariants();
    }

    #[test]
    fn resident_bytes_shrink_with_sharing() {
        let d = 8;
        let mut p = pool(64, 4, d, 2);
        let parent = p.new_seq();
        let row = vec![0.5f32; d];
        for _ in 0..32 {
            p.append(parent, &row, &row).unwrap();
        }
        let solo = p.resident_kv_bytes();
        for _ in 0..7 {
            p.fork(parent);
        }
        // 8 sequences, one copy of the data.
        assert_eq!(p.resident_kv_bytes(), solo);
        assert!(p.flat_equivalent_bytes(32) >= 8 * solo / 2, "flat baseline scales with seqs");
        p.check_invariants();
    }

    #[test]
    fn truncate_releases_tail_blocks_and_reappend_is_bit_identical() {
        let mut p = pool(16, 4, 8, 2);
        let s = p.new_seq();
        let mut rng = Xoshiro256::new(23);
        let rows: Vec<(Vec<f32>, Vec<f32>)> =
            (0..11).map(|_| (rng.normal_vec(8), rng.normal_vec(8))).collect();
        for (k, v) in &rows {
            p.append(s, k, v).unwrap();
        }
        assert_eq!(p.blocks(s).len(), 3);
        // Evict everything past token 6: the position-7..11 blocks go home.
        p.truncate(s, 6);
        assert_eq!(p.len(s), 6);
        assert_eq!(p.blocks(s).len(), 2, "only whole tail blocks are released");
        p.check_invariants();
        // Recompute-on-restore: re-appending the same rows restores every
        // row of both tiers bit-identically (== on f32, no tolerance).
        for (k, v) in &rows[6..] {
            p.append(s, k, v).unwrap();
        }
        for (j, (k, v)) in rows.iter().enumerate() {
            assert_eq!(p.hot_view().row(p.blocks(s), j), &k[..2], "hot row {j}");
            assert_eq!(p.cold_k_view().row(p.blocks(s), j), &k[..], "cold k row {j}");
            assert_eq!(p.cold_v_view().row(p.blocks(s), j), &v[..], "cold v row {j}");
        }
        p.free_seq(s);
        assert_eq!(p.allocator().blocks_in_use(), 0);
        p.check_invariants();
    }

    #[test]
    fn truncate_tail_blocks_frees_the_minimum_and_resume_is_bit_identical() {
        let mut p = pool(16, 4, 8, 2);
        let s = p.new_seq();
        let mut rng = Xoshiro256::new(31);
        let rows: Vec<(Vec<f32>, Vec<f32>)> =
            (0..14).map(|_| (rng.normal_vec(8), rng.normal_vec(8))).collect();
        for (k, v) in &rows {
            p.append(s, k, v).unwrap();
        }
        assert_eq!(p.blocks(s).len(), 4);
        let free_before = p.allocator().num_free();
        // Need 2 blocks back: exactly the two tail blocks go, the first
        // two (8 tokens of prefix) stay hot-resident for the resume.
        let kept = p.truncate_tail_blocks(s, 2);
        assert_eq!(kept, 8);
        assert_eq!(p.blocks(s).len(), 2);
        assert_eq!(p.allocator().num_free(), free_before + 2);
        p.check_invariants();
        // Partial-preemption resume: recompute only rows 8.. — every row
        // of both tiers must match the uninterrupted cache bit-for-bit.
        for (k, v) in &rows[kept..] {
            p.append(s, k, v).unwrap();
        }
        for (j, (k, v)) in rows.iter().enumerate() {
            assert_eq!(p.hot_view().row(p.blocks(s), j), &k[..2], "hot row {j}");
            assert_eq!(p.cold_k_view().row(p.blocks(s), j), &k[..], "cold k row {j}");
            assert_eq!(p.cold_v_view().row(p.blocks(s), j), &v[..], "cold v row {j}");
        }
        p.free_seq(s);
        assert_eq!(p.allocator().blocks_in_use(), 0);
        p.check_invariants();
    }

    #[test]
    fn truncate_tail_blocks_spares_shared_blocks_for_the_survivor() {
        let mut p = pool(16, 4, 4, 2);
        let parent = p.new_seq();
        let row = vec![1.0f32; 4];
        for _ in 0..8 {
            p.append(parent, &row, &row).unwrap();
        }
        let child = p.fork(parent); // shares both blocks
        // The child's blocks are all shared: walking its tail frees
        // nothing, refcounts drop, the parent's rows stay intact.
        let kept = p.truncate_tail_blocks(child, 1);
        assert_eq!(kept, 0, "fully-shared tail yields no free blocks");
        assert_eq!(p.allocator().blocks_in_use(), 2);
        assert_eq!(p.len(parent), 8);
        p.free_seq(parent);
        p.free_seq(child);
        assert_eq!(p.allocator().blocks_in_use(), 0);
        p.check_invariants();
    }

    #[test]
    fn gather_faults_and_demotions_emit_events() {
        let mut p = TieredKvPool::new(TieredPoolCfg {
            num_blocks: 8,
            block_size: 2,
            head_dim: 4,
            d_hot: 2,
            cold_resident_blocks: 2,
        });
        let s = p.new_seq();
        let row = vec![1.0f32; 4];
        for _ in 0..8 {
            p.append(s, &row, &row).unwrap();
        }
        // Write-through past the budget demoted pages along the way.
        assert!(p.events.drain().any(|e| matches!(e, PoolEvent::Demotion { .. })));
        // Gathering a demoted slot emits one aggregated fault event.
        p.account_gather(s, &[0]);
        let evs: Vec<_> = p.events.drain().collect();
        let fault = evs
            .iter()
            .find(|e| matches!(e, PoolEvent::Fault { .. }))
            .expect("gather of a cold page must emit a fault event");
        let PoolEvent::Fault { seq, pages, bytes } = *fault else { unreachable!() };
        assert_eq!(seq, s as u64);
        assert_eq!(pages, 1);
        assert_eq!(bytes, 2 * 2 * 4 * 4); // K+V · block_size · head_dim · f32
        // A resident re-gather emits nothing.
        p.account_gather(s, &[0]);
        assert!(p.events.is_empty());
    }

    #[test]
    fn exhaustion_surfaces_as_error() {
        let mut p = pool(1, 2, 4, 2);
        let s = p.new_seq();
        let row = vec![0.0f32; 4];
        p.append(s, &row, &row).unwrap();
        p.append(s, &row, &row).unwrap();
        assert!(p.append(s, &row, &row).is_err(), "third token needs a second block");
    }
}
