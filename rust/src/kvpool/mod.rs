//! Paged KV-pool subsystem: the memory substrate under the serving engine.
//!
//! Replaces the flat preallocated `[lanes, max_len, D]` discipline (and
//! the coordinator's old `lane_reset_frac` hygiene hack) with vLLM-style
//! paged allocation:
//!
//! * [`block`] — ref-counted block allocator with a LIFO free list; the
//!   unit of admission control and sharing.
//! * [`table`] — per-sequence block tables plus content-addressed prefix
//!   sharing (chain-hashed full prompt blocks, copy-on-write tails). The
//!   coordinator uses a [`TableSet`] to mirror the device cache and admit
//!   a request only when its blocks can actually be granted.
//! * [`radix`] — the refcounted radix tree the tables share through:
//!   nodes keyed by [`chain_hash`], parent = one-block-shorter prefix,
//!   leaves = live sequences. The single prefix-sharing structure —
//!   admission, the engine's mirror and the router's affinity view all
//!   resolve against it.
//! * [`tiered`] — the data plane: hot low-rank K̂ tier (always resident,
//!   Loki ranks here) + cold full-KV tier with LRU page residency; the
//!   paged attention kernels in [`crate::attnsim`] read it through
//!   [`PagedArena`] views.
//! * [`stats`] — occupancy / eviction / sharing counters.
//!
//! The design target is the paper's serving story at scale: admission
//! backpressure instead of silent lane resets, shared system prompts paid
//! for once, and Loki's d_f·D ranking tier small enough to pin hot while
//! full-D pages page in on demand (cf. Double Sparsity, Yang et al.).

pub mod block;
pub mod radix;
pub mod stats;
pub mod table;
pub mod tiered;

pub use block::{BlockAllocator, BlockId, PoolExhausted};
pub use radix::{RadixNode, RadixTree};
pub use stats::{PoolStats, TierStats};
pub use table::{chain_hash, prefix_block_hashes, BlockTable, SeqId, TableSet, TruncateOutcome};
pub use tiered::{PagedArena, PoolSeqId, TieredKvPool, TieredPoolCfg};
