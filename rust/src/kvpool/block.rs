//! Ref-counted KV block allocator with a LIFO free list.
//!
//! The pool's unit of accounting is a *block* of `block_size` token slots
//! (vLLM calls these pages). Blocks are reference counted so sequences can
//! share a common prefix: `alloc` hands out a block at refcount 1,
//! `retain` adds a sharer, `release` drops one and returns the block to
//! the free list when the count reaches zero. The allocator never touches
//! the actual KV bytes — storage (flat arena, tiered store, or the
//! device-resident cache the coordinator mirrors) is the caller's concern.

use super::stats::PoolStats;

pub type BlockId = u32;

/// Error returned when the free list cannot grant a request.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct PoolExhausted {
    pub requested: usize,
    pub free: usize,
}

impl std::fmt::Display for PoolExhausted {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "kv pool exhausted: requested {} blocks, {} free", self.requested, self.free)
    }
}

impl std::error::Error for PoolExhausted {}

pub struct BlockAllocator {
    block_size: usize,
    /// Per-block sharer count; 0 means the block is on the free list.
    refcount: Vec<u32>,
    /// LIFO free list (recently freed blocks are re-used first — they are
    /// the ones most likely still warm in whatever tier backs them).
    free: Vec<BlockId>,
    pub stats: PoolStats,
}

impl BlockAllocator {
    pub fn new(num_blocks: usize, block_size: usize) -> Self {
        assert!(block_size > 0, "block_size must be positive");
        // Reverse order so the first allocations come out 0, 1, 2, …
        let free: Vec<BlockId> = (0..num_blocks as BlockId).rev().collect();
        Self { block_size, refcount: vec![0; num_blocks], free, stats: PoolStats::default() }
    }

    pub fn block_size(&self) -> usize {
        self.block_size
    }

    pub fn num_blocks(&self) -> usize {
        self.refcount.len()
    }

    pub fn num_free(&self) -> usize {
        self.free.len()
    }

    pub fn blocks_in_use(&self) -> usize {
        self.refcount.len() - self.free.len()
    }

    /// Number of token slots a sequence of `len` tokens occupies.
    pub fn blocks_for(&self, len: usize) -> usize {
        len.div_ceil(self.block_size)
    }

    pub fn can_grant(&self, n: usize) -> bool {
        n <= self.free.len()
    }

    pub fn ref_count(&self, b: BlockId) -> u32 {
        self.refcount[b as usize]
    }

    /// Take one block off the free list (refcount 0 → 1).
    pub fn alloc(&mut self) -> Result<BlockId, PoolExhausted> {
        match self.free.pop() {
            Some(b) => {
                debug_assert_eq!(self.refcount[b as usize], 0, "free-listed block has refs");
                self.refcount[b as usize] = 1;
                self.stats.allocs += 1;
                self.stats.note_in_use(self.blocks_in_use());
                Ok(b)
            }
            None => {
                self.stats.failed_allocs += 1;
                Err(PoolExhausted { requested: 1, free: 0 })
            }
        }
    }

    /// All-or-nothing batch allocation (admission control wants atomicity:
    /// a sequence either gets every block it reserved or none).
    pub fn alloc_many(&mut self, n: usize) -> Result<Vec<BlockId>, PoolExhausted> {
        if !self.can_grant(n) {
            self.stats.failed_allocs += 1;
            return Err(PoolExhausted { requested: n, free: self.free.len() });
        }
        Ok((0..n).map(|_| self.alloc().expect("can_grant checked")).collect())
    }

    /// Add a sharer to a live block (prefix sharing / sequence fork).
    pub fn retain(&mut self, b: BlockId) {
        let rc = &mut self.refcount[b as usize];
        assert!(*rc > 0, "retain of free block {b}");
        *rc += 1;
        self.stats.forks += 1;
    }

    /// Drop one sharer. Returns `true` when the block went back to the
    /// free list (last reference). Panics on refcount underflow — a
    /// double-free is a caller bug, not a recoverable condition.
    pub fn release(&mut self, b: BlockId) -> bool {
        let rc = &mut self.refcount[b as usize];
        assert!(*rc > 0, "double free of block {b}");
        *rc -= 1;
        if *rc == 0 {
            self.free.push(b);
            self.stats.frees += 1;
            true
        } else {
            false
        }
    }

    /// Structural invariants, used by the property tests: every block is
    /// either on the free list (refcount 0) or referenced (refcount > 0),
    /// and the free list holds no duplicates.
    pub fn check_invariants(&self) {
        let mut on_free = vec![false; self.refcount.len()];
        for &b in &self.free {
            assert!(!on_free[b as usize], "block {b} on free list twice");
            on_free[b as usize] = true;
            assert_eq!(self.refcount[b as usize], 0, "free block {b} has refs");
        }
        let live = self.refcount.iter().filter(|&&rc| rc > 0).count();
        assert_eq!(
            live + self.free.len(),
            self.refcount.len(),
            "block leak: {} live + {} free != {}",
            live,
            self.free.len(),
            self.refcount.len()
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn alloc_release_roundtrip() {
        let mut a = BlockAllocator::new(4, 16);
        assert_eq!(a.num_free(), 4);
        let b0 = a.alloc().unwrap();
        let b1 = a.alloc().unwrap();
        assert_eq!((b0, b1), (0, 1));
        assert_eq!(a.blocks_in_use(), 2);
        assert!(a.release(b0));
        assert_eq!(a.num_free(), 3);
        // LIFO: the freed block comes back first.
        assert_eq!(a.alloc().unwrap(), b0);
        a.check_invariants();
    }

    #[test]
    fn retain_defers_free() {
        let mut a = BlockAllocator::new(2, 8);
        let b = a.alloc().unwrap();
        a.retain(b);
        assert_eq!(a.ref_count(b), 2);
        assert!(!a.release(b));
        assert!(a.release(b));
        assert_eq!(a.num_free(), 2);
        a.check_invariants();
    }

    #[test]
    fn exhaustion_is_reported_not_fatal() {
        let mut a = BlockAllocator::new(1, 8);
        let _b = a.alloc().unwrap();
        let err = a.alloc().unwrap_err();
        assert_eq!(err.free, 0);
        assert!(a.alloc_many(1).is_err());
        assert_eq!(a.stats.failed_allocs, 2);
    }

    #[test]
    #[should_panic(expected = "double free")]
    fn double_free_panics() {
        let mut a = BlockAllocator::new(2, 8);
        let b = a.alloc().unwrap();
        a.release(b);
        a.release(b);
    }

    #[test]
    fn alloc_many_is_atomic() {
        let mut a = BlockAllocator::new(3, 8);
        assert!(a.alloc_many(4).is_err());
        assert_eq!(a.num_free(), 3, "failed batch must not leak partial grants");
        let got = a.alloc_many(3).unwrap();
        assert_eq!(got.len(), 3);
        a.check_invariants();
    }
}
