//! Per-sequence block tables with copy-on-write prefix sharing.
//!
//! A [`BlockTable`] maps a sequence's token positions onto allocator
//! blocks. [`TableSet`] manages one table per live sequence plus the
//! content-addressed [`super::RadixTree`]: every *full* block of prompt
//! tokens is a tree node keyed by the chain hash of all tokens up to and
//! including that block (its parent is the one-block-shorter prefix), so
//! two requests with the same prompt prefix resolve to the same blocks
//! (refcount++) instead of fresh allocations — vLLM-style automatic
//! prefix caching, no request-side grouping API required. Tail blocks
//! (partial prompt block + generated tokens) are always private, which is
//! what makes the sharing copy-on-write: divergence after the common
//! prefix lands in per-sequence blocks (a fork's tail is a child branch).
//! When a tree node's block drains its last reference the tables emit
//! [`PoolEvent::PrefixReleased`] so downstream mirrors (the router's
//! per-replica affinity view) drop the dead entry.
//!
//! `TableSet` is pure bookkeeping over token ids — the coordinator uses it
//! to mirror the device cache for admission control. The data-plane
//! sibling (which owns actual KV bytes) is [`super::TieredKvPool`].

use std::collections::{HashMap, HashSet};

use crate::obs::{PoolEvent, PoolEventLog};

use super::block::{BlockAllocator, BlockId, PoolExhausted};
use super::radix::RadixTree;

pub type SeqId = u64;

/// One sequence's view of the pool: `blocks[i]` backs token positions
/// `[i·bs, (i+1)·bs)`; `len` tokens are live.
#[derive(Clone, Debug, Default)]
pub struct BlockTable {
    pub blocks: Vec<BlockId>,
    pub len: usize,
}

/// What a [`TableSet::truncate_tail`] actually did: blocks physically
/// returned to the free list vs the prefix the sequence kept.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct TruncateOutcome {
    /// Blocks returned to the allocator's free list (refcount hit zero).
    pub freed: usize,
    /// Blocks the sequence still holds.
    pub kept_blocks: usize,
    /// Token positions still covered by the kept blocks.
    pub kept_len: usize,
}

/// Position-dependent content hash: identifies "these exact tokens as a
/// prefix", not "this bag of tokens" — extending a chain with the next
/// block's tokens yields the next key.
pub fn chain_hash(prev: u64, tokens: &[i32]) -> u64 {
    let mut h = prev ^ 0x9E37_79B9_7F4A_7C15;
    for &t in tokens {
        h ^= (t as u32 as u64).wrapping_mul(0x0100_0000_01B3);
        h = h.rotate_left(27).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    }
    h
}

/// The chained content hashes of a prompt's *full* blocks — hash `i`
/// covers `prompt[..(i+1)·block_size]`, exactly the keys
/// [`TableSet::admit`] registers in its prefix map. This is the
/// content-addressing surface the multi-replica router keys affinity on:
/// two prompts share a resident prefix iff their leading hashes agree,
/// so a router that mirrors routed hashes per replica can compute block
/// overlap without touching any engine-owned `TableSet`.
pub fn prefix_block_hashes(prompt: &[i32], block_size: usize) -> Vec<u64> {
    let bs = block_size.max(1);
    let mut chain = 0u64;
    let mut hashes = Vec::with_capacity(prompt.len() / bs);
    for i in 0..prompt.len() / bs {
        chain = chain_hash(chain, &prompt[i * bs..(i + 1) * bs]);
        hashes.push(chain);
    }
    hashes
}

pub struct TableSet {
    block_size: usize,
    sharing: bool,
    // lint:allow(nondet-iter): keyed access only (by SeqId), never iterated
    tables: HashMap<SeqId, BlockTable>,
    next: SeqId,
    /// The one prefix-sharing structure: chain hash → node → block,
    /// with parent/child links for the conversation-tree queries. The
    /// old flat `prefix_map`/`block_hash` pair delegated here and was
    /// removed.
    tree: RadixTree,
    /// Live blocks holding at least one written token slot (maintained
    /// incrementally on admit/advance/fork and pruned on physical free,
    /// so the per-decode-iteration occupancy snapshot is O(1)).
    // lint:allow(nondet-iter): membership checks + counted len only; occupancy snapshot never iterates
    written: HashSet<BlockId>,
    /// Blocks obtained by sharing instead of allocation (the savings).
    pub shared_hits: u64,
    /// Bounded trace side-channel: lifecycle events pushed here are
    /// drained by the engine into the flight recorder each round (the
    /// tables have no clock, so the engine stamps timestamps).
    pub events: PoolEventLog,
}

impl TableSet {
    pub fn new(block_size: usize, sharing: bool) -> Self {
        assert!(block_size > 0);
        Self {
            block_size,
            sharing,
            tables: HashMap::new(),
            next: 1,
            tree: RadixTree::new(),
            written: HashSet::new(),
            shared_hits: 0,
            events: PoolEventLog::default(),
        }
    }

    pub fn block_size(&self) -> usize {
        self.block_size
    }

    pub fn sharing_enabled(&self) -> bool {
        self.sharing
    }

    pub fn live_seqs(&self) -> usize {
        self.tables.len()
    }

    pub fn table(&self, seq: SeqId) -> Option<&BlockTable> {
        self.tables.get(&seq)
    }

    /// Admit a sequence: reserve blocks for `reserve_total` token slots
    /// (prompt now + decode growth later), sharing full prompt blocks by
    /// content. All-or-nothing: on exhaustion every acquired block is
    /// rolled back and the pool is untouched.
    pub fn admit(
        &mut self,
        alloc: &mut BlockAllocator,
        prompt: &[i32],
        reserve_total: usize,
    ) -> Result<SeqId, PoolExhausted> {
        assert_eq!(self.block_size, alloc.block_size(), "table/allocator block size mismatch");
        let bs = self.block_size;
        let reserve_total = reserve_total.max(prompt.len()).max(1);
        let total_blocks = reserve_total.div_ceil(bs);
        let full = prompt.len() / bs; // shareable full prompt blocks

        let mut blocks: Vec<BlockId> = Vec::with_capacity(total_blocks);
        let mut shared_now = 0u32;
        let mut chain = 0u64;
        let mut parent: Option<u64> = None;
        for i in 0..full {
            chain = chain_hash(chain, &prompt[i * bs..(i + 1) * bs]);
            let shared = if self.sharing { self.tree.lookup(chain) } else { None };
            match shared {
                Some(b) => {
                    alloc.retain(b);
                    self.shared_hits += 1;
                    shared_now += 1;
                    blocks.push(b);
                }
                None => match alloc.alloc() {
                    Ok(b) => {
                        if self.sharing {
                            self.tree.insert(chain, parent, b);
                        }
                        blocks.push(b);
                    }
                    Err(e) => {
                        self.rollback(alloc, &blocks);
                        return Err(e);
                    }
                },
            }
            parent = Some(chain);
        }
        // Private tail: partial prompt block + reserved decode headroom.
        for _ in full..total_blocks {
            match alloc.alloc() {
                Ok(b) => blocks.push(b),
                Err(e) => {
                    self.rollback(alloc, &blocks);
                    return Err(e);
                }
            }
        }
        // Prompt slots are written at admission; the reserved decode tail
        // is not (written-block accounting is what speculative admission
        // optimizes, so the distinction matters).
        let prompt_blocks = prompt.len().div_ceil(bs).min(blocks.len());
        for &b in &blocks[..prompt_blocks] {
            self.written.insert(b);
        }
        let id = self.next;
        self.next += 1;
        self.events.push(PoolEvent::Alloc {
            seq: id,
            blocks: blocks.len() as u32,
            shared: shared_now,
        });
        self.tables.insert(id, BlockTable { blocks, len: prompt.len() });
        Ok(id)
    }

    /// True when the next `advance` would step past the sequence's
    /// currently granted blocks. Under `ReserveFull` admission this never
    /// fires (the reservation covers the whole decode budget); under
    /// speculative admission it is the signal to [`TableSet::grow`].
    pub fn needs_grow(&self, seq: SeqId) -> bool {
        let t = self.tables.get(&seq).expect("needs_grow of unknown seq");
        t.len >= t.blocks.len() * self.block_size
    }

    /// Extend a live sequence's reservation by up to `want` blocks (at
    /// least one attempted). Partial grants are fine — the caller asked
    /// for headroom, not a budget — but a zero grant is an error: the
    /// pool had nothing free and the caller must evict or preempt.
    pub fn grow(
        &mut self,
        alloc: &mut BlockAllocator,
        seq: SeqId,
        want: usize,
    ) -> Result<usize, PoolExhausted> {
        let want = want.max(1);
        let t = self.tables.get_mut(&seq).expect("grow of unknown seq");
        let mut granted = 0usize;
        while granted < want {
            match alloc.alloc() {
                Ok(b) => {
                    t.blocks.push(b);
                    granted += 1;
                }
                Err(e) => {
                    if granted == 0 {
                        return Err(e);
                    }
                    break;
                }
            }
        }
        alloc.stats.grown_blocks += granted as u64;
        self.events.push(PoolEvent::Grow { seq, blocks: granted as u32 });
        Ok(granted)
    }

    /// Blocks of a sequence held by no other table (refcount 1). These
    /// are what preempting the sequence would actually return to the free
    /// list — shared prefix blocks only drop a reference.
    pub fn private_blocks(&self, alloc: &BlockAllocator, seq: SeqId) -> usize {
        let t = self.tables.get(&seq).expect("private_blocks of unknown seq");
        t.blocks.iter().filter(|&&b| alloc.ref_count(b) == 1).count()
    }

    /// Live blocks holding written token slots, counting each physical
    /// block once (a prefix block shared by N sequences is one block of
    /// real KV). The utilization numerator: blocks reserved but not yet
    /// decoded into do not count. O(1) — the engine reads this every
    /// decode iteration.
    pub fn written_blocks(&self) -> usize {
        self.written.len()
    }

    /// Partial preemption: drop whole blocks from the *tail* of a live
    /// sequence until `need_free` blocks have physically returned to the
    /// free list (a dropped shared block only decrements its refcount and
    /// frees nothing, so the walk keeps going past it). The kept prefix —
    /// typically the shared prompt blocks plus the oldest decode blocks —
    /// stays granted to `seq`, which remains a live table; `len` shrinks
    /// to the kept block capacity and written-block accounting follows
    /// the physical frees. Returns what actually happened so the caller
    /// can fall back to a full release when nothing came free.
    pub fn truncate_tail(
        &mut self,
        alloc: &mut BlockAllocator,
        seq: SeqId,
        need_free: usize,
    ) -> TruncateOutcome {
        let bs = self.block_size;
        let need_free = need_free.max(1);
        let mut freed = 0usize;
        loop {
            let Some(t) = self.tables.get_mut(&seq) else { break };
            if freed >= need_free || t.blocks.is_empty() {
                break;
            }
            let b = t.blocks.pop().expect("checked non-empty");
            if self.release_and_clean(alloc, b) {
                freed += 1;
            }
        }
        let t = self.tables.get_mut(&seq).expect("truncate_tail of unknown seq");
        t.len = t.len.min(t.blocks.len() * bs);
        let out = TruncateOutcome { freed, kept_blocks: t.blocks.len(), kept_len: t.len };
        self.events.push(PoolEvent::Truncate {
            seq,
            freed: out.freed as u32,
            kept_blocks: out.kept_blocks as u32,
            kept_len: out.kept_len as u32,
        });
        out
    }

    /// Dry-run twin of [`TableSet::truncate_tail`]: what *would* a
    /// partial preemption of `seq` for `need_free` blocks free and keep,
    /// without touching the table or the allocator? The victim scorers
    /// use this to price candidates by their **planned truncation
    /// depth** — the tokens the resume would actually recompute — instead
    /// of the full-history proxy, which overcharges long-running lanes
    /// whose tail is cheap. The chain hash is position-dependent, so a
    /// block never appears twice in one table and the walk's refcount
    /// reads match what the destructive walk would observe.
    pub fn planned_truncation(
        &self,
        alloc: &BlockAllocator,
        seq: SeqId,
        need_free: usize,
    ) -> TruncateOutcome {
        let t = self.tables.get(&seq).expect("planned_truncation of unknown seq");
        let need_free = need_free.max(1);
        let mut freed = 0usize;
        let mut kept = t.blocks.len();
        while kept > 0 && freed < need_free {
            if alloc.ref_count(t.blocks[kept - 1]) == 1 {
                freed += 1;
            }
            kept -= 1;
        }
        TruncateOutcome {
            freed,
            kept_blocks: kept,
            kept_len: t.len.min(kept * self.block_size),
        }
    }

    /// Shrink a live sequence's logical length without releasing blocks.
    /// Partial preemption uses this to drop a position the mirror already
    /// advanced for an in-flight token that was never delivered: the
    /// resume replays history only up to `len`, and
    /// [`TableSet::resume_extend`] asserts the replay covers every kept
    /// position.
    pub fn clamp_len(&mut self, seq: SeqId, len: usize) {
        let t = self.tables.get_mut(&seq).expect("clamp_len of unknown seq");
        t.len = t.len.min(len);
    }

    /// Re-admission of a sequence that kept a truncated prefix across a
    /// partial preemption: grow its table back to `total_blocks`, then
    /// mark the resume re-prefill — `new_len` tokens, covering the kept
    /// prefix plus the recomputed suffix — as written. All-or-nothing:
    /// on exhaustion every newly acquired block is rolled back and the
    /// kept prefix is untouched, so the caller can simply retry later.
    pub fn resume_extend(
        &mut self,
        alloc: &mut BlockAllocator,
        seq: SeqId,
        new_len: usize,
        total_blocks: usize,
    ) -> Result<(), PoolExhausted> {
        let bs = self.block_size;
        let total_blocks = total_blocks.max(new_len.div_ceil(bs)).max(1);
        let have = {
            let t = self.tables.get(&seq).expect("resume_extend of unknown seq");
            assert!(new_len >= t.len, "resume must not shrink a kept prefix");
            t.blocks.len()
        };
        let mut acquired: Vec<BlockId> = Vec::new();
        for _ in have..total_blocks {
            match alloc.alloc() {
                Ok(b) => acquired.push(b),
                Err(e) => {
                    self.rollback(alloc, &acquired);
                    return Err(e);
                }
            }
        }
        self.events.push(PoolEvent::Grow { seq, blocks: acquired.len() as u32 });
        let to_mark: Vec<BlockId> = {
            let t = self.tables.get_mut(&seq).expect("checked above");
            t.blocks.extend_from_slice(&acquired);
            t.len = new_len;
            let written_blocks = new_len.div_ceil(bs).min(t.blocks.len());
            t.blocks[..written_blocks].to_vec()
        };
        for b in to_mark {
            self.written.insert(b);
        }
        Ok(())
    }

    /// Release a preempted sequence's blocks. Behaviourally identical to
    /// [`TableSet::free`] — `release` only returns a block to the free
    /// list at refcount zero, so prefixes shared with co-resident
    /// sequences survive the victim — but tallied separately so the
    /// allocator stats distinguish eviction traffic from completions.
    pub fn preempt_free(&mut self, alloc: &mut BlockAllocator, seq: SeqId) {
        alloc.stats.preempt_frees += 1;
        self.free(alloc, seq);
    }

    /// Advance a sequence by one generated token (must stay within the
    /// blocks currently granted — the engine either reserves the whole
    /// decode budget at admission or `grow`s the table before advancing,
    /// so an overrun here is a scheduler bug, not backpressure).
    pub fn advance(&mut self, seq: SeqId) {
        let bs = self.block_size;
        let t = self.tables.get_mut(&seq).expect("advance of unknown seq");
        assert!(
            t.len < t.blocks.len() * bs,
            "sequence {seq} outgrew its reservation ({} blocks)",
            t.blocks.len()
        );
        t.len += 1;
        // The new token's slot makes its block written (no-op when the
        // position stays within an already-written block).
        self.written.insert(t.blocks[(t.len - 1) / bs]);
    }

    /// Release every block a sequence holds.
    pub fn free(&mut self, alloc: &mut BlockAllocator, seq: SeqId) {
        let t = self.tables.remove(&seq).expect("free of unknown seq");
        self.events.push(PoolEvent::Free { seq, blocks: t.blocks.len() as u32 });
        for b in t.blocks {
            self.release_and_clean(alloc, b);
        }
    }

    /// Fork: the child shares every full block of the parent (refcount++)
    /// and gets a private copy-on-write tail block if the parent's length
    /// is mid-block. Used by the property tests and by speculative /
    /// beam-style serving extensions.
    pub fn fork(
        &mut self,
        alloc: &mut BlockAllocator,
        parent: SeqId,
    ) -> Result<SeqId, PoolExhausted> {
        let bs = self.block_size;
        let (p_blocks, p_len) = {
            let t = self.tables.get(&parent).expect("fork of unknown seq");
            (t.blocks.clone(), t.len)
        };
        let full = p_len / bs;
        let mut blocks: Vec<BlockId> = Vec::with_capacity(p_blocks.len());
        for &b in p_blocks.iter().take(full) {
            alloc.retain(b);
            blocks.push(b);
        }
        if p_len % bs != 0 {
            // CoW of the partial tail: a private block the child may
            // write; it conceptually holds a copy of the parent's written
            // tail slots, so it counts as written from birth.
            match alloc.alloc() {
                Ok(b) => {
                    alloc.stats.cow_copies += 1;
                    self.written.insert(b);
                    blocks.push(b);
                }
                Err(e) => {
                    self.rollback(alloc, &blocks);
                    return Err(e);
                }
            }
        }
        let id = self.next;
        self.next += 1;
        // The fork counter tracks branch fan-out (sampling n>1, retries);
        // before the radix refactor it was never incremented here, so
        // `PoolStats::forks` read 0 however many branches were live.
        alloc.stats.forks += 1;
        // A fork is an admission by another name: full blocks are shared,
        // only a CoW tail (if any) is a fresh allocation.
        self.events.push(PoolEvent::Alloc {
            seq: id,
            blocks: blocks.len() as u32,
            shared: full.min(blocks.len()) as u32,
        });
        self.tables.insert(id, BlockTable { blocks, len: p_len });
        Ok(id)
    }

    /// How many full prompt blocks of `prompt` would be shared (not
    /// freshly allocated) if it were admitted right now — an occupancy
    /// probe for dashboards/tests. Note sharing does not change whether
    /// a request *fits* a pool: shared blocks are live allocations, so a
    /// grant always needs the request's total block count within
    /// `num_blocks`.
    pub fn shareable_full_blocks(&self, prompt: &[i32]) -> usize {
        if !self.sharing {
            return 0;
        }
        prefix_block_hashes(prompt, self.block_size)
            .iter()
            .filter(|&&h| self.tree.contains(h))
            .count()
    }

    /// Read-only view of the radix tree (routing probes, tests,
    /// snapshots). Lookups through this view never charge the hit
    /// counter — use [`TableSet::admit`] for that.
    pub fn radix(&self) -> &RadixTree {
        &self.tree
    }

    /// Live prefix nodes — the `radix_nodes` gauge.
    pub fn radix_nodes(&self) -> usize {
        self.tree.len()
    }

    /// Cumulative admission blocks served from the tree — the
    /// `radix_hit_blocks` gauge.
    pub fn radix_hit_blocks(&self) -> u64 {
        self.tree.hit_blocks()
    }

    fn rollback(&mut self, alloc: &mut BlockAllocator, acquired: &[BlockId]) {
        for &b in acquired.iter().rev() {
            self.release_and_clean(alloc, b);
        }
    }

    fn release_and_clean(&mut self, alloc: &mut BlockAllocator, b: BlockId) -> bool {
        if alloc.release(b) {
            self.written.remove(&b);
            if let Some(h) = self.tree.remove_by_block(b) {
                self.events.push(PoolEvent::PrefixReleased { hash: h });
            }
            true
        } else {
            false
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toks(n: usize, base: i32) -> Vec<i32> {
        (0..n as i32).map(|i| base + i).collect()
    }

    #[test]
    fn identical_prompts_share_full_blocks() {
        let mut alloc = BlockAllocator::new(32, 4);
        let mut ts = TableSet::new(4, true);
        let prompt = toks(10, 100); // 2 full blocks + 2-token tail
        let a = ts.admit(&mut alloc, &prompt, 12).unwrap();
        let before = alloc.blocks_in_use();
        let b = ts.admit(&mut alloc, &prompt, 12).unwrap();
        // Second admit shares the 2 full prompt blocks, allocates only the
        // private tail block.
        assert_eq!(alloc.blocks_in_use(), before + 1);
        assert_eq!(ts.shared_hits, 2);
        let (ta, tb) = (ts.table(a).unwrap().clone(), ts.table(b).unwrap().clone());
        assert_eq!(ta.blocks[..2], tb.blocks[..2]);
        assert_ne!(ta.blocks[2], tb.blocks[2]);
        ts.free(&mut alloc, a);
        ts.free(&mut alloc, b);
        assert_eq!(alloc.blocks_in_use(), 0);
        alloc.check_invariants();
    }

    #[test]
    fn divergent_prompts_do_not_share() {
        let mut alloc = BlockAllocator::new(32, 4);
        let mut ts = TableSet::new(4, true);
        let a = ts.admit(&mut alloc, &toks(8, 0), 8).unwrap();
        let b = ts.admit(&mut alloc, &toks(8, 999), 8).unwrap();
        assert_eq!(ts.shared_hits, 0);
        ts.free(&mut alloc, a);
        ts.free(&mut alloc, b);
        alloc.check_invariants();
    }

    #[test]
    fn sharing_disabled_allocates_fresh() {
        let mut alloc = BlockAllocator::new(32, 4);
        let mut ts = TableSet::new(4, false);
        let prompt = toks(8, 7);
        let _a = ts.admit(&mut alloc, &prompt, 8).unwrap();
        let _b = ts.admit(&mut alloc, &prompt, 8).unwrap();
        assert_eq!(ts.shared_hits, 0);
        assert_eq!(alloc.blocks_in_use(), 4);
    }

    #[test]
    fn admission_rolls_back_on_exhaustion() {
        let mut alloc = BlockAllocator::new(3, 4);
        let mut ts = TableSet::new(4, true);
        // Needs 4 blocks; only 3 exist.
        assert!(ts.admit(&mut alloc, &toks(13, 0), 16).is_err());
        assert_eq!(alloc.blocks_in_use(), 0, "failed admit must roll back");
        assert_eq!(ts.live_seqs(), 0);
        alloc.check_invariants();
    }

    #[test]
    fn advance_stays_within_reservation() {
        let mut alloc = BlockAllocator::new(8, 4);
        let mut ts = TableSet::new(4, true);
        let s = ts.admit(&mut alloc, &toks(3, 0), 8).unwrap();
        for _ in 0..5 {
            ts.advance(s);
        }
        assert_eq!(ts.table(s).unwrap().len, 8);
    }

    #[test]
    #[should_panic(expected = "outgrew its reservation")]
    fn advance_past_reservation_panics() {
        let mut alloc = BlockAllocator::new(8, 4);
        let mut ts = TableSet::new(4, true);
        let s = ts.admit(&mut alloc, &toks(3, 0), 4).unwrap();
        for _ in 0..2 {
            ts.advance(s);
        }
    }

    #[test]
    fn fork_shares_full_blocks_and_cows_tail() {
        let mut alloc = BlockAllocator::new(16, 4);
        let mut ts = TableSet::new(4, true);
        let p = ts.admit(&mut alloc, &toks(6, 0), 6).unwrap();
        let before = alloc.blocks_in_use();
        let c = ts.fork(&mut alloc, p).unwrap();
        assert_eq!(alloc.blocks_in_use(), before + 1, "only the tail is copied");
        let (tp, tc) = (ts.table(p).unwrap().clone(), ts.table(c).unwrap().clone());
        assert_eq!(tp.blocks[0], tc.blocks[0]);
        assert_ne!(tp.blocks[1], tc.blocks[1]);
        assert_eq!(alloc.ref_count(tp.blocks[0]), 2);
        ts.free(&mut alloc, p);
        assert_eq!(alloc.ref_count(tc.blocks[0]), 1, "parent free keeps shared block live");
        ts.free(&mut alloc, c);
        assert_eq!(alloc.blocks_in_use(), 0);
        alloc.check_invariants();
    }

    #[test]
    fn shared_block_reusable_after_full_free() {
        let mut alloc = BlockAllocator::new(8, 4);
        let mut ts = TableSet::new(4, true);
        let prompt = toks(4, 50);
        let a = ts.admit(&mut alloc, &prompt, 4).unwrap();
        ts.free(&mut alloc, a);
        assert_eq!(alloc.blocks_in_use(), 0);
        // The hash entry must be gone: a fresh admit re-allocates (and the
        // stale map must not hand out a freed block).
        let b = ts.admit(&mut alloc, &prompt, 4).unwrap();
        assert_eq!(alloc.blocks_in_use(), 1);
        assert_eq!(alloc.ref_count(ts.table(b).unwrap().blocks[0]), 1);
        ts.free(&mut alloc, b);
        alloc.check_invariants();
    }

    #[test]
    fn shareable_full_blocks_counts_resident_prefix() {
        let mut alloc = BlockAllocator::new(16, 4);
        let mut ts = TableSet::new(4, true);
        let prompt = toks(10, 0); // 2 full blocks + tail
        assert_eq!(ts.shareable_full_blocks(&prompt), 0, "nothing resident yet");
        let a = ts.admit(&mut alloc, &prompt, 10).unwrap();
        assert_eq!(ts.shareable_full_blocks(&prompt), 2);
        // A prompt diverging in the second block shares only the first.
        let mut other = prompt.clone();
        other[5] = 999;
        assert_eq!(ts.shareable_full_blocks(&other), 1);
        ts.free(&mut alloc, a);
        assert_eq!(ts.shareable_full_blocks(&prompt), 0, "freed blocks leave the index");
        // Sharing disabled → never counts.
        let ts_off = TableSet::new(4, false);
        assert_eq!(ts_off.shareable_full_blocks(&prompt), 0);
    }

    #[test]
    fn prefix_block_hashes_match_the_tables_registration() {
        let bs = 4;
        let prompt = toks(10, 0); // 2 full blocks + tail
        let hashes = prefix_block_hashes(&prompt, bs);
        assert_eq!(hashes.len(), 2, "only full blocks hash");
        // Hash i is the chained hash the admit path registers: a prompt
        // sharing block 0 but diverging in block 1 agrees on hash 0 only.
        let mut other = prompt.clone();
        other[5] = 999;
        let other_hashes = prefix_block_hashes(&other, bs);
        assert_eq!(hashes[0], other_hashes[0]);
        assert_ne!(hashes[1], other_hashes[1]);
        // Agreement with the resident index: after admitting the prompt,
        // exactly the blocks whose hashes are registered are shareable.
        let mut alloc = BlockAllocator::new(16, bs);
        let mut ts = TableSet::new(bs, true);
        ts.admit(&mut alloc, &prompt, 10).unwrap();
        assert_eq!(ts.shareable_full_blocks(&prompt), hashes.len());
        assert_eq!(ts.shareable_full_blocks(&other), 1);
        // Degenerate block size clamps instead of dividing by zero.
        assert_eq!(prefix_block_hashes(&prompt, 0).len(), prompt.len());
    }

    #[test]
    fn grow_extends_reservation_and_partial_grants_count() {
        let mut alloc = BlockAllocator::new(4, 4);
        let mut ts = TableSet::new(4, true);
        // 3 tokens, reserve 4 → 1 block; 3 blocks free.
        let s = ts.admit(&mut alloc, &toks(3, 0), 4).unwrap();
        ts.advance(s); // len 4 == 1 block × 4 slots
        assert!(ts.needs_grow(s));
        // Want 5, only 3 free → partial grant of 3.
        assert_eq!(ts.grow(&mut alloc, s, 5).unwrap(), 3);
        assert!(!ts.needs_grow(s));
        assert_eq!(alloc.stats.grown_blocks, 3);
        // Pool empty → zero grant is an error, not a silent no-op.
        assert!(ts.grow(&mut alloc, s, 1).is_err());
        for _ in 0..12 {
            ts.advance(s);
        }
        assert_eq!(ts.table(s).unwrap().len, 16);
        ts.free(&mut alloc, s);
        assert_eq!(alloc.blocks_in_use(), 0);
        alloc.check_invariants();
    }

    #[test]
    fn preempt_free_spares_shared_prefix_blocks() {
        let mut alloc = BlockAllocator::new(16, 4);
        let mut ts = TableSet::new(4, true);
        let prompt = toks(8, 0); // 2 full shareable blocks
        let a = ts.admit(&mut alloc, &prompt, 10).unwrap();
        let b = ts.admit(&mut alloc, &prompt, 10).unwrap();
        let shared: Vec<_> = ts.table(a).unwrap().blocks[..2].to_vec();
        assert_eq!(ts.private_blocks(&alloc, a), 1, "only the tail is private");
        ts.preempt_free(&mut alloc, b);
        assert_eq!(alloc.stats.preempt_frees, 1);
        for &blk in &shared {
            assert_eq!(alloc.ref_count(blk), 1, "survivor keeps the prefix");
        }
        // Survivor's table is fully intact and re-admission re-shares.
        let c = ts.admit(&mut alloc, &prompt, 10).unwrap();
        assert_eq!(ts.table(c).unwrap().blocks[..2], shared[..]);
        ts.free(&mut alloc, a);
        ts.free(&mut alloc, c);
        assert_eq!(alloc.blocks_in_use(), 0);
        alloc.check_invariants();
    }

    #[test]
    fn written_blocks_ignores_unwritten_reservation() {
        let mut alloc = BlockAllocator::new(32, 4);
        let mut ts = TableSet::new(4, true);
        // 5 prompt tokens, 16-slot reservation → 4 blocks granted, 2 written.
        let s = ts.admit(&mut alloc, &toks(5, 0), 16).unwrap();
        assert_eq!(alloc.blocks_in_use(), 4);
        assert_eq!(ts.written_blocks(), 2);
        // A second identical prompt shares its written prefix block.
        let t = ts.admit(&mut alloc, &toks(5, 0), 16).unwrap();
        assert_eq!(ts.written_blocks(), 3, "shared block counts once");
        ts.advance(s);
        ts.advance(s);
        ts.advance(s); // len 8 → still 2 written blocks for s
        assert_eq!(ts.written_blocks(), 3);
        ts.advance(s); // len 9 → third block written
        assert_eq!(ts.written_blocks(), 4);
        ts.free(&mut alloc, s);
        ts.free(&mut alloc, t);
        assert_eq!(ts.written_blocks(), 0);
        alloc.check_invariants();
    }

    #[test]
    fn truncate_tail_frees_only_what_is_needed_and_keeps_the_prefix() {
        let mut alloc = BlockAllocator::new(16, 4);
        let mut ts = TableSet::new(4, true);
        // 6 prompt tokens + 18-slot reservation → 5 blocks.
        let s = ts.admit(&mut alloc, &toks(6, 0), 20).unwrap();
        for _ in 0..10 {
            ts.advance(s); // len 16 → 4 written blocks
        }
        assert_eq!(ts.written_blocks(), 4);
        let out = ts.truncate_tail(&mut alloc, s, 2);
        assert_eq!(out.freed, 2, "exactly the needed blocks return");
        assert_eq!(out.kept_blocks, 3);
        assert_eq!(out.kept_len, 12, "len shrinks to the kept capacity");
        assert_eq!(ts.table(s).unwrap().len, 12);
        assert_eq!(ts.written_blocks(), 3, "freed blocks leave the written set");
        assert_eq!(alloc.num_free(), 16 - 3);
        ts.free(&mut alloc, s);
        assert_eq!(alloc.blocks_in_use(), 0);
        alloc.check_invariants();
    }

    #[test]
    fn truncate_tail_walks_past_shared_blocks_without_freeing_them() {
        let mut alloc = BlockAllocator::new(16, 4);
        let mut ts = TableSet::new(4, true);
        let prompt = toks(8, 0); // 2 full shareable blocks
        let a = ts.admit(&mut alloc, &prompt, 9).unwrap(); // + 1 private tail
        let b = ts.admit(&mut alloc, &prompt, 9).unwrap();
        // Asking for 2 frees from a drops its private tail (1 free) and
        // then walks into the shared prompt blocks: refcounts drop but
        // the survivor keeps them live.
        let out = ts.truncate_tail(&mut alloc, a, 2);
        assert_eq!(out.freed, 1, "shared blocks free nothing");
        assert_eq!(out.kept_blocks, 0, "the walk consumed the whole table");
        let tb = ts.table(b).unwrap().clone();
        assert!(tb.blocks.iter().all(|&blk| alloc.ref_count(blk) >= 1));
        ts.free(&mut alloc, a); // empty table, still removable
        ts.free(&mut alloc, b);
        assert_eq!(alloc.blocks_in_use(), 0);
        alloc.check_invariants();
    }

    #[test]
    fn resume_extend_regrows_and_marks_the_recomputed_suffix_written() {
        let mut alloc = BlockAllocator::new(16, 4);
        let mut ts = TableSet::new(4, true);
        let s = ts.admit(&mut alloc, &toks(6, 0), 20).unwrap(); // 5 blocks
        for _ in 0..10 {
            ts.advance(s);
        }
        let out = ts.truncate_tail(&mut alloc, s, 2);
        assert_eq!((out.kept_blocks, out.kept_len), (3, 12));
        // Resume at 16 live tokens with a 6-block reservation.
        ts.resume_extend(&mut alloc, s, 16, 6).unwrap();
        let t = ts.table(s).unwrap();
        assert_eq!(t.blocks.len(), 6);
        assert_eq!(t.len, 16);
        assert_eq!(ts.written_blocks(), 4, "re-prefilled slots count as written");
        for _ in 0..8 {
            ts.advance(s); // the regrown reservation is usable
        }
        assert_eq!(ts.table(s).unwrap().len, 24);
        ts.free(&mut alloc, s);
        assert_eq!(alloc.blocks_in_use(), 0);
        alloc.check_invariants();
    }

    #[test]
    fn resume_extend_rolls_back_on_exhaustion() {
        let mut alloc = BlockAllocator::new(4, 4);
        let mut ts = TableSet::new(4, true);
        let s = ts.admit(&mut alloc, &toks(6, 0), 8).unwrap(); // 2 blocks
        let in_use = alloc.blocks_in_use();
        // Wants 6 blocks total, only 2 more exist → all-or-nothing error.
        assert!(ts.resume_extend(&mut alloc, s, 8, 6).is_err());
        assert_eq!(alloc.blocks_in_use(), in_use, "failed extend must roll back");
        assert_eq!(ts.table(s).unwrap().blocks.len(), 2);
        assert_eq!(ts.table(s).unwrap().len, 6, "kept prefix untouched");
        ts.free(&mut alloc, s);
        alloc.check_invariants();
    }

    #[test]
    fn planned_truncation_matches_truncate_tail() {
        // The dry run must agree with the destructive walk on every
        // (private tail, shared prefix, need) combination the victim
        // scorer can see — otherwise tail-cost scoring prices a
        // preemption the actual eviction won't perform.
        for need in 1..=5 {
            let mut alloc = BlockAllocator::new(16, 4);
            let mut ts = TableSet::new(4, true);
            let prompt = toks(8, 0); // 2 full shareable blocks
            let a = ts.admit(&mut alloc, &prompt, 18).unwrap(); // 5 blocks
            let _b = ts.admit(&mut alloc, &prompt, 9).unwrap(); // shares 2
            for _ in 0..8 {
                ts.advance(a); // len 16 → tail blocks written
            }
            let planned = ts.planned_truncation(&alloc, a, need);
            let actual = ts.truncate_tail(&mut alloc, a, need);
            assert_eq!(planned, actual, "dry run diverged at need={need}");
        }
    }

    #[test]
    fn planned_truncation_leaves_state_untouched() {
        let mut alloc = BlockAllocator::new(8, 4);
        let mut ts = TableSet::new(4, true);
        let s = ts.admit(&mut alloc, &toks(6, 0), 12).unwrap();
        let in_use = alloc.blocks_in_use();
        let before = ts.table(s).unwrap().clone();
        let out = ts.planned_truncation(&alloc, s, 2);
        assert!(out.freed > 0);
        assert_eq!(alloc.blocks_in_use(), in_use, "dry run must not free");
        let after = ts.table(s).unwrap();
        assert_eq!(before.blocks, after.blocks);
        assert_eq!(before.len, after.len);
        alloc.check_invariants();
    }

    #[test]
    fn lifecycle_emits_pool_events() {
        let mut alloc = BlockAllocator::new(16, 4);
        let mut ts = TableSet::new(4, true);
        let s = ts.admit(&mut alloc, &toks(6, 0), 20).unwrap(); // 5 blocks
        for _ in 0..10 {
            ts.advance(s);
        }
        ts.truncate_tail(&mut alloc, s, 2); // keeps 3 blocks / len 12
        ts.resume_extend(&mut alloc, s, 16, 6).unwrap(); // re-acquires 3
        ts.free(&mut alloc, s);
        let evs: Vec<_> = ts.events.drain().collect();
        assert_eq!(evs[0], PoolEvent::Alloc { seq: s, blocks: 5, shared: 0 });
        assert_eq!(
            evs[1],
            PoolEvent::Truncate { seq: s, freed: 2, kept_blocks: 3, kept_len: 12 }
        );
        assert_eq!(evs[2], PoolEvent::Grow { seq: s, blocks: 3 });
        assert_eq!(evs[3], PoolEvent::Free { seq: s, blocks: 6 });
        // The prompt's one full block was a radix node; its physical
        // free (refcount drained) announces the released chain hash so
        // affinity mirrors can drop the entry.
        let h = chain_hash(0, &toks(4, 0));
        assert_eq!(evs[4], PoolEvent::PrefixReleased { hash: h });
        assert_eq!(evs.len(), 5);
        // Sharing shows up in the admit event.
        let prompt = toks(8, 0);
        let _a = ts.admit(&mut alloc, &prompt, 9).unwrap();
        let b = ts.admit(&mut alloc, &prompt, 9).unwrap();
        let evs: Vec<_> = ts.events.drain().collect();
        assert_eq!(evs[1], PoolEvent::Alloc { seq: b, blocks: 3, shared: 2 });
    }

    #[test]
    fn admit_builds_linked_radix_nodes() {
        let mut alloc = BlockAllocator::new(16, 4);
        let mut ts = TableSet::new(4, true);
        let prompt = toks(12, 0); // 3 full blocks
        let s = ts.admit(&mut alloc, &prompt, 12).unwrap();
        assert_eq!(ts.radix_nodes(), 3);
        let hashes = prefix_block_hashes(&prompt, 4);
        assert_eq!(ts.radix().depth(hashes[2]), Some(3));
        assert_eq!(ts.radix().ancestry(hashes[2]), vec![hashes[2], hashes[1], hashes[0]]);
        assert!(ts.radix().is_leaf(hashes[2]));
        // A prompt diverging in its second block branches under the
        // shared root instead of duplicating it.
        let mut other = prompt.clone();
        other[6] = 999;
        let t = ts.admit(&mut alloc, &other, 12).unwrap();
        let oh = prefix_block_hashes(&other, 4);
        assert_eq!(oh[0], hashes[0], "shared first block, same node");
        assert!(!ts.radix().is_leaf(hashes[0]), "root now has two children");
        assert_eq!(ts.radix_nodes(), 5, "1 shared root + 2 nodes per branch");
        assert_eq!(ts.radix_hit_blocks(), 1, "one block served from the tree");
        assert_eq!(ts.radix().ancestry(oh[2]), vec![oh[2], oh[1], hashes[0]]);
        ts.free(&mut alloc, s);
        ts.free(&mut alloc, t);
        assert_eq!(ts.radix_nodes(), 0, "drained tree is empty");
        alloc.check_invariants();
    }

    #[test]
    fn prefix_released_fires_only_at_physical_free() {
        let mut alloc = BlockAllocator::new(16, 4);
        let mut ts = TableSet::new(4, true);
        let prompt = toks(4, 0); // exactly one shared full block
        let a = ts.admit(&mut alloc, &prompt, 4).unwrap();
        let b = ts.admit(&mut alloc, &prompt, 4).unwrap();
        ts.events.drain().for_each(drop);
        ts.free(&mut alloc, a);
        let evs: Vec<_> = ts.events.drain().collect();
        assert!(
            !evs.iter().any(|e| matches!(e, PoolEvent::PrefixReleased { .. })),
            "survivor still references the block: no release event"
        );
        assert_eq!(ts.radix_nodes(), 1);
        ts.free(&mut alloc, b);
        let evs: Vec<_> = ts.events.drain().collect();
        let h = chain_hash(0, &prompt);
        assert!(evs.contains(&PoolEvent::PrefixReleased { hash: h }));
        assert_eq!(ts.radix_nodes(), 0);
        alloc.check_invariants();
    }

    #[test]
    fn chain_hash_is_position_dependent() {
        let a = chain_hash(0, &[1, 2, 3, 4]);
        let b = chain_hash(0, &[1, 2, 4, 3]);
        assert_ne!(a, b);
        let c = chain_hash(a, &[5, 6, 7, 8]);
        let d = chain_hash(b, &[5, 6, 7, 8]);
        assert_ne!(c, d, "divergent prefixes must not reconverge");
    }
}
