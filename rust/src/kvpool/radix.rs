//! Refcounted radix tree over block-granular token runs — the single
//! source of truth for prefix sharing.
//!
//! Nodes are full prompt blocks keyed by the content-addressed
//! [`super::chain_hash`] scheme: a node's key is the chained hash of
//! every token from the prompt start through its own block, so its
//! parent is simply the node for the one-block-shorter prefix. Edges
//! are therefore token-run segments (one block per edge), the root set
//! is the forest of distinct first blocks, and leaves are the deepest
//! blocks still referenced by live sequences. [`super::TableSet`] walks
//! this tree on `admit`/`fork`/`free` (the old flat `prefix_map` /
//! `block_hash` pair is gone — there is no second index to drift), the
//! engine's admission mirror answers prefix probes through it, and the
//! router's per-replica affinity mirror is kept honest by the
//! `PoolEvent::PrefixReleased` feedback emitted when a node's block
//! drains its last reference.
//!
//! Physical lifetime stays with the ref-counted block allocator: the
//! tree holds *structure* (hash → block, parent/child links), never a
//! reference of its own. Ancestor protection for idle-leaf eviction is
//! structural — a shared ancestor block carries one refcount per live
//! descendant table, so freeing a leaf can only return the leaf's
//! private blocks.
//!
//! Determinism: storage is `BTreeMap`/`BTreeSet` only, so every
//! iteration order is sorted and reproducible by construction, and the
//! hot paths are written panic-free (no indexing, no unwrap) — this
//! module is inside the `repro-lint` `nondet-iter` and
//! `panic-in-hot-path` scopes.

use super::block::BlockId;
use std::collections::{BTreeMap, BTreeSet};

/// One full prompt block in the tree. Plain data: the allocator owns
/// the block's refcount, the node only records where it sits.
#[derive(Clone, Debug)]
pub struct RadixNode {
    /// Chain hash of the one-block-shorter prefix; `None` for a root
    /// (first block of a prompt) or after the parent was released
    /// out-of-order.
    pub parent: Option<u64>,
    /// Physical block this prefix resolves to.
    pub block: BlockId,
    /// Number of full blocks in the prefix this node terminates
    /// (1-based: a root node has depth 1).
    pub depth: usize,
    /// Chain hashes of the one-block-longer prefixes seen so far.
    pub children: BTreeSet<u64>,
}

/// The tree. See the module docs for the design.
#[derive(Clone, Debug, Default)]
pub struct RadixTree {
    nodes: BTreeMap<u64, RadixNode>,
    /// Reverse index for eviction feedback: physical block → node key.
    by_block: BTreeMap<BlockId, u64>,
    /// Cumulative blocks served from the tree (admission walks that
    /// resolved to an existing node) — the `radix_hit_blocks` gauge.
    hit_blocks: u64,
}

impl RadixTree {
    pub fn new() -> Self {
        Self::default()
    }

    /// Resolve a chain hash to its physical block and count the hit.
    /// Use [`RadixTree::peek`] for non-charging probes.
    pub fn lookup(&mut self, hash: u64) -> Option<BlockId> {
        match self.nodes.get(&hash) {
            Some(n) => {
                self.hit_blocks += 1;
                Some(n.block)
            }
            None => None,
        }
    }

    /// Resolve without charging the hit counter (planning / routing
    /// probes that never admit).
    pub fn peek(&self, hash: u64) -> Option<BlockId> {
        self.nodes.get(&hash).map(|n| n.block)
    }

    pub fn contains(&self, hash: u64) -> bool {
        self.nodes.contains_key(&hash)
    }

    /// Insert a node for `hash` resolving to `block`, linked under
    /// `parent` (the hash of the one-block-shorter prefix, if that
    /// prefix is itself indexed). Inserting an existing hash is a
    /// no-op: content addressing means equal hash ⇒ equal tokens, and
    /// the first writer's block is the shared one.
    pub fn insert(&mut self, hash: u64, parent: Option<u64>, block: BlockId) {
        if self.nodes.contains_key(&hash) {
            return;
        }
        let depth = match parent.and_then(|p| self.nodes.get_mut(&p)) {
            Some(pn) => {
                pn.children.insert(hash);
                pn.depth + 1
            }
            None => 1,
        };
        let parent = parent.filter(|p| self.nodes.contains_key(p));
        self.nodes.insert(hash, RadixNode { parent, block, depth, children: BTreeSet::new() });
        self.by_block.insert(block, hash);
    }

    /// A physical block drained its last reference: drop its node (if
    /// the block was indexed) and return the released chain hash so the
    /// caller can emit `PoolEvent::PrefixReleased`. Children of the
    /// released node are detached, not removed — out-of-order release
    /// (tables free front-to-back to keep the allocator's LIFO free
    /// list order pinned) may drop an ancestor while a descendant block
    /// still holds references.
    pub fn remove_by_block(&mut self, block: BlockId) -> Option<u64> {
        let hash = self.by_block.remove(&block)?;
        let node = self.nodes.remove(&hash)?;
        if let Some(p) = node.parent.and_then(|p| self.nodes.get_mut(&p)) {
            p.children.remove(&hash);
        }
        for c in &node.children {
            if let Some(cn) = self.nodes.get_mut(c) {
                cn.parent = None;
            }
        }
        Some(hash)
    }

    /// Live nodes — the `radix_nodes` gauge.
    pub fn len(&self) -> usize {
        self.nodes.len()
    }

    pub fn is_empty(&self) -> bool {
        self.nodes.is_empty()
    }

    /// Cumulative tree-lookup hits — the `radix_hit_blocks` gauge.
    pub fn hit_blocks(&self) -> u64 {
        self.hit_blocks
    }

    /// A leaf has no indexed one-block-longer extension.
    pub fn is_leaf(&self, hash: u64) -> bool {
        self.nodes.get(&hash).map(|n| n.children.is_empty()).unwrap_or(false)
    }

    /// Depth of the node (full blocks in its prefix), if present.
    pub fn depth(&self, hash: u64) -> Option<usize> {
        self.nodes.get(&hash).map(|n| n.depth)
    }

    /// Node keys in sorted order — deterministic iteration for tests
    /// and snapshots.
    pub fn keys(&self) -> impl Iterator<Item = u64> + '_ {
        self.nodes.keys().copied()
    }

    /// Walk up from `hash` to its root, returning the path (self
    /// first). Bounded by the recorded depth, so a corrupted link can
    /// never loop.
    pub fn ancestry(&self, hash: u64) -> Vec<u64> {
        let mut path = Vec::new();
        let mut cur = Some(hash);
        let mut fuel = self.nodes.get(&hash).map(|n| n.depth).unwrap_or(0);
        while let Some(h) = cur {
            match self.nodes.get(&h) {
                Some(n) => {
                    path.push(h);
                    cur = n.parent;
                }
                None => break,
            }
            if fuel == 0 {
                break;
            }
            fuel -= 1;
        }
        path
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn insert_links_parent_and_depth() {
        let mut t = RadixTree::new();
        t.insert(10, None, 0);
        t.insert(20, Some(10), 1);
        t.insert(30, Some(20), 2);
        assert_eq!(t.len(), 3);
        assert_eq!(t.depth(10), Some(1));
        assert_eq!(t.depth(30), Some(3));
        assert!(t.is_leaf(30));
        assert!(!t.is_leaf(10));
        assert_eq!(t.ancestry(30), vec![30, 20, 10]);
    }

    #[test]
    fn lookup_counts_hits_and_peek_does_not() {
        let mut t = RadixTree::new();
        t.insert(10, None, 0);
        assert_eq!(t.peek(10), Some(0));
        assert_eq!(t.hit_blocks(), 0);
        assert_eq!(t.lookup(10), Some(0));
        assert_eq!(t.lookup(99), None);
        assert_eq!(t.hit_blocks(), 1);
    }

    #[test]
    fn duplicate_insert_keeps_the_first_block() {
        let mut t = RadixTree::new();
        t.insert(10, None, 0);
        t.insert(10, None, 7);
        assert_eq!(t.peek(10), Some(0));
        assert_eq!(t.len(), 1);
    }

    #[test]
    fn remove_by_block_detaches_children_without_dropping_them() {
        let mut t = RadixTree::new();
        t.insert(10, None, 0);
        t.insert(20, Some(10), 1);
        t.insert(21, Some(10), 2);
        // Front-to-back free order: the ancestor's block drains first.
        assert_eq!(t.remove_by_block(0), Some(10));
        assert_eq!(t.len(), 2);
        assert!(t.contains(20) && t.contains(21));
        // Detached children become roots of their own subtrees; their
        // recorded depth is historical, ancestry stops at the break.
        assert_eq!(t.ancestry(20), vec![20]);
        // Removing a child cleans it out of nothing (parent gone).
        assert_eq!(t.remove_by_block(1), Some(20));
        assert_eq!(t.remove_by_block(1), None, "already gone");
        assert_eq!(t.remove_by_block(5), None, "never indexed");
    }

    #[test]
    fn remove_cleans_parent_child_link() {
        let mut t = RadixTree::new();
        t.insert(10, None, 0);
        t.insert(20, Some(10), 1);
        assert!(!t.is_leaf(10));
        assert_eq!(t.remove_by_block(1), Some(20));
        assert!(t.is_leaf(10), "releasing the child must restore leaf-ness");
    }
}
