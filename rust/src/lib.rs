//! # Loki: Low-rank Keys for Efficient Sparse Attention — reproduction
//!
//! Full-system reproduction of Singhania et al., NeurIPS 2024, as a
//! three-layer Rust + JAX + Pallas stack:
//!
//! * **L3 (this crate)** — a serving coordinator (continuous batcher,
//!   prefill/decode scheduler, KV-lane manager) with Loki sparse attention
//!   as a first-class per-request attention variant, plus every substrate
//!   the paper's evaluation needs (PCA/eigen analysis, pure-Rust attention
//!   kernels at large-model shapes, synthetic corpora and task suites,
//!   benchmark harnesses).
//! * **L2/L1 (python/, build-time only)** — a llama-style JAX model whose
//!   decode hot path runs Pallas kernels, AOT-lowered to HLO text that the
//!   [`runtime`] module loads and executes via the PJRT CPU client.
//!
//! Start with [`runtime::Artifacts`] + [`model::ServedModel`] for the
//! compiled path, or [`attnsim`] for the pure-Rust substrate. See
//! `DESIGN.md` for the experiment index and `examples/` for runnable
//! entry points.

pub mod analysis;
pub mod attnsim;
pub mod coordinator;
pub mod data;
pub mod eval;
pub mod experiments;
pub mod kvpool;
pub mod linalg;
pub mod model;
pub mod obs;
pub mod runtime;
pub mod server;
pub mod tensor;
pub mod util;

/// Repo-relative default artifact directory (`make artifacts` output).
pub const ARTIFACTS_DIR: &str = "artifacts";
/// Repo-relative directory experiment harnesses write results into.
pub const RESULTS_DIR: &str = "results";
