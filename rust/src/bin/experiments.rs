//! `repro-experiments` — regenerate every table and figure of the paper.
//!
//!     repro-experiments <id> [--quick]
//!
//! ids: fig1 fig2 fig9 fig10 fig12 fig3 fig4 fig5 fig6-jaccard fig6-calib
//!      fig6-append fig7 fig7-tradeoff fig15 fig16 table1 table2 table3
//!      table5 | analysis | quality | timing | all
//!
//! Output: the paper-shaped table on stdout + results/<id>.{txt,json}.

use anyhow::Result;

use loki::experiments as ex;
use loki::runtime::RuntimeStack;
use loki::util::args::Args;
use loki::util::artifacts_dir;

#[allow(clippy::disallowed_methods)] // genuine wall measurement: per-figure runtime reporting
fn main() -> Result<()> {
    let args = Args::from_env();
    let quick = args.flag("quick") || std::env::var("LOKI_QUICK").is_ok();
    let ids: Vec<String> = if args.positional.is_empty() {
        eprintln!("usage: repro-experiments <id>|analysis|quality|timing|all [--quick]");
        return Ok(());
    } else {
        args.positional.clone()
    };

    let expand = |id: &str| -> Vec<&'static str> {
        match id {
            "analysis" => vec!["fig1", "fig2", "fig9", "fig10", "fig12"],
            "quality" => vec!["table2", "fig3", "fig4", "fig5", "fig6-calib", "fig15", "table5"],
            "timing" => vec!["fig6-jaccard", "fig6-append", "fig7", "fig7-tradeoff", "fig16",
                             "table1", "hlo-cost", "roofline"],
            "all" => vec![
                "fig1", "fig2", "fig9", "fig10", "fig12", "table1", "hlo-cost",
                "roofline", "fig6-jaccard", "fig6-append", "fig16", "fig7",
                "fig7-tradeoff", "table2", "fig3", "fig5", "fig4", "fig6-calib",
                "fig15", "table5", "table3",
            ],
            other => vec![Box::leak(other.to_string().into_boxed_str())],
        }
    };

    // The compiled runtime loads lazily (several quality experiments share it).
    let mut stack: Option<RuntimeStack> = None;
    let mut get_stack = || -> Result<&'static RuntimeStack> {
        if stack.is_none() {
            stack = Some(RuntimeStack::load(&artifacts_dir())?);
        }
        // SAFETY-free leak: the stack lives for the whole process.
        Ok(Box::leak(Box::new(stack.take().unwrap())))
    };
    let mut leaked: Option<&'static RuntimeStack> = None;
    type StackRef = &'static RuntimeStack;
    let mut runtime = |leaked: &mut Option<StackRef>| -> Result<StackRef> {
        if leaked.is_none() {
            *leaked = Some(get_stack()?);
        }
        Ok(leaked.unwrap())
    };

    for group in &ids {
        for id in expand(group) {
            let t0 = std::time::Instant::now();
            println!("\n##### {id} ################################################");
            match id {
                "fig1" => drop(ex::fig1_rank_models::run(90.0)?),
                "fig2" => drop(ex::fig2_rank_layers::run_layers(90.0)?),
                "fig9" => drop(ex::fig2_rank_layers::run_spectra()?),
                "fig10" => drop(ex::fig2_rank_layers::run_heatmap(90.0)?),
                "fig12" => drop(ex::fig2_rank_layers::run_qv(90.0)?),
                "table1" => drop(ex::table1_speedup::run()?),
                "hlo-cost" => drop(ex::hlo_cost::run()?),
                "roofline" => drop(ex::roofline_report::run()?),
                "fig6-jaccard" => drop(ex::fig6_jaccard::run(quick)?),
                "fig6-append" => drop(ex::fig6_append::run(quick)?),
                "fig7" => drop(ex::fig7_attn_time::run(quick)?),
                "fig7-tradeoff" => drop(ex::fig7_attn_time::run_tradeoff(quick)?),
                "fig16" => drop(ex::fig16_kernels::run(quick)?),
                "table2" => drop(ex::table2_ppl::run(runtime(&mut leaked)?, quick)?),
                "fig3" => drop(ex::fig3_quality_sweep::run(runtime(&mut leaked)?, quick, false)?),
                "table3" => drop(ex::fig3_quality_sweep::run(runtime(&mut leaked)?, quick, true)?),
                "fig4" => drop(ex::fig4_longbench::run(runtime(&mut leaked)?, quick)?),
                "fig5" => drop(ex::fig5_downstream::run(runtime(&mut leaked)?, quick)?),
                "fig6-calib" => drop(ex::fig6_calib::run(runtime(&mut leaked)?, quick)?),
                "fig15" => drop(ex::fig15_variable_df::run(runtime(&mut leaked)?, quick)?),
                "table5" => drop(ex::table5_pcaattn::run(runtime(&mut leaked)?, quick)?),
                other => eprintln!("unknown experiment id: {other}"),
            }
            println!("[{id} took {:.1}s]", t0.elapsed().as_secs_f64());
        }
    }
    Ok(())
}
