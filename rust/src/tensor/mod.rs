//! Minimal owned f32 tensor for the pure-Rust substrates.
//!
//! Deliberately simple: contiguous row-major storage, shape checked ops,
//! O(1) views by row range. The heavy lifting (matmuls, attention) lives
//! in [`crate::linalg`] and [`crate::attnsim`] which operate on slices for
//! zero-copy hot paths; `Tensor` is the container and bookkeeping layer.

#[derive(Clone, Debug, PartialEq)]
pub struct Tensor {
    shape: Vec<usize>,
    data: Vec<f32>,
}

impl Tensor {
    pub fn zeros(shape: &[usize]) -> Self {
        let n = shape.iter().product();
        Self { shape: shape.to_vec(), data: vec![0.0; n] }
    }

    pub fn from_vec(shape: &[usize], data: Vec<f32>) -> Self {
        assert_eq!(
            shape.iter().product::<usize>(),
            data.len(),
            "shape {shape:?} does not match data length {}",
            data.len()
        );
        Self { shape: shape.to_vec(), data }
    }

    pub fn full(shape: &[usize], v: f32) -> Self {
        let n = shape.iter().product();
        Self { shape: shape.to_vec(), data: vec![v; n] }
    }

    /// Standard-normal random tensor (testing / synthetic workloads).
    pub fn randn(shape: &[usize], rng: &mut crate::util::rng::Xoshiro256) -> Self {
        let n = shape.iter().product();
        Self { shape: shape.to_vec(), data: rng.normal_vec(n) }
    }

    pub fn shape(&self) -> &[usize] {
        &self.shape
    }

    pub fn ndim(&self) -> usize {
        self.shape.len()
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    pub fn data(&self) -> &[f32] {
        &self.data
    }

    pub fn data_mut(&mut self) -> &mut [f32] {
        &mut self.data
    }

    pub fn into_vec(self) -> Vec<f32> {
        self.data
    }

    /// Reshape in place (must preserve element count).
    pub fn reshape(mut self, shape: &[usize]) -> Self {
        assert_eq!(shape.iter().product::<usize>(), self.data.len());
        self.shape = shape.to_vec();
        self
    }

    /// Flat index of a multi-dimensional coordinate.
    pub fn idx(&self, coords: &[usize]) -> usize {
        debug_assert_eq!(coords.len(), self.shape.len());
        let mut flat = 0;
        for (c, s) in coords.iter().zip(&self.shape) {
            debug_assert!(c < s, "coord {coords:?} out of bounds for {:?}", self.shape);
            flat = flat * s + c;
        }
        flat
    }

    pub fn at(&self, coords: &[usize]) -> f32 {
        self.data[self.idx(coords)]
    }

    pub fn set(&mut self, coords: &[usize], v: f32) {
        let i = self.idx(coords);
        self.data[i] = v;
    }

    /// Borrow row `i` of a 2-D tensor.
    pub fn row(&self, i: usize) -> &[f32] {
        assert_eq!(self.ndim(), 2);
        let w = self.shape[1];
        &self.data[i * w..(i + 1) * w]
    }

    pub fn row_mut(&mut self, i: usize) -> &mut [f32] {
        assert_eq!(self.ndim(), 2);
        let w = self.shape[1];
        &mut self.data[i * w..(i + 1) * w]
    }

    /// Borrow the contiguous sub-block for a leading index of an N-D
    /// tensor (e.g. `slab(l)` of `[L, B, H, M, D]` -> `[B, H, M, D]` data).
    pub fn slab(&self, i: usize) -> &[f32] {
        let inner: usize = self.shape[1..].iter().product();
        &self.data[i * inner..(i + 1) * inner]
    }

    pub fn slab_mut(&mut self, i: usize) -> &mut [f32] {
        let inner: usize = self.shape[1..].iter().product();
        &mut self.data[i * inner..(i + 1) * inner]
    }

    // -- elementwise ---------------------------------------------------------

    pub fn map(mut self, f: impl Fn(f32) -> f32) -> Self {
        for v in &mut self.data {
            *v = f(*v);
        }
        self
    }

    pub fn add_assign(&mut self, other: &Tensor) {
        assert_eq!(self.shape, other.shape);
        for (a, b) in self.data.iter_mut().zip(&other.data) {
            *a += b;
        }
    }

    pub fn scale(&mut self, s: f32) {
        for v in &mut self.data {
            *v *= s;
        }
    }

    /// Maximum absolute elementwise difference (test helper).
    pub fn max_abs_diff(&self, other: &Tensor) -> f32 {
        assert_eq!(self.shape, other.shape);
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f32::max)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Xoshiro256;

    #[test]
    fn indexing_row_major() {
        let t = Tensor::from_vec(&[2, 3], (0..6).map(|x| x as f32).collect());
        assert_eq!(t.at(&[0, 0]), 0.0);
        assert_eq!(t.at(&[0, 2]), 2.0);
        assert_eq!(t.at(&[1, 0]), 3.0);
        assert_eq!(t.row(1), &[3.0, 4.0, 5.0]);
    }

    #[test]
    fn slab_views() {
        let t = Tensor::from_vec(&[2, 2, 2], (0..8).map(|x| x as f32).collect());
        assert_eq!(t.slab(1), &[4.0, 5.0, 6.0, 7.0]);
    }

    #[test]
    fn map_and_arith() {
        let mut a = Tensor::full(&[4], 2.0);
        let b = Tensor::full(&[4], 3.0);
        a.add_assign(&b);
        a.scale(2.0);
        assert_eq!(a.data(), &[10.0; 4]);
        let c = a.map(|x| x - 10.0);
        assert_eq!(c.data(), &[0.0; 4]);
    }

    #[test]
    fn randn_deterministic() {
        let mut r1 = Xoshiro256::new(5);
        let mut r2 = Xoshiro256::new(5);
        assert_eq!(Tensor::randn(&[8], &mut r1), Tensor::randn(&[8], &mut r2));
    }

    #[test]
    #[should_panic]
    fn shape_mismatch_panics() {
        Tensor::from_vec(&[2, 2], vec![1.0; 3]);
    }
}
