//! TPU roofline estimator for the L1 Pallas kernels (DESIGN.md §Perf).
//!
//! CPU-interpret execution gives no TPU timings, so the per-kernel TPU
//! performance claim is *estimated* from first principles: VMEM footprint
//! of the chosen BlockSpec, HBM bytes streamed per decode step, and MXU
//! utilization of the score matvec. `repro-experiments` does not ship a
//! TPU; this module makes the estimate explicit, testable and printed
//! (`roofline` id) instead of a hand-waved paragraph.

/// A TPU-generation model (defaults ≈ TPU v4: 275 TFLOP/s bf16 MXU,
/// 1.2 TB/s HBM, 16 MiB VMEM per core).
#[derive(Clone, Copy, Debug)]
pub struct TpuModel {
    pub mxu_flops: f64,
    pub hbm_bytes_per_s: f64,
    pub vmem_bytes: u64,
}

impl Default for TpuModel {
    fn default() -> Self {
        Self { mxu_flops: 275e12, hbm_bytes_per_s: 1.2e12, vmem_bytes: 16 << 20 }
    }
}

/// The Loki decode-attention kernel plan for one layer.
#[derive(Clone, Copy, Debug)]
pub struct KernelPlan {
    pub lanes: usize,    // batch · heads
    pub head_dim: usize, // D
    pub live: usize,     // S
    pub d_sub: usize,    // d_f · D
    pub k_sel: usize,    // k_f · S
    pub block_m: usize,  // sequence tile
    pub bytes_per_elem: u64,
}

#[derive(Clone, Copy, Debug)]
pub struct RooflineEstimate {
    /// Peak VMEM held by one grid step (K-tile + query + partial scores).
    pub vmem_per_step: u64,
    /// HBM bytes streamed per decode step (score K̂ slice + gathered K/V).
    pub hbm_bytes: u64,
    pub flops: f64,
    /// FLOPs / bytes — decode attention is far below the machine balance
    /// point, i.e. bandwidth-bound.
    pub arithmetic_intensity: f64,
    /// Time bounds (s) under the model.
    pub t_bandwidth: f64,
    pub t_compute: f64,
    /// Fraction of MXU peak achievable given the bandwidth bound.
    pub mxu_utilization: f64,
}

impl KernelPlan {
    pub fn paper_13b(batch: usize, live: usize, k_f: f64, d_f: f64) -> Self {
        let d = 128;
        Self {
            lanes: batch * 40,
            head_dim: d,
            live,
            d_sub: (d as f64 * d_f) as usize,
            k_sel: (live as f64 * k_f) as usize,
            block_m: 128,
            bytes_per_elem: 2, // bf16 cache
        }
    }

    pub fn estimate(&self, tpu: &TpuModel) -> RooflineEstimate {
        let be = self.bytes_per_elem;
        // One grid step holds: K̂ tile [block_m, d_sub] + q [D] + partial
        // scores [block_m] (plus double-buffering ×2 on the tile).
        let vmem_per_step = (2 * self.block_m * self.d_sub) as u64 * be
            + self.head_dim as u64 * be
            + self.block_m as u64 * 4;
        // Streamed from HBM per decode step per lane:
        //   scores: live × d_sub   (leading-slice reads) — skipped when the
        //     plan is vanilla (d_sub = D, k = S): a fused vanilla kernel
        //     reads K exactly once inside the attend stage (Eq. 5's 2DS).
        //   attend: 2 × k_sel × D  (gathered K̂ and V rows)
        let is_vanilla = self.d_sub == self.head_dim && self.k_sel == self.live;
        let score_bytes = if is_vanilla { 0 } else { self.live * self.d_sub };
        let hbm_bytes = self.lanes as u64
            * (score_bytes as u64 + (2 * self.k_sel * self.head_dim) as u64)
            * be;
        let flops = self.lanes as f64
            * (2.0 * self.live as f64 * self.d_sub as f64
                + 4.0 * self.k_sel as f64 * self.head_dim as f64);
        let ai = flops / hbm_bytes as f64;
        let t_bw = hbm_bytes as f64 / tpu.hbm_bytes_per_s;
        let t_c = flops / tpu.mxu_flops;
        RooflineEstimate {
            vmem_per_step,
            hbm_bytes,
            flops,
            arithmetic_intensity: ai,
            t_bandwidth: t_bw,
            t_compute: t_c,
            mxu_utilization: (t_c / t_bw.max(t_c)).min(1.0),
        }
    }

    /// Vanilla attention plan at the same shape (for the speedup ratio).
    pub fn vanilla(&self) -> Self {
        Self { d_sub: self.head_dim, k_sel: self.live, ..*self }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn vmem_fits_and_is_dominated_by_tile() {
        let plan = KernelPlan::paper_13b(16, 3072, 0.25, 0.25);
        let est = plan.estimate(&TpuModel::default());
        assert!(est.vmem_per_step < TpuModel::default().vmem_bytes / 8,
                "tile should be a small VMEM fraction: {}", est.vmem_per_step);
    }

    #[test]
    fn decode_attention_is_bandwidth_bound() {
        let plan = KernelPlan::paper_13b(16, 3072, 0.25, 0.25);
        let est = plan.estimate(&TpuModel::default());
        // Arithmetic intensity ≈ 2 FLOPs/byte — far under the v4 balance
        // point (275e12 / 1.2e12 ≈ 229), so bandwidth-bound.
        assert!(est.arithmetic_intensity < 8.0, "{}", est.arithmetic_intensity);
        assert!(est.t_bandwidth > est.t_compute);
        assert!(est.mxu_utilization < 0.05);
    }

    #[test]
    fn estimated_speedup_matches_eq5() {
        let loki = KernelPlan::paper_13b(16, 3072, 0.25, 0.25);
        let vanilla = loki.vanilla();
        let tpu = TpuModel::default();
        let s = vanilla.estimate(&tpu).t_bandwidth / loki.estimate(&tpu).t_bandwidth;
        let eq5 = 1.0 / (0.25 / 2.0 + 0.25);
        assert!((s - eq5).abs() / eq5 < 0.05, "roofline speedup {s:.2} vs Eq.5 {eq5:.2}");
    }
}
