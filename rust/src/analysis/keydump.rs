//! Loader for the key/query/value sample dumps
//! (`artifacts/keys_{profile}.npz`, `artifacts/family_{model}.npz`).

use std::path::Path;

use anyhow::{anyhow, Result};
use xla::{FromRawBytes, Literal};

use crate::linalg::pca::{Pca, PcaBasis};

/// `[L, H, N, D]` samples of one tensor kind for one model/corpus.
pub struct KeyDump {
    pub layers: usize,
    pub heads: usize,
    pub samples: usize,
    pub dim: usize,
    data: Vec<f32>,
}

impl KeyDump {
    /// `kind` ∈ {k_pre, k_post, q_pre, q_post, v} for keys_{profile}.npz;
    /// {k_pre, k_post} for family_{model}.npz.
    pub fn load(path: &Path, kind: &str) -> Result<Self> {
        let lits = Literal::read_npz_by_name(path, &(), &[kind])
            .map_err(|e| anyhow!("loading {kind} from {}: {e}", path.display()))?;
        let lit = &lits[0];
        let shape = lit.array_shape().map_err(|e| anyhow!("{e}"))?;
        let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
        anyhow::ensure!(dims.len() == 4, "expected [L,H,N,D], got {dims:?}");
        Ok(Self {
            layers: dims[0],
            heads: dims[1],
            samples: dims[2],
            dim: dims[3],
            data: lit.to_vec::<f32>().map_err(|e| anyhow!("{e}"))?,
        })
    }

    /// The `[N, D]` sample block for one (layer, head).
    pub fn block(&self, layer: usize, head: usize) -> &[f32] {
        let n = self.samples * self.dim;
        let off = (layer * self.heads + head) * n;
        &self.data[off..off + n]
    }

    /// Fit PCA for one (layer, head).
    pub fn pca(&self, layer: usize, head: usize) -> PcaBasis {
        Pca::fit(self.block(layer, head), self.samples, self.dim)
    }

    /// Fit PCA for every (layer, head); row-major `[layers][heads]`.
    pub fn pca_all(&self) -> Vec<Vec<PcaBasis>> {
        (0..self.layers)
            .map(|l| (0..self.heads).map(|h| self.pca(l, h)).collect())
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::artifacts_dir;

    #[test]
    fn loads_main_dump_and_fits() {
        let p = artifacts_dir().join("keys_wiki.npz");
        if !p.exists() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let dump = KeyDump::load(&p, "k_post").unwrap();
        assert!(dump.layers >= 1 && dump.heads >= 1);
        assert!(dump.samples >= 128);
        let basis = dump.pca(0, 0);
        assert_eq!(basis.dim, dump.dim);
        // Eigenvalues sum to ~1 and are descending.
        let sum: f32 = basis.eigenvalues.iter().sum();
        assert!((sum - 1.0).abs() < 1e-3, "sum {sum}");
        for w in basis.eigenvalues.windows(2) {
            assert!(w[0] >= w[1] - 1e-6);
        }
    }

    #[test]
    fn rust_pca_matches_python_spectrum() {
        // The python pipeline stored its own eigenvalues; recomputing from
        // the dumped samples with the Jacobi solver should land close
        // (the dump is a subsample of the calibration set, so tolerances
        // are loose but shape-preserving).
        let dir = artifacts_dir();
        let kp = dir.join("keys_wiki.npz");
        let pp = dir.join("pca_wiki_post.npz");
        if !kp.exists() || !pp.exists() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let dump = KeyDump::load(&kp, "k_post").unwrap();
        let lits = Literal::read_npz_by_name(&pp, &(), &["eig"]).unwrap();
        let py_eig = lits[0].to_vec::<f32>().unwrap();
        let d = dump.dim;
        // Compare Rank@90 per (layer, head) — the metric the paper uses.
        let mut diffs = Vec::new();
        for l in 0..dump.layers {
            for h in 0..dump.heads {
                let rust_rank = dump.pca(l, h).rank_at(90.0) as i64;
                let off = (l * dump.heads + h) * d;
                let mut cum = 0.0;
                let mut py_rank = d as i64;
                for (i, &e) in py_eig[off..off + d].iter().enumerate() {
                    cum += e as f64;
                    if cum >= 0.9 {
                        py_rank = i as i64 + 1;
                        break;
                    }
                }
                diffs.push((rust_rank - py_rank).abs());
            }
        }
        let max_diff = diffs.iter().max().copied().unwrap_or(0);
        assert!(max_diff <= 6, "Rank@90 diverges between rust/python PCA: {max_diff}");
    }
}
