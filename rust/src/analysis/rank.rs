//! Rank@v aggregation (Eq. 2) over (layer, head) PCA spectra.

use crate::linalg::pca::PcaBasis;

/// Per-layer rank statistics at a variance threshold.
#[derive(Clone, Debug)]
pub struct RankStats {
    pub v_pct: f64,
    /// `[layers]` mean rank across heads.
    pub per_layer: Vec<f64>,
    /// `[layers][heads]` raw ranks (the heatmap of App. Figs 10/11).
    pub per_head: Vec<Vec<usize>>,
    pub dim: usize,
}

impl RankStats {
    /// Mean of the per-layer means (the Fig-1 scalar per model).
    pub fn model_mean(&self) -> f64 {
        if self.per_layer.is_empty() {
            return 0.0;
        }
        self.per_layer.iter().sum::<f64>() / self.per_layer.len() as f64
    }
}

/// Compute Rank@v for a `[layers][heads]` PCA grid.
pub fn rank_table(bases: &[Vec<PcaBasis>], v_pct: f64) -> RankStats {
    let per_head: Vec<Vec<usize>> = bases
        .iter()
        .map(|row| row.iter().map(|b| b.rank_at(v_pct)).collect())
        .collect();
    let per_layer = per_head
        .iter()
        .map(|row| {
            if row.is_empty() {
                0.0
            } else {
                row.iter().sum::<usize>() as f64 / row.len() as f64
            }
        })
        .collect();
    let dim = bases
        .first()
        .and_then(|r| r.first())
        .map(|b| b.dim)
        .unwrap_or(0);
    RankStats { v_pct, per_layer, per_head, dim }
}

/// Eigen-spectrum (normalized eigenvalues) of one basis — App. Fig 9.
pub fn spectrum(basis: &PcaBasis) -> Vec<f32> {
    basis.eigenvalues.clone()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::pca::Pca;
    use crate::util::rng::Xoshiro256;

    fn basis_with_effective_rank(r: usize, d: usize, seed: u64) -> PcaBasis {
        let mut rng = Xoshiro256::new(seed);
        let n = 600;
        let mut samples = vec![0.0f32; n * d];
        for row in samples.chunks_exact_mut(d) {
            for (j, x) in row.iter_mut().enumerate() {
                let scale = if j < r { 1.0 } else { 0.01 };
                *x = rng.normal_f32() * scale;
            }
        }
        Pca::fit(&samples, n, d)
    }

    #[test]
    fn aggregates_per_layer_means() {
        let grid = vec![
            vec![basis_with_effective_rank(2, 16, 1), basis_with_effective_rank(4, 16, 2)],
            vec![basis_with_effective_rank(8, 16, 3), basis_with_effective_rank(8, 16, 4)],
        ];
        let stats = rank_table(&grid, 90.0);
        assert_eq!(stats.per_head.len(), 2);
        assert!(stats.per_layer[0] < stats.per_layer[1]);
        let mm = stats.model_mean();
        assert!(mm > 0.0 && mm < 16.0);
        // Low-rank layers report low Rank@90.
        assert!(stats.per_head[0][0] <= 4, "{:?}", stats.per_head);
    }

    #[test]
    fn spectrum_is_normalized() {
        let b = basis_with_effective_rank(3, 8, 9);
        let s = spectrum(&b);
        let sum: f32 = s.iter().sum();
        assert!((sum - 1.0).abs() < 1e-4);
    }
}
