//! Dimensionality analysis (paper §3, Appendix A) and the theoretical
//! cost model (Eq. 5 / Table 1).
//!
//! * [`keydump`] — loads the key/query/value samples exported per
//!   calibration corpus and recomputes PCA with the Rust eigensolver
//!   (cross-validated against the python spectra in tests).
//! * [`rank`]    — Rank@v aggregation across layers/heads (Eq. 2),
//!   eigen-spectra extraction, head×layer heatmaps.
//! * [`speedup`] — the Eq.-5 closed-form speedup model and Table-1
//!   budget accounting, validated against measured byte movement.

pub mod keydump;
pub mod rank;
pub mod roofline;
pub mod speedup;

pub use keydump::KeyDump;
pub use rank::{rank_table, RankStats};
pub use speedup::{loki_speedup, memory_saving, SpeedupModel};
