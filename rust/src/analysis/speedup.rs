//! Eq. 5 / Table 1: the paper's closed-form cost model for attention
//! variants, and its validation hooks against measured byte movement.

/// Closed-form per-step attention cost model (counts multiply-accumulate
/// ops of the score + AV stages, plus Loki's extras). Mirrors §4.2.
#[derive(Clone, Copy, Debug)]
pub struct SpeedupModel {
    /// Head dimension D.
    pub d_full: usize,
    /// Sequence/cache length S.
    pub seq: usize,
}

impl SpeedupModel {
    pub fn vanilla_cost(&self) -> f64 {
        // O(2·D·S): q·Kᵀ plus a·V.
        2.0 * self.d_full as f64 * self.seq as f64
    }

    pub fn loki_cost(&self, d_f: f64, k_f: f64) -> f64 {
        let d = d_f * self.d_full as f64;
        let k = k_f * self.seq as f64;
        // Eq. 5 numerator terms: d·S (approx scores) + 2·D·k (exact part)
        // + 2·D² (query/key rotations).
        d * self.seq as f64
            + 2.0 * self.d_full as f64 * k
            + 2.0 * (self.d_full as f64).powi(2)
    }

    pub fn exact_topk_cost(&self, k_f: f64) -> f64 {
        // Full scores + top-k AV: D·S + 2·D·k — no speedup on scores.
        self.d_full as f64 * self.seq as f64
            + 2.0 * self.d_full as f64 * k_f * self.seq as f64
    }

    pub fn h2o_cost(&self, k_f: f64) -> f64 {
        // Attention over a k_f cache: 2·D·k.
        2.0 * self.d_full as f64 * k_f * self.seq as f64
    }

    pub fn pcaattn_cost(&self, d_f: f64) -> f64 {
        // d·S scores + D·S AV (values stay full-dimensional).
        (d_f + 1.0) * self.d_full as f64 * self.seq as f64
    }

    /// Speedup of Loki over vanilla (Eq. 5).
    pub fn loki_speedup(&self, d_f: f64, k_f: f64) -> f64 {
        self.vanilla_cost() / self.loki_cost(d_f, k_f)
    }

    /// The S→∞ asymptote 1/(d_f/2 + k_f).
    pub fn loki_speedup_asymptote(d_f: f64, k_f: f64) -> f64 {
        1.0 / (d_f / 2.0 + k_f)
    }
}

/// Convenience free function (Table 1 row for Loki).
pub fn loki_speedup(d: usize, s: usize, d_f: f64, k_f: f64) -> f64 {
    SpeedupModel { d_full: d, seq: s }.loki_speedup(d_f, k_f)
}

/// Table 1 memory column: H2O's KV-cache shrinks by 1/k_f; Loki and
/// Exact-TopK keep the full cache.
pub fn memory_saving(variant: &str, k_f: f64) -> f64 {
    match variant {
        "h2o" => 1.0 / k_f,
        _ => 1.0,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_headline_speedup() {
        // k_f = d_f = 0.25 → asymptotic 1/(0.125+0.25) ≈ 2.67× ("2.6x" in §5).
        let a = SpeedupModel::loki_speedup_asymptote(0.25, 0.25);
        assert!((a - 2.6667).abs() < 1e-3, "{a}");
        // Same asymptote for (k_f=0.125, d_f=0.5): 1/(0.25+0.125) = 2.67.
        let b = SpeedupModel::loki_speedup_asymptote(0.5, 0.125);
        assert!((a - b).abs() < 1e-9);
    }

    #[test]
    fn finite_s_speedup_below_asymptote() {
        let m = SpeedupModel { d_full: 128, seq: 4096 };
        let s = m.loki_speedup(0.25, 0.25);
        let a = SpeedupModel::loki_speedup_asymptote(0.25, 0.25);
        assert!(s < a);
        assert!(s > 0.8 * a, "finite-S {s} vs asymptote {a}");
        // Longer context → closer to the asymptote.
        let m2 = SpeedupModel { d_full: 128, seq: 65536 };
        assert!(m2.loki_speedup(0.25, 0.25) > s);
    }

    #[test]
    fn cost_model_orderings() {
        let m = SpeedupModel { d_full: 128, seq: 3072 };
        // Loki cheaper than vanilla and exact top-k at paper settings.
        assert!(m.loki_cost(0.25, 0.25) < m.vanilla_cost());
        assert!(m.loki_cost(0.25, 0.25) < m.exact_topk_cost(0.25));
        // H2O (smaller cache) is the cheapest — its cost is memory, not compute.
        assert!(m.h2o_cost(0.25) < m.loki_cost(0.25, 0.25));
        assert_eq!(memory_saving("h2o", 0.25), 4.0);
        assert_eq!(memory_saving("loki", 0.25), 1.0);
    }
}
